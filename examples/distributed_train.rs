//! Distributed training smoke (ISSUE 5): the native pipeline over REAL
//! transports, with the bitwise-parity and wire-size contracts asserted.
//!
//! Three runs of the tiny 4-stage subspace config (Grassmann updates
//! on, so the U-basis broadcast path is exercised too):
//!
//!   1. single-process `NativePipeline`, 200 steps — the reference;
//!   2. distributed over the **channel** transport (4 workers on
//!      threads, framed `mpsc`), 200 steps — per-step losses must be
//!      **bitwise identical** to the reference: every worker replays
//!      the same seeded init/data streams and the wire is
//!      bit-transparent, so any divergence is a protocol bug;
//!   3. distributed over **TCP loopback** (real sockets, one OS thread
//!      per stage), 40 steps — the same bitwise contract holds: thread
//!      and socket scheduling may reorder wall-clock, never arithmetic.
//!
//! Plus a 40-step raw-mode channel run for the wire claim: subspace
//! boundary frames must be ≥ 10x smaller than raw on the wire, with
//! every frame's payload equal to `compress::wire_bytes` (checked
//! inside the workers on every frame, and re-checked here against the
//! `memory::transport_frame_bytes` model).
//!
//!     cargo run --release --example distributed_train

use protomodels::compress::{wire_bytes, Mode};
use protomodels::coordinator::PipelineConfig;
use protomodels::data::CorpusKind;
use protomodels::manifest::Hyper;
use protomodels::memory;
use protomodels::netsim::{LinkSpec, Topology};
use protomodels::nn::{NativePipeline, Optim};
use protomodels::rng::Rng;
use protomodels::transport::{
    launch, reference_dp_losses, run_local, Reduce, TrainSpec,
    TransportKind, WorkerSpec,
};

const STEPS: usize = 200;
const TCP_STEPS: usize = 40;
const GRID_STEPS: usize = 6;
const SEED: u64 = 5;

fn spec(mode: Mode, steps: usize) -> WorkerSpec {
    WorkerSpec {
        h: Hyper::tiny_native(),
        cfg: PipelineConfig {
            mode,
            microbatches: 2,
            // exercise the Grassmann U-broadcast over the wire
            grassmann_interval: 50,
            lr: 1e-2,
            warmup_steps: 6,
            total_steps: steps,
            seed: SEED,
            ..Default::default()
        },
        optim: Optim::AdamW,
        steps,
        corpus_kind: CorpusKind::Wiki,
        corpus_tokens: 200_000,
    }
}

/// Reference: the single-process native backend under the same spec.
fn single_process_losses(s: &WorkerSpec) -> Vec<f64> {
    let h = s.h.clone();
    let mut rng = Rng::new(SEED);
    let topo =
        Topology::uniform(h.stages, LinkSpec::internet_80m(), &mut rng);
    let corpus = s.corpus();
    let mut pipe =
        NativePipeline::new(h.clone(), topo, s.cfg.clone(), s.optim)
            .expect("native pipeline");
    (0..s.steps)
        .map(|_| {
            pipe.train_step(|r| corpus.train_batch(h.b, h.n, r))
                .expect("train step")
                .loss
        })
        .collect()
}

fn assert_bitwise(label: &str, reference: &[f64], got: &[f64]) {
    assert_eq!(
        reference.len(),
        got.len(),
        "{label}: {} steps vs reference {}",
        got.len(),
        reference.len()
    );
    for (i, (a, b)) in reference.iter().zip(got).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{label}: loss diverged at step {} ({a} vs {b})",
            i + 1
        );
    }
}

fn main() {
    let sub = spec(Mode::Subspace, STEPS);
    let h = sub.h.clone();
    println!(
        "distributed smoke: d={} k={} stages={} microbatches={} — \
         {STEPS} channel steps, {TCP_STEPS} tcp steps\n",
        h.d, h.k, h.stages, sub.cfg.microbatches
    );

    // ---- reference curve (single process)
    let reference = single_process_losses(&sub);

    // ---- channel transport: full-length bitwise parity
    let chan = run_local(&sub, TransportKind::Channel).expect("channel run");
    assert_bitwise("channel", &reference, &chan.losses);
    println!(
        "channel: {} steps bitwise-identical to single-process \
         (final loss {:.4}, mean step {:.2} ms)",
        STEPS,
        chan.losses.last().unwrap(),
        chan.mean_step_seconds() * 1e3
    );

    // ---- TCP loopback: real sockets, same arithmetic. Keep the
    // 200-step lr schedule (cfg.total_steps) and run only the first 40
    // steps, so the curve is a strict prefix of the reference.
    let mut tcp_spec = spec(Mode::Subspace, STEPS);
    tcp_spec.steps = TCP_STEPS;
    let tcp = run_local(&tcp_spec, TransportKind::Tcp).expect("tcp run");
    assert_bitwise("tcp", &reference[..TCP_STEPS], &tcp.losses);
    println!(
        "tcp:     {} steps bitwise-identical over loopback sockets \
         (mean step {:.2} ms)",
        TCP_STEPS,
        tcp.mean_step_seconds() * 1e3
    );

    // ---- wire-size claim: subspace frames ~10x smaller than raw
    let raw_spec = spec(Mode::Raw, TCP_STEPS);
    let raw = run_local(&raw_spec, TransportKind::Channel).expect("raw run");
    // per-frame payloads match the analytic wire accounting exactly
    // (workers hard-assert this on every received frame; re-derive here)
    let sub_frame = tcp.frame_payload_bytes;
    let raw_frame = raw.frame_payload_bytes;
    assert_eq!(
        sub_frame,
        wire_bytes(Mode::Subspace, h.b, h.n, h.d, h.k, h.ratio),
        "subspace frame payload != compress::wire_bytes"
    );
    assert_eq!(
        raw_frame,
        wire_bytes(Mode::Raw, h.b, h.n, h.d, h.k, h.ratio),
        "raw frame payload != compress::wire_bytes"
    );
    assert_eq!(
        memory::transport_frame_bytes(&h, Mode::Subspace),
        sub_frame + protomodels::transport::HEADER_LEN,
        "memory model disagrees with the frame layout"
    );
    let ratio = raw_frame as f64 / sub_frame as f64;
    assert!(
        ratio >= 10.0,
        "subspace frames only {ratio:.1}x smaller than raw (need >= 10x)"
    );
    // and the totals agree: equal step counts, equal frame counts,
    // payload totals in exactly the per-frame ratio
    assert_eq!(tcp.frames, raw.frames, "frame counts must match");
    let total_ratio =
        raw.boundary_payload_bytes as f64 / tcp.boundary_payload_bytes as f64;
    assert!(
        (total_ratio - ratio).abs() / ratio < 1e-9,
        "total payload ratio {total_ratio:.3} != per-frame ratio {ratio:.3}"
    );

    println!(
        "wire:    subspace {sub_frame} B/frame vs raw {raw_frame} B/frame \
         -> {ratio:.1}x smaller on the wire ({} frames, {} payload B \
         total at {} steps)",
        tcp.frames, tcp.boundary_payload_bytes, TCP_STEPS
    );
    // ---- R×P grid (DESIGN.md §14): 2 replicas × 4 stages on the
    // channel backend with the ring all-reduce, launched through the
    // unified TrainSpec/Topology API; the grid's mean loss curve must
    // be bitwise the in-process replica reference (shared init, ring
    // order adds, exact codec arithmetic on every gradient hop)
    let grid = TrainSpec::builder(h.clone())
        .mode(Mode::Subspace)
        .steps(GRID_STEPS)
        .microbatches(2)
        .seed(SEED)
        .lr(1e-2)
        .warmup(6)
        .grassmann(0)
        .corpus(CorpusKind::Wiki, 200_000)
        .replicas(2)
        .dp_mode(Mode::Subspace)
        .reduce(Reduce::Ring)
        .build()
        .expect("grid spec");
    let want = reference_dp_losses(&grid).expect("replica reference");
    let rep = launch(&grid.topology(TransportKind::Channel), &grid)
        .expect("grid run");
    assert_bitwise("ring grid", &want, &rep.losses);
    assert!(rep.dp_payload_bytes > 0, "no gradient bytes crossed the mesh");
    println!(
        "grid:    2x{} ring grid, {} steps bitwise-identical to the \
         in-process replica path ({} gradient payload B on the mesh)",
        h.stages, GRID_STEPS, rep.dp_payload_bytes
    );

    println!(
        "\nok: the pipeline trains over real framed transports with a \
         bitwise-identical loss curve and a {ratio:.1}x subspace wire \
         reduction"
    );
}
