//! The paper's headline claim, computed rather than priced (ISSUE 4):
//! activations and activation-gradients confined to a k-dimensional
//! subspace with full reconstruction downstream lose **nothing** —
//! subspace training tracks the uncompressed loss curve at a >10x wire
//! reduction — while magnitude top-k at *matched* wire bytes falls
//! measurably behind and int8 buys nothing for 2.7x more bytes,
//! exactly the failure of naive activation compression Bian et al.
//! observed.
//!
//! Four tiny transformers train natively (no AOT artifacts, no PJRT)
//! on the in-process autodiff backend, with **identical seeds, weight
//! init, and data order** (the init RNG stream is mode-aligned, see
//! `stage.rs`) — the runs differ only in the stage-boundary codec:
//!
//!   subspace — (b·n, k) coefficients, lossless by the Eq. 7 closure
//!   raw      — uncompressed (b·n, d) activations
//!   topk     — magnitude top-k at exactly subspace's wire bytes
//!   quant    — int8, which still ships ~2.7x more bytes than subspace
//!   raw-bf16 — raw math with a bf16 wire: half of raw's bytes, and the
//!              asserted convergence envelope is within 2% of f32 raw
//!
//! The asserted statistic is the mean training loss over steps 51..500
//! ("curve level" — how the ISSUE words it: subspace must *track the
//! raw loss curve*), which averages 450 samples and is far less
//! endpoint-sensitive than a final loss; final val losses are printed
//! and parity-checked too. Acceptance:
//!   (a) subspace ships ≥ 10x fewer boundary bytes than raw;
//!   (b) subspace within 2% of raw — on the curve level and on final
//!       val loss (it in fact *beats* raw at this scale: the frozen
//!       high-rank embedding + rank-k trainable residual is a strong
//!       prior on Zipfian token data);
//!   (c) topk at matched bytes measurably (> 3%) worse than subspace;
//!   (d) int8 measurably (> 1.5%) worse than subspace despite 2.7x
//!       more wire bytes — subspace Pareto-dominates it.
//!
//! Thresholds sized from a python line-port of the full backend over
//! five seeds at 500 steps (curve-level ratios at this seed: sub/raw
//! 0.96, topk/sub 1.07, quant/sub 1.04 — every assertion has ≥ 1.7x
//! headroom; across seeds topk/sub never fell below 1.045).
//!
//!     cargo run --release --example native_convergence

use protomodels::compress::Mode;
use protomodels::coordinator::PipelineConfig;
use protomodels::data::{Corpus, CorpusKind};
use protomodels::manifest::Hyper;
use protomodels::netsim::{LinkSpec, Topology};
use protomodels::nn::{NativePipeline, Optim};
use protomodels::rng::Rng;

const STEPS: usize = 500;
/// Steps discarded before the curve-level mean (warmup + takeoff).
const BURN_IN: usize = 50;
const SEED: u64 = 5;

struct Outcome {
    mode: Mode,
    val_loss: f64,
    curve_level: f64,
    boundary_bytes: usize,
}

fn run(mode: Mode) -> Outcome {
    let h = Hyper::tiny_native();
    let mut rng = Rng::new(SEED);
    let topo =
        Topology::uniform(h.stages, LinkSpec::internet_80m(), &mut rng);
    let pcfg = PipelineConfig {
        mode,
        microbatches: 2,
        grassmann_interval: 0,
        lr: 1e-2,
        warmup_steps: 6,
        total_steps: STEPS,
        seed: SEED,
        ..Default::default()
    };
    let mut pipe = NativePipeline::new(h.clone(), topo, pcfg, Optim::AdamW)
        .expect("native pipeline");
    let corpus =
        Corpus::synthetic(CorpusKind::Wiki, h.vocab, 200_000, SEED ^ 0xDD);
    let mut post_burn = Vec::new();
    for step in 0..STEPS {
        let s = pipe
            .train_step(|r| corpus.train_batch(h.b, h.n, r))
            .expect("train step");
        if step >= BURN_IN {
            post_burn.push(s.loss);
        }
    }
    let val = pipe
        .eval(8, |r| corpus.val_batch(h.b, h.n, r))
        .expect("eval");
    Outcome {
        mode,
        val_loss: val,
        curve_level: post_burn.iter().sum::<f64>()
            / post_burn.len() as f64,
        boundary_bytes: pipe.boundary_bytes(),
    }
}

fn main() {
    let h = Hyper::tiny_native();
    println!(
        "native convergence: d={} k={} stages={} — {} steps per mode\n",
        h.d, h.k, h.stages, STEPS
    );
    let outcomes: Vec<Outcome> = [
        Mode::Subspace,
        Mode::Raw,
        Mode::TopK,
        Mode::Quant,
        Mode::RawBf16,
    ]
    .into_iter()
    .map(run)
    .collect();
    println!(
        "{:>10} {:>12} {:>10} {:>14} {:>10}",
        "mode", "curve level", "val loss", "boundary B", "vs raw"
    );
    let raw_bytes = outcomes[1].boundary_bytes;
    for o in &outcomes {
        println!(
            "{:>10} {:>12.4} {:>10.4} {:>14} {:>9.1}x",
            o.mode.as_str(),
            o.curve_level,
            o.val_loss,
            o.boundary_bytes,
            raw_bytes as f64 / o.boundary_bytes as f64
        );
    }
    let (sub, raw, topk, quant, raw_bf16) = (
        &outcomes[0],
        &outcomes[1],
        &outcomes[2],
        &outcomes[3],
        &outcomes[4],
    );

    // (a) ≥ 10x fewer boundary wire bytes than raw
    let compression = raw.boundary_bytes as f64 / sub.boundary_bytes as f64;
    assert!(
        compression >= 10.0,
        "subspace compression {compression:.1}x below the 10x bar"
    );
    // (b) convergence parity: subspace within 2% of raw, on the curve
    // level and on the final val loss
    assert!(
        sub.curve_level <= raw.curve_level * 1.02,
        "subspace curve level {:.4} not within 2% of raw {:.4}",
        sub.curve_level,
        raw.curve_level
    );
    assert!(
        sub.val_loss <= raw.val_loss * 1.02,
        "subspace val loss {:.4} not within 2% of raw {:.4}",
        sub.val_loss,
        raw.val_loss
    );
    // (c) top-k at MATCHED bytes falls measurably behind
    assert!(
        topk.boundary_bytes as f64 <= sub.boundary_bytes as f64 * 1.1,
        "topk bytes {} not matched to subspace {}",
        topk.boundary_bytes,
        sub.boundary_bytes
    );
    assert!(
        topk.curve_level > sub.curve_level * 1.03,
        "topk at matched bytes should degrade: {:.4} vs subspace {:.4}",
        topk.curve_level,
        sub.curve_level
    );
    // (d) int8 is measurably worse than subspace despite shipping
    // ~2.7x MORE bytes — Pareto-dominated
    assert!(
        quant.boundary_bytes as f64 >= sub.boundary_bytes as f64 * 2.5,
        "int8 bytes {} unexpectedly near subspace's {}",
        quant.boundary_bytes,
        sub.boundary_bytes
    );
    assert!(
        quant.curve_level > sub.curve_level * 1.015,
        "int8 should trail subspace: {:.4} vs {:.4}",
        quant.curve_level,
        sub.curve_level
    );
    // (e) bf16 convergence envelope: the raw-bf16 wire (truncate to
    // bf16 on encode, widen exactly on decode — DESIGN.md §13) halves
    // the raw wire and stays within 2% of f32-raw on the curve level
    // and the final val loss — bf16's ~2⁻⁷ relative boundary error is
    // noise next to SGD noise, unlike int8's
    assert_eq!(
        raw.boundary_bytes,
        2 * raw_bf16.boundary_bytes,
        "raw-bf16 must ship exactly half of raw's boundary bytes"
    );
    assert!(
        raw_bf16.curve_level <= raw.curve_level * 1.02,
        "raw-bf16 curve level {:.4} not within 2% of f32 raw {:.4}",
        raw_bf16.curve_level,
        raw.curve_level
    );
    assert!(
        raw_bf16.val_loss <= raw.val_loss * 1.02,
        "raw-bf16 val loss {:.4} not within 2% of f32 raw {:.4}",
        raw_bf16.val_loss,
        raw.val_loss
    );

    println!(
        "\nok: subspace tracks raw ({:+.2}% curve, {:+.2}% val) at \
         {compression:.1}x fewer boundary bytes; topk at matched bytes is \
         {:.1}% worse, int8 {:.1}% worse at {:.1}x subspace's bytes; \
         raw-bf16 tracks raw ({:+.2}% curve) at half the wire",
        (sub.curve_level / raw.curve_level - 1.0) * 100.0,
        (sub.val_loss / raw.val_loss - 1.0) * 100.0,
        (topk.curve_level / sub.curve_level - 1.0) * 100.0,
        (quant.curve_level / sub.curve_level - 1.0) * 100.0,
        quant.boundary_bytes as f64 / sub.boundary_bytes as f64,
        (raw_bf16.curve_level / raw.curve_level - 1.0) * 100.0
    );
}
