//! Fig. 6 scenario as a runnable example: at a matched compression ratio,
//! the DDP-style lossy schemes (top-k, int8 quantization, power-iteration
//! low-rank) injure convergence — error accumulates across pipeline
//! stages (Statement 7.1 / Theorem B.1) — while the subspace scheme
//! matches the uncompressed baseline.
//!
//!     cargo run --release --example lossy_baselines [steps]

use protomodels::compress::Mode;
use protomodels::coordinator::{Pipeline, PipelineConfig};
use protomodels::data::{Corpus, CorpusKind};
use protomodels::manifest::Manifest;
use protomodels::netsim::{LinkSpec, Topology};
use protomodels::rng::Rng;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(120);
    let manifest = Manifest::load("artifacts")?;
    let config = "small";
    let h = manifest.config(config)?.hyper.clone();
    println!(
        "== lossy baselines on {config}: ratio {}x, {steps} steps ==",
        h.ratio
    );

    println!("{:<22} {:>10} {:>10} {:>12}", "scheme", "loss@25%", "loss@end", "wire B/step");
    for (label, mode) in [
        ("uncompressed", Mode::Raw),
        ("ours (subspace)", Mode::Subspace),
        ("top-k", Mode::TopK),
        ("quant int8", Mode::Quant),
        ("low-rank (power)", Mode::PowerLR),
    ] {
        let mut rng = Rng::new(21);
        let topo =
            Topology::uniform(h.stages, LinkSpec::centralized_100g(), &mut rng);
        let pcfg = PipelineConfig {
            mode,
            microbatches: 8,
            grassmann_interval: 0,
            lr: 6e-3,
            warmup_steps: 10,
            total_steps: steps,
            seed: 21,
            ..Default::default()
        };
        let mut pipe = Pipeline::new(&manifest, config, topo, pcfg)?;
        let corpus =
            Corpus::synthetic(CorpusKind::Wiki, h.vocab, 400_000, 21);
        let mut quarter = f64::NAN;
        let mut last = f64::NAN;
        let mut wire = 0u64;
        for step in 0..steps {
            let s = pipe.train_step(|r| corpus.train_batch(h.b, h.n, r))?;
            if step == steps / 4 {
                quarter = s.loss;
            }
            last = s.loss;
            wire = s.wire_bytes;
        }
        println!("{label:<22} {quarter:>10.4} {last:>10.4} {wire:>12}");
    }
    Ok(())
}
