//! Quickstart: build a compressed pipeline on the `tiny` config, train a
//! few steps over simulated 80 Mbps links, and inspect what the system
//! gives you: loss, simulated wall-clock, bytes on the wire, and the
//! subspace-closure diagnostic.
//!
//!     make artifacts && cargo run --release --example quickstart

use protomodels::compress::Mode;
use protomodels::coordinator::{Pipeline, PipelineConfig};
use protomodels::data::{Corpus, CorpusKind};
use protomodels::manifest::Manifest;
use protomodels::netsim::{LinkSpec, Topology};
use protomodels::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. load the AOT artifact manifest (python ran once, at build time)
    let manifest = Manifest::load("artifacts")?;
    let h = manifest.config("tiny")?.hyper.clone();
    println!(
        "model: {} params, {} layers on {} stages, d={}, k={} ({}x wire compression)",
        h.param_count, h.layers, h.stages, h.d, h.k, h.ratio
    );

    // 2. a decentralized topology: consumer links between stages
    let mut rng = Rng::new(42);
    let topo = Topology::uniform(h.stages, LinkSpec::internet_80m(), &mut rng);

    // 3. the coordinator: GPipe microbatching + subspace compression
    let pcfg = PipelineConfig {
        mode: Mode::Subspace,
        microbatches: 8,
        grassmann_interval: 20, // paper uses 500; shortened for the demo
        lr: 1e-2,
        warmup_steps: 5,
        total_steps: 60,
        seed: 42,
        ..Default::default()
    };
    let mut pipe = Pipeline::new(&manifest, "tiny", topo, pcfg)?;

    // 4. synthetic corpus (offline stand-in for WikiText)
    let corpus = Corpus::synthetic(CorpusKind::Wiki, h.vocab, 200_000, 42);

    // 5. train
    for step in 0..60 {
        let s = pipe.train_step(|r| corpus.train_batch(h.b, h.n, r))?;
        if step % 10 == 0 || step == 59 {
            println!(
                "step {:>3}  loss {:.4}  sim {:>7.4}s  wire {:>8} B  leak {:.1e}",
                s.step,
                s.loss,
                s.sim_seconds,
                s.wire_bytes,
                pipe.subspace_leak()
            );
        }
    }

    // 6. validation
    let val = pipe.eval(4, |r| corpus.val_batch(h.b, h.n, r))?;
    println!(
        "val loss {:.4} (ppl {:.1}) after {:.2} simulated seconds",
        val,
        val.exp(),
        pipe.clock
    );
    Ok(())
}
