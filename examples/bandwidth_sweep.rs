//! Fig. 4 / 13 scenario as a runnable example: throughput of the
//! compressed vs uncompressed pipeline as the inter-stage bandwidth
//! shrinks from datacenter (100 Gbps) to consumer internet (10 Mbps),
//! for both training and (forward-only) inference serving.
//!
//!     cargo run --release --example bandwidth_sweep

use protomodels::compress::Mode;
use protomodels::coordinator::{Pipeline, PipelineConfig};
use protomodels::data::{Corpus, CorpusKind};
use protomodels::manifest::Manifest;
use protomodels::netsim::{LinkSpec, Topology, GBPS, MBPS};
use protomodels::rng::Rng;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts")?;
    let config = "small";
    let h = manifest.config(config)?.hyper.clone();
    let corpus = Corpus::synthetic(CorpusKind::C4, h.vocab, 200_000, 7);

    let bws: &[(&str, f64)] = &[
        ("10mbps", 10.0 * MBPS),
        ("80mbps", 80.0 * MBPS),
        ("500mbps", 500.0 * MBPS),
        ("1gbps", 1.0 * GBPS),
        ("16gbps", 16.0 * GBPS),
        ("100gbps", 100.0 * GBPS),
    ];
    println!(
        "{:<10} {:>14} {:>14} {:>8} | {:>14} {:>14} {:>8}",
        "bandwidth", "train raw", "train ours", "gain",
        "infer raw", "infer ours", "gain"
    );
    for (name, bps) in bws {
        let mut tps = std::collections::BTreeMap::new();
        for mode in [Mode::Raw, Mode::Subspace] {
            let mut rng = Rng::new(9);
            let spec = if *bps >= GBPS {
                LinkSpec::new(*bps, 100e-6)
            } else {
                LinkSpec::internet(*bps)
            };
            let topo = Topology::uniform(h.stages, spec, &mut rng);
            let pcfg = PipelineConfig {
                mode,
                microbatches: 8,
                grassmann_interval: 0,
                total_steps: 100,
                ..Default::default()
            };
            let mut pipe = Pipeline::new(&manifest, config, topo, pcfg)?;
            let mut t = 0.0;
            let mut toks = 0usize;
            for _ in 0..3 {
                let s = pipe.train_step(|r| corpus.train_batch(h.b, h.n, r))?;
                t += s.sim_seconds;
                toks += s.tokens;
            }
            tps.insert((mode.as_str(), "train"), toks as f64 / t);
            let (ti, tki) =
                pipe.forward_throughput(24, |r| corpus.val_batch(h.b, h.n, r))?;
            tps.insert((mode.as_str(), "infer"), tki as f64 / ti);
        }
        println!(
            "{:<10} {:>12.0}/s {:>12.0}/s {:>7.1}x | {:>12.0}/s {:>12.0}/s {:>7.1}x",
            name,
            tps[&("raw", "train")],
            tps[&("subspace", "train")],
            tps[&("subspace", "train")] / tps[&("raw", "train")],
            tps[&("raw", "infer")],
            tps[&("subspace", "infer")],
            tps[&("subspace", "infer")] / tps[&("raw", "infer")],
        );
    }
    Ok(())
}
