//! Node churn over consumer internet: the discrete-event swarm
//! simulator (DESIGN.md §9).
//!
//! A fixed wall-clock churn timeline — a member leaves every ~1.1
//! simulated seconds and returns 0.45 s later — hits two protocols at
//! 80 Mbps: subspace-compressed (activations, gradients, *and* the
//! rejoin state sync all priced at k/d of raw) versus raw. Because the
//! timeline is anchored to wall clock, a protocol whose steps are slow
//! absorbs proportionally more churn per step: raw's ~4.7 s steps eat
//! dozens of leave/rejoin cycles (each rejoin stalling a barrier for
//! an ~82 MB state sync), while subspace's ~0.2 s steps dodge almost
//! all of them and pay ~2.6 MB when they don't.
//!
//! Acceptance (ISSUE 3): under this churn at 80 Mbps, subspace keeps
//! the mean step within 1.5x of its no-churn baseline; raw degrades by
//! more than 3x. Runs entirely on the analytic cost model — no AOT
//! artifacts or PJRT backend needed.
//!
//!     cargo run --release --example churn_swarm

use protomodels::compress::Mode;
use protomodels::manifest::Hyper;
use protomodels::netsim::{LinkSpec, MBPS};
use protomodels::sim::{
    simulate_swarm, ChurnEvent, ChurnKind, ChurnSpec, SimReport, SwarmSpec,
};

/// Deterministic links: all timing differences below come from the
/// protocol, not from sampled jitter.
fn quiet(bw_mbps: f64) -> LinkSpec {
    LinkSpec { bandwidth_bps: bw_mbps * MBPS, latency_s: 2e-3, jitter_frac: 0.0 }
}

/// One leave/rejoin cycle every `period` seconds out to `horizon`,
/// round-robining over replicas 1..=3 (replica 0 stays). The same
/// absolute timeline hits every protocol — the honest comparison.
fn churn_timeline(period: f64, downtime: f64, horizon: f64) -> ChurnSpec {
    let mut events = Vec::new();
    let mut t = 0.7;
    let mut k = 0usize;
    while t < horizon {
        let replica = 1 + (k % 3);
        events.push(ChurnEvent { time: t, replica, kind: ChurnKind::Leave });
        events.push(ChurnEvent {
            time: t + downtime,
            replica,
            kind: ChurnKind::Rejoin,
        });
        k += 1;
        t += period;
    }
    ChurnSpec::Scripted(events)
}

fn run(mode: Mode, churn: Option<ChurnSpec>) -> SimReport {
    let mut spec = SwarmSpec::uniform(Hyper::base_sim(), 4, 80.0 * MBPS);
    spec.link = quiet(80.0);
    spec.ring_link = quiet(80.0);
    spec.mode = mode;
    spec.dp_mode = mode;
    spec.steps = 6;
    if let Some(c) = churn {
        spec.churn = c;
    }
    simulate_swarm(&spec).expect("swarm simulation")
}

fn main() {
    let churn = || Some(churn_timeline(1.1, 0.45, 400.0));

    println!("6 hybrid steps at 80 Mbps, 4 replicas, leave/rejoin every 1.1s\n");
    println!(
        "{:>10} {:>14} {:>14} {:>9} {:>8} {:>9} {:>9}",
        "mode", "no-churn s/step", "churn s/step", "degrade",
        "leaves", "rejoins", "restarts"
    );
    let mut ratios = Vec::new();
    for mode in [Mode::Subspace, Mode::Raw] {
        let base = run(mode, None);
        let churned = run(mode, churn());
        let ratio = churned.mean_step() / base.mean_step();
        ratios.push((mode, ratio, churned.allreduce_restarts));
        println!(
            "{:>10} {:>14.4} {:>14.4} {:>8.2}x {:>8} {:>9} {:>9}",
            mode.as_str(),
            base.mean_step(),
            churned.mean_step(),
            ratio,
            churned.leaves,
            churned.rejoins,
            churned.allreduce_restarts,
        );
    }

    let (_, sub_ratio, sub_restarts) = ratios[0];
    let (_, raw_ratio, raw_restarts) = ratios[1];

    // acceptance (a): subspace stays within 1.5x of its no-churn pace
    assert!(
        sub_ratio <= 1.5,
        "subspace degraded {sub_ratio:.2}x under churn (must stay <= 1.5x)"
    );
    // acceptance (b): raw degrades past 3x — its long steps absorb far
    // more of the wall-clock churn timeline, and every rejoin stalls a
    // barrier for a raw-priced state sync
    assert!(
        raw_ratio > 3.0,
        "raw degraded only {raw_ratio:.2}x under churn (expected > 3x)"
    );
    // sanity: the mid-all-reduce abort path actually fired
    assert!(
        sub_restarts + raw_restarts >= 1,
        "no all-reduce was ever interrupted by churn"
    );

    println!(
        "\nok: subspace stays within {sub_ratio:.2}x of its no-churn step \
         time; raw degrades {raw_ratio:.1}x at the same 80 Mbps churn"
    );
}
