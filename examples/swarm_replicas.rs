//! Replicated pipelines over consumer internet: the data-parallel ×
//! model-parallel hybrid (DESIGN.md §6).
//!
//! Prices one hybrid training step — R replicated GPipe pipelines joined
//! by a ring all-reduce of per-stage weight gradients — across a
//! replicas × bandwidth grid, comparing dp-modes (how the gradient
//! payload is compressed on the cross-replica links), then models a 2×
//! straggler replica and checks the observed degradation against the
//! closed-form prediction.
//!
//! Runs entirely on the analytic cost model: no AOT artifacts or PJRT
//! backend needed.
//!
//!     cargo run --release --example swarm_replicas

use protomodels::compress::Mode;
use protomodels::coordinator::replica::{simulate_hybrid_step, HybridSimSpec};
use protomodels::manifest::Hyper;
use protomodels::netsim::{LinkSpec, MBPS};

fn base_hyper() -> Hyper {
    // the `base` config's dimensions (d=256, 8 layers on 4 stages)
    Hyper::base_sim()
}

fn quiet(bw_mbps: f64) -> LinkSpec {
    // deterministic links so the printed grid is exactly reproducible
    LinkSpec { bandwidth_bps: bw_mbps * MBPS, latency_s: 2e-3, jitter_frac: 0.0 }
}

fn step_seconds(replicas: usize, bw_mbps: f64, dp_mode: Mode) -> f64 {
    let mut spec = HybridSimSpec::uniform(base_hyper(), replicas, bw_mbps * MBPS);
    spec.link = quiet(bw_mbps);
    spec.ring_link = quiet(bw_mbps);
    spec.dp_mode = dp_mode;
    simulate_hybrid_step(&spec).makespan.total
}

fn main() {
    let replicas = [1usize, 2, 4, 8];
    let bws = [20.0f64, 80.0, 300.0, 1000.0];

    println!("hybrid step makespan (seconds), subspace vs raw dp-mode");
    println!("model: base (d=256, 4 stages), 8 microbatches, analytic 2 TFLOP/s\n");
    println!(
        "{:>8} {:>12} {:>14} {:>14} {:>9}",
        "replicas", "bw_mbps", "dp=subspace", "dp=raw", "speedup"
    );
    let mut sub_80 = 0.0;
    let mut raw_80 = 0.0;
    for &r in &replicas {
        for &bw in &bws {
            let sub = step_seconds(r, bw, Mode::Subspace);
            let raw = step_seconds(r, bw, Mode::Raw);
            if r == 4 && (bw - 80.0).abs() < 1e-9 {
                sub_80 = sub;
                raw_80 = raw;
            }
            println!(
                "{r:>8} {bw:>12.0} {sub:>14.4} {raw:>14.4} {:>8.1}x",
                raw / sub
            );
        }
        println!();
    }

    // acceptance (a): subspace dp-mode beats raw at 80 Mbps
    assert!(
        sub_80 < raw_80,
        "subspace dp-mode ({sub_80:.3}s) must beat raw ({raw_80:.3}s) at 80 Mbps"
    );
    println!(
        "at 4 replicas x 80 Mbps: subspace dp-mode is {:.1}x faster than raw\n",
        raw_80 / sub_80
    );

    // ---- straggler: one replica at 2x slowdown, compute-bound links ----
    // prediction: with the all-reduce fully overlapped (fat ring) and
    // negligible activation serialization, the hybrid step is
    // max over replicas of the pipeline makespan, so a 2x-slower replica
    // degrades the step by ~2x (latency terms do not scale, hence "~").
    let fat = 16_000.0; // 16 Gbps: compute-bound
    // zero-latency links for the check: propagation latency is a fixed
    // additive term that does not scale with compute, so it would dilute
    // the clean 2x prediction (at 80 Mbps the grid above already includes
    // latency)
    let fat_spec = LinkSpec {
        bandwidth_bps: fat * MBPS,
        latency_s: 0.0,
        jitter_frac: 0.0,
    };
    let mut nominal = HybridSimSpec::uniform(base_hyper(), 4, fat * MBPS);
    nominal.link = fat_spec;
    nominal.ring_link = fat_spec;
    let t_nominal = simulate_hybrid_step(&nominal).makespan;
    let mut straggled = nominal.clone();
    straggled.slowdown = vec![1.0, 1.0, 1.0, 2.0];
    let t_straggled = simulate_hybrid_step(&straggled).makespan;
    let observed = t_straggled.total / t_nominal.total;
    let predicted = 2.0;
    println!("straggler check (4 replicas, 16 Gbps links, one 2x-slow replica):");
    println!("  nominal step   {:.4}s", t_nominal.total);
    println!("  straggled step {:.4}s", t_straggled.total);
    println!("  degradation    {observed:.2}x (predicted ~{predicted:.2}x)");
    // acceptance (b): degradation matches the predicted factor
    assert!(
        (observed - predicted).abs() < 0.15,
        "straggler degradation {observed:.3} != predicted {predicted}"
    );
    println!("\nok: subspace dp-mode wins at 80 Mbps; straggler scales as predicted");
}
