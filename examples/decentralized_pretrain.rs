//! End-to-end validation driver (DESIGN.md §7): pretrain the base model
//! (8 layers, d=256, ≈6.8M params, 4 pipeline stages) for several hundred
//! steps on the synthetic corpus under three deployments:
//!
//!   A. decentralized + subspace compression @ 80 Mbps   (the paper)
//!   B. decentralized, uncompressed          @ 80 Mbps   (the problem)
//!   C. centralized, uncompressed            @ 100 Gbps  (the reference)
//!
//! All three train *real* models through PJRT; the loss curves are real.
//! Simulated wall-clock comes from netsim + the analytic A10G-ratio time
//! model. Results land in results/e2e_pretrain/*.csv and a summary is
//! printed — this run is recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example decentralized_pretrain [steps] [config]

use protomodels::compress::Mode;
use protomodels::coordinator::{Pipeline, PipelineConfig};
use protomodels::data::{Corpus, CorpusKind};
use protomodels::manifest::Manifest;
use protomodels::metrics::RunLog;
use protomodels::netsim::{LinkSpec, Topology};
use protomodels::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize =
        args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let config = args.get(1).cloned().unwrap_or_else(|| "base".to_string());

    let manifest = Manifest::load("artifacts")?;
    let h = manifest.config(&config)?.hyper.clone();
    println!(
        "== decentralized_pretrain: {config} ({} params, {} layers / {} stages, {}x compression), {steps} steps ==",
        h.param_count, h.layers, h.stages, h.ratio
    );

    let systems = [
        ("A_decentralized_compressed_80mbps", Mode::Subspace, false),
        ("B_decentralized_raw_80mbps", Mode::Raw, false),
        ("C_centralized_raw_100gbps", Mode::Raw, true),
    ];

    let mut rows = Vec::new();
    for (label, mode, centralized) in systems {
        let mut rng = Rng::new(1234);
        let spec = if centralized {
            LinkSpec::centralized_100g()
        } else {
            LinkSpec::internet_80m()
        };
        let topo = Topology::uniform(h.stages, spec, &mut rng);
        let pcfg = PipelineConfig {
            mode,
            microbatches: 8,
            grassmann_interval: if mode == Mode::Subspace { 100 } else { 0 },
            lr: 6e-3,
            warmup_steps: (steps / 20).max(5),
            total_steps: steps,
            seed: 1234,
            ..Default::default()
        };
        let mut pipe = Pipeline::new(&manifest, &config, topo, pcfg)?;
        let corpus =
            Corpus::synthetic(CorpusKind::Wiki, h.vocab, 400_000, 99);
        let mut log = RunLog::create("results/e2e_pretrain", label)?;
        let t0 = std::time::Instant::now();
        for step in 0..steps {
            let s = pipe.train_step(|r| corpus.train_batch(h.b, h.n, r))?;
            log.log(&s)?;
            if step % 25 == 0 {
                println!(
                    "[{label}] step {:>4}/{steps}  loss {:.4}  sim_t {:>9.2}s",
                    step, s.loss, log.sim_time
                );
            }
        }
        let val = pipe.eval(8, |r| corpus.val_batch(h.b, h.n, r))?;
        let host = t0.elapsed().as_secs_f64();
        println!(
            "[{label}] DONE  val_loss {:.4}  ppl {:.2}  sim_tps {:.1}  sim_wall {:.2}s  (host {:.1}s)  leak {:.1e}",
            val,
            val.exp(),
            log.tps(),
            log.sim_time,
            host,
            pipe.subspace_leak()
        );
        rows.push((label, val, log.tps(), log.sim_time));
        log.finish()?;
    }

    println!("\n== summary (see EXPERIMENTS.md §E2E) ==");
    println!("{:<40} {:>9} {:>12} {:>12}", "system", "val_loss", "sim_tps", "sim_wall_s");
    for (l, v, t, w) in &rows {
        println!("{l:<40} {v:>9.4} {t:>12.1} {w:>12.2}");
    }
    let (_, va, ta, _) = rows[0];
    let (_, _, tb, _) = rows[1];
    let (_, vc, tc, _) = rows[2];
    println!(
        "\ncompressed-vs-centralized: Δval_loss {:+.4}, tps ratio {:.2}x",
        va - vc,
        ta / tc
    );
    println!(
        "compressed-vs-raw-decentralized throughput gain: {:.1}x",
        ta / tb
    );
    Ok(())
}
