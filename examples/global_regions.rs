//! Fig. 5 scenario as a runnable example: a 16-layer model pipelined
//! across 8 stages spread over 4 geographic regions (no two consecutive
//! stages share a region → every pipeline link is a slow 60–350 Mbps
//! inter-region path), vs a same-region 16 Gbps centralized deployment.
//!
//!     cargo run --release --example global_regions [steps]

use protomodels::compress::Mode;
use protomodels::coordinator::{Pipeline, PipelineConfig};
use protomodels::data::{Corpus, CorpusKind};
use protomodels::manifest::Manifest;
use protomodels::metrics::RunLog;
use protomodels::netsim::{LinkSpec, Topology};
use protomodels::rng::Rng;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(120);
    let manifest = Manifest::load("artifacts")?;
    let config = "deep16";
    let h = manifest.config(config)?.hyper.clone();

    let mut rng = Rng::new(5);
    let global_topo = Topology::global_regions(h.stages, &mut rng);
    println!("stage → region map:");
    for (s, r) in global_topo.regions.as_ref().unwrap().iter().enumerate() {
        print!("  s{s}:{}", r.name());
    }
    println!(
        "\nmin inter-region bandwidth: {:.0} Mbps",
        global_topo.min_bandwidth() / 1e6
    );

    let runs: Vec<(&str, Mode, Topology)> = vec![
        ("global_4regions_compressed", Mode::Subspace, global_topo.clone()),
        ("global_4regions_raw", Mode::Raw, global_topo),
        (
            "centralized_16gbps",
            Mode::Raw,
            Topology::uniform(h.stages, LinkSpec::centralized_16g(), &mut rng),
        ),
    ];

    println!("\n{:<32} {:>9} {:>12} {:>12}", "system", "loss", "sim_tps", "sim_wall_s");
    for (label, mode, topo) in runs {
        let pcfg = PipelineConfig {
            mode,
            microbatches: 4,
            grassmann_interval: 0,
            lr: 6e-3,
            warmup_steps: 10,
            total_steps: steps,
            seed: 5,
            ..Default::default()
        };
        let mut pipe = Pipeline::new(&manifest, config, topo, pcfg)?;
        let corpus = Corpus::synthetic(CorpusKind::C4, h.vocab, 400_000, 5);
        let mut log = RunLog::create("results/example_global_regions", label)?;
        for _ in 0..steps {
            let s = pipe.train_step(|r| corpus.train_batch(h.b, h.n, r))?;
            log.log(&s)?;
        }
        println!(
            "{label:<32} {:>9.4} {:>12.1} {:>12.2}",
            log.last_loss,
            log.tps(),
            log.sim_time
        );
        log.finish()?;
    }
    Ok(())
}
