"""Optimizer entrypoints: subspace closure, equivalence to standard AdamW
where no constraint applies, and schedule-scalar handling."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import optim
from compile.configs import CONFIGS, stage_param_schema
from compile.kernels import ref
from tests.conftest import init_stage, orthonormal


CFG = CONFIGS["tiny"]


def rand_flat(rng, stage, scale=1.0):
    return [
        jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)
        for _, shape in stage_param_schema(CFG, stage)
    ]


def zeros_like(flat):
    return [jnp.zeros_like(x) for x in flat]


def test_subspace_step_keeps_constrained_rows_in_s():
    rng = np.random.default_rng(0)
    u = orthonormal(CFG.d, CFG.k, 1)
    proj = u @ u.T
    t_fixed = jnp.asarray(rng.standard_normal((CFG.vocab, CFG.d)) * 0.02,
                          jnp.float32)
    w = init_stage(CFG, 0, u, t_fixed, rng)
    m, v = zeros_like(w), zeros_like(w)
    for t in range(1, 8):
        g = rand_flat(rng, 0)  # arbitrary out-of-S gradients
        w, m, v = optim.adamw_subspace(
            CFG, 0, w, g, m, v, u, jnp.float32(1e-3), jnp.float32(t))
    for (name, _), x in zip(stage_param_schema(CFG, 0), w):
        if name.endswith(("wp1", "wp2")) or name == "t_s":
            leak = float(jnp.max(jnp.abs(x - x @ proj)))
            assert leak < 1e-5, (name, leak)


def test_unconstrained_params_match_standard_adamw():
    """For wq/wk/wv/w1/ln/head, adamw_subspace must reduce to the
    unmodified update."""
    rng = np.random.default_rng(1)
    u = orthonormal(CFG.d, CFG.k, 2)
    w = rand_flat(rng, 2, 0.02)
    g = rand_flat(rng, 2)
    m, v = zeros_like(w), zeros_like(w)
    lr, t = jnp.float32(3e-4), jnp.float32(5.0)
    w2, m2, v2 = optim.adamw_subspace(CFG, 2, w, g, m, v, u, lr, t)
    w2r, m2r, v2r = optim.adamw_standard(CFG, 2, w, g, m, v, lr, t)
    for (name, _), a, b in zip(stage_param_schema(CFG, 2), w2, w2r):
        if name.endswith(("wp1", "wp2")) or name == "t_s":
            continue
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7,
                                   err_msg=name)


def test_layernorm_params_not_decayed():
    """Weight decay must not shrink LN gains toward zero."""
    rng = np.random.default_rng(2)
    w = [jnp.ones(s) if n.endswith("_g") else
         jnp.asarray(rng.standard_normal(s) * 0.02, jnp.float32)
         for n, s in stage_param_schema(CFG, 2)]
    g = zeros_like(w)  # zero gradients: only decay acts
    m, v = zeros_like(w), zeros_like(w)
    w2, _, _ = optim.adamw_standard(
        CFG, 2, w, g, m, v, jnp.float32(1e-2), jnp.float32(1.0))
    for (name, _), before, after in zip(stage_param_schema(CFG, 2), w, w2):
        if name.endswith(("_g", "_b")):
            np.testing.assert_allclose(after, before, atol=1e-7,
                                       err_msg=name)
        elif name == "w_head":
            # decayed parameters must actually shrink
            assert float(jnp.sum(jnp.abs(after))) < \
                float(jnp.sum(jnp.abs(before)))


@settings(max_examples=10, deadline=None)
@given(t=st.integers(1, 10_000), lr=st.floats(1e-5, 1e-2))
def test_bias_correction_matches_reference(t, lr):
    rng = np.random.default_rng(t)
    w = jnp.asarray(rng.standard_normal((8, 16)) * 0.1, jnp.float32)
    g = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    m = jnp.zeros_like(w)
    v = jnp.zeros_like(w)
    h = jnp.asarray(
        [lr, 1 - optim.BETA1 ** t, 1 - optim.BETA2 ** t, 0.01], jnp.float32)
    w1, _, _ = ref.standard_adamw(w, g, m, v, h)
    # manual expected first step: mhat = g, vhat = g², update = sign-ish
    mhat = (1 - optim.BETA1) * g / (1 - optim.BETA1 ** t)
    vhat = (1 - optim.BETA2) * g * g / (1 - optim.BETA2 ** t)
    want = w - lr * mhat / (jnp.sqrt(vhat) + optim.EPS) - lr * 0.01 * w
    np.testing.assert_allclose(w1, want, rtol=1e-4, atol=1e-5)
