"""Lossy baseline compressors (Fig. 6 / Thm B.1 substrate)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import baselines


@settings(max_examples=25, deadline=None)
@given(numel=st.integers(16, 2048), ratio=st.floats(2.0, 64.0),
       seed=st.integers(0, 2**16))
def test_topk_keeps_largest_by_magnitude(numel, ratio, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(numel), jnp.float32)
    y = baselines.topk_cd(x, ratio)
    kk = baselines.topk_keep(numel, ratio)
    nz = np.flatnonzero(np.asarray(y))
    assert len(nz) <= kk
    if len(nz) and len(nz) < numel:
        kept_min = np.abs(np.asarray(x))[nz].min()
        dropped = np.delete(np.abs(np.asarray(x)), nz)
        assert kept_min >= dropped.max() - 1e-6
    # surviving entries are bit-exact
    np.testing.assert_array_equal(np.asarray(y)[nz], np.asarray(x)[nz])


@settings(max_examples=25, deadline=None)
@given(numel=st.integers(1, 1024), seed=st.integers(0, 2**16))
def test_quant_error_bounded_by_half_step(numel, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(numel) * 3.0, jnp.float32)
    y = baselines.quant_cd(x)
    step = float(jnp.max(jnp.abs(x))) / 127.0
    assert float(jnp.max(jnp.abs(x - y))) <= step * 0.501 + 1e-6


def test_powerlr_rank_budget_and_error():
    rng = np.random.default_rng(3)
    b, n, d = 2, 64, 32
    x = jnp.asarray(rng.standard_normal((b, n, d)), jnp.float32)
    ratio = 8.0
    y = baselines.powerlr_cd(x, ratio)
    assert y.shape == x.shape
    r = baselines.powerlr_rank(n, d, ratio)
    # each slice of the reconstruction has rank ≤ r
    for i in range(b):
        sv = np.linalg.svd(np.asarray(y[i]), compute_uv=False)
        assert (sv > 1e-4 * sv[0]).sum() <= r + 1
    # and it is lossy but not degenerate
    rel = float(jnp.linalg.norm(x - y) / jnp.linalg.norm(x))
    assert 0.01 < rel < 1.0


def test_powerlr_deterministic():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((1, 32, 16)), jnp.float32)
    a = baselines.powerlr_cd(x, 4.0)
    b = baselines.powerlr_cd(x, 4.0)
    np.testing.assert_array_equal(a, b)


@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 4), n=st.sampled_from([32, 64, 128]),
       d=st.sampled_from([64, 128, 256]), k=st.sampled_from([4, 8, 16]))
def test_wire_bytes_ordering(b, n, d, k):
    ratio = d / k
    raw = baselines.wire_bytes("raw", b, n, d, k, ratio)
    sub = baselines.wire_bytes("subspace", b, n, d, k, ratio)
    assert raw // sub == d // k
    for mode in ("topk", "quant", "powerlr"):
        assert baselines.wire_bytes(mode, b, n, d, k, ratio) <= raw + 8


def test_orthonormalize_columns():
    rng = np.random.default_rng(5)
    p = jnp.asarray(rng.standard_normal((32, 5)), jnp.float32)
    q = baselines._orthonormalize(p)
    gram = np.asarray(q.T @ q)
    np.testing.assert_allclose(gram, np.eye(5), atol=1e-4)
