"""L2 correctness: the compressed pipeline is equivalent to a monolithic
uncompressed model — the paper's central losslessness claim.

Invariants (Sec. 4.3/4.4, Appendix A):
  * pipeline loss == monolithic loss (bit-level on CPU f32)
  * gradients of every UNconstrained parameter are exact
  * gradients of constrained parameters match after projection onto S
    at boundary-adjacent blocks
  * stage shapes compose; boundary payloads are (b, n, k)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import CONFIGS, stage_param_schema
from compile.kernels import subspace as K

CONSTRAINED = ("wp1", "wp2", "t_s")


def is_constrained(name):
    return name.endswith(("wp1", "wp2")) or name == "t_s"


def run_pipeline(cfg, params, u, t_fixed, tok, tgt):
    acts = [M.first_fwd(cfg, params[0], u, t_fixed, tok)]
    for s in range(1, cfg.stages - 1):
        acts.append(M.mid_fwd(cfg, params[s], u, t_fixed, tok, acts[-1]))
    loss, gc, grads_last, gtg = M.last_loss(
        cfg, params[-1], u, t_fixed, tok, acts[-1], tgt)
    grads = [None] * cfg.stages
    grads[-1] = grads_last
    for s in range(cfg.stages - 2, 0, -1):
        gc, grads[s] = M.mid_bwd(cfg, params[s], u, t_fixed, tok,
                                 acts[s - 1], gc)
    grads[0] = M.first_bwd(cfg, params[0], u, t_fixed, tok, gc)
    return loss, grads, acts, gtg


def monolithic(cfg, params, u, t_fixed, tok, tgt):
    def f(ps):
        p0 = M.pack(cfg, 0, ps[0])
        x = M.high_rank_e(cfg, t_fixed, tok) + p0["t_s"][tok]
        x = M.stage_blocks(cfg, p0, x)
        for s in range(1, cfg.stages - 1):
            x = M.stage_blocks(cfg, M.pack(cfg, s, ps[s]), x)
        return M._last_inner(cfg, ps[-1], x, tgt)

    return jax.value_and_grad(f)(params)


def test_pipeline_loss_exact(tiny_setup):
    cfg, params, u, t_fixed, tok, tgt = tiny_setup
    loss_p, _, _, _ = run_pipeline(cfg, params, u, t_fixed, tok, tgt)
    loss_m, _ = monolithic(cfg, params, u, t_fixed, tok, tgt)
    assert abs(float(loss_p) - float(loss_m)) < 1e-6, (loss_p, loss_m)


def test_unconstrained_grads_exact(tiny_setup):
    cfg, params, u, t_fixed, tok, tgt = tiny_setup
    _, grads_p, _, _ = run_pipeline(cfg, params, u, t_fixed, tok, tgt)
    _, grads_m = monolithic(cfg, params, u, t_fixed, tok, tgt)
    for s in range(cfg.stages):
        for (name, _), a, b in zip(stage_param_schema(cfg, s),
                                   grads_p[s], grads_m[s]):
            if is_constrained(name):
                continue
            scale = float(jnp.max(jnp.abs(b))) + 1e-8
            err = float(jnp.max(jnp.abs(a - b))) / scale
            assert err < 5e-4, f"stage{s} {name}: rel err {err}"


def test_constrained_grads_match_in_subspace(tiny_setup):
    """The projected (= optimizer-effective) constrained gradients of the
    pipeline agree with the monolithic ones projected onto S for wp2 at
    boundary-adjacent blocks (Appendix A)."""
    cfg, params, u, t_fixed, tok, tgt = tiny_setup
    proj = u @ u.T
    _, grads_p, _, _ = run_pipeline(cfg, params, u, t_fixed, tok, tgt)
    _, grads_m = monolithic(cfg, params, u, t_fixed, tok, tgt)
    checked = 0
    for s in range(cfg.stages - 1):  # last stage sees exact grads anyway
        for (name, _), a, b in zip(stage_param_schema(cfg, s),
                                   grads_p[s], grads_m[s]):
            if not name.endswith("wp2"):
                continue
            scale = float(jnp.max(jnp.abs(b))) + 1e-8
            err = float(jnp.max(jnp.abs(a @ proj - b @ proj))) / scale
            assert err < 5e-4, f"stage{s} {name}: rel {err}"
            checked += 1
    assert checked >= 1


def test_boundary_payload_shapes(tiny_setup):
    cfg, params, u, t_fixed, tok, tgt = tiny_setup
    _, _, acts, gtg = run_pipeline(cfg, params, u, t_fixed, tok, tgt)
    for a in acts:
        assert a.shape == (cfg.b, cfg.n, cfg.k)
    assert gtg.shape == (cfg.d, cfg.d)
    # GtG is symmetric PSD
    np.testing.assert_allclose(gtg, gtg.T, rtol=1e-4, atol=1e-7)
    eig = np.linalg.eigvalsh(np.asarray(gtg))
    assert eig.min() > -1e-5


def test_last_eval_matches_last_loss(tiny_setup):
    cfg, params, u, t_fixed, tok, tgt = tiny_setup
    acts_in = M.first_fwd(cfg, params[0], u, t_fixed, tok)
    for s in range(1, cfg.stages - 1):
        acts_in = M.mid_fwd(cfg, params[s], u, t_fixed, tok, acts_in)
    loss_a, _, _, _ = M.last_loss(cfg, params[-1], u, t_fixed, tok,
                                  acts_in, tgt)
    loss_b = M.last_eval(cfg, params[-1], u, t_fixed, tok, acts_in, tgt)
    assert abs(float(loss_a) - float(loss_b)) < 1e-6


def test_raw_pipeline_matches_its_monolith(tiny_setup):
    """The uncompressed baseline path is self-consistent."""
    cfg, params, u, t_fixed, tok, tgt = tiny_setup
    x = M.first_fwd_lossy(cfg, "raw", params[0], tok)
    for s in range(1, cfg.stages - 1):
        x = M.mid_fwd_lossy(cfg, "raw", params[s], x)
    loss, g, grads_last = M.last_loss_lossy(cfg, "raw", params[-1], x, tgt)

    def f(ps):
        p0 = M.pack(cfg, 0, ps[0])
        xx = M._embed_raw(cfg, p0, tok)
        xx = M.stage_blocks(cfg, p0, xx)
        for s in range(1, cfg.stages - 1):
            xx = M.stage_blocks(cfg, M.pack(cfg, s, ps[s]), xx)
        return M._last_inner(cfg, ps[-1], xx, tgt)

    loss_m = f(params)
    assert abs(float(loss) - float(loss_m)) < 1e-6


@pytest.mark.parametrize("mode", ["topk", "quant", "powerlr"])
def test_lossy_modes_inject_error(tiny_setup, mode):
    """Negative control (Statement 7.1): lossy boundaries actually perturb
    activations; the subspace path does not."""
    cfg, params, u, t_fixed, tok, tgt = tiny_setup
    x_raw = M.first_fwd_lossy(cfg, "raw", params[0], tok)
    x_lossy = M.first_fwd_lossy(cfg, mode, params[0], tok)
    err = float(jnp.max(jnp.abs(x_raw - x_lossy)))
    assert err > 1e-6, f"{mode} produced no error?"


def test_grassmann_step_returns_orthonormal():
    rng = np.random.default_rng(11)
    d, k = 64, 8
    q, _ = np.linalg.qr(rng.standard_normal((d, k)))
    u = jnp.asarray(q, jnp.float32)
    g = rng.standard_normal((d, d))
    s_acc = jnp.asarray(g @ g.T, jnp.float32)
    u2 = M.grassmann_step(u, s_acc, jnp.float32(1e-3))
    gram = np.asarray(u2.T @ u2)
    np.testing.assert_allclose(gram, np.eye(k), atol=1e-4)
    # the step should move U (nonzero learning signal)
    assert float(jnp.max(jnp.abs(u2 - u))) > 1e-7


def test_grassmann_step_reduces_leftover_energy():
    """Minimizing L_Grassmann: after steps toward the dominant gradient
    subspace, the out-of-S energy ‖G(I−UUᵀ)‖² decreases (Sec. 4.5)."""
    rng = np.random.default_rng(12)
    d, k = 32, 4
    # gradients concentrated in a planted k-dim subspace
    basis, _ = np.linalg.qr(rng.standard_normal((d, k)))
    G = rng.standard_normal((256, k)) @ basis.T + \
        0.01 * rng.standard_normal((256, d))
    s_acc = jnp.asarray(G.T @ G / 256.0, jnp.float32)
    q, _ = np.linalg.qr(rng.standard_normal((d, k)))
    u = jnp.asarray(q, jnp.float32)

    def leftover(u):
        r = G - (G @ np.asarray(u)) @ np.asarray(u).T
        return float((r ** 2).sum())

    # step size scaled to the accumulator's spectral mass, as the trainer
    # does (rust optim::grassmann)
    eta = float(0.5 * d / np.trace(np.asarray(s_acc)))
    step = jax.jit(M.grassmann_step)
    before = leftover(u)
    for _ in range(500):
        u = step(u, s_acc, jnp.float32(eta))
    after = leftover(u)
    assert after < 0.5 * before, (before, after)


def test_reproject_restores_subspace(tiny_setup):
    cfg, params, u, t_fixed, tok, tgt = tiny_setup
    proj = u @ u.T
    rng = np.random.default_rng(13)
    # perturb constrained weights out of S
    dirty = [w + jnp.asarray(rng.standard_normal(w.shape) * 0.01,
                             jnp.float32) for w in params[0]]
    moms = [jnp.ones_like(w) for w in params[0]]
    w2, m2 = M.reproject(cfg, 0, dirty, moms, u)
    for (name, _), w in zip(stage_param_schema(cfg, 0), w2):
        if is_constrained(name):
            leak = float(jnp.max(jnp.abs(w - w @ proj)))
            assert leak < 1e-5, (name, leak)


def test_sinusoidal_pe_deterministic_and_high_rank():
    pe = M.sinusoidal_pe(64, 64)
    pe2 = M.sinusoidal_pe(64, 64)
    np.testing.assert_array_equal(pe, pe2)
    # PE must be high-rank in the *linear* sense (it cannot be absorbed
    # into S, which is why it is subtracted before projection). Its
    # stable rank is naturally small (the near-constant high-frequency
    # cos columns concentrate spectral mass), so count σᵢ > tol instead.
    # what matters for the method: rank(PE) exceeds any config's k, so
    # PE could never be represented inside S (hence the subtraction)
    s = np.linalg.svd(np.asarray(pe), compute_uv=False)
    linear_rank = int((s > 1e-4 * s[0]).sum())
    assert linear_rank > 16, linear_rank
