"""AOT pipeline: manifest consistency and HLO-text portability.

The artifacts/ directory is the rust runtime's entire world; these tests
pin the contract (arg order = schema order, shapes, mode coverage) and
ensure the emitted HLO stays parseable by the *old* XLA text parser (no
`topk`/custom-call instructions).
"""

import json
import os

import pytest

from compile import aot
from compile.configs import CONFIGS, stage_param_schema

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    with open(path) as f:
        return json.load(f)


def test_manifest_covers_default_configs(manifest):
    for name in ("tiny", "small", "base"):
        assert name in manifest["configs"], name


def test_entry_args_follow_schema_order(manifest):
    cfgm = manifest["configs"]["tiny"]
    cfg = CONFIGS["tiny"]
    e = cfgm["entries"]["subspace/mid_bwd"]
    names = [a["name"] for a in e["args"]]
    schema = [f"p.{n}" for n, _ in stage_param_schema(cfg, 1)]
    assert names[: len(schema)] == schema
    assert names[len(schema):] == ["u", "t_fixed", "tok", "xc_in", "gc_out"]


def test_boundary_shapes_are_compressed(manifest):
    for cname, cm in manifest["configs"].items():
        h = cm["hyper"]
        for key, e in cm["entries"].items():
            mode = key.split("/")[0]
            if mode not in ("subspace", "nofixed"):
                continue
            for a in e["args"]:
                if a["name"] in ("xc_in", "gc_out", "gc_in"):
                    assert a["shape"] == [h["b"], h["n"], h["k"]], (cname, key)


def test_adamw_outputs_triple_schema(manifest):
    cm = manifest["configs"]["tiny"]
    cfg = CONFIGS["tiny"]
    for kind, stage in (("first", 0), ("mid", 1), ("last", 2)):
        e = cm["entries"][f"subspace/adamw_{kind}"]
        n = len(stage_param_schema(cfg, stage))
        assert len(e["outs"]) == 3 * n, kind


def test_hlo_files_exist_and_are_text(manifest):
    for cname, cm in manifest["configs"].items():
        for key, e in cm["entries"].items():
            path = os.path.join(ART, e["file"])
            assert os.path.exists(path), (cname, key)
            head = open(path).read(200)
            assert head.startswith("HloModule"), (cname, key, head[:40])


def test_no_unparseable_instructions(manifest):
    """xla_extension 0.5.1's text parser rejects `topk(...)` and any
    custom-call — ensure no artifact contains them."""
    for cname, cm in manifest["configs"].items():
        for key, e in cm["entries"].items():
            text = open(os.path.join(ART, e["file"])).read()
            assert " topk(" not in text, (cname, key)
            assert "custom-call" not in text, (cname, key)


def test_grassmann_entry_present_for_subspace_configs(manifest):
    for cname, cm in manifest["configs"].items():
        if "subspace" in cm["modes"]:
            assert "subspace/grassmann_step" in cm["entries"], cname


def test_param_counts_match(manifest):
    for cname, cm in manifest["configs"].items():
        cfg = CONFIGS[cname]
        assert cm["hyper"]["param_count"] == cfg.param_count
