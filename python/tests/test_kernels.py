"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (both the Pallas fast path, rows % 64 == 0, and
the jnp fallback) and asserts allclose. This is the CORE correctness
signal for the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import subspace as K

jax.config.update("jax_platform_name", "cpu")


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


def ortho(rng, d, k):
    q, _ = np.linalg.qr(rng.standard_normal((d, k)))
    return jnp.asarray(q, jnp.float32)


# rows = b*n; include multiples of BM (pallas path) and odd sizes (fallback)
ROWS = st.sampled_from([64, 128, 192, 1, 7, 63, 65, 100])
DIMS = st.sampled_from([8, 16, 64, 96])
RANKS = st.sampled_from([1, 2, 4, 8])


@settings(max_examples=40, deadline=None)
@given(rows=ROWS, d=DIMS, k=RANKS, seed=st.integers(0, 2**16))
def test_project_matches_ref(rows, d, k, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, 1, rows, d)
    e = rand(rng, 1, rows, d)
    u = ortho(rng, d, min(k, d))
    got = K.subspace_project(x, e, u)
    want = ref.subspace_project(x, e, u)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(rows=ROWS, d=DIMS, k=RANKS, seed=st.integers(0, 2**16))
def test_reconstruct_matches_ref(rows, d, k, seed):
    rng = np.random.default_rng(seed)
    k = min(k, d)
    xc = rand(rng, 1, rows, k)
    e = rand(rng, 1, rows, d)
    u = ortho(rng, d, k)
    got = K.subspace_reconstruct(xc, e, u)
    want = ref.subspace_reconstruct(xc, e, u)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(rows=ROWS, d=DIMS, k=RANKS, seed=st.integers(0, 2**16))
def test_grad_kernels_match_ref(rows, d, k, seed):
    rng = np.random.default_rng(seed)
    k = min(k, d)
    g = rand(rng, 1, rows, d)
    gc = rand(rng, 1, rows, k)
    u = ortho(rng, d, k)
    np.testing.assert_allclose(
        K.grad_project(g, u), ref.grad_project(g, u), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        K.grad_expand(gc, u), ref.grad_expand(gc, u), rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(rows=st.sampled_from([64, 128, 37]), cols=DIMS, k=RANKS,
       t=st.integers(1, 5000), seed=st.integers(0, 2**16))
def test_rowwise_adamw_matches_ref(rows, cols, k, t, seed):
    rng = np.random.default_rng(seed)
    k = min(k, cols)
    w, g = rand(rng, rows, cols), rand(rng, rows, cols)
    m, v = rand(rng, rows, cols), jnp.abs(rand(rng, rows, cols))
    u = ortho(rng, cols, k)
    h = jnp.asarray(
        [3e-4, 1 - 0.9**t, 1 - 0.999**t, 0.01], jnp.float32)
    got = K.rowwise_adamw(w, g, m, v, u, h)
    want = ref.rowwise_adamw(w, g, m, v, u, h)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_project_is_exact_inverse_on_subspace():
    """Eq. 7: X̂ U Uᵀ = X̂ when Row(X̂) ⊆ S — the lossless-wire property."""
    rng = np.random.default_rng(3)
    d, k, rows = 64, 8, 128
    u = ortho(rng, d, k)
    # activation whose residual lies exactly in S
    resid = rand(rng, 1, rows, k) @ u.T
    e = rand(rng, 1, rows, d)
    x = resid + e
    xc = K.subspace_project(x, e, u)
    back = K.subspace_reconstruct(xc, e, u)
    np.testing.assert_allclose(back, x, rtol=1e-5, atol=1e-6)


def test_grad_roundtrip_exact_on_subspace():
    """Eq. 9–10: gradient wire compression is lossless for in-S grads."""
    rng = np.random.default_rng(4)
    d, k, rows = 64, 8, 128
    u = ortho(rng, d, k)
    g = rand(rng, 1, rows, k) @ u.T
    back = K.grad_expand(K.grad_project(g, u), u)
    np.testing.assert_allclose(back, g, rtol=1e-5, atol=1e-6)


def test_custom_vjp_project():
    """d/dX[(X−E)U]ᵀ·ct = ct Uᵀ; d/dE = −ct Uᵀ (closed form vs autodiff
    of the reference)."""
    rng = np.random.default_rng(5)
    d, k, rows = 16, 4, 64
    u = ortho(rng, d, k)
    x, e = rand(rng, 1, rows, d), rand(rng, 1, rows, d)
    ct = rand(rng, 1, rows, k)

    gx_k = jax.vjp(lambda xx: K.subspace_project(xx, e, u), x)[1](ct)[0]
    gx_r = jax.vjp(lambda xx: ref.subspace_project(xx, e, u), x)[1](ct)[0]
    np.testing.assert_allclose(gx_k, gx_r, rtol=1e-5, atol=1e-6)

    ge_k = jax.vjp(lambda ee: K.subspace_project(x, ee, u), e)[1](ct)[0]
    ge_r = jax.vjp(lambda ee: ref.subspace_project(x, ee, u), e)[1](ct)[0]
    np.testing.assert_allclose(ge_k, ge_r, rtol=1e-5, atol=1e-6)


def test_custom_vjp_reconstruct():
    rng = np.random.default_rng(6)
    d, k, rows = 16, 4, 64
    u = ortho(rng, d, k)
    xc, e = rand(rng, 1, rows, k), rand(rng, 1, rows, d)
    ct = rand(rng, 1, rows, d)

    g_k = jax.vjp(lambda xx: K.subspace_reconstruct(xx, e, u), xc)[1](ct)[0]
    g_r = jax.vjp(lambda xx: ref.subspace_reconstruct(xx, e, u), xc)[1](ct)[0]
    np.testing.assert_allclose(g_k, g_r, rtol=1e-5, atol=1e-6)

    ge_k = jax.vjp(lambda ee: K.subspace_reconstruct(xc, ee, u), e)[1](ct)[0]
    ge_r = jax.vjp(lambda ee: ref.subspace_reconstruct(xc, ee, u), e)[1](ct)[0]
    np.testing.assert_allclose(ge_k, ge_r, rtol=1e-5, atol=1e-6)


def test_rowwise_adamw_preserves_subspace():
    """Sec. 5 invariant: rows of W stay in S under the modified update,
    for arbitrary (out-of-S) incoming gradients."""
    rng = np.random.default_rng(8)
    rows, cols, k = 128, 32, 4
    u = ortho(rng, cols, k)
    proj = u @ u.T
    w = rand(rng, rows, cols) @ proj
    m = jnp.zeros((rows, cols))
    v = jnp.zeros((rows, cols))
    for t in range(1, 6):
        g = rand(rng, rows, cols)  # arbitrary direction
        h = jnp.asarray([1e-2, 1 - 0.9**t, 1 - 0.999**t, 0.01], jnp.float32)
        w, m, v = K.rowwise_adamw(w, g, m, v, u, h)
    leak = jnp.max(jnp.abs(w - w @ proj))
    assert float(leak) < 1e-5, f"rows left S: {leak}"


def test_standard_adamw_breaks_subspace():
    """Negative control: the *unmodified* AdamW drifts W out of S — the
    very failure Sec. 5 exists to fix."""
    rng = np.random.default_rng(9)
    rows, cols, k = 64, 32, 4
    u = ortho(rng, cols, k)
    proj = u @ u.T
    w = rand(rng, rows, cols) @ proj
    m = jnp.zeros((rows, cols))
    v = jnp.zeros((rows, cols))
    for t in range(1, 6):
        g = rand(rng, rows, cols) @ proj  # even with in-S gradients
        h = jnp.asarray([1e-2, 1 - 0.9**t, 1 - 0.999**t, 0.0], jnp.float32)
        w, m, v = ref.standard_adamw(w, g, m, v, h)
    leak = jnp.max(jnp.abs(w - w @ proj))
    assert float(leak) > 1e-6, "expected elementwise V̂ to distort rows"


def test_vmem_and_mxu_estimates():
    # paper-scale reference shapes: d=4096, k=40 (100x), BM=64
    vb = K.vmem_bytes(4096, 40)
    assert vb < 4 * 2**20, f"VMEM/grid-step {vb} exceeds budget"
    assert 0.0 < K.mxu_utilization(4096, 40) <= 1.0
