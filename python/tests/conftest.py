import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile.configs import CONFIGS, stage_param_schema  # noqa: E402


def orthonormal(d: int, k: int, seed: int = 0) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((d, k)))
    return jnp.asarray(q, jnp.float32)


def init_stage(cfg, stage, u, t_fixed, rng, in_subspace=True):
    """Initialize one stage's flat parameter list; constrained matrices
    start with rows in S = Col(u), T_S = T_fixed U Uᵀ (Sec. 4.3.1)."""
    proj = u @ u.T
    flat = []
    for name, shape in stage_param_schema(cfg, stage):
        if name.endswith("_g"):
            a = jnp.ones(shape, jnp.float32)
        elif name.endswith("_b"):
            a = jnp.zeros(shape, jnp.float32)
        else:
            a = jnp.asarray(rng.standard_normal(shape) * 0.02, jnp.float32)
        if in_subspace:
            if name.endswith("wp1") or name.endswith("wp2"):
                a = a @ proj
            if name == "t_s":
                a = t_fixed @ proj
        flat.append(a)
    return flat


@pytest.fixture(scope="session")
def tiny_setup():
    cfg = CONFIGS["tiny"]
    rng = np.random.default_rng(7)
    u = orthonormal(cfg.d, cfg.k, seed=7)
    t_fixed = jnp.asarray(
        rng.standard_normal((cfg.vocab, cfg.d)) * 0.02, jnp.float32)
    params = [init_stage(cfg, s, u, t_fixed, rng) for s in range(cfg.stages)]
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (cfg.b, cfg.n)), jnp.int32)
    tgt = jnp.asarray(rng.integers(0, cfg.vocab, (cfg.b, cfg.n)), jnp.int32)
    return cfg, params, u, t_fixed, tok, tgt
