"""Model / AOT configuration suite for protomodels.

Every config is shape-specialized at AOT time (HLO has static shapes), so
the rust coordinator selects a config by name from artifacts/manifest.json.

The parameter *schema* (ordered flat list of (name, shape)) defined here is
the single source of truth shared by model.py (pytree packing), aot.py
(manifest emission) and — via the manifest — the rust runtime (literal
packing order). Do not reorder fields without bumping MANIFEST_VERSION.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

MANIFEST_VERSION = 3

# Boundary modes. "subspace" is the paper's method; "raw" is the
# uncompressed baseline; "nofixed" is the Fig.-15 ablation (token
# embedding entirely restricted to S, no high-rank decomposition); the
# rest are the lossy baselines of Fig. 6.
MODES = ("subspace", "raw", "topk", "quant", "powerlr", "nofixed")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A shape-specialized model + pipeline configuration."""

    name: str
    d: int            # embedding dim
    d_ff: int         # MLP hidden dim
    heads: int        # attention heads
    layers: int       # total transformer blocks
    stages: int       # pipeline stages (blocks split evenly)
    n: int            # context length
    vocab: int        # vocabulary size
    k: int            # subspace rank (compression ratio ~= d / k)
    b: int            # microbatch size baked into the HLO
    modes: Tuple[str, ...] = ("subspace", "raw")

    def __post_init__(self):
        assert self.d % self.heads == 0, "d must divide heads"
        assert self.layers % self.stages == 0, "layers must divide stages"
        assert self.k < self.d
        assert all(m in MODES for m in self.modes), self.modes

    @property
    def blocks_per_stage(self) -> int:
        return self.layers // self.stages

    @property
    def d_head(self) -> int:
        return self.d // self.heads

    @property
    def compression_ratio(self) -> float:
        return self.d / self.k

    @property
    def param_count(self) -> int:
        return sum(
            int_prod(shape)
            for s in range(self.stages)
            for _, shape in stage_param_schema(self, s)
        )

    # ---- parameter schema -------------------------------------------------

    def block_schema(self) -> List[Tuple[str, Tuple[int, ...]]]:
        d, dff = self.d, self.d_ff
        return [
            ("ln1_g", (d,)),
            ("ln1_b", (d,)),
            ("wq", (d, d)),
            ("wk", (d, d)),
            ("wv", (d, d)),
            ("wp1", (d, d)),   # attention output projection — constrained to S
            ("ln2_g", (d,)),
            ("ln2_b", (d,)),
            ("w1", (d, dff)),
            ("wp2", (dff, d)),  # MLP down projection — constrained to S
        ]


def int_prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


def stage_param_schema(cfg: ModelConfig, stage: int) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list for one pipeline stage.

    stage 0 additionally owns the trainable low-rank embedding table T_S;
    the last stage owns the final layer-norm and LM head.
    """
    schema: List[Tuple[str, Tuple[int, ...]]] = []
    if stage == 0:
        schema.append(("t_s", (cfg.vocab, cfg.d)))
    for blk in range(cfg.blocks_per_stage):
        for name, shape in cfg.block_schema():
            schema.append((f"b{blk}_{name}", shape))
    if stage == cfg.stages - 1:
        schema.append(("lnf_g", (cfg.d,)))
        schema.append(("lnf_b", (cfg.d,)))
        schema.append(("w_head", (cfg.d, cfg.vocab)))
    return schema


def constrained_names(cfg: ModelConfig, stage: int):
    """Names whose rows must live in S.

    - "*_wp2" and "t_s": preserved by the row-wise AdamW variant (Sec. 5),
      never re-projected during normal steps.
    - "*_wp1": re-projected onto S after every optimizer step (Appendix A).
    Both sets are re-projected after a Grassmann subspace update.
    """
    rowwise, reproject = [], []
    for name, _ in stage_param_schema(cfg, stage):
        if name.endswith("wp2") or name == "t_s":
            rowwise.append(name)
        elif name.endswith("wp1"):
            reproject.append(name)
    return rowwise, reproject


# --------------------------------------------------------------------------
# The AOT suite. `tiny` exists for tests; `small` powers the fast presets of
# every experiment harness; `base` is the e2e pretrain config (~13M params);
# `deep16` is the depth-ablation config; `wide` is the optional large run.
# --------------------------------------------------------------------------

CONFIGS: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        ModelConfig(
            name="tiny", d=64, d_ff=256, heads=4, layers=3, stages=3,
            n=32, vocab=256, k=16, b=2,
            modes=("subspace", "raw", "topk", "quant", "powerlr"),
        ),
        ModelConfig(
            name="small", d=128, d_ff=512, heads=4, layers=4, stages=4,
            n=64, vocab=512, k=8, b=4,
            modes=("subspace", "raw", "topk", "quant", "powerlr", "nofixed"),
        ),
        # context-length ablation family (Figs. 10/11): same model, n sweep
        ModelConfig(
            name="ctx128", d=128, d_ff=512, heads=4, layers=4, stages=4,
            n=128, vocab=512, k=8, b=2,
            modes=("subspace", "raw"),
        ),
        ModelConfig(
            name="ctx256", d=128, d_ff=512, heads=4, layers=4, stages=4,
            n=256, vocab=512, k=8, b=1,
            modes=("subspace", "raw"),
        ),
        ModelConfig(
            name="base", d=256, d_ff=1024, heads=8, layers=8, stages=4,
            n=128, vocab=1024, k=8, b=4,
            modes=("subspace", "raw"),
        ),
        ModelConfig(
            name="deep16", d=192, d_ff=768, heads=6, layers=16, stages=8,
            n=64, vocab=512, k=8, b=2,
            modes=("subspace", "raw"),
        ),
        ModelConfig(
            name="wide", d=512, d_ff=2048, heads=8, layers=16, stages=8,
            n=128, vocab=2048, k=8, b=2,
            modes=("subspace", "raw"),
        ),
    ]
}

# Configs built by default (`make artifacts`). "wide" is opt-in via
# `python -m compile.aot --configs all`.
DEFAULT_BUILD = ("tiny", "small", "base", "deep16", "ctx128", "ctx256")
