"""Optimizer entrypoints — AdamW and the Sec. 5 subspace-preserving variant.

Per-parameter rules (subspace mode):
  * ``*_wp2`` and ``t_s``  — row-wise-constant second moment (Pallas
    kernel), which keeps Row(W) ⊆ S exactly, so these are NEVER
    re-projected during normal steps (Appendix A).
  * ``*_wp1``             — standard AdamW followed by an explicit row
    projection onto S (required because of the attention nonlinearity
    upstream; Sec. 5 / Appendix A).
  * everything else       — standard AdamW.

Raw/lossy modes use standard AdamW for all parameters.

Learning-rate schedule scalars (lr, bias corrections from the step count)
are computed by the rust coordinator and passed in, so warmup/decay live
in L3 where the step counter lives.
"""

from __future__ import annotations

import jax.numpy as jnp

from .configs import ModelConfig, stage_param_schema
from .kernels import subspace as K

BETA1 = K.BETA1
BETA2 = K.BETA2
EPS = K.EPS
WEIGHT_DECAY = 0.01
# LayerNorm gains/biases are excluded from weight decay (standard practice).
NO_DECAY_SUFFIXES = ("_g", "_b")


def _h(lr, t, wd):
    """[lr, 1−β1ᵗ, 1−β2ᵗ, wd] — the schedule-dependent scalars."""
    bc1 = 1.0 - jnp.power(jnp.float32(BETA1), t)
    bc2 = 1.0 - jnp.power(jnp.float32(BETA2), t)
    return jnp.stack([lr, bc1, bc2, jnp.float32(wd)])


def _standard(w, g, m, v, lr, bc1, bc2, wd):
    m_new = BETA1 * m + (1.0 - BETA1) * g
    v_new = BETA2 * v + (1.0 - BETA2) * g * g
    mhat = m_new / bc1
    vhat = v_new / bc2
    w_new = w - lr * mhat / (jnp.sqrt(vhat) + EPS) - lr * wd * w
    return w_new, m_new, v_new


def _decay_for(name: str) -> float:
    return 0.0 if name.endswith(NO_DECAY_SUFFIXES) else WEIGHT_DECAY


def adamw_subspace(cfg: ModelConfig, stage: int, flat_w, flat_g, flat_m,
                   flat_v, u, lr, t):
    """One optimizer step for a whole stage (subspace mode)."""
    bc1 = 1.0 - jnp.power(jnp.float32(BETA1), t)
    bc2 = 1.0 - jnp.power(jnp.float32(BETA2), t)
    proj = u @ u.T
    schema = stage_param_schema(cfg, stage)
    w_out, m_out, v_out = [], [], []
    for (name, _), w, g, m, v in zip(schema, flat_w, flat_g, flat_m, flat_v):
        wd = _decay_for(name)
        if name.endswith("wp2") or name == "t_s":
            w2, m2, v2 = K.rowwise_adamw(w, g, m, v, u, _h(lr, t, wd))
        elif name.endswith("wp1"):
            w2, m2, v2 = _standard(w, g, m, v, lr, bc1, bc2, wd)
            w2 = w2 @ proj  # iterative projection back onto S
        else:
            w2, m2, v2 = _standard(w, g, m, v, lr, bc1, bc2, wd)
        w_out.append(w2)
        m_out.append(m2)
        v_out.append(v2)
    return tuple(w_out), tuple(m_out), tuple(v_out)


def adamw_standard(cfg: ModelConfig, stage: int, flat_w, flat_g, flat_m,
                   flat_v, lr, t):
    """One optimizer step for a whole stage (raw / lossy baselines)."""
    bc1 = 1.0 - jnp.power(jnp.float32(BETA1), t)
    bc2 = 1.0 - jnp.power(jnp.float32(BETA2), t)
    schema = stage_param_schema(cfg, stage)
    w_out, m_out, v_out = [], [], []
    for (name, _), w, g, m, v in zip(schema, flat_w, flat_g, flat_m, flat_v):
        w2, m2, v2 = _standard(w, g, m, v, lr, bc1, bc2, _decay_for(name))
        w_out.append(w2)
        m_out.append(m2)
        v_out.append(v2)
    return tuple(w_out), tuple(m_out), tuple(v_out)
