"""Lossy boundary compressors — the DDP-style baselines of Fig. 6 / Thm B.1.

Each `*_cd` function is a fused compress→decompress round trip applied at a
pipeline boundary: the tensor that the downstream stage *sees* is the lossy
reconstruction, so approximation error propagates through layers exactly as
in a real deployment (Statement 7.1). Wire byte counts are analytic
(`wire_bytes`) and consumed by the rust netsim, mirrored by
rust/src/compress.

"SVD low-rank" substitution: exact SVD lowers to LAPACK custom-calls the
portable HLO runtime cannot execute, so we use single-shot subspace
iteration with a fixed Gaussian sketch (PowerSGD-style), the standard
practical stand-in — if anything *more* favourable to the baseline
(DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def topk_keep(numel: int, ratio: float) -> int:
    """Elements kept so that (value,index) pairs hit the byte ratio:
    kept·8B ≤ numel·4B / ratio."""
    return max(1, int(numel * 4.0 / (8.0 * ratio)))


def topk_cd(x, ratio: float):
    """Magnitude top-k sparsification over the whole tensor.

    Implemented via argsort rather than jax.lax.top_k: the latter lowers
    to a `topk(..., largest=true)` HLO instruction that xla_extension
    0.5.1's text parser rejects; `sort` is classic HLO and round-trips.
    """
    flat = x.reshape(-1)
    kk = topk_keep(flat.shape[0], ratio)
    order = jnp.argsort(-jnp.abs(flat))
    idx = order[:kk]
    out = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return out.reshape(x.shape)


def quant_cd(x, bits: int = 8):
    """Per-tensor symmetric uniform quantization (int8 by default — 4×
    over f32; the paper notes quantization cannot reach 100×)."""
    qmax = float(2 ** (bits - 1) - 1)
    scale = jnp.max(jnp.abs(x)) / qmax + 1e-12
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    return q * scale


def powerlr_rank(n: int, d: int, ratio: float) -> int:
    """Rank giving wire bytes (n+d)·r·4 ≈ n·d·4 / ratio."""
    return max(1, int(n * d / (ratio * (n + d))))


def _orthonormalize(p):
    """Modified Gram–Schmidt over the (few) columns of p — QR-free."""
    r = p.shape[1]
    q = jnp.zeros_like(p)

    def body(i, q):
        v = p[:, i] - q @ (q.T @ p[:, i])
        v = v / (jnp.linalg.norm(v) + 1e-8)
        return q.at[:, i].set(v)

    return jax.lax.fori_loop(0, r, body, q)


def powerlr_cd(x, ratio: float, seed: int = 17):
    """Rank-r approximation of each (n, d) slice via one subspace
    iteration with a fixed sketch (deterministic; baked as a constant)."""
    b, n, d = x.shape
    r = powerlr_rank(n, d, ratio)
    sketch = jnp.asarray(
        np.random.default_rng(seed).standard_normal((d, r)), dtype=x.dtype
    )

    def one(xm):
        p = _orthonormalize(xm @ sketch)          # (n, r)
        return p @ (p.T @ xm)                      # (n, r) @ (r, d)

    return jax.vmap(one)(x)


def boundary_cd(mode: str, ratio: float):
    """The compress→decompress closure for a lossy mode (or identity)."""
    if mode == "topk":
        return lambda x: topk_cd(x, ratio)
    if mode == "quant":
        return lambda x: quant_cd(x, 8)
    if mode == "powerlr":
        return lambda x: powerlr_cd(x, ratio)
    if mode == "raw":
        return lambda x: x
    raise ValueError(f"not a lossy mode: {mode}")


def wire_bytes(mode: str, b: int, n: int, d: int, k: int, ratio: float) -> int:
    """Bytes on the wire for one boundary tensor under each scheme
    (f32 payloads; mirrored in rust/src/compress/mod.rs)."""
    dense = b * n * d * 4
    if mode == "subspace":
        return b * n * k * 4
    if mode == "raw":
        return dense
    if mode == "topk":
        return topk_keep(b * n * d, ratio) * 8
    if mode == "quant":
        return b * n * d * 1 + 4  # int8 + scale
    if mode == "powerlr":
        r = powerlr_rank(n, d, ratio)
        return b * (n + d) * r * 4
    raise ValueError(mode)
