"""AOT compiler: lower every (config × mode × entrypoint) to HLO text.

HLO *text* (never ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1
rejects; the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Outputs:
    artifacts/<config>/<mode>/<entry>.hlo.txt
    artifacts/manifest.json   — shapes / arg order / hyperparams for rust

Usage:
    python -m compile.aot [--configs tiny,small | all] [--out-dir DIR]
                          [--force]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, optim
from .configs import (CONFIGS, DEFAULT_BUILD, MANIFEST_VERSION, ModelConfig,
                      constrained_names, stage_param_schema)
from .kernels import subspace as K


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _param_specs(cfg: ModelConfig, stage: int, prefix: str):
    return [
        (f"{prefix}.{name}", _f32(*shape))
        for name, shape in stage_param_schema(cfg, stage)
    ]


Entry = Tuple[object, List[Tuple[str, object]]]  # (fn, [(argname, spec-or-list)])


def build_entries(cfg: ModelConfig) -> Dict[str, Entry]:
    """All entrypoints for one config, keyed "<mode>/<entry>".

    An arg whose spec is a *list* is a whole parameter bundle; its manifest
    names come from the stage schema.
    """
    d, k, n, v, b = cfg.d, cfg.k, cfg.n, cfg.vocab, cfg.b
    last = cfg.stages - 1
    u = ("u", _f32(d, k))
    tf = ("t_fixed", _f32(v, d))
    tok = ("tok", _i32(b, n))
    tgt = ("targets", _i32(b, n))
    xc_ = ("xc_in", _f32(b, n, k))
    gc_ = ("gc_out", _f32(b, n, k))
    xf_ = ("x_in", _f32(b, n, d))
    gf_ = ("g_out", _f32(b, n, d))
    lr = ("lr", _f32())
    t = ("t", _f32())

    def P(stage, prefix="p"):
        return (prefix, [s for _, s in _param_specs(cfg, stage, prefix)],
                [nm for nm, _ in _param_specs(cfg, stage, prefix)])

    # an arg triple (name, spec, flat_names) for bundles; pairs for leaves
    entries: Dict[str, Entry] = {}

    def add(mode, name, fn, args):
        entries[f"{mode}/{name}"] = (fn, args)

    for mode in cfg.modes:
        if mode == "subspace":
            add(mode, "first_fwd",
                lambda p, uu, tff, tk: model.first_fwd(cfg, p, uu, tff, tk),
                [P(0), u, tf, tok])
            add(mode, "first_bwd",
                lambda p, uu, tff, tk, g: model.first_bwd(cfg, p, uu, tff, tk, g),
                [P(0), u, tf, tok, ("gc_in", _f32(b, n, k))])
            if cfg.stages >= 3:
                add(mode, "mid_fwd",
                    lambda p, uu, tff, tk, x: model.mid_fwd(cfg, p, uu, tff, tk, x),
                    [P(1), u, tf, tok, xc_])
                add(mode, "mid_bwd",
                    lambda p, uu, tff, tk, x, g: model.mid_bwd(cfg, p, uu, tff, tk, x, g),
                    [P(1), u, tf, tok, xc_, gc_])
            add(mode, "last_loss",
                lambda p, uu, tff, tk, x, tg: model.last_loss(cfg, p, uu, tff, tk, x, tg),
                [P(last), u, tf, tok, xc_, tgt])
            add(mode, "last_eval",
                lambda p, uu, tff, tk, x, tg: model.last_eval(cfg, p, uu, tff, tk, x, tg),
                [P(last), u, tf, tok, xc_, tgt])
            for kind, stage in (("first", 0), ("mid", min(1, cfg.stages - 1)),
                                ("last", last)):
                add(mode, f"adamw_{kind}",
                    (lambda st: lambda w, g, m, vv, uu, l, tt:
                        optim.adamw_subspace(cfg, st, w, g, m, vv, uu, l, tt))(stage),
                    [P(stage, "w"), P(stage, "g"), P(stage, "m"),
                     P(stage, "v"), u, lr, t])
                add(mode, f"reproject_{kind}",
                    (lambda st: lambda w, m, uu:
                        model.reproject(cfg, st, w, m, uu))(stage),
                    [P(stage, "w"), P(stage, "m"), u])
            add(mode, "grassmann_step",
                lambda uu, s, e: model.grassmann_step(uu, s, e),
                [u, ("s_acc", _f32(d, d)), ("eta", _f32())])
        elif mode == "nofixed":
            add(mode, "first_fwd",
                lambda p, uu, tk: model.first_fwd_nofixed(cfg, p, uu, tk),
                [P(0), u, tok])
            add(mode, "first_bwd",
                lambda p, uu, tk, g: model.first_bwd_nofixed(cfg, p, uu, tk, g),
                [P(0), u, tok, ("gc_in", _f32(b, n, k))])
            if cfg.stages >= 3:
                add(mode, "mid_fwd",
                    lambda p, uu, tk, x: model.mid_fwd_nofixed(cfg, p, uu, tk, x),
                    [P(1), u, tok, xc_])
                add(mode, "mid_bwd",
                    lambda p, uu, tk, x, g: model.mid_bwd_nofixed(cfg, p, uu, tk, x, g),
                    [P(1), u, tok, xc_, gc_])
            add(mode, "last_loss",
                lambda p, uu, tk, x, tg: model.last_loss_nofixed(cfg, p, uu, tk, x, tg),
                [P(last), u, tok, xc_, tgt])
            add(mode, "last_eval",
                lambda p, uu, tk, x, tg: model.last_eval_nofixed(cfg, p, uu, tk, x, tg),
                [P(last), u, tok, xc_, tgt])
            # optimizer / reproject / grassmann entries are shared with
            # "subspace" (identical schemas and constraint rules)
        else:
            add(mode, "first_fwd",
                (lambda md: lambda p, tk: model.first_fwd_lossy(cfg, md, p, tk))(mode),
                [P(0), tok])
            add(mode, "first_bwd",
                (lambda md: lambda p, tk, g: model.first_bwd_lossy(cfg, md, p, tk, g))(mode),
                [P(0), tok, ("g_in", _f32(b, n, d))])
            if cfg.stages >= 3:
                add(mode, "mid_fwd",
                    (lambda md: lambda p, x: model.mid_fwd_lossy(cfg, md, p, x))(mode),
                    [P(1), xf_])
                add(mode, "mid_bwd",
                    (lambda md: lambda p, x, g: model.mid_bwd_lossy(cfg, md, p, x, g))(mode),
                    [P(1), xf_, gf_])
            add(mode, "last_loss",
                (lambda md: lambda p, x, tg: model.last_loss_lossy(cfg, md, p, x, tg))(mode),
                [P(last), xf_, tgt])
            add(mode, "last_eval",
                lambda p, x, tg: model.last_eval_lossy(cfg, p, x, tg),
                [P(last), xf_, tgt])
            if mode == "raw":
                for kind, stage in (("first", 0), ("mid", min(1, cfg.stages - 1)),
                                    ("last", last)):
                    add(mode, f"adamw_{kind}",
                        (lambda st: lambda w, g, m, vv, l, tt:
                            optim.adamw_standard(cfg, st, w, g, m, vv, l, tt))(stage),
                        [P(stage, "w"), P(stage, "g"), P(stage, "m"),
                         P(stage, "v"), lr, t])
    return entries


def _flatten_args(args):
    """→ (lowering specs in call order, manifest flat-arg descriptors)."""
    specs, flat = [], []
    for a in args:
        if len(a) == 3:  # parameter bundle
            _, spec_list, names = a
            specs.append(list(spec_list))
            for nm, sp in zip(names, spec_list):
                flat.append({"name": nm, "shape": list(sp.shape),
                             "dtype": _dt(sp.dtype)})
        else:
            nm, sp = a
            specs.append(sp)
            flat.append({"name": nm, "shape": list(sp.shape),
                         "dtype": _dt(sp.dtype)})
    return specs, flat


def _dt(dtype) -> str:
    return {"float32": "f32", "int32": "i32"}[jnp.dtype(dtype).name]


def lower_entry(fn, specs) -> Tuple[str, list]:
    lowered = jax.jit(fn).lower(*specs)
    out_shapes = jax.tree_util.tree_leaves(jax.eval_shape(fn, *specs))
    outs = [{"shape": list(o.shape), "dtype": _dt(o.dtype)} for o in out_shapes]
    return to_hlo_text(lowered), outs


def config_manifest(cfg: ModelConfig) -> dict:
    rowwise0, reproj0 = constrained_names(cfg, 0)
    return {
        "hyper": {
            "d": cfg.d, "d_ff": cfg.d_ff, "heads": cfg.heads,
            "layers": cfg.layers, "stages": cfg.stages, "n": cfg.n,
            "vocab": cfg.vocab, "k": cfg.k, "b": cfg.b,
            "blocks_per_stage": cfg.blocks_per_stage,
            "ratio": cfg.compression_ratio,
            "param_count": cfg.param_count,
        },
        "modes": list(cfg.modes),
        "schemas": {
            kind: [[nm, list(sh)] for nm, sh in
                   stage_param_schema(cfg, stage)]
            for kind, stage in (
                ("first", 0), ("mid", min(1, cfg.stages - 1)),
                ("last", cfg.stages - 1))
        },
        "constrained": {"rowwise": rowwise0, "reproject": reproj0},
        "optimizer": {
            "beta1": optim.BETA1, "beta2": optim.BETA2, "eps": optim.EPS,
            "weight_decay": optim.WEIGHT_DECAY,
        },
        "entries": {},
    }


def build(config_names, out_dir: str, force: bool) -> None:
    manifest_path = os.path.join(out_dir, "manifest.json")
    # merge into an existing manifest so partial rebuilds don't clobber
    # other configs' entries
    manifest = {"version": MANIFEST_VERSION, "configs": {}}
    if os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                old = json.load(f)
            if old.get("version") == MANIFEST_VERSION:
                manifest["configs"].update(old.get("configs", {}))
        except (json.JSONDecodeError, OSError):
            pass
    for cname in config_names:
        cfg = CONFIGS[cname]
        cm = config_manifest(cfg)
        entries = build_entries(cfg)
        for key, (fn, args) in sorted(entries.items()):
            mode, ename = key.split("/")
            rel = os.path.join(cname, mode, f"{ename}.hlo.txt")
            path = os.path.join(out_dir, rel)
            os.makedirs(os.path.dirname(path), exist_ok=True)
            specs, flat_args = _flatten_args(args)
            if force or not os.path.exists(path):
                text, outs = lower_entry(fn, specs)
                with open(path, "w") as f:
                    f.write(text)
                print(f"  lowered {cname}/{key}  "
                      f"({len(text)//1024} KiB, {len(outs)} outs)")
            else:
                # shapes must still go into the manifest
                outs = [
                    {"shape": list(o.shape), "dtype": _dt(o.dtype)}
                    for o in jax.tree_util.tree_leaves(
                        jax.eval_shape(fn, *specs))
                ]
                print(f"  cached  {cname}/{key}")
            cm["entries"][key] = {"file": rel, "args": flat_args, "outs": outs}
        manifest["configs"][cname] = cm
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {manifest_path} ({len(manifest['configs'])} configs)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--configs", default=",".join(DEFAULT_BUILD),
                    help="comma list of config names, or 'all'")
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--force", action="store_true",
                    help="re-lower even if the .hlo.txt exists")
    args = ap.parse_args()
    names = (list(CONFIGS) if args.configs == "all"
             else [c for c in args.configs.split(",") if c])
    for nm in names:
        if nm not in CONFIGS:
            sys.exit(f"unknown config {nm!r}; have {list(CONFIGS)}")
    out = os.path.abspath(args.out_dir)
    os.makedirs(out, exist_ok=True)
    build(names, out, args.force)


if __name__ == "__main__":
    main()
