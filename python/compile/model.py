"""L2 — the paper's transformer, partitioned into pipeline-stage programs.

Each pipeline stage is lowered to standalone HLO entrypoints (forward,
fused recompute-backward, loss head, optimizer step, …) that the rust
coordinator executes via PJRT. The boundary compression of Sec. 4 is
*inside* these programs (calling the L1 Pallas kernels), so the tensors
crossing stage boundaries — and therefore the bytes the rust netsim
accounts — are exactly the compressed (b, n, k) payloads.

Architecture (Sec. 3, pre-LN so the residual-stream recursion of Eq. 4
holds: every write into the stream goes through W_p1 or W_p2, whose rows
are confined to S):

    x  = x + Attn(LN(x)) @ W_p1
    x  = x + relu(LN(x) @ W_1) @ W_p2

Backward passes use GPipe-style rematerialization: `*_bwd` entrypoints
take the stage's saved (compressed) input plus the incoming (compressed)
output-gradient and recompute the forward inside one fused HLO, returning
the input-gradient and parameter gradients.
"""

from __future__ import annotations

from typing import Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import baselines
from .configs import ModelConfig, stage_param_schema
from .kernels import subspace as K


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------


def sinusoidal_pe(n: int, d: int, dtype=jnp.float32):
    """Deterministic positional embedding — computable locally on every
    node (Sec. 4.3.1), hence part of the high-rank additive component E."""
    pos = np.arange(n)[:, None].astype(np.float64)
    i = np.arange(d)[None, :].astype(np.float64)
    angle = pos / np.power(10000.0, 2.0 * (i // 2) / d)
    pe = np.where(i % 2 == 0, np.sin(angle), np.cos(angle))
    return jnp.asarray(pe, dtype=dtype)


def layer_norm(x, g, b, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def causal_attention(x, wq, wk, wv, heads: int):
    b, n, d = x.shape
    dh = d // heads

    def split(w):
        return (x @ w).reshape(b, n, heads, dh).transpose(0, 2, 1, 3)

    q, k, v = split(wq), split(wk), split(wv)
    scores = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(dh))
    mask = jnp.tril(jnp.ones((n, n), dtype=bool))
    scores = jnp.where(mask[None, None], scores, jnp.float32(-1e9))
    att = jax.nn.softmax(scores, axis=-1)
    return (att @ v).transpose(0, 2, 1, 3).reshape(b, n, d)


def pack(cfg: ModelConfig, stage: int, flat: Sequence) -> Dict[str, jnp.ndarray]:
    schema = stage_param_schema(cfg, stage)
    assert len(flat) == len(schema), (len(flat), len(schema), stage)
    return {name: arr for (name, _), arr in zip(schema, flat)}


def apply_block(p: Dict[str, jnp.ndarray], blk: int, x, heads: int):
    g = lambda name: p[f"b{blk}_{name}"]
    a = layer_norm(x, g("ln1_g"), g("ln1_b"))
    attn = causal_attention(a, g("wq"), g("wk"), g("wv"), heads)
    x = x + attn @ g("wp1")
    h = layer_norm(x, g("ln2_g"), g("ln2_b"))
    h = jax.nn.relu(h @ g("w1"))
    x = x + h @ g("wp2")
    return x


def stage_blocks(cfg: ModelConfig, p: Dict[str, jnp.ndarray], x):
    for blk in range(cfg.blocks_per_stage):
        x = apply_block(p, blk, x, cfg.heads)
    return x


def ce_loss(logits, targets):
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return -jnp.mean(ll)


def high_rank_e(cfg: ModelConfig, t_fixed, tok):
    """E = PE + T_fixed[tok] — the static high-rank component subtracted
    before projection and re-added after reconstruction (Eq. 8)."""
    return sinusoidal_pe(cfg.n, cfg.d)[None] + t_fixed[tok]


# ---------------------------------------------------------------------------
# subspace-mode stage programs (the paper's method)
# ---------------------------------------------------------------------------


def first_fwd(cfg: ModelConfig, flat, u, t_fixed, tok):
    """Stage 0: embed (T_fixed + T_S + PE), run blocks, emit compressed."""
    p = pack(cfg, 0, flat)
    e = high_rank_e(cfg, t_fixed, tok)
    x = e + p["t_s"][tok]
    x = stage_blocks(cfg, p, x)
    return K.subspace_project(x, e, u)


def first_bwd(cfg: ModelConfig, flat, u, t_fixed, tok, gc):
    _, vjp = jax.vjp(lambda fl: first_fwd(cfg, fl, u, t_fixed, tok), list(flat))
    (grads,) = vjp(gc)
    return tuple(grads)


def mid_fwd(cfg: ModelConfig, flat, u, t_fixed, tok, xc):
    p = pack(cfg, 1, flat)
    e = high_rank_e(cfg, t_fixed, tok)
    x = K.subspace_reconstruct(xc, e, u)
    x = stage_blocks(cfg, p, x)
    return K.subspace_project(x, e, u)


def mid_bwd(cfg: ModelConfig, flat, u, t_fixed, tok, xc, gc_out):
    _, vjp = jax.vjp(
        lambda fl, xin: mid_fwd(cfg, fl, u, t_fixed, tok, xin), list(flat), xc
    )
    grads, gc_in = vjp(gc_out)
    return gc_in, tuple(grads)


def _last_inner(cfg: ModelConfig, flat, x, targets):
    p = pack(cfg, cfg.stages - 1, flat)
    x = stage_blocks(cfg, p, x)
    x = layer_norm(x, p["lnf_g"], p["lnf_b"])
    logits = x @ p["w_head"]
    return ce_loss(logits, targets)


def last_loss(cfg: ModelConfig, flat, u, t_fixed, tok, xc, targets):
    """Last stage fwd+bwd fused: loss, compressed input-gradient, parameter
    gradients, and the Grassmann accumulator term GᵀG (Sec. 6)."""
    e = high_rank_e(cfg, t_fixed, tok)
    x_full = K.subspace_reconstruct(xc, e, u)
    loss, vjp = jax.vjp(
        lambda fl, xf: _last_inner(cfg, fl, xf, targets), list(flat), x_full
    )
    grads, g_full = vjp(jnp.float32(1.0))
    g2 = g_full.reshape(-1, cfg.d)
    gtg = g2.T @ g2
    gc = K.grad_project(g_full, u)
    return loss, gc, tuple(grads), gtg


def last_eval(cfg: ModelConfig, flat, u, t_fixed, tok, xc, targets):
    e = high_rank_e(cfg, t_fixed, tok)
    x_full = K.subspace_reconstruct(xc, e, u)
    return _last_inner(cfg, flat, x_full, targets)


# ---------------------------------------------------------------------------
# raw (uncompressed) and lossy-baseline stage programs
# ---------------------------------------------------------------------------


def _embed_raw(cfg: ModelConfig, p, tok):
    # Raw mode keeps a single full embedding table (stored in the t_s slot).
    return sinusoidal_pe(cfg.n, cfg.d)[None] + p["t_s"][tok]


def _first_clean(cfg, flat, tok):
    p = pack(cfg, 0, flat)
    return stage_blocks(cfg, p, _embed_raw(cfg, p, tok))


def first_fwd_lossy(cfg: ModelConfig, mode: str, flat, tok):
    x = _first_clean(cfg, flat, tok)
    if mode == "raw":
        return x
    return baselines.boundary_cd(mode, cfg.compression_ratio)(x)


def first_bwd_lossy(cfg: ModelConfig, mode: str, flat, tok, g):
    # Backprop through the stage's own exact computation; the incoming g is
    # whatever the (possibly lossy) wire delivered. The first stage
    # transmits no gradients, so `mode` plays no role here.
    del mode
    _, vjp = jax.vjp(lambda fl: _first_clean(cfg, fl, tok), list(flat))
    (grads,) = vjp(g)
    return tuple(grads)


def _mid_clean(cfg, flat, x):
    return stage_blocks(cfg, pack(cfg, 1, flat), x)


def mid_fwd_lossy(cfg: ModelConfig, mode: str, flat, x):
    x = _mid_clean(cfg, flat, x)
    if mode == "raw":
        return x
    return baselines.boundary_cd(mode, cfg.compression_ratio)(x)


def mid_bwd_lossy(cfg: ModelConfig, mode: str, flat, x, g_out):
    _, vjp = jax.vjp(lambda fl, xin: _mid_clean(cfg, fl, xin), list(flat), x)
    grads, g_in = vjp(g_out)
    if mode != "raw":
        g_in = baselines.boundary_cd(mode, cfg.compression_ratio)(g_in)
    return g_in, tuple(grads)


def last_loss_lossy(cfg: ModelConfig, mode: str, flat, x, targets):
    loss, vjp = jax.vjp(
        lambda fl, xf: _last_inner(cfg, fl, xf, targets), list(flat), x
    )
    grads, g_full = vjp(jnp.float32(1.0))
    if mode != "raw":
        g_full = baselines.boundary_cd(mode, cfg.compression_ratio)(g_full)
    return loss, g_full, tuple(grads)


def last_eval_lossy(cfg: ModelConfig, flat, x, targets):
    return _last_inner(cfg, flat, x, targets)


# ---------------------------------------------------------------------------
# "nofixed" ablation (Fig. 15): the token embedding is restricted entirely
# to S (no fixed high-rank component). Mathematically still lossless on
# the wire, but the representation capacity of TE is crippled — the paper
# shows (and we reproduce) inferior convergence.
# ---------------------------------------------------------------------------


def _pe_e(cfg: ModelConfig, tok):
    b = tok.shape[0]
    e = jnp.broadcast_to(
        sinusoidal_pe(cfg.n, cfg.d)[None], (b, cfg.n, cfg.d))
    # keep `tok` alive in the traced graph (exact zero contribution) so
    # the lowered entry keeps a uniform signature across nofixed programs
    # — jax would otherwise DCE the unused parameter and desync the
    # manifest arg count from the compiled program.
    return e + 0.0 * tok[..., None].astype(e.dtype)


def first_fwd_nofixed(cfg: ModelConfig, flat, u, tok):
    p = pack(cfg, 0, flat)
    e = _pe_e(cfg, tok)
    x = e + p["t_s"][tok]  # t_s is the ONLY embedding, Row(t_s) ⊆ S
    x = stage_blocks(cfg, p, x)
    return K.subspace_project(x, e, u)


def first_bwd_nofixed(cfg: ModelConfig, flat, u, tok, gc):
    _, vjp = jax.vjp(
        lambda fl: first_fwd_nofixed(cfg, fl, u, tok), list(flat))
    (grads,) = vjp(gc)
    return tuple(grads)


def mid_fwd_nofixed(cfg: ModelConfig, flat, u, tok, xc):
    p = pack(cfg, 1, flat)
    e = _pe_e(cfg, tok)
    x = K.subspace_reconstruct(xc, e, u)
    x = stage_blocks(cfg, p, x)
    return K.subspace_project(x, e, u)


def mid_bwd_nofixed(cfg: ModelConfig, flat, u, tok, xc, gc_out):
    _, vjp = jax.vjp(
        lambda fl, xin: mid_fwd_nofixed(cfg, fl, u, tok, xin),
        list(flat), xc)
    grads, gc_in = vjp(gc_out)
    return gc_in, tuple(grads)


def last_loss_nofixed(cfg: ModelConfig, flat, u, tok, xc, targets):
    e = _pe_e(cfg, tok)
    x_full = K.subspace_reconstruct(xc, e, u)
    loss, vjp = jax.vjp(
        lambda fl, xf: _last_inner(cfg, fl, xf, targets), list(flat), x_full)
    grads, g_full = vjp(jnp.float32(1.0))
    g2 = g_full.reshape(-1, cfg.d)
    gtg = g2.T @ g2
    gc = K.grad_project(g_full, u)
    return loss, gc, tuple(grads), gtg


def last_eval_nofixed(cfg: ModelConfig, flat, u, tok, xc, targets):
    e = _pe_e(cfg, tok)
    x_full = K.subspace_reconstruct(xc, e, u)
    return _last_inner(cfg, flat, x_full, targets)


# ---------------------------------------------------------------------------
# subspace maintenance (Sec. 4.5 / Grassmann)
# ---------------------------------------------------------------------------


def grassmann_step(u, s_acc, eta):
    """One Riemannian descent step on G(k, d) minimizing the leftover
    gradient energy, followed by a Gram–Schmidt retraction (Sec. 4.5, 6).

    ∇L(U) = −2·S·U;  tangent = ∇ − U Uᵀ ∇;  retract = orthonormalize.
    """
    g_euc = -2.0 * (s_acc @ u)
    g_tan = g_euc - u @ (u.T @ g_euc)
    u_new = u - eta * g_tan
    return baselines._orthonormalize(u_new)


def reproject(cfg: ModelConfig, stage: int, flat_w, flat_m, u):
    """Project the constrained matrices (and their first momenta) onto the
    current S — run after every Grassmann subspace update."""
    proj = u @ u.T
    schema = stage_param_schema(cfg, stage)
    w_out, m_out = [], []
    for (name, _), w, m in zip(schema, flat_w, flat_m):
        if name.endswith("wp1") or name.endswith("wp2") or name == "t_s":
            w_out.append(w @ proj)
            m_out.append(m @ proj)
        else:
            w_out.append(w)
            m_out.append(m)
    return tuple(w_out), tuple(m_out)
