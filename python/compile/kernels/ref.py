"""Pure-jnp oracles for every Pallas kernel in subspace.py.

These are the CORE correctness signal: pytest (python/tests/test_kernels.py)
sweeps shapes/dtypes with hypothesis and asserts allclose between the
Pallas kernels and these references. They are intentionally written as the
most direct transcription of the paper's equations.
"""

from __future__ import annotations

import jax.numpy as jnp

BETA1 = 0.9
BETA2 = 0.999
EPS = 1e-8


def subspace_project(x, e, u):
    """Eq. 8: Xc = (X − E) U_k."""
    return (x - e) @ u


def subspace_reconstruct(xc, e, u):
    """Reconstruction: X = Xc U_kᵀ + E."""
    return xc @ u.T + e


def grad_project(g, u):
    """Eq. 9: Gc = ∇X · U_k."""
    return g @ u


def grad_expand(gc, u):
    """Eq. 10: ∇X = Gc · U_kᵀ."""
    return gc @ u.T


def rowwise_adamw(w, g, m, v, u, h):
    """Sec. 5: project g onto S = Col(u), then AdamW with row-constant
    second-moment scaling.

    h = [lr, 1−β1ᵗ, 1−β2ᵗ, weight_decay].
    """
    lr, bc1, bc2, wd = h[0], h[1], h[2], h[3]
    g = (g @ u) @ u.T
    m_new = BETA1 * m + (1.0 - BETA1) * g
    v_new = BETA2 * v + (1.0 - BETA2) * g * g
    mhat = m_new / bc1
    vhat = v_new / bc2
    vrow = jnp.mean(vhat, axis=1, keepdims=True)
    w_new = w - lr * mhat / (jnp.sqrt(vrow) + EPS) - lr * wd * w
    return w_new, m_new, v_new


def standard_adamw(w, g, m, v, h):
    """Unmodified AdamW (Eq. 12) — used for all unconstrained weights."""
    lr, bc1, bc2, wd = h[0], h[1], h[2], h[3]
    m_new = BETA1 * m + (1.0 - BETA1) * g
    v_new = BETA2 * v + (1.0 - BETA2) * g * g
    mhat = m_new / bc1
    vhat = v_new / bc2
    w_new = w - lr * mhat / (jnp.sqrt(vhat) + EPS) - lr * wd * w
    return w_new, m_new, v_new
