"""L1 — Pallas kernels for the subspace boundary compression hot path.

The paper's wire compression is, computationally, a fused
``subtract-high-rank-embeddings + project-onto-U_k`` at the sending stage
and ``expand-from-U_k + add-high-rank-embeddings`` at the receiving stage
(Sec. 4.3/4.3.1), plus the row-wise-constant AdamW second-moment update
(Sec. 5). These are the per-token O(d·k) operations executed at every
pipeline boundary for every microbatch, so they are implemented as Pallas
kernels.

TPU mapping (DESIGN.md §Hardware-Adaptation): rows of the flattened
(b·n, d) activation tensor are tiled into BM-row panels streamed
HBM→VMEM by the BlockSpec index maps, while the (d, k) U_k panel stays
resident in VMEM across the whole grid (d·k·4B ≤ 1 MiB at paper scale).
The subtraction is fused into the same pass as the matmul so the d-wide
activation read is amortized over both operations.

All kernels run with ``interpret=True``: the CPU PJRT runtime cannot
execute Mosaic custom-calls, and the interpret path lowers to plain HLO
that the rust runtime loads (see /opt/xla-example/README.md).

Autodiff: ``pallas_call`` is not differentiable, so the public entry
points carry ``jax.custom_vjp`` with closed-form backward rules
(Appendix A): d/dX[(X−E)U] = ct·Uᵀ and d/dXc[Xc·Uᵀ+E] = ct·U — themselves
implemented with the same kernels. Cotangents w.r.t. U are *not*
propagated (U is a frozen constant between Grassmann updates); cotangents
w.r.t. E are exact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile height. All shipped configs keep b·n a multiple of BM; other
# shapes transparently fall back to the pure-jnp path (same math).
BM = 64

# AdamW constants (baked; the schedule-dependent scalars arrive as args).
BETA1 = 0.9
BETA2 = 0.999
EPS = 1e-8

_INTERPRET = True


def _rows_ok(rows: int) -> bool:
    return rows % BM == 0 and rows >= BM


# ---------------------------------------------------------------------------
# kernel bodies
# ---------------------------------------------------------------------------


def _project_kernel(x_ref, e_ref, u_ref, o_ref):
    """o = (x − e) @ u for one (BM, d) row panel; u resident (d, k)."""
    o_ref[...] = (x_ref[...] - e_ref[...]) @ u_ref[...]


def _reconstruct_kernel(xc_ref, e_ref, u_ref, o_ref):
    """o = xc @ uᵀ + e for one (BM, k) row panel."""
    o_ref[...] = xc_ref[...] @ u_ref[...].T + e_ref[...]


def _mm_kernel(a_ref, b_ref, o_ref):
    """o = a @ b (gradient projection: G·U)."""
    o_ref[...] = a_ref[...] @ b_ref[...]


def _mm_t_kernel(a_ref, b_ref, o_ref):
    """o = a @ bᵀ (gradient expansion: Gc·Uᵀ)."""
    o_ref[...] = a_ref[...] @ b_ref[...].T


def _rowwise_adamw_kernel(w_ref, g_ref, m_ref, v_ref, u_ref, h_ref,
                          w_o, m_o, v_o):
    """Sec. 5 modified AdamW: the incoming gradient is first projected onto
    S (the proximal/constrained-optimization step — required because the
    stream gradient picks up out-of-S components from branch backprop
    within a stage), then the 1/√V̂ scaling is made constant per row
    (V̂ → row-mean) so the update direction stays inside Row(W) ⊆ S and W
    itself never needs re-projection.

    h = [lr, 1−β1ᵗ, 1−β2ᵗ, weight_decay]."""
    lr, bc1, bc2, wd = h_ref[0], h_ref[1], h_ref[2], h_ref[3]
    u = u_ref[...]
    g = (g_ref[...] @ u) @ u.T  # fused projection onto S
    m = BETA1 * m_ref[...] + (1.0 - BETA1) * g
    v = BETA2 * v_ref[...] + (1.0 - BETA2) * g * g
    mhat = m / bc1
    vhat = v / bc2
    vrow = jnp.mean(vhat, axis=1, keepdims=True)
    upd = mhat / (jnp.sqrt(vrow) + EPS)
    w = w_ref[...]
    w_o[...] = w - lr * upd - lr * wd * w
    m_o[...] = m
    v_o[...] = v


# ---------------------------------------------------------------------------
# pallas_call wrappers (2-D, rows already flattened)
# ---------------------------------------------------------------------------


def _panel_call(kernel, a, b2, u, out_cols):
    """Grid over row panels of `a` (and optional second row-tensor `b2`),
    with `u` resident across the grid."""
    rows = a.shape[0]
    grid = (rows // BM,)
    in_specs = [pl.BlockSpec((BM, a.shape[1]), lambda i: (i, 0))]
    args = [a]
    if b2 is not None:
        in_specs.append(pl.BlockSpec((BM, b2.shape[1]), lambda i: (i, 0)))
        args.append(b2)
    in_specs.append(pl.BlockSpec(u.shape, lambda i: (0, 0)))
    args.append(u)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((BM, out_cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, out_cols), a.dtype),
        interpret=_INTERPRET,
    )(*args)


def _project2d(x, e, u):
    if not _rows_ok(x.shape[0]):
        return (x - e) @ u
    return _panel_call(_project_kernel, x, e, u, u.shape[1])


def _reconstruct2d(xc, e, u):
    if not _rows_ok(xc.shape[0]):
        return xc @ u.T + e
    return _panel_call(_reconstruct_kernel, xc, e, u, u.shape[0])


def _grad_project2d(g, u):
    """G·U — backward of reconstruction."""
    if not _rows_ok(g.shape[0]):
        return g @ u
    return _panel_call(_mm_kernel, g, None, u, u.shape[1])


def _grad_expand2d(gc, u):
    """Gc·Uᵀ — backward of projection."""
    if not _rows_ok(gc.shape[0]):
        return gc @ u.T
    return _panel_call(_mm_t_kernel, gc, None, u, u.shape[0])


# ---------------------------------------------------------------------------
# public, differentiable, (b, n, ·)-shaped entry points
# ---------------------------------------------------------------------------


def _flat(x):
    return x.reshape(-1, x.shape[-1])


@jax.custom_vjp
def subspace_project(x, e, u):
    """Xc = (X − E) @ U_k.  x, e: (b, n, d);  u: (d, k)  →  (b, n, k).

    E is the high-rank additive component PE + T_fixed[tok] (Eq. 8); the
    residual X − E lies in S = Col(U_k) by construction, so the projection
    is lossless (Eq. 7)."""
    b, n, _ = x.shape
    return _project2d(_flat(x), _flat(e), u).reshape(b, n, u.shape[1])


def _project_fwd(x, e, u):
    return subspace_project(x, e, u), (u,)


def _project_bwd(res, ct):
    (u,) = res
    b, n, _ = ct.shape
    gx = _grad_expand2d(_flat(ct), u).reshape(b, n, u.shape[0])
    return gx, -gx, jnp.zeros_like(u)


subspace_project.defvjp(_project_fwd, _project_bwd)


@jax.custom_vjp
def subspace_reconstruct(xc, e, u):
    """X = Xc @ U_kᵀ + E — exact inverse of `subspace_project` whenever
    Row(X − E) ⊆ S.  xc: (b, n, k); e: (b, n, d); u: (d, k) → (b, n, d)."""
    b, n, _ = xc.shape
    return _reconstruct2d(_flat(xc), _flat(e), u).reshape(b, n, u.shape[0])


def _reconstruct_fwd(xc, e, u):
    return subspace_reconstruct(xc, e, u), (u,)


def _reconstruct_bwd(res, ct):
    (u,) = res
    b, n, _ = ct.shape
    gxc = _grad_project2d(_flat(ct), u).reshape(b, n, u.shape[1])
    return gxc, ct, jnp.zeros_like(u)


subspace_reconstruct.defvjp(_reconstruct_fwd, _reconstruct_bwd)


def grad_project(g, u):
    """Gc = ∇X · U_k — the lossless backward-pass wire compression (Eq. 9)."""
    b, n, _ = g.shape
    return _grad_project2d(_flat(g), u).reshape(b, n, u.shape[1])


def grad_expand(gc, u):
    """∇X = Gc · U_kᵀ — recovery at the upstream stage (Eq. 10)."""
    b, n, _ = gc.shape
    return _grad_expand2d(_flat(gc), u).reshape(b, n, u.shape[0])


def rowwise_adamw(w, g, m, v, u, h):
    """Sec. 5 AdamW variant for W_p2 / T_S: project g onto S, then apply a
    row-constant second-moment scaling — keeps Row(W) ⊆ S without ever
    re-projecting W.

    w, g, m, v: (R, C);  u: (C, k);  h: (4,) = [lr, 1−β1ᵗ, 1−β2ᵗ, wd]
    → (w', m', v')."""
    rows, cols = w.shape
    if not _rows_ok(rows):
        return _rowwise_adamw_ref(w, g, m, v, u, h)
    grid = (rows // BM,)
    row_spec = pl.BlockSpec((BM, cols), lambda i: (i, 0))
    out = pl.pallas_call(
        _rowwise_adamw_kernel,
        grid=grid,
        in_specs=[row_spec, row_spec, row_spec, row_spec,
                  pl.BlockSpec(u.shape, lambda i: (0, 0)),
                  pl.BlockSpec((4,), lambda i: (0,))],
        out_specs=[row_spec, row_spec, row_spec],
        out_shape=[jax.ShapeDtypeStruct((rows, cols), w.dtype)] * 3,
        interpret=_INTERPRET,
    )(w, g, m, v, u, h)
    return tuple(out)


def _rowwise_adamw_ref(w, g, m, v, u, h):
    lr, bc1, bc2, wd = h[0], h[1], h[2], h[3]
    g = (g @ u) @ u.T
    m = BETA1 * m + (1.0 - BETA1) * g
    v = BETA2 * v + (1.0 - BETA2) * g * g
    vrow = jnp.mean(v / bc2, axis=1, keepdims=True)
    w = w - lr * (m / bc1) / (jnp.sqrt(vrow) + EPS) - lr * wd * w
    return w, m, v


# VMEM / MXU estimate helpers (used by EXPERIMENTS.md §Perf tables) -------


def vmem_bytes(d: int, k: int, bm: int = BM, dtype_bytes: int = 4) -> int:
    """Resident VMEM per grid step of the fused project kernel:
    X panel + E panel + U panel + out panel."""
    return dtype_bytes * (bm * d + bm * d + d * k + bm * k)


def mxu_utilization(d: int, k: int, lane: int = 128) -> float:
    """Fraction of MXU lanes doing useful work when k < the 128-lane width
    (output tile is (BM, k) against a (BM, 128) systolic pass)."""
    return min(1.0, k / lane)
