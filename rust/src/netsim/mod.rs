//! Decentralized network substrate: per-link bandwidth/latency simulation.
//!
//! The paper itself simulates bandwidth (Sec. 8.1: "Bandwidth simulations
//! sample from N(B, 0.2B) per pass"); we do exactly that. Every boundary
//! transfer samples an instantaneous bandwidth from N(B, 0.2·B) (clamped
//! at 5% of nominal), so transfer time = latency + bytes·8 / sampled_bps.
//!
//! `Topology` models the pipeline's stage-to-stage links, including the
//! multi-region layout of Fig. 5 (no two consecutive stages in the same
//! region → every pipeline link crosses a slow inter-region path, while
//! the centralized baseline keeps everything intra-region).

use crate::rng::Rng;

/// One megabit per second, in bits/s.
pub const MBPS: f64 = 1e6;
/// One gigabit per second, in bits/s.
pub const GBPS: f64 = 1e9;

/// Nominal characteristics of one network link.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkSpec {
    /// nominal bandwidth, bits/s
    pub bandwidth_bps: f64,
    /// one-way latency, seconds
    pub latency_s: f64,
    /// σ/μ of the per-transfer bandwidth sample (paper: 0.2)
    pub jitter_frac: f64,
}

impl LinkSpec {
    /// Link with the paper's default 0.2 jitter fraction.
    pub fn new(bandwidth_bps: f64, latency_s: f64) -> Self {
        LinkSpec { bandwidth_bps, latency_s, jitter_frac: 0.2 }
    }

    /// Expected (jitter-free) transfer time for `bytes` on this link.
    pub fn expected_time(&self, bytes: usize) -> f64 {
        self.latency_s + (bytes as f64 * 8.0) / self.bandwidth_bps
    }

    /// Parse a CLI bandwidth label: `"100gbps"` / `"16gbps"` / `"80mbps"`
    /// map to the named presets, any other `"<N>mbps"` (or bare number,
    /// in Mbps) to a consumer-internet link at that bandwidth.
    pub fn parse(s: &str) -> Option<LinkSpec> {
        Some(match s {
            "100gbps" => LinkSpec::centralized_100g(),
            "16gbps" => LinkSpec::centralized_16g(),
            "80mbps" => LinkSpec::internet_80m(),
            other => {
                let mbps: f64 =
                    other.trim_end_matches("mbps").parse().ok()?;
                LinkSpec::internet(mbps * MBPS)
            }
        })
    }

    /// Datacenter-grade 100 Gbps (the paper's "centralized" reference).
    pub fn centralized_100g() -> Self {
        LinkSpec::new(100.0 * GBPS, 10e-6)
    }

    /// Same-region cloud instances, 16 Gbps (Fig. 5 centralized).
    pub fn centralized_16g() -> Self {
        LinkSpec::new(16.0 * GBPS, 100e-6)
    }

    /// Consumer internet, 80 Mbps (the paper's headline decentralized
    /// link). Latency is scaled to 2 ms — our models are ~100× smaller
    /// than the paper's 2B reference, so real 30 ms internet RTTs would
    /// artificially dominate compute at this scale; 2 ms preserves the
    /// paper's latency:compute ratio (DESIGN.md §4 Substitutions).
    pub fn internet_80m() -> Self {
        LinkSpec::new(80.0 * MBPS, 2e-3)
    }

    /// Consumer internet at an arbitrary bandwidth, scaled latency.
    pub fn internet(bandwidth_bps: f64) -> Self {
        LinkSpec::new(bandwidth_bps, 2e-3)
    }
}

/// One directed link with jittered bandwidth and cumulative accounting.
#[derive(Clone, Debug)]
pub struct Link {
    /// nominal bandwidth / latency / jitter of this link
    pub spec: LinkSpec,
    rng: Rng,
    /// cumulative bytes pushed through the link
    pub bytes_sent: u64,
    /// cumulative transfer count
    pub transfers: u64,
    /// cumulative serialization (link-busy) seconds
    pub busy_s: f64,
}

impl Link {
    /// Link with its own deterministic bandwidth-sample stream.
    pub fn new(spec: LinkSpec, rng: Rng) -> Self {
        Link { spec, rng, bytes_sent: 0, transfers: 0, busy_s: 0.0 }
    }

    /// Sample one transfer: (serialization seconds, propagation latency).
    /// Serialization occupies the link; latency pipelines away. Bandwidth
    /// is drawn from the paper's N(B, 0.2B) per transfer.
    pub fn sample(&mut self, bytes: usize) -> (f64, f64) {
        let bw = self.rng.normal_clamped(
            self.spec.bandwidth_bps,
            self.spec.jitter_frac * self.spec.bandwidth_bps,
            0.05 * self.spec.bandwidth_bps,
        );
        let ser = (bytes as f64 * 8.0) / bw;
        self.bytes_sent += bytes as u64;
        self.transfers += 1;
        self.busy_s += ser;
        (ser, self.spec.latency_s)
    }

    /// [`Link::sample`] with *latency* jitter layered on top: the
    /// propagation latency is scaled by a clamped N(1, `lat_jitter_frac`)
    /// factor (floor 0.05 — latency never goes negative or vanishes).
    /// With `lat_jitter_frac == 0` this is exactly `sample` (no extra
    /// draw is consumed, keeping zero-jitter streams bit-identical).
    /// Used by the discrete-event swarm simulator, where WAN latency
    /// variation — not just bandwidth variation — drives tail behavior.
    pub fn sample_jittered(
        &mut self,
        bytes: usize,
        lat_jitter_frac: f64,
    ) -> (f64, f64) {
        let (ser, lat) = self.sample(bytes);
        if lat_jitter_frac <= 0.0 {
            return (ser, lat);
        }
        let factor = self.rng.normal_clamped(1.0, lat_jitter_frac, 0.05);
        (ser, lat * factor)
    }

    /// Simulated wall-clock seconds to push `bytes` through this link.
    pub fn transfer_time(&mut self, bytes: usize) -> f64 {
        let (ser, lat) = self.sample(bytes);
        ser + lat
    }

    /// Expected (jitter-free) transfer time — used by analytic sweeps.
    pub fn expected_time(&self, bytes: usize) -> f64 {
        self.spec.expected_time(bytes)
    }
}

/// Geographic region of a stage host (Fig. 5 layout).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[allow(missing_docs)] // variant names are self-describing
pub enum Region {
    NorthAmerica,
    Europe,
    Asia,
    SouthAmerica,
}

/// The four regions of the Fig. 5 deployment, in round-robin order.
pub const ALL_REGIONS: [Region; 4] = [
    Region::NorthAmerica,
    Region::Europe,
    Region::Asia,
    Region::SouthAmerica,
];

impl Region {
    /// Short label used in CSV output.
    pub fn name(&self) -> &'static str {
        match self {
            Region::NorthAmerica => "na",
            Region::Europe => "eu",
            Region::Asia => "as",
            Region::SouthAmerica => "sa",
        }
    }
}

/// The pipeline's P−1 stage-to-stage links (plus broadcast accounting for
/// U_k / T_fixed distribution, which reuses the slowest link).
#[derive(Clone, Debug)]
pub struct Topology {
    /// link i connects stage i to stage i+1
    pub links: Vec<Link>,
    /// per-stage region assignment (global-regions layouts only)
    pub regions: Option<Vec<Region>>,
}

impl Topology {
    /// Uniform links between consecutive stages.
    pub fn uniform(stages: usize, spec: LinkSpec, rng: &mut Rng) -> Self {
        let links = (0..stages.saturating_sub(1))
            .map(|i| Link::new(spec, rng.fork(0x11C + i as u64)))
            .collect();
        Topology { links, regions: None }
    }

    /// Fig. 5: stages round-robined across 4 regions so that no two
    /// consecutive stages share a region; inter-region links sample a
    /// nominal bandwidth uniformly in [60, 350] Mbps (paper's measured
    /// span), intra-region 16 Gbps.
    pub fn global_regions(stages: usize, rng: &mut Rng) -> Self {
        let regions: Vec<Region> =
            (0..stages).map(|s| ALL_REGIONS[s % 4]).collect();
        let links = (0..stages.saturating_sub(1))
            .map(|i| {
                let cross = regions[i] != regions[i + 1];
                let bw = if cross {
                    (60.0 + rng.uniform() * 290.0) * MBPS
                } else {
                    16.0 * GBPS
                };
                // inter-region RTTs (~80 ms real) are scaled by the same
                // ~1/100 model-scale factor as LinkSpec::internet_80m so
                // the latency:compute ratio matches the paper's 8B run
                // (DESIGN.md §4)
                let lat = if cross { 1e-3 } else { 100e-6 };
                Link::new(LinkSpec::new(bw, lat), rng.fork(0x5EC + i as u64))
            })
            .collect();
        Topology { links, regions: Some(regions) }
    }

    /// Number of pipeline stages this topology connects.
    pub fn stages(&self) -> usize {
        self.links.len() + 1
    }

    /// Transfer across the link between stage s and s+1.
    pub fn send(&mut self, from_stage: usize, bytes: usize) -> f64 {
        self.links[from_stage].transfer_time(bytes)
    }

    /// One-shot broadcast (U_k update, T_fixed at startup) to all stages:
    /// modeled as sequential sends down the pipeline (conservative).
    pub fn broadcast(&mut self, bytes: usize) -> f64 {
        let mut t = 0.0;
        for l in &mut self.links {
            t += l.transfer_time(bytes);
        }
        t
    }

    /// Cumulative bytes that crossed any pipeline link.
    pub fn total_bytes(&self) -> u64 {
        self.links.iter().map(|l| l.bytes_sent).sum()
    }

    /// Slowest nominal link bandwidth in the topology.
    pub fn min_bandwidth(&self) -> f64 {
        self.links
            .iter()
            .map(|l| l.spec.bandwidth_bps)
            .fold(f64::INFINITY, f64::min)
    }
}

// ---------------------------------------------------------------------------
// cross-replica topology (data-parallel ring)
// ---------------------------------------------------------------------------

/// Cross-replica topology for replicated pipelines: R peers in a ring
/// (replica i → (i+1) mod R), the classic bandwidth-optimal layout for a
/// ring all-reduce of weight gradients. Each directed ring link gets its
/// own jittered bandwidth stream, like pipeline links.
#[derive(Clone, Debug)]
pub struct ReplicaRing {
    /// the R directed links; empty when R == 1 (no peers, no comm)
    pub links: Vec<Link>,
}

/// Closed-form bytes each ring link carries for one all-reduce of a
/// `bytes`-sized payload: 2·(R−1) rounds of ⌈bytes/R⌉-sized chunks
/// (reduce-scatter + all-gather).
pub fn ring_allreduce_bytes_per_link(replicas: usize, bytes: usize) -> u64 {
    if replicas <= 1 || bytes == 0 {
        return 0;
    }
    let chunk = (bytes + replicas - 1) / replicas;
    2 * (replicas as u64 - 1) * chunk as u64
}

impl ReplicaRing {
    /// Build a ring of `replicas` peers with identical link specs.
    pub fn new(replicas: usize, spec: LinkSpec, rng: &mut Rng) -> Self {
        let n = if replicas <= 1 { 0 } else { replicas };
        let links = (0..n)
            .map(|i| Link::new(spec, rng.fork(0xD9 + i as u64)))
            .collect();
        ReplicaRing { links }
    }

    /// Number of replicas in the ring (1 when there are no links).
    pub fn replicas(&self) -> usize {
        self.links.len().max(1)
    }

    /// Simulate one ring all-reduce of a `bytes` payload. Every round
    /// moves one ⌈bytes/R⌉ chunk per link concurrently; the round
    /// completes when the slowest sampled link finishes, and 2·(R−1)
    /// rounds complete the reduce-scatter + all-gather. Returns simulated
    /// seconds (0 for a single replica). Delegates to
    /// [`ReplicaRing::all_reduce_among`] over the full membership, so
    /// the two paths are structurally identical.
    pub fn all_reduce(&mut self, bytes: usize) -> f64 {
        let members: Vec<usize> = (0..self.links.len()).collect();
        self.all_reduce_among(&members, bytes, 0.0)
    }

    /// One all-reduce over a *subset* of the ring — the churn-re-routed
    /// ring the swarm simulator uses after members leave: `members`
    /// (indices into `links`, each with its own persistent sample
    /// stream) form a smaller ring of R′ = `members.len()` peers, so
    /// 2·(R′−1) rounds of ⌈bytes/R′⌉ chunks, each round as slow as its
    /// slowest member link. `lat_jitter_frac` adds latency jitter per
    /// sample (see [`Link::sample_jittered`]). With all members and
    /// zero latency jitter this reproduces [`ReplicaRing::all_reduce`]
    /// exactly. Returns simulated seconds (0 for < 2 members).
    pub fn all_reduce_among(
        &mut self,
        members: &[usize],
        bytes: usize,
        lat_jitter_frac: f64,
    ) -> f64 {
        let r = members.len();
        if r <= 1 || bytes == 0 {
            return 0.0;
        }
        let chunk = (bytes + r - 1) / r;
        let mut total = 0.0;
        for _round in 0..2 * (r - 1) {
            let mut slowest = 0.0f64;
            for &m in members {
                let (ser, lat) =
                    self.links[m].sample_jittered(chunk, lat_jitter_frac);
                slowest = slowest.max(ser + lat);
            }
            total += slowest;
        }
        total
    }

    /// One seeded gossip round over the ring's members: `pairs` are
    /// disjoint `(a, b)` member pairs; each member pushes the full
    /// `bytes` payload to its partner over its own directed link, all
    /// pairs concurrently. The round completes when the slowest
    /// sampled exchange finishes — there is no global barrier, so an
    /// idle (unpaired) member costs nothing. Returns simulated seconds
    /// (0 with no pairs).
    pub fn gossip_among(
        &mut self,
        pairs: &[(usize, usize)],
        bytes: usize,
        lat_jitter_frac: f64,
    ) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        let mut slowest = 0.0f64;
        for &(a, b) in pairs {
            for m in [a, b] {
                let (ser, lat) =
                    self.links[m].sample_jittered(bytes, lat_jitter_frac);
                slowest = slowest.max(ser + lat);
            }
        }
        slowest
    }

    /// Jitter-free expected seconds for one all-reduce of `bytes`.
    pub fn expected_all_reduce(&self, bytes: usize) -> f64 {
        let r = self.replicas();
        if r <= 1 || bytes == 0 {
            return 0.0;
        }
        let chunk = (bytes + r - 1) / r;
        let per_round = self
            .links
            .iter()
            .map(|l| l.expected_time(chunk))
            .fold(0.0, f64::max);
        2.0 * (r - 1) as f64 * per_round
    }

    /// Cumulative bytes that crossed any ring link.
    pub fn total_bytes(&self) -> u64 {
        self.links.iter().map(|l| l.bytes_sent).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_scales_with_bytes() {
        let mut rng = Rng::new(1);
        let mut link = Link::new(LinkSpec::new(80.0 * MBPS, 0.0), rng.fork(0));
        let n = 200;
        let t_small: f64 = (0..n).map(|_| link.transfer_time(10_000)).sum();
        let t_big: f64 = (0..n).map(|_| link.transfer_time(1_000_000)).sum();
        assert!(t_big > 50.0 * t_small, "{t_big} vs {t_small}");
    }

    #[test]
    fn bandwidth_samples_cluster_around_nominal() {
        let mut rng = Rng::new(2);
        let mut link = Link::new(LinkSpec::new(100.0 * MBPS, 0.0), rng.fork(0));
        let bytes = 1_250_000; // 10 Mbit → nominal 0.1 s
        let n = 2000;
        let mean: f64 =
            (0..n).map(|_| link.transfer_time(bytes)).sum::<f64>() / n as f64;
        assert!((mean - 0.1).abs() < 0.01, "mean transfer {mean}");
    }

    #[test]
    fn centralized_much_faster_than_internet() {
        let mut rng = Rng::new(3);
        let mut fast = Link::new(LinkSpec::centralized_100g(), rng.fork(0));
        let mut slow = Link::new(LinkSpec::internet_80m(), rng.fork(1));
        let bytes = 4 * 1024 * 1024;
        assert!(slow.transfer_time(bytes) > 100.0 * fast.transfer_time(bytes));
    }

    #[test]
    fn global_regions_no_consecutive_same_region() {
        let mut rng = Rng::new(4);
        let topo = Topology::global_regions(8, &mut rng);
        let regions = topo.regions.as_ref().unwrap();
        for w in regions.windows(2) {
            assert_ne!(w[0], w[1]);
        }
        // every pipeline link is inter-region, hence slow
        for l in &topo.links {
            assert!(l.spec.bandwidth_bps <= 350.0 * MBPS);
            assert!(l.spec.bandwidth_bps >= 60.0 * MBPS);
        }
    }

    #[test]
    fn accounting_accumulates() {
        let mut rng = Rng::new(5);
        let mut topo =
            Topology::uniform(4, LinkSpec::internet_80m(), &mut rng);
        topo.send(0, 1000);
        topo.send(1, 2000);
        topo.broadcast(500);
        assert_eq!(topo.total_bytes(), 1000 + 2000 + 3 * 500);
        assert_eq!(topo.stages(), 4);
    }

    #[test]
    fn parse_bandwidth_labels() {
        assert_eq!(
            LinkSpec::parse("100gbps").unwrap(),
            LinkSpec::centralized_100g()
        );
        assert_eq!(
            LinkSpec::parse("80mbps").unwrap(),
            LinkSpec::internet_80m()
        );
        let l = LinkSpec::parse("250mbps").unwrap();
        assert!((l.bandwidth_bps - 250.0 * MBPS).abs() < 1.0);
        assert!(LinkSpec::parse("fastish").is_none());
    }

    #[test]
    fn ring_bytes_match_closed_form() {
        for (r, bytes) in [(2usize, 1_000_000usize), (4, 999_999), (8, 12_345)] {
            let mut rng = Rng::new(6);
            let mut ring =
                ReplicaRing::new(r, LinkSpec::internet_80m(), &mut rng);
            let t = ring.all_reduce(bytes);
            assert!(t > 0.0);
            let per_link = ring_allreduce_bytes_per_link(r, bytes);
            for l in &ring.links {
                assert_eq!(l.bytes_sent, per_link, "R={r}");
            }
            assert_eq!(ring.total_bytes(), per_link * r as u64);
        }
    }

    #[test]
    fn single_replica_ring_is_free() {
        let mut rng = Rng::new(7);
        let mut ring = ReplicaRing::new(1, LinkSpec::internet_80m(), &mut rng);
        assert_eq!(ring.replicas(), 1);
        assert_eq!(ring.all_reduce(1_000_000), 0.0);
        assert_eq!(ring_allreduce_bytes_per_link(1, 1_000_000), 0);
        assert_eq!(ring.total_bytes(), 0);
    }

    #[test]
    fn all_reduce_among_full_ring_matches_all_reduce() {
        let spec = LinkSpec {
            bandwidth_bps: 80.0 * MBPS,
            latency_s: 1e-3,
            jitter_frac: 0.0,
        };
        let mut rng_a = Rng::new(11);
        let mut rng_b = Rng::new(11);
        let mut a = ReplicaRing::new(4, spec, &mut rng_a);
        let mut b = ReplicaRing::new(4, spec, &mut rng_b);
        let t_full = a.all_reduce(1_000_000);
        let t_among = b.all_reduce_among(&[0, 1, 2, 3], 1_000_000, 0.0);
        assert_eq!(t_full, t_among);
        // a re-routed 3-member ring does fewer (4 vs 6) rounds of
        // bigger chunks: 2·2·⌈B/3⌉ < 2·3·⌈B/4⌉ per link at fixed bw
        let t_sub = b.all_reduce_among(&[0, 1, 3], 1_000_000, 0.0);
        assert!(t_sub < t_among, "{t_sub} vs {t_among}");
        assert_eq!(b.all_reduce_among(&[2], 1_000_000, 0.0), 0.0);
    }

    #[test]
    fn latency_jitter_layering() {
        let spec = LinkSpec {
            bandwidth_bps: 80.0 * MBPS,
            latency_s: 10e-3,
            jitter_frac: 0.0,
        };
        let mut rng = Rng::new(12);
        let mut quiet = Link::new(spec, rng.fork(0));
        let mut noisy = Link::new(spec, rng.fork(1));
        // zero jitter: exactly the nominal latency, no extra draw
        let (_, lat) = quiet.sample_jittered(1000, 0.0);
        assert_eq!(lat, 10e-3);
        // jittered latencies vary but stay positive and near-nominal
        let n = 500;
        let mut sum = 0.0;
        let mut varied = false;
        for _ in 0..n {
            let (_, l) = noisy.sample_jittered(1000, 0.3);
            assert!(l > 0.0);
            if (l - 10e-3).abs() > 1e-6 {
                varied = true;
            }
            sum += l;
        }
        assert!(varied, "jittered latency never moved");
        let mean = sum / n as f64;
        assert!((mean - 10e-3).abs() < 1.5e-3, "mean latency {mean}");
    }

    #[test]
    fn expected_allreduce_grows_with_replicas() {
        // per-link traffic 2(R−1)/R · B grows in R → so does the expected
        // all-reduce time at fixed per-link bandwidth
        let mut rng = Rng::new(8);
        let spec = LinkSpec { bandwidth_bps: 80.0 * MBPS, latency_s: 0.0, jitter_frac: 0.0 };
        let b = 10_000_000;
        let mut prev = 0.0;
        for r in [1usize, 2, 4, 8] {
            let ring = ReplicaRing::new(r, spec, &mut rng);
            let t = ring.expected_all_reduce(b);
            assert!(t >= prev, "R={r}: {t} < {prev}");
            prev = t;
        }
    }
}
