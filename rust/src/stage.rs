//! Per-stage model state: parameters + optimizer moments, initialized
//! according to the paper's subspace constraints.
//!
//! In subspace mode, the constrained matrices start with rows in
//! S = Col(U_k):  W_p1, W_p2 ← W·U·Uᵀ and T_S = T_fixed·U·Uᵀ
//! (Sec. 4.3/4.3.1). The closure property of the modified optimizer then
//! keeps them there for the rest of training without re-projection.

use anyhow::Result;

use crate::compress::Mode;
use crate::linalg;
use crate::manifest::ConfigManifest;
use crate::rng::Rng;
use crate::tensor::Tensor;

/// Weight init std (GPT-2 style).
pub const INIT_STD: f32 = 0.02;

/// One pipeline stage's trainable state.
#[derive(Clone)]
pub struct StageState {
    /// stage index in the pipeline
    pub stage: usize,
    /// schema kind: "first" / "mid" / "last"
    pub kind: &'static str,
    /// ordered (name, shape) parameter schema
    pub schema: Vec<(String, Vec<usize>)>,
    /// parameter tensors, schema order
    pub params: Vec<Tensor>,
    /// AdamW first moments
    pub m: Vec<Tensor>,
    /// AdamW second moments
    pub v: Vec<Tensor>,
}

/// Global (leader-owned) state shared by all stages.
#[derive(Clone)]
pub struct GlobalState {
    /// orthonormal subspace basis U_k ∈ R^{d×k}
    pub u: Tensor,
    /// fixed high-rank token embedding table T_fixed ∈ R^{v×d}
    pub t_fixed: Tensor,
}

impl GlobalState {
    /// Random orthonormal U plus Gaussian T_fixed.
    pub fn init(cfg: &ConfigManifest, rng: &mut Rng) -> GlobalState {
        GlobalState::from_hyper(&cfg.hyper, rng)
    }

    /// [`GlobalState::init`] from bare dimensions — the manifest-free
    /// path used by the native autodiff backend.
    pub fn from_hyper(h: &crate::manifest::Hyper, rng: &mut Rng) -> GlobalState {
        let u = linalg::random_orthonormal(h.d, h.k, rng);
        let t_fixed = Tensor::new(
            vec![h.vocab, h.d],
            rng.normal_f32_vec(h.vocab * h.d, INIT_STD),
        );
        GlobalState { u, t_fixed }
    }
}

/// Whether a parameter's rows are constrained to live in S (shared with
/// the replica layer's post-average re-projection).
pub(crate) fn constrained(name: &str) -> bool {
    name.ends_with("wp1") || name.ends_with("wp2") || name == "t_s"
}

impl StageState {
    /// Initialize a stage. In `Mode::Subspace`, constrained matrices are
    /// projected into S and T_S = T_fixed·U·Uᵀ; in raw/lossy modes the
    /// t_s slot holds the full (unconstrained) embedding table.
    pub fn init(
        cfg: &ConfigManifest,
        stage: usize,
        mode: Mode,
        global: &GlobalState,
        rng: &mut Rng,
    ) -> Result<StageState> {
        StageState::from_schema(
            cfg.schema(stage).to_vec(),
            cfg.stage_kind(stage),
            stage,
            mode,
            global,
            rng,
        )
    }

    /// [`StageState::init`] from an explicit schema — shared by the
    /// manifest path above and the native backend (which derives the
    /// schema from [`crate::manifest::Hyper::stage_schema`]). The RNG
    /// draw order is the schema order, so manifest and native runs with
    /// the same dimensions initialize identically.
    pub fn from_schema(
        schema: Vec<(String, Vec<usize>)>,
        kind: &'static str,
        stage: usize,
        mode: Mode,
        global: &GlobalState,
        rng: &mut Rng,
    ) -> Result<StageState> {
        let mut params = Vec::with_capacity(schema.len());
        for (name, shape) in &schema {
            let numel: usize = shape.iter().product();
            let t = if name.ends_with("_g") {
                Tensor::new(shape.clone(), vec![1.0; numel])
            } else if name.ends_with("_b") {
                Tensor::zeros(shape)
            } else if name == "t_s" && mode.uses_fixed_embedding() {
                // consume the draws every other mode makes for this
                // slot, so the init stream — and everything downstream
                // of it: later parameters, the data-batch forks — stays
                // aligned across modes. Cross-mode convergence
                // comparisons (fig 2/6, `exp convergence-native`,
                // examples/native_convergence.rs) then differ *only* in
                // the boundary codec, not in init or batch order.
                let _ = rng.normal_f32_vec(numel, INIT_STD);
                linalg::project_rows(&global.t_fixed, &global.u)
            } else {
                let mut t = Tensor::new(
                    shape.clone(),
                    rng.normal_f32_vec(numel, INIT_STD),
                );
                if mode.compressed() && (constrained(name) || name == "t_s")
                {
                    t = linalg::project_rows(&t, &global.u);
                }
                t
            };
            params.push(t);
        }
        let m = params.iter().map(|p| Tensor::zeros(&p.shape)).collect();
        let v = params.iter().map(|p| Tensor::zeros(&p.shape)).collect();
        Ok(StageState { stage, kind, schema, params, m, v })
    }

    /// Parameter tensor by schema name, if present on this stage.
    pub fn param(&self, name: &str) -> Option<&Tensor> {
        self.schema
            .iter()
            .position(|(n, _)| n == name)
            .map(|i| &self.params[i])
    }

    /// Zero tensors matching every parameter (gradient accumulators).
    pub fn zero_grads(&self) -> Vec<Tensor> {
        self.params.iter().map(|p| Tensor::zeros(&p.shape)).collect()
    }

    /// Total parameter element count of this stage.
    pub fn param_count(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// Max out-of-subspace leak across constrained matrices — the closure
    /// diagnostic asserted by integration tests.
    pub fn subspace_leak(&self, u: &Tensor) -> f64 {
        let mut worst = 0.0f64;
        for ((name, _), p) in self.schema.iter().zip(&self.params) {
            if constrained(name) {
                let norm = p.frobenius_norm() as f64 + 1e-12;
                worst = worst.max(linalg::out_of_subspace_norm(p, u) / norm);
            }
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::Manifest;

    /// These tests need the AOT manifest (`make artifacts`); they
    /// self-skip when it has not been generated.
    fn tiny() -> Option<(ConfigManifest, GlobalState, Rng)> {
        let dir =
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        let m = Manifest::load(dir).unwrap();
        let cfg = m.config("tiny").unwrap().clone();
        let mut rng = Rng::new(11);
        let g = GlobalState::init(&cfg, &mut rng);
        Some((cfg, g, rng))
    }

    #[test]
    fn subspace_init_has_rows_in_s() {
        let Some((cfg, g, mut rng)) = tiny() else { return };
        for s in 0..cfg.hyper.stages {
            let st =
                StageState::init(&cfg, s, Mode::Subspace, &g, &mut rng).unwrap();
            assert!(
                st.subspace_leak(&g.u) < 1e-5,
                "stage {s} leak {}",
                st.subspace_leak(&g.u)
            );
        }
    }

    #[test]
    fn raw_init_is_unconstrained() {
        let Some((cfg, g, mut rng)) = tiny() else { return };
        let st = StageState::init(&cfg, 0, Mode::Raw, &g, &mut rng).unwrap();
        assert!(st.subspace_leak(&g.u) > 0.1);
    }

    #[test]
    fn layernorm_init_is_identity() {
        let Some((cfg, g, mut rng)) = tiny() else { return };
        let st =
            StageState::init(&cfg, 0, Mode::Subspace, &g, &mut rng).unwrap();
        let ln_g = st.param("b0_ln1_g").unwrap();
        assert!(ln_g.data.iter().all(|&x| x == 1.0));
        let ln_b = st.param("b0_ln1_b").unwrap();
        assert!(ln_b.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn param_counts_match_manifest() {
        let Some((cfg, g, mut rng)) = tiny() else { return };
        let total: usize = (0..cfg.hyper.stages)
            .map(|s| {
                StageState::init(&cfg, s, Mode::Subspace, &g, &mut rng)
                    .unwrap()
                    .param_count()
            })
            .sum();
        assert_eq!(total, cfg.hyper.param_count);
    }
}
