//! Analytic peak-memory model — reproduces Tables 3 & 4.
//!
//! The paper's memory argument is itself an accounting argument: the
//! fixed-embedding additions are *ephemeral* (no stored activation
//! gradients; the caching allocator reuses them before attention), so the
//! only persistent overhead is the cached tables — T_fixed (v·d) plus the
//! U_k basis — ≈ 400 MB at the paper's dims, constant in sequence length
//! L and in worker count. We reproduce the accounting at the paper's
//! dimensions (Table 3/4 rows) and validate the model's *shape* against
//! measured host-buffer sizes of our own configs.
//!
//! All sizes in bytes; activations assume f16 at paper scale (as in their
//! H100 runs) and f32 for our CPU configs.

/// Model/deployment dimensions for the memory model.
#[derive(Clone, Copy, Debug)]
pub struct MemDims {
    /// transformer blocks hosted per worker
    pub layers_per_worker: usize,
    /// embedding dim
    pub d: usize,
    /// MLP hidden dim
    pub d_ff: usize,
    /// attention heads
    pub heads: usize,
    /// vocabulary size
    pub vocab: usize,
    /// subspace rank
    pub k: usize,
    /// per-worker sequence length (context parallel splits L)
    pub seq: usize,
    /// batch size
    pub batch: usize,
    /// bytes per activation element (2 = f16, 4 = f32)
    pub dtype_bytes: usize,
}

impl MemDims {
    /// The paper's Table 3 setup: 2B model (8 layers, d=4096, 16 heads)
    /// pipelined across eight H100s → one layer per worker; f16
    /// activations.
    pub fn paper_2b(seq: usize) -> MemDims {
        MemDims {
            layers_per_worker: 1,
            d: 4096,
            d_ff: 4 * 4096,
            heads: 16,
            vocab: 128_256,
            k: 40,
            seq,
            batch: 1,
            dtype_bytes: 2,
        }
    }
}

/// Parameter bytes per worker.
pub fn param_bytes(m: &MemDims) -> usize {
    let block = 4 * m.d * m.d + 2 * m.d * m.d_ff + 4 * m.d;
    m.layers_per_worker * block * m.dtype_bytes
}

/// Baseline peak activation memory (per worker): attention scores
/// O(B·H·L²) dominate at long L, plus per-layer hidden states O(B·L·d_ff)
/// retained for backward.
pub fn baseline_activation_bytes(m: &MemDims) -> usize {
    let scores = m.batch * m.heads * m.seq * m.seq; // attention matrix
    let hiddens =
        m.layers_per_worker * m.batch * m.seq * (2 * m.d + m.d_ff);
    (scores + hiddens) * m.dtype_bytes
}

/// Baseline peak = params + optimizer (2 moments, f32) + activations.
pub fn baseline_peak_bytes(m: &MemDims) -> usize {
    param_bytes(m) + 2 * param_bytes(m) * 4 / m.dtype_bytes.max(1)
        + baseline_activation_bytes(m)
}

/// The subspace method's *persistent* overhead: cached T_fixed + U_k
/// (+ the low-rank trainable T_S lives where the baseline's embedding
/// table would, so it does not count). Constant in L and in workers.
pub fn subspace_overhead_bytes(m: &MemDims) -> usize {
    (m.vocab * m.d + m.d * m.k) * m.dtype_bytes
}

/// Ephemeral embedding additions: O(B·L·d) transient, released before
/// attention — they do NOT persist into the peak (the paper's §8.8
/// explanation). Exposed so tests can check they are dominated by
/// attention/MLP terms.
pub fn ephemeral_embed_bytes(m: &MemDims) -> usize {
    m.batch * m.seq * m.d * m.dtype_bytes
}

/// Peak with the subspace method.
pub fn subspace_peak_bytes(m: &MemDims) -> usize {
    baseline_peak_bytes(m) + subspace_overhead_bytes(m)
}

/// One Table-3/4 row.
#[derive(Clone, Debug)]
pub struct MemRow {
    /// total sequence length L
    pub seq: usize,
    /// context-parallel worker count
    pub workers: usize,
    /// baseline peak memory, GB
    pub baseline_gb: f64,
    /// subspace-method peak memory, GB
    pub ours_gb: f64,
    /// absolute overhead, MB
    pub overhead_mb: f64,
    /// overhead as a fraction of the baseline peak
    pub relative: f64,
}

// ---------------------------------------------------------------------------
// native autodiff backend (rust/src/nn) — tape + optimizer accounting
// ---------------------------------------------------------------------------

use crate::manifest::Hyper;
use crate::timemodel::stage_param_count;

/// Bytes one stage's tape holds at its backward-pass peak under the
/// native backend: leaf values (parameter copies, E, U, the boundary
/// input), every op's forward value, the aux state backward needs
/// (softmax rows, LN row stats, token ids), and one gradient per
/// requires-grad node. Enumerates the graph
/// `nn::model::build_stage` constructs, term for term — the unit test
/// checks the measured [`crate::nn::Tape::bytes`] against this.
pub fn native_tape_bytes(h: &Hyper, stage: usize, compressed: bool) -> usize {
    let m = h.b * h.n;
    let (d, dff, v) = (h.d, h.d_ff, h.vocab);
    let last = stage == h.stages - 1;
    let c_in = if compressed { h.k } else { d };
    let p_s = stage_param_count(h, stage);
    // params + their grads — minus the matmul-weight grads
    // (wq/wk/wv/wp1/w1/wp2 per block, the logits matrix on the last
    // stage), which `Tape::backward_into` streams straight into the
    // persistent grad accumulators instead of materializing on the tape
    // (DESIGN.md §13); LN gains/biases and the embedding tables keep
    // tape-held grads
    let fused_w = h.blocks_per_stage * (4 * d * d + 2 * d * dff)
        + if last { d * v } else { 0 };
    let mut floats = 2 * p_s - fused_w;
    // constant leaves: E (stage 0 and compressed stages), U (compressed)
    if stage == 0 || compressed {
        floats += m * d;
    }
    if compressed {
        floats += h.d * h.k;
    }
    let mut aux = 0usize; // non-f32-tensor state, already in bytes/4
    if stage == 0 {
        // embed + residual add (values + grads), token ids aux
        floats += 2 * m * d + 2 * m * d;
        aux += m;
    } else {
        floats += 2 * m * c_in; // boundary-input leaf + grad
        if compressed {
            floats += 2 * m * d + 2 * m * d; // Xc·Uᵀ and the +E add
        }
    }
    // per block: ten (m, d) nodes — ln1, q, k, v, attn, attn·wp1, the
    // attention residual add, ln2, h1·wp2, the MLP residual add — and
    // two (m, d_ff) nodes — h·w1, relu — all values + grads, plus the
    // attention softmax rows and two LN row-stat pairs
    floats += h.blocks_per_stage * (2 * m * (10 * d + 2 * dff));
    aux += h.blocks_per_stage * (h.b * h.heads * h.n * h.n + 4 * m);
    if last {
        floats += 2 * m * d; // final LN
        aux += 2 * m;
        floats += 2 * m * v; // logits
        floats += 2; // scalar loss + seed
        aux += m * v + m; // softmax probs + targets
    } else if compressed {
        floats += 2 * m * d; // X − E
        floats += 2 * m * h.k; // (X − E)·U payload
    }
    (floats + aux) * 4
}

/// Persistent bytes of a native pipeline: parameters, both optimizer
/// moment buffers, and the shared global state (U, T_fixed, PE).
pub fn native_persistent_bytes(h: &Hyper) -> usize {
    let params: usize =
        (0..h.stages).map(|s| stage_param_count(h, s)).sum();
    (3 * params + h.d * h.k + h.vocab * h.d + h.n * h.d) * 4
}

/// Peak bytes of one native training step: persistent state, the
/// per-stage gradient accumulators, the saved boundary inputs of one
/// in-flight microbatch (GPipe remat), and the largest stage tape at
/// its backward peak. `NativePipeline::peak_bytes` measures the same
/// quantity.
pub fn native_peak_bytes(h: &Hyper, compressed: bool) -> usize {
    let m = h.b * h.n;
    let c_in = if compressed { h.k } else { h.d };
    let grad_acc: usize =
        (0..h.stages).map(|s| stage_param_count(h, s) * 4).sum();
    let saved = (h.stages - 1) * m * c_in * 4;
    let tape = (0..h.stages)
        .map(|s| native_tape_bytes(h, s, compressed))
        .max()
        .unwrap_or(0);
    native_persistent_bytes(h) + grad_acc + saved + tape
}

// ---------------------------------------------------------------------------
// distributed transport (rust/src/transport) — per-worker accounting
// ---------------------------------------------------------------------------

/// Bytes one boundary tensor occupies as a framed message on the wire:
/// the codec payload priced by [`crate::compress::wire_bytes`] plus the
/// fixed frame header. The distributed smoke asserts measured frame
/// sizes against exactly this (DESIGN.md §11).
pub fn transport_frame_bytes(h: &Hyper, mode: crate::compress::Mode) -> usize {
    crate::transport::HEADER_LEN
        + crate::compress::wire_bytes(mode, h.b, h.n, h.d, h.k, h.ratio)
}

/// Persistent bytes ONE distributed stage worker holds: its own stage's
/// parameters with both moment buffers, plus the replicated global
/// state every worker carries (U, T_fixed, PE) — the distributed
/// memory claim: per-worker residency scales with `params/P + O(v·d)`,
/// not with total model size. (Transient tape/frame buffers come on top
/// per [`native_tape_bytes`]; frames add two in-flight
/// [`transport_frame_bytes`] per link.)
pub fn transport_worker_bytes(h: &Hyper, stage: usize) -> usize {
    (3 * stage_param_count(h, stage)
        + h.d * h.k
        + h.vocab * h.d
        + h.n * h.d)
        * 4
}

/// Bytes of one stage's checkpoint payload under the elastic recovery
/// protocol (DESIGN.md §12): the fixed header, the basis U, then per
/// schema slot the parameter (dense, or — under the `Coeff` codec in a
/// compressed mode — priced *exactly* by
/// [`crate::compress::dp_wire_bytes`] since every constrained matrix is
/// `rows × d`) plus both AdamW moments dense, plus the d×d Grassmann
/// accumulator when `has_s_acc`. `compress::ckpt` tests pin the encoder
/// output length to this formula; the chaos suite asserts measured
/// `Checkpoint` frame payloads against it.
pub fn checkpoint_payload_bytes(
    h: &Hyper,
    stage: usize,
    mode: crate::compress::Mode,
    codec: crate::compress::CkptCodec,
    has_s_acc: bool,
) -> usize {
    use crate::compress::{dp_wire_bytes, CkptCodec, Mode};
    let compressed = mode.compressed();
    let mut bytes =
        crate::compress::ckpt::CKPT_HEADER_LEN + h.d * h.k * 4;
    for (name, shape) in h.stage_schema(stage) {
        let numel: usize = shape.iter().product();
        bytes += if codec == CkptCodec::Coeff
            && compressed
            && crate::stage::constrained(&name)
        {
            // checkpoints serialize f32 coefficient rows (ckpt.rs), so
            // they price under the base mode: bf16 halving applies to
            // gradient frames on the wire, never to recovery state
            dp_wire_bytes(mode.base(), numel, h.d, h.k, h.ratio)
        } else {
            numel * 4
        };
        bytes += 2 * numel * 4; // m, v — never compressed
    }
    if has_s_acc {
        bytes += h.d * h.d * 4;
    }
    bytes
}

/// Bytes of one heartbeat frame payload: the sender's step (u64) + its
/// local monotonic clock in milliseconds (u64). The liveness protocol's
/// entire steady-state overhead is this payload plus the frame header,
/// once per `--hb-every` steps per worker.
pub fn heartbeat_payload_bytes() -> usize {
    16
}

/// Wire bytes ALL replicas of one stage send per training step to
/// ring-all-reduce an `elems`-element fused weight-gradient accumulator
/// across `replicas` workers (DESIGN.md §14): 2(R−1) phases
/// (reduce-scatter then all-gather), each shipping every one of the R
/// balanced chunks exactly once across the ring, framed `GradRing`
/// payloads priced by [`crate::compress::dp_wire_bytes`] plus the frame
/// header. R ≤ 1 sends nothing. `transport::dp` asserts its measured
/// frame bytes against exactly this.
pub fn dp_ring_step_wire_bytes(
    elems: usize,
    replicas: usize,
    mode: crate::compress::Mode,
    d: usize,
    k: usize,
    ratio: f64,
) -> usize {
    if replicas < 2 {
        return 0;
    }
    let per_round: usize = (0..replicas)
        .map(|i| {
            let c = elems / replicas + usize::from(i < elems % replicas);
            crate::transport::HEADER_LEN
                + crate::compress::dp_wire_bytes(mode, c, d, k, ratio)
        })
        .sum();
    2 * (replicas - 1) * per_round
}

/// Wire bytes ONE replica of one stage sends in a gossip exchange: the
/// whole `elems`-element gradient as a single framed `GradGossip`
/// payload (each partner sends one frame and receives one — no chunking,
/// no barrier; unpaired replicas send nothing that step).
pub fn dp_gossip_exchange_wire_bytes(
    elems: usize,
    mode: crate::compress::Mode,
    d: usize,
    k: usize,
    ratio: f64,
) -> usize {
    crate::transport::HEADER_LEN
        + crate::compress::dp_wire_bytes(mode, elems, d, k, ratio)
}

// ---------------------------------------------------------------------------
// inference serving (rust/src/transport/serve) — decode-time accounting
// ---------------------------------------------------------------------------

/// Bytes ONE session's K/V cache occupies on ONE stage after decoding
/// `positions` tokens: `blocks_per_stage` blocks × (K + V) ×
/// `positions` rows × `d` f32 lanes. This is the serving-side memory
/// claim — per-session residency grows linearly in decoded length and
/// splits across stages exactly like the parameters do. Asserted
/// **exactly** against [`crate::nn::StageKv::bytes`] (the same
/// contract [`transport_frame_bytes`] has with measured frames).
pub fn kv_cache_bytes(h: &Hyper, positions: usize) -> usize {
    h.blocks_per_stage * 2 * positions * h.d * 4
}

/// Bytes one framed `Decode` boundary message occupies on the wire for
/// `sessions` active sessions. Each session contributes one new row,
/// and the protocol encodes **per session** — `sessions` independent
/// `(1, 1)`-shaped codec payloads, concatenated — rather than one
/// packed `(sessions, 1)` payload, because the lossy codecs are
/// batch-coupled (top-k selection and the int8 scale span the whole
/// tensor): per-session encoding is what makes evicting a session
/// provably unable to perturb survivors. The price is therefore
/// `sessions ×` [`crate::compress::wire_bytes`]`(mode, 1, 1, …)` plus
/// the fixed frame header. Receivers assert received `payload_len`
/// against exactly this (PowerLR excepted: its dense stand-in rows
/// ship `d` floats per session while the *priced* bytes follow the
/// factor formula, mirroring the training-side exemption).
pub fn decode_frame_bytes(
    h: &Hyper,
    mode: crate::compress::Mode,
    sessions: usize,
) -> usize {
    crate::transport::HEADER_LEN
        + sessions
            * crate::compress::wire_bytes(mode, 1, 1, h.d, h.k, h.ratio)
}

/// Bytes one framed `Token` relay message occupies: one `(session id,
/// token)` u32 LE pair per active session plus the frame header. The
/// token relay is the *entire* backward-direction traffic of the decode
/// protocol — 8 B per session per step, independent of `d`.
pub fn token_frame_bytes(sessions: usize) -> usize {
    crate::transport::HEADER_LEN + sessions * 8
}

/// Compute one Table-3/4 row at the paper's 2B dimensions.
pub fn table_row(seq_total: usize, workers: usize) -> MemRow {
    // context parallel: each worker holds seq_total / workers tokens
    let m = MemDims::paper_2b(seq_total / workers);
    let base = baseline_peak_bytes(&m) as f64;
    let ours = subspace_peak_bytes(&m) as f64;
    MemRow {
        seq: seq_total,
        workers,
        baseline_gb: base / 1e9,
        ours_gb: ours / 1e9,
        overhead_mb: (ours - base) / 1e6,
        relative: (ours - base) / base,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overhead_constant_in_sequence_length() {
        // Table 3: ~constant absolute overhead, shrinking relative share
        let rows: Vec<_> =
            [8192, 16384, 24576].iter().map(|&l| table_row(l, 1)).collect();
        let mb0 = rows[0].overhead_mb;
        for r in &rows {
            assert!(
                (r.overhead_mb - mb0).abs() < 1.0,
                "overhead should be constant: {} vs {mb0}",
                r.overhead_mb
            );
        }
        assert!(rows[0].relative > rows[1].relative);
        assert!(rows[1].relative > rows[2].relative);
    }

    #[test]
    fn overhead_magnitude_matches_paper() {
        // paper reports ≈ 400 MB at v=128k, d=4096, f16 ⇒ v·d·2 ≈ 1.05 GB;
        // their 400 MB suggests the allocator shares part of the table —
        // we assert the right order of magnitude (hundreds of MB, < 1.5 GB)
        let r = table_row(8192, 1);
        assert!(
            r.overhead_mb > 100.0 && r.overhead_mb < 1500.0,
            "overhead {} MB",
            r.overhead_mb
        );
    }

    #[test]
    fn overhead_constant_per_worker_table4() {
        // Table 4: overhead per worker independent of worker count
        let r1 = table_row(49_152, 2);
        let r2 = table_row(65_536, 3);
        assert!((r1.overhead_mb - r2.overhead_mb).abs() < 1.0);
    }

    #[test]
    fn ephemeral_embeds_dominated_by_attention() {
        // §8.8: O(B·L·d) ≪ O(B·H·L²) at long L
        let m = MemDims::paper_2b(16384);
        assert!(
            ephemeral_embed_bytes(&m) * 10
                < baseline_activation_bytes(&m)
        );
    }

    #[test]
    fn baseline_grows_superlinearly_with_l() {
        let b8 = baseline_peak_bytes(&MemDims::paper_2b(8192)) as f64;
        let b24 = baseline_peak_bytes(&MemDims::paper_2b(24576)) as f64;
        assert!(b24 / b8 > 3.0, "L² attention term should dominate growth");
    }

    #[test]
    fn native_peak_matches_measured_pipeline() {
        use crate::compress::Mode;
        use crate::coordinator::PipelineConfig;
        use crate::data::{Corpus, CorpusKind};
        use crate::netsim::{LinkSpec, Topology};
        use crate::nn::{NativePipeline, Optim};
        use crate::rng::Rng;

        let h = Hyper::tiny_native();
        for (mode, compressed) in
            [(Mode::Subspace, true), (Mode::Raw, false), (Mode::Quant, false)]
        {
            let mut rng = Rng::new(3);
            let topo = Topology::uniform(
                h.stages,
                LinkSpec::internet_80m(),
                &mut rng,
            );
            let pcfg = PipelineConfig {
                mode,
                microbatches: 2,
                grassmann_interval: 0,
                total_steps: 4,
                seed: 3,
                ..Default::default()
            };
            let mut pipe =
                NativePipeline::new(h.clone(), topo, pcfg, Optim::AdamW)
                    .unwrap();
            let corpus =
                Corpus::synthetic(CorpusKind::Wiki, h.vocab, 20_000, 4);
            pipe.train_step(|r| corpus.train_batch(h.b, h.n, r)).unwrap();
            let measured = pipe.peak_bytes() as f64;
            let analytic = native_peak_bytes(&h, compressed) as f64;
            let rel = (measured - analytic).abs() / analytic;
            // the model enumerates the tape term-for-term (verified
            // exact against a python graph-trace port); 0.1% headroom
            // only guards future graph tweaks drifting silently
            assert!(
                rel < 1e-3,
                "{mode:?}: measured {measured} vs analytic {analytic} \
                 ({rel:.4} rel)"
            );
        }
    }

    #[test]
    fn native_tape_peaks_at_the_loss_stage() {
        // the LM head + softmax probs dominate: the last stage's tape
        // must be the per-stage max, and compressed boundaries must not
        // grow it by more than the tiny projection-pair footprint
        let h = Hyper::tiny_native();
        let last = h.stages - 1;
        for compressed in [true, false] {
            let tapes: Vec<usize> = (0..h.stages)
                .map(|s| native_tape_bytes(&h, s, compressed))
                .collect();
            let max = *tapes.iter().max().unwrap();
            assert_eq!(max, tapes[last], "{compressed}: {tapes:?}");
        }
        let sub = native_peak_bytes(&h, true) as f64;
        let raw = native_peak_bytes(&h, false) as f64;
        assert!(
            (sub - raw).abs() / raw < 0.1,
            "subspace peak {sub} vs raw {raw}: boundary overhead must be \
             marginal"
        );
    }

    #[test]
    fn decode_frame_and_kv_pricing() {
        use crate::compress::{wire_bytes, Mode};
        let h = Hyper::tiny_native();
        let hdr = crate::transport::HEADER_LEN;
        // decode frames price `sessions` independent single-row codec
        // payloads — per-session encoding is the eviction-invariance
        // guarantee, so the price is linear in the session count
        for mode in [Mode::Subspace, Mode::Raw, Mode::Quant, Mode::TopK] {
            for s in [1usize, 3, 8] {
                assert_eq!(
                    decode_frame_bytes(&h, mode, s),
                    hdr + s * wire_bytes(mode, 1, 1, h.d, h.k, h.ratio)
                );
            }
        }
        // subspace decode rows ship k floats per session, raw ships d
        assert_eq!(
            decode_frame_bytes(&h, Mode::Subspace, 4) - hdr,
            4 * h.k * 4
        );
        assert_eq!(decode_frame_bytes(&h, Mode::Raw, 4) - hdr, 4 * h.d * 4);
        // token relay: 8 B per session, d-independent
        assert_eq!(token_frame_bytes(0), hdr);
        assert_eq!(token_frame_bytes(5) - hdr, 40);
        // KV: linear in positions, zero at zero
        assert_eq!(kv_cache_bytes(&h, 0), 0);
        assert_eq!(
            kv_cache_bytes(&h, 7),
            h.blocks_per_stage * 2 * 7 * h.d * 4
        );
    }

    #[test]
    fn dp_grad_frame_pricing() {
        use crate::compress::{dp_wire_bytes, Mode};
        let hdr = crate::transport::HEADER_LEN;
        // balanced split, every chunk once per phase, 2(R−1) phases
        let (elems, r, d, k, ratio) = (1200usize, 3usize, 32, 4, 8.0);
        let want = 2 * (r - 1)
            * (hdr * r + 3 * dp_wire_bytes(Mode::Raw, 400, d, k, ratio));
        assert_eq!(
            dp_ring_step_wire_bytes(elems, r, Mode::Raw, d, k, ratio),
            want
        );
        // a lone replica reduces nothing
        assert_eq!(
            dp_ring_step_wire_bytes(elems, 1, Mode::Raw, d, k, ratio),
            0
        );
        // uneven split still prices every element exactly once per round
        let uneven =
            dp_ring_step_wire_bytes(1201, 2, Mode::Raw, d, k, ratio);
        assert_eq!(uneven, 2 * (hdr * 2 + 601 * 4 + 600 * 4));
        // bf16 gossip frames halve the raw payload
        let g32 = dp_gossip_exchange_wire_bytes(elems, Mode::Raw, d, k, ratio);
        let g16 =
            dp_gossip_exchange_wire_bytes(elems, Mode::RawBf16, d, k, ratio);
        assert_eq!(g32 - hdr, 2 * (g16 - hdr));
    }

    #[test]
    fn transport_accounting_consistency() {
        use crate::compress::{wire_bytes, Mode};

        let h = Hyper::tiny_native();
        // frame = header + exactly the codec payload the wire carries
        for mode in [Mode::Subspace, Mode::Raw, Mode::TopK, Mode::Quant] {
            assert_eq!(
                transport_frame_bytes(&h, mode),
                crate::transport::HEADER_LEN
                    + wire_bytes(mode, h.b, h.n, h.d, h.k, h.ratio),
            );
        }
        // subspace frames stay ~10x under raw even with header overhead
        let sub = transport_frame_bytes(&h, Mode::Subspace) as f64;
        let raw = transport_frame_bytes(&h, Mode::Raw) as f64;
        assert!(raw / sub >= 10.0, "framed ratio {:.2}", raw / sub);
        // per-worker residency: every worker carries the shared global
        // state; the stage split covers the rest, so the sum over
        // workers exceeds the single-process persistent bytes by
        // exactly (P − 1) global-state copies
        let per_worker: usize =
            (0..h.stages).map(|s| transport_worker_bytes(&h, s)).sum();
        let global = (h.d * h.k + h.vocab * h.d + h.n * h.d) * 4;
        assert_eq!(
            per_worker,
            native_persistent_bytes(&h) + (h.stages - 1) * global
        );
    }
}
