//! Artifact manifest — the contract between the python compile path and
//! the rust runtime (artifacts/manifest.json, written by compile/aot.py).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::json::Json;

/// Manifest schema version this runtime understands.
pub const SUPPORTED_VERSION: usize = 3;

/// The parsed artifact manifest: every AOT-compiled config.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// artifacts directory (entry files are relative to it)
    pub root: PathBuf,
    /// config name → its manifest
    pub configs: BTreeMap<String, ConfigManifest>,
}

/// Static hyperparameters of one shape-specialized config.
#[derive(Clone, Debug)]
#[allow(missing_docs)] // field names mirror the paper's notation
pub struct Hyper {
    pub d: usize,
    pub d_ff: usize,
    pub heads: usize,
    pub layers: usize,
    pub stages: usize,
    pub n: usize,
    pub vocab: usize,
    pub k: usize,
    pub b: usize,
    pub blocks_per_stage: usize,
    pub ratio: f64,
    pub param_count: usize,
}

/// One config's manifest: hyperparameters, schemas and entry points.
#[derive(Clone, Debug)]
pub struct ConfigManifest {
    /// config name (e.g. "tiny", "base")
    pub name: String,
    /// static model/pipeline dimensions
    pub hyper: Hyper,
    /// boundary modes this config was AOT-compiled for
    pub modes: Vec<String>,
    /// stage-kind ("first"/"mid"/"last") → ordered (name, shape)
    pub schemas: BTreeMap<String, Vec<(String, Vec<usize>)>>,
    /// parameter names updated with the row-wise AdamW variant
    pub rowwise: Vec<String>,
    /// parameter names re-projected onto S each step
    pub reproject: Vec<String>,
    /// entry key ("mode/name") → compiled program descriptor
    pub entries: BTreeMap<String, Entry>,
}

/// Element type of a runtime argument/output.
impl Hyper {
    /// The `base` config's dimensions (python/compile/configs.py),
    /// constructible without a manifest — the shared shape for analytic
    /// cost-model sweeps (`exp::dp_grid`, `examples/swarm_replicas.rs`,
    /// benches, tests). `param_count` is 0: analytic paths derive
    /// parameter counts from the dimensions instead.
    pub fn base_sim() -> Hyper {
        Hyper {
            d: 256,
            d_ff: 1024,
            heads: 8,
            layers: 8,
            stages: 4,
            n: 128,
            vocab: 1024,
            k: 8,
            b: 4,
            blocks_per_stage: 2,
            ratio: 32.0,
            param_count: 0,
        }
    }

    /// The `small` config's dimensions — the fast-preset analogue of
    /// [`Hyper::base_sim`].
    pub fn small_sim() -> Hyper {
        Hyper {
            d: 128,
            d_ff: 512,
            heads: 4,
            layers: 4,
            stages: 4,
            n: 64,
            vocab: 512,
            k: 8,
            b: 4,
            blocks_per_stage: 1,
            ratio: 16.0,
            param_count: 0,
        }
    }

    /// Dimensions sized for the native autodiff backend's CI smoke runs
    /// (`exp convergence-native`, `examples/native_convergence.rs`):
    /// four single-block stages (three compressed boundaries, so lossy
    /// error accumulates with depth per Thm. B.1) at a d/k ratio above
    /// the 10x acceptance bar.
    pub fn tiny_native() -> Hyper {
        Hyper {
            d: 64,
            d_ff: 256,
            heads: 4,
            layers: 4,
            stages: 4,
            n: 32,
            vocab: 256,
            k: 6,
            b: 4,
            blocks_per_stage: 1,
            ratio: 64.0 / 6.0,
            param_count: 0,
        }
    }

    /// Schema kind ("first" / "mid" / "last") for a stage index — the
    /// manifest-free mirror of [`ConfigManifest::stage_kind`].
    pub fn stage_kind(&self, stage: usize) -> &'static str {
        if stage == 0 {
            "first"
        } else if stage == self.stages - 1 {
            "last"
        } else {
            "mid"
        }
    }

    /// Ordered (name, shape) parameter schema of one pipeline stage,
    /// derived from the dimensions alone — the rust-side mirror of
    /// `python/compile/configs.py::stage_param_schema` (same names, same
    /// shapes, same order), so the native backend trains the *same*
    /// model the AOT artifacts compile without needing a manifest.
    pub fn stage_schema(&self, stage: usize) -> Vec<(String, Vec<usize>)> {
        let (d, d_ff) = (self.d, self.d_ff);
        let mut schema: Vec<(String, Vec<usize>)> = Vec::new();
        if stage == 0 {
            schema.push(("t_s".into(), vec![self.vocab, d]));
        }
        for blk in 0..self.blocks_per_stage {
            let block: [(&str, Vec<usize>); 10] = [
                ("ln1_g", vec![d]),
                ("ln1_b", vec![d]),
                ("wq", vec![d, d]),
                ("wk", vec![d, d]),
                ("wv", vec![d, d]),
                ("wp1", vec![d, d]),
                ("ln2_g", vec![d]),
                ("ln2_b", vec![d]),
                ("w1", vec![d, d_ff]),
                ("wp2", vec![d_ff, d]),
            ];
            for (name, shape) in block {
                schema.push((format!("b{blk}_{name}"), shape));
            }
        }
        if stage == self.stages - 1 {
            schema.push(("lnf_g".into(), vec![d]));
            schema.push(("lnf_b".into(), vec![d]));
            schema.push(("w_head".into(), vec![d, self.vocab]));
        }
        schema
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[allow(missing_docs)]
pub enum Dtype {
    F32,
    I32,
}

/// One program argument: name, static shape, dtype.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    /// argument name from the python lowering
    pub name: String,
    /// static shape
    pub shape: Vec<usize>,
    /// element type
    pub dtype: Dtype,
}

/// One program output: static shape + dtype.
#[derive(Clone, Debug)]
pub struct OutSpec {
    /// static shape
    pub shape: Vec<usize>,
    /// element type
    pub dtype: Dtype,
}

/// One AOT-compiled entry point.
#[derive(Clone, Debug)]
pub struct Entry {
    /// path relative to the artifacts root
    pub file: String,
    /// ordered argument specs
    pub args: Vec<ArgSpec>,
    /// ordered output specs
    pub outs: Vec<OutSpec>,
}

fn dtype(s: &str) -> Result<Dtype> {
    match s {
        "f32" => Ok(Dtype::F32),
        "i32" => Ok(Dtype::I32),
        other => bail!("unknown dtype {other:?}"),
    }
}

fn shape(j: &Json) -> Result<Vec<usize>> {
    j.arr()?.iter().map(|x| x.usize()).collect()
}

impl Manifest {
    /// Parse `artifacts_dir/manifest.json` (written by `make artifacts`).
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Manifest> {
        let root = artifacts_dir.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let j = Json::parse(&text)?;
        let version = j.get("version")?.usize()?;
        if version != SUPPORTED_VERSION {
            bail!("manifest version {version} != supported {SUPPORTED_VERSION}");
        }
        let mut configs = BTreeMap::new();
        for (name, cj) in j.get("configs")?.obj()? {
            configs.insert(name.clone(), ConfigManifest::parse(name, cj)?);
        }
        Ok(Manifest { root, configs })
    }

    /// Look up a config by name with a helpful error.
    pub fn config(&self, name: &str) -> Result<&ConfigManifest> {
        self.configs.get(name).with_context(|| {
            format!(
                "config {name:?} not in manifest; have {:?}",
                self.configs.keys().collect::<Vec<_>>()
            )
        })
    }
}

impl ConfigManifest {
    fn parse(name: &str, j: &Json) -> Result<ConfigManifest> {
        let h = j.get("hyper")?;
        let hyper = Hyper {
            d: h.get("d")?.usize()?,
            d_ff: h.get("d_ff")?.usize()?,
            heads: h.get("heads")?.usize()?,
            layers: h.get("layers")?.usize()?,
            stages: h.get("stages")?.usize()?,
            n: h.get("n")?.usize()?,
            vocab: h.get("vocab")?.usize()?,
            k: h.get("k")?.usize()?,
            b: h.get("b")?.usize()?,
            blocks_per_stage: h.get("blocks_per_stage")?.usize()?,
            ratio: h.get("ratio")?.num()?,
            param_count: h.get("param_count")?.usize()?,
        };
        let modes = j
            .get("modes")?
            .arr()?
            .iter()
            .map(|m| Ok(m.str()?.to_string()))
            .collect::<Result<_>>()?;
        let mut schemas = BTreeMap::new();
        for (kind, sj) in j.get("schemas")?.obj()? {
            let fields = sj
                .arr()?
                .iter()
                .map(|f| {
                    let pair = f.arr()?;
                    Ok((pair[0].str()?.to_string(), shape(&pair[1])?))
                })
                .collect::<Result<Vec<_>>>()?;
            schemas.insert(kind.clone(), fields);
        }
        let cons = j.get("constrained")?;
        let names = |key: &str| -> Result<Vec<String>> {
            cons.get(key)?
                .arr()?
                .iter()
                .map(|x| Ok(x.str()?.to_string()))
                .collect()
        };
        let mut entries = BTreeMap::new();
        for (ename, ej) in j.get("entries")?.obj()? {
            let args = ej
                .get("args")?
                .arr()?
                .iter()
                .map(|a| {
                    Ok(ArgSpec {
                        name: a.get("name")?.str()?.to_string(),
                        shape: shape(a.get("shape")?)?,
                        dtype: dtype(a.get("dtype")?.str()?)?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let outs = ej
                .get("outs")?
                .arr()?
                .iter()
                .map(|o| {
                    Ok(OutSpec {
                        shape: shape(o.get("shape")?)?,
                        dtype: dtype(o.get("dtype")?.str()?)?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            entries.insert(
                ename.clone(),
                Entry { file: ej.get("file")?.str()?.to_string(), args, outs },
            );
        }
        Ok(ConfigManifest {
            name: name.to_string(),
            hyper,
            modes,
            schemas,
            rowwise: names("rowwise")?,
            reproject: names("reproject")?,
            entries,
        })
    }

    /// Look up an entry point ("mode/name") with a helpful error.
    pub fn entry(&self, key: &str) -> Result<&Entry> {
        self.entries
            .get(key)
            .with_context(|| format!("entry {key:?} missing for config {}", self.name))
    }

    /// Schema kind for a pipeline stage index.
    pub fn stage_kind(&self, stage: usize) -> &'static str {
        if stage == 0 {
            "first"
        } else if stage == self.hyper.stages - 1 {
            "last"
        } else {
            "mid"
        }
    }

    /// Ordered (name, shape) parameter schema for a stage.
    pub fn schema(&self, stage: usize) -> &[(String, Vec<usize>)] {
        &self.schemas[self.stage_kind(stage)]
    }

    /// Total parameter element count of one stage.
    pub fn stage_param_count(&self, stage: usize) -> usize {
        self.schema(stage)
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Artifacts are generated by `make artifacts` (python AOT lowering),
    /// not checked in; these tests self-skip when they are absent so the
    /// suite stays green in artifact-less environments (e.g. CI).
    fn have_artifacts() -> bool {
        let ok = artifacts_dir().join("manifest.json").exists();
        if !ok {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
        }
        ok
    }

    #[test]
    fn loads_manifest_and_schemas() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(artifacts_dir()).unwrap();
        let c = m.config("tiny").unwrap();
        assert_eq!(c.hyper.d, 64);
        assert_eq!(c.hyper.stages, 3);
        assert_eq!(c.stage_kind(0), "first");
        assert_eq!(c.stage_kind(1), "mid");
        assert_eq!(c.stage_kind(2), "last");
        // first stage owns t_s; last owns the head
        assert_eq!(c.schema(0)[0].0, "t_s");
        assert!(c.schema(2).iter().any(|(n, _)| n == "w_head"));
        assert!(!c.rowwise.is_empty());
    }

    #[test]
    fn entry_args_end_with_boundary_tensors() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(artifacts_dir()).unwrap();
        let c = m.config("tiny").unwrap();
        let e = c.entry("subspace/mid_bwd").unwrap();
        let names: Vec<_> = e.args.iter().map(|a| a.name.as_str()).collect();
        assert_eq!(names[names.len() - 2], "xc_in");
        assert_eq!(names[names.len() - 1], "gc_out");
        let h = &c.hyper;
        assert_eq!(
            e.args.last().unwrap().shape,
            vec![h.b, h.n, h.k],
            "boundary payload must be compressed"
        );
    }

    #[test]
    fn unknown_config_errors() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(artifacts_dir()).unwrap();
        assert!(m.config("nope").is_err());
    }

    #[test]
    fn stage_schema_counts_match_analytic_param_count() {
        // the manifest-free schema must agree with the analytic per-stage
        // parameter counts the DP all-reduce pricing already uses
        for h in [Hyper::base_sim(), Hyper::small_sim(), Hyper::tiny_native()]
        {
            for s in 0..h.stages {
                let from_schema: usize = h
                    .stage_schema(s)
                    .iter()
                    .map(|(_, shape)| shape.iter().product::<usize>())
                    .sum();
                assert_eq!(
                    from_schema,
                    crate::timemodel::stage_param_count(&h, s),
                    "{} stage {s}",
                    h.d
                );
            }
            assert_eq!(h.stage_kind(0), "first");
            assert_eq!(h.stage_kind(h.stages - 1), "last");
        }
    }

    #[test]
    fn missing_manifest_reports_helpfully() {
        let err = Manifest::load("/nonexistent/protomodels-artifacts")
            .unwrap_err()
            .to_string();
        assert!(err.contains("make artifacts"), "unhelpful error: {err}");
    }
}
