//! Artifact manifest — the contract between the python compile path and
//! the rust runtime (artifacts/manifest.json, written by compile/aot.py).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::json::Json;

pub const SUPPORTED_VERSION: usize = 3;

#[derive(Clone, Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub configs: BTreeMap<String, ConfigManifest>,
}

/// Static hyperparameters of one shape-specialized config.
#[derive(Clone, Debug)]
pub struct Hyper {
    pub d: usize,
    pub d_ff: usize,
    pub heads: usize,
    pub layers: usize,
    pub stages: usize,
    pub n: usize,
    pub vocab: usize,
    pub k: usize,
    pub b: usize,
    pub blocks_per_stage: usize,
    pub ratio: f64,
    pub param_count: usize,
}

#[derive(Clone, Debug)]
pub struct ConfigManifest {
    pub name: String,
    pub hyper: Hyper,
    pub modes: Vec<String>,
    /// stage-kind ("first"/"mid"/"last") → ordered (name, shape)
    pub schemas: BTreeMap<String, Vec<(String, Vec<usize>)>>,
    /// parameter names updated with the row-wise AdamW variant
    pub rowwise: Vec<String>,
    /// parameter names re-projected onto S each step
    pub reproject: Vec<String>,
    pub entries: BTreeMap<String, Entry>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

#[derive(Clone, Debug)]
pub struct OutSpec {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

#[derive(Clone, Debug)]
pub struct Entry {
    /// path relative to the artifacts root
    pub file: String,
    pub args: Vec<ArgSpec>,
    pub outs: Vec<OutSpec>,
}

fn dtype(s: &str) -> Result<Dtype> {
    match s {
        "f32" => Ok(Dtype::F32),
        "i32" => Ok(Dtype::I32),
        other => bail!("unknown dtype {other:?}"),
    }
}

fn shape(j: &Json) -> Result<Vec<usize>> {
    j.arr()?.iter().map(|x| x.usize()).collect()
}

impl Manifest {
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Manifest> {
        let root = artifacts_dir.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let j = Json::parse(&text)?;
        let version = j.get("version")?.usize()?;
        if version != SUPPORTED_VERSION {
            bail!("manifest version {version} != supported {SUPPORTED_VERSION}");
        }
        let mut configs = BTreeMap::new();
        for (name, cj) in j.get("configs")?.obj()? {
            configs.insert(name.clone(), ConfigManifest::parse(name, cj)?);
        }
        Ok(Manifest { root, configs })
    }

    pub fn config(&self, name: &str) -> Result<&ConfigManifest> {
        self.configs.get(name).with_context(|| {
            format!(
                "config {name:?} not in manifest; have {:?}",
                self.configs.keys().collect::<Vec<_>>()
            )
        })
    }
}

impl ConfigManifest {
    fn parse(name: &str, j: &Json) -> Result<ConfigManifest> {
        let h = j.get("hyper")?;
        let hyper = Hyper {
            d: h.get("d")?.usize()?,
            d_ff: h.get("d_ff")?.usize()?,
            heads: h.get("heads")?.usize()?,
            layers: h.get("layers")?.usize()?,
            stages: h.get("stages")?.usize()?,
            n: h.get("n")?.usize()?,
            vocab: h.get("vocab")?.usize()?,
            k: h.get("k")?.usize()?,
            b: h.get("b")?.usize()?,
            blocks_per_stage: h.get("blocks_per_stage")?.usize()?,
            ratio: h.get("ratio")?.num()?,
            param_count: h.get("param_count")?.usize()?,
        };
        let modes = j
            .get("modes")?
            .arr()?
            .iter()
            .map(|m| Ok(m.str()?.to_string()))
            .collect::<Result<_>>()?;
        let mut schemas = BTreeMap::new();
        for (kind, sj) in j.get("schemas")?.obj()? {
            let fields = sj
                .arr()?
                .iter()
                .map(|f| {
                    let pair = f.arr()?;
                    Ok((pair[0].str()?.to_string(), shape(&pair[1])?))
                })
                .collect::<Result<Vec<_>>>()?;
            schemas.insert(kind.clone(), fields);
        }
        let cons = j.get("constrained")?;
        let names = |key: &str| -> Result<Vec<String>> {
            cons.get(key)?
                .arr()?
                .iter()
                .map(|x| Ok(x.str()?.to_string()))
                .collect()
        };
        let mut entries = BTreeMap::new();
        for (ename, ej) in j.get("entries")?.obj()? {
            let args = ej
                .get("args")?
                .arr()?
                .iter()
                .map(|a| {
                    Ok(ArgSpec {
                        name: a.get("name")?.str()?.to_string(),
                        shape: shape(a.get("shape")?)?,
                        dtype: dtype(a.get("dtype")?.str()?)?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let outs = ej
                .get("outs")?
                .arr()?
                .iter()
                .map(|o| {
                    Ok(OutSpec {
                        shape: shape(o.get("shape")?)?,
                        dtype: dtype(o.get("dtype")?.str()?)?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            entries.insert(
                ename.clone(),
                Entry { file: ej.get("file")?.str()?.to_string(), args, outs },
            );
        }
        Ok(ConfigManifest {
            name: name.to_string(),
            hyper,
            modes,
            schemas,
            rowwise: names("rowwise")?,
            reproject: names("reproject")?,
            entries,
        })
    }

    pub fn entry(&self, key: &str) -> Result<&Entry> {
        self.entries
            .get(key)
            .with_context(|| format!("entry {key:?} missing for config {}", self.name))
    }

    /// Schema kind for a pipeline stage index.
    pub fn stage_kind(&self, stage: usize) -> &'static str {
        if stage == 0 {
            "first"
        } else if stage == self.hyper.stages - 1 {
            "last"
        } else {
            "mid"
        }
    }

    pub fn schema(&self, stage: usize) -> &[(String, Vec<usize>)] {
        &self.schemas[self.stage_kind(stage)]
    }

    /// Total parameter element count of one stage.
    pub fn stage_param_count(&self, stage: usize) -> usize {
        self.schema(stage)
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_manifest_and_schemas() {
        let m = Manifest::load(artifacts_dir()).unwrap();
        let c = m.config("tiny").unwrap();
        assert_eq!(c.hyper.d, 64);
        assert_eq!(c.hyper.stages, 3);
        assert_eq!(c.stage_kind(0), "first");
        assert_eq!(c.stage_kind(1), "mid");
        assert_eq!(c.stage_kind(2), "last");
        // first stage owns t_s; last owns the head
        assert_eq!(c.schema(0)[0].0, "t_s");
        assert!(c.schema(2).iter().any(|(n, _)| n == "w_head"));
        assert!(!c.rowwise.is_empty());
    }

    #[test]
    fn entry_args_end_with_boundary_tensors() {
        let m = Manifest::load(artifacts_dir()).unwrap();
        let c = m.config("tiny").unwrap();
        let e = c.entry("subspace/mid_bwd").unwrap();
        let names: Vec<_> = e.args.iter().map(|a| a.name.as_str()).collect();
        assert_eq!(names[names.len() - 2], "xc_in");
        assert_eq!(names[names.len() - 1], "gc_out");
        let h = &c.hyper;
        assert_eq!(
            e.args.last().unwrap().shape,
            vec![h.b, h.n, h.k],
            "boundary payload must be compressed"
        );
    }

    #[test]
    fn unknown_config_errors() {
        let m = Manifest::load(artifacts_dir()).unwrap();
        assert!(m.config("nope").is_err());
    }
}
