//! protomodels — leader entrypoint / CLI.
//!
//! Subcommands:
//!   train     train one system (config × mode × bandwidth) and log a curve
//!   exp       regenerate a paper figure/table (see DESIGN.md §5)
//!   inspect   dump the artifact manifest summary
//!   timing    short run + per-entry PJRT timing report (profiling)

use anyhow::{bail, Result};

use protomodels::cli::Flags;
use protomodels::compress::{CkptCodec, Mode};
use protomodels::coordinator::replica::{ReplicaConfig, ReplicaSet};
use protomodels::coordinator::{Backend, BackendKind, Pipeline, PipelineConfig};
use protomodels::data::{Corpus, CorpusKind};
use protomodels::exp::{self, ExpOpts};
use protomodels::manifest::Manifest;
use protomodels::metrics::{perplexity, RunLog};
use protomodels::netsim::{LinkSpec, ReplicaRing, Topology};
use protomodels::obs::counters::RunMetrics;
use protomodels::obs::trace::{Clock, Trace, TraceSession};
use protomodels::par;
use protomodels::rng::Rng;
use protomodels::sim::{simulate_swarm, ChurnSpec, ChurnTimeline, Schedule, SwarmSpec};
use protomodels::timemodel::{SlowdownProfile, TimeModel};
use protomodels::transport::{
    self, ElasticOpts, ElasticSpec, FaultFamily, FaultPlan, FaultSchedule,
    LinkSide, Reduce, TrainSpec, TransportKind, WorkerSpec,
};

fn usage() -> ! {
    eprintln!(
        "protomodels — Protocol Models reproduction

USAGE:
  protomodels train   [--backend pjrt|native] [--config base]
                      [--mode subspace|raw|topk|quant|powerlr|nofixed
                              |raw-bf16|subspace-bf16]
                      [--bandwidth 80mbps|16gbps|100gbps|<N>mbps] [--regions]
                      [--steps 200] [--microbatches 8] [--corpus wiki|books|web|c4]
                      [--lr 6e-3] [--grassmann 0] [--seed 17]
                      [--optim adamw|sgd|sgd:<momentum>]
                      [--time-model analytic|analytic:<TFLOPs>|measured]
                      [--schedule gpipe|1f1b] [--sim]
                      [--replicas R] [--dp-mode subspace|raw|topk|quant]
                      [--dp-bandwidth 80mbps] [--hetero 1,1,2]
                      [--transport channel|tcp] [--reduce ring|gossip]
                      [--stages N] [--kill-replica R@S] (native backend only)
                      [--chaos kill:W@S,join:W@S] [--fault drop|delay|sever]
                      [--fault-seed N] [--ckpt-every N] [--ckpt-codec raw|coeff]
                      [--stale-ms 5000] [--hb-every 1] [--spares 1]
                      [--max-epochs 8]           (elastic native runtime)
                      [--artifacts artifacts] [--out results] [--label NAME]
                      [--trace trace.json]       (span trace + METRICS.json)
  protomodels serve   --stage I [--config tiny] [--mode subspace] [--steps 200]
                      [--microbatches 4] [--seed 17] [--optim adamw]
                      [--schedule gpipe|1f1b] [--grassmann 0]
                      [--host 127.0.0.1] [--port-base 7070]
                      [--elastic] [--spare] [+ elastic train flags]
                      [--trace trace.json]
  protomodels serve-infer
                      [--config tiny] [--mode subspace|raw|...] [--seed 17]
                      [--sessions 8] [--mean-gap 2.0] [--prompt 4:8]
                      [--gen 4:8] [--max-batch 4] [--steps 1000]
                      [--transport local|channel|tcp]
                      [--stage I --host 127.0.0.1 --port-base 7070]
                      [--trace trace.json]
  protomodels sim     [--preset base|small] [--replicas 4] [--steps 5]
                      [--bandwidth 80mbps] [--dp-bandwidth 80mbps]
                      [--mode subspace] [--dp-mode subspace]
                      [--reduce ring|gossip[:rounds]|none]
                      [--schedule gpipe|1f1b|interleaved[:chunks]]
                      [--microbatches 8] [--jitter 0.2] [--churn-rate 0.0]
                      [--downtime 0.5] [--hetero 1,1,2] [--seed 17]
                      [--trace trace.json]       (virtual-clock spans)
  protomodels exp     <name|all> [--fast] [--steps N] [--seed N]
                      [--threads N] [--exact-rank]
                      [--artifacts artifacts] [--out results]
      names: {}
  protomodels inspect [--artifacts artifacts]
  protomodels timing  [--config tiny] [--steps 3]
  protomodels trace   <trace.json>   (summarize a recorded span trace)
  protomodels bench   [--json] [--fast] [--out .] [--threads N]
                      [--check BENCH_baseline] [--max-regress 0.25]
                      [--compare <old.json> <new.json>]

Replicated runs (--replicas > 1) train R data-parallel pipeline replicas
and all-reduce weight gradients over a simulated cross-replica ring; the
payload is priced under --dp-mode and --hetero assigns per-replica
compute slowdowns (stragglers). See DESIGN.md §6.

`sim` runs the artifact-free discrete-event swarm simulator (DESIGN.md
§9): --jitter sets bandwidth *and* latency jitter fractions,
--churn-rate is Poisson leaves per simulated second (each leaver
rejoins after --downtime and pays a dp-mode-priced state sync), and
--schedule picks the pipeline schedule the event engine executes.
`train --schedule 1f1b` / `train --sim` route the coordinator's step
timing through the same engine.

`train --backend native --transport tcp|channel` runs the SAME training
distributed: one worker per pipeline stage, boundary tensors moving as
framed codec payloads over real sockets (tcp, loopback) or in-process
channels — the loss curve is bitwise identical to the single-process
run (DESIGN.md §11). With --replicas R the native backend launches a
real R×P worker grid (DESIGN.md §14): R pipeline chains plus a
per-stage replica mesh carrying gradient frames priced by --dp-mode.
--reduce ring all-reduces gradients synchronously (bitwise identical to
the in-process replica path); --reduce gossip exchanges with one seeded
peer per step, no global barrier, and survives scripted replica kills
(--kill-replica R@S). `serve --stage I` runs one stage as a standalone
TCP worker process: launch one per stage with identical flags (stage I
listens on port-base+I; launch order is free) and stage 0 prints the
curve.

`serve-infer` serves autoregressive decode over the staged pipeline
(DESIGN.md §16): sessions arrive on a seeded open-loop clock, a
replicated continuous batcher admits up to --max-batch of them per
decode step, and each step moves ONE subspace-compressed boundary row
per active session between stages (per-session codec payloads — the
token stream a session produces is bitwise identical whatever else is
in the batch). --transport channel|tcp runs the decode grid over real
links in one process; --stage I runs one stage per process over TCP
(identical flags everywhere; the PMCFG3 handshake rejects mismatches,
including train-vs-serve workload confusion). --steps is the decode-step
budget, a deterministic bail when the traffic doesn't finish in time.
`exp serve-report` sweeps bandwidth × batch and holds the serving
simulator's predicted step walls against measured runs.

`train --chaos` / `--fault` (native backend) runs the elastic runtime
(DESIGN.md §12): stage workers emit heartbeats and ship compressed
per-stage checkpoints every --ckpt-every steps; a supervisor detects
departed workers by heartbeat staleness (--stale-ms), consumes a spare
for each permanent leave, and resumes every stage from the newest
complete checkpoint boundary. --chaos scripts deterministic worker
kills/rejoins; --fault injects a seeded drop/delay/sever schedule into
a chain link. With --ckpt-codec raw the recovered loss curve is bitwise
identical to the no-churn run. `serve --elastic` runs the same runtime
across processes: stage 0 leads, `serve --spare` enrolls hot standbys.

`train --backend native` trains on the in-process autodiff backend
(DESIGN.md §10): artifact-free and PJRT-free, losses computed natively,
boundary activations and activation-gradients routed through the real
compression codecs. Configs are built-in presets (tiny/small/base) and
the defaults differ from the pjrt path (--lr 1e-2, --microbatches 4 —
sized for the tiny presets); `exp convergence-native` measures the
convergence-parity claim.

--threads N runs experiment grid cells on an N-worker pool (default:
all cores; emitted CSVs are byte-identical for any N). `bench --json`
writes BENCH_linalg.json / BENCH_pipeline.json perf-trajectory files
to --out (DESIGN.md §8); `bench --check <dir>` compares them against
the committed baseline and fails on >25% wall-time regression;
`bench --compare old.json new.json` prints a per-entry speedup table
between two suite files. The raw-bf16 / subspace-bf16 modes ship bf16
boundary payloads (truncate on encode, widen exactly on decode) at
half the wire bytes of their f32 base modes (DESIGN.md §13).

--trace <path> records every span the run emits — fwd/bwd per (stage,
microbatch), codec encode/decode, every transport frame, ring/gossip
reduce phases, heartbeats, checkpoints — as Chrome trace_event JSON
(open in https://ui.perfetto.dev) and writes METRICS.json (the unified
counter registry) beside it; tracing off or on, loss curves are
bitwise identical. `protomodels trace <file>` summarizes a recording;
`exp trace-diff` replays one against the event engine's predicted
timeline (DESIGN.md §15). PROTOMODELS_LOG=error|warn|info|debug
enables leveled runtime diagnostics on stderr (default: off).
",
        exp::ALL.join(", ")
    );
    std::process::exit(2)
}

fn bandwidth_spec(flags: &Flags, key: &str, default: &str) -> Result<LinkSpec> {
    let bw = flags.str(key, default);
    LinkSpec::parse(&bw)
        .ok_or_else(|| anyhow::anyhow!("bad --{key} {bw:?}"))
}

fn make_topo(flags: &Flags, stages: usize, rng: &mut Rng) -> Result<Topology> {
    if flags.switch("regions") {
        return Ok(Topology::global_regions(stages, rng));
    }
    Ok(Topology::uniform(stages, bandwidth_spec(flags, "bandwidth", "80mbps")?, rng))
}

/// `--trace <path>` plumbing shared by train/serve/sim: when the flag
/// is present, record the run in a [`TraceSession`] and on `finish`
/// write the Chrome-JSON trace plus a sibling `METRICS.json` holding
/// the unified counter registry (DESIGN.md §15).
struct TraceOut {
    path: std::path::PathBuf,
    session: TraceSession,
}

impl TraceOut {
    fn start(flags: &Flags, clock: Clock) -> Option<TraceOut> {
        let path = flags.opt("trace")?;
        Some(TraceOut {
            path: path.into(),
            session: TraceSession::start(clock),
        })
    }

    fn finish(self, extra: impl FnOnce(&mut RunMetrics)) -> Result<()> {
        let trace = self.session.stop();
        trace.write_file(&self.path)?;
        let mut m = RunMetrics::new();
        m.absorb_trace(&trace);
        extra(&mut m);
        let mpath = self
            .path
            .parent()
            .map(|p| p.to_path_buf())
            .unwrap_or_default()
            .join("METRICS.json");
        m.write_file(&mpath)?;
        println!(
            "trace: {} events -> {}  metrics -> {}",
            trace.events.len(),
            self.path.display(),
            mpath.display()
        );
        Ok(())
    }
}

/// Build the native backend's [`WorkerSpec`] from CLI flags — shared by
/// `train --backend native` (single-process and `--transport`
/// distributed) and by `serve --stage`, so a leader and its standalone
/// workers derive identical specs (the transport handshake enforces it).
fn native_spec(flags: &Flags) -> Result<WorkerSpec> {
    use protomodels::manifest::Hyper;
    use protomodels::nn::Optim;

    let config = flags.str("config", "tiny");
    let h = match config.as_str() {
        "tiny" => Hyper::tiny_native(),
        "small" => Hyper::small_sim(),
        "base" => Hyper::base_sim(),
        other => bail!(
            "--backend native knows the presets tiny/small/base, not {other:?}"
        ),
    };
    let mut h = h;
    // shrink/stretch the pipeline depth without a new preset (the CI
    // dp-smoke grid trains 2 replicas x 2 stages)
    let stages = flags.usize("stages", 0)?;
    if stages > 0 {
        h.stages = stages;
        h.layers = h.blocks_per_stage * stages;
    }
    let mode = Mode::parse(&flags.str("mode", "subspace"))?;
    let steps = flags.usize("steps", 200)?;
    let seed = flags.usize("seed", 17)? as u64;
    let tm = TimeModel::parse(&flags.str("time-model", "analytic"))
        .ok_or_else(|| anyhow::anyhow!("bad --time-model"))?;
    let schedule = flags.str("schedule", "gpipe").parse::<Schedule>()?;
    let optim = Optim::parse(&flags.str("optim", "adamw"))?;
    let cfg = PipelineConfig {
        mode,
        microbatches: flags.usize("microbatches", 4)?,
        grassmann_interval: flags.usize("grassmann", 0)?,
        lr: flags.f64("lr", 1e-2)? as f32,
        warmup_steps: (steps / 20).max(5),
        total_steps: steps,
        time_model: tm,
        seed,
        schedule,
        event_sim: flags.switch("sim"),
        ..Default::default()
    };
    let corpus_kind = CorpusKind::parse(&flags.str("corpus", "wiki"))
        .ok_or_else(|| anyhow::anyhow!("bad --corpus"))?;
    Ok(WorkerSpec {
        h,
        cfg,
        optim,
        steps,
        corpus_kind,
        corpus_tokens: 400_000,
    })
}

/// Parse the elastic/chaos flags into the [`ElasticOpts`] nested inside
/// [`TrainSpec`]: the churn timeline (`--chaos kill:W@S,join:W@S`), an
/// optional seeded link-fault family (`--fault drop|delay|sever`,
/// applied to stage 1's left link during the first epoch), and the
/// liveness/checkpoint cadences (DESIGN.md §12).
fn elastic_opts(flags: &Flags, worker: &WorkerSpec) -> Result<ElasticOpts> {
    let mut o = ElasticOpts::default();
    if let Some(script) = flags.opt("chaos") {
        o.chaos = ChurnTimeline::parse(script)?;
    }
    // 0 = auto (steps/4); the CLI default keeps the auto cadence
    o.ckpt_every = flags.usize("ckpt-every", 0)? as u64;
    o.ckpt_codec = flags.str("ckpt-codec", "raw").parse::<CkptCodec>()?;
    o.heartbeat_every = flags.usize("hb-every", 1)? as u64;
    o.stale_ms = flags.usize("stale-ms", 5_000)? as u64;
    o.spares = flags.usize("spares", 1)?;
    o.max_epochs = flags.usize("max-epochs", 8)?;
    if let Some(fam) = flags.opt("fault") {
        let family = FaultFamily::parse(fam)?;
        let seed =
            flags.usize("fault-seed", worker.cfg.seed as usize)? as u64;
        // a middle link receives ~2M frames per step (Fwd + StepEnd in,
        // Bwd out is the other side), so this horizon spans the run
        let horizon = (worker.steps * worker.cfg.microbatches * 2) as u64;
        o.faults = FaultPlan {
            target_epoch: 0,
            entries: vec![(
                1,
                LinkSide::Left,
                FaultSchedule::seeded(seed, horizon, family),
            )],
        };
    }
    Ok(o)
}

/// Assemble the legacy [`ElasticSpec`] (the multi-process `serve
/// --elastic` entry still consumes it directly).
fn elastic_spec(flags: &Flags, worker: WorkerSpec) -> Result<ElasticSpec> {
    let opts = elastic_opts(flags, &worker)?;
    let mut spec = TrainSpec::from_worker(worker);
    spec.elastic = Some(opts);
    spec.validate()?;
    let es = spec.elastic_spec().expect("elastic opts present");
    es.validate()?;
    Ok(es)
}

/// Parse the full `train --backend native` flag surface into the
/// canonical validated [`TrainSpec`]: the per-chain worker, the
/// data-parallel axis (`--replicas`, `--reduce`, `--dp-mode`), and —
/// when any chaos flag is present — the nested elastic options.
fn native_train_spec(flags: &Flags) -> Result<TrainSpec> {
    let worker = native_spec(flags)?;
    let replicas = flags.usize("replicas", 1)?;
    let reduce = Reduce::parse(&flags.str(
        "reduce",
        if replicas > 1 { "ring" } else { "none" },
    ))?;
    let dp_mode = Mode::parse(&flags.str("dp-mode", "raw"))?;
    let elastic = (flags.opt("chaos").is_some()
        || flags.opt("fault").is_some()
        || flags.switch("elastic"))
    .then(|| elastic_opts(flags, &worker))
    .transpose()?;
    let spec = TrainSpec { worker, replicas, dp_mode, reduce, elastic };
    spec.validate()?;
    Ok(spec)
}

/// `train --backend native --chaos/--fault`: the elastic distributed
/// pipeline (DESIGN.md §12) — stage workers on threads joined by real
/// transports, a supervisor that detects departures via heartbeat
/// staleness, and recovery that resumes every stage from the newest
/// complete checkpoint boundary (spares absorb permanent leaves).
fn train_native_elastic(
    flags: &Flags,
    spec: TrainSpec,
    kind: TransportKind,
) -> Result<()> {
    let config = flags.str("config", "tiny");
    let es = spec.elastic_spec().expect("elastic opts present");
    let steps = es.worker.steps;
    let tokens_per_step =
        es.worker.cfg.microbatches * es.worker.h.b * es.worker.h.n;
    println!(
        "elastic native train: {config} x{} stages over {} transport, \
         {steps} steps, ckpt every {} ({}), stale {} ms, spares {}, \
         chaos {:?}",
        es.worker.h.stages,
        kind.as_str(),
        es.ckpt_every,
        es.ckpt_codec.as_str(),
        es.stale_ms,
        es.spares,
        es.chaos.to_script(),
    );
    let tr = TraceOut::start(flags, Clock::Host);
    let launched = transport::launch(&spec.topology(kind), &spec)?;
    if let Some(tr) = tr {
        tr.finish(|m| m.absorb_launch(&launched))?;
    }
    let report = *launched.elastic.expect("elastic runs report detail");
    let label = flags.str(
        "label",
        &format!(
            "native_elastic_{config}_{}_{}",
            es.worker.cfg.mode.as_str(),
            kind.as_str()
        ),
    );
    let mut log = RunLog::create(flags.str("out", "results"), &label)?;
    // step_seconds covers the final epoch only; earlier (recomputed)
    // steps log zero wall-clock
    let sec_off = steps.saturating_sub(report.dist.step_seconds.len());
    let wire_per_step = report.dist.wire_bytes / steps.max(1) as u64;
    for (i, loss) in report.losses.iter().enumerate() {
        let secs = if i >= sec_off {
            report.dist.step_seconds[i - sec_off]
        } else {
            0.0
        };
        log.log_parts(
            (i + 1) as u64,
            *loss,
            secs,
            wire_per_step,
            tokens_per_step,
        )?;
        if i % 10 == 0 || i + 1 == steps {
            println!("step {:>5}  loss {loss:.4}", i + 1);
        }
    }
    println!(
        "final: loss {:.4}  epochs {}  recoveries {}  resumed from {:?}  \
         spares used {}",
        report.losses.last().copied().unwrap_or(f64::NAN),
        report.epochs,
        report.recoveries,
        report.resume_steps,
        report.spares_used,
    );
    println!(
        "control plane: {} heartbeat frames ({} B), {} checkpoint frames \
         ({} B); data plane: {} B wire",
        report.heartbeat_frames,
        report.heartbeat_bytes,
        report.ckpt_frames,
        report.ckpt_bytes,
        report.dist.wire_bytes,
    );
    log.finish()?;
    Ok(())
}

/// `train --backend native --transport channel|tcp` (and/or
/// `--replicas R`): the distributed pipeline — R×P workers inside this
/// process, joined by real framed transports (DESIGN.md §11/§14). With
/// `--reduce ring` the grid's loss curve is bitwise identical to the
/// single-process replica path with the same flags.
fn train_native_grid(
    flags: &Flags,
    spec: TrainSpec,
    kind: TransportKind,
) -> Result<()> {
    let config = flags.str("config", "tiny");
    let steps = spec.worker.steps;
    let w = &spec.worker;
    let tokens_per_step =
        w.cfg.microbatches * w.h.b * w.h.n * spec.replicas;
    let mut topo = spec.topology(kind);
    if let Some(kill) = flags.opt("kill-replica") {
        let (r, s) = kill.split_once('@').ok_or_else(|| {
            anyhow::anyhow!("--kill-replica wants R@S, got {kill:?}")
        })?;
        topo.chaos_kill = Some((r.parse()?, s.parse()?));
    }
    println!(
        "distributed native train: {config} {}x{} grid over {} \
         transport, reduce {}, dp-mode {}, {} steps, frame payload {} B",
        spec.replicas,
        w.h.stages,
        kind.as_str(),
        spec.reduce.label(),
        spec.dp_mode.as_str(),
        steps,
        w.cfg.boundary_bytes(&w.h),
    );
    let tr = TraceOut::start(flags, Clock::Host);
    let report = transport::launch(&topo, &spec)?;
    if let Some(tr) = tr {
        tr.finish(|m| m.absorb_launch(&report))?;
    }
    let label = flags.str(
        "label",
        &format!(
            "native_dist_{config}_{}_{}",
            w.cfg.mode.as_str(),
            kind.as_str()
        ),
    );
    let mut log = RunLog::create(flags.str("out", "results"), &label)?;
    let wire_per_step = report.wire_bytes / steps.max(1) as u64;
    for (i, loss) in report.losses.iter().enumerate() {
        log.log_parts(
            (i + 1) as u64,
            *loss,
            report.step_seconds[i],
            wire_per_step,
            tokens_per_step,
        )?;
        if i % 10 == 0 || i + 1 == steps {
            println!(
                "step {:>5}  loss {:.4}  wall {:>8.4}s",
                i + 1,
                loss,
                report.step_seconds[i]
            );
        }
    }
    println!(
        "final ({} transport, {}/{} replicas finished): loss {:.4}  \
         mean step {:.4}s  {} frames, {} boundary payload B, \
         {} dp payload B, {} wire B",
        kind.as_str(),
        report.survivors,
        report.replicas,
        report.losses.last().copied().unwrap_or(f64::NAN),
        report.mean_step_seconds(),
        report.frames,
        report.boundary_payload_bytes,
        report.dp_payload_bytes,
        report.wire_bytes,
    );
    log.finish()?;
    Ok(())
}

/// `train --backend native`: the in-process autodiff backend —
/// artifact-free, so config names resolve to built-in dimension presets
/// instead of the AOT manifest.
fn train_native(flags: &Flags) -> Result<()> {
    use protomodels::nn::NativePipeline;

    let spec = native_train_spec(flags)?;
    let kind = flags
        .opt("transport")
        .map(TransportKind::parse)
        .transpose()?
        .unwrap_or(TransportKind::Channel);
    if spec.elastic.is_some() {
        return train_native_elastic(flags, spec, kind);
    }
    if spec.replicas > 1 || flags.opt("transport").is_some() {
        return train_native_grid(flags, spec, kind);
    }
    let config = flags.str("config", "tiny");
    let WorkerSpec { h, cfg: pcfg, optim, steps, .. } = spec.worker.clone();
    let mode = pcfg.mode;
    let seed = pcfg.seed;
    let corpus = spec.worker.corpus();
    let mut rng = Rng::new(seed);
    let topo = make_topo(flags, h.stages, &mut rng)?;
    // drive through the coordinator's backend facade — the same surface
    // a PJRT pipeline presents
    let mut backend = Backend::Native(Box::new(NativePipeline::new(
        h.clone(),
        topo,
        pcfg,
        optim,
    )?));
    let label = flags.str(
        "label",
        &format!(
            "native_{config}_{}_{}",
            mode.as_str(),
            flags.str("bandwidth", "80mbps")
        ),
    );
    let mut log = RunLog::create(flags.str("out", "results"), &label)?;
    let tr = TraceOut::start(flags, Clock::Host);
    for step in 0..steps {
        let stats =
            backend.train_step(|r| corpus.train_batch(h.b, h.n, r))?;
        log.log(&stats)?;
        if step % 10 == 0 || step + 1 == steps {
            println!(
                "step {:>5}  loss {:.4}  sim_t {:>9.3}s  wire {:>10}B  tps {:>9.1}",
                stats.step,
                stats.loss,
                log.sim_time,
                stats.wire_bytes,
                stats.tokens as f64 / stats.sim_seconds
            );
        }
    }
    let val = backend.eval(8, |r| corpus.val_batch(h.b, h.n, r))?;
    if let Some(tr) = tr {
        tr.finish(|_| {})?;
    }
    println!(
        "final (native, {}): val_loss {:.4}  val_ppl {:.2}  mean_tps {:.1}  \
         subspace_leak {:.2e}",
        optim.as_str(),
        val,
        perplexity(val),
        log.tps(),
        backend.subspace_leak(),
    );
    if let Backend::Native(pipe) = &backend {
        println!(
            "native: peak_mem {:.1} MB  host {:.2}s",
            pipe.peak_bytes() as f64 / 1e6,
            pipe.host_seconds
        );
    }
    log.finish()?;
    Ok(())
}

fn cmd_train(flags: &Flags) -> Result<()> {
    if BackendKind::parse(&flags.str("backend", "pjrt"))?
        == BackendKind::Native
    {
        return train_native(flags);
    }
    let manifest = Manifest::load(flags.str("artifacts", "artifacts"))?;
    let config = flags.str("config", "base");
    let mode = Mode::parse(&flags.str("mode", "subspace"))?;
    let steps = flags.usize("steps", 200)?;
    let seed = flags.usize("seed", 17)? as u64;
    let h = manifest.config(&config)?.hyper.clone();
    let tm = TimeModel::parse(&flags.str("time-model", "analytic"))
        .ok_or_else(|| anyhow::anyhow!("bad --time-model"))?;
    let schedule = flags.str("schedule", "gpipe").parse::<Schedule>()?;
    let pcfg = PipelineConfig {
        mode,
        microbatches: flags.usize("microbatches", 8)?,
        grassmann_interval: flags.usize("grassmann", 0)?,
        lr: flags.f64("lr", 6e-3)? as f32,
        warmup_steps: (steps / 20).max(5),
        total_steps: steps,
        time_model: tm,
        seed,
        schedule,
        event_sim: flags.switch("sim"),
        ..Default::default()
    };
    let corpus_kind = CorpusKind::parse(&flags.str("corpus", "wiki"))
        .ok_or_else(|| anyhow::anyhow!("bad --corpus"))?;
    let corpus = Corpus::synthetic(corpus_kind, h.vocab, 400_000, seed ^ 0xDD);
    let label = flags.str(
        "label",
        &format!(
            "{config}_{}_{}",
            mode.as_str(),
            flags.str("bandwidth", "80mbps")
        ),
    );
    let replicas = flags.usize("replicas", 1)?;
    if replicas > 1 {
        return train_replicated(
            flags, &manifest, &config, replicas, pcfg, &corpus, &label,
        );
    }
    let mut rng = Rng::new(seed);
    let topo = make_topo(flags, h.stages, &mut rng)?;
    let mut pipe = Pipeline::new(&manifest, &config, topo, pcfg)?;
    let mut log = RunLog::create(flags.str("out", "results"), &label)?;
    let tr = TraceOut::start(flags, Clock::Host);
    for step in 0..steps {
        let stats = pipe.train_step(|r| corpus.train_batch(h.b, h.n, r))?;
        log.log(&stats)?;
        if step % 10 == 0 || step + 1 == steps {
            println!(
                "step {:>5}  loss {:.4}  sim_t {:>9.3}s  wire {:>10}B  tps {:>9.1}",
                stats.step,
                stats.loss,
                log.sim_time,
                stats.wire_bytes,
                stats.tokens as f64 / stats.sim_seconds
            );
        }
    }
    let val = pipe.eval(8, |r| corpus.val_batch(h.b, h.n, r))?;
    if let Some(tr) = tr {
        tr.finish(|m| m.absorb_timing(&pipe.timing_report()))?;
    }
    println!(
        "final: val_loss {:.4}  val_ppl {:.2}  mean_tps {:.1}  subspace_leak {:.2e}",
        val,
        perplexity(val),
        log.tps(),
        pipe.subspace_leak()
    );
    log.finish()?;
    Ok(())
}

/// Replicated training: R data-parallel pipeline replicas joined by a
/// ring all-reduce of weight gradients (--replicas / --dp-mode /
/// --dp-bandwidth / --hetero).
fn train_replicated(
    flags: &Flags,
    manifest: &Manifest,
    config: &str,
    replicas: usize,
    pcfg: PipelineConfig,
    corpus: &Corpus,
    label: &str,
) -> Result<()> {
    let h = manifest.config(config)?.hyper.clone();
    let steps = pcfg.total_steps;
    let seed = pcfg.seed;
    let dp_mode = Mode::parse(&flags.str("dp-mode", "subspace"))?;
    let slowdown = flags.f64_list("hetero")?.unwrap_or_default();
    if !slowdown.is_empty() && slowdown.len() != replicas {
        bail!(
            "--hetero lists {} factors for {replicas} replicas",
            slowdown.len()
        );
    }
    // positivity and time-model compatibility of the slowdown factors
    // are validated by ReplicaSet::new
    let mut rng = Rng::new(seed ^ 0xD9);
    let topos = (0..replicas)
        .map(|_| make_topo(flags, h.stages, &mut rng))
        .collect::<Result<Vec<_>>>()?;
    let ring_spec = bandwidth_spec(
        flags,
        "dp-bandwidth",
        &flags.str("bandwidth", "80mbps"),
    )?;
    let ring = ReplicaRing::new(replicas, ring_spec, &mut rng);
    let mut set = ReplicaSet::new(
        manifest,
        config,
        topos,
        ring,
        pcfg,
        ReplicaConfig { dp_mode, slowdown },
    )?;
    let label = format!("{label}_r{replicas}_{}", dp_mode.as_str());
    let mut log = RunLog::create(flags.str("out", "results"), &label)?;
    for step in 0..steps {
        let s = set.train_step(|r| corpus.train_batch(h.b, h.n, r))?;
        log.log_parts(s.step, s.loss, s.sim_seconds, s.wire_bytes + s.dp_bytes, s.tokens)?;
        if step % 10 == 0 || step + 1 == steps {
            println!(
                "step {:>5}  loss {:.4}  sim_t {:>9.3}s  act {:>10}B  dp {:>10}B  tail {:>7.4}s",
                s.step, s.loss, log.sim_time, s.wire_bytes, s.dp_bytes,
                s.makespan.tail
            );
        }
    }
    let val = set.eval(8, |r| corpus.val_batch(h.b, h.n, r))?;
    println!(
        "final ({} replicas, dp-mode {}): val_loss {:.4}  val_ppl {:.2}  mean_tps {:.1}",
        set.replicas(),
        dp_mode.as_str(),
        val,
        perplexity(val),
        log.tps()
    );
    log.finish()?;
    Ok(())
}

/// `sim` subcommand: the artifact-free discrete-event swarm simulator
/// (DESIGN.md §9) — jitter, time-varying stragglers, churn, and async
/// pipeline schedules, priced from the analytic cost model alone.
fn cmd_sim(flags: &Flags) -> Result<()> {
    use protomodels::manifest::Hyper;
    use protomodels::netsim::MBPS;

    let preset = flags.str("preset", "base");
    let hyper = match preset.as_str() {
        "base" => Hyper::base_sim(),
        "small" => Hyper::small_sim(),
        other => bail!("bad --preset {other:?} (have base, small)"),
    };
    let replicas = flags.usize("replicas", 4)?;
    let mut spec = SwarmSpec::uniform(hyper, replicas, 80.0 * MBPS);
    spec.link = bandwidth_spec(flags, "bandwidth", "80mbps")?;
    spec.ring_link = bandwidth_spec(
        flags,
        "dp-bandwidth",
        &flags.str("bandwidth", "80mbps"),
    )?;
    spec.mode = Mode::parse(&flags.str("mode", "subspace"))?;
    spec.dp_mode = Mode::parse(&flags.str("dp-mode", "subspace"))?;
    spec.reduce = Reduce::parse(&flags.str("reduce", "ring"))?;
    spec.schedule = flags.str("schedule", "gpipe").parse::<Schedule>()?;
    spec.microbatches = flags.usize("microbatches", 8)?;
    spec.steps = flags.usize("steps", 5)?;
    spec.seed = flags.usize("seed", 17)? as u64;
    // one knob drives both jitter axes: bandwidth sigma/mu on each link
    // plus the per-transfer latency factor
    let jitter = flags.f64("jitter", 0.2)?;
    spec.link.jitter_frac = jitter;
    spec.ring_link.jitter_frac = jitter;
    spec.lat_jitter_frac = jitter;
    if let Some(hetero) = flags.f64_list("hetero")? {
        if hetero.len() != replicas {
            bail!("--hetero lists {} factors for {replicas} replicas", hetero.len());
        }
        spec.straggler =
            hetero.into_iter().map(SlowdownProfile::Constant).collect();
    }
    let rate = flags.f64("churn-rate", 0.0)?;
    if rate > 0.0 {
        spec.churn = ChurnSpec::Poisson {
            rate_per_s: rate,
            downtime_s: flags.f64("downtime", 0.5)?,
        };
    }

    let tr = TraceOut::start(flags, Clock::Virtual);
    let rep = simulate_swarm(&spec)?;
    if let Some(tr) = tr {
        tr.finish(|_| {})?;
    }
    println!(
        "swarm: {preset} x{replicas} replicas, {} schedule, {} steps, \
         jitter {jitter}, churn {rate}/s",
        spec.schedule.as_str(),
        spec.steps,
    );
    for (i, s) in rep.step_seconds.iter().enumerate() {
        println!("  step {:>3}  {:>9.4}s", i + 1, s);
    }
    println!(
        "total {:.4}s  mean step {:.4}s  compute_end {:.4}s  comm_end {:.4}s  \
         tail {:.4}s",
        rep.total,
        rep.mean_step(),
        rep.compute_end,
        rep.comm_end,
        rep.tail
    );
    println!(
        "churn: {} leaves, {} rejoins ({:.3}s sync), {} all-reduce restarts, \
         min membership {}",
        rep.leaves,
        rep.rejoins,
        rep.sync_seconds,
        rep.allreduce_restarts,
        rep.min_active
    );
    println!(
        "bytes: {} activation, {} gradient | ring busy {:.4}s",
        rep.wire_bytes, rep.dp_bytes, rep.allreduce_busy
    );
    Ok(())
}

/// `serve --stage I`: run one pipeline stage as a standalone TCP worker
/// (one process per stage; see DESIGN.md §11). All model/run flags must
/// match across the swarm — the transport handshake rejects mismatches.
fn cmd_serve(flags: &Flags) -> Result<()> {
    let spec = native_spec(flags)?;
    if flags.switch("elastic")
        || flags.switch("spare")
        || flags.opt("chaos").is_some()
    {
        return cmd_serve_elastic(flags, spec);
    }
    let stage: usize = flags.require("stage")?.parse().map_err(|_| {
        anyhow::anyhow!("--stage wants a stage index in [0, stages)")
    })?;
    let host = flags.str("host", "127.0.0.1");
    let port_base = flags.usize("port-base", 7070)?;
    if port_base + spec.h.stages > u16::MAX as usize {
        bail!("--port-base {port_base} leaves no room for {} stage ports", spec.h.stages);
    }
    println!(
        "serve: stage {stage}/{} ({} mode, {} steps) on {host}, ports \
         {port_base}+",
        spec.h.stages,
        spec.cfg.mode.as_str(),
        spec.steps,
    );
    let tr = TraceOut::start(flags, Clock::Host);
    let report =
        transport::serve_stage(&spec, stage, &host, port_base as u16)?;
    if let Some(tr) = tr {
        tr.finish(|_| {})?;
    }
    if stage == 0 {
        for (i, loss) in report.losses.iter().enumerate() {
            if i % 10 == 0 || i + 1 == report.losses.len() {
                println!("step {:>5}  loss {loss:.4}", i + 1);
            }
        }
        let mean: f64 = report.step_seconds.iter().sum::<f64>()
            / report.step_seconds.len().max(1) as f64;
        println!(
            "final: loss {:.4}  mean step {mean:.4}s",
            report.losses.last().copied().unwrap_or(f64::NAN)
        );
    }
    println!(
        "stage {stage} done: {} frames, {} B boundary payload, {} B wire",
        report.frames_sent, report.boundary_payload_bytes, report.wire_bytes
    );
    Ok(())
}

/// `serve --elastic` / `serve --spare`: the churn-tolerant serve mode
/// (DESIGN.md §12). Stage 0 is the leader: it enrolls workers and
/// spares over a control port, monitors heartbeats, and reassigns dead
/// stages to spares across recovery epochs. `--spare` processes enroll
/// as hot standbys and wait for a stage assignment; `--stage I` (I ≥ 1)
/// processes run their stage and re-enroll for resume orders after a
/// failure tears the epoch down.
fn cmd_serve_elastic(flags: &Flags, spec: WorkerSpec) -> Result<()> {
    let es = elastic_spec(flags, spec)?;
    let host = flags.str("host", "127.0.0.1");
    let port_base = flags.usize("port-base", 7070)?;
    if port_base > u16::MAX as usize {
        bail!("--port-base {port_base} is not a TCP port");
    }
    let port_base = port_base as u16;
    if flags.switch("spare") {
        println!(
            "serve: spare standby on {host}, ctl port {port_base} — waiting \
             for a stage assignment"
        );
        return transport::serve_spare(&es, &host, port_base);
    }
    let stage = flags.usize("stage", 0)?;
    if stage == 0 {
        println!(
            "serve: elastic leader (stage 0/{}) on {host}, ctl port \
             {port_base} — {} workers + {} spare(s) expected",
            es.worker.h.stages,
            es.worker.h.stages - 1,
            es.spares,
        );
        let tr = TraceOut::start(flags, Clock::Host);
        let report = transport::serve_elastic(&es, &host, port_base)?;
        if let Some(tr) = tr {
            tr.finish(|m| m.absorb_elastic(&report))?;
        }
        for (i, loss) in report.losses.iter().enumerate() {
            if i % 10 == 0 || i + 1 == report.losses.len() {
                println!("step {:>5}  loss {loss:.4}", i + 1);
            }
        }
        println!(
            "final: loss {:.4}  epochs {}  recoveries {}  resumed from \
             {:?}  spares used {}",
            report.losses.last().copied().unwrap_or(f64::NAN),
            report.epochs,
            report.recoveries,
            report.resume_steps,
            report.spares_used,
        );
        println!(
            "control plane: {} heartbeat frames ({} B), {} checkpoint \
             frames ({} B)",
            report.heartbeat_frames,
            report.heartbeat_bytes,
            report.ckpt_frames,
            report.ckpt_bytes,
        );
        return Ok(());
    }
    println!(
        "serve: elastic stage {stage}/{} on {host}, ctl port {port_base}",
        es.worker.h.stages
    );
    match transport::serve_stage_elastic(&es, stage, &host, port_base) {
        Ok(()) => Ok(()),
        // a scripted chaos kill is this process's success condition: the
        // timeline told it to die, and it did
        Err(e) if format!("{e:#}").contains("chaos kill") => {
            println!("stage {stage}: {e:#}");
            Ok(())
        }
        Err(e) => Err(e),
    }
}

/// Parse an inclusive `lo:hi` token range (`"4:8"`), accepting a bare
/// `n` as `n:n`.
fn parse_range(s: &str, flag: &str) -> Result<(usize, usize)> {
    let parse1 = |t: &str| -> Result<usize> {
        t.parse()
            .map_err(|_| anyhow::anyhow!("{flag} wants `lo:hi` or `n`, got {s:?}"))
    };
    match s.split_once(':') {
        Some((lo, hi)) => Ok((parse1(lo)?, parse1(hi)?)),
        None => {
            let n = parse1(s)?;
            Ok((n, n))
        }
    }
}

/// `serve-infer`: autoregressive decode serving over the staged
/// pipeline with subspace-compressed KV-boundary frames and continuous
/// batching (DESIGN.md §16). Single-process by default; `--transport
/// channel|tcp` runs the full decode grid in this process over real
/// links (token streams bitwise identical to single-process);
/// `--stage I` runs ONE stage as a standalone TCP worker — launch one
/// process per stage with identical flags, stage 0 prints the session
/// table.
fn cmd_serve_infer(flags: &Flags) -> Result<()> {
    use protomodels::transport::{
        run_serve_local, serve_infer, serve_infer_stage, ServeSpec,
        TrafficSpec,
    };

    let mut core = native_spec(flags)?;
    if flags.opt("steps").is_none() {
        // decode steps are cheap: default to a budget that serves the
        // default traffic with plenty of slack
        core.steps = 1_000;
        core.cfg.total_steps = 1_000;
    }
    let traffic = TrafficSpec {
        sessions: flags.usize("sessions", 8)?,
        mean_gap: flags.f64("mean-gap", 2.0)?,
        prompt: parse_range(&flags.str("prompt", "4:8"), "--prompt")?,
        gen: parse_range(&flags.str("gen", "4:8"), "--gen")?,
    };
    let spec = ServeSpec {
        core,
        traffic,
        max_batch: flags.usize("max-batch", 4)?,
    };
    spec.validate()?;

    let tr = TraceOut::start(flags, Clock::Host);
    let report = if let Some(stage) = flags.opt("stage") {
        let stage: usize = stage.parse().map_err(|_| {
            anyhow::anyhow!("--stage wants a stage index in [0, stages)")
        })?;
        let host = flags.str("host", "127.0.0.1");
        let port_base = flags.usize("port-base", 7070)?;
        if port_base + spec.core.h.stages > u16::MAX as usize {
            bail!(
                "--port-base {port_base} leaves no room for {} stage ports",
                spec.core.h.stages
            );
        }
        println!(
            "serve-infer: stage {stage}/{} ({} mode, {} sessions, \
             max-batch {}) on {host}, ports {port_base}+",
            spec.core.h.stages,
            spec.core.cfg.mode.as_str(),
            spec.traffic.sessions,
            spec.max_batch,
        );
        serve_infer_stage(&spec, stage, &host, port_base as u16)?
    } else {
        match flags.str("transport", "local").as_str() {
            "local" => run_serve_local(&spec)?,
            other => serve_infer(&spec, TransportKind::parse(other)?)?,
        }
    };
    if let Some(tr) = tr {
        tr.finish(|m| m.absorb_serve(&report))?;
    }
    if report.stage == 0 {
        println!(
            "session  arrive  admit  first  done  prompt  gen  latency"
        );
        for s in &report.sessions {
            println!(
                "{:>7}  {:>6}  {:>5}  {:>5}  {:>4}  {:>6}  {:>3}  {:.4}s",
                s.id,
                s.arrival_step,
                s.admit_step,
                s.first_token_step,
                s.done_step,
                s.prompt_len,
                s.gen,
                s.latency_s,
            );
        }
    }
    println!(
        "serve-infer done: {} decode steps, {} tokens, {:.1} tok/s, \
         latency p50 {:.4}s p99 {:.4}s",
        report.steps,
        report.tokens_generated,
        report.tokens_per_sec(),
        report.latency_percentile(50.0),
        report.latency_percentile(99.0),
    );
    println!(
        "wire: {} frames, {} B decode payload, {} B token payload, \
         {} B total; kv peak {} B",
        report.frames,
        report.decode_payload_bytes,
        report.token_payload_bytes,
        report.wire_bytes,
        report.kv_peak_bytes,
    );
    Ok(())
}

/// `trace <file>`: print the per-(cat, name) summary of a recorded
/// trace file (event count, total duration, summed `bytes`).
fn cmd_trace(flags: &Flags) -> Result<()> {
    let path = flags.positional.first().ok_or_else(|| {
        anyhow::anyhow!("usage: protomodels trace <trace.json>")
    })?;
    let trace = Trace::read_file(std::path::Path::new(path))?;
    print!("{}", trace.summary());
    Ok(())
}

fn cmd_inspect(flags: &Flags) -> Result<()> {
    let manifest = Manifest::load(flags.str("artifacts", "artifacts"))?;
    println!("artifacts root: {}", manifest.root.display());
    for (name, cm) in &manifest.configs {
        let h = &cm.hyper;
        println!(
            "config {name}: d={} d_ff={} heads={} layers={} stages={} n={} \
             vocab={} k={} b={} ratio={:.0}x params={}",
            h.d, h.d_ff, h.heads, h.layers, h.stages, h.n, h.vocab, h.k, h.b,
            h.ratio, h.param_count
        );
        println!("  modes: {:?}  entries: {}", cm.modes, cm.entries.len());
    }
    Ok(())
}

fn cmd_timing(flags: &Flags) -> Result<()> {
    let manifest = Manifest::load(flags.str("artifacts", "artifacts"))?;
    let config = flags.str("config", "tiny");
    let steps = flags.usize("steps", 3)?;
    let h = manifest.config(&config)?.hyper.clone();
    let mut rng = Rng::new(1);
    let topo =
        Topology::uniform(h.stages, LinkSpec::internet_80m(), &mut rng);
    let pcfg = PipelineConfig {
        microbatches: 4,
        total_steps: steps,
        grassmann_interval: steps.max(1),
        ..Default::default()
    };
    let mut pipe = Pipeline::new(&manifest, &config, topo, pcfg)?;
    let corpus = Corpus::synthetic(CorpusKind::Wiki, h.vocab, 100_000, 2);
    for _ in 0..steps {
        pipe.train_step(|r| corpus.train_batch(h.b, h.n, r))?;
    }
    print!("{}", pipe.timing_report());
    let compute = pipe.total_compute_seconds();
    println!(
        "total PJRT compute: {compute:.3}s | host coordination: {:.3}s \
         ({:.1}% overhead)",
        pipe.host_seconds - compute,
        (pipe.host_seconds / compute.max(1e-9) - 1.0) * 100.0
    );
    Ok(())
}

/// `bench` subcommand: the in-tree perf suite. Artifact-free — it
/// exercises the linalg kernels and the analytic pipeline cost model
/// only, so CI can track the perf trajectory without JAX/PJRT. With
/// `--json` the results land in `BENCH_linalg.json` and
/// `BENCH_pipeline.json` under `--out` (default: the current directory,
/// i.e. the repo root under `make bench`).
fn cmd_bench(flags: &Flags) -> Result<()> {
    use protomodels::bench::{black_box, write_json, BenchEntry, Bencher};
    use protomodels::coordinator::replica::{
        simulate_hybrid_step, HybridSimSpec,
    };
    use protomodels::linalg;
    use protomodels::manifest::Hyper;
    use protomodels::netsim::MBPS;
    use protomodels::tensor::Tensor;

    let json = flags.switch("json");
    let fast = flags.switch("fast");
    let out = std::path::PathBuf::from(flags.str("out", "."));
    // regression-gate mode: compare the BENCH_*.json in --out against a
    // committed baseline directory and fail on >--max-regress wall-time
    // growth for any entry present in both
    // speedup-table mode: `bench --compare old.json new.json` prints
    // per-entry old/new means and the speedup ratio — kernel wins are
    // reportable without hand-diffing JSON
    if let Some(old) = flags.opt("compare") {
        let new = flags.positional.first().ok_or_else(|| {
            anyhow::anyhow!(
                "bench --compare needs two suite files: \
                 --compare <old.json> <new.json>"
            )
        })?;
        let rows = protomodels::bench::compare_suites(
            std::path::Path::new(old),
            std::path::Path::new(new),
        )?;
        let best = protomodels::bench::print_comparison(&rows);
        println!("best speedup: {best:.2}x ({old} -> {new})");
        return Ok(());
    }
    if let Some(baseline) = flags.opt("check") {
        let max_regress = flags.f64("max-regress", 0.25)?;
        let report = protomodels::bench::check_regressions(
            &out,
            std::path::Path::new(baseline),
            max_regress,
        )?;
        println!(
            "bench check: {} entries compared, {} without baseline, \
             {} regressed",
            report.checked,
            report.skipped,
            report.failures.len()
        );
        if !report.failures.is_empty() {
            for f in &report.failures {
                eprintln!("REGRESSION: {f}");
            }
            bail!(
                "{} bench entr{} regressed beyond {:.0}%",
                report.failures.len(),
                if report.failures.len() == 1 { "y" } else { "ies" },
                max_regress * 100.0
            );
        }
        return Ok(());
    }
    let bench = if fast { Bencher::quick() } else { Bencher::default() };
    let randt = |seed: u64, m: usize, n: usize| -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::new(vec![m, n], rng.normal_f32_vec(m * n, 1.0))
    };

    // ---- linalg kernels ----
    let mut linalg_entries: Vec<BenchEntry> = Vec::new();
    let mm_sizes: &[usize] = if fast { &[128, 256] } else { &[256, 512] };
    for &d in mm_sizes {
        let a = randt(1, d, d);
        let b = randt(2, d, d);
        let flops = 2.0 * (d as f64).powi(3);
        let r = bench.run(&format!("matmul_tiled_{d}"), || {
            black_box(linalg::matmul(black_box(&a), black_box(&b)));
        });
        println!("    -> {:.2} GFLOP/s", r.throughput(flops) / 1e9);
        linalg_entries
            .push(BenchEntry { result: r, items_per_iter: Some(flops) });
        let r = bench.run(&format!("matmul_reference_{d}"), || {
            black_box(linalg::matmul_reference(black_box(&a), black_box(&b)));
        });
        linalg_entries
            .push(BenchEntry { result: r, items_per_iter: Some(flops) });
        let r = bench.run(&format!("matmul_nt_{d}"), || {
            black_box(linalg::matmul_nt(black_box(&a), black_box(&b)));
        });
        linalg_entries
            .push(BenchEntry { result: r, items_per_iter: Some(flops) });
        let r = bench.run(&format!("matmul_tn_{d}"), || {
            black_box(linalg::matmul_tn(black_box(&a), black_box(&b)));
        });
        linalg_entries
            .push(BenchEntry { result: r, items_per_iter: Some(flops) });
        let r = bench.run(&format!("transpose_{d}"), || {
            black_box(linalg::transpose(black_box(&a)));
        });
        linalg_entries.push(BenchEntry {
            result: r,
            items_per_iter: Some((d * d) as f64),
        });
    }
    {
        // fused row projection (W·U)·Uᵀ at the init/reproject shape
        let w = randt(3, 1024, 256);
        let mut u = randt(4, 256, 8);
        linalg::orthonormalize_columns(&mut u);
        let r = bench.run("project_rows_1024x256_k8", || {
            black_box(linalg::project_rows(black_box(&w), black_box(&u)));
        });
        linalg_entries.push(BenchEntry { result: r, items_per_iter: None });
    }
    // stable rank: exact Jacobi vs the randomized range-finder
    let exact_sizes: &[usize] = if fast { &[128] } else { &[128, 256] };
    for &d in exact_sizes {
        let a = randt(5, d, d);
        let r = bench.run(&format!("stable_rank_exact_{d}"), || {
            black_box(linalg::stable_rank(black_box(&a)));
        });
        linalg_entries.push(BenchEntry { result: r, items_per_iter: None });
    }
    let approx_sizes: &[usize] =
        if fast { &[128, 256] } else { &[256, 512, 1024] };
    for &d in approx_sizes {
        let a = randt(5, d, d);
        let r = bench.run(&format!("stable_rank_approx_{d}"), || {
            black_box(linalg::stable_rank_approx(
                black_box(&a),
                linalg::STABLE_RANK_SKETCH,
            ));
        });
        linalg_entries.push(BenchEntry { result: r, items_per_iter: None });
    }

    // ---- pipeline cost model + worker pool ----
    let mut pipe_entries: Vec<BenchEntry> = Vec::new();
    for (name, h) in
        [("small_sim", Hyper::small_sim()), ("base_sim", Hyper::base_sim())]
    {
        let spec = HybridSimSpec::uniform(h, 4, 80.0 * MBPS);
        let r = bench.run(&format!("simulate_hybrid_step_{name}_r4"), || {
            black_box(simulate_hybrid_step(black_box(&spec)));
        });
        pipe_entries.push(BenchEntry { result: r, items_per_iter: None });
    }
    {
        // pool scaling on a synthetic grid of single-threaded cells
        // (96³ stays under the matmul threading threshold, so the
        // serial baseline really is serial)
        let cells: Vec<u64> = (0..32).collect();
        let cell = |seed: u64| {
            for rep in 0..4u64 {
                let a = randt(seed ^ (rep << 8), 96, 96);
                let b = randt(seed ^ (rep << 8) ^ 1, 96, 96);
                black_box(linalg::matmul(&a, &b));
            }
        };
        let r1 = bench.run("par_grid_32cells_threads1", || {
            par::map(1, &cells, |_, s| cell(*s));
        });
        let avail = par::max_threads();
        // only meaningful (and uniquely named) when a pool exists
        let rn = if avail > 1 {
            let rn =
                bench.run(&format!("par_grid_32cells_threads{avail}"), || {
                    par::map(avail, &cells, |_, s| cell(*s));
                });
            println!(
                "    -> pool speedup at {avail} threads: {:.2}x",
                r1.mean_ns / rn.mean_ns
            );
            Some(rn)
        } else {
            None
        };
        pipe_entries.push(BenchEntry { result: r1, items_per_iter: None });
        if let Some(rn) = rn {
            pipe_entries.push(BenchEntry { result: rn, items_per_iter: None });
        }
    }
    {
        // the discrete-event engine: one swarm step per schedule, plus
        // a churn-heavy multi-step run (per-step cost of the simulator
        // itself, not of the simulated system)
        for (name, sched) in [
            ("gpipe", Schedule::Gpipe),
            ("1f1b", Schedule::OneFOneB),
            ("interleaved", Schedule::Interleaved { chunks: 2 }),
        ] {
            let mut spec = SwarmSpec::uniform(
                protomodels::manifest::Hyper::base_sim(),
                4,
                80.0 * MBPS,
            );
            spec.schedule = sched;
            let r = bench.run(&format!("sim_step_{name}_base_r4"), || {
                black_box(simulate_swarm(black_box(&spec)).expect("sim step"));
            });
            pipe_entries.push(BenchEntry { result: r, items_per_iter: None });
        }
        let mut spec = SwarmSpec::uniform(
            protomodels::manifest::Hyper::base_sim(),
            4,
            80.0 * MBPS,
        );
        spec.steps = 6;
        spec.lat_jitter_frac = 0.2;
        spec.churn = ChurnSpec::Poisson { rate_per_s: 0.5, downtime_s: 0.3 };
        let r = bench.run("sim_churn_swarm_6steps_r4", || {
            black_box(simulate_swarm(black_box(&spec)).expect("churn swarm"));
        });
        pipe_entries.push(BenchEntry { result: r, items_per_iter: None });
    }
    {
        // end-to-end grid driver (artifact-free): dp-grid fast preset
        let tmp = std::env::temp_dir().join("protomodels_bench_dp_grid");
        let widths: Vec<usize> = if par::max_threads() > 1 {
            vec![1, par::max_threads()]
        } else {
            vec![1]
        };
        for threads in widths {
            let opts = ExpOpts {
                out_dir: tmp.join(format!("t{threads}")),
                fast: true,
                threads,
                ..Default::default()
            };
            let r = bench
                .run(&format!("exp_dp_grid_fast_threads{threads}"), || {
                    exp::run("dp-grid", &opts).expect("dp-grid bench run");
                });
            pipe_entries.push(BenchEntry { result: r, items_per_iter: None });
        }
    }

    // ---- native autodiff backend: per-stage fwd/bwd + full train step ----
    let mut nn_entries: Vec<BenchEntry> = Vec::new();
    {
        use protomodels::nn::model::{
            build_stage, high_rank_e, sinusoidal_pe, StageIo,
        };
        use protomodels::nn::{NativePipeline, Optim};
        use protomodels::stage::{GlobalState, StageState};
        use protomodels::timemodel::{stage_flops, Phase};

        let h = Hyper::tiny_native();
        let corpus = Corpus::synthetic(CorpusKind::Wiki, h.vocab, 50_000, 3);
        let mut rng = Rng::new(7);
        let global = GlobalState::from_hyper(&h, &mut rng);
        let st = StageState::from_schema(
            h.stage_schema(1),
            "mid",
            1,
            Mode::Subspace,
            &global,
            &mut rng,
        )
        .expect("stage init");
        let pe = sinusoidal_pe(h.n, h.d);
        let (tok, _) = corpus.train_batch(h.b, h.n, &mut rng);
        let e = high_rank_e(&h, Mode::Subspace, &pe, &global.t_fixed, &tok);
        let m = h.b * h.n;
        let xin = Tensor::new(vec![m, h.k], rng.normal_f32_vec(m * h.k, 0.1));
        let gc = Tensor::new(vec![m, h.k], rng.normal_f32_vec(m * h.k, 1e-3));
        let io = || StageIo {
            u: &global.u,
            e: &e,
            tok: &tok,
            input: Some(&xin),
            targets: None,
        };
        let r = bench.run("nn_stage_fwd_tiny_subspace", || {
            let built =
                build_stage(&h, Mode::Subspace, 1, &st.params, io());
            black_box(built.tape.value(built.output).numel());
        });
        println!(
            "    -> {:.2} GFLOP/s",
            r.throughput(stage_flops(&h, 1, Phase::Fwd, true)) / 1e9
        );
        nn_entries.push(BenchEntry {
            result: r,
            items_per_iter: Some(stage_flops(&h, 1, Phase::Fwd, true)),
        });
        let r = bench.run("nn_stage_bwd_tiny_subspace", || {
            let mut built =
                build_stage(&h, Mode::Subspace, 1, &st.params, io());
            built.tape.backward_from(built.output, gc.clone());
            black_box(
                built.tape.grad(built.input.expect("input")).is_some(),
            );
        });
        nn_entries.push(BenchEntry {
            result: r,
            items_per_iter: Some(stage_flops(&h, 1, Phase::Bwd, true)),
        });
        // the hot-path variant the pipelines actually run: matmul
        // weight grads stream into persistent accumulators
        // (`backward_into`), skipping the per-tape grad tensors
        let mut acc: Vec<Tensor> =
            st.params.iter().map(|p| Tensor::zeros(&p.shape)).collect();
        let r = bench.run("nn_stage_bwd_fused_tiny_subspace", || {
            let mut built =
                build_stage(&h, Mode::Subspace, 1, &st.params, io());
            built.tape.backward_into(
                built.output,
                Some(gc.clone()),
                &built.params,
                &mut acc,
            );
            black_box(
                built.tape.grad(built.input.expect("input")).is_some(),
            );
        });
        nn_entries.push(BenchEntry {
            result: r,
            items_per_iter: Some(stage_flops(&h, 1, Phase::Bwd, true)),
        });
        for mode in [Mode::Subspace, Mode::Raw] {
            let pcfg = protomodels::coordinator::PipelineConfig {
                mode,
                microbatches: 2,
                grassmann_interval: 0,
                total_steps: 10_000,
                seed: 5,
                ..Default::default()
            };
            let mut rng = Rng::new(5);
            let topo = protomodels::netsim::Topology::uniform(
                h.stages,
                LinkSpec::internet_80m(),
                &mut rng,
            );
            let mut pipe =
                NativePipeline::new(h.clone(), topo, pcfg, Optim::AdamW)
                    .expect("native pipeline");
            let tokens = (2 * h.b * h.n) as f64;
            let r = bench
                .run(&format!("nn_train_step_tiny_{}", mode.as_str()), || {
                    let s = pipe
                        .train_step(|r| corpus.train_batch(h.b, h.n, r))
                        .expect("train step");
                    black_box(s.loss);
                });
            println!(
                "    -> {:.0} tokens/s ({})",
                r.throughput(tokens),
                mode.as_str()
            );
            nn_entries
                .push(BenchEntry { result: r, items_per_iter: Some(tokens) });
        }
    }

    // ---- transport: frame codec + one distributed TCP step ----
    let mut transport_entries: Vec<BenchEntry> = Vec::new();
    {
        use protomodels::compress;
        use protomodels::data::CorpusKind;
        use protomodels::nn::Optim;
        use protomodels::transport::frame::{FrameKind, WireFrame};

        let h = Hyper::tiny_native();
        let mut rng = Rng::new(21);
        let m = h.b * h.n;
        let payload_t =
            Tensor::new(vec![m, h.k], rng.normal_f32_vec(m * h.k, 1.0));
        let frame_bytes =
            protomodels::memory::transport_frame_bytes(&h, Mode::Subspace)
                as f64;
        let r = bench.run("transport_frame_encode", || {
            let cf = compress::encode(
                black_box(&payload_t),
                Mode::Subspace,
                h.ratio,
            );
            let wf = WireFrame::boundary(
                FrameKind::Fwd,
                Mode::Subspace,
                3,
                0,
                cf.payload,
            );
            black_box(wf.to_bytes().len());
        });
        println!(
            "    -> {:.2} MB/s framed",
            r.throughput(frame_bytes) / 1e6
        );
        transport_entries
            .push(BenchEntry { result: r, items_per_iter: Some(frame_bytes) });
        let r = bench.run("transport_roundtrip", || {
            // the full wire path: codec encode → frame → bytes → parse →
            // codec decode, exactly what one boundary hop costs
            let cf = compress::encode(
                black_box(&payload_t),
                Mode::Subspace,
                h.ratio,
            );
            let wf = WireFrame::boundary(
                FrameKind::Fwd,
                Mode::Subspace,
                3,
                0,
                cf.payload,
            );
            let bytes = wf.to_bytes();
            let parsed = WireFrame::read_from(&mut std::io::Cursor::new(
                bytes,
            ))
            .expect("frame parse");
            let back = compress::Frame {
                mode: Mode::Subspace,
                shape: vec![m, h.k],
                payload: parsed.payload,
            };
            black_box(compress::decode(&back).numel());
        });
        transport_entries
            .push(BenchEntry { result: r, items_per_iter: Some(frame_bytes) });
        // one synchronous distributed step over real loopback sockets,
        // session setup (listeners, handshake, init replay) included —
        // the end-to-end latency floor of the TCP transport
        let mut h2 = Hyper::tiny_native();
        h2.stages = 2;
        h2.layers = h2.blocks_per_stage * h2.stages;
        let spec = protomodels::transport::WorkerSpec {
            h: h2,
            cfg: protomodels::coordinator::PipelineConfig {
                mode: Mode::Subspace,
                microbatches: 2,
                grassmann_interval: 0,
                total_steps: 1,
                seed: 5,
                ..Default::default()
            },
            optim: Optim::AdamW,
            steps: 1,
            corpus_kind: CorpusKind::Wiki,
            corpus_tokens: 20_000,
        };
        let r = bench.run("transport_step_tcp", || {
            let rep = protomodels::transport::run_local(
                black_box(&spec),
                protomodels::transport::TransportKind::Tcp,
            )
            .expect("tcp distributed step");
            black_box(rep.losses.len());
        });
        transport_entries
            .push(BenchEntry { result: r, items_per_iter: None });

        // tracing cost on the same distributed step over in-process
        // channels: the off entry measures the disabled fast path (one
        // relaxed atomic load per span site), the on entry records
        // every span into an active session
        let r_off = bench.run("trace_overhead_off_step_channel", || {
            let rep = protomodels::transport::run_local(
                black_box(&spec),
                protomodels::transport::TransportKind::Channel,
            )
            .expect("channel distributed step");
            black_box(rep.losses.len());
        });
        let off_ns = r_off.mean_ns;
        transport_entries
            .push(BenchEntry { result: r_off, items_per_iter: None });
        let session = TraceSession::start(Clock::Host);
        let r_on = bench.run("trace_overhead_on_step_channel", || {
            let rep = protomodels::transport::run_local(
                black_box(&spec),
                protomodels::transport::TransportKind::Channel,
            )
            .expect("channel distributed step");
            black_box(rep.losses.len());
        });
        drop(session.stop());
        println!(
            "    -> tracing overhead: {:+.1}%",
            (r_on.mean_ns / off_ns - 1.0) * 100.0
        );
        transport_entries
            .push(BenchEntry { result: r_on, items_per_iter: None });

        // the dp gradient-reduce primitives, in process: the exact
        // codec arithmetic every grid hop runs (transport/dp.rs),
        // minus sockets — stable enough for a wall-time ceiling
        let n = 16_384usize;
        let template: Vec<Vec<f32>> =
            (0..4).map(|_| rng.normal_f32_vec(n, 1.0)).collect();
        for mode in [Mode::Raw, Mode::Subspace] {
            let name =
                format!("dp_allreduce_ring_{}_r4_16k", mode.as_str());
            let r = bench.run(&name, || {
                let mut flats = black_box(template.clone());
                protomodels::transport::ring_allreduce_local(
                    &mut flats, mode, h.d, h.k, h.ratio,
                )
                .expect("ring allreduce");
                black_box(flats[0][0]);
            });
            transport_entries
                .push(BenchEntry { result: r, items_per_iter: None });
        }
        let (ga, gb) = (template[0].clone(), template[1].clone());
        let r = bench.run("dp_allreduce_gossip_subspace_pair_16k", || {
            use protomodels::transport::dp::{decode_grad, encode_grad};
            let ea =
                encode_grad(Mode::Subspace, black_box(&ga), h.d, h.k, h.ratio)
                    .expect("encode");
            let eb =
                encode_grad(Mode::Subspace, black_box(&gb), h.d, h.k, h.ratio)
                    .expect("encode");
            let da =
                decode_grad(Mode::Subspace, &ea, ga.len(), h.d, h.k, h.ratio)
                    .expect("decode");
            let db =
                decode_grad(Mode::Subspace, &eb, gb.len(), h.d, h.k, h.ratio)
                    .expect("decode");
            let avg: f32 =
                da.iter().zip(&db).map(|(x, y)| 0.5 * (x + y)).sum();
            black_box(avg);
        });
        transport_entries
            .push(BenchEntry { result: r, items_per_iter: None });
    }

    // ---- serving: KV append, single decode steps, end-to-end serve ----
    let mut serve_entries: Vec<BenchEntry> = Vec::new();
    {
        use protomodels::nn::model::sinusoidal_pe;
        use protomodels::nn::{StageDecoder, StageKv};
        use protomodels::stage::{GlobalState, StageState};
        use protomodels::transport::{
            run_serve_local, ServeSpec, TrafficSpec,
        };

        let h = Hyper::tiny_native();
        // pure cache-append cost: one session filling its context
        let mut rng = Rng::new(9);
        let krow = rng.normal_f32_vec(h.d, 1.0);
        let vrow = rng.normal_f32_vec(h.d, 1.0);
        let r = bench.run("kv_append_tiny_full_context", || {
            let mut kv = StageKv::new(h.blocks_per_stage);
            for pos in 0..h.n {
                for b in &mut kv.blocks {
                    b.k.extend_from_slice(black_box(&krow));
                    b.v.extend_from_slice(black_box(&vrow));
                }
                kv.pos = pos + 1;
            }
            black_box(kv.bytes());
        });
        serve_entries.push(BenchEntry {
            result: r,
            items_per_iter: Some(h.n as f64),
        });

        // one decode step at a warm (16-row) prefix, stage 0
        for mode in [Mode::Subspace, Mode::Raw] {
            let mut rng = Rng::new(9);
            let global = GlobalState::from_hyper(&h, &mut rng);
            let st = StageState::from_schema(
                h.stage_schema(0),
                h.stage_kind(0),
                0,
                mode,
                &global,
                &mut rng,
            )
            .expect("stage init");
            let pe = sinusoidal_pe(h.n, h.d);
            let dec = StageDecoder {
                h: &h,
                mode,
                stage: 0,
                params: &st.params,
                u: &global.u,
                t_fixed: &global.t_fixed,
                pe: &pe,
            };
            let mut warm = StageKv::new(h.blocks_per_stage);
            for pos in 0..16 {
                dec.step(&mut warm, (pos % h.vocab) as u32, None)
                    .expect("warm decode");
            }
            let r = bench
                .run(&format!("decode_step_tiny_{}", mode.as_str()), || {
                    let mut kv = black_box(&warm).clone();
                    black_box(
                        dec.step(&mut kv, 7, None)
                            .expect("decode step")
                            .len(),
                    );
                });
            serve_entries
                .push(BenchEntry { result: r, items_per_iter: None });
        }

        // end-to-end single-process serving run: batcher, per-session
        // codecs, pricing asserts, the lot
        let spec = ServeSpec::builder(h.clone())
            .mode(Mode::Subspace)
            .steps(200)
            .seed(9)
            .corpus(CorpusKind::Wiki, 10_000)
            .traffic(TrafficSpec {
                sessions: 3,
                mean_gap: 1.0,
                prompt: (3, 5),
                gen: (3, 4),
            })
            .max_batch(2)
            .build()
            .expect("serve spec");
        let r = bench.run("decode_serve_local_tiny_subspace", || {
            let rep =
                run_serve_local(black_box(&spec)).expect("serve run");
            black_box(rep.tokens_generated);
        });
        serve_entries.push(BenchEntry { result: r, items_per_iter: None });
    }

    if json {
        write_json(out.join("BENCH_linalg.json"), "linalg", &linalg_entries)?;
        write_json(
            out.join("BENCH_pipeline.json"),
            "pipeline",
            &pipe_entries,
        )?;
        write_json(out.join("BENCH_nn.json"), "nn", &nn_entries)?;
        write_json(
            out.join("BENCH_transport.json"),
            "transport",
            &transport_entries,
        )?;
        write_json(out.join("BENCH_serve.json"), "serve", &serve_entries)?;
    }
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
    }
    let flags = Flags::parse(&args[1..])?;
    // global thread budget: experiment pools and the threaded linalg
    // kernels both key off this (0 = all available cores)
    par::set_max_threads(flags.usize("threads", 0)?);
    match args[0].as_str() {
        "train" => cmd_train(&flags),
        "serve" => cmd_serve(&flags),
        "serve-infer" => cmd_serve_infer(&flags),
        "sim" => cmd_sim(&flags),
        "inspect" => cmd_inspect(&flags),
        "timing" => cmd_timing(&flags),
        "trace" => cmd_trace(&flags),
        "exp" => {
            let name = flags
                .positional
                .first()
                .map(|s| s.to_string())
                .unwrap_or_else(|| usage());
            let opts = ExpOpts {
                artifacts: flags.str("artifacts", "artifacts").into(),
                out_dir: flags.str("out", "results").into(),
                fast: flags.switch("fast"),
                steps: flags.opt("steps").map(|s| s.parse()).transpose()?,
                seed: flags.usize("seed", 17)? as u64,
                threads: flags.usize("threads", 0)?,
                exact_rank: flags.switch("exact-rank"),
            };
            exp::run(&name, &opts)
        }
        "bench" => cmd_bench(&flags),
        "help" | "--help" | "-h" => usage(),
        other => bail!("unknown subcommand {other:?} (try --help)"),
    }
}
