//! Scoped thread pool for experiment grids and linalg kernels (no
//! external crates).
//!
//! Design contract (DESIGN.md §8):
//!
//! - **Deterministic work assignment.** Job *results* are collected in
//!   submission order ([`map`] / [`try_map`]), and per-cell randomness is
//!   derived from `(master_seed, cell_index)` only ([`cell_seed`]), never
//!   from pool size or execution interleaving. A grid driver built on
//!   this module therefore emits byte-identical CSVs at `--threads 1`
//!   and `--threads N`.
//! - **No nested oversubscription.** Pool workers carry a thread-local
//!   kernel budget — their fair share `max_threads() / workers` of the
//!   global budget; the threaded linalg kernels consult
//!   [`kernel_threads`], so a grid of jobs never multiplies by the
//!   kernels' own parallelism, yet a grid with fewer cells than cores
//!   still uses the whole machine.
//! - **Scoped threads only.** Workers are `std::thread::scope` children
//!   of the submitting call: no detached state, panics propagate to the
//!   caller, and non-`Send` values (e.g. a PJRT `Runtime`) can be
//!   constructed and dropped entirely inside one worker.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

/// Global thread budget set from the CLI (`--threads N`); 0 = auto
/// (use [`available`]).
static MAX_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Serializes unit tests that temporarily mutate [`MAX_THREADS`] —
/// cargo's harness runs tests of one binary concurrently.
#[cfg(test)]
pub(crate) static TEST_THREADS_LOCK: Mutex<()> = Mutex::new(());

thread_local! {
    /// Kernel-thread budget granted to the current pool worker
    /// (0 = this thread is not a pool worker).
    static WORKER_KERNEL_BUDGET: Cell<usize> = Cell::new(0);
}

/// Hardware parallelism of this host (≥ 1).
pub fn available() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Set the global thread budget (0 = auto). Wired to `--threads`.
pub fn set_max_threads(n: usize) {
    MAX_THREADS.store(n, Ordering::Relaxed);
}

/// The effective global thread budget (≥ 1).
pub fn max_threads() -> usize {
    let n = MAX_THREADS.load(Ordering::Relaxed);
    if n == 0 {
        available()
    } else {
        n.max(1)
    }
}

/// The raw configured budget (0 = auto). Lets callers save and restore
/// the setting without resolving the auto default to a pinned count.
pub fn max_threads_setting() -> usize {
    MAX_THREADS.load(Ordering::Relaxed)
}

/// Whether the calling thread is a pool worker.
pub fn in_worker() -> bool {
    WORKER_KERNEL_BUDGET.with(|b| b.get()) != 0
}

/// Thread budget for *kernel-internal* parallelism. Inside a pool
/// worker this is the worker's granted share of the global budget
/// (`max_threads() / workers`, ≥ 1) — a 3-cell grid on a 16-core host
/// still drives 15 cores instead of pinning each cell to one — and the
/// full global budget otherwise. Kernels must produce identical
/// results for every budget, so this only shifts wall-clock.
pub fn kernel_threads() -> usize {
    let granted = WORKER_KERNEL_BUDGET.with(|b| b.get());
    if granted != 0 {
        granted
    } else {
        max_threads()
    }
}

/// Deterministic per-cell seed: a SplitMix64-style finalizer over
/// `(master, index)`. Depends only on the pair — stable under pool-size
/// changes, execution order, and driver refactors that keep cell order.
pub fn cell_seed(master: u64, index: usize) -> u64 {
    let mut z = master
        ^ (index as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Run `f(index, &items[index])` for every item on up to `threads`
/// scoped workers and return the results **in input order**, regardless
/// of which worker finished first. Work is pulled from a shared atomic
/// counter (dynamic load balancing — cells of a grid can differ in cost
/// by orders of magnitude). Worker panics propagate to the caller.
pub fn map<T, R, F>(threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    // each worker's fair share of the *caller's* budget, for nested
    // kernels: from the main thread that is the global budget; from
    // inside a pool worker (e.g. the tape's data-parallel ops running
    // in a grid cell) it is the worker's granted share, so nesting
    // divides the budget instead of multiplying the thread count
    let kernel_budget = (kernel_threads() / threads).max(1);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                WORKER_KERNEL_BUDGET.with(|b| b.set(kernel_budget));
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = f(i, &items[i]);
                    *slots[i].lock().unwrap() = Some(r);
                }
                WORKER_KERNEL_BUDGET.with(|b| b.set(0));
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap()
                .expect("pool worker left a result slot empty")
        })
        .collect()
}

/// [`map`] over fallible jobs. Every cell runs (no early cancellation —
/// jobs may hold partially-written per-cell outputs); the *first error
/// in input order* is returned, so the reported failure is deterministic
/// under any interleaving.
pub fn try_map<T, R, F>(threads: usize, items: &[T], f: F) -> Result<Vec<R>>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> Result<R> + Sync,
{
    let results = map(threads, items, f);
    let mut out = Vec::with_capacity(results.len());
    for r in results {
        out.push(r?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order_across_pool_sizes() {
        let items: Vec<usize> = (0..57).collect();
        let serial: Vec<usize> =
            items.iter().map(|x| x * x + 1).collect();
        for threads in [1usize, 2, 3, 4, 8, 16] {
            let got = map(threads, &items, |i, x| {
                assert_eq!(i, *x);
                x * x + 1
            });
            assert_eq!(got, serial, "threads={threads}");
        }
    }

    #[test]
    fn map_handles_empty_and_single() {
        let empty: Vec<u32> = Vec::new();
        assert!(map(4, &empty, |_, x| *x).is_empty());
        assert_eq!(map(4, &[7u32], |_, x| x + 1), vec![8]);
    }

    #[test]
    fn try_map_returns_first_error_by_index() {
        let items: Vec<usize> = (0..40).collect();
        for threads in [1usize, 4] {
            let err = try_map(threads, &items, |_, x| {
                if *x == 13 || *x == 31 {
                    anyhow::bail!("cell {x} failed")
                }
                Ok(*x)
            })
            .unwrap_err();
            assert_eq!(err.to_string(), "cell 13 failed");
        }
        let ok = try_map(3, &items[..5], |_, x| Ok(*x)).unwrap();
        assert_eq!(ok, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn cell_seed_stable_and_spread() {
        // depends only on (master, index): recomputing under any "pool
        // size" is the identity — the API has no pool input at all
        assert_eq!(cell_seed(17, 3), cell_seed(17, 3));
        // distinct across indices and masters
        let seeds: Vec<u64> = (0..64).map(|i| cell_seed(17, i)).collect();
        let mut uniq = seeds.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), seeds.len());
        assert_ne!(cell_seed(17, 0), cell_seed(18, 0));
    }

    #[test]
    fn workers_are_flagged_and_main_is_not() {
        assert!(!in_worker());
        let flags = map(4, &[0u8; 16], |_, _| in_worker());
        assert!(flags.iter().all(|f| *f));
        assert!(!in_worker());
    }

    #[test]
    fn nested_map_divides_the_worker_budget() {
        // an outer 2-way map on a budget of 8 grants 4 per worker; a
        // nested 2-way map inside a worker must grant 2 per inner
        // worker — dividing the caller's share, never re-reading the
        // global budget (which would oversubscribe 2×2×4 threads)
        let _guard = TEST_THREADS_LOCK.lock().unwrap();
        let before = max_threads_setting();
        set_max_threads(8);
        let budgets = map(2, &[0u8; 2], |_, _| {
            map(2, &[0u8; 2], |_, _| kernel_threads())
        });
        set_max_threads(before);
        for inner in budgets {
            assert_eq!(inner, vec![2, 2], "nested budgets {inner:?}");
        }
    }

    #[test]
    fn worker_kernel_budget_is_fair_share() {
        // a 2-cell grid must not pin each worker's kernels to 1 thread
        // when the budget allows more
        let _guard = TEST_THREADS_LOCK.lock().unwrap();
        let before = max_threads_setting();
        set_max_threads(8);
        let budgets = map(2, &[0u8; 2], |_, _| kernel_threads());
        set_max_threads(before);
        assert!(budgets.iter().all(|b| *b == 4), "budgets {budgets:?}");
    }
}
