//! Elastic churn-tolerant distributed training (DESIGN.md §12).
//!
//! The classic distributed pipeline ([`super::dist`]) treats a vanished
//! worker as a terminal error. This module converts that into a
//! **bounded recovery event**:
//!
//! - every worker sends [`FrameKind::Heartbeat`] frames on a control
//!   link to the supervisor/leader at a step cadence, and every receive
//!   in the data plane is bounded by a stale timeout — total silence
//!   past the deadline surfaces as a departure, never a hang;
//! - every worker ships a compressed checkpoint of its stage state
//!   ([`crate::compress::ckpt`]) at a step-boundary cadence, priced by
//!   [`crate::memory::checkpoint_payload_bytes`] against the same
//!   `dp_wire_bytes` vocabulary the paper's DP sync uses;
//! - when an epoch fails (a scripted chaos kill, an injected fault, or
//!   a real dead peer), the supervisor reassigns the lost stage — to a
//!   spare, or to the restarted process, both of which rebuild the
//!   seeded init stream deterministically — and resumes **all** stages
//!   from the newest step boundary whose checkpoints are complete;
//! - because the checkpoint boundary is a full-pipeline synchronization
//!   point and the data RNG forks are replayed per step, a `Raw`-codec
//!   recovery rejoins the no-churn loss curve **bitwise**, and a
//!   `Coeff`-codec recovery rejoins within float-rounding of the
//!   subspace projection — the recovery parity contract `tests/chaos.rs`
//!   enforces against the envelope `sim/swarm.rs` predicts on the same
//!   churn timeline.
//!
//! Failure detection is deliberately epoch-grained: any departure tears
//! down the whole epoch (errors cascade along the dropped links, and
//! every receive is stale-bounded, so teardown terminates), and recovery
//! restarts the full chain from the checkpoint boundary. That trades a
//! few recomputed steps for a protocol with no partial-pipeline state
//! machine — the same trade the swarm simulator's churn model makes.

use std::collections::{BTreeMap, HashSet};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::compress::CkptCodec;
use crate::sim::{ChurnKind, ChurnTimeline};

use super::dist::{
    chain_ends, run_stage_inner, DistReport, TransportKind, WorkerReport,
    WorkerSpec,
};
use super::dp::{ElasticOpts, TrainSpec};
use super::fault::{FaultPlan, FaultTransport, LinkSide};
use super::frame::{FrameKind, WireFrame};
use super::{channel_pair, TcpTransport, Transport};

// ---------------------------------------------------------------------------
// wire codecs: heartbeat payloads and reassignment orders
// ---------------------------------------------------------------------------

/// Encode a heartbeat payload: the sender's last started step and its
/// local monotonic clock in ms, both u64 LE — 16 bytes, the figure
/// [`crate::memory::heartbeat_payload_bytes`] prices. The clock is
/// informational only: liveness is judged on the *receiver's* arrival
/// clock, so a skewed sender cannot trip (or mask) staleness.
pub fn heartbeat_payload(step: u64, clock_ms: u64) -> Vec<u8> {
    let mut p = Vec::with_capacity(16);
    p.extend_from_slice(&step.to_le_bytes());
    p.extend_from_slice(&clock_ms.to_le_bytes());
    p
}

/// Decode a heartbeat payload back to `(step, clock_ms)`.
pub fn parse_heartbeat(payload: &[u8]) -> Result<(u64, u64)> {
    if payload.len() != 16 {
        bail!(
            "heartbeat payload is {} B (expected exactly 16)",
            payload.len()
        );
    }
    Ok((
        u64::from_le_bytes(payload[0..8].try_into().expect("8 B")),
        u64::from_le_bytes(payload[8..16].try_into().expect("8 B")),
    ))
}

/// Sentinel stage in a [`ReassignOrder`] meaning "the run is complete —
/// shut down cleanly" (no real pipeline has 2^32 − 1 stages).
pub const REASSIGN_DONE: u32 = u32::MAX;

/// The payload of a [`FrameKind::Reassign`] control frame: the leader's
/// order to one actor to run one stage for one epoch, resuming from a
/// checkpointed boundary.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ReassignOrder {
    /// recovery epoch this order starts (0 = the first attempt)
    pub epoch: u32,
    /// stage to run, or [`REASSIGN_DONE`]
    pub stage: u32,
    /// step boundary to resume from (0 = fresh start)
    pub resume: u64,
    /// scripted chaos kill this worker must execute during this epoch
    /// (the step at which it dies). Scheduled by the *leader*, which
    /// owns the fired-kill bookkeeping — that is what lets multi-process
    /// chaos honor kill scripts in any epoch, not just the first: a
    /// replacement actor enrolling fresh cannot know which kills already
    /// fired, but the leader does.
    pub kill_at: Option<u64>,
    /// the stage's checkpoint blob at `resume` (required when
    /// `resume > 0`)
    pub ckpt: Option<Vec<u8>>,
}

impl ReassignOrder {
    /// The shutdown order: the run completed, actors may exit.
    pub fn done(epoch: u32) -> ReassignOrder {
        ReassignOrder {
            epoch,
            stage: REASSIGN_DONE,
            resume: 0,
            kill_at: None,
            ckpt: None,
        }
    }

    /// True for the shutdown order.
    pub fn is_done(&self) -> bool {
        self.stage == REASSIGN_DONE
    }

    /// Serialize: epoch u32, stage u32, resume u64, has-kill u8,
    /// kill step u64, has-ckpt u8, blob len u64, blob bytes — all LE.
    pub fn encode(&self) -> Vec<u8> {
        let blob = self.ckpt.as_deref().unwrap_or(&[]);
        let mut out = Vec::with_capacity(34 + blob.len());
        out.extend_from_slice(&self.epoch.to_le_bytes());
        out.extend_from_slice(&self.stage.to_le_bytes());
        out.extend_from_slice(&self.resume.to_le_bytes());
        out.push(u8::from(self.kill_at.is_some()));
        out.extend_from_slice(&self.kill_at.unwrap_or(0).to_le_bytes());
        out.push(u8::from(self.ckpt.is_some()));
        out.extend_from_slice(&(blob.len() as u64).to_le_bytes());
        out.extend_from_slice(blob);
        out
    }

    /// Parse an encoded order, validating the length envelope.
    pub fn decode(bytes: &[u8]) -> Result<ReassignOrder> {
        if bytes.len() < 34 {
            bail!(
                "reassign order is {} B, shorter than the 34 B header",
                bytes.len()
            );
        }
        let epoch = u32::from_le_bytes(bytes[0..4].try_into().expect("u32"));
        let stage = u32::from_le_bytes(bytes[4..8].try_into().expect("u32"));
        let resume = u64::from_le_bytes(bytes[8..16].try_into().expect("u64"));
        let has_kill = bytes[16] == 1;
        let kill =
            u64::from_le_bytes(bytes[17..25].try_into().expect("u64"));
        let has_ckpt = bytes[25] == 1;
        let blob_len =
            u64::from_le_bytes(bytes[26..34].try_into().expect("u64")) as usize;
        if bytes.len() != 34 + blob_len {
            bail!(
                "reassign order declares a {blob_len} B checkpoint but \
                 carries {} trailing bytes",
                bytes.len() - 34
            );
        }
        Ok(ReassignOrder {
            epoch,
            stage,
            resume,
            kill_at: has_kill.then_some(kill),
            ckpt: has_ckpt.then(|| bytes[34..].to_vec()),
        })
    }
}

// ---------------------------------------------------------------------------
// liveness
// ---------------------------------------------------------------------------

/// Stale-timeout liveness detection over one link. Staleness is judged
/// **only** on the local arrival clock: the deadline is `last frame's
/// arrival + stale`, a peer is stale strictly *after* the deadline
/// (exactly-at-deadline is alive), and the `clock_ms` a heartbeat
/// carries never feeds the decision — so a clock-skewed sender can
/// neither trip nor mask the timeout (DESIGN.md §12).
pub struct LivenessMonitor {
    stale: Duration,
    last_seen: Instant,
    /// highest step any observed heartbeat reported
    pub last_step: u64,
    /// heartbeat frames observed
    pub beats: u64,
}

impl LivenessMonitor {
    /// Start monitoring now, with the given stale timeout.
    pub fn new(stale: Duration) -> LivenessMonitor {
        LivenessMonitor {
            stale,
            last_seen: Instant::now(),
            last_step: 0,
            beats: 0,
        }
    }

    /// Record one received frame: *any* frame refreshes the deadline
    /// (bulk traffic proves liveness as well as chatter does); a
    /// well-formed heartbeat additionally updates the step/beat stats.
    pub fn observe(&mut self, frame: &WireFrame) {
        self.last_seen = Instant::now();
        if frame.kind == FrameKind::Heartbeat {
            if let Ok((step, _clock_ms)) = parse_heartbeat(&frame.payload) {
                self.last_step = self.last_step.max(step);
                self.beats += 1;
            }
        }
    }

    /// The instant after which the peer counts as departed.
    pub fn deadline(&self) -> Instant {
        self.last_seen + self.stale
    }

    /// Staleness at an explicit instant — strictly after the deadline,
    /// so a heartbeat landing exactly on it keeps the peer alive.
    pub fn is_stale_at(&self, now: Instant) -> bool {
        now > self.deadline()
    }

    /// Staleness now.
    pub fn is_stale(&self) -> bool {
        self.is_stale_at(Instant::now())
    }
}

/// One bounded, liveness-aware receive: waits until the monitor's
/// deadline, feeds every arrival to the monitor, and yields `Ok(None)`
/// for heartbeats (callers loop) or quiet timeouts that have not yet
/// crossed the deadline. Total silence past the deadline — and only
/// that — comes back as a `"departed"` error.
pub fn recv_live(
    conn: &mut dyn Transport,
    mon: &mut LivenessMonitor,
) -> Result<Option<WireFrame>> {
    let now = Instant::now();
    let stale_err = |mon: &LivenessMonitor| {
        anyhow!(
            "worker departed: stale liveness timeout — no frame or \
             heartbeat for over {} ms (last heartbeat reported step {})",
            mon.stale.as_millis(),
            mon.last_step
        )
    };
    if mon.is_stale_at(now) {
        return Err(stale_err(mon));
    }
    let wait = mon.deadline().saturating_duration_since(now);
    match conn.recv_timeout(wait)? {
        None => {
            if mon.is_stale() {
                return Err(stale_err(mon));
            }
            Ok(None)
        }
        Some(f) => {
            mon.observe(&f);
            if f.kind == FrameKind::Heartbeat {
                Ok(None)
            } else {
                Ok(Some(f))
            }
        }
    }
}

// ---------------------------------------------------------------------------
// elastic run configuration
// ---------------------------------------------------------------------------

/// Per-worker elastic context, handed into the stage loop: where to
/// resume, what to restore, the liveness/checkpoint cadences, and — in
/// chaos runs — when to die.
#[derive(Clone, Debug)]
pub struct ElasticCtx {
    /// first step to train (0 = fresh start)
    pub resume_step: u64,
    /// checkpoint blob to restore (required when `resume_step > 0`)
    pub ckpt: Option<Vec<u8>>,
    /// ship a checkpoint every this many steps (≥ 1)
    pub ckpt_every: u64,
    /// checkpoint parameter codec
    pub ckpt_codec: CkptCodec,
    /// send a heartbeat every this many steps (≥ 1)
    pub heartbeat_every: u64,
    /// stale liveness timeout bounding every data-plane receive
    pub stale_ms: u64,
    /// scripted chaos: abruptly leave at the top of this step
    pub kill_at: Option<u64>,
}

/// Configuration of one elastic run: the worker spec everything else is
/// derived from, plus the liveness/checkpoint cadences, the spare
/// budget, and the chaos inputs (churn timeline + fault plan).
#[derive(Clone, Debug)]
pub struct ElasticSpec {
    /// the run every stage executes (model, data, schedule, steps)
    pub worker: WorkerSpec,
    /// checkpoint cadence in steps (≥ 1)
    pub ckpt_every: u64,
    /// checkpoint parameter codec (`raw` = bitwise recovery, `coeff` =
    /// subspace-priced recovery)
    pub ckpt_codec: CkptCodec,
    /// heartbeat cadence in steps (≥ 1)
    pub heartbeat_every: u64,
    /// stale liveness timeout in ms — set it above the slowest step
    pub stale_ms: u64,
    /// spare workers standing by to adopt a dead stage
    pub spares: usize,
    /// scripted churn timeline (`kill:W@S,join:W@S`)
    pub chaos: ChurnTimeline,
    /// deterministic link-fault plan (drops / delays / severs)
    pub faults: FaultPlan,
    /// recovery attempts before the run is declared unrecoverable
    pub max_epochs: usize,
}

impl ElasticSpec {
    /// Defaults around a worker spec: checkpoint four times per run,
    /// heartbeat every step, 5 s stale timeout, one spare, no chaos.
    pub fn new(worker: WorkerSpec) -> ElasticSpec {
        let ckpt_every = (worker.steps as u64 / 4).max(1);
        ElasticSpec {
            worker,
            ckpt_every,
            ckpt_codec: CkptCodec::Raw,
            heartbeat_every: 1,
            stale_ms: 5_000,
            spares: 1,
            chaos: ChurnTimeline::default(),
            faults: FaultPlan::default(),
            max_epochs: 8,
        }
    }

    /// Reject configurations the elastic runtime cannot execute.
    pub fn validate(&self) -> Result<()> {
        self.worker.validate()?;
        if self.ckpt_every == 0 {
            bail!("--ckpt-every must be >= 1");
        }
        if self.heartbeat_every == 0 {
            bail!("--hb-every must be >= 1");
        }
        if self.stale_ms == 0 {
            bail!("--stale-ms must be >= 1");
        }
        if self.max_epochs == 0 {
            bail!("max epochs must be >= 1");
        }
        self.chaos
            .validate(self.worker.h.stages, self.worker.steps as u64)
            .context("validating the --chaos timeline")?;
        Ok(())
    }
}

/// What an elastic run reports beyond the classic [`DistReport`]: the
/// recovery history and the liveness/checkpoint wire accounting the
/// chaos tests assert against the `memory.rs` cost model.
#[derive(Clone, Debug)]
pub struct ElasticReport {
    /// per-step mean training loss, stitched across epochs — steps
    /// recomputed after a recovery keep their *final* (post-recovery)
    /// value, which the parity contract compares to the no-churn curve
    pub losses: Vec<f64>,
    /// epochs executed (1 = no recovery was needed)
    pub epochs: usize,
    /// recovery events (epochs that failed)
    pub recoveries: usize,
    /// the step boundary each recovery resumed from
    pub resume_steps: Vec<u64>,
    /// spares consumed by permanent departures
    pub spares_used: usize,
    /// checkpoint frames shipped on control links, all epochs
    pub ckpt_frames: u64,
    /// checkpoint payload bytes shipped, all epochs — equals
    /// `ckpt_frames / stages` complete boundaries priced by
    /// [`crate::memory::checkpoint_payload_bytes`]
    pub ckpt_bytes: u64,
    /// heartbeat frames shipped on control links, all epochs
    pub heartbeat_frames: u64,
    /// heartbeat payload bytes shipped — `16 ×` the frame count
    pub heartbeat_bytes: u64,
    /// the data-plane report of the epoch that completed (recovery
    /// epochs that failed ship no worker reports)
    pub dist: DistReport,
}

// ---------------------------------------------------------------------------
// control-plane bookkeeping shared by both supervisors
// ---------------------------------------------------------------------------

/// Everything the supervisor accumulates from control links: checkpoint
/// blobs by boundary, the stitched loss curve, and the wire counters.
#[derive(Default)]
struct CtlStore {
    /// boundary step → per-stage checkpoint blobs (a boundary is usable
    /// only when every slot is `Some`)
    ckpts: BTreeMap<u64, Vec<Option<Vec<u8>>>>,
    /// per-step mean loss relayed by stage 0
    losses: Vec<Option<f64>>,
    /// (frames, payload bytes) of heartbeats seen
    hb: (u64, u64),
    /// (frames, payload bytes) of checkpoints seen
    ck: (u64, u64),
}

impl CtlStore {
    fn with_steps(steps: usize) -> CtlStore {
        CtlStore { losses: vec![None; steps], ..CtlStore::default() }
    }

    /// Record one control frame from `stage`.
    fn record(&mut self, stage: usize, p: usize, f: WireFrame) {
        match f.kind {
            FrameKind::Heartbeat => {
                self.hb.0 += 1;
                self.hb.1 += f.payload.len() as u64;
            }
            FrameKind::Checkpoint => {
                self.ck.0 += 1;
                self.ck.1 += f.payload.len() as u64;
                let row = self
                    .ckpts
                    .entry(f.step)
                    .or_insert_with(|| vec![None; p]);
                if stage < row.len() {
                    row[stage] = Some(f.payload);
                }
            }
            FrameKind::StepEnd => {
                if f.payload.len() >= 8 {
                    let idx = f.step as usize;
                    if idx < self.losses.len() {
                        self.losses[idx] = Some(f64::from_le_bytes(
                            f.payload[0..8].try_into().expect("8 B"),
                        ));
                    }
                }
            }
            _ => {}
        }
    }

    /// Newest boundary whose checkpoints are complete across all stages.
    fn best_boundary(&self) -> u64 {
        self.ckpts
            .iter()
            .rev()
            .find(|(_, row)| row.iter().all(Option::is_some))
            .map(|(step, _)| *step)
            .unwrap_or(0)
    }

    /// The stitched loss curve — every step must have reported.
    fn full_losses(&self) -> Result<Vec<f64>> {
        self.losses
            .iter()
            .enumerate()
            .map(|(i, l)| {
                l.ok_or_else(|| {
                    anyhow!("step {i} never reported a loss to the supervisor")
                })
            })
            .collect()
    }
}

/// Drain every frame already queued on a control link (the worker has
/// exited, so this terminates: buffered frames, then disconnect).
fn drain_ctl(ctl: &mut dyn Transport, stage: usize, p: usize, store: &mut CtlStore) {
    while let Ok(Some(f)) = ctl.recv_timeout(Duration::from_millis(1)) {
        store.record(stage, p, f);
    }
}

/// The scripted kill step for each stage this epoch: the earliest
/// not-yet-fired `kill` event per worker.
fn kills_this_epoch(
    chaos: &ChurnTimeline,
    p: usize,
    fired: &HashSet<(usize, u64)>,
) -> Vec<Option<u64>> {
    (0..p)
        .map(|s| {
            chaos
                .events
                .iter()
                .filter(|e| {
                    e.kind == ChurnKind::Leave
                        && e.worker == s
                        && !fired.contains(&(s, e.step))
                })
                .map(|e| e.step)
                .min()
        })
        .collect()
}

/// Whether a scripted `join` covers a kill of `stage` at `step` — i.e.
/// the same worker restarts, so no spare is consumed.
fn rejoin_covers(chaos: &ChurnTimeline, stage: usize, step: u64) -> bool {
    chaos
        .events
        .iter()
        .any(|e| e.kind == ChurnKind::Rejoin && e.worker == stage && e.step >= step)
}

// ---------------------------------------------------------------------------
// in-process elastic supervisor
// ---------------------------------------------------------------------------

/// Run the full elastic pipeline locally — a thin shim over the one
/// in-process entry point [`super::launch`]: the elastic knobs nest
/// inside the [`TrainSpec`] as [`ElasticOpts`], and `launch` routes a
/// spec that carries them back to the elastic runtime. Kept for callers
/// that already think in [`ElasticSpec`].
pub fn run_elastic(es: &ElasticSpec, kind: TransportKind) -> Result<ElasticReport> {
    es.validate()?;
    let spec = to_train_spec(es);
    let report = super::launch(&spec.topology(kind), &spec)?;
    match report.elastic {
        Some(er) => Ok(*er),
        None => bail!("launch dropped the elastic report"),
    }
}

/// The elastic supervisor body behind [`run_elastic`] / [`super::launch`]:
/// P stage workers on OS threads joined by the chosen transport, a
/// control link per worker, and a supervisor that detects failed
/// epochs, accounts the scripted churn (consuming spares for permanent
/// departures), and resumes everyone from the newest complete
/// checkpoint boundary. Fault schedules from `spec.faults` wrap the
/// matching link ends with [`FaultTransport`].
pub(crate) fn run_elastic_impl(
    es: &ElasticSpec,
    kind: TransportKind,
) -> Result<ElasticReport> {
    es.validate()?;
    let spec = &es.worker;
    let p = spec.h.stages;
    let mut store = CtlStore::with_steps(spec.steps);
    let mut fired: HashSet<(usize, u64)> = HashSet::new();
    let mut spares_left = es.spares;
    let mut spares_used = 0usize;
    let mut resume = 0u64;
    let mut recoveries = 0usize;
    let mut resume_steps = Vec::new();

    for epoch in 0..es.max_epochs {
        let kill_at = kills_this_epoch(&es.chaos, p, &fired);
        let blobs: Vec<Option<Vec<u8>>> = if resume > 0 {
            store
                .ckpts
                .get(&resume)
                .cloned()
                .expect("best_boundary returned a stored boundary")
        } else {
            vec![None; p]
        };

        // fresh chain, optionally fault-wrapped on the scheduled ends
        let mut ends = chain_ends(p, kind)?;
        for (stage, end) in ends.iter_mut().enumerate() {
            for (side, slot) in
                [(LinkSide::Left, &mut end.0), (LinkSide::Right, &mut end.1)]
            {
                if let Some(sched) = es.faults.schedule_for(epoch, stage, side) {
                    if let Some(inner) = slot.take() {
                        *slot = Some(Box::new(FaultTransport::new(inner, sched)));
                    }
                }
            }
        }

        // one control link per worker; the supervisor keeps one half
        let mut worker_ctl = Vec::with_capacity(p);
        let mut sup_ctl = Vec::with_capacity(p);
        for _ in 0..p {
            let (w, s) = channel_pair();
            worker_ctl.push(w);
            sup_ctl.push(s);
        }
        let ctxs: Vec<ElasticCtx> = (0..p)
            .map(|s| ElasticCtx {
                resume_step: resume,
                ckpt: blobs[s].clone(),
                ckpt_every: es.ckpt_every,
                ckpt_codec: es.ckpt_codec,
                heartbeat_every: es.heartbeat_every,
                stale_ms: es.stale_ms,
                kill_at: kill_at[s],
            })
            .collect();

        let results: Vec<Result<WorkerReport>> = std::thread::scope(|scope| {
            let handles: Vec<_> = ends
                .drain(..)
                .zip(worker_ctl.drain(..))
                .zip(ctxs.iter())
                .enumerate()
                .map(|(stage, (((left, right), mut ctl), ctx))| {
                    let spec = spec.clone();
                    scope.spawn(move || {
                        run_stage_inner(
                            &spec,
                            stage,
                            left,
                            right,
                            Some(&mut ctl as &mut dyn Transport),
                            Some(ctx),
                            None,
                        )
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| match h.join() {
                    Ok(r) => r,
                    Err(_) => Err(anyhow!("stage worker panicked")),
                })
                .collect()
        });

        // harvest everything the epoch's control links carried (the
        // workers have exited, so the queues are final)
        for (stage, ctl) in sup_ctl.iter_mut().enumerate() {
            drain_ctl(ctl, stage, p, &mut store);
        }

        if results.iter().all(Result::is_ok) {
            let mut stage0: Option<WorkerReport> = None;
            let mut boundary = 0u64;
            let mut wire = 0u64;
            let mut frames = 0u64;
            for (stage, r) in results.into_iter().enumerate() {
                let r = r.expect("checked all_ok");
                boundary += r.boundary_payload_bytes;
                wire += r.wire_bytes;
                frames += r.frames_sent;
                if stage == 0 {
                    stage0 = Some(r);
                }
            }
            let stage0 = stage0.expect("stage 0 report");
            let losses = store.full_losses()?;
            return Ok(ElasticReport {
                losses: losses.clone(),
                epochs: epoch + 1,
                recoveries,
                resume_steps,
                spares_used,
                ckpt_frames: store.ck.0,
                ckpt_bytes: store.ck.1,
                heartbeat_frames: store.hb.0,
                heartbeat_bytes: store.hb.1,
                dist: DistReport {
                    losses,
                    step_seconds: stage0.step_seconds,
                    boundary_payload_bytes: boundary,
                    wire_bytes: wire,
                    frames,
                    frame_payload_bytes: spec.cfg.boundary_bytes(&spec.h),
                    dp_payload_bytes: 0,
                },
            });
        }

        // ---- recovery: account the epoch's scripted kills, consume a
        // spare for permanent departures, pick the resume boundary
        recoveries += 1;
        for (stage, r) in results.iter().enumerate() {
            let Err(e) = r else { continue };
            if !format!("{e:#}").contains("chaos kill") {
                continue;
            }
            let k = kill_at[stage].expect("scripted kill fired");
            fired.insert((stage, k));
            if !rejoin_covers(&es.chaos, stage, k) {
                if spares_left == 0 {
                    bail!(
                        "stage {stage} left permanently at step {k} and no \
                         spare remains — unrecoverable churn"
                    );
                }
                spares_left -= 1;
                spares_used += 1;
            }
        }
        resume = store.best_boundary();
        resume_steps.push(resume);
    }
    bail!(
        "elastic run did not complete within {} epochs — the churn/fault \
         schedule outpaces the checkpoint cadence",
        es.max_epochs
    )
}

// ---------------------------------------------------------------------------
// standalone elastic processes (`serve --elastic`, `serve --spare`)
// ---------------------------------------------------------------------------

/// Dial/accept budgets mirroring the classic `serve_stage` worker.
const DIAL_ATTEMPTS: usize = 120;
const DIAL_BACKOFF_MS: u64 = 250;
/// How long a bound chain listener waits for its right neighbor.
const ACCEPT_WAIT_MS: u64 = DIAL_ATTEMPTS as u64 * DIAL_BACKOFF_MS;
/// Idle actors ping the leader at this cadence while awaiting orders.
const IDLE_HEARTBEAT_MS: u64 = 200;

/// The control-plane port is `port_base`; chain link `link` of recovery
/// epoch `epoch` lives at `port_base + 1 + epoch·(P−1) + link` — every
/// epoch gets fresh ports so stale half-open sockets from a torn-down
/// epoch can never be dialed by the next one.
fn chain_port(port_base: u16, epoch: usize, link: usize, p: usize) -> Result<u16> {
    let off = 1 + epoch * (p - 1) + link;
    u16::try_from(port_base as usize + off).map_err(|_| {
        anyhow!(
            "port budget exceeded: base {port_base} + offset {off} \
             overflows u16 (lower the port base or max epochs)"
        )
    })
}

/// Dial with retries so process launch order is free.
fn dial_retry(host: &str, port: u16, what: &str) -> Result<TcpStream> {
    for attempt in 0..DIAL_ATTEMPTS {
        match TcpStream::connect((host, port)) {
            Ok(s) => return Ok(s),
            Err(e) if attempt + 1 == DIAL_ATTEMPTS => {
                return Err(e).with_context(|| {
                    format!("{what} never appeared at {host}:{port}")
                });
            }
            Err(_) => std::thread::sleep(Duration::from_millis(DIAL_BACKOFF_MS)),
        }
    }
    unreachable!("loop returns on the final attempt")
}

/// Accept one connection within a bounded window — a dead dialer must
/// surface as an error, never a hang (the liveness discipline applies
/// to connection establishment too).
fn accept_within(listener: &TcpListener, what: &str) -> Result<TcpStream> {
    listener
        .set_nonblocking(true)
        .context("arming nonblocking accept")?;
    let deadline = Instant::now() + Duration::from_millis(ACCEPT_WAIT_MS);
    loop {
        match listener.accept() {
            Ok((s, _)) => {
                s.set_nonblocking(false)
                    .context("restoring blocking mode on accepted stream")?;
                return Ok(s);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() > deadline {
                    bail!("{what} never dialed us (accept window expired)");
                }
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(e) => return Err(e).with_context(|| format!("accepting {what}")),
        }
    }
}

/// A control connection shared between the leader's epoch loop (sends
/// reassignment orders) and its monitor thread (drains frames, judges
/// liveness).
type CtlConn = Arc<Mutex<Box<dyn Transport>>>;

/// Run the elastic **leader**: stage 0 of the pipeline plus the
/// supervisor role — it accepts every worker/spare on the control port,
/// monitors their liveness, reassigns dead stages to spares, and
/// resumes each recovery epoch from the newest complete checkpoint
/// boundary. Blocks until the run completes (or is unrecoverable).
///
/// The returned report's `dist` leg carries **stage 0's** data-plane
/// accounting only: remote workers' wire counters stay in their own
/// processes (the in-process [`run_elastic`] aggregates all stages).
///
/// Thin shim over the one multi-process entry point
/// [`super::launch_serve`] with [`super::ServeRole::ElasticLeader`].
pub fn serve_elastic(
    es: &ElasticSpec,
    host: &str,
    port_base: u16,
) -> Result<ElasticReport> {
    let tspec = to_train_spec(es);
    match super::launch_serve(
        &super::ServeRole::ElasticLeader,
        &super::WorkloadSpec::Train(&tspec),
        host,
        port_base,
    )? {
        super::ServeOutcome::Elastic(er) => Ok(*er),
        other => bail!("serve_elastic produced an unexpected {other:?}"),
    }
}

/// Fold an [`ElasticSpec`] back into the unified [`TrainSpec`] shape
/// the `launch_serve` entry point speaks.
fn to_train_spec(es: &ElasticSpec) -> TrainSpec {
    let mut spec = TrainSpec::from_worker(es.worker.clone());
    spec.elastic = Some(ElasticOpts {
        ckpt_every: es.ckpt_every,
        ckpt_codec: es.ckpt_codec,
        heartbeat_every: es.heartbeat_every,
        stale_ms: es.stale_ms,
        spares: es.spares,
        chaos: es.chaos.clone(),
        faults: es.faults.clone(),
        max_epochs: es.max_epochs,
    });
    spec
}

/// The leader body behind [`serve_elastic`] / [`super::launch_serve`].
pub(crate) fn serve_elastic_impl(
    es: &ElasticSpec,
    host: &str,
    port_base: u16,
) -> Result<ElasticReport> {
    es.validate()?;
    let spec = &es.worker;
    let p = spec.h.stages;
    if es.chaos.events.iter().any(|e| e.worker == 0) {
        bail!(
            "the --chaos timeline names worker 0, but stage 0 is the \
             elastic leader and cannot be killed"
        );
    }
    // fail fast if the last possible epoch's ports do not fit
    chain_port(port_base, es.max_epochs - 1, p - 2, p)?;

    // ---- enrollment: every worker and spare dials the control port
    let listener = TcpListener::bind((host, port_base))
        .with_context(|| format!("binding the control port {host}:{port_base}"))?;
    // PMCFG3 train wrap: a serve-infer worker pointed at this port can
    // never enroll, even with identical model flags
    let digest = TrainSpec::from_worker(spec.clone()).handshake_digest();
    let mut actors: Vec<CtlConn> = Vec::new();
    let mut assignment: Vec<Option<usize>> = vec![None; p]; // stage → actor
    let mut spares_q: Vec<usize> = Vec::new();
    for _ in 0..(p - 1) + es.spares {
        let stream = accept_within(&listener, "an elastic worker or spare")?;
        let mut conn: Box<dyn Transport> = Box::new(TcpTransport::new(stream)?);
        let hello = conn
            .recv_timeout(Duration::from_millis(ACCEPT_WAIT_MS))
            .context("receiving an enrollment Hello")?
            .ok_or_else(|| {
                anyhow!("an enrolling actor connected but never said Hello")
            })?;
        if hello.kind != FrameKind::Hello
            || hello.payload.len() != digest.len() + 5
            || hello.payload[..digest.len()] != digest[..]
        {
            bail!(
                "enrollment rejected: config digest mismatch — every \
                 worker must be launched with identical model/run flags"
            );
        }
        let role = hello.payload[digest.len()];
        let stage = u32::from_le_bytes(
            hello.payload[digest.len() + 1..].try_into().expect("u32"),
        ) as usize;
        let idx = actors.len();
        if role == 0 {
            if stage == 0 || stage >= p {
                bail!("worker announced stage {stage} of a {p}-stage pipeline");
            }
            if assignment[stage].is_some() {
                bail!("two workers announced stage {stage}");
            }
            assignment[stage] = Some(idx);
        } else {
            spares_q.push(idx);
        }
        actors.push(Arc::new(Mutex::new(conn)));
    }
    for (stage, a) in assignment.iter().enumerate().skip(1) {
        if a.is_none() {
            bail!("no worker enrolled for stage {stage} — launch it first");
        }
    }

    // ---- liveness monitors: one thread per control connection
    let shared = Arc::new(Mutex::new(CtlStore::with_steps(spec.steps)));
    let dead: Arc<Mutex<HashSet<usize>>> = Arc::new(Mutex::new(HashSet::new()));
    let stop = Arc::new(AtomicBool::new(false));
    // double the data-plane stale bound: a worker parked in a bounded
    // recv can be ctl-silent for up to stale_ms without being dead
    let ctl_stale = Duration::from_millis(es.stale_ms * 2 + 500);
    let monitors: Vec<std::thread::JoinHandle<()>> = actors
        .iter()
        .enumerate()
        .map(|(idx, conn)| {
            let conn = Arc::clone(conn);
            let shared = Arc::clone(&shared);
            let dead = Arc::clone(&dead);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut mon = LivenessMonitor::new(ctl_stale);
                while !stop.load(Ordering::Relaxed) {
                    let r = {
                        let mut c = conn.lock().expect("ctl conn");
                        c.recv_timeout(Duration::from_millis(50))
                    };
                    match r {
                        Ok(Some(f)) => {
                            mon.observe(&f);
                            // checkpoints carry their stage in the blob
                            // header (bytes 16..20); other control
                            // frames need no attribution
                            let stage = if f.kind == FrameKind::Checkpoint
                                && f.payload.len() >= 20
                            {
                                u32::from_le_bytes(
                                    f.payload[16..20]
                                        .try_into()
                                        .expect("u32"),
                                ) as usize
                            } else {
                                usize::MAX
                            };
                            shared
                                .lock()
                                .expect("ctl store")
                                .record(stage, p, f);
                        }
                        Ok(None) => {
                            if mon.is_stale() {
                                dead.lock().expect("dead set").insert(idx);
                                return;
                            }
                        }
                        Err(_) => {
                            dead.lock().expect("dead set").insert(idx);
                            return;
                        }
                    }
                }
            })
        })
        .collect();

    // everything below must stop the monitors before returning
    let result = serve_elastic_epochs(
        es,
        host,
        port_base,
        &actors,
        &mut assignment,
        &mut spares_q,
        &shared,
        &dead,
    );
    stop.store(true, Ordering::Relaxed);
    for m in monitors {
        let _ = m.join();
    }
    result
}

/// The leader's epoch loop, split out so [`serve_elastic`] can stop the
/// monitor threads on every exit path.
#[allow(clippy::too_many_arguments)]
fn serve_elastic_epochs(
    es: &ElasticSpec,
    host: &str,
    port_base: u16,
    actors: &[CtlConn],
    assignment: &mut [Option<usize>],
    spares_q: &mut Vec<usize>,
    shared: &Arc<Mutex<CtlStore>>,
    dead: &Arc<Mutex<HashSet<usize>>>,
) -> Result<ElasticReport> {
    let spec = &es.worker;
    let p = spec.h.stages;
    let mut resume = 0u64;
    let mut recoveries = 0usize;
    let mut resume_steps = Vec::new();
    let mut spares_used = 0usize;
    // chaos kills already executed, keyed (stage, step). Kill
    // scheduling lives HERE — in the leader — because actor processes
    // exit when killed: whatever replaces them (a restart or a
    // promoted spare) enrolls with no memory of which scripted kills
    // already fired. The leader ships each epoch's kill in the
    // reassignment order instead, so kill scripts work in any epoch.
    let mut fired: HashSet<(usize, u64)> = HashSet::new();

    for epoch in 0..es.max_epochs {
        let kill_at = kills_this_epoch(&es.chaos, p, &fired);
        let blobs: Vec<Option<Vec<u8>>> = if resume > 0 {
            shared
                .lock()
                .expect("ctl store")
                .ckpts
                .get(&resume)
                .cloned()
                .expect("resume points at a stored boundary")
        } else {
            vec![None; p]
        };
        // order every assigned worker into position for this epoch
        for stage in 1..p {
            let idx = assignment[stage].expect("stage assigned");
            let order = ReassignOrder {
                epoch: epoch as u32,
                stage: stage as u32,
                resume,
                kill_at: kill_at[stage],
                ckpt: blobs[stage].clone(),
            };
            let mut c = actors[idx].lock().expect("ctl conn");
            // a failed send surfaces as a dead actor next epoch
            let _ = c.send(&WireFrame::control(
                FrameKind::Reassign,
                resume,
                order.encode(),
            ));
        }

        // run our own stage 0 inline
        let epoch_result: Result<WorkerReport> = (|| {
            let port = chain_port(port_base, epoch, 0, p)?;
            let listener = TcpListener::bind((host, port))
                .with_context(|| format!("binding chain link 0 at {host}:{port}"))?;
            let stream = accept_within(&listener, "stage 1 (right neighbor)")?;
            let right: Option<Box<dyn Transport>> =
                Some(Box::new(TcpTransport::new(stream)?));
            let (mut wctl, mut sctl) = channel_pair();
            let ectx = ElasticCtx {
                resume_step: resume,
                ckpt: blobs[0].clone(),
                ckpt_every: es.ckpt_every,
                ckpt_codec: es.ckpt_codec,
                heartbeat_every: es.heartbeat_every,
                stale_ms: es.stale_ms,
                kill_at: None, // the leader is never scripted to die
            };
            let r = run_stage_inner(
                spec,
                0,
                None,
                right,
                Some(&mut wctl as &mut dyn Transport),
                Some(&ectx),
                None,
            );
            drop(wctl);
            {
                let mut s = shared.lock().expect("ctl store");
                drain_ctl(&mut sctl, 0, p, &mut s);
            }
            r
        })();

        match epoch_result {
            Ok(r0) => {
                // the relay reached us every step: the pipeline is done.
                // release every actor (workers and unused spares alike)
                for conn in actors {
                    let mut c = conn.lock().expect("ctl conn");
                    let _ = c.send(&WireFrame::control(
                        FrameKind::Reassign,
                        0,
                        ReassignOrder::done(epoch as u32).encode(),
                    ));
                }
                let s = shared.lock().expect("ctl store");
                let losses = s.full_losses()?;
                return Ok(ElasticReport {
                    losses: losses.clone(),
                    epochs: epoch + 1,
                    recoveries,
                    resume_steps,
                    spares_used,
                    ckpt_frames: s.ck.0,
                    ckpt_bytes: s.ck.1,
                    heartbeat_frames: s.hb.0,
                    heartbeat_bytes: s.hb.1,
                    dist: DistReport {
                        losses,
                        step_seconds: r0.step_seconds,
                        boundary_payload_bytes: r0.boundary_payload_bytes,
                        wire_bytes: r0.wire_bytes,
                        frames: r0.frames_sent,
                        frame_payload_bytes: spec.cfg.boundary_bytes(&spec.h),
                        dp_payload_bytes: 0,
                    },
                });
            }
            Err(e) => {
                recoveries += 1;
                crate::obs::log!(
                    Warn,
                    "elastic: epoch {epoch} failed ({e:#}); recovering"
                );
                if crate::obs::trace::enabled() {
                    crate::obs::trace::instant(
                        "elastic",
                        "recovery",
                        vec![crate::obs::trace::u("epoch", epoch as u64)],
                    );
                }
                // give the monitors one stale window to notice deaths
                std::thread::sleep(Duration::from_millis(es.stale_ms.min(500)));
                let dead_now = dead.lock().expect("dead set").clone();
                for stage in 1..p {
                    let idx = assignment[stage].expect("stage assigned");
                    if !dead_now.contains(&idx) {
                        continue;
                    }
                    // a killed actor *exits*, so an assigned actor
                    // turning up dead while its stage had a scheduled
                    // kill means that kill fired — retire it so the
                    // replacement's epoch schedules the next one
                    if let Some(k) = kill_at[stage] {
                        fired.insert((stage, k));
                    }
                    // promote the first living spare
                    let replacement = loop {
                        let Some(cand) = spares_q.first().copied() else {
                            bail!(
                                "stage {stage} departed permanently and no \
                                 spare remains — unrecoverable churn"
                            );
                        };
                        spares_q.remove(0);
                        if dead_now.contains(&cand) {
                            continue;
                        }
                        break cand;
                    };
                    assignment[stage] = Some(replacement);
                    spares_used += 1;
                    crate::obs::log!(
                        Warn,
                        "elastic: stage {stage}: reassigned to a spare"
                    );
                    if crate::obs::trace::enabled() {
                        crate::obs::trace::instant(
                            "elastic",
                            "reassign",
                            vec![crate::obs::trace::u("stage", stage as u64)],
                        );
                    }
                }
                resume = shared.lock().expect("ctl store").best_boundary();
                resume_steps.push(resume);
            }
        }
    }
    bail!(
        "elastic run did not complete within {} epochs — the churn/fault \
         schedule outpaces the checkpoint cadence",
        es.max_epochs
    )
}

/// The shared body of [`serve_stage_elastic`] and [`serve_spare`]: dial
/// the leader's control port, enroll (announcing a fixed stage, or
/// spare-hood), then serve reassignment orders until the leader says
/// done. While idle — and that includes a spare that is never needed —
/// the actor heartbeats the leader so its liveness monitor stays fed.
fn serve_actor(
    es: &ElasticSpec,
    announce: Option<usize>,
    host: &str,
    port_base: u16,
) -> Result<()> {
    es.validate()?;
    let spec = &es.worker;
    let p = spec.h.stages;
    let stream = dial_retry(host, port_base, "the elastic leader")?;
    let mut ctl: Box<dyn Transport> = Box::new(TcpTransport::new(stream)?);
    let mut hello = TrainSpec::from_worker(spec.clone()).handshake_digest();
    hello.push(u8::from(announce.is_none()));
    hello.extend_from_slice(&(announce.unwrap_or(0) as u32).to_le_bytes());
    ctl.send(&WireFrame::control(FrameKind::Hello, 0, hello))?;

    loop {
        let f = match ctl
            .recv_timeout(Duration::from_millis(IDLE_HEARTBEAT_MS))?
        {
            None => {
                ctl.send(&WireFrame::control(
                    FrameKind::Heartbeat,
                    0,
                    heartbeat_payload(0, 0),
                ))?;
                continue;
            }
            Some(f) => f,
        };
        if f.kind != FrameKind::Reassign {
            continue; // stray control chatter
        }
        let order = ReassignOrder::decode(&f.payload)?;
        if order.is_done() {
            return Ok(());
        }
        let stage = order.stage as usize;
        if stage == 0 || stage >= p {
            bail!("leader assigned stage {stage} of a {p}-stage pipeline");
        }
        let epoch = order.epoch as usize;
        // bind our right listener before dialing left: launch order free
        let listener = if stage < p - 1 {
            let port = chain_port(port_base, epoch, stage, p)?;
            Some(
                TcpListener::bind((host, port))
                    .with_context(|| format!("binding {host}:{port}"))?,
            )
        } else {
            None
        };
        let left_port = chain_port(port_base, epoch, stage - 1, p)?;
        let left_stream = dial_retry(
            host,
            left_port,
            &format!("stage {stage}: the left neighbor"),
        )?;
        let left: Option<Box<dyn Transport>> =
            Some(Box::new(TcpTransport::new(left_stream)?));
        let right: Option<Box<dyn Transport>> = match &listener {
            Some(l) => Some(Box::new(TcpTransport::new(accept_within(
                l,
                &format!("stage {stage}: the right neighbor"),
            )?)?)),
            None => None,
        };
        // scripted kills come from the leader's order: the leader owns
        // the fired-kill bookkeeping (a replacement actor enrolling
        // fresh can't know which kills already fired), so multi-process
        // chaos honors kill scripts in ANY epoch, not just the first
        let ectx = ElasticCtx {
            resume_step: order.resume,
            ckpt: order.ckpt,
            ckpt_every: es.ckpt_every,
            ckpt_codec: es.ckpt_codec,
            heartbeat_every: es.heartbeat_every,
            stale_ms: es.stale_ms,
            kill_at: order.kill_at,
        };
        match run_stage_inner(
            spec,
            stage,
            left,
            right,
            Some(ctl.as_mut()),
            Some(&ectx),
            None,
        ) {
            // epoch done: loop back and await done / the next epoch
            Ok(_) => {}
            Err(e) => {
                let msg = format!("{e:#}");
                if msg.contains("chaos kill") {
                    // scripted death: exit the process like a real kill
                    return Err(e);
                }
                crate::obs::log!(
                    Warn,
                    "elastic: stage {stage} epoch {epoch} failed: {msg}; \
                     awaiting reassignment"
                );
            }
        }
    }
}

/// Run one non-leader stage as a standalone elastic process: enroll
/// with the leader at `host:port_base`, then follow its reassignment
/// orders (including resumes from checkpointed boundaries) until the
/// run completes. Thin shim over [`super::launch_serve`] with
/// [`super::ServeRole::ElasticStage`].
pub fn serve_stage_elastic(
    es: &ElasticSpec,
    stage: usize,
    host: &str,
    port_base: u16,
) -> Result<()> {
    let tspec = to_train_spec(es);
    match super::launch_serve(
        &super::ServeRole::ElasticStage { stage },
        &super::WorkloadSpec::Train(&tspec),
        host,
        port_base,
    )? {
        super::ServeOutcome::Idle => Ok(()),
        other => bail!("serve_stage_elastic produced an unexpected {other:?}"),
    }
}

/// The stage-actor body behind [`serve_stage_elastic`].
pub(crate) fn serve_stage_elastic_impl(
    es: &ElasticSpec,
    stage: usize,
    host: &str,
    port_base: u16,
) -> Result<()> {
    if stage == 0 {
        bail!(
            "stage 0 is the elastic leader — run `serve --elastic` \
             without --stage (or with --stage 0) to host it"
        );
    }
    if stage >= es.worker.h.stages {
        bail!(
            "--stage {stage} out of range for {} stages",
            es.worker.h.stages
        );
    }
    serve_actor(es, Some(stage), host, port_base)
}

/// Run a hot spare: enroll with the leader, heartbeat while idle, and
/// adopt whatever stage the leader assigns after a worker dies. Returns
/// when the leader declares the run done (possibly never having run a
/// single step). Thin shim over [`super::launch_serve`] with
/// [`super::ServeRole::Spare`].
pub fn serve_spare(es: &ElasticSpec, host: &str, port_base: u16) -> Result<()> {
    let tspec = to_train_spec(es);
    match super::launch_serve(
        &super::ServeRole::Spare,
        &super::WorkloadSpec::Train(&tspec),
        host,
        port_base,
    )? {
        super::ServeOutcome::Idle => Ok(()),
        other => bail!("serve_spare produced an unexpected {other:?}"),
    }
}

/// The spare-actor body behind [`serve_spare`].
pub(crate) fn serve_spare_impl(
    es: &ElasticSpec,
    host: &str,
    port_base: u16,
) -> Result<()> {
    serve_actor(es, None, host, port_base)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Mode;
    use crate::transport::fault::{FaultEvent, FaultKind, FaultSchedule};

    #[test]
    fn heartbeat_payload_roundtrips_at_priced_length() {
        let p = heartbeat_payload(7, 123_456);
        assert_eq!(p.len(), crate::memory::heartbeat_payload_bytes());
        assert_eq!(parse_heartbeat(&p).unwrap(), (7, 123_456));
        let err = parse_heartbeat(&p[..15]).unwrap_err().to_string();
        assert!(err.contains("15 B"), "{err}");
        assert!(parse_heartbeat(&[0; 17]).is_err());
    }

    #[test]
    fn reassign_order_roundtrips() {
        for order in [
            ReassignOrder {
                epoch: 2,
                stage: 3,
                resume: 12,
                kill_at: Some(37),
                ckpt: Some(vec![1, 2, 3, 4, 5]),
            },
            ReassignOrder {
                epoch: 0,
                stage: 1,
                resume: 0,
                kill_at: None,
                ckpt: None,
            },
            ReassignOrder::done(4),
        ] {
            let back = ReassignOrder::decode(&order.encode()).unwrap();
            assert_eq!(back, order);
        }
        assert!(ReassignOrder::done(0).is_done());
        // a lying length envelope is rejected, not sliced wrong
        let mut bytes = ReassignOrder {
            epoch: 1,
            stage: 2,
            resume: 6,
            kill_at: None,
            ckpt: Some(vec![9; 8]),
        }
        .encode();
        bytes.pop();
        let err = ReassignOrder::decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("trailing"), "{err}");
        assert!(ReassignOrder::decode(&bytes[..10]).is_err());
    }

    #[test]
    fn liveness_exactly_at_deadline_is_alive() {
        let mon = LivenessMonitor::new(Duration::from_millis(50));
        let d = mon.deadline();
        // the boundary itself: alive — staleness is *strictly after*
        assert!(!mon.is_stale_at(d));
        assert!(mon.is_stale_at(d + Duration::from_nanos(1)));
        // well before: alive
        assert!(!mon.is_stale_at(d - Duration::from_millis(49)));
    }

    #[test]
    fn clock_skewed_sender_cannot_trip_liveness() {
        let mut mon = LivenessMonitor::new(Duration::from_secs(60));
        // a sender whose local clock claims an absurd future: liveness
        // only reads the local arrival instant, so this stays alive
        let hb = WireFrame::control(
            FrameKind::Heartbeat,
            9,
            heartbeat_payload(9, u64::MAX),
        );
        mon.observe(&hb);
        assert!(!mon.is_stale());
        assert_eq!(mon.beats, 1);
        assert_eq!(mon.last_step, 9);
        // ...and a heartbeat claiming the distant past refreshes too
        let t_before = mon.deadline();
        std::thread::sleep(Duration::from_millis(5));
        mon.observe(&WireFrame::control(
            FrameKind::Heartbeat,
            10,
            heartbeat_payload(10, 0),
        ));
        assert!(mon.deadline() > t_before);
        assert_eq!(mon.last_step, 10);
    }

    #[test]
    fn heartbeat_keeps_link_alive_through_delayed_bulk_frame() {
        // the bulk frame (receive ordinal 1) is held 40 ms by the fault
        // schedule; the heartbeat ahead of it refreshes the deadline, so
        // the delayed payload still lands inside the stale window intact
        let (mut a, b) = channel_pair();
        let mut ft = FaultTransport::new(
            Box::new(b),
            FaultSchedule::scripted(vec![FaultEvent {
                at: 1,
                kind: FaultKind::DelayMs(40),
            }]),
        );
        a.send(&WireFrame::control(
            FrameKind::Heartbeat,
            3,
            heartbeat_payload(3, 7),
        ))
        .unwrap();
        let bulk =
            WireFrame::boundary(FrameKind::Fwd, Mode::Raw, 3, 0, vec![9; 4096]);
        a.send(&bulk).unwrap();
        let mut mon = LivenessMonitor::new(Duration::from_millis(1_000));
        // the heartbeat is consumed silently but observed
        assert!(recv_live(&mut ft, &mut mon).unwrap().is_none());
        assert_eq!(mon.beats, 1);
        assert_eq!(mon.last_step, 3);
        let t0 = Instant::now();
        let f = loop {
            if let Some(f) = recv_live(&mut ft, &mut mon).unwrap() {
                break f;
            }
        };
        assert!(t0.elapsed() >= Duration::from_millis(30));
        assert_eq!(f, bulk);
        assert_eq!(ft.stats().delayed, 1);
        assert!(!mon.is_stale());
    }

    #[test]
    fn recv_live_flags_stale_silence_as_departure() {
        let (mut a, _b) = channel_pair();
        let mut mon = LivenessMonitor::new(Duration::from_millis(15));
        let err = loop {
            match recv_live(&mut a as &mut dyn Transport, &mut mon) {
                Ok(None) => continue, // marginal timing: not stale yet
                Ok(Some(f)) => panic!("silent link delivered {f:?}"),
                Err(e) => break e.to_string(),
            }
        };
        assert!(err.contains("departed"), "{err}");
        assert!(err.contains("stale"), "{err}");
    }

    fn tiny_worker(steps: usize) -> WorkerSpec {
        WorkerSpec {
            h: crate::manifest::Hyper::tiny_native(),
            cfg: crate::coordinator::PipelineConfig {
                mode: Mode::Subspace,
                microbatches: 2,
                grassmann_interval: 0,
                lr: 1e-2,
                warmup_steps: 3,
                total_steps: steps,
                seed: 5,
                ..Default::default()
            },
            optim: crate::nn::Optim::AdamW,
            steps,
            corpus_kind: crate::data::CorpusKind::Wiki,
            corpus_tokens: 50_000,
        }
    }

    #[test]
    fn elastic_spec_validation_rejects_bad_shapes() {
        let es = ElasticSpec::new(tiny_worker(8));
        assert_eq!(es.ckpt_every, 2); // steps / 4
        es.validate().unwrap();
        let mut bad = es.clone();
        bad.ckpt_every = 0;
        assert!(bad.validate().is_err());
        let mut bad = es.clone();
        bad.heartbeat_every = 0;
        assert!(bad.validate().is_err());
        let mut bad = es.clone();
        bad.stale_ms = 0;
        assert!(bad.validate().is_err());
        let mut bad = es.clone();
        bad.max_epochs = 0;
        assert!(bad.validate().is_err());
        // chaos naming a worker beyond the pipeline is caught up front
        let mut bad = es;
        bad.chaos = ChurnTimeline::parse("kill:99@1").unwrap();
        let err = bad.validate().unwrap_err();
        assert!(format!("{err:#}").contains("worker 99"), "{err:#}");
    }
}



