//! Deterministic fault injection for the framed transport
//! (DESIGN.md §12).
//!
//! [`FaultTransport`] wraps any [`Transport`] and applies a *seeded,
//! replayable* schedule of faults on the receive side: dropping frames,
//! delaying them, truncating them mid-bytes (re-using the exact
//! severed-link errors `frame.rs` produces for real partial reads), or
//! severing the link outright. Every chaos scenario in `tests/chaos.rs`
//! replays bit-identically because the schedule is data, not chance: a
//! [`FaultSchedule`] maps receive ordinals (0-based count of frames the
//! wrapped link has produced) to [`FaultKind`]s, and
//! [`FaultSchedule::seeded`] derives that map from the repo's own
//! deterministic [`crate::rng::Rng`].
//!
//! The wrapper is bitwise transparent under the empty schedule — a
//! parity leg in `tests/transport_parity.rs` pins that invariant, so
//! the harness itself can never skew a measured curve.

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::rng::Rng;

use super::{Transport, WireFrame};

/// One fault to apply to a received frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Swallow the frame entirely; the receiver never sees it.
    Drop,
    /// Hold the frame for this many milliseconds before delivering it.
    DelayMs(u64),
    /// Deliver only the first `n` bytes of the frame's wire encoding,
    /// then treat the link as severed — surfaces the same
    /// "severed mid-header" / "severed mid-payload" errors a real
    /// partial read produces.
    Truncate(usize),
    /// Cut the link: this and every later receive fails with a
    /// `"departed"` error.
    Sever,
}

/// A fault pinned to one receive ordinal.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    /// 0-based index of the received frame this fault applies to.
    pub at: u64,
    /// What to do to that frame.
    pub kind: FaultKind,
}

/// Named fault mixes for [`FaultSchedule::seeded`] — the three families
/// the CI chaos matrix runs (DESIGN.md §12).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultFamily {
    /// Mostly dropped frames (lost packets on a flaky link).
    DropHeavy,
    /// Mostly short delays (congested but lossless link).
    DelayHeavy,
    /// A single mid-horizon sever (a peer yanked off the network).
    Sever,
}

impl FaultFamily {
    /// Parse a family name (`drop` / `delay` / `sever`), as accepted by
    /// the `--fault` CLI flag.
    pub fn parse(s: &str) -> Result<FaultFamily> {
        match s {
            "drop" => Ok(FaultFamily::DropHeavy),
            "delay" => Ok(FaultFamily::DelayHeavy),
            "sever" => Ok(FaultFamily::Sever),
            other => bail!(
                "unknown fault family {other:?} (expected drop|delay|sever)"
            ),
        }
    }
}

/// A deterministic receive-ordinal → fault map. Cloneable so the same
/// schedule can be handed to several epochs or compared across runs.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultSchedule {
    events: Vec<FaultEvent>,
}

impl FaultSchedule {
    /// The empty schedule: every frame passes through untouched. Under
    /// this schedule [`FaultTransport`] is bitwise transparent.
    pub fn transparent() -> FaultSchedule {
        FaultSchedule { events: Vec::new() }
    }

    /// A hand-written schedule. Events are sorted by ordinal; the first
    /// event at a given ordinal wins.
    pub fn scripted(mut events: Vec<FaultEvent>) -> FaultSchedule {
        events.sort_by_key(|e| e.at);
        FaultSchedule { events }
    }

    /// Derive a schedule from a seed: roughly one fault per eight
    /// receive ordinals over `[0, horizon)`, drawn from the family's
    /// mix. Same `(seed, horizon, family)` → same schedule, always.
    pub fn seeded(
        seed: u64,
        horizon: u64,
        family: FaultFamily,
    ) -> FaultSchedule {
        let mut rng = Rng::new(seed ^ 0xFA017);
        let mut events = Vec::new();
        match family {
            FaultFamily::Sever => {
                // one cut somewhere in the middle half of the horizon
                let span = (horizon / 2).max(1);
                let at = horizon / 4 + rng.next_u64() % span;
                events.push(FaultEvent { at, kind: FaultKind::Sever });
            }
            FaultFamily::DropHeavy | FaultFamily::DelayHeavy => {
                let mut at = rng.next_u64() % 8;
                while at < horizon {
                    let kind = match family {
                        FaultFamily::DropHeavy => FaultKind::Drop,
                        _ => FaultKind::DelayMs(1 + rng.next_u64() % 5),
                    };
                    events.push(FaultEvent { at, kind });
                    at += 1 + rng.next_u64() % 15;
                }
            }
        }
        FaultSchedule::scripted(events)
    }

    /// The fault scheduled for receive ordinal `at`, if any.
    pub fn fault_at(&self, at: u64) -> Option<FaultKind> {
        self.events
            .iter()
            .find(|e| e.at == at)
            .map(|e| e.kind)
    }

    /// True if no fault is ever scheduled.
    pub fn is_transparent(&self) -> bool {
        self.events.is_empty()
    }

    /// The scheduled events, sorted by ordinal.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }
}

/// Counters of what the wrapper actually did — chaos tests assert these
/// so a schedule that silently never fired cannot pass as coverage.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Frames delivered untouched.
    pub passed: u64,
    /// Frames swallowed by [`FaultKind::Drop`].
    pub dropped: u64,
    /// Frames held back by [`FaultKind::DelayMs`] before delivery.
    pub delayed: u64,
    /// Frames cut short by [`FaultKind::Truncate`].
    pub truncated: u64,
    /// Links cut by [`FaultKind::Sever`].
    pub severed: u64,
}

/// A [`Transport`] wrapper that injects the faults a [`FaultSchedule`]
/// prescribes, on the receive side, by receive ordinal. Sends pass
/// through untouched until the link is severed (after which both
/// directions fail with `"departed"` errors, like a real dead peer).
pub struct FaultTransport {
    inner: Box<dyn Transport>,
    sched: FaultSchedule,
    recvs: u64,
    stats: FaultStats,
    /// a delayed frame waiting for its delivery instant
    pending: Option<(WireFrame, Instant)>,
    /// once set, the link is dead and every call fails with this message
    dead: Option<String>,
}

impl FaultTransport {
    /// Wrap `inner` under `sched`.
    pub fn new(
        inner: Box<dyn Transport>,
        sched: FaultSchedule,
    ) -> FaultTransport {
        FaultTransport {
            inner,
            sched,
            recvs: 0,
            stats: FaultStats::default(),
            pending: None,
            dead: None,
        }
    }

    /// What the wrapper has done so far.
    pub fn stats(&self) -> FaultStats {
        self.stats
    }

    /// Apply the scheduled fault (if any) to a freshly received frame.
    /// `Ok(Some)` delivers now, `Ok(None)` means the frame was dropped
    /// or parked for delayed delivery, `Err` means the link died.
    fn apply(&mut self, frame: WireFrame) -> Result<Option<WireFrame>> {
        let ord = self.recvs;
        self.recvs += 1;
        match self.sched.fault_at(ord) {
            None => {
                self.stats.passed += 1;
                Ok(Some(frame))
            }
            Some(FaultKind::Drop) => {
                self.stats.dropped += 1;
                Ok(None)
            }
            Some(FaultKind::DelayMs(ms)) => {
                self.pending =
                    Some((frame, Instant::now() + Duration::from_millis(ms)));
                Ok(None)
            }
            Some(FaultKind::Truncate(n)) => {
                self.stats.truncated += 1;
                let bytes = frame.to_bytes();
                let cut = &bytes[..n.min(bytes.len())];
                match WireFrame::read_from(&mut std::io::Cursor::new(cut)) {
                    // degenerate truncation (n >= frame length): whole
                    // frame survives, deliver it
                    Ok(f) => Ok(Some(f)),
                    Err(e) => {
                        let msg = e.to_string();
                        self.dead = Some(msg.clone());
                        bail!("{msg}")
                    }
                }
            }
            Some(FaultKind::Sever) => {
                self.stats.severed += 1;
                let msg = format!(
                    "worker departed: link severed by fault injection \
                     at receive ordinal {ord}"
                );
                self.dead = Some(msg.clone());
                bail!("{msg}")
            }
        }
    }

    /// Deliver the parked delayed frame, sleeping out its remaining
    /// hold time.
    fn release_pending(&mut self, frame: WireFrame, at: Instant) -> WireFrame {
        let now = Instant::now();
        if at > now {
            std::thread::sleep(at - now);
        }
        self.stats.delayed += 1;
        frame
    }
}

impl Transport for FaultTransport {
    fn send(&mut self, frame: &WireFrame) -> Result<()> {
        if let Some(msg) = &self.dead {
            bail!("{msg}");
        }
        self.inner.send(frame)
    }

    fn recv(&mut self) -> Result<WireFrame> {
        loop {
            if let Some(msg) = &self.dead {
                bail!("{msg}");
            }
            if let Some((frame, at)) = self.pending.take() {
                return Ok(self.release_pending(frame, at));
            }
            let frame = self.inner.recv()?;
            if let Some(f) = self.apply(frame)? {
                return Ok(f);
            }
        }
    }

    fn recv_timeout(
        &mut self,
        timeout: Duration,
    ) -> Result<Option<WireFrame>> {
        let deadline = Instant::now() + timeout;
        loop {
            if let Some(msg) = &self.dead {
                bail!("{msg}");
            }
            if let Some((frame, at)) = self.pending.take() {
                if at > deadline {
                    // the hold outlasts this wait: park it again and
                    // report silence, like a genuinely slow link
                    self.pending = Some((frame, at));
                    let now = Instant::now();
                    if deadline > now {
                        std::thread::sleep(deadline - now);
                    }
                    return Ok(None);
                }
                return Ok(Some(self.release_pending(frame, at)));
            }
            let now = Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            match self.inner.recv_timeout(deadline - now)? {
                None => return Ok(None),
                Some(frame) => {
                    if let Some(f) = self.apply(frame)? {
                        return Ok(Some(f));
                    }
                }
            }
        }
    }

    fn bytes_sent(&self) -> u64 {
        self.inner.bytes_sent()
    }

    fn label(&self) -> &'static str {
        "fault"
    }
}

/// Which end of a stage's two links a schedule attaches to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkSide {
    /// The link toward stage - 1.
    Left,
    /// The link toward stage + 1.
    Right,
}

/// A per-epoch fault assignment for the elastic runtime: schedules keyed
/// by `(stage, side)`, applied only during `target_epoch` so recovery
/// epochs run clean and the run terminates (DESIGN.md §12).
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// Epoch the faults fire in (0 = the first attempt).
    pub target_epoch: usize,
    /// `(stage, side, schedule)` triples.
    pub entries: Vec<(usize, LinkSide, FaultSchedule)>,
}

impl FaultPlan {
    /// The schedule for `(stage, side)` in `epoch`, if one applies.
    pub fn schedule_for(
        &self,
        epoch: usize,
        stage: usize,
        side: LinkSide,
    ) -> Option<FaultSchedule> {
        if epoch != self.target_epoch {
            return None;
        }
        self.entries
            .iter()
            .find(|(s, d, _)| *s == stage && *d == side)
            .map(|(_, _, sched)| sched.clone())
    }

    /// True if no schedule is registered at all.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::super::{channel_pair, FrameKind};
    use super::*;
    use crate::compress::Mode;

    fn frame(step: u64, fill: u8) -> WireFrame {
        WireFrame::boundary(FrameKind::Fwd, Mode::Raw, step, 0, vec![fill; 40])
    }

    #[test]
    fn transparent_schedule_is_bitwise_passthrough() {
        let (a, mut b) = channel_pair();
        let mut ft =
            FaultTransport::new(Box::new(a), FaultSchedule::transparent());
        let mut sent = Vec::new();
        for i in 0..5u64 {
            let f = frame(i, i as u8);
            b.send(&f).unwrap();
            sent.push(f);
        }
        for f in &sent {
            assert_eq!(&ft.recv().unwrap(), f);
        }
        assert_eq!(ft.stats().passed, 5);
        assert_eq!(
            ft.stats(),
            FaultStats { passed: 5, ..FaultStats::default() }
        );
    }

    #[test]
    fn drop_swallows_exactly_the_scheduled_ordinal() {
        let (a, mut b) = channel_pair();
        let sched = FaultSchedule::scripted(vec![FaultEvent {
            at: 1,
            kind: FaultKind::Drop,
        }]);
        let mut ft = FaultTransport::new(Box::new(a), sched);
        for i in 0..3u64 {
            b.send(&frame(i, 0)).unwrap();
        }
        // ordinal 1 vanishes: we see steps 0 then 2
        assert_eq!(ft.recv().unwrap().step, 0);
        assert_eq!(ft.recv().unwrap().step, 2);
        assert_eq!(ft.stats().dropped, 1);
        assert_eq!(ft.stats().passed, 2);
    }

    #[test]
    fn delay_holds_then_delivers_intact() {
        let (a, mut b) = channel_pair();
        let sched = FaultSchedule::scripted(vec![FaultEvent {
            at: 0,
            kind: FaultKind::DelayMs(30),
        }]);
        let mut ft = FaultTransport::new(Box::new(a), sched);
        let f = frame(7, 9);
        b.send(&f).unwrap();
        // a short wait sees silence (the frame is parked)…
        assert!(ft
            .recv_timeout(Duration::from_millis(5))
            .unwrap()
            .is_none());
        // …but a blocking recv rides out the hold and gets it intact
        let start = Instant::now();
        assert_eq!(ft.recv().unwrap(), f);
        assert!(start.elapsed() >= Duration::from_millis(10));
        assert_eq!(ft.stats().delayed, 1);
    }

    #[test]
    fn truncation_surfaces_frame_layer_severed_errors() {
        // mid-header cut
        let (a, mut b) = channel_pair();
        let sched = FaultSchedule::scripted(vec![FaultEvent {
            at: 0,
            kind: FaultKind::Truncate(10),
        }]);
        let mut ft = FaultTransport::new(Box::new(a), sched);
        b.send(&frame(0, 1)).unwrap();
        let err = ft.recv().unwrap_err().to_string();
        assert!(err.contains("severed mid-header"), "{err}");
        // the link stays dead afterwards, both directions
        let err = ft.recv().unwrap_err().to_string();
        assert!(err.contains("departed"), "{err}");
        let err = ft.send(&frame(1, 1)).unwrap_err().to_string();
        assert!(err.contains("departed"), "{err}");

        // mid-payload cut (past the 24 B header)
        let (a, mut b) = channel_pair();
        let sched = FaultSchedule::scripted(vec![FaultEvent {
            at: 0,
            kind: FaultKind::Truncate(30),
        }]);
        let mut ft = FaultTransport::new(Box::new(a), sched);
        b.send(&frame(0, 2)).unwrap();
        let err = ft.recv().unwrap_err().to_string();
        assert!(err.contains("severed mid-payload"), "{err}");
    }

    #[test]
    fn sever_kills_the_link_with_a_departed_error() {
        let (a, mut b) = channel_pair();
        let sched = FaultSchedule::scripted(vec![FaultEvent {
            at: 2,
            kind: FaultKind::Sever,
        }]);
        let mut ft = FaultTransport::new(Box::new(a), sched);
        for i in 0..4u64 {
            b.send(&frame(i, 0)).unwrap();
        }
        assert_eq!(ft.recv().unwrap().step, 0);
        assert_eq!(ft.recv().unwrap().step, 1);
        let err = ft.recv().unwrap_err().to_string();
        assert!(err.contains("departed"), "{err}");
        assert!(err.contains("fault injection"), "{err}");
        assert_eq!(ft.stats().severed, 1);
    }

    #[test]
    fn seeded_schedules_replay_bit_identically() {
        for family in
            [FaultFamily::DropHeavy, FaultFamily::DelayHeavy, FaultFamily::Sever]
        {
            let a = FaultSchedule::seeded(99, 64, family);
            let b = FaultSchedule::seeded(99, 64, family);
            assert_eq!(a, b, "{family:?} not deterministic");
            assert!(!a.is_transparent(), "{family:?} scheduled nothing");
            assert!(
                a.events().iter().all(|e| e.at < 64),
                "{family:?} event past horizon"
            );
            // a different seed moves the schedule
            let c = FaultSchedule::seeded(100, 64, family);
            assert_ne!(a, c, "{family:?} ignores the seed");
        }
    }

    #[test]
    fn fault_plan_scopes_schedules_to_epoch_stage_and_side() {
        let sched = FaultSchedule::seeded(5, 16, FaultFamily::DropHeavy);
        let plan = FaultPlan {
            target_epoch: 0,
            entries: vec![(1, LinkSide::Left, sched.clone())],
        };
        assert_eq!(plan.schedule_for(0, 1, LinkSide::Left), Some(sched));
        assert_eq!(plan.schedule_for(0, 1, LinkSide::Right), None);
        assert_eq!(plan.schedule_for(0, 2, LinkSide::Left), None);
        assert_eq!(plan.schedule_for(1, 1, LinkSide::Left), None);
    }
}
