//! Real multi-worker transport: the framed wire protocol and the
//! distributed pipeline that runs [`crate::nn::NativePipeline`] stage
//! subgraphs across workers (DESIGN.md §11).
//!
//! Until this module existed, every "wire byte" in the repo was an
//! accounting entry — [`crate::compress::wire_bytes`] priced transfers
//! the netsim never performed. Here the bytes actually move:
//!
//! - [`frame`] — the length-prefixed wire format; a boundary frame's
//!   payload is the exact byte string the [`crate::compress`] codecs
//!   emit, so `payload_len == wire_bytes` holds on the wire itself;
//! - [`Transport`] — a blocking, ordered, reliable duplex byte link
//!   between two neighboring stage workers, with two backends:
//!   [`ChannelTransport`] (in-process `mpsc`, deterministic, used by the
//!   parity tests) and [`TcpTransport`] (real sockets, loopback in CI,
//!   routable in a genuine deployment);
//! - [`dist`] — the distributed pipeline: config-digest handshake,
//!   per-stage workers executing GPipe/1F1B wave orders, loss/U-basis
//!   relay frames, and graceful worker-departure errors mirroring the
//!   swarm simulator's churn semantics.
//!
//! The parity contract (enforced in `tests/transport_parity.rs` and
//! `examples/distributed_train.rs`): a distributed run over *either*
//! backend reproduces the single-process native backend's loss curve
//! **bitwise**, because every worker replays the same seeded init and
//! data streams and the wire is lossless for what the codec preserves.

pub mod dist;
pub mod dp;
pub mod elastic;
pub mod fault;
pub mod frame;
pub mod serve;
pub mod spec;

use anyhow::{bail, Context, Result};

use crate::compress::Mode;
use crate::obs::trace;

pub use dist::{
    run_local, serve_stage, DistReport, TransportKind, WorkerReport,
    WorkerSpec,
};
pub use dp::{
    gossip_pairs, gossip_partner, launch, reference_dp_losses,
    ring_allreduce_local, ElasticOpts, LaunchReport, Reduce, Topology,
    TrainSpec, TrainSpecBuilder,
};
pub use elastic::{
    heartbeat_payload, parse_heartbeat, recv_live, run_elastic,
    serve_elastic, serve_spare, serve_stage_elastic, ElasticCtx,
    ElasticReport, ElasticSpec, LivenessMonitor, ReassignOrder,
    REASSIGN_DONE,
};
pub use fault::{
    FaultEvent, FaultFamily, FaultKind, FaultPlan, FaultSchedule,
    FaultStats, FaultTransport, LinkSide,
};
pub use frame::{FrameKind, WireFrame, HEADER_LEN, MAX_PAYLOAD};
pub use serve::{
    run_serve_local, serve_infer, serve_infer_stage, ServeReport,
    SessionStat,
};
pub use spec::{
    handshake_wrap, ServeSpec, ServeSpecBuilder, SpecCore, TrafficSpec,
    Workload,
};

// ---------------------------------------------------------------------------
// launch_serve — the one multi-process entry point
// ---------------------------------------------------------------------------

/// Which actor a `launch_serve` process hosts. Training workloads use
/// the first four roles (classic chain stage, elastic leader/stage/
/// spare); serving workloads use [`ServeRole::Infer`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeRole {
    /// one stage of a classic (non-elastic) training chain
    Stage {
        /// pipeline stage index in `0..stages`
        stage: usize,
    },
    /// the elastic supervisor + stage 0 (blocks until the run ends)
    ElasticLeader,
    /// one non-leader elastic stage actor
    ElasticStage {
        /// pipeline stage index in `1..stages`
        stage: usize,
    },
    /// a hot spare awaiting reassignment from the elastic leader
    Spare,
    /// one stage of a decode pipeline (`protomodels serve-infer`)
    Infer {
        /// pipeline stage index in `0..stages`
        stage: usize,
    },
}

/// The workload a `launch_serve` process executes: the same two spec
/// types the in-process entry points take ([`launch`] /
/// [`run_serve_local`]), so every path into the runtime speaks
/// [`SpecCore`]-composed specs. The `PMCFG3` handshake digest embeds
/// the workload tag, so a train worker and a serve worker pointed at
/// each other refuse to connect.
#[derive(Clone, Copy, Debug)]
pub enum WorkloadSpec<'a> {
    /// a training run (classic or elastic, chosen by `spec.elastic`)
    Train(&'a TrainSpec),
    /// an autoregressive decode serving run
    Serve(&'a ServeSpec),
}

/// What a `launch_serve` role returns when its process is done.
#[derive(Debug)]
pub enum ServeOutcome {
    /// a training chain stage's data-plane accounting
    Worker(WorkerReport),
    /// the elastic leader's full run report
    Elastic(Box<ElasticReport>),
    /// a decode stage's serving report (stage 0 carries session stats)
    Infer(Box<ServeReport>),
    /// the actor ran to completion with nothing to report (elastic
    /// stages and spares: their counters live in the leader's report)
    Idle,
}

/// Host one actor of a multi-process run: the single entry point every
/// `serve_*` free function shims to, mirroring how [`launch`] fronts
/// the in-process paths. The role picks the actor, the workload picks
/// the protocol, and mismatches (an [`ServeRole::Infer`] role with a
/// [`WorkloadSpec::Train`] spec, elastic roles without
/// `spec.elastic`, …) fail with errors that say what to change.
pub fn launch_serve(
    role: &ServeRole,
    workload: &WorkloadSpec<'_>,
    host: &str,
    port_base: u16,
) -> Result<ServeOutcome> {
    match (role, workload) {
        (ServeRole::Stage { stage }, WorkloadSpec::Train(ts)) => {
            ts.validate()?;
            if ts.replicas != 1 {
                bail!(
                    "serve --stage hosts one chain stage; {}-replica \
                     grids are in-process only (use launch)",
                    ts.replicas
                );
            }
            if ts.elastic.is_some() {
                bail!(
                    "the spec carries elastic options — use \
                     ServeRole::ElasticLeader / ElasticStage / Spare"
                );
            }
            dist::serve_stage_impl(&ts.worker, *stage, host, port_base)
                .map(ServeOutcome::Worker)
        }
        (ServeRole::ElasticLeader, WorkloadSpec::Train(ts)) => {
            let es = elastic_spec_of(ts)?;
            elastic::serve_elastic_impl(&es, host, port_base)
                .map(|er| ServeOutcome::Elastic(Box::new(er)))
        }
        (ServeRole::ElasticStage { stage }, WorkloadSpec::Train(ts)) => {
            let es = elastic_spec_of(ts)?;
            elastic::serve_stage_elastic_impl(&es, *stage, host, port_base)
                .map(|()| ServeOutcome::Idle)
        }
        (ServeRole::Spare, WorkloadSpec::Train(ts)) => {
            let es = elastic_spec_of(ts)?;
            elastic::serve_spare_impl(&es, host, port_base)
                .map(|()| ServeOutcome::Idle)
        }
        (ServeRole::Infer { stage }, WorkloadSpec::Serve(ss)) => {
            serve::serve_infer_stage_impl(ss, *stage, host, port_base)
                .map(|r| ServeOutcome::Infer(Box::new(r)))
        }
        (ServeRole::Infer { .. }, WorkloadSpec::Train(_)) => bail!(
            "ServeRole::Infer decodes — hand it a WorkloadSpec::Serve \
             (a ServeSpec), not a TrainSpec"
        ),
        (_, WorkloadSpec::Serve(_)) => bail!(
            "training roles (Stage/ElasticLeader/ElasticStage/Spare) \
             take a WorkloadSpec::Train; for decode serving use \
             ServeRole::Infer"
        ),
    }
}

/// Project a [`TrainSpec`] carrying [`ElasticOpts`] down to the
/// [`ElasticSpec`] the elastic runtime executes.
fn elastic_spec_of(ts: &TrainSpec) -> Result<ElasticSpec> {
    ts.validate()?;
    ts.elastic_spec().ok_or_else(|| {
        anyhow::anyhow!(
            "elastic roles need elastic options on the spec — set \
             TrainSpec::elastic (CLI: --elastic)"
        )
    })
}

/// Record one wire-frame event on the current logical track: category
/// `frame`, name `<dir>:<kind>`, duration bounded by the `t0_us`
/// handed back from [`trace::begin`] at the call's entry. Every frame
/// on every link flows through the two backend impls below, so these
/// five argument keys (`bytes` = full wire length, `payload`, `step`,
/// `mb`, `tag` = codec wire tag or `0xFF`) are the whole frame schema
/// the `METRICS.json` byte counters and the trace-determinism tests
/// consume. No-op (one relaxed atomic load) without a trace session.
fn trace_frame(dir: &str, frame: &WireFrame, t0_us: f64) {
    if !trace::enabled() || t0_us.is_nan() {
        return;
    }
    trace::end(
        "frame",
        &format!("{dir}:{}", frame.kind.name()),
        t0_us,
        vec![
            trace::u("bytes", frame.wire_len() as u64),
            trace::u("payload", frame.payload.len() as u64),
            trace::u("step", frame.step),
            trace::u("mb", frame.microbatch as u64),
            trace::u(
                "tag",
                frame
                    .codec
                    .map(Mode::wire_tag)
                    .unwrap_or(frame::CODEC_NONE) as u64,
            ),
        ],
    );
}

/// A blocking, ordered, reliable duplex link to one neighboring stage
/// worker. Implementations must be `Send` (workers run on their own OS
/// threads) and must surface a closed peer as an error whose message
/// contains `"departed"` — the distributed pipeline's churn-mirroring
/// contract (a vanished worker is a *leave event*, not a hang or a
/// panic).
pub trait Transport: Send {
    /// Send one frame. Blocks until the frame is handed to the link.
    fn send(&mut self, frame: &WireFrame) -> Result<()>;

    /// Receive the next frame. Blocks until one arrives or the peer
    /// departs.
    fn recv(&mut self) -> Result<WireFrame>;

    /// Receive with a bounded wait: `Ok(None)` if no frame *started*
    /// arriving within `timeout` (the liveness probe the elastic
    /// runtime's stale detection is built on — DESIGN.md §12), `Ok(Some)`
    /// once a whole frame is in, `Err` if the peer departed. The default
    /// implementation ignores the timeout and blocks — backends that can
    /// wait boundedly override it.
    fn recv_timeout(
        &mut self,
        timeout: std::time::Duration,
    ) -> Result<Option<WireFrame>> {
        let _ = timeout;
        self.recv().map(Some)
    }

    /// Cumulative bytes this end has sent, frame headers included.
    fn bytes_sent(&self) -> u64;

    /// Backend label for error messages (`"channel"` / `"tcp"`).
    fn label(&self) -> &'static str;
}

// ---------------------------------------------------------------------------
// in-process channel backend
// ---------------------------------------------------------------------------

use std::sync::mpsc::{channel, Receiver, Sender};

/// In-process transport over a pair of `mpsc` channels. Frames are
/// serialized to bytes and re-parsed on receive, so the channel backend
/// exercises the exact encoder/decoder the TCP backend uses — the only
/// difference between the backends is the pipe.
pub struct ChannelTransport {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    sent: u64,
}

/// Build a connected pair of channel transports (the two ends of one
/// stage-to-stage link).
pub fn channel_pair() -> (ChannelTransport, ChannelTransport) {
    let (atx, brx) = channel();
    let (btx, arx) = channel();
    (
        ChannelTransport { tx: atx, rx: arx, sent: 0 },
        ChannelTransport { tx: btx, rx: brx, sent: 0 },
    )
}

impl Transport for ChannelTransport {
    fn send(&mut self, frame: &WireFrame) -> Result<()> {
        let t0 = trace::begin();
        let bytes = frame.to_bytes();
        self.sent += bytes.len() as u64;
        let res = self.tx.send(bytes).map_err(|_| {
            anyhow::anyhow!(
                "worker departed: channel peer dropped before \
                 receiving a {} frame",
                frame.kind.name()
            )
        });
        trace_frame("send", frame, t0);
        res
    }

    fn recv(&mut self) -> Result<WireFrame> {
        let t0 = trace::begin();
        let bytes = self.rx.recv().map_err(|_| {
            anyhow::anyhow!(
                "worker departed: channel peer dropped while we \
                 awaited a frame"
            )
        })?;
        let frame =
            WireFrame::read_from(&mut std::io::Cursor::new(bytes))?;
        trace_frame("recv", &frame, t0);
        Ok(frame)
    }

    fn recv_timeout(
        &mut self,
        timeout: std::time::Duration,
    ) -> Result<Option<WireFrame>> {
        use std::sync::mpsc::RecvTimeoutError;
        let t0 = trace::begin();
        match self.rx.recv_timeout(timeout) {
            Ok(bytes) => {
                let frame = WireFrame::read_from(
                    &mut std::io::Cursor::new(bytes),
                )?;
                trace_frame("recv", &frame, t0);
                Ok(Some(frame))
            }
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => Err(anyhow::anyhow!(
                "worker departed: channel peer dropped while we \
                 awaited a frame"
            )),
        }
    }

    fn bytes_sent(&self) -> u64 {
        self.sent
    }

    fn label(&self) -> &'static str {
        "channel"
    }
}

// ---------------------------------------------------------------------------
// TCP backend
// ---------------------------------------------------------------------------

use std::net::TcpStream;
use std::sync::{Arc, Mutex};

/// Transport over one TCP stream. `TCP_NODELAY` is set at construction
/// (Nagle-delaying a 3 KB boundary frame by 40 ms would dwarf the tiny
/// presets' compute), and **sends never block the worker**: each link
/// owns a writer thread draining an unbounded outbound queue, so even
/// frames larger than the kernel socket buffers cannot create a
/// circular send-wait between neighboring stages. With non-blocking
/// sends, the wave orders are deadlock-free for *any* microbatch count
/// × frame size — the step's message dependencies form a DAG (the
/// single-process execution order), and a Kahn network with unbounded
/// queues executing a DAG always makes progress. In-flight memory is
/// bounded by the schedule: M frames per link for GPipe fill-drain,
/// pipeline depth for 1F1B.
pub struct TcpTransport {
    reader: TcpStream,
    /// outbound queue; dropped (closed) first so the writer drains+exits
    tx: Option<Sender<Vec<u8>>>,
    writer: Option<std::thread::JoinHandle<()>>,
    /// first socket write error, surfaced on the next `send`
    failed: Arc<Mutex<Option<String>>>,
    sent: u64,
}

impl TcpTransport {
    /// Wrap a connected stream (sets `TCP_NODELAY`, spawns the writer).
    pub fn new(stream: TcpStream) -> Result<TcpTransport> {
        stream
            .set_nodelay(true)
            .context("setting TCP_NODELAY on transport stream")?;
        let reader = stream
            .try_clone()
            .context("cloning transport stream for the read half")?;
        let (tx, rx) = channel::<Vec<u8>>();
        let failed = Arc::new(Mutex::new(None));
        let flag = Arc::clone(&failed);
        let mut write_half = stream;
        let writer = std::thread::spawn(move || {
            use std::io::Write;
            for buf in rx {
                if let Err(e) = write_half.write_all(&buf) {
                    *flag.lock().expect("writer flag") = Some(e.to_string());
                    return;
                }
            }
        });
        Ok(TcpTransport {
            reader,
            tx: Some(tx),
            writer: Some(writer),
            failed,
            sent: 0,
        })
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, frame: &WireFrame) -> Result<()> {
        if let Some(e) = self.failed.lock().expect("writer flag").clone() {
            anyhow::bail!(
                "worker departed: tcp peer unreachable while sending a \
                 {} frame ({e})",
                frame.kind.name()
            );
        }
        let t0 = trace::begin();
        let bytes = frame.to_bytes();
        self.sent += bytes.len() as u64;
        let res = self
            .tx
            .as_ref()
            .expect("writer queue open while transport lives")
            .send(bytes)
            .map_err(|_| {
                anyhow::anyhow!(
                    "worker departed: tcp writer gone while sending a \
                     {} frame",
                    frame.kind.name()
                )
            });
        trace_frame("send", frame, t0);
        res
    }

    fn recv(&mut self) -> Result<WireFrame> {
        let t0 = trace::begin();
        let frame = WireFrame::read_from(&mut self.reader)?;
        trace_frame("recv", &frame, t0);
        Ok(frame)
    }

    fn recv_timeout(
        &mut self,
        timeout: std::time::Duration,
    ) -> Result<Option<WireFrame>> {
        // Probe with `peek` under a read timeout: peek never consumes, so
        // a timeout leaves the stream exactly where it was and the
        // subsequent blocking `recv` still sees whole frames. The probe
        // only answers "has the next frame *started* arriving" — which is
        // all stale detection needs.
        self.reader
            .set_read_timeout(Some(timeout))
            .context("arming transport read timeout")?;
        let probe = self.reader.peek(&mut [0u8; 1]);
        self.reader
            .set_read_timeout(None)
            .context("disarming transport read timeout")?;
        match probe {
            Ok(0) => Err(anyhow::anyhow!(
                "worker departed: tcp peer closed the stream while we \
                 awaited a frame"
            )),
            Ok(_) => self.recv().map(Some),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                Ok(None)
            }
            Err(e) => Err(anyhow::anyhow!(
                "worker departed: tcp stream error while we awaited a \
                 frame ({e})"
            )),
        }
    }

    fn bytes_sent(&self) -> u64 {
        self.sent
    }

    fn label(&self) -> &'static str {
        "tcp"
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        // close the queue, then wait for the writer to flush everything
        // (the Bye frame, trailing boundary frames) before the socket
        // write-half drops
        drop(self.tx.take());
        if let Some(h) = self.writer.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Mode;

    #[test]
    fn channel_pair_roundtrips_frames() {
        let (mut a, mut b) = channel_pair();
        let f = WireFrame::boundary(
            FrameKind::Fwd,
            Mode::Subspace,
            1,
            0,
            vec![9; 12],
        );
        a.send(&f).unwrap();
        let g = b.recv().unwrap();
        assert_eq!(f, g);
        assert_eq!(a.bytes_sent(), f.wire_len() as u64);
        // duplex: the other direction works too
        b.send(&f).unwrap();
        assert_eq!(a.recv().unwrap(), f);
    }

    #[test]
    fn dropped_channel_peer_reports_departure() {
        let (mut a, b) = channel_pair();
        drop(b);
        let f = WireFrame::control(FrameKind::Bye, 0, Vec::new());
        let err = a.send(&f).unwrap_err().to_string();
        assert!(err.contains("departed"), "{err}");
        let err = a.recv().unwrap_err().to_string();
        assert!(err.contains("departed"), "{err}");
    }

    #[test]
    fn channel_recv_timeout_distinguishes_silence_from_departure() {
        let (mut a, mut b) = channel_pair();
        let t = std::time::Duration::from_millis(10);
        // silence: no frame within the window
        assert!(a.recv_timeout(t).unwrap().is_none());
        // a queued frame arrives whole
        let f = WireFrame::control(FrameKind::Heartbeat, 4, vec![1; 16]);
        b.send(&f).unwrap();
        assert_eq!(a.recv_timeout(t).unwrap(), Some(f));
        // a dropped peer is a departure, not a timeout
        drop(b);
        let err = a.recv_timeout(t).unwrap_err().to_string();
        assert!(err.contains("departed"), "{err}");
    }

    #[test]
    fn tcp_recv_timeout_probes_without_consuming() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        let mut a = TcpTransport::new(client).unwrap();
        let mut b = TcpTransport::new(server).unwrap();
        let t = std::time::Duration::from_millis(20);
        assert!(b.recv_timeout(t).unwrap().is_none());
        let f = WireFrame::boundary(
            FrameKind::Checkpoint,
            Mode::Raw,
            2,
            0,
            vec![5; 96],
        );
        a.send(&f).unwrap();
        // the probe must not eat header bytes: the whole frame survives
        assert_eq!(
            b.recv_timeout(std::time::Duration::from_secs(5)).unwrap(),
            Some(f)
        );
        drop(a);
        let err = b.recv_timeout(t).unwrap_err().to_string();
        assert!(err.contains("departed"), "{err}");
    }

    #[test]
    fn tcp_pair_roundtrips_frames_on_loopback() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        let mut a = TcpTransport::new(client).unwrap();
        let mut b = TcpTransport::new(server).unwrap();
        let f = WireFrame::boundary(
            FrameKind::Bwd,
            Mode::Quant,
            3,
            1,
            vec![7; 260],
        );
        a.send(&f).unwrap();
        assert_eq!(b.recv().unwrap(), f);
        // peer closing mid-conversation is a departure, not a hang
        drop(a);
        let err = b.recv().unwrap_err().to_string();
        assert!(err.contains("departed"), "{err}");
    }
}
