//! The framed wire protocol for boundary tensors (DESIGN.md §11).
//!
//! Every message between stage workers is one length-prefixed frame:
//!
//! ```text
//! offset  size  field
//! 0       4     magic  b"PMF1"
//! 4       1     kind   (0 hello, 1 fwd, 2 bwd, 3 step-end, 4 bye,
//!                       5 heartbeat, 6 checkpoint, 7 reassign,
//!                       8 grad-ring, 9 grad-gossip, 10 decode,
//!                       11 token)
//! 5       1     codec  Mode::wire_tag for boundary frames, 0xFF control
//! 6       2     reserved (zero)
//! 8       8     step        u64 LE
//! 16      4     microbatch  u32 LE
//! 20      4     payload_len u32 LE
//! 24      …     payload     exactly payload_len bytes
//! ```
//!
//! The payload of a boundary frame is **the exact byte string the
//! [`crate::compress`] codecs emit** (`compress::Frame::payload`) — no
//! re-serialization layer — so a boundary frame's `payload_len` equals
//! `compress::wire_bytes` for every codec whose rust-side frame is the
//! wire representation (all modes except PowerLR, whose dense frame
//! stands in for factor shipping; see [`crate::compress::encode`]).
//! Tensor shapes travel out-of-band: both ends derive them from the
//! handshaked config, exactly as the AOT entry-point shapes are static.
//!
//! Decoding is hardened for untrusted sockets: magic/kind/codec bytes
//! are validated before the length is trusted, and `payload_len` is
//! rejected against [`MAX_PAYLOAD`] *before* any allocation, so a
//! corrupt or hostile peer cannot trigger a multi-gigabyte allocation
//! with a 24-byte header.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::compress::Mode;

/// Frame header magic (`b"PMF1"` — Protocol Models Frame v1).
pub const MAGIC: [u8; 4] = *b"PMF1";

/// Serialized header length in bytes.
pub const HEADER_LEN: usize = 24;

/// Hard ceiling on a frame payload (256 MiB). Far above any boundary
/// tensor this repo ships, far below an allocation that could hurt.
pub const MAX_PAYLOAD: usize = 1 << 28;

/// Codec byte used by control frames (no tensor payload semantics).
pub const CODEC_NONE: u8 = 0xFF;

/// What a frame carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// handshake: payload is the sender's config digest
    Hello,
    /// forward boundary activation payload
    Fwd,
    /// backward activation-gradient payload
    Bwd,
    /// end-of-step relay: loss sum (+ optional new U basis) toward stage 0
    StepEnd,
    /// graceful goodbye before closing the connection
    Bye,
    /// liveness beacon: sender's step + local clock (DESIGN.md §12)
    Heartbeat,
    /// periodic per-stage state snapshot shipped to the leader
    Checkpoint,
    /// leader → worker recovery order: epoch, stage, resume boundary
    /// (+ checkpoint payload when a spare takes over a dead stage)
    Reassign,
    /// one ring-all-reduce chunk of a stage's weight gradients, crossing
    /// the replica ring (DESIGN.md §14); `microbatch` carries the ring
    /// phase, the payload is exact `dp_wire_bytes`-priced codec bytes
    GradRing,
    /// one gossip exchange of a stage's whole weight gradient with the
    /// step's scheduled peer — same dp codec payload, no global barrier
    GradGossip,
    /// one decode step's boundary activations for every active session,
    /// compressed by the boundary codec (DESIGN.md §16); `microbatch`
    /// carries the active-session count the receiver cross-checks
    Decode,
    /// the sampled-token relay toward stage 0: `(session id, token)`
    /// u32 LE pairs, one per active session, 8 B each
    Token,
}

impl FrameKind {
    /// Wire byte of this kind.
    pub fn tag(self) -> u8 {
        match self {
            FrameKind::Hello => 0,
            FrameKind::Fwd => 1,
            FrameKind::Bwd => 2,
            FrameKind::StepEnd => 3,
            FrameKind::Bye => 4,
            FrameKind::Heartbeat => 5,
            FrameKind::Checkpoint => 6,
            FrameKind::Reassign => 7,
            FrameKind::GradRing => 8,
            FrameKind::GradGossip => 9,
            FrameKind::Decode => 10,
            FrameKind::Token => 11,
        }
    }

    /// Inverse of [`FrameKind::tag`].
    pub fn from_tag(tag: u8) -> Option<FrameKind> {
        Some(match tag {
            0 => FrameKind::Hello,
            1 => FrameKind::Fwd,
            2 => FrameKind::Bwd,
            3 => FrameKind::StepEnd,
            4 => FrameKind::Bye,
            5 => FrameKind::Heartbeat,
            6 => FrameKind::Checkpoint,
            7 => FrameKind::Reassign,
            8 => FrameKind::GradRing,
            9 => FrameKind::GradGossip,
            10 => FrameKind::Decode,
            11 => FrameKind::Token,
            _ => return None,
        })
    }

    /// Human-readable label for protocol errors.
    pub fn name(self) -> &'static str {
        match self {
            FrameKind::Hello => "hello",
            FrameKind::Fwd => "fwd",
            FrameKind::Bwd => "bwd",
            FrameKind::StepEnd => "step-end",
            FrameKind::Bye => "bye",
            FrameKind::Heartbeat => "heartbeat",
            FrameKind::Checkpoint => "checkpoint",
            FrameKind::Reassign => "reassign",
            FrameKind::GradRing => "grad-ring",
            FrameKind::GradGossip => "grad-gossip",
            FrameKind::Decode => "decode",
            FrameKind::Token => "token",
        }
    }
}

/// One parsed wire frame.
#[derive(Clone, Debug, PartialEq)]
pub struct WireFrame {
    /// what this frame carries
    pub kind: FrameKind,
    /// boundary codec of the payload (`None` for control frames)
    pub codec: Option<Mode>,
    /// optimizer step the frame belongs to
    pub step: u64,
    /// microbatch index (0 for control frames)
    pub microbatch: u32,
    /// payload bytes — for boundary frames, exactly the
    /// [`crate::compress`] codec output
    pub payload: Vec<u8>,
}

impl WireFrame {
    /// A control frame (hello / step-end / bye).
    pub fn control(kind: FrameKind, step: u64, payload: Vec<u8>) -> WireFrame {
        WireFrame { kind, codec: None, step, microbatch: 0, payload }
    }

    /// A boundary frame wrapping one codec payload.
    pub fn boundary(
        kind: FrameKind,
        codec: Mode,
        step: u64,
        microbatch: usize,
        payload: Vec<u8>,
    ) -> WireFrame {
        debug_assert!(matches!(kind, FrameKind::Fwd | FrameKind::Bwd));
        WireFrame {
            kind,
            codec: Some(codec),
            step,
            microbatch: microbatch as u32,
            payload,
        }
    }

    /// A gradient frame on the data-parallel axis: one ring chunk
    /// (`phase` = ring phase index, reusing the microbatch header slot)
    /// or one whole gossip exchange (`phase` = 0). The payload is the
    /// dp codec's exact byte string — receivers assert `payload_len ==
    /// compress::dp_wire_bytes` before decoding.
    pub fn grad(
        kind: FrameKind,
        codec: Mode,
        step: u64,
        phase: usize,
        payload: Vec<u8>,
    ) -> WireFrame {
        debug_assert!(matches!(
            kind,
            FrameKind::GradRing | FrameKind::GradGossip
        ));
        WireFrame {
            kind,
            codec: Some(codec),
            step,
            microbatch: phase as u32,
            payload,
        }
    }

    /// A decode-boundary frame: one serving step's compressed
    /// activations for `sessions` active sessions. The payload is the
    /// exact boundary-codec byte string for an `(S_active, ·)` tensor;
    /// the receiver cross-checks the session count against its own
    /// replicated batcher state and `payload_len` against
    /// [`crate::memory::decode_frame_bytes`].
    pub fn decode_step(
        codec: Mode,
        step: u64,
        sessions: usize,
        payload: Vec<u8>,
    ) -> WireFrame {
        WireFrame {
            kind: FrameKind::Decode,
            codec: Some(codec),
            step,
            microbatch: sessions as u32,
            payload,
        }
    }

    /// A token-relay frame toward stage 0: `(session id, token)` u32 LE
    /// pairs, one per active session.
    pub fn token_relay(
        step: u64,
        sessions: usize,
        payload: Vec<u8>,
    ) -> WireFrame {
        debug_assert_eq!(payload.len(), sessions * 8);
        WireFrame {
            kind: FrameKind::Token,
            codec: None,
            step,
            microbatch: sessions as u32,
            payload,
        }
    }

    /// Total bytes this frame occupies on the wire.
    pub fn wire_len(&self) -> usize {
        HEADER_LEN + self.payload.len()
    }

    /// Serialize to one contiguous buffer (header + payload).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_len());
        out.extend_from_slice(&MAGIC);
        out.push(self.kind.tag());
        out.push(self.codec.map(Mode::wire_tag).unwrap_or(CODEC_NONE));
        out.extend_from_slice(&[0u8; 2]); // reserved
        out.extend_from_slice(&self.step.to_le_bytes());
        out.extend_from_slice(&self.microbatch.to_le_bytes());
        out.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.payload);
        out
    }

    /// Write this frame to a stream as one buffer (a single syscall on
    /// sockets — keeps small control frames from fragmenting).
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        if self.payload.len() > MAX_PAYLOAD {
            bail!(
                "refusing to send a {} B payload (> MAX_PAYLOAD {})",
                self.payload.len(),
                MAX_PAYLOAD
            );
        }
        w.write_all(&self.to_bytes())
            .context("writing wire frame")?;
        Ok(())
    }

    /// Read one frame, tolerating arbitrarily fragmented reads (TCP
    /// segments, 1-byte test readers): the reader loops until the header
    /// and payload are complete or the stream ends. A stream end is
    /// reported as a departed peer, with the *cut position*
    /// distinguished so chaos assertions can tell a clean shutdown from
    /// a severed link:
    ///
    /// - EOF exactly at a frame boundary (zero header bytes) — the peer
    ///   closed cleanly between frames ("closed cleanly at a frame
    ///   boundary");
    /// - EOF mid-header or mid-payload — the link was cut while a frame
    ///   was in flight ("link severed mid-header" / "mid-payload").
    pub fn read_from(r: &mut impl Read) -> Result<WireFrame> {
        let mut header = [0u8; HEADER_LEN];
        let got = read_full(r, &mut header)
            .map_err(|e| anyhow::anyhow!("reading frame header: {e}"))?;
        if got == 0 {
            bail!(
                "worker departed: connection closed cleanly at a frame \
                 boundary (no frame in flight)"
            );
        }
        if got < HEADER_LEN {
            bail!(
                "worker departed: link severed mid-header (got {got} of \
                 {HEADER_LEN} header bytes)"
            );
        }
        let (kind, codec, step, microbatch, len) = parse_header(&header)?;
        let mut payload = vec![0u8; len];
        let got = read_full(r, &mut payload)
            .map_err(|e| anyhow::anyhow!("reading {len} B frame payload: {e}"))?;
        if got < len {
            bail!(
                "worker departed: link severed mid-payload (got {got} of \
                 {len} payload bytes)"
            );
        }
        Ok(WireFrame { kind, codec, step, microbatch, payload })
    }
}

/// Fill `buf` from `r`, looping over short reads, and return how many
/// bytes actually arrived (less than `buf.len()` only at end of
/// stream). Unlike `read_exact`, the caller learns *where* the stream
/// ended — the information the severed-vs-clean-shutdown distinction
/// needs.
fn read_full(r: &mut impl Read, buf: &mut [u8]) -> std::io::Result<usize> {
    let mut n = 0;
    while n < buf.len() {
        match r.read(&mut buf[n..]) {
            Ok(0) => break,
            Ok(m) => n += m,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(n)
}

/// Validate and destructure a serialized header. Pure — shared by the
/// stream reader and the header unit tests. The payload length is
/// checked against [`MAX_PAYLOAD`] here, before any allocation.
pub fn parse_header(
    h: &[u8; HEADER_LEN],
) -> Result<(FrameKind, Option<Mode>, u64, u32, usize)> {
    if h[0..4] != MAGIC {
        bail!(
            "bad frame magic {:02x?} (expected {:02x?}) — peer is not \
             speaking the protomodels wire protocol",
            &h[0..4],
            MAGIC
        );
    }
    let kind = FrameKind::from_tag(h[4])
        .ok_or_else(|| anyhow::anyhow!("unknown frame kind byte {}", h[4]))?;
    let codec = match h[5] {
        CODEC_NONE => None,
        tag => Some(Mode::from_wire_tag(tag).ok_or_else(|| {
            anyhow::anyhow!("unknown boundary codec byte {tag}")
        })?),
    };
    let step = u64::from_le_bytes([
        h[8], h[9], h[10], h[11], h[12], h[13], h[14], h[15],
    ]);
    let microbatch = u32::from_le_bytes([h[16], h[17], h[18], h[19]]);
    let len = u32::from_le_bytes([h[20], h[21], h[22], h[23]]) as usize;
    if len > MAX_PAYLOAD {
        bail!(
            "frame payload length {len} exceeds MAX_PAYLOAD {MAX_PAYLOAD} \
             — rejecting before allocation"
        );
    }
    Ok((kind, codec, step, microbatch, len))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    /// A reader that hands out at most `chunk` bytes per `read` call —
    /// models short reads on a congested socket.
    struct Trickle<'a> {
        data: &'a [u8],
        pos: usize,
        chunk: usize,
    }

    impl Read for Trickle<'_> {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            let n = buf
                .len()
                .min(self.chunk)
                .min(self.data.len() - self.pos);
            buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    fn sample_frame() -> WireFrame {
        WireFrame::boundary(
            FrameKind::Fwd,
            Mode::Subspace,
            42,
            3,
            vec![1, 2, 3, 4, 5, 6, 7, 8],
        )
    }

    #[test]
    fn roundtrip_through_bytes() {
        let f = sample_frame();
        let bytes = f.to_bytes();
        assert_eq!(bytes.len(), f.wire_len());
        let g = WireFrame::read_from(&mut Cursor::new(&bytes)).unwrap();
        assert_eq!(f, g);
    }

    #[test]
    fn bf16_codec_bytes_roundtrip() {
        // the bf16 wires (tags 6/7) are first-class boundary codecs:
        // their 2-byte-per-element payloads frame and parse unchanged
        let payload = crate::compress::encode_dense_bf16(
            &crate::tensor::Tensor::new(
                vec![2, 3],
                vec![1.5, -2.25, 0.0, 3.75e8, -1.0e-9, 42.0],
            ),
            Mode::RawBf16,
        )
        .payload;
        for mode in [Mode::RawBf16, Mode::SubspaceBf16] {
            let f = WireFrame::boundary(
                FrameKind::Fwd,
                mode,
                11,
                0,
                payload.clone(),
            );
            let bytes = f.to_bytes();
            assert_eq!(bytes[5], mode.wire_tag());
            let g = WireFrame::read_from(&mut Cursor::new(&bytes)).unwrap();
            assert_eq!(g, f);
            assert_eq!(g.codec, Some(mode));
        }
    }

    #[test]
    fn control_frames_carry_no_codec() {
        let f = WireFrame::control(FrameKind::StepEnd, 7, vec![0u8; 8]);
        let bytes = f.to_bytes();
        assert_eq!(bytes[5], CODEC_NONE);
        let g = WireFrame::read_from(&mut Cursor::new(&bytes)).unwrap();
        assert_eq!(g.codec, None);
        assert_eq!(g.kind, FrameKind::StepEnd);
        assert_eq!(g.step, 7);
    }

    #[test]
    fn survives_one_byte_reads() {
        // partial/short reads: the reader loops until the frame is whole
        let f = sample_frame();
        let bytes = f.to_bytes();
        let mut r = Trickle { data: &bytes, pos: 0, chunk: 1 };
        let g = WireFrame::read_from(&mut r).unwrap();
        assert_eq!(f, g);
    }

    #[test]
    fn truncated_header_and_payload_report_departure() {
        let bytes = sample_frame().to_bytes();
        // cut inside the header: a severed link, and the message says so
        let err = WireFrame::read_from(&mut Cursor::new(&bytes[..10]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("departed"), "{err}");
        assert!(err.contains("severed mid-header"), "{err}");
        // cut inside the payload: severed too, at the other position
        let err = WireFrame::read_from(&mut Cursor::new(
            &bytes[..HEADER_LEN + 3],
        ))
        .unwrap_err()
        .to_string();
        assert!(err.contains("departed"), "{err}");
        assert!(err.contains("severed mid-payload"), "{err}");
        // clean EOF before any bytes is a departure as well, but a
        // *clean-shutdown* one — chaos assertions tell them apart
        let err = WireFrame::read_from(&mut Cursor::new(&[] as &[u8]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("departed"), "{err}");
        assert!(err.contains("frame boundary"), "{err}");
        assert!(!err.contains("severed"), "{err}");
    }

    #[test]
    fn liveness_frame_kinds_roundtrip_with_stable_tags() {
        // the recovery protocol's kinds append to the tag space — the
        // wire numbering is a compatibility contract, like Mode tags
        for (kind, tag) in [
            (FrameKind::Heartbeat, 5u8),
            (FrameKind::Checkpoint, 6),
            (FrameKind::Reassign, 7),
            (FrameKind::GradRing, 8),
            (FrameKind::GradGossip, 9),
        ] {
            assert_eq!(kind.tag(), tag);
            assert_eq!(FrameKind::from_tag(tag), Some(kind));
            let f = WireFrame::control(kind, 9, vec![0xEE; 16]);
            let bytes = f.to_bytes();
            assert_eq!(bytes[4], tag);
            let g = WireFrame::read_from(&mut Cursor::new(&bytes)).unwrap();
            assert_eq!(g, f);
        }
    }

    #[test]
    fn grad_frames_carry_codec_and_phase() {
        // the DP kinds (tags 8/9) ride the same header: codec byte names
        // the dp scheme, the microbatch slot carries the ring phase
        for kind in [FrameKind::GradRing, FrameKind::GradGossip] {
            let f = WireFrame::grad(kind, Mode::Quant, 13, 2, vec![9u8; 12]);
            let bytes = f.to_bytes();
            assert_eq!(bytes[4], kind.tag());
            assert_eq!(bytes[5], Mode::Quant.wire_tag());
            let g = WireFrame::read_from(&mut Cursor::new(&bytes)).unwrap();
            assert_eq!(g, f);
            assert_eq!(g.microbatch, 2);
        }
    }

    #[test]
    fn serving_frame_kinds_roundtrip_with_stable_tags() {
        // the decode protocol's kinds append to the tag space like
        // every extension before them (tags 10/11)
        assert_eq!(FrameKind::Decode.tag(), 10);
        assert_eq!(FrameKind::Token.tag(), 11);
        assert_eq!(FrameKind::from_tag(10), Some(FrameKind::Decode));
        assert_eq!(FrameKind::from_tag(11), Some(FrameKind::Token));
        assert_eq!(FrameKind::from_tag(12), None);
        let d = WireFrame::decode_step(Mode::Subspace, 17, 3, vec![4u8; 72]);
        let bytes = d.to_bytes();
        assert_eq!(bytes[4], 10);
        assert_eq!(bytes[5], Mode::Subspace.wire_tag());
        let g = WireFrame::read_from(&mut Cursor::new(&bytes)).unwrap();
        assert_eq!(g, d);
        assert_eq!(g.microbatch, 3); // active-session count rides along
        let t = WireFrame::token_relay(17, 2, vec![0u8; 16]);
        let bytes = t.to_bytes();
        assert_eq!(bytes[4], 11);
        assert_eq!(bytes[5], CODEC_NONE); // token relays are control-coded
        let g = WireFrame::read_from(&mut Cursor::new(&bytes)).unwrap();
        assert_eq!(g, t);
    }

    #[test]
    fn oversized_length_rejected_before_allocation() {
        let mut bytes = sample_frame().to_bytes();
        bytes[20..24]
            .copy_from_slice(&((MAX_PAYLOAD as u32) + 1).to_le_bytes());
        let err = WireFrame::read_from(&mut Cursor::new(&bytes))
            .unwrap_err()
            .to_string();
        assert!(err.contains("MAX_PAYLOAD"), "{err}");
    }

    #[test]
    fn bad_magic_kind_and_codec_rejected() {
        let good = sample_frame().to_bytes();
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(WireFrame::read_from(&mut Cursor::new(&bad))
            .unwrap_err()
            .to_string()
            .contains("magic"));
        let mut bad = good.clone();
        bad[4] = 99;
        assert!(WireFrame::read_from(&mut Cursor::new(&bad))
            .unwrap_err()
            .to_string()
            .contains("kind"));
        let mut bad = good;
        bad[5] = 42;
        assert!(WireFrame::read_from(&mut Cursor::new(&bad))
            .unwrap_err()
            .to_string()
            .contains("codec"));
    }

    #[test]
    fn interleaved_microbatches_parse_in_order() {
        // two microbatches' frames back-to-back in one stream — headers
        // keep them apart without any out-of-band framing
        let f0 = WireFrame::boundary(
            FrameKind::Fwd,
            Mode::Raw,
            5,
            0,
            vec![0xA0; 16],
        );
        let f1 = WireFrame::boundary(
            FrameKind::Fwd,
            Mode::Raw,
            5,
            1,
            vec![0xB1; 24],
        );
        let mut stream = f0.to_bytes();
        stream.extend_from_slice(&f1.to_bytes());
        let mut cur = Cursor::new(&stream);
        let g0 = WireFrame::read_from(&mut cur).unwrap();
        let g1 = WireFrame::read_from(&mut cur).unwrap();
        assert_eq!(g0, f0);
        assert_eq!(g1, f1);
        assert_eq!(g0.microbatch, 0);
        assert_eq!(g1.microbatch, 1);
    }
}
