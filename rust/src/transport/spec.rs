//! The unified run-description core shared by training and serving.
//!
//! [`SpecCore`] is the workload-independent heart of a run: model
//! dimensions, boundary codec, seed, optimizer, data source, and step
//! budget. [`super::dp::TrainSpec`] composes it with the data-parallel
//! axis (replicas, reduce, elastic options); [`ServeSpec`] composes it
//! with the inference-serving axis (traffic model, continuous-batching
//! width). Both expose the same builder/`validate()`/digest discipline,
//! and both derive their `Hello` handshake digest through
//! [`Workload`]-tagged `PMCFG3` material — `PMCFG3 = PMCFG2 ‖
//! workload-tag` — so a train worker and a serve worker launched
//! against the same host/ports refuse to connect instead of
//! desynchronizing silently.
//!
//! Historically this struct was `transport::dist::WorkerSpec`; the
//! alias is kept so existing call sites (and the `PMCFG1` digest
//! layout) stay valid.

use anyhow::{bail, Result};

use crate::compress::Mode;
use crate::coordinator::PipelineConfig;
use crate::data::{Corpus, CorpusKind};
use crate::manifest::Hyper;
use crate::nn::Optim;
use crate::sim::Schedule;

/// Which workload a worker participates in. The tag byte terminates the
/// `PMCFG3` handshake digest, so train and serve workers can never
/// cross-connect: their digests differ in the final byte even when
/// every shared field agrees.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workload {
    /// gradient-descent training (`launch`, `serve --stage`)
    Train,
    /// autoregressive decode serving (`serve-infer`)
    Serve,
}

impl Workload {
    /// Digest tag byte of this workload.
    pub fn tag(self) -> u8 {
        match self {
            Workload::Train => 0,
            Workload::Serve => 1,
        }
    }
}

/// Wrap workload-specific digest material into the `PMCFG3` handshake
/// digest: `b"PMCFG3" ‖ material ‖ workload-tag`.
pub fn handshake_wrap(material: &[u8], workload: Workload) -> Vec<u8> {
    let mut d = Vec::with_capacity(material.len() + 7);
    d.extend_from_slice(b"PMCFG3");
    d.extend_from_slice(material);
    d.push(workload.tag());
    d
}

/// The shared run-description core: everything a single stage worker
/// needs that is independent of the workload axis. Two workers whose
/// cores differ in any digested field refuse to run together.
#[derive(Clone, Debug)]
pub struct SpecCore {
    /// model/pipeline dimensions
    pub h: Hyper,
    /// run-level configuration (mode, microbatches, seed, lr schedule,
    /// Grassmann cadence, pipeline schedule)
    pub cfg: PipelineConfig,
    /// optimizer every stage steps with (training workloads)
    pub optim: Optim,
    /// step budget: optimizer steps when training, decode steps when
    /// serving
    pub steps: usize,
    /// synthetic corpus preset (training data / serve prompt source)
    pub corpus_kind: CorpusKind,
    /// corpus length in tokens
    pub corpus_tokens: usize,
}

/// The historical name of [`SpecCore`], kept for every existing call
/// site: a "worker spec" is exactly the workload-independent core.
pub type WorkerSpec = SpecCore;

impl SpecCore {
    /// Start a builder from model dimensions.
    pub fn builder(h: Hyper) -> SpecCoreBuilder {
        SpecCoreBuilder::new(h)
    }

    /// The corpus every worker regenerates locally (same derivation as
    /// `train --backend native` and the native examples).
    pub fn corpus(&self) -> Corpus {
        Corpus::synthetic(
            self.corpus_kind,
            self.h.vocab,
            self.corpus_tokens,
            self.cfg.seed ^ 0xDD,
        )
    }

    /// Reject cores the distributed runtimes cannot execute.
    pub fn validate(&self) -> Result<()> {
        if self.h.stages < 2 {
            bail!("distributed pipeline needs >= 2 stages, got {}", self.h.stages);
        }
        if self.cfg.microbatches == 0 {
            bail!("need >= 1 microbatch");
        }
        if matches!(self.cfg.schedule, Schedule::Interleaved { .. }) {
            bail!(
                "interleaved schedules are simulator-only \
                 (`protomodels sim --schedule interleaved`); the \
                 transport runs gpipe or 1f1b wave orders"
            );
        }
        Ok(())
    }

    /// Canonical byte digest of every numerics-affecting field
    /// (`PMCFG1`). Fields that cannot change the numbers (time model,
    /// event-sim routing, grad recording) are deliberately excluded.
    pub fn digest(&self) -> Vec<u8> {
        let h = &self.h;
        let c = &self.cfg;
        let mut d = Vec::with_capacity(96);
        d.extend_from_slice(b"PMCFG1");
        for v in [
            h.d, h.d_ff, h.heads, h.layers, h.stages, h.n, h.vocab, h.k,
            h.b, h.blocks_per_stage,
        ] {
            d.extend_from_slice(&(v as u64).to_le_bytes());
        }
        d.extend_from_slice(&h.ratio.to_le_bytes());
        d.push(c.mode.wire_tag());
        d.extend_from_slice(&(c.microbatches as u64).to_le_bytes());
        d.extend_from_slice(&(c.grassmann_interval as u64).to_le_bytes());
        d.extend_from_slice(&c.grassmann_eta.to_le_bytes());
        d.extend_from_slice(&c.lr.to_le_bytes());
        d.extend_from_slice(&(c.warmup_steps as u64).to_le_bytes());
        d.extend_from_slice(&(c.total_steps as u64).to_le_bytes());
        d.extend_from_slice(&c.seed.to_le_bytes());
        d.push(match c.schedule {
            Schedule::Gpipe => 0,
            Schedule::OneFOneB => 1,
            Schedule::Interleaved { .. } => 2, // rejected by validate()
        });
        match self.optim {
            Optim::AdamW => d.push(0),
            Optim::Sgd { momentum } => {
                d.push(1);
                d.extend_from_slice(&momentum.to_le_bytes());
            }
        }
        d.push(match self.corpus_kind {
            CorpusKind::Wiki => 0,
            CorpusKind::Books => 1,
            CorpusKind::Web => 2,
            CorpusKind::C4 => 3,
        });
        d.extend_from_slice(&(self.corpus_tokens as u64).to_le_bytes());
        d.extend_from_slice(&(self.steps as u64).to_le_bytes());
        d
    }
}

/// Builder for [`SpecCore`] — every setter returns `self`; `build`
/// validates with descriptive errors.
pub struct SpecCoreBuilder {
    core: SpecCore,
}

impl SpecCoreBuilder {
    fn new(h: Hyper) -> SpecCoreBuilder {
        let cfg = PipelineConfig { total_steps: 200, ..Default::default() };
        SpecCoreBuilder {
            core: SpecCore {
                h,
                cfg,
                optim: Optim::AdamW,
                steps: 200,
                corpus_kind: CorpusKind::Wiki,
                corpus_tokens: 400_000,
            },
        }
    }

    /// Boundary compression mode.
    pub fn mode(mut self, m: Mode) -> Self {
        self.core.cfg.mode = m;
        self
    }

    /// Step budget (also sets the LR schedule horizon).
    pub fn steps(mut self, n: usize) -> Self {
        self.core.steps = n;
        self.core.cfg.total_steps = n;
        self
    }

    /// Run seed (init, data, traffic, gossip schedules).
    pub fn seed(mut self, s: u64) -> Self {
        self.core.cfg.seed = s;
        self
    }

    /// Synthetic corpus preset and length.
    pub fn corpus(mut self, kind: CorpusKind, tokens: usize) -> Self {
        self.core.corpus_kind = kind;
        self.core.corpus_tokens = tokens;
        self
    }

    /// Optimizer (training workloads).
    pub fn optim(mut self, o: Optim) -> Self {
        self.core.optim = o;
        self
    }

    /// Escape hatch for rarely-set core fields.
    pub fn tweak(mut self, f: impl FnOnce(&mut SpecCore)) -> Self {
        f(&mut self.core);
        self
    }

    /// Validate and return the core.
    pub fn build(self) -> Result<SpecCore> {
        self.core.validate()?;
        Ok(self.core)
    }
}

// ---------------------------------------------------------------------------
// serving spec
// ---------------------------------------------------------------------------

/// The synthetic open-loop traffic model: sessions arrive on a seeded
/// Poisson-like clock regardless of service progress (open loop — the
/// generator never waits for the system), each with a seeded prompt
/// drawn from the shared corpus and a seeded generation budget.
#[derive(Clone, Debug)]
pub struct TrafficSpec {
    /// total sessions the generator emits
    pub sessions: usize,
    /// mean inter-arrival gap in decode steps (0 = all at step 0)
    pub mean_gap: f64,
    /// inclusive prompt-length range in tokens
    pub prompt: (usize, usize),
    /// inclusive generation-budget range in tokens
    pub gen: (usize, usize),
}

impl Default for TrafficSpec {
    fn default() -> Self {
        TrafficSpec {
            sessions: 8,
            mean_gap: 2.0,
            prompt: (4, 8),
            gen: (4, 8),
        }
    }
}

/// The canonical, validated description of an inference-serving run:
/// the shared [`SpecCore`] plus the serving axis — traffic model and
/// continuous-batching width. The serve analogue of
/// [`super::dp::TrainSpec`]; `serve_infer` digests it into the
/// handshake, every stage worker derives the full session table and
/// batching schedule from it deterministically.
#[derive(Clone, Debug)]
pub struct ServeSpec {
    /// the shared run core (model, codec, seed, decode-step budget)
    pub core: SpecCore,
    /// the open-loop traffic the run serves
    pub traffic: TrafficSpec,
    /// continuous-batching width: max concurrent sessions per step
    pub max_batch: usize,
}

impl ServeSpec {
    /// Wrap a core with default traffic.
    pub fn from_core(core: SpecCore) -> ServeSpec {
        ServeSpec { core, traffic: TrafficSpec::default(), max_batch: 4 }
    }

    /// Start a builder from model dimensions.
    pub fn builder(h: Hyper) -> ServeSpecBuilder {
        ServeSpecBuilder {
            spec: ServeSpec::from_core(SpecCoreBuilder::new(h).core),
        }
    }

    /// Reject configurations the serving runtime cannot execute — with
    /// errors that say *why* and what to do instead.
    pub fn validate(&self) -> Result<()> {
        self.core.validate()?;
        let t = &self.traffic;
        if t.sessions == 0 {
            bail!("traffic needs >= 1 session");
        }
        if t.sessions > 1024 {
            bail!(
                "traffic of {} sessions exceeds the tested ceiling of \
                 1024; shard the workload across runs",
                t.sessions
            );
        }
        if self.max_batch == 0 {
            bail!("continuous batching needs --max-batch >= 1");
        }
        if t.prompt.0 == 0 {
            bail!("prompts need >= 1 token");
        }
        if t.prompt.0 > t.prompt.1 || t.gen.0 > t.gen.1 {
            bail!(
                "traffic ranges must be lo <= hi (prompt {}..{}, gen \
                 {}..{})",
                t.prompt.0,
                t.prompt.1,
                t.gen.0,
                t.gen.1
            );
        }
        if t.gen.0 == 0 {
            bail!("generation budgets need >= 1 token");
        }
        if !(t.mean_gap.is_finite() && t.mean_gap >= 0.0) {
            bail!("mean inter-arrival gap must be finite and >= 0");
        }
        let n = self.core.h.n;
        if t.prompt.1 + t.gen.1 - 1 > n {
            bail!(
                "a session may touch up to prompt+gen-1 = {} positions, \
                 but the model context (and per-session KV capacity) is \
                 n = {n}; shrink --prompt/--gen or grow the model",
                t.prompt.1 + t.gen.1 - 1
            );
        }
        if self.core.steps == 0 {
            bail!("serve needs a decode-step budget of >= 1 step");
        }
        Ok(())
    }

    /// The serve handshake digest: `PMCFG3` wrapping the train-shaped
    /// `PMCFG2` core material plus every serving-axis field, terminated
    /// by the [`Workload::Serve`] tag — byte-incompatible with every
    /// train worker's digest by construction.
    pub fn handshake_digest(&self) -> Vec<u8> {
        let mut m =
            super::dp::TrainSpec::from_worker(self.core.clone()).digest();
        let t = &self.traffic;
        m.extend_from_slice(&(t.sessions as u64).to_le_bytes());
        m.extend_from_slice(&t.mean_gap.to_le_bytes());
        for v in [t.prompt.0, t.prompt.1, t.gen.0, t.gen.1, self.max_batch] {
            m.extend_from_slice(&(v as u64).to_le_bytes());
        }
        handshake_wrap(&m, Workload::Serve)
    }
}

/// Builder for [`ServeSpec`] — core setters plus the serving axis;
/// `build` validates.
pub struct ServeSpecBuilder {
    spec: ServeSpec,
}

impl ServeSpecBuilder {
    /// Boundary compression mode.
    pub fn mode(mut self, m: Mode) -> Self {
        self.spec.core.cfg.mode = m;
        self
    }

    /// Decode-step budget.
    pub fn steps(mut self, n: usize) -> Self {
        self.spec.core.steps = n;
        self.spec.core.cfg.total_steps = n;
        self
    }

    /// Run seed (init, prompts, arrivals).
    pub fn seed(mut self, s: u64) -> Self {
        self.spec.core.cfg.seed = s;
        self
    }

    /// Synthetic corpus preset and length (the prompt source).
    pub fn corpus(mut self, kind: CorpusKind, tokens: usize) -> Self {
        self.spec.core.corpus_kind = kind;
        self.spec.core.corpus_tokens = tokens;
        self
    }

    /// Traffic model.
    pub fn traffic(mut self, t: TrafficSpec) -> Self {
        self.spec.traffic = t;
        self
    }

    /// Continuous-batching width.
    pub fn max_batch(mut self, b: usize) -> Self {
        self.spec.max_batch = b;
        self
    }

    /// Escape hatch for rarely-set core fields.
    pub fn tweak(mut self, f: impl FnOnce(&mut SpecCore)) -> Self {
        f(&mut self.spec.core);
        self
    }

    /// Validate and return the spec.
    pub fn build(self) -> Result<ServeSpec> {
        self.spec.validate()?;
        Ok(self.spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_serve() -> ServeSpec {
        ServeSpec::builder(Hyper::tiny_native())
            .mode(Mode::Subspace)
            .steps(500)
            .seed(7)
            .traffic(TrafficSpec {
                sessions: 3,
                mean_gap: 1.0,
                prompt: (2, 4),
                gen: (2, 4),
            })
            .max_batch(2)
            .build()
            .unwrap()
    }

    #[test]
    fn serve_spec_validates_descriptively() {
        let mut s = tiny_serve();
        s.traffic.sessions = 0;
        assert!(s.validate().unwrap_err().to_string().contains("session"));
        let mut s = tiny_serve();
        s.max_batch = 0;
        assert!(s.validate().unwrap_err().to_string().contains("max-batch"));
        let mut s = tiny_serve();
        s.traffic.prompt = (5, 2);
        assert!(s.validate().unwrap_err().to_string().contains("lo <= hi"));
        let mut s = tiny_serve();
        s.traffic.prompt = (30, 30);
        s.traffic.gen = (30, 30);
        let err = s.validate().unwrap_err().to_string();
        assert!(err.contains("KV capacity"), "{err}");
        let mut s = tiny_serve();
        s.traffic.mean_gap = f64::NAN;
        assert!(s.validate().is_err());
    }

    #[test]
    fn train_and_serve_handshakes_never_match() {
        let s = tiny_serve();
        let t = super::super::dp::TrainSpec::from_worker(s.core.clone());
        let hs = s.handshake_digest();
        let ht = t.handshake_digest();
        assert_ne!(hs, ht);
        // both are PMCFG3 material with the workload tag terminal
        assert_eq!(&hs[..6], b"PMCFG3");
        assert_eq!(&ht[..6], b"PMCFG3");
        assert_eq!(*hs.last().unwrap(), Workload::Serve.tag());
        assert_eq!(*ht.last().unwrap(), Workload::Train.tag());
        // the shared PMCFG2 core material is a common prefix
        let cut = ht.len() - 1;
        assert_eq!(&hs[..cut], &ht[..cut]);
    }

    #[test]
    fn core_builder_round_trips_through_both_specs() {
        let core = SpecCore::builder(Hyper::tiny_native())
            .mode(Mode::Raw)
            .steps(12)
            .seed(9)
            .build()
            .unwrap();
        let t = super::super::dp::TrainSpec::from_worker(core.clone());
        let s = ServeSpec::from_core(core.clone());
        assert_eq!(t.worker.digest(), s.core.digest());
        assert_eq!(core.cfg.total_steps, 12);
    }
}
