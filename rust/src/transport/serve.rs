//! Distributed inference serving: staged autoregressive decode over the
//! training pipeline's stages, codecs, and wire (DESIGN.md §16).
//!
//! The training transport ships *batched sequence* boundaries; serving
//! ships *one new row per session per step*. Everything else is reused
//! deliberately:
//!
//! - the forward arithmetic is [`crate::nn::StageDecoder`] — the
//!   tape-free single-row mirror of the training kernels, over the same
//!   seeded parameter init every worker replays;
//! - boundary activations cross stage boundaries through the same
//!   [`crate::compress`] codecs inside `PMF1` frames
//!   ([`FrameKind::Decode`]), with `payload_len` asserted against
//!   [`crate::memory::decode_frame_bytes`];
//! - sampled tokens relay back to stage 0 as [`FrameKind::Token`]
//!   frames — 8 B per session per step, the *entire* backward traffic.
//!
//! **Per-session encoding.** A `Decode` frame's payload is the
//! concatenation of `S_active` independent per-session codec payloads
//! (each session's row encoded as its own `(1, k)` / `(1, d)` tensor),
//! *not* one packed `(S, ·)` encode. The lossy codecs are batch-coupled
//! (top-k selection and the int8 scale span the whole tensor), so
//! per-session encoding is what makes the continuous batcher's
//! admissions and evictions provably unable to perturb a surviving
//! session's token stream — the eviction-invariance property
//! `tests/serve_infer.rs` checks. Every mode's per-session payload is
//! the same length across sessions, so the receiver slices evenly.
//!
//! **Replicated batching.** There is no admission control plane on the
//! wire: every stage derives the identical session table (seeded
//! arrivals, prompts, generation budgets — [`generate_sessions`]) and
//! runs the identical [`Batcher`] state machine, so the active-session
//! list agrees everywhere by construction. Frames cross-check it: the
//! `Decode` header carries the sender's active count, the `Token`
//! payload carries session ids, and any disagreement is a protocol
//! error, not silence.
//!
//! Three entries, one protocol: [`run_serve_local`] (single process,
//! codecs round-tripped in memory), [`serve_infer`] (threads joined by
//! channel or loopback-TCP transports), and [`serve_infer_stage`] (one
//! stage per OS process over real TCP, shimming
//! [`super::launch_serve`]). All three produce bitwise-identical token
//! streams for every codec — the serving analogue of the training
//! parity contract.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::compress::{self, Mode};
use crate::manifest::Hyper;
use crate::memory;
use crate::nn::decode::{argmax, StageDecoder, StageKv};
use crate::nn::model::sinusoidal_pe;
use crate::obs::trace;
use crate::rng::Rng;
use crate::stage::{GlobalState, StageState};
use crate::tensor::Tensor;

use super::dist::{chain_ends, recv_expect, tcp_chain_links, TransportKind};
use super::spec::ServeSpec;
use super::{FrameKind, Transport, WireFrame, HEADER_LEN};

// ---------------------------------------------------------------------------
// session table + batcher (replicated on every stage)
// ---------------------------------------------------------------------------

/// One generated session: its arrival time on the open-loop clock, its
/// prompt drawn from the shared corpus, and its generation budget.
#[derive(Clone, Debug, PartialEq, Eq)]
pub(crate) struct SessionSpec {
    /// session id — also the arrival order
    pub id: u32,
    /// decode step at (or after) which the session may be admitted
    pub arrival_step: u64,
    /// prompt token ids
    pub prompt: Vec<u32>,
    /// tokens to generate after the prompt
    pub gen: usize,
}

impl SessionSpec {
    /// Positions the session occupies a batch slot for: the prompt is
    /// prefilled one position per step through the same pipeline, and
    /// the logits at position `prompt-1 .. prompt+gen-2` each yield one
    /// generated token.
    pub fn total_positions(&self) -> usize {
        self.prompt.len() + self.gen - 1
    }
}

/// Derive the full session table from the spec — same derivation on
/// every worker (seed `cfg.seed ^ 0x5E4E`), so serving needs no
/// admission control plane. Inter-arrival gaps are exponential with the
/// spec's mean (an open-loop Poisson clock: arrivals never wait for the
/// system), prompts are corpus windows, budgets uniform in range.
pub(crate) fn generate_sessions(spec: &ServeSpec) -> Result<Vec<SessionSpec>> {
    spec.validate()?;
    let t = &spec.traffic;
    let corpus = spec.core.corpus();
    let mut rng = Rng::new(spec.core.cfg.seed ^ 0x5E4E);
    let mut clock = 0.0f64;
    let mut out = Vec::with_capacity(t.sessions);
    for id in 0..t.sessions {
        if id > 0 && t.mean_gap > 0.0 {
            clock += -t.mean_gap * (1.0 - rng.uniform()).ln();
        }
        let plen = t.prompt.0 + rng.below(t.prompt.1 - t.prompt.0 + 1);
        let gen = t.gen.0 + rng.below(t.gen.1 - t.gen.0 + 1);
        let (x, _) = corpus.train_batch(1, plen, &mut rng);
        out.push(SessionSpec {
            id: id as u32,
            arrival_step: clock.floor() as u64,
            prompt: x.data.iter().map(|&v| v as u32).collect(),
            gen,
        });
    }
    Ok(out)
}

/// The continuous-batching state machine every stage replicates:
/// admission in arrival order while a slot is free, one position per
/// active session per step, eviction the step a session finishes. Pure
/// control flow (no model state), so the serving simulator replays it
/// verbatim for the predicted schedule.
pub(crate) struct Batcher {
    arrivals: Vec<u64>,
    totals: Vec<usize>,
    processed: Vec<usize>,
    next_pending: usize,
    active: Vec<u32>,
    max_batch: usize,
}

impl Batcher {
    /// Build from the replicated session table.
    pub fn new(sessions: &[SessionSpec], max_batch: usize) -> Batcher {
        Batcher {
            arrivals: sessions.iter().map(|s| s.arrival_step).collect(),
            totals: sessions.iter().map(|s| s.total_positions()).collect(),
            processed: vec![0; sessions.len()],
            next_pending: 0,
            active: Vec::new(),
            max_batch,
        }
    }

    /// Admit arrived sessions into free slots (arrival order); returns
    /// the newly admitted ids.
    pub fn admit(&mut self, step: u64) -> Vec<u32> {
        let mut newly = Vec::new();
        while self.next_pending < self.arrivals.len()
            && self.active.len() < self.max_batch
            && self.arrivals[self.next_pending] <= step
        {
            let sid = self.next_pending as u32;
            self.active.push(sid);
            newly.push(sid);
            self.next_pending += 1;
        }
        newly
    }

    /// Currently active session ids, admission order.
    pub fn active(&self) -> &[u32] {
        &self.active
    }

    /// Positions already processed for a session — equivalently its next
    /// decode position. Exposed for the serving-schedule simulator.
    pub fn position(&self, sid: u32) -> usize {
        self.processed[sid as usize]
    }

    /// Arrival step of the next not-yet-admitted session.
    pub fn next_arrival(&self) -> Option<u64> {
        self.arrivals.get(self.next_pending).copied()
    }

    /// Account one processed position per active session and evict the
    /// finished ones; returns the evicted ids.
    pub fn advance(&mut self) -> Vec<u32> {
        for &sid in &self.active {
            self.processed[sid as usize] += 1;
        }
        let mut finished = Vec::new();
        let processed = &self.processed;
        let totals = &self.totals;
        self.active.retain(|&sid| {
            let done = processed[sid as usize] >= totals[sid as usize];
            if done {
                finished.push(sid);
            }
            !done
        });
        finished
    }

    /// Whether every session has been admitted and evicted.
    pub fn finished(&self) -> bool {
        self.active.is_empty() && self.next_pending >= self.arrivals.len()
    }
}

// ---------------------------------------------------------------------------
// per-session boundary codec
// ---------------------------------------------------------------------------

/// Logical width of one session's boundary row on a link.
fn row_width(h: &Hyper, mode: Mode) -> usize {
    if mode.compressed() {
        h.k
    } else {
        h.d
    }
}

/// Bytes one session contributes to a `Decode` frame's payload.
/// Everything except PowerLR matches [`compress::wire_bytes`] for a
/// `(1, 1)` boundary exactly; PowerLR ships its dense `d`-float
/// stand-in (the training wire's documented exemption) while the
/// *priced* bytes follow the factor formula.
pub(crate) fn session_payload_len(h: &Hyper, mode: Mode) -> usize {
    match mode {
        Mode::PowerLR => h.d * 4,
        m => compress::wire_bytes(m, 1, 1, h.d, h.k, h.ratio),
    }
}

/// Encode one session's boundary row for the link out of `stage`.
/// PowerLR's sketch RNG is keyed by (seed, link, session, *position*) —
/// deliberately not by the decode step — so a session's wire bytes
/// depend only on its own history, never on when the batcher happened
/// to schedule it (eviction invariance extends to PowerLR).
fn encode_session_row(
    h: &Hyper,
    mode: Mode,
    seed: u64,
    link: usize,
    sid: u32,
    pos: usize,
    row: &[f32],
) -> Vec<u8> {
    let t = Tensor::new(vec![1, row.len()], row.to_vec());
    let f = match mode {
        Mode::PowerLR => {
            let rank = compress::powerlr_rank(1, h.d, h.ratio);
            let mut rng = Rng::new(
                seed ^ 0x53E7
                    ^ (pos as u64).wrapping_mul(0x9E37)
                    ^ ((link as u64) << 20)
                    ^ ((sid as u64) << 4),
            );
            let reduced = crate::linalg::low_rank_approx(&t, rank, &mut rng);
            compress::encode_dense(&reduced, Mode::PowerLR)
        }
        m => compress::encode(&t, m, h.ratio),
    };
    f.payload
}

/// Decode one session's slice of a `Decode` frame payload.
fn decode_session_row(h: &Hyper, mode: Mode, slice: &[u8]) -> Vec<f32> {
    let f = compress::Frame {
        mode,
        shape: vec![1, row_width(h, mode)],
        payload: slice.to_vec(),
    };
    compress::decode(&f).data
}

// ---------------------------------------------------------------------------
// reports
// ---------------------------------------------------------------------------

/// Per-session serving outcome, recorded by every stage (they agree by
/// construction; stage 0's copy is the canonical report).
#[derive(Clone, Debug)]
pub struct SessionStat {
    /// session id
    pub id: u32,
    /// open-loop arrival step
    pub arrival_step: u64,
    /// step the batcher admitted the session
    pub admit_step: u64,
    /// step the first generated token was produced
    pub first_token_step: u64,
    /// step the session finished (last token produced)
    pub done_step: u64,
    /// prompt length in tokens
    pub prompt_len: usize,
    /// generation budget
    pub gen: usize,
    /// the generated tokens (exactly `gen` of them)
    pub tokens: Vec<u32>,
    /// wall seconds, admission → completion
    pub latency_s: f64,
    /// wall seconds, admission → first generated token
    pub first_token_s: f64,
}

/// One serving run's measured accounting. Byte counters hold what this
/// worker actually put on (or priced for) its links: the single-process
/// runner aggregates every link of the chain; a distributed stage
/// counts its own sends.
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    /// pipeline stage this report came from (0 for `run_serve_local`)
    pub stage: usize,
    /// per-session stats, session-id order
    pub sessions: Vec<SessionStat>,
    /// decode steps executed (idle fast-forwards excluded)
    pub steps: u64,
    /// total generated tokens
    pub tokens_generated: u64,
    /// wall seconds of each executed decode step
    pub step_seconds: Vec<f64>,
    /// `Decode` frame payload bytes sent
    pub decode_payload_bytes: u64,
    /// `Token` frame payload bytes sent / relayed
    pub token_payload_bytes: u64,
    /// full wire bytes sent, frame headers included
    pub wire_bytes: u64,
    /// frames sent
    pub frames: u64,
    /// peak simultaneous K/V residency on one stage, bytes
    pub kv_peak_bytes: usize,
}

impl ServeReport {
    /// Total measured wall seconds across executed decode steps.
    pub fn wall_seconds(&self) -> f64 {
        self.step_seconds.iter().sum()
    }

    /// Generated-token throughput over the measured wall time.
    pub fn tokens_per_sec(&self) -> f64 {
        let w = self.wall_seconds();
        if w > 0.0 {
            self.tokens_generated as f64 / w
        } else {
            0.0
        }
    }

    /// Mean wall seconds per executed decode step.
    pub fn mean_step_seconds(&self) -> f64 {
        if self.step_seconds.is_empty() {
            0.0
        } else {
            self.wall_seconds() / self.step_seconds.len() as f64
        }
    }

    /// Nearest-rank percentile (`p` in 0..=100) of per-session
    /// admission→completion latency, seconds.
    pub fn latency_percentile(&self, p: f64) -> f64 {
        let mut v: Vec<f64> =
            self.sessions.iter().map(|s| s.latency_s).collect();
        if v.is_empty() {
            return 0.0;
        }
        v.sort_by(|a, b| a.total_cmp(b));
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }
}

// ---------------------------------------------------------------------------
// stage engine
// ---------------------------------------------------------------------------

/// One admitted session's runtime state on one stage.
struct SessionRun {
    kv: StageKv,
    /// prompt ++ generated tokens (the generated suffix doubles as the
    /// E-seed for `T_fixed` lookups on every stage)
    tokens: Vec<u32>,
    admit_step: u64,
    admit_s: f64,
    first_token_step: Option<u64>,
    first_token_s: f64,
}

/// One pipeline stage's full decode runtime: replayed parameters, the
/// replicated batcher, per-session K/V caches, and the serving stats.
/// The three run entries differ only in how rows move between engines.
struct StageEngine {
    h: Hyper,
    mode: Mode,
    stage: usize,
    st: StageState,
    global: GlobalState,
    pe: Tensor,
    sessions: Vec<SessionSpec>,
    batcher: Batcher,
    runs: Vec<Option<SessionRun>>,
    stats: Vec<SessionStat>,
    clock0: Instant,
    tokens_generated: u64,
    kv_peak_bytes: usize,
}

impl StageEngine {
    /// Build the engine for `stage`: the identical seeded init replay
    /// the training workers run (`seed ^ 0x9137`, every stage drawn in
    /// order, own stage kept), so serving weights match training's
    /// step-0 weights bitwise.
    fn new(
        spec: &ServeSpec,
        stage: usize,
        sessions: Vec<SessionSpec>,
    ) -> Result<StageEngine> {
        let h = spec.core.h.clone();
        if stage >= h.stages {
            bail!(
                "--stage {stage} out of range for a {}-stage pipeline",
                h.stages
            );
        }
        let mut rng = Rng::new(spec.core.cfg.seed ^ 0x9137);
        let global = GlobalState::from_hyper(&h, &mut rng);
        let mut my_stage: Option<StageState> = None;
        for s in 0..h.stages {
            let st = StageState::from_schema(
                h.stage_schema(s),
                h.stage_kind(s),
                s,
                spec.core.cfg.mode,
                &global,
                &mut rng,
            )?;
            if s == stage {
                my_stage = Some(st);
            }
        }
        let pe = sinusoidal_pe(h.n, h.d);
        let batcher = Batcher::new(&sessions, spec.max_batch);
        let runs = (0..sessions.len()).map(|_| None).collect();
        Ok(StageEngine {
            h,
            mode: spec.core.cfg.mode,
            stage,
            st: my_stage.expect("own stage initialized"),
            global,
            pe,
            sessions,
            batcher,
            runs,
            stats: Vec::new(),
            clock0: Instant::now(),
            tokens_generated: 0,
            kv_peak_bytes: 0,
        })
    }

    /// Admit arrived sessions (allocating their K/V caches).
    fn admit(&mut self, step: u64) {
        let now = self.clock0.elapsed().as_secs_f64();
        for sid in self.batcher.admit(step) {
            let s = &self.sessions[sid as usize];
            self.runs[sid as usize] = Some(SessionRun {
                kv: StageKv::new(self.h.blocks_per_stage),
                tokens: s.prompt.clone(),
                admit_step: step,
                admit_s: now,
                first_token_step: None,
                first_token_s: 0.0,
            });
        }
    }

    /// Advance every active session one position. `input` holds the
    /// decoded boundary rows from the left neighbor in active order
    /// (stages > 0). Returns `(sid, position processed, output row)`
    /// per session, and asserts each K/V cache against the analytic
    /// [`memory::kv_cache_bytes`] model — exactly, every step.
    fn process(
        &mut self,
        input: Option<&[Vec<f32>]>,
    ) -> Result<Vec<(u32, usize, Vec<f32>)>> {
        if let Some(rows) = input {
            if rows.len() != self.batcher.active.len() {
                bail!(
                    "stage {}: {} boundary rows for {} active sessions",
                    self.stage,
                    rows.len(),
                    self.batcher.active.len()
                );
            }
        }
        let dec = StageDecoder {
            h: &self.h,
            mode: self.mode,
            stage: self.stage,
            params: &self.st.params,
            u: &self.global.u,
            t_fixed: &self.global.t_fixed,
            pe: &self.pe,
        };
        let mut out = Vec::with_capacity(self.batcher.active.len());
        let mut kv_now = 0usize;
        for (i, &sid) in self.batcher.active.iter().enumerate() {
            let run = self.runs[sid as usize].as_mut().ok_or_else(|| {
                anyhow::anyhow!(
                    "stage {}: session {sid} active without state",
                    self.stage
                )
            })?;
            let pos = run.kv.pos;
            let tok = *run.tokens.get(pos).ok_or_else(|| {
                anyhow::anyhow!(
                    "stage {}: session {sid} has no token for position \
                     {pos} — token relay out of sync",
                    self.stage
                )
            })?;
            let row = dec.step(
                &mut run.kv,
                tok,
                input.map(|rows| rows[i].as_slice()),
            )?;
            let want = memory::kv_cache_bytes(&self.h, run.kv.pos);
            if run.kv.bytes() != want {
                bail!(
                    "stage {}: session {sid} K/V cache holds {} B at \
                     position {} but memory::kv_cache_bytes prices {want} \
                     B — the analytic memory model drifted from the \
                     runtime",
                    self.stage,
                    run.kv.bytes(),
                    run.kv.pos
                );
            }
            kv_now += run.kv.bytes();
            out.push((sid, pos, row));
        }
        self.kv_peak_bytes = self.kv_peak_bytes.max(kv_now);
        Ok(out)
    }

    /// Absorb the step's token relay: cross-check the session ids
    /// against the replicated batcher, append each real (post-prefill)
    /// token to its session's stream.
    fn absorb_tokens(&mut self, step: u64, pairs: &[(u32, u32)]) -> Result<()> {
        if pairs.len() != self.batcher.active.len()
            || pairs
                .iter()
                .zip(self.batcher.active.iter())
                .any(|(p, &sid)| p.0 != sid)
        {
            bail!(
                "stage {}: token relay names sessions {:?} but the \
                 replicated batcher has {:?} active — desynchronized \
                 serving pipeline",
                self.stage,
                pairs.iter().map(|p| p.0).collect::<Vec<_>>(),
                self.batcher.active
            );
        }
        let now = self.clock0.elapsed().as_secs_f64();
        for &(sid, tok) in pairs {
            let run = self.runs[sid as usize]
                .as_mut()
                .expect("active session has state");
            let plen = self.sessions[sid as usize].prompt.len();
            // position just processed; its logits sampled `tok`
            let pos = run.kv.pos - 1;
            if pos + 1 >= plen {
                run.tokens.push(tok);
                self.tokens_generated += 1;
                if run.first_token_step.is_none() {
                    run.first_token_step = Some(step);
                    run.first_token_s = now - run.admit_s;
                }
            }
        }
        Ok(())
    }

    /// Evict finished sessions, freeing their K/V and recording stats.
    fn evict(&mut self, step: u64) {
        let now = self.clock0.elapsed().as_secs_f64();
        for sid in self.batcher.advance() {
            let run = self.runs[sid as usize]
                .take()
                .expect("evicted session had state");
            let s = &self.sessions[sid as usize];
            let plen = s.prompt.len();
            self.stats.push(SessionStat {
                id: sid,
                arrival_step: s.arrival_step,
                admit_step: run.admit_step,
                first_token_step: run
                    .first_token_step
                    .expect("finished session produced tokens"),
                done_step: step,
                prompt_len: plen,
                gen: s.gen,
                tokens: run.tokens[plen..].to_vec(),
                latency_s: now - run.admit_s,
                first_token_s: run.first_token_s,
            });
        }
    }

    /// Session stats in id order (the batcher evicts in admission
    /// order, which is id order, but sort anyway for the contract).
    fn take_stats(&mut self) -> Vec<SessionStat> {
        let mut v = std::mem::take(&mut self.stats);
        v.sort_by_key(|s| s.id);
        v
    }
}

/// The budget error every stage raises deterministically at the same
/// step, so no worker hangs on a peer that gave up.
fn budget_error(spec: &ServeSpec, step: u64, unfinished: usize) -> anyhow::Error {
    anyhow::anyhow!(
        "decode-step budget of {} steps exhausted at step {step} with \
         {unfinished} sessions unfinished — raise --steps or shrink the \
         traffic",
        spec.core.steps
    )
}

// ---------------------------------------------------------------------------
// single-process runner
// ---------------------------------------------------------------------------

/// Serve the spec's traffic in one process: every stage engine in one
/// loop, boundary rows round-tripped through the *same* per-session
/// codec paths the distributed runners put on the wire — which is why
/// the token streams match the distributed backends bitwise. The
/// reference semantics of the decode protocol, and the oracle the
/// parity tests compare against.
pub fn run_serve_local(spec: &ServeSpec) -> Result<ServeReport> {
    spec.validate()?;
    let sessions = generate_sessions(spec)?;
    let p = spec.core.h.stages;
    let mut engines = (0..p)
        .map(|s| StageEngine::new(spec, s, sessions.clone()))
        .collect::<Result<Vec<_>>>()?;
    let h = &spec.core.h;
    let mode = spec.core.cfg.mode;
    let seed = spec.core.cfg.seed;
    let per = session_payload_len(h, mode);
    let mut report = ServeReport::default();
    let mut step: u64 = 0;
    loop {
        for e in engines.iter_mut() {
            e.admit(step);
        }
        if engines[0].batcher.active().is_empty() {
            match engines[0].batcher.next_arrival() {
                None => break,
                Some(a) => {
                    // idle fast-forward: no frames, no budget spent
                    step = a;
                    continue;
                }
            }
        }
        if report.steps as usize >= spec.core.steps {
            let unfinished =
                sessions.len() - engines[0].stats.len();
            return Err(budget_error(spec, step, unfinished));
        }
        let t0 = Instant::now();
        let tr0 = trace::begin();
        let active = engines[0].batcher.active().len();
        let mut outs = engines[0].process(None)?;
        for s in 1..p {
            let link = s - 1;
            let mut payload = Vec::with_capacity(outs.len() * per);
            let mut delivered = Vec::with_capacity(outs.len());
            for (sid, pos, row) in &outs {
                let enc =
                    encode_session_row(h, mode, seed, link, *sid, *pos, row);
                if enc.len() != per {
                    bail!(
                        "session {sid} encoded to {} B but every session \
                         must contribute {per} B (mode {})",
                        enc.len(),
                        mode.as_str()
                    );
                }
                delivered.push(decode_session_row(h, mode, &enc));
                payload.extend_from_slice(&enc);
            }
            if mode != Mode::PowerLR {
                let want = memory::decode_frame_bytes(h, mode, outs.len());
                if HEADER_LEN + payload.len() != want {
                    bail!(
                        "decode frame would carry {} B on link {link} but \
                         memory::decode_frame_bytes prices {want} B",
                        HEADER_LEN + payload.len()
                    );
                }
            }
            report.decode_payload_bytes += payload.len() as u64;
            report.wire_bytes += (HEADER_LEN + payload.len()) as u64;
            report.frames += 1;
            outs = engines[s].process(Some(&delivered))?;
        }
        let pairs: Vec<(u32, u32)> = outs
            .iter()
            .map(|(sid, _, logits)| (*sid, argmax(logits)))
            .collect();
        // the token relay retraces every link back to stage 0
        let tp = pairs.len() * 8;
        report.token_payload_bytes += ((p - 1) * tp) as u64;
        report.wire_bytes += ((p - 1) * (HEADER_LEN + tp)) as u64;
        report.frames += (p - 1) as u64;
        for e in engines.iter_mut() {
            e.absorb_tokens(step, &pairs)?;
            e.evict(step);
        }
        report.step_seconds.push(t0.elapsed().as_secs_f64());
        report.steps += 1;
        if trace::enabled() {
            trace::end(
                "serve",
                "decode_step",
                tr0,
                vec![trace::u("step", step), trace::u("active", active as u64)],
            );
        }
        step += 1;
    }
    report.sessions = engines[0].take_stats();
    report.tokens_generated = engines[0].tokens_generated;
    report.kv_peak_bytes = engines[0].kv_peak_bytes;
    Ok(report)
}

// ---------------------------------------------------------------------------
// distributed stage worker
// ---------------------------------------------------------------------------

/// Run one decode stage over its neighbor links: the worker behind both
/// [`serve_infer`] (threads) and [`serve_infer_stage`] (processes).
fn run_infer_stage(
    spec: &ServeSpec,
    stage: usize,
    mut left: Option<Box<dyn Transport>>,
    mut right: Option<Box<dyn Transport>>,
) -> Result<ServeReport> {
    spec.validate()?;
    let h = spec.core.h.clone();
    let last = h.stages - 1;
    if stage > last {
        bail!("stage {stage} out of range for a {}-stage pipeline", h.stages);
    }
    if (stage > 0) != left.is_some() || (stage < last) != right.is_some() {
        bail!("stage {stage}: neighbor links do not match the position");
    }
    if trace::enabled() {
        trace::set_track(0, stage as u32);
    }

    // ---- handshake: the workload-tagged PMCFG3 serve digest on every
    // link — a train worker (or a serve worker with different traffic)
    // on the other end is rejected here, not desynchronized later
    let digest = spec.handshake_digest();
    for (conn, name) in
        [(left.as_deref_mut(), "left"), (right.as_deref_mut(), "right")]
    {
        let Some(conn) = conn else { continue };
        conn.send(&WireFrame::control(FrameKind::Hello, 0, digest.clone()))?;
        let hello =
            recv_expect(conn, FrameKind::Hello, 0, None, stage, name, None)?;
        if hello.payload != digest {
            bail!(
                "stage {stage}: serve digest mismatch with the {name} \
                 neighbor ({} vs our {} bytes) — every worker must be \
                 launched with the identical ServeSpec (model, codec, \
                 traffic, --max-batch, workload)",
                hello.payload.len(),
                digest.len()
            );
        }
    }

    let sessions = generate_sessions(spec)?;
    let total_sessions = sessions.len();
    let mut engine = StageEngine::new(spec, stage, sessions)?;
    let mode = spec.core.cfg.mode;
    let seed = spec.core.cfg.seed;
    let per = session_payload_len(&h, mode);
    let mut report = ServeReport { stage, ..Default::default() };
    let mut step: u64 = 0;
    loop {
        engine.admit(step);
        if engine.batcher.active().is_empty() {
            match engine.batcher.next_arrival() {
                None => break,
                Some(a) => {
                    step = a;
                    continue;
                }
            }
        }
        if report.steps as usize >= spec.core.steps {
            // every stage computes this identically, so the whole chain
            // stops at the same step instead of hanging a neighbor
            let unfinished = total_sessions - engine.stats.len();
            return Err(budget_error(spec, step, unfinished));
        }
        let t0 = Instant::now();
        let tr0 = trace::begin();
        let active = engine.batcher.active().len();

        // ---- forward: boundary rows ride Decode frames rightward
        let outs = if stage == 0 {
            engine.process(None)?
        } else {
            let conn = left.as_deref_mut().expect("stage > 0 has a left link");
            let f = recv_expect(
                conn,
                FrameKind::Decode,
                step,
                Some(active as u32),
                stage,
                "left",
                None,
            )?;
            match f.codec {
                Some(c) if c == mode => {}
                other => bail!(
                    "stage {stage}: decode frame codec {other:?} does not \
                     match the handshaked mode {mode:?}"
                ),
            }
            if f.payload.len() != active * per {
                bail!(
                    "stage {stage}: decode frame payload is {} B for {} \
                     sessions but per-session encoding requires {} B",
                    f.payload.len(),
                    active,
                    active * per
                );
            }
            if mode != Mode::PowerLR
                && HEADER_LEN + f.payload.len()
                    != memory::decode_frame_bytes(&h, mode, active)
            {
                bail!(
                    "stage {stage}: decode frame carries {} B but \
                     memory::decode_frame_bytes prices {} B",
                    HEADER_LEN + f.payload.len(),
                    memory::decode_frame_bytes(&h, mode, active)
                );
            }
            let delivered: Vec<Vec<f32>> = f
                .payload
                .chunks_exact(per)
                .map(|c| decode_session_row(&h, mode, c))
                .collect();
            engine.process(Some(&delivered))?
        };
        if stage < last {
            let mut payload = Vec::with_capacity(outs.len() * per);
            for (sid, pos, row) in &outs {
                let enc = encode_session_row(
                    &h, mode, seed, stage, *sid, *pos, row,
                );
                payload.extend_from_slice(&enc);
            }
            let f = WireFrame::decode_step(mode, step, outs.len(), payload);
            report.decode_payload_bytes += f.payload.len() as u64;
            report.frames += 1;
            right
                .as_deref_mut()
                .expect("non-last stage has a right link")
                .send(&f)?;
        }

        // ---- backward: sampled tokens relay leftward to stage 0
        let pairs: Vec<(u32, u32)> = if stage == last {
            let pairs: Vec<(u32, u32)> = outs
                .iter()
                .map(|(sid, _, logits)| (*sid, argmax(logits)))
                .collect();
            let mut payload = Vec::with_capacity(pairs.len() * 8);
            for &(sid, tok) in &pairs {
                payload.extend_from_slice(&sid.to_le_bytes());
                payload.extend_from_slice(&tok.to_le_bytes());
            }
            let f = WireFrame::token_relay(step, pairs.len(), payload);
            report.token_payload_bytes += f.payload.len() as u64;
            report.frames += 1;
            left.as_deref_mut()
                .expect("last stage of a >=2-stage chain has a left link")
                .send(&f)?;
            pairs
        } else {
            let conn =
                right.as_deref_mut().expect("non-last stage has a right link");
            let f = recv_expect(
                conn,
                FrameKind::Token,
                step,
                Some(active as u32),
                stage,
                "right",
                None,
            )?;
            if f.payload.len() != active * 8
                || HEADER_LEN + f.payload.len()
                    != memory::token_frame_bytes(active)
            {
                bail!(
                    "stage {stage}: token frame payload is {} B for {} \
                     sessions (8 B per session expected)",
                    f.payload.len(),
                    active
                );
            }
            let pairs: Vec<(u32, u32)> = f
                .payload
                .chunks_exact(8)
                .map(|c| {
                    (
                        u32::from_le_bytes([c[0], c[1], c[2], c[3]]),
                        u32::from_le_bytes([c[4], c[5], c[6], c[7]]),
                    )
                })
                .collect();
            if stage > 0 {
                report.token_payload_bytes += f.payload.len() as u64;
                report.frames += 1;
                left.as_deref_mut()
                    .expect("stage > 0 has a left link")
                    .send(&f)?;
            }
            pairs
        };
        engine.absorb_tokens(step, &pairs)?;
        engine.evict(step);
        report.step_seconds.push(t0.elapsed().as_secs_f64());
        report.steps += 1;
        if trace::enabled() {
            trace::end(
                "serve",
                "decode_step",
                tr0,
                vec![trace::u("step", step), trace::u("active", active as u64)],
            );
        }
        step += 1;
    }

    // termination is deterministic and replicated, so both neighbors
    // exit at the same step; the Bye is a courtesy, not a join
    for conn in [left.as_deref_mut(), right.as_deref_mut()] {
        if let Some(conn) = conn {
            let _ = conn.send(&WireFrame::control(
                FrameKind::Bye,
                step,
                Vec::new(),
            ));
        }
    }
    report.wire_bytes = left.as_ref().map_or(0, |c| c.bytes_sent())
        + right.as_ref().map_or(0, |c| c.bytes_sent());
    report.sessions = engine.take_stats();
    report.tokens_generated = engine.tokens_generated;
    report.kv_peak_bytes = engine.kv_peak_bytes;
    Ok(report)
}

/// Serve the spec's traffic across in-process stage workers joined by
/// the chosen transport (channel or loopback TCP) — the distributed
/// decode analogue of training's `run_local`. Returns stage 0's report
/// (the canonical session stats).
pub fn serve_infer(spec: &ServeSpec, kind: TransportKind) -> Result<ServeReport> {
    spec.validate()?;
    let p = spec.core.h.stages;
    let ends = chain_ends(p, kind)?;
    crate::obs::log!(
        Info,
        "serve-infer: {p} decode stages over {} transport, {} sessions",
        kind.as_str(),
        spec.traffic.sessions
    );
    let results: Vec<Result<ServeReport>> = std::thread::scope(|sc| {
        let mut handles = Vec::with_capacity(p);
        for (stage, (left, right)) in ends.into_iter().enumerate() {
            let spec = &*spec;
            handles.push(sc.spawn(move || {
                run_infer_stage(spec, stage, left, right)
            }));
        }
        handles
            .into_iter()
            .map(|jh| {
                jh.join().unwrap_or_else(|_| {
                    Err(anyhow::anyhow!("serve-infer worker panicked"))
                })
            })
            .collect()
    });
    let mut first = None;
    for (stage, res) in results.into_iter().enumerate() {
        let rep = res
            .with_context(|| format!("serve-infer stage {stage} failed"))?;
        if stage == 0 {
            first = Some(rep);
        }
    }
    Ok(first.expect("stage 0 reported"))
}

/// Run one decode stage as a standalone process over real TCP
/// (`protomodels serve-infer --stage i`): stage `i` binds
/// `host:port_base+i` and dials `host:port_base+i−1` with retries, like
/// the training `serve --stage` workers. Thin shim over
/// [`super::launch_serve`] with a [`super::ServeRole::Infer`] role.
pub fn serve_infer_stage(
    spec: &ServeSpec,
    stage: usize,
    host: &str,
    port_base: u16,
) -> Result<ServeReport> {
    match super::launch_serve(
        &super::ServeRole::Infer { stage },
        &super::WorkloadSpec::Serve(spec),
        host,
        port_base,
    )? {
        super::ServeOutcome::Infer(r) => Ok(*r),
        other => bail!("serve_infer_stage produced an unexpected {other:?}"),
    }
}

/// The standalone-TCP decode worker behind [`serve_infer_stage`] /
/// [`super::launch_serve`].
pub(crate) fn serve_infer_stage_impl(
    spec: &ServeSpec,
    stage: usize,
    host: &str,
    port_base: u16,
) -> Result<ServeReport> {
    spec.validate()?;
    let (left, right) =
        tcp_chain_links(spec.core.h.stages, stage, host, port_base)?;
    run_infer_stage(spec, stage, left, right)
}

#[cfg(test)]
mod tests {
    use super::super::spec::TrafficSpec;
    use super::*;
    use crate::data::CorpusKind;

    fn tiny(mode: Mode) -> ServeSpec {
        ServeSpec::builder(Hyper::tiny_native())
            .mode(mode)
            .steps(400)
            .seed(11)
            .corpus(CorpusKind::Wiki, 4_000)
            .traffic(TrafficSpec {
                sessions: 3,
                mean_gap: 1.5,
                prompt: (2, 4),
                gen: (2, 3),
            })
            .max_batch(2)
            .build()
            .unwrap()
    }

    #[test]
    fn session_tables_replay_deterministically() {
        let spec = tiny(Mode::Subspace);
        let a = generate_sessions(&spec).unwrap();
        let b = generate_sessions(&spec).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 3);
        for (i, s) in a.iter().enumerate() {
            assert_eq!(s.id as usize, i);
            assert!(s.prompt.len() >= 2 && s.prompt.len() <= 4);
            assert!(s.gen >= 2 && s.gen <= 3);
            if i > 0 {
                assert!(s.arrival_step >= a[i - 1].arrival_step);
            }
        }
    }

    #[test]
    fn local_decode_serves_every_session() {
        let spec = tiny(Mode::Subspace);
        let rep = run_serve_local(&spec).unwrap();
        assert_eq!(rep.sessions.len(), 3);
        let mut toks = 0;
        for s in &rep.sessions {
            assert_eq!(s.tokens.len(), s.gen);
            assert!(s.done_step >= s.first_token_step);
            assert!(s.first_token_step >= s.admit_step);
            assert!(s.admit_step >= s.arrival_step);
            toks += s.tokens.len() as u64;
        }
        assert_eq!(rep.tokens_generated, toks);
        assert!(rep.steps > 0);
        assert_eq!(rep.step_seconds.len(), rep.steps as usize);
        assert!(rep.kv_peak_bytes > 0);
        assert!(rep.latency_percentile(50.0) <= rep.latency_percentile(99.0));
        // 3 links, decode + token frames per executed step
        assert_eq!(rep.frames, rep.steps * 6);
    }

    #[test]
    fn channel_grid_matches_local_token_streams() {
        for mode in [Mode::Subspace, Mode::TopK] {
            let spec = tiny(mode);
            let local = run_serve_local(&spec).unwrap();
            let grid = serve_infer(&spec, TransportKind::Channel).unwrap();
            assert_eq!(grid.sessions.len(), local.sessions.len());
            for (a, b) in grid.sessions.iter().zip(&local.sessions) {
                assert_eq!(a.tokens, b.tokens, "mode {mode:?}");
                assert_eq!(a.done_step, b.done_step);
            }
            assert_eq!(grid.steps, local.steps);
        }
    }

    #[test]
    fn batching_width_cannot_perturb_a_session() {
        // eviction/admission invariance: per-session encoding makes a
        // session's tokens a function of its own history only — even
        // for the batch-coupled lossy codecs and PowerLR's sketch
        for mode in [Mode::TopK, Mode::Quant, Mode::PowerLR] {
            let mut narrow = tiny(mode);
            narrow.max_batch = 1;
            let mut wide = tiny(mode);
            wide.max_batch = 3;
            let a = run_serve_local(&narrow).unwrap();
            let b = run_serve_local(&wide).unwrap();
            for (x, y) in a.sessions.iter().zip(&b.sessions) {
                assert_eq!(x.tokens, y.tokens, "mode {mode:?}");
            }
        }
    }

    #[test]
    fn exhausted_step_budget_says_what_to_raise() {
        let mut spec = tiny(Mode::Subspace);
        spec.core.steps = 1;
        spec.core.cfg.total_steps = 1;
        let err = run_serve_local(&spec).unwrap_err().to_string();
        assert!(err.contains("raise --steps"), "{err}");
    }
}
