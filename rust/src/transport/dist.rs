//! The distributed native pipeline: one worker per stage, joined by
//! [`super::Transport`] links, training the exact model the
//! single-process [`crate::nn::NativePipeline`] trains (DESIGN.md §11).
//!
//! ## Determinism-first protocol
//!
//! Every worker derives *all* state a step needs from the handshaked
//! [`WorkerSpec`]: it replays the full seeded init stream (keeping only
//! its own stage's parameters) and regenerates every microbatch locally
//! from the shared data RNG — so token ids never cross the wire, and
//! the only payloads are the compressed boundary tensors the paper's
//! protocol actually ships. Because the init replay leaves each
//! worker's RNG in the identical state the single-process backend
//! carries, and the wire is bit-transparent (f32 LE round-trips
//! exactly), a distributed run's loss curve is **bitwise identical** to
//! the single-process run — the contract `tests/transport_parity.rs`
//! and `examples/distributed_train.rs` enforce over both backends.
//!
//! ## Per-step protocol (stage s of P, M microbatches)
//!
//! 1. sample all M batches from the step's data fork (stream order
//!    matches the single-process loop);
//! 2. execute the wave order of the configured schedule — GPipe
//!    (fill-then-drain) or 1F1B (warmup `min(M, P−s)` forwards, then
//!    alternate) — where a forward task receives the left boundary
//!    frame, builds the stage subgraph, and ships the codec frame
//!    right, and a backward task receives the gradient cotangent from
//!    the right, rebuilds the subgraph (GPipe rematerialization), and
//!    ships the input-gradient frame left; the last stage fuses
//!    fwd+loss+bwd per microbatch like the in-process backend;
//! 3. average gradients, step the stage's optimizer;
//! 4. relay one `StepEnd` frame from the last stage to stage 0 carrying
//!    the exact f64 loss-sum bits — and, on Grassmann-update steps, the
//!    new U basis, which every worker applies by re-projecting its own
//!    constrained parameters (the paper's basis-broadcast, for real).
//!
//! A vanished peer surfaces as a graceful `Err` whose message names the
//! stage, direction, and step — the transport mirror of the swarm
//! simulator's churn leave events — instead of a hang or a panic.

use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::compress::{self, Mode};
use crate::linalg;
use crate::manifest::Hyper;
use crate::nn::model::{build_stage, high_rank_e, sinusoidal_pe, StageIo};
use crate::nn::optim::{step_stage, OptStep};
use crate::obs::trace;
use crate::nn::{
    encode_boundary, grassmann_step_u, reproject_stage, BoundaryDir,
};
use crate::rng::Rng;
use crate::sim::Schedule;
use crate::stage::{GlobalState, StageState};
use crate::tensor::Tensor;

use super::dp::{dp_reduce_stage, DpCtx, TrainSpec};
use super::elastic::{heartbeat_payload, ElasticCtx};
use super::frame::{FrameKind, WireFrame};
use super::{channel_pair, TcpTransport, Transport};

pub use super::spec::{SpecCore, WorkerSpec};

/// Which transport backend a distributed run uses (`--transport`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// in-process `mpsc` channels — deterministic, used by parity tests
    Channel,
    /// real TCP sockets over loopback, one OS thread per stage
    Tcp,
}

impl TransportKind {
    /// Parse a CLI label (`"channel"`, `"tcp"`).
    pub fn parse(s: &str) -> Result<TransportKind> {
        match s {
            "channel" => Ok(TransportKind::Channel),
            "tcp" => Ok(TransportKind::Tcp),
            other => bail!("unknown transport {other:?} (have channel, tcp)"),
        }
    }

    /// Canonical label.
    pub fn as_str(&self) -> &'static str {
        match self {
            TransportKind::Channel => "channel",
            TransportKind::Tcp => "tcp",
        }
    }
}

/// What one stage worker reports after a run.
#[derive(Clone, Debug)]
pub struct WorkerReport {
    /// stage this worker drove
    pub stage: usize,
    /// per-step mean training loss (stage 0 only — the relay terminus)
    pub losses: Vec<f64>,
    /// per-step wall-clock seconds (stage 0 only; spans the full wave
    /// including the StepEnd relay, i.e. the step makespan)
    pub step_seconds: Vec<f64>,
    /// boundary payload bytes this worker sent (codec bytes, no headers)
    pub boundary_payload_bytes: u64,
    /// total bytes this worker sent, frame headers and control included
    pub wire_bytes: u64,
    /// frames this worker sent
    pub frames_sent: u64,
    /// gradient-frame payload bytes this worker sent on the dp mesh
    pub dp_payload_bytes: u64,
}

/// Aggregate result of a distributed run.
#[derive(Clone, Debug)]
pub struct DistReport {
    /// per-step mean training loss (bitwise-comparable to the
    /// single-process backend's `StepStats::loss`)
    pub losses: Vec<f64>,
    /// per-step wall-clock seconds measured at stage 0
    pub step_seconds: Vec<f64>,
    /// boundary payload bytes that crossed all links, both directions
    pub boundary_payload_bytes: u64,
    /// total wire bytes including frame headers and control frames
    pub wire_bytes: u64,
    /// total frames sent
    pub frames: u64,
    /// payload bytes of one boundary frame — asserted equal to
    /// [`crate::compress::wire_bytes`] on every frame received
    pub frame_payload_bytes: usize,
    /// gradient-frame payload bytes across the dp mesh (0 for R = 1)
    pub dp_payload_bytes: u64,
}

impl DistReport {
    /// Mean wall-clock seconds per step.
    pub fn mean_step_seconds(&self) -> f64 {
        if self.step_seconds.is_empty() {
            return 0.0;
        }
        self.step_seconds.iter().sum::<f64>() / self.step_seconds.len() as f64
    }
}

// ---------------------------------------------------------------------------
// stage worker
// ---------------------------------------------------------------------------

/// One unit of the wave order.
#[derive(Clone, Copy, Debug)]
enum Task {
    Fwd(usize),
    Bwd(usize),
}

/// The microbatch task order for one stage under a schedule. Both
/// orders process backwards in ascending microbatch order, so gradient
/// accumulation — hence the loss curve — is schedule-independent and
/// bitwise-identical to the single-process loop; the schedules differ
/// only in how many forwards are in flight (link buffering / overlap).
fn wave_order(
    schedule: Schedule,
    stages: usize,
    stage: usize,
    m: usize,
) -> Vec<Task> {
    let last = stages - 1;
    if stage == last {
        // the last stage fuses fwd+loss+bwd per microbatch
        return (0..m).map(Task::Fwd).collect();
    }
    let warmup = match schedule {
        // fill-then-drain: every forward before any backward
        Schedule::Gpipe => m,
        // classic 1F1B: keep at most P − s microbatches in flight
        Schedule::OneFOneB => m.min(stages - stage),
        Schedule::Interleaved { .. } => m, // unreachable (validate())
    };
    let mut order = Vec::with_capacity(2 * m);
    for mb in 0..warmup {
        order.push(Task::Fwd(mb));
    }
    let mut next_fwd = warmup;
    for mb in 0..m {
        order.push(Task::Bwd(mb));
        if next_fwd < m {
            order.push(Task::Fwd(next_fwd));
            next_fwd += 1;
        }
    }
    order
}

/// Neighbor links of one worker.
struct Links {
    left: Option<Box<dyn Transport>>,
    right: Option<Box<dyn Transport>>,
}

impl Links {
    fn left(&mut self) -> &mut dyn Transport {
        self.left.as_deref_mut().expect("stage > 0 has a left link")
    }

    fn right(&mut self) -> &mut dyn Transport {
        self.right.as_deref_mut().expect("stage < last has a right link")
    }
}

/// Receive one frame and validate its header against expectations; a
/// `Bye` or a closed connection is reported as a departure with enough
/// context to locate the leave in the pipeline. With `stale` set (the
/// elastic runtime), the wait is bounded: heartbeat frames refresh the
/// deadline, and total silence past it surfaces as a departure — a hung
/// or vanished peer can never block a worker forever (DESIGN.md §12).
pub(crate) fn recv_expect(
    conn: &mut dyn Transport,
    kind: FrameKind,
    step: u64,
    mb: Option<u32>,
    stage: usize,
    from: &str,
    stale: Option<Duration>,
) -> Result<WireFrame> {
    let ctx = || {
        format!(
            "stage {stage}: awaiting a {} frame from the {from} neighbor \
             at step {step}",
            kind.name()
        )
    };
    let f = loop {
        match stale {
            None => break conn.recv().with_context(ctx)?,
            Some(limit) => match conn.recv_timeout(limit).with_context(ctx)? {
                // liveness chatter: note it and keep waiting
                Some(f) if f.kind == FrameKind::Heartbeat => continue,
                Some(f) => break f,
                None => bail!(
                    "stage {stage}: worker departed — no {} frame or \
                     heartbeat from the {from} neighbor within {} ms at \
                     step {step} (stale liveness timeout)",
                    kind.name(),
                    limit.as_millis()
                ),
            },
        }
    };
    if f.kind == FrameKind::Bye {
        bail!(
            "stage {stage}: worker departed — {from} neighbor said \
             goodbye at step {step} while we expected a {} frame \
             (mirrors a swarm leave event)",
            kind.name()
        );
    }
    if f.kind != kind {
        bail!(
            "stage {stage}: protocol error — expected a {} frame from \
             the {from} neighbor at step {step}, got {}",
            kind.name(),
            f.kind.name()
        );
    }
    if f.step != step {
        bail!(
            "stage {stage}: {} frame from the {from} neighbor is for \
             step {} but we are at step {step} — desynchronized pipeline",
            kind.name(),
            f.step
        );
    }
    if let Some(mb) = mb {
        if f.microbatch != mb {
            bail!(
                "stage {stage}: {} frame from the {from} neighbor is \
                 for microbatch {} but we expected {mb}",
                kind.name(),
                f.microbatch
            );
        }
    }
    Ok(f)
}

/// Accumulate one built stage's parameter gradients into `acc`
/// (borrowed from the tape; mirrors the in-process backend).
fn accumulate_grads(built: &crate::nn::model::BuiltStage, acc: &mut [Tensor]) {
    for (a, p) in acc.iter_mut().zip(&built.params) {
        if let Some(g) = built.tape.grad(*p) {
            a.add_assign(g);
        }
    }
}

/// Logical shape of a decoded boundary tensor under a spec.
fn boundary_shape(h: &Hyper, mode: Mode) -> Vec<usize> {
    if mode.compressed() {
        vec![h.b * h.n, h.k]
    } else {
        vec![h.b * h.n, h.d]
    }
}

/// Validate a received boundary frame (codec tag + the `payload_len ==
/// wire_bytes` contract) and decode it to the delivered tensor.
fn decode_boundary(
    spec: &WorkerSpec,
    f: &WireFrame,
    stage: usize,
) -> Result<Tensor> {
    let mode = spec.cfg.mode;
    match f.codec {
        Some(c) if c == mode => {}
        other => bail!(
            "stage {stage}: boundary frame codec {other:?} does not \
             match the handshaked mode {mode:?}"
        ),
    }
    // the acceptance contract: what the codec accounts is what the wire
    // carries (PowerLR's dense stand-in is the documented exception)
    if mode != Mode::PowerLR {
        let want = spec.cfg.boundary_bytes(&spec.h);
        if f.payload.len() != want {
            bail!(
                "stage {stage}: boundary frame payload is {} B but \
                 compress::wire_bytes prices {want} B for mode {}",
                f.payload.len(),
                mode.as_str()
            );
        }
    }
    let cf = compress::Frame {
        mode,
        shape: boundary_shape(&spec.h, mode),
        payload: f.payload.clone(),
    };
    Ok(compress::decode(&cf))
}

/// Run one stage worker to completion over its neighbor links. This is
/// the function `serve --stage` drives directly (one process per stage)
/// and [`run_local`] drives on threads (one process, P workers).
pub fn run_stage(
    spec: &WorkerSpec,
    stage: usize,
    left: Option<Box<dyn Transport>>,
    right: Option<Box<dyn Transport>>,
) -> Result<WorkerReport> {
    run_stage_inner(spec, stage, left, right, None, None, None)
}

/// [`run_stage`] plus the elastic hooks (DESIGN.md §12): a control link
/// to the supervisor/leader carrying heartbeats, per-boundary
/// checkpoints, and (stage 0) per-step losses, and an [`ElasticCtx`]
/// that resumes the worker from a checkpointed step boundary, bounds
/// every receive by the stale timeout, and — in chaos runs — kills the
/// worker at a scripted step. With `ctl`/`ectx` absent, behavior is
/// byte-for-byte the classic `run_stage`.
pub(crate) fn run_stage_inner(
    spec: &WorkerSpec,
    stage: usize,
    left: Option<Box<dyn Transport>>,
    right: Option<Box<dyn Transport>>,
    mut ctl: Option<&mut dyn Transport>,
    ectx: Option<&ElasticCtx>,
    mut dp: Option<DpCtx>,
) -> Result<WorkerReport> {
    spec.validate()?;
    let h = spec.h.clone();
    let cfg = spec.cfg.clone();
    let last = h.stages - 1;
    if stage > h.stages - 1 {
        bail!("stage {stage} out of range for a {}-stage pipeline", h.stages);
    }
    if (stage > 0) != left.is_some() || (stage < last) != right.is_some() {
        bail!("stage {stage}: neighbor links do not match the position");
    }
    let mut links = Links { left, right };
    let stale =
        ectx.map(|e| Duration::from_millis(e.stale_ms.max(1)));
    let clock0 = Instant::now();
    // logical trace track: pid = replica, tid = stage — stable across
    // transports, pool widths, and OS thread scheduling
    if trace::enabled() {
        trace::set_track(
            dp.as_ref().map_or(0, |d| d.replica as u32),
            stage as u32,
        );
    }

    // ---- handshake: exchange config digests on every link. In a
    // replica grid the dp context carries the grid-wide digest (the
    // TrainSpec's `PMCFG3` handshake digest, wrapping PMCFG2 wrapping
    // this worker's PMCFG1 digest plus the train workload tag) — chain
    // and mesh links then all agree on the full run description, and a
    // serve-infer worker dialing a train port is rejected at hello.
    let digest = dp.as_ref().map_or_else(
        || TrainSpec::from_worker(spec.clone()).handshake_digest(),
        |d| d.digest.clone(),
    );
    for (conn, name) in [
        (links.left.as_deref_mut(), "left"),
        (links.right.as_deref_mut(), "right"),
    ] {
        let Some(conn) = conn else { continue };
        conn.send(&WireFrame::control(
            FrameKind::Hello,
            0,
            digest.clone(),
        ))?;
        let hello =
            recv_expect(conn, FrameKind::Hello, 0, None, stage, name, stale)?;
        if hello.payload != digest {
            bail!(
                "stage {stage}: config digest mismatch with the {name} \
                 neighbor ({} vs our {} bytes) — both workers must be \
                 launched with identical model/run flags",
                hello.payload.len(),
                digest.len()
            );
        }
    }
    if let Some(dp) = dp.as_mut() {
        for peer in 0..dp.replicas {
            let Some(conn) = dp.links[peer].as_deref_mut() else {
                continue;
            };
            conn.send(&WireFrame::control(
                FrameKind::Hello,
                0,
                digest.clone(),
            ))?;
            let hello = recv_expect(
                conn,
                FrameKind::Hello,
                0,
                None,
                stage,
                "replica",
                None,
            )?;
            if hello.payload != digest {
                bail!(
                    "replica {} stage {stage}: grid digest mismatch \
                     with replica {peer} — every worker must be \
                     launched from the identical TrainSpec",
                    dp.replica
                );
            }
        }
    }

    // ---- init replay: identical RNG stream to NativePipeline::new —
    // every worker builds every stage's init draws, keeps its own
    let mut rng = Rng::new(cfg.seed ^ 0x9137);
    let global = GlobalState::from_hyper(&h, &mut rng);
    let mut my_stage: Option<StageState> = None;
    for s in 0..h.stages {
        let st = StageState::from_schema(
            h.stage_schema(s),
            h.stage_kind(s),
            s,
            cfg.mode,
            &global,
            &mut rng,
        )?;
        if s == stage {
            my_stage = Some(st);
        }
    }
    let mut st = my_stage.expect("own stage initialized");
    let mut global = global;
    if let Some(dp) = dp.as_ref() {
        // replica data sharding: after the shared init replay, continue
        // from this replica's shard seed — the exact
        // `NativePipeline::reseed_data` transformation, so grid and
        // in-process replicas draw identical batch streams
        rng = Rng::new(dp.shard_seed ^ 0xDA7A_5EED);
    }
    let pe = sinusoidal_pe(h.n, h.d);
    let corpus = spec.corpus();
    let compressed = cfg.compressed();
    let m_count = cfg.microbatches;
    let bbytes = cfg.boundary_bytes(&h);
    let order = wave_order(cfg.schedule, h.stages, stage, m_count);

    // Grassmann accumulator: last stage only (the one worker that sees
    // g_full) — the other P−1 workers never touch it, so they skip the
    // d×d residency
    let mut s_acc: Option<Tensor> = (stage == last && compressed)
        .then(|| Tensor::zeros(&[h.d, h.d]));
    let mut s_count = 0u64;

    // ---- elastic resume: burn the data forks of already-trained steps
    // (fork() advances the parent stream, so the RNG lands in exactly
    // the state a worker that really ran them carries), then restore
    // state from the checkpointed boundary
    let resume = ectx.map_or(0, |e| e.resume_step);
    if let Some(e) = ectx {
        for s in 0..e.resume_step {
            let _ = rng.fork(0xDA7A ^ s);
        }
        if let Some(blob) = &e.ckpt {
            let ck = crate::compress::ckpt::decode_stage(
                blob, &mut st, h.d, h.k, cfg.mode,
            )
            .with_context(|| {
                format!("stage {stage}: restoring the recovery checkpoint")
            })?;
            if ck.step != e.resume_step {
                bail!(
                    "stage {stage}: checkpoint is for boundary {} but the \
                     leader ordered a resume from {}",
                    ck.step,
                    e.resume_step
                );
            }
            global.u = ck.u;
            s_count = ck.s_count;
            if let Some(acc) = ck.s_acc {
                s_acc = Some(acc);
            }
        } else if e.resume_step > 0 {
            bail!(
                "stage {stage}: ordered to resume from step {} without a \
                 checkpoint payload",
                e.resume_step
            );
        }
    }
    // priced bytes of one boundary frame: the codec payload for every
    // mode except PowerLR, whose dense frame stands in for factor
    // shipping — accounting stays on the factor bytes, exactly like
    // the single-process ship() hook
    let priced_frame = |payload_len: usize| -> u64 {
        if cfg.mode == Mode::PowerLR {
            bbytes as u64
        } else {
            payload_len as u64
        }
    };
    let mut losses = Vec::new();
    let mut step_seconds = Vec::new();
    let mut boundary_payload = 0u64;
    let mut frames_sent = 0u64;

    for step in resume..spec.steps as u64 {
        if let Some(dp) = dp.as_ref() {
            if dp.kill_at == Some(step) {
                // scripted grid churn: every stage of this replica
                // leaves abruptly; gossip survivors detect the
                // departure at their next exchange and keep training
                bail!(
                    "chaos kill: replica {} stage {stage} leaves the \
                     grid at step {step} (scripted gossip churn)",
                    dp.replica
                );
            }
        }
        // ---- elastic step preamble: scripted kill, then heartbeat
        if let Some(e) = ectx {
            if e.kill_at == Some(step) {
                // scripted churn: leave the swarm abruptly — no Bye, no
                // cleanup; neighbors see a departure, exactly like a
                // yanked process (the chaos harness's leave event)
                bail!(
                    "chaos kill: stage {stage} leaves the swarm at step \
                     {step} (scripted churn timeline)"
                );
            }
            if let Some(ctl) = ctl.as_deref_mut() {
                if e.heartbeat_every > 0 && step % e.heartbeat_every == 0 {
                    ctl.send(&WireFrame::control(
                        FrameKind::Heartbeat,
                        step,
                        heartbeat_payload(
                            step,
                            clock0.elapsed().as_millis() as u64,
                        ),
                    ))?;
                }
            }
        }
        let t0 = Instant::now();
        let tt_step = trace::begin();
        // data stream: one fork per step, batches drawn in microbatch
        // order — byte-for-byte the single-process sampler sequence
        let mut data_rng = rng.fork(0xDA7A ^ step);
        let batches: Vec<_> = (0..m_count)
            .map(|_| corpus.train_batch(h.b, h.n, &mut data_rng))
            .collect();
        let es: Vec<Tensor> = batches
            .iter()
            .map(|(tok, _)| {
                high_rank_e(&h, cfg.mode, &pe, &global.t_fixed, tok)
            })
            .collect();

        let mut grad_acc = st.zero_grads();
        let mut saved: Vec<Option<Tensor>> = vec![None; m_count];
        let mut loss_sum = 0.0f64;

        for task in &order {
            match *task {
                Task::Fwd(mb) => {
                    let (tok, tgt) = &batches[mb];
                    if stage > 0 {
                        let f = recv_expect(
                            links.left(),
                            FrameKind::Fwd,
                            step,
                            Some(mb as u32),
                            stage,
                            "left",
                            stale,
                        )?;
                        let td = trace::begin();
                        saved[mb] = Some(decode_boundary(spec, &f, stage)?);
                        if trace::enabled() {
                            trace::end(
                                "codec",
                                "decode:fwd",
                                td,
                                vec![
                                    trace::u("step", step),
                                    trace::u("mb", mb as u64),
                                    trace::u(
                                        "bytes",
                                        f.payload.len() as u64,
                                    ),
                                ],
                            );
                        }
                    }
                    if stage < last {
                        let tt = trace::begin();
                        let built = build_stage(
                            &h,
                            cfg.mode,
                            stage,
                            &st.params,
                            StageIo {
                                u: &global.u,
                                e: &es[mb],
                                tok,
                                input: saved[mb].as_ref(),
                                targets: None,
                            },
                        );
                        let out = built.tape.value(built.output).clone();
                        if trace::enabled() {
                            trace::end(
                                "compute",
                                "fwd",
                                tt,
                                vec![
                                    trace::u("step", step),
                                    trace::u("mb", mb as u64),
                                ],
                            );
                        }
                        let te = trace::begin();
                        let cf = encode_boundary(
                            &cfg,
                            &h,
                            &out,
                            stage,
                            mb,
                            BoundaryDir::Fwd,
                            step,
                        );
                        if trace::enabled() {
                            trace::end(
                                "codec",
                                "encode:fwd",
                                te,
                                vec![
                                    trace::u("step", step),
                                    trace::u("mb", mb as u64),
                                    trace::u(
                                        "bytes",
                                        cf.wire_len() as u64,
                                    ),
                                ],
                            );
                        }
                        if cfg.mode != Mode::PowerLR
                            && cf.wire_len() != bbytes
                        {
                            bail!(
                                "stage {stage}: encoded fwd frame is {} B, \
                                 wire accounting prices {bbytes} B",
                                cf.wire_len()
                            );
                        }
                        boundary_payload += priced_frame(cf.wire_len());
                        frames_sent += 1;
                        links.right().send(&WireFrame::boundary(
                            FrameKind::Fwd,
                            cfg.mode,
                            step,
                            mb,
                            cf.payload,
                        ))?;
                    } else {
                        // last stage: fused fwd + loss + bwd
                        let tt = trace::begin();
                        let mut built = build_stage(
                            &h,
                            cfg.mode,
                            stage,
                            &st.params,
                            StageIo {
                                u: &global.u,
                                e: &es[mb],
                                tok,
                                input: saved[mb].as_ref(),
                                targets: Some(tgt),
                            },
                        );
                        loss_sum +=
                            built.tape.value(built.output).item() as f64;
                        built.tape.backward_into(
                            built.output,
                            None,
                            &built.params,
                            &mut grad_acc,
                        );
                        accumulate_grads(&built, &mut grad_acc);
                        if compressed {
                            let g_full = built
                                .tape
                                .grad(
                                    built
                                        .x_full
                                        .expect("last stage reconstructs"),
                                )
                                .expect("g_full");
                            linalg::matmul_tn_acc(
                                g_full,
                                g_full,
                                s_acc
                                    .as_mut()
                                    .expect("last-stage accumulator"),
                            );
                            s_count += 1;
                        }
                        let gc = built
                            .tape
                            .grad(built.input.expect("last stage input"))
                            .expect("boundary gradient")
                            .clone();
                        if trace::enabled() {
                            trace::end(
                                "compute",
                                "fused",
                                tt,
                                vec![
                                    trace::u("step", step),
                                    trace::u("mb", mb as u64),
                                ],
                            );
                        }
                        let te = trace::begin();
                        let cf = encode_boundary(
                            &cfg,
                            &h,
                            &gc,
                            stage - 1,
                            mb,
                            BoundaryDir::Bwd,
                            step,
                        );
                        if trace::enabled() {
                            trace::end(
                                "codec",
                                "encode:bwd",
                                te,
                                vec![
                                    trace::u("step", step),
                                    trace::u("mb", mb as u64),
                                    trace::u(
                                        "bytes",
                                        cf.wire_len() as u64,
                                    ),
                                ],
                            );
                        }
                        boundary_payload += priced_frame(cf.wire_len());
                        frames_sent += 1;
                        links.left().send(&WireFrame::boundary(
                            FrameKind::Bwd,
                            cfg.mode,
                            step,
                            mb,
                            cf.payload,
                        ))?;
                        saved[mb] = None;
                    }
                }
                Task::Bwd(mb) => {
                    // stages < last only: rebuild (rematerialization),
                    // inject the delivered cotangent, ship the
                    // input-gradient further left
                    let (tok, _) = &batches[mb];
                    let f = recv_expect(
                        links.right(),
                        FrameKind::Bwd,
                        step,
                        Some(mb as u32),
                        stage,
                        "right",
                        stale,
                    )?;
                    let td = trace::begin();
                    let delivered = decode_boundary(spec, &f, stage)?;
                    if trace::enabled() {
                        trace::end(
                            "codec",
                            "decode:bwd",
                            td,
                            vec![
                                trace::u("step", step),
                                trace::u("mb", mb as u64),
                                trace::u("bytes", f.payload.len() as u64),
                            ],
                        );
                    }
                    let tt = trace::begin();
                    let mut built = build_stage(
                        &h,
                        cfg.mode,
                        stage,
                        &st.params,
                        StageIo {
                            u: &global.u,
                            e: &es[mb],
                            tok,
                            input: saved[mb].as_ref(),
                            targets: None,
                        },
                    );
                    built.tape.backward_into(
                        built.output,
                        Some(delivered),
                        &built.params,
                        &mut grad_acc,
                    );
                    accumulate_grads(&built, &mut grad_acc);
                    if trace::enabled() {
                        trace::end(
                            "compute",
                            "bwd",
                            tt,
                            vec![
                                trace::u("step", step),
                                trace::u("mb", mb as u64),
                            ],
                        );
                    }
                    if stage > 0 {
                        let gc = built
                            .tape
                            .grad(built.input.expect("mid stage input"))
                            .expect("boundary gradient")
                            .clone();
                        let cf = encode_boundary(
                            &cfg,
                            &h,
                            &gc,
                            stage - 1,
                            mb,
                            BoundaryDir::Bwd,
                            step,
                        );
                        boundary_payload += priced_frame(cf.wire_len());
                        frames_sent += 1;
                        links.left().send(&WireFrame::boundary(
                            FrameKind::Bwd,
                            cfg.mode,
                            step,
                            mb,
                            cf.payload,
                        ))?;
                    }
                    saved[mb] = None;
                }
            }
        }

        // ---- average gradients, optimizer step (own stage only)
        let scale = 1.0 / m_count as f32;
        for g in grad_acc.iter_mut() {
            g.scale(scale);
        }
        // ---- data-parallel axis: reduce this stage's averaged
        // gradients across the replica mesh before the optimizer sees
        // them (ring: exact mean of all replicas; gossip: pairwise
        // average with the step's scheduled peer)
        if let Some(dp) = dp.as_mut() {
            dp_reduce_stage(dp, &mut grad_acc, &h, step, stage)?;
        }
        let lr = cfg.lr_at(step);
        let u_now = global.u.clone();
        step_stage(
            &mut st,
            &grad_acc,
            &OptStep {
                optim: spec.optim,
                u: compressed.then_some(&u_now),
                lr,
                t: (step + 1) as f32,
            },
        );

        // ---- StepEnd relay: loss bits (+ new U on Grassmann steps)
        let due = compressed
            && cfg.grassmann_interval > 0
            && (step + 1) % cfg.grassmann_interval as u64 == 0
            && s_count > 0;
        if stage == last {
            let mut payload = loss_sum.to_le_bytes().to_vec();
            if due {
                let acc = s_acc.as_mut().expect("last-stage accumulator");
                global.u = grassmann_step_u(
                    &global.u,
                    acc,
                    s_count,
                    cfg.grassmann_eta,
                );
                reproject_stage(&mut st, &global.u);
                *acc = Tensor::zeros(&[h.d, h.d]);
                s_count = 0;
                for x in &global.u.data {
                    payload.extend_from_slice(&x.to_le_bytes());
                }
            }
            frames_sent += 1;
            links.left().send(&WireFrame::control(
                FrameKind::StepEnd,
                step,
                payload,
            ))?;
        } else {
            let f = recv_expect(
                links.right(),
                FrameKind::StepEnd,
                step,
                None,
                stage,
                "right",
                stale,
            )?;
            let u_len = h.d * h.k * 4;
            match f.payload.len() {
                8 => {}
                n if n == 8 + u_len => {
                    let mut u_new = Vec::with_capacity(h.d * h.k);
                    for c in f.payload[8..].chunks_exact(4) {
                        u_new.push(f32::from_le_bytes([
                            c[0], c[1], c[2], c[3],
                        ]));
                    }
                    global.u = Tensor::new(vec![h.d, h.k], u_new);
                    reproject_stage(&mut st, &global.u);
                }
                n => bail!(
                    "stage {stage}: StepEnd payload is {n} B (expected 8 \
                     or {})",
                    8 + u_len
                ),
            }
            let relayed_loss = f64::from_le_bytes(
                f.payload[0..8].try_into().expect("8-byte loss prefix"),
            );
            if stage > 0 {
                frames_sent += 1;
                links.left().send(&f)?;
            } else {
                let mean = relayed_loss / m_count as f64;
                losses.push(mean);
                step_seconds.push(t0.elapsed().as_secs_f64());
                // elastic: relay the step's loss to the supervisor so
                // the curve survives an epoch that later fails
                if let Some(ctl) = ctl.as_deref_mut() {
                    ctl.send(&WireFrame::control(
                        FrameKind::StepEnd,
                        step,
                        mean.to_le_bytes().to_vec(),
                    ))?;
                }
            }
        }

        // ---- elastic: ship a compressed checkpoint of this stage's
        // state at the configured boundary cadence
        if let (Some(e), Some(ctl)) = (ectx, ctl.as_deref_mut()) {
            if e.ckpt_every > 0 && (step + 1) % e.ckpt_every == 0 {
                let blob = crate::compress::ckpt::encode_stage(
                    &st,
                    &global.u,
                    s_acc.as_ref(),
                    s_count,
                    step + 1,
                    cfg.mode,
                    e.ckpt_codec,
                );
                ctl.send(&WireFrame::control(
                    FrameKind::Checkpoint,
                    step + 1,
                    blob,
                ))?;
            }
        }
        if trace::enabled() {
            trace::end("step", "step", tt_step, vec![trace::u("step", step)]);
        }
    }

    // ---- graceful goodbye on both links (best effort)
    let bye = WireFrame::control(FrameKind::Bye, spec.steps as u64, Vec::new());
    if let Some(conn) = links.left.as_deref_mut() {
        let _ = conn.send(&bye);
    }
    if let Some(conn) = links.right.as_deref_mut() {
        let _ = conn.send(&bye);
    }

    let mut wire_bytes = links.left.as_deref().map_or(0, |c| c.bytes_sent())
        + links.right.as_deref().map_or(0, |c| c.bytes_sent());
    let mut dp_payload_bytes = 0u64;
    if let Some(dp) = dp.as_ref() {
        wire_bytes += dp.link_bytes_sent();
        frames_sent += dp.dp_frames;
        dp_payload_bytes = dp.dp_payload_bytes;
    }
    Ok(WorkerReport {
        stage,
        losses,
        step_seconds,
        boundary_payload_bytes: boundary_payload,
        wire_bytes,
        frames_sent,
        dp_payload_bytes,
    })
}

// ---------------------------------------------------------------------------
// local multi-worker drivers (threads in one process)
// ---------------------------------------------------------------------------

/// One optional link end (absent at the pipeline's outer edges).
pub(crate) type LinkEnd = Option<Box<dyn Transport>>;

/// Build the per-stage (left, right) link ends of one pipeline chain
/// over the chosen backend — shared by [`run_local`] and the elastic
/// supervisor (which rebuilds a fresh chain every recovery epoch).
pub(crate) fn chain_ends(
    p: usize,
    kind: TransportKind,
) -> Result<Vec<(LinkEnd, LinkEnd)>> {
    let mut ends: Vec<(LinkEnd, LinkEnd)> =
        (0..p).map(|_| (None, None)).collect();
    for link in 0..p - 1 {
        let (a, b): (Box<dyn Transport>, Box<dyn Transport>) = match kind {
            TransportKind::Channel => {
                let (a, b) = channel_pair();
                (Box::new(a), Box::new(b))
            }
            TransportKind::Tcp => {
                let listener = std::net::TcpListener::bind("127.0.0.1:0")
                    .context("binding loopback listener")?;
                let addr = listener.local_addr()?;
                let client = std::net::TcpStream::connect(addr)
                    .with_context(|| format!("connecting loopback {addr}"))?;
                let (server, _) = listener
                    .accept()
                    .context("accepting loopback connection")?;
                (
                    Box::new(TcpTransport::new(client)?),
                    Box::new(TcpTransport::new(server)?),
                )
            }
        };
        ends[link].1 = Some(a); // stage `link`'s right end
        ends[link + 1].0 = Some(b); // stage `link + 1`'s left end
    }
    Ok(ends)
}

/// Run the full distributed pipeline locally: P stage workers on OS
/// threads, joined by the chosen transport (in-process channels, or
/// real TCP sockets over loopback). Returns the aggregate report; any
/// worker error — including a departed peer — propagates with its
/// stage context.
pub fn run_local(spec: &WorkerSpec, kind: TransportKind) -> Result<DistReport> {
    // thin shim over the unified entry point: a 1×P grid with no
    // reduce is exactly the classic single-chain run
    let tspec = super::dp::TrainSpec::from_worker(spec.clone());
    let rep = super::dp::launch(&tspec.topology(kind), &tspec)?;
    Ok(DistReport {
        losses: rep.losses,
        step_seconds: rep.step_seconds,
        boundary_payload_bytes: rep.boundary_payload_bytes,
        wire_bytes: rep.wire_bytes,
        frames: rep.frames,
        frame_payload_bytes: spec.cfg.boundary_bytes(&spec.h),
        dp_payload_bytes: rep.dp_payload_bytes,
    })
}

// ---------------------------------------------------------------------------
// standalone worker processes (`protomodels serve --stage i`)
// ---------------------------------------------------------------------------

/// Connection-establishment retry budget for `serve` workers: how long
/// a dialing stage waits for its left neighbor's listener to appear.
const DIAL_ATTEMPTS: usize = 120;
const DIAL_BACKOFF_MS: u64 = 250;

/// Run one stage as a standalone process over real TCP: stage `i` binds
/// `host:port_base+i` for its right neighbor and dials
/// `host:port_base+i−1` (with retries, so launch order is free). Blocks
/// until the run completes; returns this worker's report (stage 0's
/// carries the loss curve).
///
/// Thin shim over [`super::launch_serve`] with a
/// [`super::ServeRole::Stage`] role.
pub fn serve_stage(
    spec: &WorkerSpec,
    stage: usize,
    host: &str,
    port_base: u16,
) -> Result<WorkerReport> {
    let tspec = TrainSpec::from_worker(spec.clone());
    match super::launch_serve(
        &super::ServeRole::Stage { stage },
        &super::WorkloadSpec::Train(&tspec),
        host,
        port_base,
    )? {
        super::ServeOutcome::Worker(w) => Ok(w),
        other => bail!("serve_stage produced an unexpected {other:?}"),
    }
}

/// Establish one stage's (left, right) TCP link ends of a serve chain:
/// bind `host:port_base+stage` for the right neighbor (stages < last)
/// and dial `host:port_base+stage−1` with retries (stages > 0), so
/// process launch order is free. Shared by the train and serve-infer
/// standalone workers.
pub(crate) fn tcp_chain_links(
    stages: usize,
    stage: usize,
    host: &str,
    port_base: u16,
) -> Result<(LinkEnd, LinkEnd)> {
    let last = stages - 1;
    if stage > last {
        bail!("--stage {stage} out of range for {stages} stages");
    }
    // bind our own listener before dialing left, so the successor can
    // complete its dial regardless of process launch order
    let listener = if stage < last {
        let port = port_base
            .checked_add(stage as u16)
            .ok_or_else(|| anyhow::anyhow!("port base too high"))?;
        Some(
            std::net::TcpListener::bind((host, port))
                .with_context(|| format!("binding {host}:{port}"))?,
        )
    } else {
        None
    };
    let left: Option<Box<dyn Transport>> = if stage > 0 {
        let port = port_base + (stage as u16) - 1;
        let mut stream = None;
        for attempt in 0..DIAL_ATTEMPTS {
            match std::net::TcpStream::connect((host, port)) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(e) if attempt + 1 == DIAL_ATTEMPTS => {
                    return Err(e).with_context(|| {
                        format!(
                            "stage {stage}: left neighbor never appeared \
                             at {host}:{port}"
                        )
                    });
                }
                Err(_) => std::thread::sleep(
                    std::time::Duration::from_millis(DIAL_BACKOFF_MS),
                ),
            }
        }
        Some(Box::new(TcpTransport::new(stream.expect("dialed"))?))
    } else {
        None
    };
    let right: Option<Box<dyn Transport>> = match listener {
        Some(l) => {
            let (s, peer) = l.accept().with_context(|| {
                format!("stage {stage}: accepting the right neighbor")
            })?;
            crate::obs::log!(
                Info,
                "serve: stage {stage}: right neighbor {peer}"
            );
            Some(Box::new(TcpTransport::new(s)?))
        }
        None => None,
    };
    Ok((left, right))
}

/// The standalone-TCP train worker behind [`serve_stage`] /
/// [`super::launch_serve`].
pub(crate) fn serve_stage_impl(
    spec: &WorkerSpec,
    stage: usize,
    host: &str,
    port_base: u16,
) -> Result<WorkerReport> {
    spec.validate()?;
    let (left, right) = tcp_chain_links(spec.h.stages, stage, host, port_base)?;
    run_stage(spec, stage, left, right)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::PipelineConfig;
    use crate::data::CorpusKind;
    use crate::nn::Optim;

    fn tiny_spec(steps: usize) -> WorkerSpec {
        WorkerSpec {
            h: Hyper::tiny_native(),
            cfg: PipelineConfig {
                mode: Mode::Subspace,
                microbatches: 2,
                grassmann_interval: 0,
                lr: 1e-2,
                warmup_steps: 3,
                total_steps: steps,
                seed: 5,
                ..Default::default()
            },
            optim: Optim::AdamW,
            steps,
            corpus_kind: CorpusKind::Wiki,
            corpus_tokens: 50_000,
        }
    }

    #[test]
    fn digest_is_sensitive_to_numerics_fields_only() {
        let a = tiny_spec(4);
        let mut b = tiny_spec(4);
        assert_eq!(a.digest(), b.digest());
        b.cfg.seed ^= 1;
        assert_ne!(a.digest(), b.digest());
        let mut c = tiny_spec(4);
        c.cfg.mode = Mode::Raw;
        assert_ne!(a.digest(), c.digest());
        // the virtual-clock model cannot change the loss curve: excluded
        let mut d = tiny_spec(4);
        d.cfg.event_sim = true;
        d.cfg.record_grads = true;
        assert_eq!(a.digest(), d.digest());
    }

    #[test]
    fn wave_orders_cover_every_microbatch_once() {
        for schedule in [Schedule::Gpipe, Schedule::OneFOneB] {
            for stages in [2usize, 4] {
                for stage in 0..stages {
                    for m in [1usize, 2, 5, 8] {
                        let order = wave_order(schedule, stages, stage, m);
                        let mut fwd = vec![0usize; m];
                        let mut bwd = vec![0usize; m];
                        let mut last_bwd = None;
                        for t in &order {
                            match *t {
                                Task::Fwd(mb) => fwd[mb] += 1,
                                Task::Bwd(mb) => {
                                    // backwards strictly ascending — the
                                    // bitwise grad-accumulation contract
                                    let in_order = match last_bwd {
                                        None => mb == 0,
                                        Some(p) => mb == p + 1,
                                    };
                                    assert!(
                                        in_order,
                                        "bwd order broke at {mb}"
                                    );
                                    last_bwd = Some(mb);
                                    // fwd must precede its own bwd
                                    assert_eq!(fwd[mb], 1, "mb {mb}");
                                    bwd[mb] += 1;
                                }
                            }
                        }
                        assert!(fwd.iter().all(|&c| c == 1));
                        if stage == stages - 1 {
                            // last stage fuses: no separate bwd tasks
                            assert!(bwd.iter().all(|&c| c == 0));
                        } else {
                            assert!(bwd.iter().all(|&c| c == 1));
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn one_f_one_b_bounds_in_flight_forwards() {
        // at most P − s forwards may run before the first backward
        let order = wave_order(Schedule::OneFOneB, 4, 1, 8);
        let before_first_bwd = order
            .iter()
            .take_while(|t| matches!(**t, Task::Fwd(_)))
            .count();
        assert_eq!(before_first_bwd, 3);
        // gpipe drains every forward first
        let order = wave_order(Schedule::Gpipe, 4, 1, 8);
        let before_first_bwd = order
            .iter()
            .take_while(|t| matches!(**t, Task::Fwd(_)))
            .count();
        assert_eq!(before_first_bwd, 8);
    }

    #[test]
    fn interleaved_schedule_rejected() {
        let mut spec = tiny_spec(2);
        spec.cfg.schedule = Schedule::Interleaved { chunks: 2 };
        let err = spec.validate().unwrap_err().to_string();
        assert!(err.contains("interleaved"), "{err}");
    }

    #[test]
    fn transport_kind_parse_roundtrip() {
        for k in [TransportKind::Channel, TransportKind::Tcp] {
            assert_eq!(TransportKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(TransportKind::parse("carrier-pigeon").is_err());
    }
}
