//! Real cross-replica data parallelism over the wire: gradient frames,
//! ring all-reduce, gossip partial exchange, and the unified
//! [`TrainSpec`]/[`Topology`] launch API (DESIGN.md §14).
//!
//! Until this module existed the repo's data-parallel axis lived only in
//! accounting ([`crate::memory::dp_ring_step_wire_bytes`]) and in the
//! in-process [`crate::coordinator::replica::ReplicaSet`]. Here the
//! replica axis becomes real worker grids: an R×P run is R pipeline
//! chains (each identical to the single-replica distributed pipeline)
//! plus a per-stage cross-replica mesh carrying **gradient frames** —
//! [`FrameKind::GradRing`] / [`FrameKind::GradGossip`] payloads that are
//! the exact byte strings the dp codecs emit, so
//! `payload_len == compress::dp_wire_bytes` holds on the wire and is
//! asserted on every received frame.
//!
//! ## Ring all-reduce (synchronous DP)
//!
//! Each stage's fused weight-gradient accumulator is flattened, split
//! into R balanced chunks, and reduced around the replica ring in the
//! classic 2(R−1) phases: R−1 reduce-scatter hops (each hop encodes the
//! *running partial sum* under the dp codec, so lossy codecs degrade
//! identically everywhere) and R−1 all-gather hops (the owner encodes
//! its fully reduced chunk **once** and the bytes relay unchanged, so
//! every replica decodes the identical payload). The in-process
//! reference [`ring_allreduce_local`] performs the same hops with the
//! same codec calls in the same order — which is why a ring grid's loss
//! curve is **bitwise identical** to the single-process replica path
//! (`tests/transport_parity.rs` compares f64 loss bits).
//!
//! ## Gossip partial exchange (asynchronous DP)
//!
//! No global barrier: every step, a deterministic schedule seeded by
//! [`crate::par::cell_seed`]`(seed, step)` shuffles the replica ids and
//! pairs them off; each pair exchanges one full gradient frame and
//! averages (the Decent-DP-style optimizer-aware exchange: gradients are
//! averaged *before* the local optimizer step, so each replica's Adam
//! moments track its own averaged stream). An odd replica idles for the
//! step. A dead peer — scripted kill or vanished process — surfaces as a
//! departed transport error; the survivor keeps its local gradients and
//! never schedules that peer again. Gossip runs are therefore
//! churn-tolerant but only statistically aligned: the contract is a
//! convergence envelope (`tests/chaos.rs`), not bitwise parity.
//!
//! ## TrainSpec / Topology
//!
//! [`TrainSpec`] is the one validated description of a training run —
//! the CLI parses into it, `launch` digests it into the `Hello`
//! handshake (`PMCFG3 = PMCFG2 ‖ workload-tag`, wrapping the per-chain
//! `PMCFG1` [`super::spec::SpecCore`] digest), and elastic/chaos
//! options nest inside it as [`ElasticOpts`] (carrying the
//! [`FaultPlan`] and churn timeline). [`Topology`] is the runtime
//! shape — `{replicas, stages, backend, reduce}` — and
//! [`launch`]`(topology, spec)` is the single in-process entry point
//! the legacy free functions (`run_local`, `run_elastic`) now shim to;
//! the multi-process serve entries shim to [`super::launch_serve`].

use anyhow::{bail, Context, Result};

use crate::compress::{
    self, dp_wire_bytes, topk_keep, CkptCodec, Mode,
};
use crate::coordinator::PipelineConfig;
use crate::data::CorpusKind;
use crate::manifest::Hyper;
use crate::nn::{NativePipeline, Optim};
use crate::obs::trace;
use crate::par::cell_seed;
use crate::rng::Rng;
use crate::sim::ChurnTimeline;
use crate::tensor::Tensor;

use super::dist::{
    chain_ends, recv_expect, run_stage_inner, LinkEnd, TransportKind,
    WorkerReport, WorkerSpec,
};
use super::elastic::{run_elastic_impl, ElasticReport, ElasticSpec};
use super::fault::FaultPlan;
use super::frame::{FrameKind, WireFrame};
use super::{channel_pair, TcpTransport, Transport};

// ---------------------------------------------------------------------------
// reduce algorithms
// ---------------------------------------------------------------------------

/// How a replica grid reduces gradients across the data-parallel axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Reduce {
    /// single replica chain — no cross-replica traffic
    None,
    /// synchronous ring all-reduce: 2(R−1) phases, bitwise-deterministic
    Ring,
    /// asynchronous gossip: `degree` seeded peers per step (only
    /// `degree: 1` — pairwise — runs on the wire; higher degrees are
    /// simulator-only)
    Gossip {
        /// peers exchanged with per step
        degree: usize,
    },
}

impl Reduce {
    /// Parse a CLI label: `none`, `ring`, `gossip`, `gossip:<degree>`.
    pub fn parse(s: &str) -> Result<Reduce> {
        match s {
            "none" => Ok(Reduce::None),
            "ring" => Ok(Reduce::Ring),
            "gossip" => Ok(Reduce::Gossip { degree: 1 }),
            other => match other.strip_prefix("gossip:") {
                Some(deg) => {
                    let degree: usize = deg.parse().with_context(|| {
                        format!("gossip degree {deg:?} is not a number")
                    })?;
                    Ok(Reduce::Gossip { degree })
                }
                None => bail!(
                    "unknown reduce {other:?} (have none, ring, gossip, \
                     gossip:<degree>)"
                ),
            },
        }
    }

    /// Canonical label (round-trips through [`Reduce::parse`]).
    pub fn label(&self) -> String {
        match self {
            Reduce::None => "none".into(),
            Reduce::Ring => "ring".into(),
            Reduce::Gossip { degree: 1 } => "gossip".into(),
            Reduce::Gossip { degree } => format!("gossip:{degree}"),
        }
    }
}

// ---------------------------------------------------------------------------
// gradient-frame codecs
// ---------------------------------------------------------------------------

/// The R balanced `[start, end)` chunks of a flattened gradient — the
/// same split [`crate::memory::dp_ring_step_wire_bytes`] prices (chunk
/// `i` gets `elems/R + (i < elems % R)` elements).
pub fn chunk_ranges(elems: usize, replicas: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::with_capacity(replicas);
    let mut off = 0;
    for i in 0..replicas {
        let len = elems / replicas + usize::from(i < elems % replicas);
        out.push((off, off + len));
        off += len;
    }
    debug_assert_eq!(off, elems);
    out
}

/// Segment count of the subspace-mean dp codec: ⌈elems·k/d⌉ — the
/// "U-only" gradient ratio applied along the flat parameter axis.
fn subspace_segments(elems: usize, d: usize, k: usize) -> usize {
    (elems * k + d.max(1) - 1) / d.max(1)
}

/// Mean of each of `n_keep` balanced contiguous segments (f32
/// accumulation in index order — the deterministic arithmetic both the
/// wire and the in-process reference share).
fn segment_means(xs: &[f32], n_keep: usize) -> Vec<f32> {
    let base = xs.len() / n_keep;
    let rem = xs.len() % n_keep;
    let mut means = Vec::with_capacity(n_keep);
    let mut off = 0;
    for i in 0..n_keep {
        let len = base + usize::from(i < rem);
        let mut s = 0.0f32;
        for &x in &xs[off..off + len] {
            s += x;
        }
        means.push(s / len as f32);
        off += len;
    }
    means
}

/// Broadcast `n_keep` segment means back over `elems` elements.
fn segment_broadcast(means: &[f32], elems: usize) -> Vec<f32> {
    let n_keep = means.len();
    let base = elems / n_keep;
    let rem = elems % n_keep;
    let mut out = Vec::with_capacity(elems);
    for (i, &m) in means.iter().enumerate() {
        let len = base + usize::from(i < rem);
        out.extend(std::iter::repeat(m).take(len));
    }
    out
}

/// Encode one gradient slice under the dp codec for `mode`. The
/// returned payload is **exactly** [`dp_wire_bytes`] long — enforced
/// here so every sender upholds the pricing contract the receiver
/// asserts.
pub fn encode_grad(
    mode: Mode,
    xs: &[f32],
    d: usize,
    k: usize,
    ratio: f64,
) -> Result<Vec<u8>> {
    let want = dp_wire_bytes(mode, xs.len(), d, k, ratio);
    let payload = match mode {
        Mode::Raw => {
            let mut p = Vec::with_capacity(xs.len() * 4);
            for x in xs {
                p.extend_from_slice(&x.to_le_bytes());
            }
            p
        }
        Mode::RawBf16 => {
            let mut p = Vec::with_capacity(xs.len() * 2);
            for &x in xs {
                p.extend_from_slice(
                    &compress::f32_to_bf16(x).to_le_bytes(),
                );
            }
            p
        }
        Mode::Quant => {
            // same rule as compress::encode_quant: symmetric int8 with
            // one f32 scale per payload
            let max = xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let scale = if max > 0.0 { max / 127.0 } else { 1.0 };
            let mut p = Vec::with_capacity(4 + xs.len());
            p.extend_from_slice(&scale.to_le_bytes());
            for &x in xs {
                let q = (x / scale).round().clamp(-127.0, 127.0) as i8;
                p.push(q as u8);
            }
            p
        }
        Mode::TopK => {
            let keep = topk_keep(xs.len(), ratio);
            if keep > xs.len() {
                bail!(
                    "top-k keeps {keep} of a {}-element gradient chunk \
                     (ratio {ratio} is too low for dp chunking)",
                    xs.len()
                );
            }
            let mut idx: Vec<u32> = (0..xs.len() as u32).collect();
            idx.select_nth_unstable_by(keep.saturating_sub(1), |&a, &b| {
                xs[b as usize].abs().total_cmp(&xs[a as usize].abs())
            });
            idx.truncate(keep);
            idx.sort_unstable();
            let mut p = Vec::with_capacity(keep * 8);
            for &i in &idx {
                p.extend_from_slice(&i.to_le_bytes());
                p.extend_from_slice(&xs[i as usize].to_le_bytes());
            }
            p
        }
        Mode::Subspace | Mode::NoFixed => {
            let means = segment_means(xs, subspace_segments(xs.len(), d, k));
            let mut p = Vec::with_capacity(means.len() * 4);
            for m in &means {
                p.extend_from_slice(&m.to_le_bytes());
            }
            p
        }
        Mode::SubspaceBf16 => {
            let means = segment_means(xs, subspace_segments(xs.len(), d, k));
            let mut p = Vec::with_capacity(means.len() * 2);
            for &m in &means {
                p.extend_from_slice(
                    &compress::f32_to_bf16(m).to_le_bytes(),
                );
            }
            p
        }
        Mode::PowerLR => bail!(
            "powerlr is a boundary-activation scheme; gradient frames \
             have no factor codec — pick raw, quant, topk, subspace, \
             raw-bf16, or subspace-bf16 for the dp wire"
        ),
    };
    if payload.len() != want {
        bail!(
            "encoded gradient payload is {} B but dp_wire_bytes prices \
             {want} B for mode {} over {} elements",
            payload.len(),
            mode.as_str(),
            xs.len()
        );
    }
    Ok(payload)
}

/// Decode one gradient payload back to `elems` f32 values, enforcing
/// the `payload_len == dp_wire_bytes` contract on the receiving side.
pub fn decode_grad(
    mode: Mode,
    payload: &[u8],
    elems: usize,
    d: usize,
    k: usize,
    ratio: f64,
) -> Result<Vec<f32>> {
    let want = dp_wire_bytes(mode, elems, d, k, ratio);
    if payload.len() != want {
        bail!(
            "gradient frame payload is {} B but dp_wire_bytes prices \
             {want} B for mode {} over {elems} elements",
            payload.len(),
            mode.as_str()
        );
    }
    match mode {
        Mode::Raw => Ok(payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()),
        Mode::RawBf16 => Ok(payload
            .chunks_exact(2)
            .map(|c| compress::bf16_to_f32(u16::from_le_bytes([c[0], c[1]])))
            .collect()),
        Mode::Quant => {
            let scale = f32::from_le_bytes([
                payload[0], payload[1], payload[2], payload[3],
            ]);
            Ok(payload[4..]
                .iter()
                .map(|&b| (b as i8) as f32 * scale)
                .collect())
        }
        Mode::TopK => {
            let mut out = vec![0.0f32; elems];
            for c in payload.chunks_exact(8) {
                let i =
                    u32::from_le_bytes([c[0], c[1], c[2], c[3]]) as usize;
                let v = f32::from_le_bytes([c[4], c[5], c[6], c[7]]);
                if i >= elems {
                    bail!(
                        "top-k gradient index {i} out of range for a \
                         {elems}-element chunk"
                    );
                }
                out[i] = v;
            }
            Ok(out)
        }
        Mode::Subspace | Mode::NoFixed => {
            let means: Vec<f32> = payload
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            Ok(segment_broadcast(&means, elems))
        }
        Mode::SubspaceBf16 => {
            let means: Vec<f32> = payload
                .chunks_exact(2)
                .map(|c| {
                    compress::bf16_to_f32(u16::from_le_bytes([c[0], c[1]]))
                })
                .collect();
            Ok(segment_broadcast(&means, elems))
        }
        Mode::PowerLR => bail!(
            "powerlr gradient frames cannot exist (no factor codec)"
        ),
    }
}

// ---------------------------------------------------------------------------
// ring all-reduce — in-process reference
// ---------------------------------------------------------------------------

/// The in-process ring all-reduce reference: performs **exactly** the
/// hops, codec calls, and arithmetic of the wire ring (reduce-scatter
/// with per-hop re-encode of partial sums; all-gather relaying the
/// owner's one encoding; final 1/R scale) on R flat gradients held in
/// one address space. The wire ring in [`launch`] matches this function
/// bitwise — the data-parallel analogue of the chain parity contract.
pub fn ring_allreduce_local(
    flats: &mut [Vec<f32>],
    mode: Mode,
    d: usize,
    k: usize,
    ratio: f64,
) -> Result<()> {
    let r_count = flats.len();
    if r_count < 2 {
        return Ok(());
    }
    let len = flats[0].len();
    if flats.iter().any(|f| f.len() != len) {
        bail!("replica gradients disagree in length");
    }
    if len < r_count {
        bail!(
            "{len} gradient elements cannot be ring-chunked over \
             {r_count} replicas"
        );
    }
    let ranges = chunk_ranges(len, r_count);
    // reduce-scatter: R−1 phases; every hop re-encodes the running
    // partial sum (lossy codecs degrade the same way on the wire)
    for p in 0..r_count - 1 {
        let enc: Vec<Vec<u8>> = (0..r_count)
            .map(|r| {
                let idx = (2 * r_count + r - p) % r_count;
                let (a, b) = ranges[idx];
                encode_grad(mode, &flats[r][a..b], d, k, ratio)
            })
            .collect::<Result<_>>()?;
        for r in 0..r_count {
            let to = (r + 1) % r_count;
            let idx = (2 * r_count + r - p) % r_count;
            let (a, b) = ranges[idx];
            let dec = decode_grad(mode, &enc[r], b - a, d, k, ratio)?;
            for (dst, v) in flats[to][a..b].iter_mut().zip(&dec) {
                *dst += *v;
            }
        }
    }
    // all-gather: each owner encodes its fully reduced chunk once and
    // applies its own codec locally (so the owner holds the same
    // post-codec values every other replica will decode), then the
    // bytes relay unchanged around the ring
    let mut carry: Vec<Vec<u8>> = (0..r_count)
        .map(|r| {
            let owned = (r + 1) % r_count;
            let (a, b) = ranges[owned];
            let enc = encode_grad(mode, &flats[r][a..b], d, k, ratio)?;
            let dec = decode_grad(mode, &enc, b - a, d, k, ratio)?;
            flats[r][a..b].copy_from_slice(&dec);
            Ok(enc)
        })
        .collect::<Result<_>>()?;
    for p in 0..r_count - 1 {
        let mut next: Vec<Vec<u8>> = vec![Vec::new(); r_count];
        for r in 0..r_count {
            let to = (r + 1) % r_count;
            let idx = (2 * r_count + to - p) % r_count;
            let (a, b) = ranges[idx];
            let dec = decode_grad(mode, &carry[r], b - a, d, k, ratio)?;
            flats[to][a..b].copy_from_slice(&dec);
            next[to] = std::mem::take(&mut carry[r]);
        }
        carry = next;
    }
    let inv = 1.0 / r_count as f32;
    for f in flats.iter_mut() {
        for v in f.iter_mut() {
            *v *= inv;
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// gossip schedule
// ---------------------------------------------------------------------------

/// The step's deterministic gossip pairing: Fisher–Yates shuffle of all
/// replica ids seeded by [`cell_seed`]`(seed, step)`, adjacent ids
/// paired, an odd leftover idling. Every replica computes the identical
/// schedule from shared config alone — no coordinator, no barrier.
pub fn gossip_pairs(
    seed: u64,
    step: u64,
    replicas: usize,
) -> Vec<(usize, usize)> {
    let mut order: Vec<usize> = (0..replicas).collect();
    let mut rng = Rng::new(cell_seed(seed, step as usize));
    for i in (1..order.len()).rev() {
        let j = rng.below(i + 1);
        order.swap(i, j);
    }
    order.chunks_exact(2).map(|c| (c[0], c[1])).collect()
}

/// This replica's peer for the step, if the schedule pairs it.
pub fn gossip_partner(
    seed: u64,
    step: u64,
    replicas: usize,
    me: usize,
) -> Option<usize> {
    gossip_pairs(seed, step, replicas).iter().find_map(|&(a, b)| {
        if a == me {
            Some(b)
        } else if b == me {
            Some(a)
        } else {
            None
        }
    })
}

// ---------------------------------------------------------------------------
// the per-worker DP context (consumed by dist::run_stage_inner)
// ---------------------------------------------------------------------------

/// Everything one stage worker needs to participate in the
/// data-parallel axis: its replica coordinate, the cross-replica links
/// of its stage, the reduce algorithm, and the grid-wide `PMCFG2`
/// digest that replaces the per-chain digest in the handshake.
pub(crate) struct DpCtx {
    pub replica: usize,
    pub replicas: usize,
    pub reduce: Reduce,
    pub dp_mode: Mode,
    /// gossip schedule seed (the run seed; every worker derives the
    /// same pairings)
    pub seed: u64,
    /// replica-sharded data seed — mirrors
    /// `NativePipeline::reseed_data(seed ^ ((r+1)·0x9E37_79B9))`
    pub shard_seed: u64,
    /// the Train-wrapped [`TrainSpec::digest`] (see
    /// [`super::handshake_wrap`]) every grid link handshakes with
    pub digest: Vec<u8>,
    /// scripted chaos: leave the grid at this step (gossip runs only)
    pub kill_at: Option<u64>,
    /// straggler profile: extra wall seconds this replica spends per
    /// step before its gradient exchange (0 = healthy)
    pub straggle_s: f64,
    /// same-stage links to every other replica (index = replica id)
    pub links: Vec<LinkEnd>,
    /// peers observed dead (failed exchange) — never rescheduled
    pub dead: Vec<bool>,
    /// gradient-frame payload bytes sent
    pub dp_payload_bytes: u64,
    /// gradient frames sent
    pub dp_frames: u64,
}

impl DpCtx {
    /// Total bytes sent on the dp links (headers included).
    pub fn link_bytes_sent(&self) -> u64 {
        self.links
            .iter()
            .map(|l| l.as_deref().map_or(0, |c| c.bytes_sent()))
            .sum()
    }
}

/// Validate a received gradient frame: kind-specific codec tag and the
/// acceptance contract `payload_len == dp_wire_bytes`.
fn check_grad_frame(
    f: &WireFrame,
    mode: Mode,
    elems: usize,
    h: &Hyper,
    stage: usize,
    replica: usize,
) -> Result<()> {
    match f.codec {
        Some(c) if c == mode => {}
        other => bail!(
            "replica {replica} stage {stage}: gradient frame codec \
             {other:?} does not match the handshaked dp mode {mode:?}"
        ),
    }
    let want = dp_wire_bytes(mode, elems, h.d, h.k, h.ratio);
    if f.payload.len() != want {
        bail!(
            "replica {replica} stage {stage}: gradient frame payload is \
             {} B but compress::dp_wire_bytes prices {want} B for mode \
             {} over {elems} elements",
            f.payload.len(),
            mode.as_str()
        );
    }
    Ok(())
}

/// The DP hook `dist::run_stage_inner` calls between gradient averaging
/// and the optimizer step: flatten the stage's accumulators, reduce
/// across the replica axis (ring or gossip), and unflatten in place.
pub(crate) fn dp_reduce_stage(
    dp: &mut DpCtx,
    grad_acc: &mut [Tensor],
    h: &Hyper,
    step: u64,
    stage: usize,
) -> Result<()> {
    if dp.replicas < 2 || matches!(dp.reduce, Reduce::None) {
        return Ok(());
    }
    if dp.straggle_s > 0.0 {
        std::thread::sleep(std::time::Duration::from_secs_f64(
            dp.straggle_s,
        ));
    }
    let total: usize = grad_acc.iter().map(|g| g.numel()).sum();
    let mut flat = Vec::with_capacity(total);
    for g in grad_acc.iter() {
        flat.extend_from_slice(&g.data);
    }
    match dp.reduce {
        Reduce::None => unreachable!(),
        Reduce::Ring => ring_allreduce_wire(dp, &mut flat, h, step, stage)?,
        Reduce::Gossip { .. } => {
            gossip_exchange(dp, &mut flat, h, step, stage)?
        }
    }
    let mut off = 0;
    for g in grad_acc.iter_mut() {
        let n = g.numel();
        g.data.copy_from_slice(&flat[off..off + n]);
        off += n;
    }
    Ok(())
}

/// The wire ring: same hops as [`ring_allreduce_local`], executed from
/// one replica's point of view. Sends never block (both backends queue),
/// so the per-phase send-then-receive order is deadlock-free.
fn ring_allreduce_wire(
    dp: &mut DpCtx,
    flat: &mut [f32],
    h: &Hyper,
    step: u64,
    stage: usize,
) -> Result<()> {
    let r_count = dp.replicas;
    let me = dp.replica;
    let len = flat.len();
    if len < r_count {
        bail!(
            "replica {me} stage {stage}: {len} gradient elements cannot \
             be ring-chunked over {r_count} replicas"
        );
    }
    let (mode, d, k, ratio) = (dp.dp_mode, h.d, h.k, h.ratio);
    let ranges = chunk_ranges(len, r_count);
    let right = (me + 1) % r_count;
    let left = (me + r_count - 1) % r_count;
    // reduce-scatter
    let tt = trace::begin();
    let bytes0 = dp.dp_payload_bytes;
    for p in 0..r_count - 1 {
        let si = (2 * r_count + me - p) % r_count;
        let ri = (2 * r_count + me - 1 - p) % r_count;
        let (sa, sb) = ranges[si];
        let payload = encode_grad(mode, &flat[sa..sb], d, k, ratio)?;
        dp.dp_payload_bytes += payload.len() as u64;
        dp.dp_frames += 1;
        dp.links[right]
            .as_deref_mut()
            .expect("ring right link")
            .send(&WireFrame::grad(
                FrameKind::GradRing,
                mode,
                step,
                p,
                payload,
            ))?;
        let f = recv_expect(
            dp.links[left].as_deref_mut().expect("ring left link"),
            FrameKind::GradRing,
            step,
            Some(p as u32),
            stage,
            "left replica",
            None,
        )?;
        let (ra, rb) = ranges[ri];
        check_grad_frame(&f, mode, rb - ra, h, stage, me)?;
        let dec = decode_grad(mode, &f.payload, rb - ra, d, k, ratio)?;
        for (dst, v) in flat[ra..rb].iter_mut().zip(&dec) {
            *dst += *v;
        }
    }
    if trace::enabled() {
        trace::end(
            "reduce",
            "ring:reduce-scatter",
            tt,
            vec![
                trace::u("step", step),
                trace::u("bytes", dp.dp_payload_bytes - bytes0),
            ],
        );
    }
    // all-gather: encode the owned chunk once, self-decode, relay bytes
    let tt = trace::begin();
    let bytes0 = dp.dp_payload_bytes;
    let owned = (me + 1) % r_count;
    let (oa, ob) = ranges[owned];
    let mut carry = encode_grad(mode, &flat[oa..ob], d, k, ratio)?;
    let dec = decode_grad(mode, &carry, ob - oa, d, k, ratio)?;
    flat[oa..ob].copy_from_slice(&dec);
    for p in 0..r_count - 1 {
        let phase = (r_count - 1 + p) as u32;
        dp.dp_payload_bytes += carry.len() as u64;
        dp.dp_frames += 1;
        dp.links[right]
            .as_deref_mut()
            .expect("ring right link")
            .send(&WireFrame::grad(
                FrameKind::GradRing,
                mode,
                step,
                phase as usize,
                carry.clone(),
            ))?;
        let f = recv_expect(
            dp.links[left].as_deref_mut().expect("ring left link"),
            FrameKind::GradRing,
            step,
            Some(phase),
            stage,
            "left replica",
            None,
        )?;
        let ri = (2 * r_count + me - p) % r_count;
        let (ra, rb) = ranges[ri];
        check_grad_frame(&f, mode, rb - ra, h, stage, me)?;
        let dec = decode_grad(mode, &f.payload, rb - ra, d, k, ratio)?;
        flat[ra..rb].copy_from_slice(&dec);
        carry = f.payload;
    }
    if trace::enabled() {
        trace::end(
            "reduce",
            "ring:all-gather",
            tt,
            vec![
                trace::u("step", step),
                trace::u("bytes", dp.dp_payload_bytes - bytes0),
            ],
        );
    }
    let inv = 1.0 / r_count as f32;
    for v in flat.iter_mut() {
        *v *= inv;
    }
    Ok(())
}

/// One gossip step: exchange full gradient frames with the scheduled
/// peer (if any) and average. Both sides decode their **own** encoding
/// too, so a pair lands on identical values — pairwise consensus — for
/// every codec, lossless or not. A failed exchange (peer killed or
/// departed) marks the peer dead and keeps the local gradients; any
/// other error propagates.
fn gossip_exchange(
    dp: &mut DpCtx,
    flat: &mut [f32],
    h: &Hyper,
    step: u64,
    stage: usize,
) -> Result<()> {
    let Some(peer) = gossip_partner(dp.seed, step, dp.replicas, dp.replica)
    else {
        return Ok(()); // odd replica out this step
    };
    if dp.dead[peer] {
        return Ok(());
    }
    let tt = trace::begin();
    let (mode, d, k, ratio) = (dp.dp_mode, h.d, h.k, h.ratio);
    let payload = encode_grad(mode, flat, d, k, ratio)?;
    let fr = WireFrame::grad(
        FrameKind::GradGossip,
        mode,
        step,
        0,
        payload,
    );
    let conn = dp.links[peer].as_deref_mut().expect("gossip peer link");
    if let Err(e) = conn.send(&fr) {
        if format!("{e:#}").contains("departed") {
            dp.dead[peer] = true;
            return Ok(());
        }
        return Err(e);
    }
    dp.dp_payload_bytes += fr.payload.len() as u64;
    dp.dp_frames += 1;
    match recv_expect(
        conn,
        FrameKind::GradGossip,
        step,
        Some(0),
        stage,
        "gossip peer",
        None,
    ) {
        Ok(f) => {
            check_grad_frame(&f, mode, flat.len(), h, stage, dp.replica)?;
            let theirs =
                decode_grad(mode, &f.payload, flat.len(), d, k, ratio)?;
            let mine =
                decode_grad(mode, &fr.payload, flat.len(), d, k, ratio)?;
            for ((dst, m), t) in
                flat.iter_mut().zip(&mine).zip(&theirs)
            {
                *dst = 0.5 * (*m + *t);
            }
        }
        Err(e) => {
            // a vanished peer is a churn event, not a run failure —
            // the Decent-DP survivor keeps its local gradients
            if format!("{e:#}").contains("departed") {
                dp.dead[peer] = true;
            } else {
                return Err(e);
            }
        }
    }
    if trace::enabled() {
        trace::end(
            "reduce",
            "gossip",
            tt,
            vec![
                trace::u("step", step),
                trace::u("peer", peer as u64),
                trace::u("bytes", fr.payload.len() as u64),
            ],
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// TrainSpec — the one validated run description
// ---------------------------------------------------------------------------

/// Elastic/chaos options nested inside [`TrainSpec`] — the same knobs
/// [`ElasticSpec`] carries, minus the worker (the spec owns it).
#[derive(Clone, Debug)]
pub struct ElasticOpts {
    /// checkpoint cadence in steps; 0 = auto (steps/4, min 1)
    pub ckpt_every: u64,
    /// checkpoint parameter codec
    pub ckpt_codec: CkptCodec,
    /// heartbeat cadence in steps
    pub heartbeat_every: u64,
    /// stale liveness timeout in ms
    pub stale_ms: u64,
    /// spare workers standing by
    pub spares: usize,
    /// scripted churn timeline (`kill:W@S,join:W@S`)
    pub chaos: ChurnTimeline,
    /// deterministic link-fault plan (drops / delays / severs)
    pub faults: FaultPlan,
    /// recovery attempts before the run is unrecoverable
    pub max_epochs: usize,
}

impl Default for ElasticOpts {
    fn default() -> Self {
        ElasticOpts {
            ckpt_every: 0,
            ckpt_codec: CkptCodec::Raw,
            heartbeat_every: 1,
            stale_ms: 5_000,
            spares: 1,
            chaos: ChurnTimeline::default(),
            faults: FaultPlan::default(),
            max_epochs: 8,
        }
    }
}

/// The canonical, validated description of a training run: the
/// per-chain [`WorkerSpec`] plus the data-parallel axis (replica count,
/// gradient codec, reduce algorithm) and optional nested elastic/chaos
/// options. The CLI parses into this; [`launch`] digests it into the
/// handshake; everything else derives from it.
#[derive(Clone, Debug)]
pub struct TrainSpec {
    /// the run every stage worker of every replica executes
    pub worker: WorkerSpec,
    /// data-parallel replica count (1 = a single pipeline chain)
    pub replicas: usize,
    /// gradient-frame codec on the dp wire
    pub dp_mode: Mode,
    /// cross-replica reduce algorithm
    pub reduce: Reduce,
    /// elastic/chaos options (single-replica chains only)
    pub elastic: Option<ElasticOpts>,
}

impl TrainSpec {
    /// Wrap a bare worker spec: one replica, no reduce, no elastic —
    /// the exact run the legacy `run_local` executed.
    pub fn from_worker(worker: WorkerSpec) -> TrainSpec {
        TrainSpec {
            worker,
            replicas: 1,
            dp_mode: Mode::Raw,
            reduce: Reduce::None,
            elastic: None,
        }
    }

    /// Start a builder from model dimensions.
    pub fn builder(h: Hyper) -> TrainSpecBuilder {
        TrainSpecBuilder::new(h)
    }

    /// Reject configurations the runtime cannot execute — with errors
    /// that say *why* and what to do instead.
    pub fn validate(&self) -> Result<()> {
        self.worker.validate()?;
        if self.replicas == 0 {
            bail!("need >= 1 replica (got 0)");
        }
        if self.replicas > 16 {
            bail!(
                "replica grids above 16 are untested ({} requested); \
                 the thread-per-worker runtime would spawn {} workers",
                self.replicas,
                self.replicas * self.worker.h.stages
            );
        }
        if self.replicas > 1 && matches!(self.reduce, Reduce::None) {
            bail!(
                "{} replicas need a gradient reduce algorithm: pick \
                 --reduce ring (synchronous, bitwise-deterministic) or \
                 --reduce gossip (asynchronous, churn-tolerant)",
                self.replicas
            );
        }
        if self.dp_mode == Mode::PowerLR {
            bail!(
                "powerlr cannot serve as --dp-mode: its sketch factors \
                 are boundary-activation-only and gradient frames have \
                 no factor codec; pick raw, quant, topk, subspace, \
                 raw-bf16, or subspace-bf16"
            );
        }
        if self.dp_mode == Mode::TopK && self.worker.h.ratio < 1.0 {
            bail!(
                "top-k dp gradients need ratio >= 1 (got {}); smaller \
                 ratios would keep more (index, value) pairs than a \
                 chunk has elements",
                self.worker.h.ratio
            );
        }
        if let Reduce::Gossip { degree } = self.reduce {
            if degree != 1 {
                bail!(
                    "gossip exchanges one peer per step on the wire \
                     (degree 1); degree-{degree} schedules are \
                     simulator-only (`protomodels sim`)"
                );
            }
        }
        if self.replicas > 1 && self.worker.cfg.grassmann_interval > 0 {
            bail!(
                "Grassmann basis adaptation would drift per replica \
                 under data parallelism (each last stage adapts its own \
                 U); run replica grids with --grassmann 0"
            );
        }
        if self.replicas > 1 && self.elastic.is_some() {
            bail!(
                "elastic recovery drives a single replica chain; \
                 replica grids tolerate churn through --reduce gossip \
                 instead"
            );
        }
        Ok(())
    }

    /// The grid handshake digest: `PMCFG2` wrapping the per-chain
    /// `PMCFG1` worker digest plus every dp-axis field. Two workers
    /// whose TrainSpecs differ anywhere numerics-affecting refuse to
    /// train together.
    pub fn digest(&self) -> Vec<u8> {
        let mut d = Vec::with_capacity(160);
        d.extend_from_slice(b"PMCFG2");
        d.extend_from_slice(&self.worker.digest());
        d.extend_from_slice(&(self.replicas as u64).to_le_bytes());
        d.push(self.dp_mode.wire_tag());
        match self.reduce {
            Reduce::None => d.push(0),
            Reduce::Ring => d.push(1),
            Reduce::Gossip { degree } => {
                d.push(2);
                d.extend_from_slice(&(degree as u64).to_le_bytes());
            }
        }
        d
    }

    /// The `Hello` handshake digest every link actually exchanges:
    /// `PMCFG3 = PMCFG2 ‖ workload-tag` ([`super::spec::Workload::Train`]).
    /// The tag byte keeps train and serve-infer workers from ever
    /// cross-connecting — a serve worker's `PMCFG3` ends in the serve
    /// tag, so the digests differ even when the cores agree.
    pub fn handshake_digest(&self) -> Vec<u8> {
        super::spec::handshake_wrap(
            &self.digest(),
            super::spec::Workload::Train,
        )
    }

    /// Replica `r`'s data-shard seed — the `ReplicaSet` convention, so
    /// grids and the in-process replica path draw identical shards.
    pub fn shard_seed(&self, replica: usize) -> u64 {
        self.worker.cfg.seed ^ ((replica as u64 + 1) * 0x9E37_79B9)
    }

    /// The runtime topology this spec trains on over `backend`.
    pub fn topology(&self, backend: TransportKind) -> Topology {
        Topology {
            replicas: self.replicas,
            stages: self.worker.h.stages,
            backend,
            reduce: self.reduce,
            chaos_kill: None,
            straggle: None,
        }
    }

    /// Assemble the legacy [`ElasticSpec`] from the nested options.
    pub fn elastic_spec(&self) -> Option<ElasticSpec> {
        let o = self.elastic.as_ref()?;
        Some(ElasticSpec {
            worker: self.worker.clone(),
            ckpt_every: if o.ckpt_every == 0 {
                (self.worker.steps as u64 / 4).max(1)
            } else {
                o.ckpt_every
            },
            ckpt_codec: o.ckpt_codec,
            heartbeat_every: o.heartbeat_every,
            stale_ms: o.stale_ms,
            spares: o.spares,
            chaos: o.chaos.clone(),
            faults: o.faults.clone(),
            max_epochs: o.max_epochs,
        })
    }
}

/// Builder for [`TrainSpec`] — every setter returns `self`; `build`
/// validates.
pub struct TrainSpecBuilder {
    spec: TrainSpec,
}

impl TrainSpecBuilder {
    fn new(h: Hyper) -> TrainSpecBuilder {
        let cfg = PipelineConfig {
            total_steps: 200,
            ..Default::default()
        };
        TrainSpecBuilder {
            spec: TrainSpec::from_worker(WorkerSpec {
                h,
                cfg,
                optim: Optim::AdamW,
                steps: 200,
                corpus_kind: CorpusKind::Wiki,
                corpus_tokens: 400_000,
            }),
        }
    }

    /// Boundary compression mode.
    pub fn mode(mut self, m: Mode) -> Self {
        self.spec.worker.cfg.mode = m;
        self
    }

    /// Optimizer steps (also sets the LR schedule horizon).
    pub fn steps(mut self, n: usize) -> Self {
        self.spec.worker.steps = n;
        self.spec.worker.cfg.total_steps = n;
        self
    }

    /// Microbatches per step.
    pub fn microbatches(mut self, m: usize) -> Self {
        self.spec.worker.cfg.microbatches = m;
        self
    }

    /// Run seed (init, data, gossip schedules).
    pub fn seed(mut self, s: u64) -> Self {
        self.spec.worker.cfg.seed = s;
        self
    }

    /// Peak learning rate.
    pub fn lr(mut self, lr: f32) -> Self {
        self.spec.worker.cfg.lr = lr;
        self
    }

    /// Warmup steps.
    pub fn warmup(mut self, n: usize) -> Self {
        self.spec.worker.cfg.warmup_steps = n;
        self
    }

    /// Grassmann cadence (0 disables).
    pub fn grassmann(mut self, interval: usize) -> Self {
        self.spec.worker.cfg.grassmann_interval = interval;
        self
    }

    /// Pipeline schedule.
    pub fn schedule(mut self, s: crate::sim::Schedule) -> Self {
        self.spec.worker.cfg.schedule = s;
        self
    }

    /// Synthetic corpus preset and length.
    pub fn corpus(mut self, kind: CorpusKind, tokens: usize) -> Self {
        self.spec.worker.corpus_kind = kind;
        self.spec.worker.corpus_tokens = tokens;
        self
    }

    /// Optimizer.
    pub fn optim(mut self, o: Optim) -> Self {
        self.spec.worker.optim = o;
        self
    }

    /// Data-parallel replica count.
    pub fn replicas(mut self, r: usize) -> Self {
        self.spec.replicas = r;
        self
    }

    /// Gradient-frame codec on the dp wire.
    pub fn dp_mode(mut self, m: Mode) -> Self {
        self.spec.dp_mode = m;
        self
    }

    /// Cross-replica reduce algorithm.
    pub fn reduce(mut self, r: Reduce) -> Self {
        self.spec.reduce = r;
        self
    }

    /// Nest elastic/chaos options.
    pub fn elastic(mut self, e: ElasticOpts) -> Self {
        self.spec.elastic = Some(e);
        self
    }

    /// Escape hatch for rarely-set worker fields (time model, event
    /// sim, grad recording) without widening the builder surface.
    pub fn tweak(mut self, f: impl FnOnce(&mut WorkerSpec)) -> Self {
        f(&mut self.spec.worker);
        self
    }

    /// Validate and return the spec.
    pub fn build(self) -> Result<TrainSpec> {
        self.spec.validate()?;
        Ok(self.spec)
    }
}

// ---------------------------------------------------------------------------
// Topology + launch — the single entry point
// ---------------------------------------------------------------------------

/// The runtime shape of a run: how many replicas × stages, which
/// transport carries the frames, and how gradients reduce. Derive one
/// from a spec with [`TrainSpec::topology`]; `launch` cross-checks the
/// two so a topology cannot silently disagree with the digested spec.
#[derive(Clone, Copy, Debug)]
pub struct Topology {
    /// data-parallel width R
    pub replicas: usize,
    /// pipeline depth P
    pub stages: usize,
    /// wire backend (channel / tcp)
    pub backend: TransportKind,
    /// cross-replica reduce algorithm
    pub reduce: Reduce,
    /// scripted chaos: kill every stage of one replica at a step
    /// (gossip grids only — runtime context, never digested)
    pub chaos_kill: Option<(usize, u64)>,
    /// straggler profile: one replica sleeps this many extra wall
    /// seconds per step before its gradient exchange (runtime context,
    /// never digested — `exp dp-real` uses it to contrast ring and
    /// gossip step wall under a slow member)
    pub straggle: Option<(usize, f64)>,
}

/// Aggregate result of a [`launch`]ed run.
#[derive(Clone, Debug)]
pub struct LaunchReport {
    /// per-step training loss, averaged over surviving replicas in
    /// replica order (R = 1: bitwise the chain's curve)
    pub losses: Vec<f64>,
    /// each replica's own per-step curve (a killed replica's is
    /// truncated at its death)
    pub replica_losses: Vec<Vec<f64>>,
    /// each replica's own per-step wall seconds (same truncation)
    pub replica_step_seconds: Vec<Vec<f64>>,
    /// per-step wall seconds — the max over surviving replicas
    pub step_seconds: Vec<f64>,
    /// boundary payload bytes across all chains
    pub boundary_payload_bytes: u64,
    /// gradient-frame payload bytes across the dp meshes
    pub dp_payload_bytes: u64,
    /// total wire bytes, headers and control included
    pub wire_bytes: u64,
    /// total frames sent
    pub frames: u64,
    /// replicas that finished every step
    pub survivors: usize,
    /// replicas launched
    pub replicas: usize,
    /// elastic detail when the run routed through the elastic runtime
    pub elastic: Option<Box<ElasticReport>>,
}

impl LaunchReport {
    /// Mean wall seconds per step.
    pub fn mean_step_seconds(&self) -> f64 {
        if self.step_seconds.is_empty() {
            return 0.0;
        }
        self.step_seconds.iter().sum::<f64>()
            / self.step_seconds.len() as f64
    }
}

/// Build one connected transport pair over `backend` (the two ends of a
/// dp mesh link; chains reuse `dist::chain_ends`).
fn link_pair(
    backend: TransportKind,
) -> Result<(Box<dyn Transport>, Box<dyn Transport>)> {
    Ok(match backend {
        TransportKind::Channel => {
            let (a, b) = channel_pair();
            (Box::new(a), Box::new(b))
        }
        TransportKind::Tcp => {
            let listener = std::net::TcpListener::bind("127.0.0.1:0")
                .context("binding loopback listener for a dp link")?;
            let addr = listener.local_addr()?;
            let client = std::net::TcpStream::connect(addr)
                .with_context(|| format!("connecting loopback {addr}"))?;
            let (server, _) = listener
                .accept()
                .context("accepting loopback dp connection")?;
            (
                Box::new(TcpTransport::new(client)?),
                Box::new(TcpTransport::new(server)?),
            )
        }
    })
}

/// Launch a run on a topology: the **single entry point** every driver
/// routes through. `R = 1` without elastic options is exactly the
/// legacy `run_local` chain; elastic options route to the elastic
/// runtime; `R ≥ 2` builds the full R×P grid — R chains plus a
/// per-stage replica mesh — and composes stage pipelining with replica
/// reduction.
pub fn launch(topo: &Topology, spec: &TrainSpec) -> Result<LaunchReport> {
    spec.validate()?;
    if topo.replicas != spec.replicas
        || topo.stages != spec.worker.h.stages
    {
        bail!(
            "topology {}x{} disagrees with the spec's {}x{} grid — \
             derive the topology with TrainSpec::topology",
            topo.replicas,
            topo.stages,
            spec.replicas,
            spec.worker.h.stages
        );
    }
    if topo.reduce != spec.reduce {
        bail!(
            "topology reduce {} disagrees with the spec's {}",
            topo.reduce.label(),
            spec.reduce.label()
        );
    }
    if let Some((r, _)) = topo.chaos_kill {
        if r >= spec.replicas {
            bail!("chaos kill targets replica {r} of {}", spec.replicas);
        }
        if !matches!(spec.reduce, Reduce::Gossip { .. }) {
            bail!(
                "scripted replica kills need --reduce gossip (a ring \
                 cannot survive a missing member); elastic chains \
                 handle kills through ElasticOpts::chaos"
            );
        }
    }
    if let Some((r, s)) = topo.straggle {
        if r >= spec.replicas {
            bail!("straggler targets replica {r} of {}", spec.replicas);
        }
        if !(s.is_finite() && s >= 0.0) {
            bail!("straggler delay must be finite and non-negative");
        }
    }
    if spec.elastic.is_some() {
        let es = spec.elastic_spec().expect("elastic options present");
        let er = run_elastic_impl(&es, topo.backend)?;
        return Ok(LaunchReport {
            losses: er.losses.clone(),
            replica_losses: vec![er.losses.clone()],
            replica_step_seconds: vec![er.dist.step_seconds.clone()],
            step_seconds: er.dist.step_seconds.clone(),
            boundary_payload_bytes: er.dist.boundary_payload_bytes,
            dp_payload_bytes: 0,
            wire_bytes: er.dist.wire_bytes,
            frames: er.dist.frames,
            survivors: 1,
            replicas: 1,
            elastic: Some(Box::new(er)),
        });
    }
    run_grid(spec, topo)
}

/// The R×P grid runner behind [`launch`].
fn run_grid(spec: &TrainSpec, topo: &Topology) -> Result<LaunchReport> {
    let r_count = spec.replicas;
    let p = spec.worker.h.stages;
    let backend = topo.backend;
    let digest = spec.handshake_digest();
    let mut chains: Vec<Vec<(LinkEnd, LinkEnd)>> = (0..r_count)
        .map(|_| chain_ends(p, backend))
        .collect::<Result<_>>()?;
    // dp mesh: one bidirectional link per stage per replica pair
    let mut mesh: Vec<Vec<Vec<LinkEnd>>> = (0..r_count)
        .map(|_| {
            (0..p).map(|_| (0..r_count).map(|_| None).collect()).collect()
        })
        .collect();
    if r_count > 1 {
        for s in 0..p {
            for a in 0..r_count {
                for b in a + 1..r_count {
                    let (ea, eb) = link_pair(backend)?;
                    mesh[a][s][b] = Some(ea);
                    mesh[b][s][a] = Some(eb);
                }
            }
        }
    }

    let reports: Vec<Vec<Result<WorkerReport>>> =
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(r_count);
            for (r, chain) in chains.drain(..).enumerate() {
                let mut rows = Vec::with_capacity(p);
                for (s, (left, right)) in chain.into_iter().enumerate() {
                    let links = std::mem::take(&mut mesh[r][s]);
                    let wspec = spec.worker.clone();
                    let dp = (r_count > 1).then(|| DpCtx {
                        replica: r,
                        replicas: r_count,
                        reduce: spec.reduce,
                        dp_mode: spec.dp_mode,
                        seed: spec.worker.cfg.seed,
                        shard_seed: spec.shard_seed(r),
                        digest: digest.clone(),
                        kill_at: topo
                            .chaos_kill
                            .and_then(|(kr, ks)| (kr == r).then_some(ks)),
                        straggle_s: topo
                            .straggle
                            .and_then(|(sr, s)| (sr == r).then_some(s))
                            .unwrap_or(0.0),
                        links,
                        dead: vec![false; r_count],
                        dp_payload_bytes: 0,
                        dp_frames: 0,
                    });
                    rows.push(scope.spawn(move || {
                        run_stage_inner(&wspec, s, left, right, None, None, dp)
                    }));
                }
                handles.push(rows);
            }
            handles
                .into_iter()
                .map(|rows| {
                    rows.into_iter()
                        .map(|h| {
                            h.join().unwrap_or_else(|_| {
                                Err(anyhow::anyhow!(
                                    "stage worker panicked"
                                ))
                            })
                        })
                        .collect()
                })
                .collect()
        });

    let tolerate_kills = matches!(spec.reduce, Reduce::Gossip { .. });
    let mut replica_losses: Vec<Vec<f64>> = vec![Vec::new(); r_count];
    let mut replica_secs: Vec<Vec<f64>> = vec![Vec::new(); r_count];
    let mut boundary = 0u64;
    let mut dp_payload = 0u64;
    let mut wire = 0u64;
    let mut frames = 0u64;
    let mut survivors = 0usize;
    for (r, rows) in reports.into_iter().enumerate() {
        let mut alive = true;
        for (s, res) in rows.into_iter().enumerate() {
            match res {
                Ok(w) => {
                    boundary += w.boundary_payload_bytes;
                    dp_payload += w.dp_payload_bytes;
                    wire += w.wire_bytes;
                    frames += w.frames_sent;
                    if s == 0 {
                        replica_losses[r] = w.losses;
                        replica_secs[r] = w.step_seconds;
                    }
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    if tolerate_kills && msg.contains("chaos kill") {
                        alive = false;
                    } else {
                        return Err(e.context(format!(
                            "replica {r} stage {s} worker failed"
                        )));
                    }
                }
            }
        }
        if alive {
            survivors += 1;
        }
    }
    if survivors == 0 {
        bail!("every replica died — nothing survived to report");
    }

    let (losses, step_seconds) = if r_count == 1 {
        (replica_losses[0].clone(), replica_secs[0].clone())
    } else {
        let steps = replica_losses.iter().map(Vec::len).max().unwrap_or(0);
        let mut losses = Vec::with_capacity(steps);
        let mut secs = Vec::with_capacity(steps);
        for i in 0..steps {
            let vals: Vec<f64> = replica_losses
                .iter()
                .filter(|l| i < l.len())
                .map(|l| l[i])
                .collect();
            losses.push(vals.iter().sum::<f64>() / vals.len() as f64);
            secs.push(
                replica_secs
                    .iter()
                    .filter(|l| i < l.len())
                    .map(|l| l[i])
                    .fold(0.0f64, f64::max),
            );
        }
        (losses, secs)
    };
    Ok(LaunchReport {
        losses,
        replica_losses,
        replica_step_seconds: replica_secs,
        step_seconds,
        boundary_payload_bytes: boundary,
        dp_payload_bytes: dp_payload,
        wire_bytes: wire,
        frames,
        survivors,
        replicas: r_count,
        elastic: None,
    })
}

// ---------------------------------------------------------------------------
// in-process reference — the single-process replica path
// ---------------------------------------------------------------------------

/// Train `spec` entirely in process: R [`NativePipeline`]s stepping in
/// lockstep, with the per-stage gradient reduce performed by the exact
/// codec arithmetic the wire uses ([`ring_allreduce_local`], or the
/// gossip pairing with self-codec averaging). This is the path a ring
/// grid must match **bitwise** (f64 loss bits) and a kill-free gossip
/// grid matches too — the R×P generalization of the chain parity
/// contract.
pub fn reference_dp_losses(spec: &TrainSpec) -> Result<Vec<f64>> {
    spec.validate()?;
    if spec.elastic.is_some() {
        bail!("the in-process reference has no elastic runtime");
    }
    let r_count = spec.replicas;
    let w = &spec.worker;
    let h = &w.h;
    let mut pipes = (0..r_count)
        .map(|r| {
            let mut trng = Rng::new(w.cfg.seed);
            let topo = crate::netsim::Topology::uniform(
                h.stages,
                crate::netsim::LinkSpec::internet_80m(),
                &mut trng,
            );
            let mut pipe = NativePipeline::new(
                h.clone(),
                topo,
                w.cfg.clone(),
                w.optim,
            )?;
            if r_count > 1 {
                pipe.reseed_data(spec.shard_seed(r));
            }
            Ok(pipe)
        })
        .collect::<Result<Vec<_>>>()?;
    let corpus = w.corpus();
    let m = w.cfg.microbatches as f64;
    let mut losses = Vec::with_capacity(w.steps);
    for step in 0..w.steps as u64 {
        let mut pendings = pipes
            .iter_mut()
            .map(|pipe| {
                pipe.forward_backward(|rng| {
                    corpus.train_batch(h.b, h.n, rng)
                })
            })
            .collect::<Result<Vec<_>>>()?;
        if r_count > 1 {
            let stages = pendings[0].grad_acc.len();
            match spec.reduce {
                Reduce::None => unreachable!("validate rejects"),
                Reduce::Ring => {
                    for s in 0..stages {
                        let mut flats: Vec<Vec<f32>> = pendings
                            .iter()
                            .map(|pd| flatten(&pd.grad_acc[s]))
                            .collect();
                        ring_allreduce_local(
                            &mut flats, spec.dp_mode, h.d, h.k, h.ratio,
                        )?;
                        for (pd, fl) in pendings.iter_mut().zip(&flats) {
                            unflatten(fl, &mut pd.grad_acc[s]);
                        }
                    }
                }
                Reduce::Gossip { .. } => {
                    for (a, b) in
                        gossip_pairs(w.cfg.seed, step, r_count)
                    {
                        for s in 0..stages {
                            let fa = flatten(&pendings[a].grad_acc[s]);
                            let fb = flatten(&pendings[b].grad_acc[s]);
                            let ea = encode_grad(
                                spec.dp_mode, &fa, h.d, h.k, h.ratio,
                            )?;
                            let eb = encode_grad(
                                spec.dp_mode, &fb, h.d, h.k, h.ratio,
                            )?;
                            let da = decode_grad(
                                spec.dp_mode, &ea, fa.len(), h.d, h.k,
                                h.ratio,
                            )?;
                            let db = decode_grad(
                                spec.dp_mode, &eb, fb.len(), h.d, h.k,
                                h.ratio,
                            )?;
                            let avg: Vec<f32> = da
                                .iter()
                                .zip(&db)
                                .map(|(x, y)| 0.5 * (*x + *y))
                                .collect();
                            unflatten(&avg, &mut pendings[a].grad_acc[s]);
                            unflatten(&avg, &mut pendings[b].grad_acc[s]);
                        }
                    }
                }
            }
        }
        let step_losses: Vec<f64> =
            pendings.iter().map(|pd| pd.loss_sum / m).collect();
        for (pipe, pd) in pipes.iter_mut().zip(pendings) {
            pipe.apply_update(pd)?;
        }
        if r_count == 1 {
            losses.push(step_losses[0]);
        } else {
            losses.push(
                step_losses.iter().sum::<f64>() / r_count as f64,
            );
        }
    }
    Ok(losses)
}

/// Concatenate a stage's gradient tensors into one flat vector.
pub fn flatten(grads: &[Tensor]) -> Vec<f32> {
    let total: usize = grads.iter().map(Tensor::numel).sum();
    let mut out = Vec::with_capacity(total);
    for g in grads {
        out.extend_from_slice(&g.data);
    }
    out
}

/// Scatter a flat vector back over a stage's gradient tensors.
pub fn unflatten(flat: &[f32], grads: &mut [Tensor]) {
    let mut off = 0;
    for g in grads.iter_mut() {
        let n = g.numel();
        g.data.copy_from_slice(&flat[off..off + n]);
        off += n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dp_modes() -> Vec<Mode> {
        vec![
            Mode::Raw,
            Mode::RawBf16,
            Mode::Quant,
            Mode::TopK,
            Mode::Subspace,
            Mode::SubspaceBf16,
        ]
    }

    fn noisy(seed: u64, n: usize) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        rng.normal_f32_vec(n, 1.0)
    }

    #[test]
    fn chunk_ranges_are_balanced_and_cover() {
        for (elems, r) in [(1200, 3), (1201, 2), (7, 7), (10, 3)] {
            let ranges = chunk_ranges(elems, r);
            assert_eq!(ranges.len(), r);
            assert_eq!(ranges[0].0, 0);
            assert_eq!(ranges[r - 1].1, elems);
            for w in ranges.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
            let (min, max) = ranges.iter().fold(
                (usize::MAX, 0),
                |(mn, mx), &(a, b)| (mn.min(b - a), mx.max(b - a)),
            );
            assert!(max - min <= 1, "unbalanced: {ranges:?}");
        }
    }

    #[test]
    fn grad_codecs_price_exactly_and_roundtrip() {
        let (d, k, ratio) = (32, 4, 4.0);
        for mode in dp_modes() {
            for n in [13usize, 64, 257] {
                let xs = noisy(mode.wire_tag() as u64 + n as u64, n);
                let enc = encode_grad(mode, &xs, d, k, ratio).unwrap();
                assert_eq!(
                    enc.len(),
                    dp_wire_bytes(mode, n, d, k, ratio),
                    "{mode:?} n={n}"
                );
                let dec =
                    decode_grad(mode, &enc, n, d, k, ratio).unwrap();
                assert_eq!(dec.len(), n);
                if mode == Mode::Raw {
                    assert_eq!(dec, xs, "raw must be lossless");
                }
                // every dp codec is idempotent: re-encoding the decode
                // reproduces values (the all-gather consensus property)
                let enc2 = encode_grad(mode, &dec, d, k, ratio).unwrap();
                let dec2 =
                    decode_grad(mode, &enc2, n, d, k, ratio).unwrap();
                for (a, b) in dec.iter().zip(&dec2) {
                    assert!(
                        (a - b).abs() <= 1e-6 * a.abs().max(1.0),
                        "{mode:?} not stable: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn powerlr_grad_frames_are_rejected() {
        let xs = noisy(1, 16);
        let err = encode_grad(Mode::PowerLR, &xs, 32, 4, 4.0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("powerlr"), "{err}");
    }

    #[test]
    fn ring_local_matches_plain_mean_for_raw() {
        let n = 101;
        let r = 3;
        let mut flats: Vec<Vec<f32>> =
            (0..r).map(|i| noisy(40 + i as u64, n)).collect();
        let mean: Vec<f32> = (0..n)
            .map(|j| {
                flats.iter().map(|f| f[j]).sum::<f32>() / r as f32
            })
            .collect();
        ring_allreduce_local(&mut flats, Mode::Raw, 32, 4, 4.0).unwrap();
        for f in &flats {
            for (a, b) in f.iter().zip(&mean) {
                assert!((a - b).abs() < 1e-5, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn ring_local_leaves_replicas_in_consensus_for_lossy_codecs() {
        for mode in dp_modes() {
            for r in [2usize, 3, 4] {
                let n = 97;
                let mut flats: Vec<Vec<f32>> = (0..r)
                    .map(|i| noisy(7 * (i as u64 + 1), n))
                    .collect();
                ring_allreduce_local(&mut flats, mode, 32, 4, 4.0)
                    .unwrap();
                for f in &flats[1..] {
                    assert_eq!(
                        flats[0], *f,
                        "{mode:?} R={r}: replicas diverged after ring"
                    );
                }
            }
        }
    }

    #[test]
    fn gossip_pairs_are_deterministic_symmetric_and_disjoint() {
        for r in [2usize, 3, 4, 5, 8] {
            for step in 0..20u64 {
                let pairs = gossip_pairs(17, step, r);
                assert_eq!(pairs, gossip_pairs(17, step, r));
                let mut seen = std::collections::HashSet::new();
                for &(a, b) in &pairs {
                    assert_ne!(a, b);
                    assert!(seen.insert(a) && seen.insert(b));
                    assert_eq!(
                        gossip_partner(17, step, r, a),
                        Some(b)
                    );
                    assert_eq!(
                        gossip_partner(17, step, r, b),
                        Some(a)
                    );
                }
                assert_eq!(pairs.len(), r / 2);
            }
            // different steps shuffle differently (almost surely)
            let all: std::collections::HashSet<_> =
                (0..20u64).map(|s| gossip_pairs(17, s, r)).collect();
            if r > 2 {
                assert!(all.len() > 1, "schedule never varied at R={r}");
            }
        }
    }

    #[test]
    fn reduce_parse_roundtrips() {
        for r in [
            Reduce::None,
            Reduce::Ring,
            Reduce::Gossip { degree: 1 },
            Reduce::Gossip { degree: 3 },
        ] {
            assert_eq!(Reduce::parse(&r.label()).unwrap(), r);
        }
        assert!(Reduce::parse("tree").is_err());
    }

    fn tiny_spec() -> TrainSpec {
        TrainSpec::builder(Hyper::tiny_native())
            .steps(2)
            .microbatches(2)
            .seed(5)
            .lr(1e-2)
            .warmup(3)
            .grassmann(0)
            .corpus(CorpusKind::Wiki, 20_000)
            .build()
            .unwrap()
    }

    #[test]
    fn trainspec_validate_gives_descriptive_errors() {
        let base = tiny_spec();
        let mut s = base.clone();
        s.replicas = 2;
        let err = s.validate().unwrap_err().to_string();
        assert!(err.contains("--reduce"), "{err}");
        s.reduce = Reduce::Ring;
        s.validate().unwrap();
        s.dp_mode = Mode::PowerLR;
        let err = s.validate().unwrap_err().to_string();
        assert!(err.contains("powerlr"), "{err}");
        s.dp_mode = Mode::Raw;
        s.worker.cfg.grassmann_interval = 10;
        let err = s.validate().unwrap_err().to_string();
        assert!(err.contains("Grassmann"), "{err}");
        s.worker.cfg.grassmann_interval = 0;
        s.reduce = Reduce::Gossip { degree: 2 };
        let err = s.validate().unwrap_err().to_string();
        assert!(err.contains("degree"), "{err}");
        s.reduce = Reduce::Gossip { degree: 1 };
        s.elastic = Some(ElasticOpts::default());
        let err = s.validate().unwrap_err().to_string();
        assert!(err.contains("elastic"), "{err}");
    }

    #[test]
    fn trainspec_digest_covers_the_dp_axis() {
        let a = tiny_spec();
        assert!(a.digest().starts_with(b"PMCFG2"));
        let mut b = a.clone();
        b.replicas = 2;
        b.reduce = Reduce::Ring;
        assert_ne!(a.digest(), b.digest());
        let mut c = b.clone();
        c.dp_mode = Mode::Quant;
        assert_ne!(b.digest(), c.digest());
        let mut d = b.clone();
        d.reduce = Reduce::Gossip { degree: 1 };
        assert_ne!(b.digest(), d.digest());
        // the worker digest is nested verbatim
        let mut e = a.clone();
        e.worker.cfg.seed ^= 1;
        assert_ne!(a.digest(), e.digest());
    }

    #[test]
    fn topology_mismatch_is_rejected() {
        let spec = tiny_spec();
        let mut topo = spec.topology(TransportKind::Channel);
        topo.replicas = 3;
        let err = launch(&topo, &spec).unwrap_err().to_string();
        assert!(err.contains("disagrees"), "{err}");
        let mut topo = spec.topology(TransportKind::Channel);
        topo.reduce = Reduce::Ring;
        let err = launch(&topo, &spec).unwrap_err().to_string();
        assert!(err.contains("reduce"), "{err}");
        let mut topo = spec.topology(TransportKind::Channel);
        topo.chaos_kill = Some((0, 1));
        let err = launch(&topo, &spec).unwrap_err().to_string();
        assert!(err.contains("gossip"), "{err}");
    }

    #[test]
    fn r2_ring_grid_matches_the_reference_bitwise() {
        let mut spec = tiny_spec();
        spec.replicas = 2;
        spec.reduce = Reduce::Ring;
        spec.dp_mode = Mode::Quant; // lossy: parity must still be exact
        let topo = spec.topology(TransportKind::Channel);
        let grid = launch(&topo, &spec).unwrap();
        let reference = reference_dp_losses(&spec).unwrap();
        assert_eq!(grid.losses.len(), reference.len());
        for (a, b) in grid.losses.iter().zip(&reference) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
        assert_eq!(grid.survivors, 2);
        assert!(grid.dp_payload_bytes > 0);
    }
}
