//! Micro-benchmark harness (the offline vendor set has no criterion).
//!
//! Criterion-style protocol: warmup, then timed iterations until both a
//! minimum iteration count and a minimum measuring window are reached;
//! reports mean / median / p95 and throughput. Benches link this via the
//! library crate and run with `harness = false`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::Result;

use crate::json::Json;

/// Timing summary of one benchmark.
pub struct BenchResult {
    /// benchmark label
    pub name: String,
    /// measured iterations
    pub iters: usize,
    /// mean nanoseconds per iteration
    pub mean_ns: f64,
    /// median nanoseconds per iteration
    pub median_ns: f64,
    /// 95th-percentile nanoseconds per iteration
    pub p95_ns: f64,
    /// sample standard deviation, nanoseconds
    pub stddev_ns: f64,
}

impl BenchResult {
    /// Print the standard one-line report.
    pub fn report(&self) {
        println!(
            "{:<48} {:>12} {:>12} {:>12}   ({} iters, σ {})",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p95_ns),
            self.iters,
            fmt_ns(self.stddev_ns),
        );
    }

    /// items/second at the measured mean.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns * 1e-9)
    }

    /// JSON object of this result: name, iteration count, mean / median /
    /// p95 / stddev in ns, plus `throughput_per_s` when `items_per_iter`
    /// is given. Consumed by `bench --json` (BENCH_*.json trajectory
    /// files at the repo root).
    pub fn to_json(&self, items_per_iter: Option<f64>) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("iters".to_string(), Json::Num(self.iters as f64));
        m.insert("mean_ns".to_string(), Json::Num(self.mean_ns));
        m.insert("median_ns".to_string(), Json::Num(self.median_ns));
        m.insert("p95_ns".to_string(), Json::Num(self.p95_ns));
        m.insert("stddev_ns".to_string(), Json::Num(self.stddev_ns));
        if let Some(items) = items_per_iter {
            m.insert(
                "throughput_per_s".to_string(),
                Json::Num(self.throughput(items)),
            );
        }
        Json::Obj(m)
    }
}

/// One entry of a JSON bench suite: the measurement plus an optional
/// items-per-iteration figure for throughput reporting.
pub struct BenchEntry {
    /// the measured result
    pub result: BenchResult,
    /// items processed per iteration (tokens, FLOPs, cells, …)
    pub items_per_iter: Option<f64>,
}

/// Write a bench suite as `{"suite": name, "results": [...]}` to `path`
/// (pretty enough for diffing: one compact JSON document). Returns the
/// written path.
pub fn write_json(
    path: impl AsRef<Path>,
    suite: &str,
    entries: &[BenchEntry],
) -> Result<PathBuf> {
    let mut m = BTreeMap::new();
    m.insert("suite".to_string(), Json::Str(suite.to_string()));
    m.insert(
        "results".to_string(),
        Json::Arr(
            entries
                .iter()
                .map(|e| e.result.to_json(e.items_per_iter))
                .collect(),
        ),
    );
    let path = path.as_ref().to_path_buf();
    let mut text = Json::Obj(m).to_string();
    text.push('\n');
    std::fs::write(&path, text)?;
    eprintln!(
        "[bench] wrote {} results -> {}",
        entries.len(),
        path.display()
    );
    Ok(path)
}

/// Human-readable duration from nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Criterion-style measurement protocol: warmup, then timed iterations.
pub struct Bencher {
    /// minimum wall-clock seconds of measurement per bench
    pub min_time: f64,
    /// minimum timed iterations
    pub min_iters: usize,
    /// hard iteration cap
    pub max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { min_time: 1.0, min_iters: 10, max_iters: 100_000 }
    }
}

impl Bencher {
    /// Short-window protocol for expensive benchmarks.
    pub fn quick() -> Self {
        Bencher { min_time: 0.3, min_iters: 5, max_iters: 10_000 }
    }

    /// Measure `f`, print the report, and return the summary.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // warmup
        let warm_until = Instant::now();
        let mut warm = 0;
        while warm < 3 || warm_until.elapsed().as_secs_f64() < self.min_time * 0.2
        {
            f();
            warm += 1;
            if warm >= self.max_iters {
                break;
            }
        }
        // measure
        let mut samples: Vec<f64> = Vec::new();
        let t0 = Instant::now();
        while (samples.len() < self.min_iters
            || t0.elapsed().as_secs_f64() < self.min_time)
            && samples.len() < self.max_iters
        {
            let s = Instant::now();
            f();
            samples.push(s.elapsed().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>()
            / n as f64;
        let r = BenchResult {
            name: name.to_string(),
            iters: n,
            mean_ns: mean,
            median_ns: samples[n / 2],
            p95_ns: samples[(n * 95 / 100).min(n - 1)],
            stddev_ns: var.sqrt(),
        };
        r.report();
        r
    }
}

/// Prevent the optimizer from eliding a value (std::hint::black_box shim).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bencher { min_time: 0.01, min_iters: 3, max_iters: 100 };
        let mut acc = 0u64;
        let r = b.run("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.iters >= 3);
        assert!(r.mean_ns > 0.0);
        assert!(r.median_ns <= r.p95_ns);
    }

    #[test]
    fn json_roundtrip_of_results() {
        let r = BenchResult {
            name: "m".into(),
            iters: 4,
            mean_ns: 1000.0,
            median_ns: 900.0,
            p95_ns: 1500.0,
            stddev_ns: 50.0,
        };
        let j = r.to_json(Some(2000.0));
        assert_eq!(j.get("name").unwrap().str().unwrap(), "m");
        assert_eq!(j.get("iters").unwrap().usize().unwrap(), 4);
        // 2000 items / 1µs mean = 2e12 items/s
        let tput = j.get("throughput_per_s").unwrap().num().unwrap();
        assert!((tput - 2e12).abs() / 2e12 < 1e-9);
        let dir = std::env::temp_dir().join("protomodels_test_bench_json");
        std::fs::create_dir_all(&dir).unwrap();
        let p = write_json(
            dir.join("BENCH_test.json"),
            "test",
            &[BenchEntry { result: r, items_per_iter: None }],
        )
        .unwrap();
        let parsed =
            crate::json::Json::parse(&std::fs::read_to_string(p).unwrap())
                .unwrap();
        assert_eq!(parsed.get("suite").unwrap().str().unwrap(), "test");
        assert_eq!(parsed.get("results").unwrap().arr().unwrap().len(), 1);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2e9).ends_with(" s"));
    }
}
