//! Micro-benchmark harness (the offline vendor set has no criterion).
//!
//! Criterion-style protocol: warmup, then timed iterations until both a
//! minimum iteration count and a minimum measuring window are reached;
//! reports mean / median / p95 and throughput. Benches link this via the
//! library crate and run with `harness = false`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::Result;

use crate::json::Json;

/// Timing summary of one benchmark.
pub struct BenchResult {
    /// benchmark label
    pub name: String,
    /// measured iterations
    pub iters: usize,
    /// mean nanoseconds per iteration
    pub mean_ns: f64,
    /// median nanoseconds per iteration
    pub median_ns: f64,
    /// 95th-percentile nanoseconds per iteration
    pub p95_ns: f64,
    /// sample standard deviation, nanoseconds
    pub stddev_ns: f64,
}

impl BenchResult {
    /// Print the standard one-line report.
    pub fn report(&self) {
        println!(
            "{:<48} {:>12} {:>12} {:>12}   ({} iters, σ {})",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p95_ns),
            self.iters,
            fmt_ns(self.stddev_ns),
        );
    }

    /// items/second at the measured mean.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns * 1e-9)
    }

    /// JSON object of this result: name, iteration count, mean / median /
    /// p95 / stddev in ns, plus `throughput_per_s` when `items_per_iter`
    /// is given. Consumed by `bench --json` (BENCH_*.json trajectory
    /// files at the repo root).
    pub fn to_json(&self, items_per_iter: Option<f64>) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("iters".to_string(), Json::Num(self.iters as f64));
        m.insert("mean_ns".to_string(), Json::Num(self.mean_ns));
        m.insert("median_ns".to_string(), Json::Num(self.median_ns));
        m.insert("p95_ns".to_string(), Json::Num(self.p95_ns));
        m.insert("stddev_ns".to_string(), Json::Num(self.stddev_ns));
        if let Some(items) = items_per_iter {
            m.insert(
                "throughput_per_s".to_string(),
                Json::Num(self.throughput(items)),
            );
        }
        Json::Obj(m)
    }
}

/// One entry of a JSON bench suite: the measurement plus an optional
/// items-per-iteration figure for throughput reporting.
pub struct BenchEntry {
    /// the measured result
    pub result: BenchResult,
    /// items processed per iteration (tokens, FLOPs, cells, …)
    pub items_per_iter: Option<f64>,
}

/// Write a bench suite as `{"suite": name, "results": [...]}` to `path`
/// (pretty enough for diffing: one compact JSON document). Returns the
/// written path.
pub fn write_json(
    path: impl AsRef<Path>,
    suite: &str,
    entries: &[BenchEntry],
) -> Result<PathBuf> {
    let mut m = BTreeMap::new();
    m.insert("suite".to_string(), Json::Str(suite.to_string()));
    m.insert(
        "results".to_string(),
        Json::Arr(
            entries
                .iter()
                .map(|e| e.result.to_json(e.items_per_iter))
                .collect(),
        ),
    );
    let path = path.as_ref().to_path_buf();
    let mut text = Json::Obj(m).to_string();
    text.push('\n');
    std::fs::write(&path, text)?;
    eprintln!(
        "[bench] wrote {} results -> {}",
        entries.len(),
        path.display()
    );
    Ok(path)
}

// ---------------------------------------------------------------------------
// regression gate (`protomodels bench --check <dir>`)
// ---------------------------------------------------------------------------

/// Outcome of one baseline comparison.
pub struct RegressionCheck {
    /// entries compared against a baseline value
    pub checked: usize,
    /// current entries with no baseline (new or machine-dependent names)
    pub skipped: usize,
    /// human-readable description of every entry that regressed
    pub failures: Vec<String>,
}

/// `name → mean_ns` of one `{"suite": .., "results": [..]}` file.
pub fn load_suite_means(path: &Path) -> Result<BTreeMap<String, f64>> {
    let text = std::fs::read_to_string(path).map_err(|e| {
        anyhow::anyhow!("cannot read bench suite {}: {e}", path.display())
    })?;
    let json = Json::parse(&text)?;
    let mut means = BTreeMap::new();
    for entry in json.get("results")?.arr()? {
        means.insert(
            entry.get("name")?.str()?.to_string(),
            entry.get("mean_ns")?.num()?,
        );
    }
    Ok(means)
}

/// Compare the `BENCH_{linalg,pipeline,nn,transport}.json` under
/// `current_dir` (written by `bench --json`) against the matching
/// `{suite}.json` under `baseline_dir` (the committed `BENCH_baseline/`). An entry fails
/// when its mean wall time grew beyond `max_regress` (0.25 = +25%)
/// over the baseline; entries without a baseline (new benches,
/// machine-dependent names like `..._threadsN`) are skipped with a
/// note. The committed baselines are deliberately generous ceilings —
/// CI runners vary — so the gate catches order-of-magnitude
/// regressions, not noise (DESIGN.md §8).
pub fn check_regressions(
    current_dir: &Path,
    baseline_dir: &Path,
    max_regress: f64,
) -> Result<RegressionCheck> {
    let pairs = [
        ("BENCH_linalg.json", "linalg.json"),
        ("BENCH_pipeline.json", "pipeline.json"),
        ("BENCH_nn.json", "nn.json"),
        ("BENCH_transport.json", "transport.json"),
        ("BENCH_serve.json", "serve.json"),
    ];
    let mut report =
        RegressionCheck { checked: 0, skipped: 0, failures: Vec::new() };
    for (current_name, baseline_name) in pairs {
        let current = load_suite_means(&current_dir.join(current_name))?;
        let baseline = load_suite_means(&baseline_dir.join(baseline_name))?;
        // a baseline entry with no current measurement means the gate
        // lost coverage (renamed/deleted bench) — fail loudly so the
        // baseline gets updated deliberately, not silently ignored
        for name in baseline.keys() {
            if !current.contains_key(name) {
                report.failures.push(format!(
                    "{name}: baseline entry missing from the current \
                     {current_name} run (renamed bench? update \
                     BENCH_baseline deliberately)"
                ));
            }
        }
        for (name, mean_ns) in &current {
            let base_ns = match baseline.get(name) {
                Some(b) => *b,
                None => {
                    eprintln!("[bench check] no baseline for {name}, skipping");
                    report.skipped += 1;
                    continue;
                }
            };
            let ratio = mean_ns / base_ns.max(1e-9);
            let verdict = if ratio > 1.0 + max_regress { "FAIL" } else { "ok" };
            println!(
                "[bench check] {name:<44} {:>12} vs baseline {:>12}  \
                 ({ratio:>5.2}x) {verdict}",
                fmt_ns(*mean_ns),
                fmt_ns(base_ns),
            );
            report.checked += 1;
            if ratio > 1.0 + max_regress {
                report.failures.push(format!(
                    "{name}: {} vs baseline {} ({ratio:.2}x > {:.2}x)",
                    fmt_ns(*mean_ns),
                    fmt_ns(base_ns),
                    1.0 + max_regress
                ));
            }
        }
    }
    Ok(report)
}

/// One row of a `bench --compare` speedup table.
pub struct CompareRow {
    /// entry name
    pub name: String,
    /// mean ns in the old suite (`None` = entry only in the new run)
    pub old_ns: Option<f64>,
    /// mean ns in the new suite (`None` = entry only in the old run)
    pub new_ns: Option<f64>,
}

impl CompareRow {
    /// old/new — >1 means the new run is faster.
    pub fn speedup(&self) -> Option<f64> {
        match (self.old_ns, self.new_ns) {
            (Some(o), Some(n)) => Some(o / n.max(1e-9)),
            _ => None,
        }
    }
}

/// Compare two bench-suite JSON files (`bench --compare old new`): the
/// union of entry names with the per-entry speedup `old/new`. Entries
/// present on only one side are kept with a `None` slot so renames and
/// new benches show up instead of vanishing from the report.
pub fn compare_suites(old: &Path, new: &Path) -> Result<Vec<CompareRow>> {
    let old_means = load_suite_means(old)?;
    let new_means = load_suite_means(new)?;
    let mut names: Vec<&String> =
        old_means.keys().chain(new_means.keys()).collect();
    names.sort();
    names.dedup();
    Ok(names
        .into_iter()
        .map(|name| CompareRow {
            name: name.clone(),
            old_ns: old_means.get(name).copied(),
            new_ns: new_means.get(name).copied(),
        })
        .collect())
}

/// Print a `bench --compare` table and return the best speedup seen.
pub fn print_comparison(rows: &[CompareRow]) -> f64 {
    println!(
        "{:<48} {:>12} {:>12} {:>9}",
        "entry", "old", "new", "speedup"
    );
    let mut best = 0.0f64;
    for row in rows {
        let fmt_side = |ns: Option<f64>| match ns {
            Some(ns) => fmt_ns(ns),
            None => "-".to_string(),
        };
        let speed = match row.speedup() {
            Some(s) => {
                best = best.max(s);
                format!("{s:.2}x")
            }
            None => "-".to_string(),
        };
        println!(
            "{:<48} {:>12} {:>12} {:>9}",
            row.name,
            fmt_side(row.old_ns),
            fmt_side(row.new_ns),
            speed,
        );
    }
    best
}

/// Human-readable duration from nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Criterion-style measurement protocol: warmup, then timed iterations.
pub struct Bencher {
    /// minimum wall-clock seconds of measurement per bench
    pub min_time: f64,
    /// minimum timed iterations
    pub min_iters: usize,
    /// hard iteration cap
    pub max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { min_time: 1.0, min_iters: 10, max_iters: 100_000 }
    }
}

impl Bencher {
    /// Short-window protocol for expensive benchmarks.
    pub fn quick() -> Self {
        Bencher { min_time: 0.3, min_iters: 5, max_iters: 10_000 }
    }

    /// Measure `f`, print the report, and return the summary.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // warmup
        let warm_until = Instant::now();
        let mut warm = 0;
        while warm < 3 || warm_until.elapsed().as_secs_f64() < self.min_time * 0.2
        {
            f();
            warm += 1;
            if warm >= self.max_iters {
                break;
            }
        }
        // measure
        let mut samples: Vec<f64> = Vec::new();
        let t0 = Instant::now();
        while (samples.len() < self.min_iters
            || t0.elapsed().as_secs_f64() < self.min_time)
            && samples.len() < self.max_iters
        {
            let s = Instant::now();
            f();
            samples.push(s.elapsed().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>()
            / n as f64;
        let r = BenchResult {
            name: name.to_string(),
            iters: n,
            mean_ns: mean,
            median_ns: samples[n / 2],
            p95_ns: samples[(n * 95 / 100).min(n - 1)],
            stddev_ns: var.sqrt(),
        };
        r.report();
        r
    }
}

/// Prevent the optimizer from eliding a value (std::hint::black_box shim).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bencher { min_time: 0.01, min_iters: 3, max_iters: 100 };
        let mut acc = 0u64;
        let r = b.run("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.iters >= 3);
        assert!(r.mean_ns > 0.0);
        assert!(r.median_ns <= r.p95_ns);
    }

    #[test]
    fn json_roundtrip_of_results() {
        let r = BenchResult {
            name: "m".into(),
            iters: 4,
            mean_ns: 1000.0,
            median_ns: 900.0,
            p95_ns: 1500.0,
            stddev_ns: 50.0,
        };
        let j = r.to_json(Some(2000.0));
        assert_eq!(j.get("name").unwrap().str().unwrap(), "m");
        assert_eq!(j.get("iters").unwrap().usize().unwrap(), 4);
        // 2000 items / 1µs mean = 2e12 items/s
        let tput = j.get("throughput_per_s").unwrap().num().unwrap();
        assert!((tput - 2e12).abs() / 2e12 < 1e-9);
        let dir = std::env::temp_dir().join("protomodels_test_bench_json");
        std::fs::create_dir_all(&dir).unwrap();
        let p = write_json(
            dir.join("BENCH_test.json"),
            "test",
            &[BenchEntry { result: r, items_per_iter: None }],
        )
        .unwrap();
        let parsed =
            crate::json::Json::parse(&std::fs::read_to_string(p).unwrap())
                .unwrap();
        assert_eq!(parsed.get("suite").unwrap().str().unwrap(), "test");
        assert_eq!(parsed.get("results").unwrap().arr().unwrap().len(), 1);
    }

    #[test]
    fn regression_gate_flags_slow_entries() {
        let root = std::env::temp_dir().join("protomodels_test_bench_check");
        let cur = root.join("cur");
        let base = root.join("base");
        std::fs::create_dir_all(&cur).unwrap();
        std::fs::create_dir_all(&base).unwrap();
        let suite = |entries: &[(&str, f64)]| {
            let rows: Vec<String> = entries
                .iter()
                .map(|(n, m)| format!(r#"{{"name":"{n}","mean_ns":{m}}}"#))
                .collect();
            format!(r#"{{"suite":"x","results":[{}]}}"#, rows.join(","))
        };
        std::fs::write(
            cur.join("BENCH_linalg.json"),
            suite(&[("a", 1000.0), ("b", 2000.0), ("new", 500.0)]),
        )
        .unwrap();
        std::fs::write(
            base.join("linalg.json"),
            suite(&[("a", 900.0), ("b", 1000.0)]),
        )
        .unwrap();
        std::fs::write(cur.join("BENCH_pipeline.json"), suite(&[])).unwrap();
        std::fs::write(base.join("pipeline.json"), suite(&[])).unwrap();
        std::fs::write(cur.join("BENCH_nn.json"), suite(&[])).unwrap();
        std::fs::write(base.join("nn.json"), suite(&[])).unwrap();
        std::fs::write(cur.join("BENCH_transport.json"), suite(&[])).unwrap();
        std::fs::write(base.join("transport.json"), suite(&[])).unwrap();

        let rep = check_regressions(&cur, &base, 0.25).unwrap();
        assert_eq!(rep.checked, 2, "a and b compared");
        assert_eq!(rep.skipped, 1, "'new' has no baseline");
        assert_eq!(rep.failures.len(), 1, "{:?}", rep.failures);
        assert!(rep.failures[0].contains('b'), "{:?}", rep.failures);
        // a 1.11x growth stays under the 25% gate
        assert!(!rep.failures.iter().any(|f| f.starts_with("a:")));
        // a baseline entry the current run no longer produces is lost
        // gate coverage — flagged as a failure, not silently dropped
        std::fs::write(
            base.join("pipeline.json"),
            suite(&[("gone", 100.0)]),
        )
        .unwrap();
        let rep = check_regressions(&cur, &base, 0.25).unwrap();
        assert!(
            rep.failures.iter().any(|f| f.contains("gone")),
            "{:?}",
            rep.failures
        );
        // missing baseline directory is an error, not a silent pass
        assert!(
            check_regressions(&cur, &root.join("nope"), 0.25).is_err()
        );
    }

    #[test]
    fn compare_tables_union_and_speedup() {
        let dir = std::env::temp_dir().join("protomodels_test_bench_cmp");
        std::fs::create_dir_all(&dir).unwrap();
        let suite = |entries: &[(&str, f64)]| {
            let rows: Vec<String> = entries
                .iter()
                .map(|(n, m)| format!(r#"{{"name":"{n}","mean_ns":{m}}}"#))
                .collect();
            format!(r#"{{"suite":"x","results":[{}]}}"#, rows.join(","))
        };
        let old = dir.join("old.json");
        let new = dir.join("new.json");
        std::fs::write(&old, suite(&[("a", 3000.0), ("gone", 10.0)]))
            .unwrap();
        std::fs::write(&new, suite(&[("a", 1000.0), ("fresh", 20.0)]))
            .unwrap();
        let rows = compare_suites(&old, &new).unwrap();
        assert_eq!(rows.len(), 3);
        let a = rows.iter().find(|r| r.name == "a").unwrap();
        assert!((a.speedup().unwrap() - 3.0).abs() < 1e-9);
        let gone = rows.iter().find(|r| r.name == "gone").unwrap();
        assert!(gone.new_ns.is_none() && gone.speedup().is_none());
        let fresh = rows.iter().find(|r| r.name == "fresh").unwrap();
        assert!(fresh.old_ns.is_none());
        assert!((print_comparison(&rows) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2e9).ends_with(" s"));
    }
}
