//! Micro-benchmark harness (the offline vendor set has no criterion).
//!
//! Criterion-style protocol: warmup, then timed iterations until both a
//! minimum iteration count and a minimum measuring window are reached;
//! reports mean / median / p95 and throughput. Benches link this via the
//! library crate and run with `harness = false`.

use std::time::Instant;

/// Timing summary of one benchmark.
pub struct BenchResult {
    /// benchmark label
    pub name: String,
    /// measured iterations
    pub iters: usize,
    /// mean nanoseconds per iteration
    pub mean_ns: f64,
    /// median nanoseconds per iteration
    pub median_ns: f64,
    /// 95th-percentile nanoseconds per iteration
    pub p95_ns: f64,
    /// sample standard deviation, nanoseconds
    pub stddev_ns: f64,
}

impl BenchResult {
    /// Print the standard one-line report.
    pub fn report(&self) {
        println!(
            "{:<48} {:>12} {:>12} {:>12}   ({} iters, σ {})",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p95_ns),
            self.iters,
            fmt_ns(self.stddev_ns),
        );
    }

    /// items/second at the measured mean.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns * 1e-9)
    }
}

/// Human-readable duration from nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Criterion-style measurement protocol: warmup, then timed iterations.
pub struct Bencher {
    /// minimum wall-clock seconds of measurement per bench
    pub min_time: f64,
    /// minimum timed iterations
    pub min_iters: usize,
    /// hard iteration cap
    pub max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { min_time: 1.0, min_iters: 10, max_iters: 100_000 }
    }
}

impl Bencher {
    /// Short-window protocol for expensive benchmarks.
    pub fn quick() -> Self {
        Bencher { min_time: 0.3, min_iters: 5, max_iters: 10_000 }
    }

    /// Measure `f`, print the report, and return the summary.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // warmup
        let warm_until = Instant::now();
        let mut warm = 0;
        while warm < 3 || warm_until.elapsed().as_secs_f64() < self.min_time * 0.2
        {
            f();
            warm += 1;
            if warm >= self.max_iters {
                break;
            }
        }
        // measure
        let mut samples: Vec<f64> = Vec::new();
        let t0 = Instant::now();
        while (samples.len() < self.min_iters
            || t0.elapsed().as_secs_f64() < self.min_time)
            && samples.len() < self.max_iters
        {
            let s = Instant::now();
            f();
            samples.push(s.elapsed().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>()
            / n as f64;
        let r = BenchResult {
            name: name.to_string(),
            iters: n,
            mean_ns: mean,
            median_ns: samples[n / 2],
            p95_ns: samples[(n * 95 / 100).min(n - 1)],
            stddev_ns: var.sqrt(),
        };
        r.report();
        r
    }
}

/// Prevent the optimizer from eliding a value (std::hint::black_box shim).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bencher { min_time: 0.01, min_iters: 3, max_iters: 100 };
        let mut acc = 0u64;
        let r = b.run("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.iters >= 3);
        assert!(r.mean_ns > 0.0);
        assert!(r.median_ns <= r.p95_ns);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2e9).ends_with(" s"));
    }
}
