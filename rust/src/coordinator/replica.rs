//! Replicated pipelines: data-parallel × model-parallel hybrid.
//!
//! Real decentralized deployments (SWARM-style) never run a *single*
//! pipeline — they replicate it R times and all-reduce weight gradients
//! across replicas every step. This module adds that axis on top of the
//! coordinator:
//!
//! - [`ReplicaSet`] runs R [`Pipeline`] instances sharing one PJRT
//!   runtime (compiled executables are cached once, not R times), each
//!   with its own netsim link samples and data-RNG shard, and joins them
//!   with a simulated ring all-reduce of per-stage weight gradients over
//!   a cross-replica [`ReplicaRing`].
//! - The all-reduce payload is priced under the same [`Mode`] wire
//!   vocabulary as activations via [`crate::compress::dp_wire_bytes`]
//!   (raw / quant / topk / subspace-U-only).
//! - Heterogeneous replicas are modeled by per-replica
//!   [`TimeModel::scaled`] throughput factors (stragglers).
//! - The step makespan is `max` over replicas of the pipeline makespan
//!   plus the *overlapped* all-reduce tail ([`hybrid_makespan`]).
//!
//! The analytic half ([`simulate_hybrid_step`]) prices a hybrid step
//! from the config dimensions alone — no AOT artifacts or PJRT backend
//! needed — and powers `examples/swarm_replicas.rs`, the `dp-grid`
//! experiment driver, and the property tests. DESIGN.md §6 documents the
//! cost model; DESIGN.md §4 lists the simulation substitutions.

use anyhow::{bail, Result};

use crate::compress::{dp_wire_bytes, wire_bytes, Mode};
use crate::coordinator::schedule::{
    gpipe_makespan, hybrid_makespan, HybridMakespan, Makespan, StepCosts, Tx,
};
use crate::coordinator::{Pipeline, PipelineConfig, StepStats};
use crate::manifest::{Hyper, Manifest};
use crate::netsim::{LinkSpec, ReplicaRing, Topology};
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::tensor::{IntTensor, Tensor};
use crate::timemodel::{stage_param_count, stage_seconds, Phase, TimeModel};

/// Configuration of the data-parallel axis.
#[derive(Clone, Debug)]
pub struct ReplicaConfig {
    /// wire pricing of the weight-gradient all-reduce payload
    pub dp_mode: Mode,
    /// per-replica compute slowdown factors (1.0 = nominal; 2.0 = a
    /// straggler at half throughput). Empty = all nominal.
    pub slowdown: Vec<f64>,
}

impl Default for ReplicaConfig {
    fn default() -> Self {
        ReplicaConfig { dp_mode: Mode::Subspace, slowdown: Vec::new() }
    }
}

impl ReplicaConfig {
    /// Slowdown factor for replica `r` (1.0 when unspecified).
    pub fn slowdown_of(&self, r: usize) -> f64 {
        self.slowdown.get(r).copied().unwrap_or(1.0)
    }
}

/// Statistics of one hybrid (replicated) optimizer step.
#[derive(Clone, Debug)]
pub struct ReplicaStepStats {
    /// 1-based step index after this step
    pub step: u64,
    /// mean training loss across replicas
    pub loss: f64,
    /// simulated wall-clock seconds of the hybrid step
    pub sim_seconds: f64,
    /// bytes that crossed pipeline (activation) links, summed over replicas
    pub wire_bytes: u64,
    /// bytes that crossed cross-replica (gradient) links this step
    pub dp_bytes: u64,
    /// tokens consumed across all replicas (global batch)
    pub tokens: usize,
    /// timing breakdown: compute end, comm end, overlapped tail
    pub makespan: HybridMakespan,
}

/// R replicated pipelines + the cross-replica gradient ring.
pub struct ReplicaSet {
    /// the replicas; identical initial parameters, independent data shards
    pub pipelines: Vec<Pipeline>,
    /// cross-replica all-reduce topology
    pub ring: ReplicaRing,
    /// data-parallel configuration
    pub cfg: ReplicaConfig,
    /// hybrid steps completed
    pub step: u64,
    /// simulated seconds since construction
    pub clock: f64,
    /// per-stage all-reduce payload bytes under `cfg.dp_mode`
    stage_payloads: Vec<usize>,
}

impl ReplicaSet {
    /// Build R replicas of `config_name` sharing one runtime. `topos`
    /// supplies each replica's pipeline topology (its length sets R);
    /// every replica starts from identical parameters (same `pcfg.seed`)
    /// and then gets its own data shard and straggler factor.
    pub fn new(
        manifest: &Manifest,
        config_name: &str,
        topos: Vec<Topology>,
        ring: ReplicaRing,
        pcfg: PipelineConfig,
        cfg: ReplicaConfig,
    ) -> Result<ReplicaSet> {
        if topos.is_empty() {
            bail!("replica set needs at least one topology");
        }
        if ring.replicas() != topos.len() {
            bail!(
                "ring has {} replicas, got {} topologies",
                ring.replicas(),
                topos.len()
            );
        }
        if cfg.slowdown.iter().any(|s| !s.is_finite() || *s <= 0.0) {
            bail!("slowdown factors must be positive, got {:?}", cfg.slowdown);
        }
        if cfg.slowdown.iter().any(|s| (*s - 1.0).abs() > 1e-9)
            && matches!(pcfg.time_model, TimeModel::Measured)
        {
            bail!(
                "heterogeneous replicas need an analytic time model: \
                 measured wall times are real CPU seconds of this process \
                 and cannot be scaled per replica"
            );
        }
        // one shared runtime for all R replicas (RtHandle::Shared):
        // replica sets are single-threaded by construction — parallel
        // grid drivers parallelize across *cells*, each of which owns
        // its whole ReplicaSet (and runtime) inside one pool worker
        let rt = Runtime::shared(manifest, config_name)?;
        let mut pipelines = Vec::with_capacity(topos.len());
        for (r, topo) in topos.into_iter().enumerate() {
            let mut p_cfg = pcfg.clone();
            p_cfg.time_model = pcfg.time_model.scaled(cfg.slowdown_of(r));
            let mut pipe = Pipeline::with_runtime(rt.clone(), topo, p_cfg)?;
            // identical init (same seed), divergent data shards
            pipe.reseed_data(pcfg.seed ^ ((r as u64 + 1) * 0x9E37_79B9));
            pipelines.push(pipe);
        }
        // exact per-stage parameter counts from the AOT schema (the
        // analytic stage_param_count approximation is only for the
        // manifest-free simulate_hybrid_step path)
        let h = pipelines[0].hyper();
        let stage_payloads = (0..h.stages)
            .map(|s| {
                dp_wire_bytes(
                    cfg.dp_mode,
                    pipelines[0].stages[s].param_count(),
                    h.d,
                    h.k,
                    h.ratio,
                )
            })
            .collect();
        Ok(ReplicaSet {
            pipelines,
            ring,
            cfg,
            step: 0,
            clock: 0.0,
            stage_payloads,
        })
    }

    /// Number of replicas.
    pub fn replicas(&self) -> usize {
        self.pipelines.len()
    }

    /// One synchronous hybrid step: every replica runs its pipeline step
    /// on its own data shard, per-stage weight gradients are all-reduced
    /// over the ring (simulated; parameters are averaged as the
    /// numerical equivalent — DESIGN.md §4), and the virtual clock
    /// advances by the hybrid makespan.
    pub fn train_step<F>(&mut self, mut sampler: F) -> Result<ReplicaStepStats>
    where
        F: FnMut(&mut Rng) -> (IntTensor, IntTensor),
    {
        let mut per_replica: Vec<StepStats> =
            Vec::with_capacity(self.pipelines.len());
        for pipe in self.pipelines.iter_mut() {
            per_replica.push(pipe.train_step(&mut sampler)?);
        }
        self.average_replicas();

        let makespans: Vec<Makespan> =
            per_replica.iter().map(|s| s.makespan.clone()).collect();
        let dp_before = self.ring.total_bytes();
        let hybrid =
            hybrid_makespan(&makespans, &self.stage_payloads, &mut self.ring);
        let dp_bytes = self.ring.total_bytes() - dp_before;

        self.step += 1;
        self.clock += hybrid.total;
        Ok(ReplicaStepStats {
            step: self.step,
            loss: per_replica.iter().map(|s| s.loss).sum::<f64>()
                / per_replica.len() as f64,
            sim_seconds: hybrid.total,
            wire_bytes: per_replica.iter().map(|s| s.wire_bytes).sum(),
            dp_bytes,
            tokens: per_replica.iter().map(|s| s.tokens).sum(),
            makespan: hybrid,
        })
    }

    /// Synchronize replicas after local optimizer steps: average
    /// parameters and optimizer moments elementwise (the simulation's
    /// stand-in for gradient all-reduce before the optimizer), and adopt
    /// replica 0's subspace basis so compressed modes stay consistent
    /// after Grassmann updates (the basis owner in the paper's protocol).
    ///
    /// When Grassmann updates are active, replica bases may have diverged
    /// this step (each replica accumulates its own GᵀG); averaging
    /// parameters re-projected onto different bases leaves the mean
    /// outside the adopted S, so the constrained matrices (and first
    /// moments) are re-projected onto the leader's basis before the
    /// broadcast — restoring the closure invariant (DESIGN.md §4).
    fn average_replicas(&mut self) {
        let r = self.pipelines.len();
        if r <= 1 {
            return;
        }
        let scale = 1.0 / r as f32;
        let (first, rest) = self.pipelines.split_at_mut(1);
        let leader = &mut first[0];
        for s in 0..leader.stages.len() {
            for i in 0..leader.stages[s].params.len() {
                accumulate_mean(
                    &mut leader.stages[s].params[i],
                    rest.iter().map(|p| &p.stages[s].params[i]),
                    scale,
                );
                accumulate_mean(
                    &mut leader.stages[s].m[i],
                    rest.iter().map(|p| &p.stages[s].m[i]),
                    scale,
                );
                accumulate_mean(
                    &mut leader.stages[s].v[i],
                    rest.iter().map(|p| &p.stages[s].v[i]),
                    scale,
                );
            }
        }
        // re-project onto the adopted basis when bases may have diverged
        // (a no-op when they haven't: S is closed under averaging, so
        // this only runs when Grassmann maintenance is active)
        let compressed = leader.cfg.mode.compressed();
        if compressed && leader.cfg.grassmann_interval > 0 {
            for s in 0..leader.stages.len() {
                for i in 0..leader.stages[s].params.len() {
                    if !crate::stage::constrained(&leader.stages[s].schema[i].0)
                    {
                        continue;
                    }
                    leader.stages[s].params[i] = crate::linalg::project_rows(
                        &leader.stages[s].params[i],
                        &leader.global.u,
                    );
                    leader.stages[s].m[i] = crate::linalg::project_rows(
                        &leader.stages[s].m[i],
                        &leader.global.u,
                    );
                }
            }
        }
        // broadcast the averaged state (and the leader's basis) back out
        for p in rest.iter_mut() {
            for s in 0..p.stages.len() {
                p.stages[s].params = leader.stages[s].params.clone();
                p.stages[s].m = leader.stages[s].m.clone();
                p.stages[s].v = leader.stages[s].v.clone();
            }
            p.global = leader.global.clone();
        }
    }

    /// Mean validation loss of the (synchronized) model — evaluated on
    /// replica 0, which holds the averaged parameters.
    pub fn eval<F>(&mut self, batches: usize, sampler: F) -> Result<f64>
    where
        F: FnMut(&mut Rng) -> (IntTensor, IntTensor),
    {
        self.pipelines[0].eval(batches, sampler)
    }

    /// Max subspace leak across replicas (closure diagnostic).
    pub fn subspace_leak(&self) -> f64 {
        self.pipelines
            .iter()
            .map(|p| p.subspace_leak())
            .fold(0.0, f64::max)
    }
}

/// `dst = dst*scale + Σ others*scale` — elementwise mean across replicas.
fn accumulate_mean<'a>(
    dst: &mut Tensor,
    others: impl Iterator<Item = &'a Tensor>,
    scale: f32,
) {
    dst.scale(scale);
    for t in others {
        for (a, b) in dst.data.iter_mut().zip(&t.data) {
            *a += b * scale;
        }
    }
}

// ---------------------------------------------------------------------------
// analytic hybrid cost model (no artifacts / PJRT needed)
// ---------------------------------------------------------------------------

/// Inputs to the analytic hybrid-step simulator.
#[derive(Clone, Debug)]
pub struct HybridSimSpec {
    /// model/pipeline dimensions (no manifest required)
    pub hyper: Hyper,
    /// microbatches per step
    pub microbatches: usize,
    /// activation (boundary) compression mode
    pub mode: Mode,
    /// weight-gradient all-reduce pricing mode
    pub dp_mode: Mode,
    /// number of pipeline replicas R
    pub replicas: usize,
    /// per-replica slowdown factors (empty = all nominal)
    pub slowdown: Vec<f64>,
    /// stage-to-stage (pipeline) link spec
    pub link: LinkSpec,
    /// cross-replica (ring) link spec
    pub ring_link: LinkSpec,
    /// compute-time model (scaled per replica by `slowdown`)
    pub time_model: TimeModel,
    /// seed for the netsim sample streams
    pub seed: u64,
}

impl HybridSimSpec {
    /// A ready-to-run spec over uniform consumer links at `bw_bps` for
    /// both axes, nominal replicas, analytic clock.
    pub fn uniform(hyper: Hyper, replicas: usize, bw_bps: f64) -> HybridSimSpec {
        HybridSimSpec {
            hyper,
            microbatches: 8,
            mode: Mode::Subspace,
            dp_mode: Mode::Subspace,
            replicas,
            slowdown: Vec::new(),
            link: LinkSpec::internet(bw_bps),
            ring_link: LinkSpec::internet(bw_bps),
            time_model: TimeModel::default_analytic(),
            seed: 17,
        }
    }
}

/// Result of one analytic hybrid step.
#[derive(Clone, Debug)]
pub struct HybridSimResult {
    /// timing breakdown (total / compute end / comm end / tail)
    pub makespan: HybridMakespan,
    /// gradient bytes each ring link carried
    pub dp_bytes_per_link: u64,
    /// activation bytes per pipeline boundary transfer
    pub boundary_bytes: usize,
}

/// Price one hybrid step purely from the cost model: per-replica GPipe
/// makespans (analytic compute + sampled pipeline links) joined by the
/// overlapped ring all-reduce of per-stage weight gradients. Replica r's
/// netsim streams depend only on (`seed`, r), so growing R keeps the
/// existing replicas' samples fixed — makespans are monotone in R by
/// construction, which the property tests assert.
pub fn simulate_hybrid_step(spec: &HybridSimSpec) -> HybridSimResult {
    let h = &spec.hyper;
    assert!(h.stages >= 2, "pipeline needs >= 2 stages");
    assert!(spec.replicas >= 1, "need >= 1 replica");
    assert!(
        spec.slowdown.iter().all(|s| s.is_finite() && *s > 0.0),
        "slowdown factors must be positive, got {:?}",
        spec.slowdown
    );
    let compressed = spec.mode.compressed();
    let bbytes = wire_bytes(spec.mode, h.b, h.n, h.d, h.k, h.ratio);
    let (p, m) = (h.stages, spec.microbatches.max(1));

    let mut makespans = Vec::with_capacity(spec.replicas);
    for r in 0..spec.replicas {
        let slowdown = spec.slowdown.get(r).copied().unwrap_or(1.0);
        let tm = spec.time_model.scaled(slowdown);
        // per-replica stream derived from (seed, r) only — see doc above
        let mut rng = Rng::new(spec.seed ^ ((r as u64 + 1) * 0x9E37_79B9));
        let mut topo = Topology::uniform(p, spec.link, &mut rng);
        let mut costs = StepCosts {
            stages: p,
            microbatches: m,
            fwd: vec![vec![0.0; m]; p],
            bwd: vec![vec![0.0; m]; p],
            tx_fwd: vec![vec![Tx::default(); m]; p - 1],
            tx_bwd: vec![vec![Tx::default(); m]; p - 1],
            opt: vec![0.0; p],
            tail: 0.0,
        };
        for s in 0..p {
            let fwd_phase = if s == p - 1 { Phase::LastLoss } else { Phase::Fwd };
            let fwd = stage_seconds(tm, h, s, fwd_phase, compressed, None);
            let bwd = if s == p - 1 {
                0.0 // fused into last_loss
            } else {
                stage_seconds(tm, h, s, Phase::Bwd, compressed, None)
            };
            for mb in 0..m {
                costs.fwd[s][mb] = fwd;
                costs.bwd[s][mb] = bwd;
                if s + 1 < p {
                    let (ser, lat) = topo.links[s].sample(bbytes);
                    costs.tx_fwd[s][mb] = Tx { ser, lat };
                    let (ser, lat) = topo.links[s].sample(bbytes);
                    costs.tx_bwd[s][mb] = Tx { ser, lat };
                }
            }
            costs.opt[s] = stage_seconds(tm, h, s, Phase::Opt, compressed, None);
        }
        makespans.push(gpipe_makespan(&costs));
    }

    let stage_payloads: Vec<usize> = (0..p)
        .map(|s| {
            dp_wire_bytes(
                spec.dp_mode,
                stage_param_count(h, s),
                h.d,
                h.k,
                h.ratio,
            )
        })
        .collect();
    let mut ring_rng = Rng::new(spec.seed ^ 0x51C6);
    let mut ring = ReplicaRing::new(spec.replicas, spec.ring_link, &mut ring_rng);
    let makespan = hybrid_makespan(&makespans, &stage_payloads, &mut ring);
    let dp_bytes_per_link = ring
        .links
        .first()
        .map(|l| l.bytes_sent)
        .unwrap_or(0);
    HybridSimResult { makespan, dp_bytes_per_link, boundary_bytes: bbytes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::MBPS;

    fn hyper() -> Hyper {
        Hyper::base_sim()
    }

    /// Deterministic link: no jitter, no latency (tests isolate the
    /// bandwidth/compute terms; latency is exercised by netsim tests).
    fn quiet(bw_mbps: f64) -> LinkSpec {
        LinkSpec {
            bandwidth_bps: bw_mbps * MBPS,
            latency_s: 0.0,
            jitter_frac: 0.0,
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let spec = HybridSimSpec::uniform(hyper(), 4, 80.0 * MBPS);
        let a = simulate_hybrid_step(&spec).makespan.total;
        let b = simulate_hybrid_step(&spec).makespan.total;
        assert_eq!(a, b);
    }

    #[test]
    fn makespan_monotone_in_replicas() {
        let mut prev = 0.0;
        for r in [1usize, 2, 4, 8] {
            let mut spec = HybridSimSpec::uniform(hyper(), r, 80.0 * MBPS);
            spec.link = quiet(80.0);
            spec.ring_link = quiet(80.0);
            let t = simulate_hybrid_step(&spec).makespan.total;
            assert!(
                t >= prev - 1e-12,
                "R={r}: makespan {t} < previous {prev}"
            );
            prev = t;
        }
    }

    #[test]
    fn subspace_dp_mode_beats_raw_at_low_bandwidth() {
        let mut spec = HybridSimSpec::uniform(hyper(), 4, 80.0 * MBPS);
        spec.link = quiet(80.0);
        spec.ring_link = quiet(80.0);
        let sub = simulate_hybrid_step(&spec).makespan.total;
        spec.dp_mode = Mode::Raw;
        let raw = simulate_hybrid_step(&spec).makespan.total;
        assert!(
            sub < raw,
            "subspace dp {sub} should beat raw dp {raw} at 80 Mbps"
        );
    }

    #[test]
    fn straggler_replica_dominates_makespan() {
        // compute-bound setting: fat links, so makespan ≈ compute_end
        let mut spec = HybridSimSpec::uniform(hyper(), 4, 80.0 * MBPS);
        spec.link = quiet(16_000.0);
        spec.ring_link = quiet(16_000.0);
        let nominal = simulate_hybrid_step(&spec).makespan;
        spec.slowdown = vec![1.0, 1.0, 1.0, 2.0];
        let straggled = simulate_hybrid_step(&spec).makespan;
        let factor = straggled.compute_end / nominal.compute_end;
        assert!(
            (factor - 2.0).abs() < 0.05,
            "2x straggler should ~double compute_end, got {factor}"
        );
        assert!(straggled.total >= nominal.total);
    }

    #[test]
    fn dp_bytes_match_closed_form() {
        use crate::netsim::ring_allreduce_bytes_per_link;
        let spec = HybridSimSpec::uniform(hyper(), 4, 80.0 * MBPS);
        let res = simulate_hybrid_step(&spec);
        let h = hyper();
        let expect: u64 = (0..h.stages)
            .map(|s| {
                ring_allreduce_bytes_per_link(
                    4,
                    dp_wire_bytes(
                        Mode::Subspace,
                        stage_param_count(&h, s),
                        h.d,
                        h.k,
                        h.ratio,
                    ),
                )
            })
            .sum();
        assert_eq!(res.dp_bytes_per_link, expect);
    }

    #[test]
    fn tail_vanishes_on_fast_ring() {
        let mut spec = HybridSimSpec::uniform(hyper(), 4, 80.0 * MBPS);
        spec.link = quiet(80.0);
        spec.ring_link = quiet(1e6); // ~1 Tbps ring
        let res = simulate_hybrid_step(&spec);
        assert!(
            res.makespan.tail < 1e-3 * res.makespan.total,
            "tail {} vs total {}",
            res.makespan.tail,
            res.makespan.total
        );
    }
}
