//! The pipeline coordinator — the L3 system contribution.
//!
//! Owns: stage workers (parameters + optimizer state), the GPipe
//! microbatch schedule, boundary compression bookkeeping, the Grassmann
//! subspace-maintenance protocol (accumulate GᵀG at the last stage,
//! periodically step U on the manifold, re-project constrained weights,
//! broadcast the new basis), the netsim topology, and the virtual clock.
//!
//! All numerics execute via AOT HLO programs through the PJRT runtime;
//! the coordinator moves tensors between programs, accumulates gradients
//! across microbatches, and accounts every byte that would cross a link
//! in the decentralized deployment.
//!
//! The [`replica`] module layers synchronous data parallelism on top:
//! R replicated pipelines sharing one runtime, joined by a ring
//! all-reduce of per-stage weight gradients over a cross-replica
//! [`crate::netsim::ReplicaRing`].

pub mod replica;
pub mod schedule;

use anyhow::{bail, Result};

use crate::compress::{wire_bytes, Mode};
use crate::manifest::ConfigManifest;
use crate::netsim::Topology;
use crate::rng::Rng;
use crate::runtime::{Runtime, SharedRuntime};
use crate::stage::{GlobalState, StageState};
use crate::tensor::{IntTensor, Tensor, Value};
use crate::timemodel::{stage_seconds, Phase, TimeModel};
use schedule::{gpipe_makespan, Makespan, StepCosts, Tx};

/// Handle to the PJRT runtime backing a pipeline.
///
/// Two ownership regimes (DESIGN.md §8): parallel experiment grids give
/// every cell its **own** runtime, constructed and dropped entirely
/// inside one pool worker (`Runtime` is not `Send`, so per-thread
/// ownership is the only sound option); replica sets **share** one
/// runtime across R pipelines within a single thread so the compiled
/// executable cache is paid once, not R times.
pub enum RtHandle {
    /// exclusively owned — single-pipeline runs and per-thread grid jobs
    Owned(Box<Runtime>),
    /// shared across replicas within one thread (`Rc<RefCell<…>>`)
    Shared(SharedRuntime),
}

impl RtHandle {
    fn execute_timed(
        &mut self,
        key: &str,
        args: &[Value],
    ) -> Result<(Vec<Value>, f64)> {
        match self {
            RtHandle::Owned(rt) => rt.execute_timed(key, args),
            RtHandle::Shared(rt) => rt.borrow_mut().execute_timed(key, args),
        }
    }

    /// Run `f` with read access to the underlying runtime (timings,
    /// config introspection) regardless of the ownership regime.
    pub fn with<R>(&self, f: impl FnOnce(&Runtime) -> R) -> R {
        match self {
            RtHandle::Owned(rt) => f(rt),
            RtHandle::Shared(rt) => f(&rt.borrow()),
        }
    }
}

/// Which numerics substrate a pipeline trains on (CLI `--backend`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT HLO artifacts executed through the PJRT runtime
    Pjrt,
    /// the in-process native autodiff backend (`crate::nn`) —
    /// artifact-free, runs everywhere the cost model runs
    Native,
}

impl BackendKind {
    /// Parse a CLI backend label (`"pjrt"`, `"native"`).
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "pjrt" => Ok(BackendKind::Pjrt),
            "native" => Ok(BackendKind::Native),
            other => bail!("unknown backend {other:?} (have pjrt, native)"),
        }
    }

    /// Canonical label.
    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Pjrt => "pjrt",
            BackendKind::Native => "native",
        }
    }
}

/// A trainable pipeline behind either numerics backend. Grid cells own
/// their backend inside one pool worker, exactly like the [`RtHandle`]
/// ownership regime — a `Backend` is constructed, stepped, and dropped
/// without ever crossing a thread boundary.
pub enum Backend {
    /// PJRT-executed pipeline over AOT artifacts
    Pjrt(Box<Pipeline>),
    /// native autodiff pipeline (no artifacts, no PJRT)
    Native(Box<crate::nn::NativePipeline>),
}

impl Backend {
    /// Which substrate this pipeline runs on.
    pub fn kind(&self) -> BackendKind {
        match self {
            Backend::Pjrt(_) => BackendKind::Pjrt,
            Backend::Native(_) => BackendKind::Native,
        }
    }

    /// One full training step (see [`Pipeline::train_step`]).
    pub fn train_step<F>(&mut self, sampler: F) -> Result<StepStats>
    where
        F: FnMut(&mut Rng) -> (IntTensor, IntTensor),
    {
        match self {
            Backend::Pjrt(p) => p.train_step(sampler),
            Backend::Native(n) => n.train_step(sampler),
        }
    }

    /// Mean validation loss over `batches` forward passes.
    pub fn eval<F>(&mut self, batches: usize, sampler: F) -> Result<f64>
    where
        F: FnMut(&mut Rng) -> (IntTensor, IntTensor),
    {
        match self {
            Backend::Pjrt(p) => p.eval(batches, sampler),
            Backend::Native(n) => n.eval(batches, sampler),
        }
    }

    /// Max relative out-of-subspace leak across constrained weights.
    pub fn subspace_leak(&self) -> f64 {
        match self {
            Backend::Pjrt(p) => p.subspace_leak(),
            Backend::Native(n) => n.subspace_leak(),
        }
    }

    /// Simulated seconds since construction.
    pub fn clock(&self) -> f64 {
        match self {
            Backend::Pjrt(p) => p.clock,
            Backend::Native(n) => n.clock,
        }
    }
}

/// Run-level configuration of the coordinator.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// boundary (activation) compression scheme
    pub mode: Mode,
    /// microbatches per optimizer step (global batch = M · b)
    pub microbatches: usize,
    /// steps between Grassmann subspace updates (0 = off; paper: 500)
    pub grassmann_interval: usize,
    /// base Grassmann step scale (adapted by trace(S) at update time)
    pub grassmann_eta: f64,
    /// peak AdamW learning rate
    pub lr: f32,
    /// linear-warmup steps
    pub warmup_steps: usize,
    /// total steps (drives the linear decay schedule)
    pub total_steps: usize,
    /// virtual-clock model pricing stage compute
    pub time_model: TimeModel,
    /// master seed for init / data / netsim streams
    pub seed: u64,
    /// keep the last step's averaged per-stage gradients on the Pipeline
    /// (rank-collapse experiments, Figs. 1/7)
    pub record_grads: bool,
    /// pipeline schedule priced by the virtual clock: GPipe uses the
    /// closed-form recurrence, 1F1B runs on the discrete-event engine
    /// (`--schedule`); interleaved is only available through the
    /// artifact-free swarm simulator (`protomodels sim`)
    pub schedule: crate::sim::Schedule,
    /// route even GPipe timing through the event engine (`--sim`) —
    /// identical totals by the sim parity contract, exercising the
    /// event path in production runs
    pub event_sim: bool,
}

impl PipelineConfig {
    /// Learning rate at 1-based optimizer step `step + 1`: linear
    /// warmup to `lr`, then linear decay floored at 10%. Shared by both
    /// backends so pjrt-vs-native comparisons train on one schedule.
    pub fn lr_at(&self, step: u64) -> f32 {
        let t = (step + 1) as f32;
        let w = self.warmup_steps.max(1) as f32;
        let total = self.total_steps.max(1) as f32;
        let warm = (t / w).min(1.0);
        let decay = (1.0 - (t - w).max(0.0) / (total - w).max(1.0))
            .clamp(0.1, 1.0);
        self.lr * warm * decay
    }

    /// Whether the boundary mode is one of the subspace-compressed
    /// schemes (shared vocabulary for both backends).
    pub fn compressed(&self) -> bool {
        self.mode.compressed()
    }

    /// Bytes one boundary payload of dimensions `h` occupies on the
    /// wire under this config's mode.
    pub fn boundary_bytes(&self, h: &crate::manifest::Hyper) -> usize {
        wire_bytes(self.mode, h.b, h.n, h.d, h.k, h.ratio)
    }
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            mode: Mode::Subspace,
            microbatches: 4,
            grassmann_interval: 500,
            grassmann_eta: 0.5,
            lr: 3e-4,
            warmup_steps: 20,
            total_steps: 1000,
            time_model: TimeModel::default_analytic(),
            seed: 0,
            record_grads: false,
            schedule: crate::sim::Schedule::Gpipe,
            event_sim: false,
        }
    }
}

/// Statistics of one optimizer step.
#[derive(Clone, Debug)]
pub struct StepStats {
    /// 1-based step index after this step
    pub step: u64,
    /// mean training loss over the step's microbatches
    pub loss: f64,
    /// simulated wall-clock seconds of this step (netsim + time model)
    pub sim_seconds: f64,
    /// bytes that crossed pipeline links this step
    pub wire_bytes: u64,
    /// tokens consumed this step
    pub tokens: usize,
    /// full timing breakdown of the step
    pub makespan: Makespan,
}

/// One pipeline-parallel training system: P stage workers over a netsim
/// [`Topology`], driven step-by-step through the shared PJRT runtime.
pub struct Pipeline {
    /// PJRT runtime handle: owned by this pipeline, or shared across
    /// replicas in data-parallel runs
    pub rt: RtHandle,
    /// config manifest this pipeline was built for (cached off `rt`)
    pub cm: ConfigManifest,
    /// stage-to-stage network links
    pub topo: Topology,
    /// run-level configuration
    pub cfg: PipelineConfig,
    /// per-stage parameters + optimizer state
    pub stages: Vec<StageState>,
    /// leader-owned global state (U_k basis, fixed embedding)
    pub global: GlobalState,
    /// optimizer steps completed
    pub step: u64,
    /// simulated seconds since construction (includes startup broadcast)
    pub clock: f64,
    /// Grassmann accumulator S = Σ GᵀG and its sample count
    s_acc: Tensor,
    s_count: u64,
    rng: Rng,
    /// host-side coordination seconds actually spent (L3 overhead profile)
    pub host_seconds: f64,
    /// last step's averaged per-stage gradients (when cfg.record_grads)
    pub last_grads: Option<Vec<Vec<Tensor>>>,
}

impl Pipeline {
    /// Build a pipeline owning its own private runtime for
    /// `config_name` — the grid-job path: the whole pipeline (runtime
    /// included) lives and dies inside one pool worker.
    pub fn new(
        manifest: &crate::manifest::Manifest,
        config_name: &str,
        topo: Topology,
        cfg: PipelineConfig,
    ) -> Result<Pipeline> {
        let rt = RtHandle::Owned(Box::new(Runtime::new(manifest, config_name)?));
        Pipeline::with_handle(rt, topo, cfg)
    }

    /// Build a pipeline on an existing shared runtime — the
    /// replicated-pipeline path, where R replicas share one compiled
    /// executable cache (single-threaded by construction).
    pub fn with_runtime(
        rt: SharedRuntime,
        topo: Topology,
        cfg: PipelineConfig,
    ) -> Result<Pipeline> {
        Pipeline::with_handle(RtHandle::Shared(rt), topo, cfg)
    }

    /// Build a pipeline on any runtime handle.
    pub fn with_handle(
        rt: RtHandle,
        topo: Topology,
        cfg: PipelineConfig,
    ) -> Result<Pipeline> {
        let cm = rt.with(|r| r.config().clone());
        let h = cm.hyper.clone();
        if topo.stages() != h.stages {
            bail!(
                "topology has {} stages, config {} needs {}",
                topo.stages(),
                cm.name,
                h.stages
            );
        }
        // compare parsed Modes, not name strings: bf16 wire variants
        // execute the artifacts of their f32 base mode, and a manifest
        // typo surfaces as an unknown-mode entry instead of a silent
        // mismatch
        let base = cfg.mode.base();
        let compiled = cm
            .modes
            .iter()
            .any(|m| m.parse::<Mode>().is_ok_and(|m| m == base));
        if !compiled {
            bail!(
                "config {} was not AOT-compiled for mode {:?} (have {:?})",
                cm.name,
                cfg.mode.as_str(),
                cm.modes
            );
        }
        if matches!(cfg.schedule, crate::sim::Schedule::Interleaved { .. }) {
            bail!(
                "interleaved schedules need wrap-link samples the \
                 coordinator does not carry; use the swarm simulator \
                 (`protomodels sim --schedule interleaved`)"
            );
        }
        let mut rng = Rng::new(cfg.seed ^ 0x9137);
        let global = GlobalState::init(&cm, &mut rng);
        let stages = (0..h.stages)
            .map(|s| StageState::init(&cm, s, cfg.mode, &global, &mut rng))
            .collect::<Result<Vec<_>>>()?;
        let mut pipe = Pipeline {
            rt,
            cm,
            topo,
            cfg,
            stages,
            global,
            step: 0,
            clock: 0.0,
            s_acc: Tensor::zeros(&[h.d, h.d]),
            s_count: 0,
            rng,
            host_seconds: 0.0,
            last_grads: None,
        };
        // startup: broadcast T_fixed (compressed modes) + U_k once
        if pipe.cfg.mode.compressed() {
            let bytes = (h.vocab * h.d + h.d * h.k) * 4;
            pipe.clock += pipe.topo.broadcast(bytes);
        }
        Ok(pipe)
    }

    /// Re-seed the training-data RNG stream without touching parameters.
    /// Replicated data-parallel runs construct every replica from the
    /// same `cfg.seed` (identical initialization) and then diverge the
    /// data streams with this — one shard per replica.
    pub fn reseed_data(&mut self, seed: u64) {
        self.rng = Rng::new(seed ^ 0xDA7A_5EED);
    }

    /// Hyperparameters of this pipeline's config.
    pub fn hyper(&self) -> crate::manifest::Hyper {
        self.cm.hyper.clone()
    }

    fn key(&self, name: &str) -> String {
        // artifact entries exist under the f32 base mode's name; the
        // bf16 variants change only the wire encoding
        format!("{}/{}", self.cfg.mode.base().as_str(), name)
    }

    /// adamw entries only exist for subspace/raw: nofixed shares
    /// subspace's (same schema + constraint rules), lossy modes share raw's.
    fn opt_key(&self, kind: &str) -> String {
        let mode =
            if self.compressed() { Mode::Subspace } else { Mode::Raw };
        format!("{}/adamw_{kind}", mode.as_str())
    }

    fn lr_now(&self) -> f32 {
        self.cfg.lr_at(self.step)
    }

    fn boundary_bytes(&self) -> usize {
        self.cfg.boundary_bytes(&self.cm.hyper)
    }

    fn compressed(&self) -> bool {
        self.cfg.compressed()
    }

    /// Args shared by compressed-mode stage programs. The nofixed
    /// ablation drops T_fixed (its entire embedding lives in S).
    fn ctx_args(&self, tok: &IntTensor) -> Vec<Value> {
        if self.cfg.mode == Mode::NoFixed {
            vec![
                Value::F32(self.global.u.clone()),
                Value::I32(tok.clone()),
            ]
        } else {
            vec![
                Value::F32(self.global.u.clone()),
                Value::F32(self.global.t_fixed.clone()),
                Value::I32(tok.clone()),
            ]
        }
    }

    fn params_of(&self, s: usize) -> Vec<Value> {
        self.stages[s]
            .params
            .iter()
            .cloned()
            .map(Value::F32)
            .collect()
    }

    fn exec_timed(
        &mut self,
        key: &str,
        args: &[Value],
    ) -> Result<(Vec<Value>, f64)> {
        self.rt.execute_timed(key, args)
    }

    /// Total runtime seconds across all entries (profiling).
    pub fn total_compute_seconds(&self) -> f64 {
        self.rt.with(|r| r.total_compute_seconds())
    }

    /// Structured per-entry timing table (profiling); its `Display`
    /// renders the legacy `entry,calls,total_s,mean_ms` CSV text.
    pub fn timing_report(&self) -> crate::obs::counters::TimingReport {
        self.rt.with(|r| r.timing_report())
    }

    /// Forward through stage s for one microbatch; returns (output, secs).
    fn stage_fwd(
        &mut self,
        s: usize,
        tok: &IntTensor,
        input: Option<&Tensor>,
    ) -> Result<(Tensor, f64)> {
        let h = self.cm.hyper.clone();
        let last = h.stages - 1;
        assert!(s < last, "last stage uses last_loss/last_eval");
        let mut args = self.params_of(s);
        if self.compressed() {
            args.extend(self.ctx_args(tok));
        } else if s == 0 {
            args.push(Value::I32(tok.clone()));
        }
        if s > 0 {
            args.push(Value::F32(input.expect("mid stage needs input").clone()));
        }
        let name = if s == 0 { "first_fwd" } else { "mid_fwd" };
        let key = self.key(name);
        let (outs, dt) = self.exec_timed(&key, &args)?;
        let out = outs.into_iter().next().unwrap().into_f32();
        let secs = stage_seconds(
            self.cfg.time_model,
            &h,
            s,
            Phase::Fwd,
            self.compressed(),
            Some(dt),
        );
        Ok((out, secs))
    }

    /// One full training step over `microbatches` sampled by `sampler`.
    pub fn train_step<F>(&mut self, mut sampler: F) -> Result<StepStats>
    where
        F: FnMut(&mut Rng) -> (IntTensor, IntTensor),
    {
        let t_host = std::time::Instant::now();
        let h = self.cm.hyper.clone();
        let (p, m_count) = (h.stages, self.cfg.microbatches);
        let last = p - 1;
        let bbytes = self.boundary_bytes();

        let mut grad_acc: Vec<Vec<Tensor>> =
            self.stages.iter().map(|st| st.zero_grads()).collect();
        let mut costs = StepCosts {
            stages: p,
            microbatches: m_count,
            fwd: vec![vec![0.0; m_count]; p],
            bwd: vec![vec![0.0; m_count]; p],
            tx_fwd: vec![vec![Tx::default(); m_count]; p - 1],
            tx_bwd: vec![vec![Tx::default(); m_count]; p - 1],
            opt: vec![0.0; p],
            tail: 0.0,
        };
        let mut loss_sum = 0.0f64;
        let mut wire = 0u64;

        let mut data_rng = self.rng.fork(0xDA7A ^ self.step);
        for mb in 0..m_count {
            let (tok, tgt) = sampler(&mut data_rng);
            // ---- forward wave, saving each stage's input for remat bwd
            let mut saved_inputs: Vec<Option<Tensor>> = vec![None; p];
            let mut cur: Option<Tensor> = None;
            for s in 0..last {
                let (out, secs) = self.stage_fwd(s, &tok, cur.as_ref())?;
                costs.fwd[s][mb] = secs;
                let (ser, lat) = self.topo.links[s].sample(bbytes);
                costs.tx_fwd[s][mb] = Tx { ser, lat };
                wire += bbytes as u64;
                saved_inputs[s + 1] = Some(out.clone());
                cur = Some(out);
            }
            // ---- last stage: fused fwd + loss + bwd
            let mut args = self.params_of(last);
            if self.compressed() {
                args.extend(self.ctx_args(&tok));
            }
            args.push(Value::F32(cur.take().unwrap()));
            args.push(Value::I32(tgt.clone()));
            let key = self.key("last_loss");
            let (outs, dt) = self.exec_timed(&key, &args)?;
            costs.fwd[last][mb] = stage_seconds(
                self.cfg.time_model,
                &h,
                last,
                Phase::LastLoss,
                self.compressed(),
                Some(dt),
            );
            let n_params = self.stages[last].params.len();
            let mut it = outs.into_iter();
            loss_sum += it.next().unwrap().into_f32().item() as f64;
            let mut gc = it.next().unwrap().into_f32();
            for g in grad_acc[last].iter_mut() {
                g.add_assign(&it.next().unwrap().into_f32());
            }
            if self.compressed() {
                let gtg = it.next().unwrap().into_f32();
                self.s_acc.add_assign(&gtg);
                self.s_count += 1;
            } else {
                debug_assert!(it.next().is_none());
            }
            debug_assert_eq!(n_params, grad_acc[last].len());

            // ---- backward wave
            for s in (0..last).rev() {
                let (ser, lat) = self.topo.links[s].sample(bbytes);
                costs.tx_bwd[s][mb] = Tx { ser, lat };
                wire += bbytes as u64;

                let mut args = self.params_of(s);
                if self.compressed() {
                    args.extend(self.ctx_args(&tok));
                } else if s == 0 {
                    args.push(Value::I32(tok.clone()));
                }
                if s > 0 {
                    args.push(Value::F32(
                        saved_inputs[s].as_ref().unwrap().clone(),
                    ));
                }
                args.push(Value::F32(gc.clone()));
                let name = if s == 0 { "first_bwd" } else { "mid_bwd" };
                let key = self.key(name);
                let (outs, dt) = self.exec_timed(&key, &args)?;
                costs.bwd[s][mb] = stage_seconds(
                    self.cfg.time_model,
                    &h,
                    s,
                    Phase::Bwd,
                    self.compressed(),
                    Some(dt),
                );
                let mut it = outs.into_iter();
                if s > 0 {
                    gc = it.next().unwrap().into_f32();
                }
                for g in grad_acc[s].iter_mut() {
                    g.add_assign(&it.next().unwrap().into_f32());
                }
            }
        }

        // ---- average grads over microbatches, apply optimizer per stage
        let scale = 1.0 / m_count as f32;
        if self.cfg.record_grads {
            let mut snap = grad_acc.clone();
            for st in snap.iter_mut() {
                for g in st.iter_mut() {
                    g.scale(scale);
                }
            }
            self.last_grads = Some(snap);
        }
        let lr = self.lr_now();
        let t_opt = (self.step + 1) as f32;
        for s in 0..p {
            for g in grad_acc[s].iter_mut() {
                g.scale(scale);
            }
            let secs = self.optimizer_step(s, &grad_acc[s], lr, t_opt)?;
            costs.opt[s] = secs;
        }

        // ---- Grassmann subspace maintenance (Sec. 4.5)
        if self.compressed()
            && self.cfg.grassmann_interval > 0
            && (self.step + 1) % self.cfg.grassmann_interval as u64 == 0
            && self.s_count > 0
        {
            costs.tail += self.grassmann_update()?;
        }

        let makespan = self.step_makespan(&costs)?;
        self.clock += makespan.total;
        self.step += 1;
        self.host_seconds += t_host.elapsed().as_secs_f64();
        Ok(StepStats {
            step: self.step,
            loss: loss_sum / m_count as f64,
            sim_seconds: makespan.total,
            wire_bytes: wire,
            tokens: m_count * h.b * h.n,
            makespan,
        })
    }

    /// Price one step's costs under the configured schedule: the
    /// analytic recurrence for plain GPipe, the discrete-event engine
    /// for 1F1B or when `--sim` forces the event path (identical for
    /// GPipe by the parity contract in `tests/sim_swarm.rs`).
    fn step_makespan(&self, costs: &StepCosts) -> Result<Makespan> {
        if matches!(self.cfg.schedule, crate::sim::Schedule::Gpipe)
            && !self.cfg.event_sim
        {
            Ok(gpipe_makespan(costs))
        } else {
            crate::sim::step_makespan(costs, self.cfg.schedule)
        }
    }

    /// AdamW step for one stage; returns simulated seconds.
    fn optimizer_step(
        &mut self,
        s: usize,
        grads: &[Tensor],
        lr: f32,
        t: f32,
    ) -> Result<f64> {
        let h = self.cm.hyper.clone();
        let kind = self.cm.stage_kind(s);
        let mut args: Vec<Value> = self.params_of(s);
        args.extend(grads.iter().cloned().map(Value::F32));
        args.extend(self.stages[s].m.iter().cloned().map(Value::F32));
        args.extend(self.stages[s].v.iter().cloned().map(Value::F32));
        if self.compressed() {
            args.push(Value::F32(self.global.u.clone()));
        }
        args.push(Value::F32(Tensor::scalar(lr)));
        args.push(Value::F32(Tensor::scalar(t)));
        let key = self.opt_key(kind);
        let (outs, dt) = self.exec_timed(&key, &args)?;
        let n = self.stages[s].params.len();
        debug_assert_eq!(outs.len(), 3 * n);
        let mut it = outs.into_iter();
        for i in 0..n {
            self.stages[s].params[i] = it.next().unwrap().into_f32();
        }
        for i in 0..n {
            self.stages[s].m[i] = it.next().unwrap().into_f32();
        }
        for i in 0..n {
            self.stages[s].v[i] = it.next().unwrap().into_f32();
        }
        Ok(stage_seconds(
            self.cfg.time_model,
            &h,
            s,
            Phase::Opt,
            self.compressed(),
            Some(dt),
        ))
    }

    /// Riemannian subspace update + re-projection + basis broadcast.
    /// Returns simulated tail seconds added to the step.
    fn grassmann_update(&mut self) -> Result<f64> {
        let h = self.cm.hyper.clone();
        let mut s_avg = self.s_acc.clone();
        s_avg.scale(1.0 / self.s_count as f32);
        // adaptive step: eta ∝ d / tr(S) keeps the step well-scaled as
        // gradient magnitudes decay over training
        let trace: f64 = (0..h.d).map(|i| s_avg.at2(i, i) as f64).sum();
        let eta = if trace > 1e-12 {
            (self.cfg.grassmann_eta * h.d as f64 / trace) as f32
        } else {
            0.0
        };
        let gargs = [
            Value::F32(self.global.u.clone()),
            Value::F32(s_avg),
            Value::F32(Tensor::scalar(eta)),
        ];
        let (outs, dt) = self.exec_timed("subspace/grassmann_step", &gargs)?;
        self.global.u = outs.into_iter().next().unwrap().into_f32();
        // re-project constrained weights + momenta onto the new S
        let mut secs = stage_seconds(
            self.cfg.time_model,
            &h,
            h.stages - 1,
            Phase::Grassmann,
            true,
            Some(dt),
        );
        for s in 0..h.stages {
            let kind = self.cm.stage_kind(s);
            let mut args: Vec<Value> = self.params_of(s);
            args.extend(self.stages[s].m.iter().cloned().map(Value::F32));
            args.push(Value::F32(self.global.u.clone()));
            let key = format!("subspace/reproject_{kind}");
            let (outs, dt2) = self.exec_timed(&key, &args)?;
            let n = self.stages[s].params.len();
            let mut it = outs.into_iter();
            for i in 0..n {
                self.stages[s].params[i] = it.next().unwrap().into_f32();
            }
            for i in 0..n {
                self.stages[s].m[i] = it.next().unwrap().into_f32();
            }
            secs += stage_seconds(
                self.cfg.time_model,
                &h,
                s,
                Phase::Grassmann,
                true,
                Some(dt2),
            );
        }
        // broadcast the new U_k to every stage
        secs += self.topo.broadcast(h.d * h.k * 4);
        self.s_acc = Tensor::zeros(&[h.d, h.d]);
        self.s_count = 0;
        Ok(secs)
    }

    /// Mean validation loss over `batches` forward passes. Side-effect
    /// free: the eval batch stream derives from `(cfg.seed, step)` only,
    /// so evaluating mid-training does not shift subsequent training
    /// batches (which would silently break cross-run batch-order
    /// alignment).
    pub fn eval<F>(&mut self, batches: usize, mut sampler: F) -> Result<f64>
    where
        F: FnMut(&mut Rng) -> (IntTensor, IntTensor),
    {
        let h = self.cm.hyper.clone();
        let last = h.stages - 1;
        let mut rng = Rng::new(
            self.cfg.seed ^ 0xE7A1 ^ self.step.wrapping_mul(0x9E37_79B9),
        );
        let mut sum = 0.0;
        for _ in 0..batches {
            let (tok, tgt) = sampler(&mut rng);
            let mut cur: Option<Tensor> = None;
            for s in 0..last {
                let (out, _) = self.stage_fwd(s, &tok, cur.as_ref())?;
                cur = Some(out);
            }
            let mut args = self.params_of(last);
            if self.compressed() {
                args.extend(self.ctx_args(&tok));
            }
            args.push(Value::F32(cur.take().unwrap()));
            args.push(Value::I32(tgt));
            let key = self.key("last_eval");
            let (outs, _) = self.exec_timed(&key, &args)?;
            sum += outs[0].as_f32().item() as f64;
        }
        Ok(sum / batches.max(1) as f64)
    }

    /// Forward-only pipeline (inference serving path). Returns
    /// (simulated seconds, tokens processed) for `m_count` microbatches.
    pub fn forward_throughput<F>(
        &mut self,
        m_count: usize,
        mut sampler: F,
    ) -> Result<(f64, usize)>
    where
        F: FnMut(&mut Rng) -> (IntTensor, IntTensor),
    {
        let h = self.cm.hyper.clone();
        let p = h.stages;
        let last = p - 1;
        let bbytes = self.boundary_bytes();
        let mut costs = StepCosts {
            stages: p,
            microbatches: m_count,
            fwd: vec![vec![0.0; m_count]; p],
            bwd: vec![vec![0.0; m_count]; p],
            tx_fwd: vec![vec![Tx::default(); m_count]; p - 1],
            tx_bwd: vec![vec![Tx::default(); m_count]; p - 1],
            opt: vec![0.0; p],
            tail: 0.0,
        };
        let mut rng = self.rng.fork(0x1F);
        for mb in 0..m_count {
            let (tok, tgt) = sampler(&mut rng);
            let mut cur: Option<Tensor> = None;
            for s in 0..last {
                let (out, secs) = self.stage_fwd(s, &tok, cur.as_ref())?;
                costs.fwd[s][mb] = secs;
                let (ser, lat) = self.topo.links[s].sample(bbytes);
                costs.tx_fwd[s][mb] = Tx { ser, lat };
                cur = Some(out);
            }
            let mut args = self.params_of(last);
            if self.compressed() {
                args.extend(self.ctx_args(&tok));
            }
            args.push(Value::F32(cur.take().unwrap()));
            args.push(Value::I32(tgt));
            let key = self.key("last_eval");
            let (_, dt) = self.exec_timed(&key, &args)?;
            costs.fwd[last][mb] = stage_seconds(
                self.cfg.time_model,
                &h,
                last,
                Phase::Fwd,
                self.compressed(),
                Some(dt),
            );
        }
        let ms = gpipe_makespan(&costs);
        Ok((ms.total, m_count * h.b * h.n))
    }

    /// Max relative out-of-subspace leak across all constrained weights.
    pub fn subspace_leak(&self) -> f64 {
        self.stages
            .iter()
            .map(|s| s.subspace_leak(&self.global.u))
            .fold(0.0, f64::max)
    }
}
