//! GPipe pipeline schedule: simulated-makespan recurrence.
//!
//! The coordinator executes the real PJRT programs sequentially in this
//! process; *timing* of the distributed deployment is computed with an
//! event recurrence over (stage, microbatch) using per-event compute
//! costs (measured or analytic) and per-transfer netsim samples.
//!
//! Model: each stage is a serially-busy worker; each directed link
//! serializes its payload (bytes/bw) but propagation latency pipelines
//! (does not occupy the link). Backward of microbatch m at stage s starts
//! as soon as its gradient arrives and the stage is free — the 1F1B-style
//! refinement of GPipe that torch pipelining also applies. The last stage
//! fuses fwd+loss+bwd in one program (last_loss), as in the artifacts.

/// Per-transfer sample: (serialization seconds, propagation latency).
#[derive(Clone, Copy, Debug, Default)]
pub struct Tx {
    pub ser: f64,
    pub lat: f64,
}

/// All simulated costs of one optimizer step.
#[derive(Clone, Debug)]
pub struct StepCosts {
    pub stages: usize,
    pub microbatches: usize,
    /// fwd compute seconds; last stage entries hold the fused last_loss cost
    pub fwd: Vec<Vec<f64>>, // [stage][mb]
    /// bwd compute seconds for stages 0..P-1 (last stage unused)
    pub bwd: Vec<Vec<f64>>, // [stage][mb]
    /// activation transfer samples, link s (stage s → s+1)
    pub tx_fwd: Vec<Vec<Tx>>, // [link][mb]
    /// gradient transfer samples, link s (stage s+1 → s)
    pub tx_bwd: Vec<Vec<Tx>>, // [link][mb]
    /// per-stage optimizer seconds (after the last bwd on that stage)
    pub opt: Vec<f64>,
    /// extra serial seconds at the end (Grassmann step + U broadcast)
    pub tail: f64,
}

#[derive(Clone, Debug, Default)]
pub struct Makespan {
    pub total: f64,
    /// sum over links of serialization time (comm pressure diagnostic)
    pub comm_ser: f64,
    /// sum over all compute events
    pub compute: f64,
    /// time the critical path spent beyond pure compute (≈ stall + comm)
    pub overhead: f64,
}

/// Compute the simulated wall-clock of one step.
pub fn gpipe_makespan(c: &StepCosts) -> Makespan {
    let p = c.stages;
    let m = c.microbatches;
    assert!(p >= 2, "pipeline needs ≥ 2 stages");

    let mut stage_free = vec![0.0f64; p];
    let mut link_free_f = vec![0.0f64; p - 1];
    let mut link_free_b = vec![0.0f64; p - 1];
    // forward completion (last stage: fused fwd+bwd completion)
    let mut arrive_f = vec![vec![0.0f64; m]; p];
    let mut done_f = vec![vec![0.0f64; m]; p];

    // ---- forward wave (stage-major order matches GPipe fill) ----
    for mb in 0..m {
        for s in 0..p {
            let ready = if s == 0 { 0.0 } else { arrive_f[s][mb] };
            let start = ready.max(stage_free[s]);
            let done = start + c.fwd[s][mb];
            stage_free[s] = done;
            done_f[s][mb] = done;
            if s + 1 < p {
                let tx = c.tx_fwd[s][mb];
                let link_start = done.max(link_free_f[s]);
                link_free_f[s] = link_start + tx.ser;
                arrive_f[s + 1][mb] = link_start + tx.ser + tx.lat;
            }
        }
    }

    // ---- backward wave ----
    // gradient for mb leaves the last stage when its fused program ends
    let mut done_b = vec![vec![0.0f64; m]; p];
    let mut arrive_b = vec![vec![0.0f64; m]; p];
    for mb in 0..m {
        // transfer from last stage to p-2
        let tx = c.tx_bwd[p - 2][mb];
        let link_start = done_f[p - 1][mb].max(link_free_b[p - 2]);
        link_free_b[p - 2] = link_start + tx.ser;
        arrive_b[p - 2][mb] = link_start + tx.ser + tx.lat;
        for s in (0..p - 1).rev() {
            let start = arrive_b[s][mb].max(stage_free[s]);
            let done = start + c.bwd[s][mb];
            stage_free[s] = done;
            done_b[s][mb] = done;
            if s > 0 {
                let tx = c.tx_bwd[s - 1][mb];
                let link_start = done.max(link_free_b[s - 1]);
                link_free_b[s - 1] = link_start + tx.ser;
                arrive_b[s - 1][mb] = link_start + tx.ser + tx.lat;
            }
        }
    }

    // ---- optimizer flush ----
    let mut end = 0.0f64;
    for s in 0..p {
        let last_done = if s == p - 1 {
            done_f[s][m - 1]
        } else {
            done_b[s][m - 1]
        };
        end = end.max(last_done + c.opt[s]);
    }
    end += c.tail;

    // bwd[p-1] is never executed (the last stage fuses fwd+bwd into
    // last_loss, priced in fwd[p-1]) — exclude it from the accounting
    let compute: f64 = c
        .fwd
        .iter()
        .chain(c.bwd.iter().take(p - 1))
        .map(|v| v.iter().sum::<f64>())
        .sum::<f64>()
        + c.opt.iter().sum::<f64>();
    let comm_ser: f64 = c
        .tx_fwd
        .iter()
        .chain(c.tx_bwd.iter())
        .map(|v| v.iter().map(|t| t.ser).sum::<f64>())
        .sum();
    // per-stage serial compute lower bound
    let per_stage_max: f64 = (0..p)
        .map(|s| {
            let bwd = if s + 1 == p {
                0.0
            } else {
                c.bwd[s].iter().sum::<f64>()
            };
            c.fwd[s].iter().sum::<f64>() + bwd + c.opt[s]
        })
        .fold(0.0, f64::max);

    Makespan {
        total: end,
        comm_ser,
        compute,
        overhead: end - per_stage_max,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn costs(p: usize, m: usize, f: f64, b: f64, ser: f64, lat: f64) -> StepCosts {
        StepCosts {
            stages: p,
            microbatches: m,
            fwd: vec![vec![f; m]; p],
            bwd: vec![vec![b; m]; p],
            tx_fwd: vec![vec![Tx { ser, lat }; m]; p - 1],
            tx_bwd: vec![vec![Tx { ser, lat }; m]; p - 1],
            opt: vec![0.0; p],
            tail: 0.0,
        }
    }

    #[test]
    fn zero_comm_matches_gpipe_fill_drain() {
        // classic GPipe bound with negligible comm:
        // fwd fill = (P-1+M)·f on last stage, plus bwd drain
        let (p, m, f, b) = (4, 8, 1.0, 3.0);
        let ms = gpipe_makespan(&costs(p, m, f, b, 0.0, 0.0));
        // lower bound: last stage busy M·f after fill (P-1)·f,
        // then bwd wave (P-1 stages × b) + (M-1)·b on stage 0
        let lower = (p - 1) as f64 * f + m as f64 * f + (p - 1) as f64 * b;
        assert!(ms.total >= lower - 1e-9, "{} < {}", ms.total, lower);
        assert!(ms.total <= lower + m as f64 * b + 1e-9);
    }

    #[test]
    fn comm_bound_pipeline_dominated_by_link() {
        // serialization ≫ compute: steady state = M · ser on a link
        let (p, m) = (3, 16);
        let ms = gpipe_makespan(&costs(p, m, 0.001, 0.003, 1.0, 0.0));
        assert!(ms.total > m as f64 * 1.0, "{}", ms.total);
        // both directions serialize on (p-1) links, overlapped across links
        assert!(ms.total < 2.2 * m as f64 * 1.0 + 3.0, "{}", ms.total);
    }

    #[test]
    fn latency_pipelines_away() {
        // pure latency (no serialization) should add ≈ 2·(P−1)·lat once,
        // not per microbatch
        let (p, m, f, b) = (4, 32, 0.1, 0.3, );
        let no_lat = gpipe_makespan(&costs(p, m, f, b, 0.0, 0.0)).total;
        let with_lat = gpipe_makespan(&costs(p, m, f, b, 0.0, 0.5)).total;
        let added = with_lat - no_lat;
        assert!(added <= 2.0 * (p - 1) as f64 * 0.5 + 1e-6, "added {added}");
        assert!(added > 0.0);
    }

    #[test]
    fn more_microbatches_amortize_fill() {
        let (p, f, b) = (4, 1.0, 3.0);
        let t8 = gpipe_makespan(&costs(p, 8, f, b, 0.0, 0.0)).total / 8.0;
        let t32 = gpipe_makespan(&costs(p, 32, f, b, 0.0, 0.0)).total / 32.0;
        assert!(t32 < t8, "per-mb cost should shrink: {t32} vs {t8}");
    }

    #[test]
    fn overhead_metric_nonnegative() {
        let ms = gpipe_makespan(&costs(4, 8, 1.0, 3.0, 0.2, 0.01));
        assert!(ms.overhead >= -1e-9);
        assert!(ms.compute > 0.0);
        assert!(ms.comm_ser > 0.0);
    }

    #[test]
    fn optimizer_and_tail_extend_makespan() {
        let mut c = costs(3, 4, 1.0, 3.0, 0.0, 0.0);
        let base = gpipe_makespan(&c).total;
        c.opt = vec![5.0; 3];
        c.tail = 2.0;
        let with = gpipe_makespan(&c).total;
        assert!(with >= base + 5.0 + 2.0 - 1e-9);
    }
}
