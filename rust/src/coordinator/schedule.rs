//! GPipe pipeline schedule: simulated-makespan recurrence.
//!
//! The coordinator executes the real PJRT programs sequentially in this
//! process; *timing* of the distributed deployment is computed with an
//! event recurrence over (stage, microbatch) using per-event compute
//! costs (measured or analytic) and per-transfer netsim samples.
//!
//! Model: each stage is a serially-busy worker; each directed link
//! serializes its payload (bytes/bw) but propagation latency pipelines
//! (does not occupy the link). Backward of microbatch m at stage s starts
//! as soon as its gradient arrives and the stage is free — the 1F1B-style
//! refinement of GPipe that torch pipelining also applies. The last stage
//! fuses fwd+loss+bwd in one program (last_loss), as in the artifacts.

/// Per-transfer sample: (serialization seconds, propagation latency).
#[derive(Clone, Copy, Debug, Default)]
pub struct Tx {
    /// link-occupying serialization seconds (bytes / sampled bandwidth)
    pub ser: f64,
    /// propagation latency seconds (pipelines away, does not occupy link)
    pub lat: f64,
}

/// All simulated costs of one optimizer step.
#[derive(Clone, Debug)]
pub struct StepCosts {
    /// pipeline stage count P
    pub stages: usize,
    /// microbatches per optimizer step M
    pub microbatches: usize,
    /// fwd compute seconds; last stage entries hold the fused last_loss cost
    pub fwd: Vec<Vec<f64>>, // [stage][mb]
    /// bwd compute seconds for stages 0..P-1 (last stage unused)
    pub bwd: Vec<Vec<f64>>, // [stage][mb]
    /// activation transfer samples, link s (stage s → s+1)
    pub tx_fwd: Vec<Vec<Tx>>, // [link][mb]
    /// gradient transfer samples, link s (stage s+1 → s)
    pub tx_bwd: Vec<Vec<Tx>>, // [link][mb]
    /// per-stage optimizer seconds (after the last bwd on that stage)
    pub opt: Vec<f64>,
    /// extra serial seconds at the end (Grassmann step + U broadcast)
    pub tail: f64,
}

/// Timing summary of one simulated pipeline step.
#[derive(Clone, Debug, Default)]
pub struct Makespan {
    /// simulated wall-clock seconds of the whole step
    pub total: f64,
    /// sum over links of serialization time (comm pressure diagnostic)
    pub comm_ser: f64,
    /// sum over all compute events
    pub compute: f64,
    /// time the critical path spent beyond pure compute (≈ stall + comm)
    pub overhead: f64,
    /// per-stage instant at which the stage's *last* microbatch gradient
    /// is complete — the earliest point a cross-replica all-reduce of that
    /// stage's weight gradients could begin (data-parallel overlap model)
    pub grad_ready: Vec<f64>,
}

/// Compute the simulated wall-clock of one step.
pub fn gpipe_makespan(c: &StepCosts) -> Makespan {
    let p = c.stages;
    let m = c.microbatches;
    assert!(p >= 2, "pipeline needs ≥ 2 stages");

    let mut stage_free = vec![0.0f64; p];
    let mut link_free_f = vec![0.0f64; p - 1];
    let mut link_free_b = vec![0.0f64; p - 1];
    // forward completion (last stage: fused fwd+bwd completion)
    let mut arrive_f = vec![vec![0.0f64; m]; p];
    let mut done_f = vec![vec![0.0f64; m]; p];

    // ---- forward wave (stage-major order matches GPipe fill) ----
    for mb in 0..m {
        for s in 0..p {
            let ready = if s == 0 { 0.0 } else { arrive_f[s][mb] };
            let start = ready.max(stage_free[s]);
            let done = start + c.fwd[s][mb];
            stage_free[s] = done;
            done_f[s][mb] = done;
            if s + 1 < p {
                let tx = c.tx_fwd[s][mb];
                let link_start = done.max(link_free_f[s]);
                link_free_f[s] = link_start + tx.ser;
                arrive_f[s + 1][mb] = link_start + tx.ser + tx.lat;
            }
        }
    }

    // ---- backward wave ----
    // gradient for mb leaves the last stage when its fused program ends
    let mut done_b = vec![vec![0.0f64; m]; p];
    let mut arrive_b = vec![vec![0.0f64; m]; p];
    for mb in 0..m {
        // transfer from last stage to p-2
        let tx = c.tx_bwd[p - 2][mb];
        let link_start = done_f[p - 1][mb].max(link_free_b[p - 2]);
        link_free_b[p - 2] = link_start + tx.ser;
        arrive_b[p - 2][mb] = link_start + tx.ser + tx.lat;
        for s in (0..p - 1).rev() {
            let start = arrive_b[s][mb].max(stage_free[s]);
            let done = start + c.bwd[s][mb];
            stage_free[s] = done;
            done_b[s][mb] = done;
            if s > 0 {
                let tx = c.tx_bwd[s - 1][mb];
                let link_start = done.max(link_free_b[s - 1]);
                link_free_b[s - 1] = link_start + tx.ser;
                arrive_b[s - 1][mb] = link_start + tx.ser + tx.lat;
            }
        }
    }

    // ---- optimizer flush ----
    let mut end = 0.0f64;
    for s in 0..p {
        let last_done = if s == p - 1 {
            done_f[s][m - 1]
        } else {
            done_b[s][m - 1]
        };
        end = end.max(last_done + c.opt[s]);
    }
    end += c.tail;

    // bwd[p-1] is never executed (the last stage fuses fwd+bwd into
    // last_loss, priced in fwd[p-1]) — exclude it from the accounting
    let compute: f64 = c
        .fwd
        .iter()
        .chain(c.bwd.iter().take(p - 1))
        .map(|v| v.iter().sum::<f64>())
        .sum::<f64>()
        + c.opt.iter().sum::<f64>();
    let comm_ser: f64 = c
        .tx_fwd
        .iter()
        .chain(c.tx_bwd.iter())
        .map(|v| v.iter().map(|t| t.ser).sum::<f64>())
        .sum();
    // per-stage serial compute lower bound
    let per_stage_max: f64 = (0..p)
        .map(|s| {
            let bwd = if s + 1 == p {
                0.0
            } else {
                c.bwd[s].iter().sum::<f64>()
            };
            c.fwd[s].iter().sum::<f64>() + bwd + c.opt[s]
        })
        .fold(0.0, f64::max);

    // stage s's weight gradients are complete when its last microbatch's
    // backward (fused last_loss for the final stage) finishes
    let grad_ready: Vec<f64> = (0..p)
        .map(|s| {
            if s == p - 1 {
                done_f[s][m - 1]
            } else {
                done_b[s][m - 1]
            }
        })
        .collect();

    Makespan {
        total: end,
        comm_ser,
        compute,
        overhead: end - per_stage_max,
        grad_ready,
    }
}

// ---------------------------------------------------------------------------
// hybrid data-parallel × model-parallel step (replicated pipelines)
// ---------------------------------------------------------------------------

/// Timing summary of one hybrid step: R replicated pipelines plus the
/// cross-replica ring all-reduce of per-stage weight gradients.
#[derive(Clone, Debug, Default)]
pub struct HybridMakespan {
    /// simulated wall-clock seconds of the whole hybrid step
    pub total: f64,
    /// max over replicas of the pipeline makespan (compute + activation comm)
    pub compute_end: f64,
    /// instant the last per-stage gradient all-reduce completes
    pub comm_end: f64,
    /// non-overlapped all-reduce seconds appended after `compute_end`
    pub tail: f64,
    /// seconds the ring spent on gradient all-reduces: chunk
    /// serialization plus per-round propagation latency (unlike
    /// `Link::busy_s`, which counts serialization only)
    pub allreduce_busy: f64,
}

/// Combine R per-replica pipeline makespans with a ring all-reduce of the
/// per-stage weight-gradient payloads (`stage_bytes[s]`), overlapping the
/// all-reduce with the pipeline drain.
///
/// Model: the all-reduce of stage s can start once *every* replica has
/// finished stage s's last backward (`grad_ready[s]`, synchronous data
/// parallelism); stages share one ring, so their all-reduces serialize on
/// it in gradient-ready order. The step ends when both the slowest
/// pipeline and the last all-reduce are done:
/// `total = max(max_r total_r, comm_end)` — i.e. the ISSUE's
/// "max over replicas plus the overlapped all-reduce tail".
pub fn hybrid_makespan(
    replicas: &[Makespan],
    stage_bytes: &[usize],
    ring: &mut crate::netsim::ReplicaRing,
) -> HybridMakespan {
    assert!(!replicas.is_empty(), "hybrid step needs >= 1 replica");
    let compute_end = replicas.iter().map(|m| m.total).fold(0.0, f64::max);
    if ring.replicas() <= 1 || stage_bytes.is_empty() {
        return HybridMakespan {
            total: compute_end,
            compute_end,
            comm_end: 0.0,
            tail: 0.0,
            allreduce_busy: 0.0,
        };
    }
    // per-stage start = max over replicas of that stage's gradient-ready
    // instant (missing entries — e.g. hand-built Makespans — fall back to
    // 0.0, i.e. "ready immediately": optimistic, can only shorten the
    // modeled step)
    let stages = stage_bytes.len();
    let mut ready: Vec<(f64, usize)> = (0..stages)
        .map(|s| {
            let r = replicas
                .iter()
                .map(|m| m.grad_ready.get(s).copied().unwrap_or(0.0))
                .fold(0.0, f64::max);
            (r, s)
        })
        .collect();
    ready.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut ring_free = 0.0f64;
    let mut busy = 0.0f64;
    for (t_ready, s) in ready {
        let start = t_ready.max(ring_free);
        let dur = ring.all_reduce(stage_bytes[s]);
        busy += dur;
        ring_free = start + dur;
    }
    let comm_end = ring_free;
    let total = compute_end.max(comm_end);
    HybridMakespan {
        total,
        compute_end,
        comm_end,
        tail: total - compute_end,
        allreduce_busy: busy,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn costs(p: usize, m: usize, f: f64, b: f64, ser: f64, lat: f64) -> StepCosts {
        StepCosts {
            stages: p,
            microbatches: m,
            fwd: vec![vec![f; m]; p],
            bwd: vec![vec![b; m]; p],
            tx_fwd: vec![vec![Tx { ser, lat }; m]; p - 1],
            tx_bwd: vec![vec![Tx { ser, lat }; m]; p - 1],
            opt: vec![0.0; p],
            tail: 0.0,
        }
    }

    #[test]
    fn zero_comm_matches_gpipe_fill_drain() {
        // classic GPipe bound with negligible comm:
        // fwd fill = (P-1+M)·f on last stage, plus bwd drain
        let (p, m, f, b) = (4, 8, 1.0, 3.0);
        let ms = gpipe_makespan(&costs(p, m, f, b, 0.0, 0.0));
        // lower bound: last stage busy M·f after fill (P-1)·f,
        // then bwd wave (P-1 stages × b) + (M-1)·b on stage 0
        let lower = (p - 1) as f64 * f + m as f64 * f + (p - 1) as f64 * b;
        assert!(ms.total >= lower - 1e-9, "{} < {}", ms.total, lower);
        assert!(ms.total <= lower + m as f64 * b + 1e-9);
    }

    #[test]
    fn comm_bound_pipeline_dominated_by_link() {
        // serialization ≫ compute: steady state = M · ser on a link
        let (p, m) = (3, 16);
        let ms = gpipe_makespan(&costs(p, m, 0.001, 0.003, 1.0, 0.0));
        assert!(ms.total > m as f64 * 1.0, "{}", ms.total);
        // both directions serialize on (p-1) links, overlapped across links
        assert!(ms.total < 2.2 * m as f64 * 1.0 + 3.0, "{}", ms.total);
    }

    #[test]
    fn latency_pipelines_away() {
        // pure latency (no serialization) should add ≈ 2·(P−1)·lat once,
        // not per microbatch
        let (p, m, f, b) = (4, 32, 0.1, 0.3, );
        let no_lat = gpipe_makespan(&costs(p, m, f, b, 0.0, 0.0)).total;
        let with_lat = gpipe_makespan(&costs(p, m, f, b, 0.0, 0.5)).total;
        let added = with_lat - no_lat;
        assert!(added <= 2.0 * (p - 1) as f64 * 0.5 + 1e-6, "added {added}");
        assert!(added > 0.0);
    }

    #[test]
    fn more_microbatches_amortize_fill() {
        let (p, f, b) = (4, 1.0, 3.0);
        let t8 = gpipe_makespan(&costs(p, 8, f, b, 0.0, 0.0)).total / 8.0;
        let t32 = gpipe_makespan(&costs(p, 32, f, b, 0.0, 0.0)).total / 32.0;
        assert!(t32 < t8, "per-mb cost should shrink: {t32} vs {t8}");
    }

    #[test]
    fn overhead_metric_nonnegative() {
        let ms = gpipe_makespan(&costs(4, 8, 1.0, 3.0, 0.2, 0.01));
        assert!(ms.overhead >= -1e-9);
        assert!(ms.compute > 0.0);
        assert!(ms.comm_ser > 0.0);
    }

    #[test]
    fn optimizer_and_tail_extend_makespan() {
        let mut c = costs(3, 4, 1.0, 3.0, 0.0, 0.0);
        let base = gpipe_makespan(&c).total;
        c.opt = vec![5.0; 3];
        c.tail = 2.0;
        let with = gpipe_makespan(&c).total;
        assert!(with >= base + 5.0 + 2.0 - 1e-9);
    }

    #[test]
    fn grad_ready_within_step_and_ordered_sanely() {
        let ms = gpipe_makespan(&costs(4, 8, 1.0, 3.0, 0.1, 0.01));
        assert_eq!(ms.grad_ready.len(), 4);
        for &t in &ms.grad_ready {
            assert!(t > 0.0 && t <= ms.total);
        }
        // stage 0 drains last in GPipe: its gradients are the final ones
        let max = ms.grad_ready.iter().cloned().fold(0.0, f64::max);
        assert!((ms.grad_ready[0] - max).abs() < 1e-9);
    }

    fn quiet_ring(replicas: usize, mbps: f64) -> crate::netsim::ReplicaRing {
        use crate::netsim::{LinkSpec, ReplicaRing, MBPS};
        let mut rng = crate::rng::Rng::new(9);
        let spec = LinkSpec {
            bandwidth_bps: mbps * MBPS,
            latency_s: 0.0,
            jitter_frac: 0.0,
        };
        ReplicaRing::new(replicas, spec, &mut rng)
    }

    #[test]
    fn hybrid_single_replica_is_pipeline_makespan() {
        let ms = gpipe_makespan(&costs(3, 4, 1.0, 3.0, 0.0, 0.0));
        let total = ms.total;
        let mut ring = quiet_ring(1, 80.0);
        let h = hybrid_makespan(&[ms], &[1_000_000, 1_000_000, 1_000_000], &mut ring);
        assert_eq!(h.total, total);
        assert_eq!(h.tail, 0.0);
    }

    #[test]
    fn hybrid_tiny_payload_fully_overlaps() {
        let ms = gpipe_makespan(&costs(3, 8, 1.0, 3.0, 0.0, 0.0));
        let mut ring = quiet_ring(4, 1e6); // 1 Tbps: negligible comm
        let h = hybrid_makespan(&[ms.clone(), ms], &[100, 100, 100], &mut ring);
        assert!(h.tail < 1e-6, "tail {}", h.tail);
        assert!((h.total - h.compute_end).abs() < 1e-9);
    }

    #[test]
    fn hybrid_huge_payload_dominates() {
        let ms = gpipe_makespan(&costs(3, 4, 1e-3, 3e-3, 0.0, 0.0));
        let payload = 100_000_000usize; // 100 MB/stage over 80 Mbps
        let mut ring = quiet_ring(2, 80.0);
        let h = hybrid_makespan(
            &[ms.clone(), ms],
            &[payload, payload, payload],
            &mut ring,
        );
        // ring all-reduce moves 2·(R−1)/R · B per link; R=2 → B per link,
        // 3 stages × 100 MB × 8 bits / 80 Mbps = 30 s of serialization
        assert!(h.comm_end > 29.0, "comm_end {}", h.comm_end);
        assert!(h.tail > 28.0, "tail {}", h.tail);
        assert!((h.total - h.comm_end).abs() < 1e-9);
    }

    #[test]
    fn hybrid_monotone_in_payload() {
        let ms = gpipe_makespan(&costs(4, 8, 1.0, 3.0, 0.05, 0.01));
        let reps = vec![ms.clone(), ms.clone(), ms];
        let t_small = hybrid_makespan(
            &reps.clone(),
            &[10_000; 4],
            &mut quiet_ring(3, 80.0),
        )
        .total;
        let t_big = hybrid_makespan(
            &reps,
            &[10_000_000; 4],
            &mut quiet_ring(3, 80.0),
        )
        .total;
        assert!(t_big >= t_small, "{t_big} < {t_small}");
    }
}
