//! Lock-cheap span tracing to Chrome `trace_event` JSON.
//!
//! The recorder is process-global but *session-scoped*: nothing is
//! recorded until a [`TraceSession`] starts, and the fast path while
//! disabled is a single relaxed atomic load (call sites additionally
//! guard their argument construction behind [`enabled`], so a build
//! without an active session pays no formatting or allocation — loss
//! curves stay bitwise identical with tracing off *and* on, because
//! tracing never touches model arithmetic).
//!
//! Events land in a per-thread buffer and are flushed into a global
//! sink under a mutex only every [`FLUSH_AT`] events or at thread
//! exit, so concurrent stage workers never contend per-span.
//!
//! Tracks are **logical**, not OS threads: `pid` is the replica index
//! and `tid` the pipeline-stage index ([`set_track`]), so a trace is
//! stable across pool widths and thread scheduling. The discrete-event
//! simulator emits the same schema from its virtual clock via
//! [`span_at`]/[`instant_at`]; [`Trace::clock`] records which domain
//! stamped the file.

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::json::Json;

/// Flush the thread-local buffer into the global sink at this size.
const FLUSH_AT: usize = 1024;

/// One typed span/instant argument value.
#[derive(Clone, Debug, PartialEq)]
pub enum Arg {
    /// Unsigned integer (counts, byte sizes, step/microbatch indices).
    U(u64),
    /// Float (seconds, ratios).
    F(f64),
    /// Short label (codec name, peer address).
    S(String),
}

/// Shorthand: an unsigned-integer argument pair.
pub fn u(key: &str, v: u64) -> (String, Arg) {
    (key.to_string(), Arg::U(v))
}

/// Shorthand: a float argument pair.
pub fn f(key: &str, v: f64) -> (String, Arg) {
    (key.to_string(), Arg::F(v))
}

/// Shorthand: a string argument pair.
pub fn s(key: &str, v: &str) -> (String, Arg) {
    (key.to_string(), Arg::S(v.to_string()))
}

/// Clock domain that stamped a trace: real runs use the host monotonic
/// clock, the event simulator stamps spans from simulated time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Clock {
    /// Host monotonic time (microseconds since session start).
    Host,
    /// Simulated time from the discrete-event engine.
    Virtual,
}

impl Clock {
    /// Stable lowercase name used in the JSON `otherData.clock` field.
    pub fn as_str(self) -> &'static str {
        match self {
            Clock::Host => "host",
            Clock::Virtual => "virtual",
        }
    }

    /// Inverse of [`Clock::as_str`].
    pub fn parse(s: &str) -> Option<Clock> {
        match s {
            "host" => Some(Clock::Host),
            "virtual" => Some(Clock::Virtual),
            _ => None,
        }
    }
}

/// One recorded event: a complete span (`ph:"X"`) or an instant
/// (`ph:"i"`). Timestamps/durations are microseconds in the trace's
/// [`Clock`] domain; `pid`/`tid` are the logical replica/stage track.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// Category (`compute`, `codec`, `frame`, `reduce`, `ckpt`,
    /// `elastic`, `sim`, ...).
    pub cat: String,
    /// Event name (`fwd`, `send:grad-ring`, ...).
    pub name: String,
    /// Logical process track: replica index.
    pub pid: u32,
    /// Logical thread track: pipeline-stage index.
    pub tid: u32,
    /// Start timestamp in microseconds.
    pub ts_us: f64,
    /// Duration in microseconds (0 for instants).
    pub dur_us: f64,
    /// True for instant events.
    pub instant: bool,
    /// Typed arguments. Never timing — only `ts_us`/`dur_us` carry
    /// clock values, which keeps the canonical span form (see
    /// [`Trace::canonical_lines`]) identical across pool widths.
    pub args: Vec<(String, Arg)>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);

fn sink() -> &'static Mutex<Vec<TraceEvent>> {
    static SINK: OnceLock<Mutex<Vec<TraceEvent>>> = OnceLock::new();
    SINK.get_or_init(|| Mutex::new(Vec::new()))
}

fn session_lock() -> &'static Mutex<()> {
    static SESSION: OnceLock<Mutex<()>> = OnceLock::new();
    SESSION.get_or_init(|| Mutex::new(()))
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

struct Buf {
    events: Vec<TraceEvent>,
}

impl Drop for Buf {
    fn drop(&mut self) {
        flush_into_sink(&mut self.events);
    }
}

thread_local! {
    static TRACK: Cell<(u32, u32)> = const { Cell::new((0, 0)) };
    static BUF: RefCell<Buf> = RefCell::new(Buf { events: Vec::new() });
}

fn flush_into_sink(events: &mut Vec<TraceEvent>) {
    if events.is_empty() {
        return;
    }
    let mut sink =
        sink().lock().unwrap_or_else(|poison| poison.into_inner());
    sink.append(events);
}

fn push(ev: TraceEvent) {
    BUF.with(|b| {
        let mut b = b.borrow_mut();
        b.events.push(ev);
        if b.events.len() >= FLUSH_AT {
            flush_into_sink(&mut b.events);
        }
    });
}

/// True while a [`TraceSession`] is recording. Call sites wrap any
/// argument construction in this check so a disabled build pays one
/// relaxed atomic load per site and nothing else.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Bind the current OS thread to a logical (replica, stage) track.
/// Subsequent [`end`]/[`instant`] events record onto it. Stage workers
/// call this once at startup; the single-process pipeline switches the
/// stage id as it walks its stages.
pub fn set_track(pid: u32, tid: u32) {
    TRACK.with(|t| t.set((pid, tid)));
}

/// Start a span: returns the host timestamp (µs) to hand back to
/// [`end`], or NaN when tracing is disabled (in which case `end`
/// drops the span even if a session started in between).
#[inline]
pub fn begin() -> f64 {
    if !enabled() {
        return f64::NAN;
    }
    epoch().elapsed().as_secs_f64() * 1e6
}

/// Finish a span started by [`begin`] on the current track.
pub fn end(cat: &str, name: &str, t0_us: f64, args: Vec<(String, Arg)>) {
    if !enabled() || t0_us.is_nan() {
        return;
    }
    let now = epoch().elapsed().as_secs_f64() * 1e6;
    let (pid, tid) = TRACK.with(|t| t.get());
    push(TraceEvent {
        cat: cat.to_string(),
        name: name.to_string(),
        pid,
        tid,
        ts_us: t0_us,
        dur_us: (now - t0_us).max(0.0),
        instant: false,
        args,
    });
}

/// Record an instant event on the current track at the host clock.
pub fn instant(cat: &str, name: &str, args: Vec<(String, Arg)>) {
    if !enabled() {
        return;
    }
    let now = epoch().elapsed().as_secs_f64() * 1e6;
    let (pid, tid) = TRACK.with(|t| t.get());
    push(TraceEvent {
        cat: cat.to_string(),
        name: name.to_string(),
        pid,
        tid,
        ts_us: now,
        dur_us: 0.0,
        instant: true,
        args,
    });
}

/// Record a complete span with explicit track and timestamps — the
/// virtual-clock entry point used by the event simulator (times in
/// microseconds of simulated time).
pub fn span_at(
    cat: &str,
    name: &str,
    pid: u32,
    tid: u32,
    ts_us: f64,
    dur_us: f64,
    args: Vec<(String, Arg)>,
) {
    if !enabled() {
        return;
    }
    push(TraceEvent {
        cat: cat.to_string(),
        name: name.to_string(),
        pid,
        tid,
        ts_us,
        dur_us: dur_us.max(0.0),
        instant: false,
        args,
    });
}

/// Record an instant with explicit track and timestamp (virtual-clock
/// companion of [`instant`]).
pub fn instant_at(
    cat: &str,
    name: &str,
    pid: u32,
    tid: u32,
    ts_us: f64,
    args: Vec<(String, Arg)>,
) {
    if !enabled() {
        return;
    }
    push(TraceEvent {
        cat: cat.to_string(),
        name: name.to_string(),
        pid,
        tid,
        ts_us,
        dur_us: 0.0,
        instant: true,
        args,
    });
}

/// An active recording session. Holds a process-wide lock so
/// concurrent tests serialize instead of cross-polluting; recording is
/// enabled for its lifetime and disabled on [`TraceSession::stop`] (or
/// drop). All recording threads must be joined before `stop` — the
/// repo's transports and grids join their workers, so this holds by
/// construction.
pub struct TraceSession {
    _guard: MutexGuard<'static, ()>,
    clock: Clock,
}

impl TraceSession {
    /// Begin recording in the given clock domain, clearing any stale
    /// buffered events from a previous session.
    pub fn start(clock: Clock) -> TraceSession {
        let guard = session_lock()
            .lock()
            .unwrap_or_else(|poison| poison.into_inner());
        sink()
            .lock()
            .unwrap_or_else(|poison| poison.into_inner())
            .clear();
        BUF.with(|b| b.borrow_mut().events.clear());
        epoch(); // pin the epoch before the first span
        ENABLED.store(true, Ordering::SeqCst);
        TraceSession { _guard: guard, clock }
    }

    /// Stop recording and collect the trace. Host-clock timestamps are
    /// normalized so the earliest event starts at 0; events are sorted
    /// by (ts, pid, tid, name) for a stable file layout.
    pub fn stop(self) -> Trace {
        ENABLED.store(false, Ordering::SeqCst);
        BUF.with(|b| flush_into_sink(&mut b.borrow_mut().events));
        let mut events = std::mem::take(
            &mut *sink()
                .lock()
                .unwrap_or_else(|poison| poison.into_inner()),
        );
        if self.clock == Clock::Host && !events.is_empty() {
            let min = events
                .iter()
                .map(|e| e.ts_us)
                .fold(f64::INFINITY, f64::min);
            for e in &mut events {
                e.ts_us -= min;
            }
        }
        events.sort_by(|a, b| {
            a.ts_us
                .total_cmp(&b.ts_us)
                .then(a.pid.cmp(&b.pid))
                .then(a.tid.cmp(&b.tid))
                .then(a.name.cmp(&b.name))
        });
        Trace { events, clock: self.clock }
    }
}

impl Drop for TraceSession {
    fn drop(&mut self) {
        ENABLED.store(false, Ordering::SeqCst);
    }
}

/// A completed recording: the event list plus its clock domain.
#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    /// All recorded events.
    pub events: Vec<TraceEvent>,
    /// Which clock stamped `ts_us`/`dur_us`.
    pub clock: Clock,
}

fn arg_to_json(a: &Arg) -> Json {
    match a {
        Arg::U(v) => Json::Num(*v as f64),
        Arg::F(v) => Json::Num(*v),
        Arg::S(v) => Json::Str(v.clone()),
    }
}

fn arg_from_json(j: &Json) -> Result<Arg> {
    match j {
        Json::Num(n) => {
            if n.fract() == 0.0 && *n >= 0.0 && *n < 1e15 {
                Ok(Arg::U(*n as u64))
            } else {
                Ok(Arg::F(*n))
            }
        }
        Json::Str(s) => Ok(Arg::S(s.clone())),
        other => bail!("trace arg is neither number nor string: {other:?}"),
    }
}

impl Trace {
    /// Serialize to the Chrome `trace_event` JSON object format:
    /// `{"traceEvents": [...], "displayTimeUnit": "ms", "otherData":
    /// {"clock": ...}}` — loadable by perfetto / `chrome://tracing`.
    pub fn to_json(&self) -> Json {
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|e| {
                let mut o = BTreeMap::new();
                o.insert("cat".to_string(), Json::Str(e.cat.clone()));
                o.insert("name".to_string(), Json::Str(e.name.clone()));
                o.insert("pid".to_string(), Json::Num(e.pid as f64));
                o.insert("tid".to_string(), Json::Num(e.tid as f64));
                o.insert("ts".to_string(), Json::Num(e.ts_us));
                if e.instant {
                    o.insert("ph".to_string(), Json::Str("i".to_string()));
                    o.insert("s".to_string(), Json::Str("t".to_string()));
                } else {
                    o.insert("ph".to_string(), Json::Str("X".to_string()));
                    o.insert("dur".to_string(), Json::Num(e.dur_us));
                }
                if !e.args.is_empty() {
                    let args: BTreeMap<String, Json> = e
                        .args
                        .iter()
                        .map(|(k, v)| (k.clone(), arg_to_json(v)))
                        .collect();
                    o.insert("args".to_string(), Json::Obj(args));
                }
                Json::Obj(o)
            })
            .collect();
        let mut other = BTreeMap::new();
        other.insert(
            "clock".to_string(),
            Json::Str(self.clock.as_str().to_string()),
        );
        let mut top = BTreeMap::new();
        top.insert("traceEvents".to_string(), Json::Arr(events));
        top.insert(
            "displayTimeUnit".to_string(),
            Json::Str("ms".to_string()),
        );
        top.insert("otherData".to_string(), Json::Obj(other));
        Json::Obj(top)
    }

    /// Rebuild a trace from [`Trace::to_json`] output. Integral
    /// non-negative numeric args parse back as [`Arg::U`] (the
    /// canonical form); unknown `ph` kinds are rejected.
    pub fn from_json(j: &Json) -> Result<Trace> {
        let clock = match j.opt("otherData").and_then(|o| o.opt("clock")) {
            Some(Json::Str(s)) => Clock::parse(s)
                .ok_or_else(|| anyhow::anyhow!("bad trace clock {s:?}"))?,
            _ => Clock::Host,
        };
        let raw = j
            .opt("traceEvents")
            .ok_or_else(|| anyhow::anyhow!("trace JSON lacks traceEvents"))?
            .arr()?;
        let mut events = Vec::with_capacity(raw.len());
        for ev in raw {
            let ph = ev.get("ph")?.str()?;
            let instant = match ph {
                "X" => false,
                "i" => true,
                other => bail!("unsupported trace event ph {other:?}"),
            };
            let num = |key: &str| -> Result<f64> { ev.get(key)?.num() };
            let mut args = Vec::new();
            if let Some(Json::Obj(o)) = ev.opt("args") {
                for (k, v) in o {
                    args.push((k.clone(), arg_from_json(v)?));
                }
            }
            events.push(TraceEvent {
                cat: ev
                    .opt("cat")
                    .and_then(|c| c.str().ok())
                    .unwrap_or_default()
                    .to_string(),
                name: ev.get("name")?.str()?.to_string(),
                pid: num("pid")? as u32,
                tid: num("tid")? as u32,
                ts_us: num("ts")?,
                dur_us: if instant { 0.0 } else { num("dur")? },
                instant,
                args,
            });
        }
        Ok(Trace { events, clock })
    }

    /// Parse a trace from its JSON text.
    pub fn parse(text: &str) -> Result<Trace> {
        Trace::from_json(&Json::parse(text)?)
    }

    /// Write the JSON to `path` (creating parent directories).
    pub fn write_file(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).with_context(|| {
                    format!("creating {}", parent.display())
                })?;
            }
        }
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing trace {}", path.display()))
    }

    /// Load and parse a trace file.
    pub fn read_file(path: &Path) -> Result<Trace> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {}", path.display()))?;
        Trace::parse(&text)
    }

    /// The *timing-free* canonical form: one sorted line per event
    /// (`cat|name|pid|tid|i?|k=v,...`, args sorted by key, `ts`/`dur`
    /// excluded). Two runs of the same workload must produce identical
    /// canonical multisets regardless of pool width or scheduling —
    /// the trace-determinism contract tested in `tests/obs.rs`.
    pub fn canonical_lines(&self) -> Vec<String> {
        let mut lines: Vec<String> = self
            .events
            .iter()
            .map(|e| {
                let mut args: Vec<String> = e
                    .args
                    .iter()
                    .map(|(k, v)| match v {
                        Arg::U(n) => format!("{k}={n}"),
                        Arg::F(x) => format!("{k}={x}"),
                        Arg::S(s) => format!("{k}={s}"),
                    })
                    .collect();
                args.sort();
                format!(
                    "{}|{}|{}|{}|{}|{}",
                    e.cat,
                    e.name,
                    e.pid,
                    e.tid,
                    if e.instant { "i" } else { "x" },
                    args.join(",")
                )
            })
            .collect();
        lines.sort();
        lines
    }

    /// Human summary: per (cat, name) the event count, total duration,
    /// and summed `bytes` arg — what `protomodels trace <file>` prints.
    pub fn summary(&self) -> String {
        #[derive(Default)]
        struct Agg {
            count: u64,
            dur_us: f64,
            bytes: u64,
        }
        let mut by_name: BTreeMap<(String, String), Agg> = BTreeMap::new();
        for e in &self.events {
            let a = by_name
                .entry((e.cat.clone(), e.name.clone()))
                .or_default();
            a.count += 1;
            a.dur_us += e.dur_us;
            for (k, v) in &e.args {
                if k == "bytes" {
                    if let Arg::U(n) = v {
                        a.bytes += n;
                    }
                }
            }
        }
        let mut s = format!(
            "trace: {} events, clock {}\n{:<28} {:>8} {:>12} {:>12}\n",
            self.events.len(),
            self.clock.as_str(),
            "cat/name",
            "count",
            "total_ms",
            "bytes"
        );
        for ((cat, name), a) in &by_name {
            s.push_str(&format!(
                "{:<28} {:>8} {:>12.3} {:>12}\n",
                format!("{cat}/{name}"),
                a.count,
                a.dur_us / 1e3,
                a.bytes
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recording_is_dropped() {
        assert!(!enabled());
        let t0 = begin();
        assert!(t0.is_nan());
        end("compute", "fwd", t0, vec![]);
        instant("x", "y", vec![]);
        let sess = TraceSession::start(Clock::Host);
        let trace = sess.stop();
        assert!(trace.events.is_empty());
    }

    #[test]
    fn session_records_and_round_trips() {
        let sess = TraceSession::start(Clock::Host);
        set_track(1, 2);
        let t0 = begin();
        end(
            "frame",
            "send:fwd",
            t0,
            vec![u("bytes", 128), f("ratio", 0.5), s("codec", "subspace")],
        );
        instant("elastic", "reassign", vec![u("stage", 1)]);
        span_at("sim", "pipeline", 3, 0, 10.0, 25.5, vec![u("step", 2)]);
        let trace = sess.stop();
        assert_eq!(trace.events.len(), 3);
        let text = trace.to_json().to_string();
        let back = Trace::parse(&text).expect("parse");
        assert_eq!(back, trace);
        assert_eq!(back.canonical_lines(), trace.canonical_lines());
    }

    #[test]
    fn host_timestamps_normalize_to_zero() {
        let sess = TraceSession::start(Clock::Host);
        let t0 = begin();
        std::thread::sleep(std::time::Duration::from_millis(1));
        end("compute", "fwd", t0, vec![]);
        let trace = sess.stop();
        assert_eq!(trace.events[0].ts_us, 0.0);
        assert!(trace.events[0].dur_us > 0.0);
    }

    #[test]
    fn virtual_clock_keeps_absolute_times() {
        let sess = TraceSession::start(Clock::Virtual);
        span_at("sim", "step", 0, 0, 5e6, 1e6, vec![]);
        let trace = sess.stop();
        assert_eq!(trace.clock, Clock::Virtual);
        assert_eq!(trace.events[0].ts_us, 5e6);
    }
}
