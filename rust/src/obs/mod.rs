//! Observability: span tracing, unified run metrics, trace-vs-sim diff.
//!
//! Zero-dependency instrumentation layer (DESIGN.md §15) threaded
//! through the native pipeline, the transports, the elastic runtime,
//! and the event simulator:
//!
//! - [`trace`]: a lock-cheap per-thread span recorder emitting Chrome
//!   `trace_event` JSON (perfetto-loadable). Real runs stamp spans from
//!   a host monotonic clock; the discrete-event simulator records the
//!   *same schema* from its virtual clock, so both open in the same
//!   viewer and feed the same comparator.
//! - [`counters`]: the unified [`counters::RunMetrics`] registry —
//!   monotonic counters, gauges, and fixed-bucket histograms with
//!   deterministic snapshot ordering, dumped as `METRICS.json`.
//! - [`diff`]: replays a recorded trace's per-(stage, microbatch)
//!   compute spans against the §9 event engine's predicted timeline
//!   and reports per-span relative error (`exp trace-diff`).
//!
//! The module also owns the leveled [`log!`](crate::obs::log) macro
//! that replaces raw `eprintln!` diagnostics: filtering is driven by
//! the `PROTOMODELS_LOG` environment variable (`error`, `warn`,
//! `info`, `debug`; unset = fully off, so test CSV byte-identity is
//! untouched).

pub mod counters;
pub mod diff;
pub mod trace;

use std::sync::atomic::{AtomicU8, Ordering};

/// Severity of an [`obs::log!`](crate::obs::log) line, ordered from
/// most to least urgent.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Unrecoverable problems that abort or degrade the run.
    Error = 1,
    /// Recoverable anomalies (fault recovery, reassignment).
    Warn = 2,
    /// Progress landmarks (epoch start, neighbor connect).
    Info = 3,
    /// High-volume diagnostics.
    Debug = 4,
}

impl Level {
    /// Short lowercase tag used as the line prefix.
    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    fn parse(s: &str) -> u8 {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" | "1" => 1,
            "warn" | "warning" | "2" => 2,
            "info" | "3" => 3,
            "debug" | "trace" | "4" => 4,
            // unrecognized values (including "off"/"0") disable logging
            _ => 0,
        }
    }
}

/// Cached max enabled level: 0xFF = not yet read from the environment,
/// 0 = logging fully off, 1..=4 = [`Level`] discriminants.
static LEVEL: AtomicU8 = AtomicU8::new(0xFF);

/// True when a [`log!`](crate::obs::log) line at `level` should print,
/// per the `PROTOMODELS_LOG` environment variable (read once and
/// cached; unset means fully off).
pub fn log_enabled(level: Level) -> bool {
    let mut cur = LEVEL.load(Ordering::Relaxed);
    if cur == 0xFF {
        cur = std::env::var("PROTOMODELS_LOG")
            .map(|v| Level::parse(&v))
            .unwrap_or(0);
        LEVEL.store(cur, Ordering::Relaxed);
    }
    level as u8 <= cur
}

/// Override the cached log level (`None` = off). Tests use this to
/// exercise the macro without touching process environment.
pub fn set_log_level(level: Option<Level>) {
    LEVEL.store(level.map(|l| l as u8).unwrap_or(0), Ordering::Relaxed);
}

/// Leveled diagnostic logging: `obs::log!(Warn, "stage {s} lost")`.
///
/// Lines print to stderr as `[<tag>] <message>` only when
/// `PROTOMODELS_LOG` enables the level (see [`Level`] and
/// [`log_enabled`]); with the variable unset the macro is a cheap
/// atomic load and no formatting happens. This is the replacement for
/// raw `eprintln!` progress/diagnostic lines in `transport/` and
/// `nn/pipeline.rs`.
#[macro_export]
macro_rules! obs_log {
    ($lvl:ident, $($arg:tt)*) => {{
        if $crate::obs::log_enabled($crate::obs::Level::$lvl) {
            eprintln!(
                "[{}] {}",
                $crate::obs::Level::$lvl.tag(),
                format_args!($($arg)*)
            );
        }
    }};
}

pub use crate::obs_log as log;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_accepts_names_and_numbers() {
        assert_eq!(Level::parse("error"), 1);
        assert_eq!(Level::parse("WARN"), 2);
        assert_eq!(Level::parse("info"), 3);
        assert_eq!(Level::parse("debug"), 4);
        assert_eq!(Level::parse("4"), 4);
        assert_eq!(Level::parse("off"), 0);
        assert_eq!(Level::parse("garbage"), 0);
    }

    #[test]
    fn log_enabled_respects_override() {
        set_log_level(Some(Level::Warn));
        assert!(log_enabled(Level::Error));
        assert!(log_enabled(Level::Warn));
        assert!(!log_enabled(Level::Info));
        set_log_level(None);
        assert!(!log_enabled(Level::Error));
    }
}
