//! Trace-vs-simulator comparator: replay a recorded trace against the
//! §9 event engine's predicted timeline (DESIGN.md §15).
//!
//! The repo's measured-vs-predicted discipline compares step *walls*;
//! this module compares *placements*. For every training step in a
//! recorded trace it rebuilds a [`StepCosts`] whose compute and
//! transfer durations are the trace's own span durations, asks the
//! event engine where each (stage, microbatch, class) task *should*
//! have landed given those durations, and reports the per-span
//! relative placement error
//! `max(|Δstart|, |Δend|) / predicted_makespan` — i.e. how far the
//! real pipeline's dispatch order and overlap drift from the
//! simulator's model once per-task costs are equalized. `exp
//! trace-diff` turns this into a figure CSV; the CI `obs-smoke` job
//! asserts the error stays under a generous ceiling.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::coordinator::schedule::{StepCosts, Tx};
use crate::obs::trace::{Arg, Trace, TraceEvent};
use crate::sim::step::{
    simulate_step_timeline, Class, Schedule, StepSpec,
};

/// One compared task: where the trace measured it vs where the event
/// engine predicted it, both in seconds relative to the step's first
/// compute dispatch.
#[derive(Clone, Debug)]
pub struct DiffRow {
    /// training step the task belongs to
    pub step: u64,
    /// pipeline stage (trace `tid`)
    pub stage: usize,
    /// microbatch index
    pub mb: usize,
    /// task class label: `fwd`, `fused`, or `bwd`
    pub class: &'static str,
    /// measured start, seconds from the step's first compute dispatch
    pub measured_start_s: f64,
    /// measured end
    pub measured_end_s: f64,
    /// predicted start (event engine, same per-task durations)
    pub predicted_start_s: f64,
    /// predicted end
    pub predicted_end_s: f64,
    /// `max(|Δstart|, |Δend|) / predicted_makespan`
    pub rel_err: f64,
}

/// Comparator output: all compared rows plus the error aggregates.
#[derive(Clone, Debug, Default)]
pub struct DiffReport {
    /// every compared (step, stage, mb, class) placement
    pub rows: Vec<DiffRow>,
    /// steps successfully compared
    pub steps: usize,
    /// steps skipped because their span set was incomplete (e.g. a
    /// partial trailing step in a truncated trace)
    pub skipped_steps: usize,
    /// worst per-span relative error across all rows
    pub max_rel_err: f64,
    /// mean per-span relative error
    pub mean_rel_err: f64,
}

impl DiffReport {
    /// Short human summary for CLI output.
    pub fn summary(&self) -> String {
        format!(
            "trace-diff: {} spans over {} steps ({} skipped), \
             rel err max {:.4} mean {:.4}",
            self.rows.len(),
            self.steps,
            self.skipped_steps,
            self.max_rel_err,
            self.mean_rel_err
        )
    }
}

fn arg_u(e: &TraceEvent, key: &str) -> Option<u64> {
    e.args.iter().find_map(|(k, v)| match v {
        Arg::U(n) if k == key => Some(*n),
        _ => None,
    })
}

fn class_of(name: &str) -> Option<(&'static str, Class)> {
    match name {
        "fwd" => Some(("fwd", Class::Fwd)),
        "fused" => Some(("fused", Class::Fwd)),
        "bwd" => Some(("bwd", Class::Bwd)),
        _ => None,
    }
}

/// Compare a recorded trace's compute-span placements against the
/// event engine under `schedule`. Groups `compute`-category spans by
/// (replica, step), rebuilds each step's [`StepCosts`] from the spans'
/// own durations (frame-send span durations become the link
/// serialization costs), and reports per-span relative placement
/// error. Steps whose span set is incomplete are skipped, not errors.
pub fn diff_trace(
    trace: &Trace,
    schedule: Schedule,
) -> Result<DiffReport> {
    // (pid, step) -> compute spans; same key -> frame-send spans
    let mut compute: BTreeMap<(u32, u64), Vec<&TraceEvent>> =
        BTreeMap::new();
    let mut sends: BTreeMap<(u32, u64), Vec<&TraceEvent>> =
        BTreeMap::new();
    for e in &trace.events {
        if e.instant {
            continue;
        }
        let step = match (arg_u(e, "step"), arg_u(e, "mb")) {
            (Some(s), Some(_)) => s,
            _ => continue,
        };
        if e.cat == "compute" && class_of(&e.name).is_some() {
            compute.entry((e.pid, step)).or_default().push(e);
        } else if e.cat == "frame"
            && (e.name == "send:fwd" || e.name == "send:bwd")
        {
            sends.entry((e.pid, step)).or_default().push(e);
        }
    }
    if compute.is_empty() {
        bail!(
            "trace holds no compute spans with step/mb args — was it \
             recorded from a training run?"
        );
    }

    let mut report = DiffReport::default();
    let mut err_sum = 0.0f64;
    for ((pid, step), spans) in &compute {
        let stages = spans.iter().map(|e| e.tid as usize).max().unwrap() + 1;
        let m = spans
            .iter()
            .filter_map(|e| arg_u(e, "mb"))
            .max()
            .unwrap_or(0) as usize
            + 1;
        if stages < 2 {
            report.skipped_steps += 1;
            continue;
        }
        // rebuild the step's costs from the measured durations
        let mut fwd = vec![vec![f64::NAN; m]; stages];
        let mut bwd = vec![vec![f64::NAN; m]; stages];
        // fused last stage: its gradient cost lives in fwd[last]
        for x in bwd[stages - 1].iter_mut() {
            *x = 0.0;
        }
        for e in spans {
            let v = e.tid as usize;
            let mb = arg_u(e, "mb").unwrap() as usize;
            let dur_s = e.dur_us / 1e6;
            match class_of(&e.name) {
                Some((_, Class::Fwd)) => fwd[v][mb] = dur_s,
                Some((_, Class::Bwd)) => bwd[v][mb] = dur_s,
                None => {}
            }
        }
        let mut tx_fwd = vec![vec![Tx { ser: 0.0, lat: 0.0 }; m]; stages - 1];
        let mut tx_bwd = vec![vec![Tx { ser: 0.0, lat: 0.0 }; m]; stages - 1];
        for e in sends.get(&(*pid, *step)).map_or(&[][..], |v| &v[..]) {
            let v = e.tid as usize;
            let mb = match arg_u(e, "mb") {
                Some(mb) => mb as usize,
                None => continue,
            };
            if mb >= m {
                continue;
            }
            let ser = e.dur_us / 1e6;
            if e.name == "send:fwd" && v < stages - 1 {
                tx_fwd[v][mb] = Tx { ser, lat: 0.0 };
            } else if e.name == "send:bwd" && v > 0 && v - 1 < stages - 1 {
                tx_bwd[v - 1][mb] = Tx { ser, lat: 0.0 };
            }
        }
        if fwd.iter().flatten().chain(bwd.iter().flatten()).any(|x| x.is_nan())
        {
            report.skipped_steps += 1;
            continue;
        }
        let costs = StepCosts {
            stages,
            microbatches: m,
            fwd,
            bwd,
            tx_fwd,
            tx_bwd,
            opt: vec![0.0; stages],
            tail: 0.0,
        };
        let spec = StepSpec::from_costs(&costs, schedule)?;
        let (ms, timeline) = simulate_step_timeline(&spec)?;
        let predicted: BTreeMap<(usize, usize, Class), (f64, f64)> =
            timeline
                .iter()
                .map(|t| ((t.v, t.mb, t.class), (t.start, t.end)))
                .collect();
        let base = spans
            .iter()
            .map(|e| e.ts_us)
            .fold(f64::INFINITY, f64::min);
        let scale = if ms.total > 0.0 { ms.total } else { 1.0 };
        for e in spans {
            let (label, class) = class_of(&e.name).unwrap();
            let v = e.tid as usize;
            let mb = arg_u(e, "mb").unwrap() as usize;
            let (ps, pe) = match predicted.get(&(v, mb, class)) {
                Some(p) => *p,
                None => continue,
            };
            let ms_start = (e.ts_us - base) / 1e6;
            let ms_end = (e.ts_us + e.dur_us - base) / 1e6;
            let rel = ((ms_start - ps).abs().max((ms_end - pe).abs()))
                / scale;
            err_sum += rel;
            report.max_rel_err = report.max_rel_err.max(rel);
            report.rows.push(DiffRow {
                step: *step,
                stage: v,
                mb,
                class: label,
                measured_start_s: ms_start,
                measured_end_s: ms_end,
                predicted_start_s: ps,
                predicted_end_s: pe,
                rel_err: rel,
            });
        }
        report.steps += 1;
    }
    if !report.rows.is_empty() {
        report.mean_rel_err = err_sum / report.rows.len() as f64;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{u, Clock};

    /// Build a synthetic trace straight from the engine's own
    /// prediction, so measured == predicted by construction.
    fn trace_from_prediction(
        costs: &StepCosts,
        schedule: Schedule,
    ) -> Trace {
        let spec = StepSpec::from_costs(costs, schedule).unwrap();
        let (_, timeline) = simulate_step_timeline(&spec).unwrap();
        let last = costs.stages - 1;
        let events = timeline
            .iter()
            .map(|t| {
                let name = match t.class {
                    Class::Fwd if t.v == last => "fused",
                    Class::Fwd => "fwd",
                    Class::Bwd => "bwd",
                };
                TraceEvent {
                    cat: "compute".to_string(),
                    name: name.to_string(),
                    pid: 0,
                    tid: t.v as u32,
                    ts_us: t.start * 1e6,
                    dur_us: (t.end - t.start) * 1e6,
                    instant: false,
                    args: vec![u("step", 0), u("mb", t.mb as u64)],
                }
            })
            .collect();
        Trace { events, clock: Clock::Host }
    }

    fn costs(p: usize, m: usize) -> StepCosts {
        StepCosts {
            stages: p,
            microbatches: m,
            fwd: vec![vec![1.0; m]; p],
            bwd: vec![vec![2.0; m]; p],
            tx_fwd: vec![vec![Tx { ser: 0.0, lat: 0.0 }; m]; p - 1],
            tx_bwd: vec![vec![Tx { ser: 0.0, lat: 0.0 }; m]; p - 1],
            opt: vec![0.0; p],
            tail: 0.0,
        }
    }

    #[test]
    fn self_consistent_trace_diffs_to_zero() {
        let c = costs(3, 4);
        let trace = trace_from_prediction(&c, Schedule::Gpipe);
        let rep = diff_trace(&trace, Schedule::Gpipe).unwrap();
        assert_eq!(rep.steps, 1);
        assert_eq!(rep.skipped_steps, 0);
        assert_eq!(rep.rows.len(), 3 * 4 + 2 * 4);
        assert!(rep.max_rel_err < 1e-9, "{}", rep.max_rel_err);
    }

    #[test]
    fn displaced_span_reports_proportional_error() {
        let c = costs(2, 2);
        let mut trace = trace_from_prediction(&c, Schedule::Gpipe);
        // shift one span late by 1 simulated second
        let e = trace
            .events
            .iter_mut()
            .find(|e| e.name == "bwd")
            .expect("bwd span");
        e.ts_us += 1e6;
        let rep = diff_trace(&trace, Schedule::Gpipe).unwrap();
        assert!(rep.max_rel_err > 0.05, "{}", rep.max_rel_err);
        assert!(rep.summary().contains("trace-diff"));
    }

    #[test]
    fn incomplete_steps_are_skipped_not_fatal() {
        let c = costs(2, 2);
        let mut trace = trace_from_prediction(&c, Schedule::Gpipe);
        trace.events.pop(); // drop one task: step becomes incomplete
        let rep = diff_trace(&trace, Schedule::Gpipe).unwrap();
        assert_eq!(rep.steps, 0);
        assert_eq!(rep.skipped_steps, 1);
    }
}
