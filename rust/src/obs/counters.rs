//! Unified run metrics: monotonic counters, gauges, and fixed-bucket
//! histograms with deterministic snapshot ordering (DESIGN.md §15).
//!
//! One [`RunMetrics`] registry per run replaces the scattered byte /
//! [`FaultStats`] / `LaunchReport.replica_step_seconds` accounting:
//! drivers absorb their reports (and, when tracing, the recorded
//! [`Trace`]) into the registry and dump it as `METRICS.json` at run
//! end. Everything is a `BTreeMap`, so the snapshot is byte-stable and
//! assertable in tests — in particular the per-frame wire-byte
//! counters must equal the `memory::*_wire_bytes` analytic models
//! exactly (`tests/obs.rs`).
//!
//! Counter naming convention (dot-separated, lowercase):
//! `frames.sent.<kind>` / `frames.recv.<kind>`,
//! `bytes.wire.<kind>` / `bytes.payload.<kind>` (sender-side),
//! `fault.<outcome>`, `liveness.<field>`, `elastic.<field>`,
//! `dp.<field>`, `timing.calls.<entry>`; gauges use the same scheme
//! for non-monotonic values (`timing.total_s.<entry>`,
//! `step.mean_seconds`); histogram names are `span_ms.<cat>`.

use std::collections::{BTreeMap, HashMap};
use std::fmt;
use std::path::Path;

use anyhow::{Context, Result};

use crate::json::Json;
use crate::obs::trace::{Arg, Trace};
use crate::transport::{
    ElasticReport, FaultStats, LaunchReport, LivenessMonitor,
    ServeReport,
};

/// Default bucket upper bounds (milliseconds) for span-duration
/// histograms: spans in this repo range from sub-10 µs frame sends to
/// multi-second fused stage steps.
pub const SPAN_MS_BOUNDS: [f64; 6] =
    [0.01, 0.1, 1.0, 10.0, 100.0, 1000.0];

/// Bucket upper bounds (seconds) for serving-latency histograms:
/// admission→completion spans range from sub-millisecond tiny-model
/// decodes to multi-second wide-batch sessions over slow links.
pub const SERVE_LATENCY_BOUNDS: [f64; 6] =
    [1e-3, 1e-2, 0.1, 1.0, 10.0, 60.0];

/// A fixed-bucket histogram: `counts[i]` holds observations
/// `<= bounds[i]`, and the final slot is the overflow bucket, so
/// `counts.len() == bounds.len() + 1`.
#[derive(Clone, Debug, PartialEq)]
pub struct Hist {
    /// Inclusive upper bounds, strictly increasing.
    pub bounds: Vec<f64>,
    /// Per-bucket observation counts (last = overflow).
    pub counts: Vec<u64>,
}

impl Hist {
    /// Empty histogram over the given bucket bounds.
    pub fn new(bounds: &[f64]) -> Hist {
        Hist {
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len() + 1],
        }
    }

    /// Count one observation into its bucket.
    pub fn observe(&mut self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|b| v <= *b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
    }

    /// Total observations across all buckets.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }
}

/// The per-run metrics registry. Deterministic by construction: all
/// three families live in `BTreeMap`s, so [`RunMetrics::to_json`]
/// output depends only on what was recorded, never on insertion or
/// thread order.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RunMetrics {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Hist>,
}

impl RunMetrics {
    /// Fresh empty registry.
    pub fn new() -> RunMetrics {
        RunMetrics::default()
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty()
            && self.gauges.is_empty()
            && self.hists.is_empty()
    }

    /// Add `by` to the monotonic counter `name` (created at 0).
    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Set the gauge `name` to `v` (last write wins).
    pub fn set_gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    /// Count `v` into histogram `name`, creating it over `bounds` on
    /// first use (later calls keep the original bounds).
    pub fn observe(&mut self, name: &str, bounds: &[f64], v: f64) {
        self.hists
            .entry(name.to_string())
            .or_insert_with(|| Hist::new(bounds))
            .observe(v);
    }

    /// Current value of counter `name` (0 when never incremented).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Current value of gauge `name`.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Histogram `name`, if any observation created it.
    pub fn hist(&self, name: &str) -> Option<&Hist> {
        self.hists.get(name)
    }

    /// Fold a recorded trace into the registry: every `frame`-category
    /// send/recv event becomes `frames.(sent|recv).<kind>` counts
    /// (byte counters sum **sender-side** only, so in-process runs
    /// never double-count a frame), and every complete span feeds the
    /// `span_ms.<cat>` duration histogram.
    pub fn absorb_trace(&mut self, trace: &Trace) {
        for e in &trace.events {
            if e.cat == "frame" {
                if let Some((dir, kind)) = e.name.split_once(':') {
                    let dir = match dir {
                        "send" => "sent",
                        "recv" => "recv",
                        _ => continue,
                    };
                    self.inc(&format!("frames.{dir}.{kind}"), 1);
                    self.inc(&format!("frames.{dir}"), 1);
                    if dir == "sent" {
                        for (k, v) in &e.args {
                            if let Arg::U(n) = v {
                                match k.as_str() {
                                    "bytes" => {
                                        self.inc(
                                            &format!(
                                                "bytes.wire.{kind}"
                                            ),
                                            *n,
                                        );
                                        self.inc("bytes.wire", *n);
                                    }
                                    "payload" => {
                                        self.inc(
                                            &format!(
                                                "bytes.payload.{kind}"
                                            ),
                                            *n,
                                        );
                                        self.inc("bytes.payload", *n);
                                    }
                                    _ => {}
                                }
                            }
                        }
                    }
                }
            }
            if !e.instant {
                self.observe(
                    &format!("span_ms.{}", e.cat),
                    &SPAN_MS_BOUNDS,
                    e.dur_us / 1e3,
                );
            }
        }
    }

    /// Surface injected-fault outcomes as `fault.*` counters — the
    /// chaos tests assert these equal the seeded schedule's event
    /// counts.
    pub fn absorb_fault(&mut self, stats: &FaultStats) {
        self.inc("fault.passed", stats.passed);
        self.inc("fault.dropped", stats.dropped);
        self.inc("fault.delayed", stats.delayed);
        self.inc("fault.truncated", stats.truncated);
        self.inc("fault.severed", stats.severed);
    }

    /// Surface a liveness monitor's verdicts: heartbeat count, the
    /// newest step a heartbeat acknowledged, and the staleness verdict
    /// at absorb time (1.0 = stale).
    pub fn absorb_liveness(&mut self, mon: &LivenessMonitor) {
        self.inc("liveness.beats", mon.beats);
        self.set_gauge("liveness.last_step", mon.last_step as f64);
        self.set_gauge(
            "liveness.stale",
            if mon.is_stale() { 1.0 } else { 0.0 },
        );
    }

    /// Fold a [`LaunchReport`] (the unified grid/chain/elastic result)
    /// into run-level counters and gauges.
    pub fn absorb_launch(&mut self, rep: &LaunchReport) {
        self.inc("run.steps", rep.losses.len() as u64);
        self.inc("run.replicas", rep.replicas as u64);
        self.inc("run.survivors", rep.survivors as u64);
        self.inc("run.frames", rep.frames);
        self.inc("run.bytes.wire", rep.wire_bytes);
        self.inc(
            "run.bytes.boundary_payload",
            rep.boundary_payload_bytes,
        );
        self.inc("run.bytes.dp_payload", rep.dp_payload_bytes);
        self.set_gauge("step.mean_seconds", rep.mean_step_seconds());
        if let Some(last) = rep.losses.last() {
            self.set_gauge("loss.final", *last);
        }
        if let Some(es) = &rep.elastic {
            self.absorb_elastic(es);
        }
    }

    /// Fold a decode-serving run ([`ServeReport`], DESIGN.md §16):
    /// step/token/frame/byte counters, throughput and tail-latency
    /// gauges, and per-session latency histograms (completion and
    /// time-to-first-token).
    pub fn absorb_serve(&mut self, rep: &ServeReport) {
        self.inc("serve.steps", rep.steps);
        self.inc("serve.sessions", rep.sessions.len() as u64);
        self.inc("serve.tokens", rep.tokens_generated);
        self.inc("frames.sent.decode", rep.frames);
        self.inc("bytes.wire.decode", rep.wire_bytes);
        self.inc(
            "bytes.payload.decode",
            rep.decode_payload_bytes + rep.token_payload_bytes,
        );
        self.set_gauge("serve.tokens_per_sec", rep.tokens_per_sec());
        self.set_gauge(
            "serve.step.mean_seconds",
            rep.mean_step_seconds(),
        );
        self.set_gauge(
            "serve.latency.p50_s",
            rep.latency_percentile(50.0),
        );
        self.set_gauge(
            "serve.latency.p99_s",
            rep.latency_percentile(99.0),
        );
        self.set_gauge("serve.kv_peak_bytes", rep.kv_peak_bytes as f64);
        for s in &rep.sessions {
            self.observe(
                "serve.latency_s",
                &SERVE_LATENCY_BOUNDS,
                s.latency_s,
            );
            self.observe(
                "serve.first_token_s",
                &SERVE_LATENCY_BOUNDS,
                s.first_token_s,
            );
        }
    }

    /// Fold the elastic runtime's recovery/liveness-wire accounting.
    pub fn absorb_elastic(&mut self, rep: &ElasticReport) {
        self.inc("elastic.epochs", rep.epochs as u64);
        self.inc("elastic.recoveries", rep.recoveries as u64);
        self.inc("elastic.spares_used", rep.spares_used as u64);
        self.inc("frames.sent.heartbeat.ctl", rep.heartbeat_frames);
        self.inc("bytes.payload.heartbeat.ctl", rep.heartbeat_bytes);
        self.inc("frames.sent.checkpoint.ctl", rep.ckpt_frames);
        self.inc("bytes.payload.checkpoint.ctl", rep.ckpt_bytes);
    }

    /// Fold a structured kernel-timing report: per-entry call counts
    /// as counters, per-entry total seconds as gauges.
    pub fn absorb_timing(&mut self, rep: &TimingReport) {
        for row in &rep.rows {
            self.inc(&format!("timing.calls.{}", row.entry), row.calls);
            self.set_gauge(
                &format!("timing.total_s.{}", row.entry),
                row.total_s,
            );
        }
    }

    /// Serialize as the `METRICS.json` object:
    /// `{"counters": {...}, "gauges": {...}, "hists": {name:
    /// {"bounds": [...], "counts": [...]}}}`.
    pub fn to_json(&self) -> Json {
        let counters: BTreeMap<String, Json> = self
            .counters
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
            .collect();
        let gauges: BTreeMap<String, Json> = self
            .gauges
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(*v)))
            .collect();
        let hists: BTreeMap<String, Json> = self
            .hists
            .iter()
            .map(|(k, h)| {
                let mut o = BTreeMap::new();
                o.insert(
                    "bounds".to_string(),
                    Json::Arr(
                        h.bounds.iter().map(|b| Json::Num(*b)).collect(),
                    ),
                );
                o.insert(
                    "counts".to_string(),
                    Json::Arr(
                        h.counts
                            .iter()
                            .map(|c| Json::Num(*c as f64))
                            .collect(),
                    ),
                );
                (k.clone(), Json::Obj(o))
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert("counters".to_string(), Json::Obj(counters));
        top.insert("gauges".to_string(), Json::Obj(gauges));
        top.insert("hists".to_string(), Json::Obj(hists));
        Json::Obj(top)
    }

    /// Rebuild a registry from [`RunMetrics::to_json`] output.
    pub fn from_json(j: &Json) -> Result<RunMetrics> {
        let mut m = RunMetrics::new();
        if let Some(Json::Obj(o)) = j.opt("counters") {
            for (k, v) in o {
                m.counters.insert(k.clone(), v.num()? as u64);
            }
        }
        if let Some(Json::Obj(o)) = j.opt("gauges") {
            for (k, v) in o {
                m.gauges.insert(k.clone(), v.num()?);
            }
        }
        if let Some(Json::Obj(o)) = j.opt("hists") {
            for (k, v) in o {
                let bounds: Result<Vec<f64>> =
                    v.get("bounds")?.arr()?.iter().map(Json::num).collect();
                let counts: Result<Vec<u64>> = v
                    .get("counts")?
                    .arr()?
                    .iter()
                    .map(|c| Ok(c.num()? as u64))
                    .collect();
                m.hists.insert(
                    k.clone(),
                    Hist { bounds: bounds?, counts: counts? },
                );
            }
        }
        Ok(m)
    }

    /// Parse from JSON text.
    pub fn parse(text: &str) -> Result<RunMetrics> {
        RunMetrics::from_json(&Json::parse(text)?)
    }

    /// Write `METRICS.json` to `path` (creating parent directories).
    pub fn write_file(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).with_context(|| {
                    format!("creating {}", parent.display())
                })?;
            }
        }
        std::fs::write(path, self.to_json().to_string()).with_context(
            || format!("writing metrics {}", path.display()),
        )
    }
}

// ---------------------------------------------------------------------------
// structured kernel-timing report
// ---------------------------------------------------------------------------

/// One executable's accumulated timing: call count and total wall
/// seconds.
#[derive(Clone, Debug, PartialEq)]
pub struct TimingRow {
    /// Executable/entry name.
    pub entry: String,
    /// Number of calls recorded.
    pub calls: u64,
    /// Total wall seconds across all calls.
    pub total_s: f64,
}

impl TimingRow {
    /// Mean milliseconds per call.
    pub fn mean_ms(&self) -> f64 {
        self.total_s / self.calls.max(1) as f64 * 1e3
    }
}

/// Structured replacement for the old string-valued
/// `Runtime::timing_report`: rows sorted by descending total time
/// (entry name breaks ties deterministically), with a `Display` that
/// reproduces the legacy CSV text byte-for-byte.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TimingReport {
    /// Rows, hottest entry first.
    pub rows: Vec<TimingRow>,
}

impl TimingReport {
    /// Build from the runtime's `entry -> (calls, total_seconds)` map.
    pub fn from_timings(
        timings: &HashMap<String, (u64, f64)>,
    ) -> TimingReport {
        let mut rows: Vec<TimingRow> = timings
            .iter()
            .map(|(k, (n, t))| TimingRow {
                entry: k.clone(),
                calls: *n,
                total_s: *t,
            })
            .collect();
        rows.sort_by(|a, b| {
            b.total_s
                .total_cmp(&a.total_s)
                .then_with(|| a.entry.cmp(&b.entry))
        });
        TimingReport { rows }
    }

    /// Total wall seconds across every entry.
    pub fn total_seconds(&self) -> f64 {
        self.rows.iter().map(|r| r.total_s).sum()
    }
}

impl fmt::Display for TimingReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("entry,calls,total_s,mean_ms\n")?;
        for r in &self.rows {
            writeln!(
                f,
                "{},{},{:.4},{:.3}",
                r.entry, r.calls, r.total_s, r.mean_ms()
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{u, Clock, TraceEvent};

    #[test]
    fn hist_buckets_observations_with_overflow() {
        let mut h = Hist::new(&[1.0, 10.0]);
        h.observe(0.5);
        h.observe(1.0); // inclusive upper bound
        h.observe(5.0);
        h.observe(100.0); // overflow
        assert_eq!(h.counts, vec![2, 1, 1]);
        assert_eq!(h.total(), 4);
    }

    #[test]
    fn metrics_json_round_trips() {
        let mut m = RunMetrics::new();
        m.inc("frames.sent.fwd", 12);
        m.set_gauge("loss.final", 0.25);
        m.observe("span_ms.compute", &SPAN_MS_BOUNDS, 3.5);
        let text = m.to_json().to_string();
        let back = RunMetrics::parse(&text).expect("parse");
        assert_eq!(back, m);
        assert_eq!(back.counter("frames.sent.fwd"), 12);
        assert_eq!(back.gauge("loss.final"), Some(0.25));
        assert_eq!(back.hist("span_ms.compute").map(Hist::total), Some(1));
    }

    #[test]
    fn absorb_trace_counts_frames_sender_side_only() {
        let mk = |name: &str, bytes: u64, payload: u64| TraceEvent {
            cat: "frame".to_string(),
            name: name.to_string(),
            pid: 0,
            tid: 0,
            ts_us: 0.0,
            dur_us: 1.0,
            instant: false,
            args: vec![u("bytes", bytes), u("payload", payload)],
        };
        let trace = Trace {
            events: vec![
                mk("send:fwd", 124, 100),
                mk("send:fwd", 124, 100),
                mk("recv:fwd", 124, 100),
                mk("send:heartbeat", 40, 16),
            ],
            clock: Clock::Host,
        };
        let mut m = RunMetrics::new();
        m.absorb_trace(&trace);
        assert_eq!(m.counter("frames.sent.fwd"), 2);
        assert_eq!(m.counter("frames.recv.fwd"), 1);
        assert_eq!(m.counter("bytes.wire.fwd"), 248);
        assert_eq!(m.counter("bytes.payload.fwd"), 200);
        // recv side never adds to byte counters
        assert_eq!(m.counter("bytes.wire"), 248 + 40);
        assert_eq!(m.counter("frames.sent"), 3);
        assert_eq!(
            m.hist("span_ms.frame").map(Hist::total),
            Some(4)
        );
    }

    #[test]
    fn timing_report_display_matches_legacy_text() {
        let mut t = HashMap::new();
        t.insert("matmul".to_string(), (4u64, 0.02f64));
        t.insert("ortho".to_string(), (1u64, 0.5f64));
        let rep = TimingReport::from_timings(&t);
        assert_eq!(rep.rows[0].entry, "ortho");
        let legacy = {
            let mut rows: Vec<_> = t.iter().collect();
            rows.sort_by(|a, b| b.1 .1.partial_cmp(&a.1 .1).unwrap());
            let mut s = String::from("entry,calls,total_s,mean_ms\n");
            for (k, (n, t)) in rows {
                s.push_str(&format!(
                    "{k},{n},{t:.4},{:.3}\n",
                    t / (*n).max(1) as f64 * 1e3
                ));
            }
            s
        };
        assert_eq!(rep.to_string(), legacy);
    }

    #[test]
    fn absorb_serve_surfaces_throughput_and_tails() {
        use crate::transport::{ServeReport, SessionStat};
        let session = |id: u32, latency_s: f64| SessionStat {
            id,
            arrival_step: 0,
            admit_step: 0,
            first_token_step: 1,
            done_step: 3,
            prompt_len: 2,
            gen: 2,
            tokens: vec![1, 2],
            latency_s,
            first_token_s: latency_s / 2.0,
        };
        let rep = ServeReport {
            stage: 0,
            sessions: vec![
                session(0, 0.002),
                session(1, 0.01),
                session(2, 0.2),
            ],
            steps: 5,
            tokens_generated: 6,
            step_seconds: vec![0.01; 5],
            decode_payload_bytes: 300,
            token_payload_bytes: 80,
            wire_bytes: 500,
            frames: 10,
            kv_peak_bytes: 4096,
        };
        let mut m = RunMetrics::new();
        m.absorb_serve(&rep);
        assert_eq!(m.counter("serve.steps"), 5);
        assert_eq!(m.counter("serve.sessions"), 3);
        assert_eq!(m.counter("serve.tokens"), 6);
        assert_eq!(m.counter("bytes.wire.decode"), 500);
        assert_eq!(m.counter("bytes.payload.decode"), 380);
        assert_eq!(
            m.gauge("serve.tokens_per_sec"),
            Some(6.0 / 0.05)
        );
        // nearest-rank over [0.002, 0.01, 0.2]
        assert_eq!(m.gauge("serve.latency.p50_s"), Some(0.01));
        assert_eq!(m.gauge("serve.latency.p99_s"), Some(0.2));
        assert_eq!(
            m.hist("serve.latency_s").map(Hist::total),
            Some(3)
        );
        assert_eq!(
            m.hist("serve.first_token_s").map(Hist::total),
            Some(3)
        );
    }

    #[test]
    fn absorb_fault_mirrors_stats() {
        let stats = FaultStats {
            passed: 7,
            dropped: 2,
            delayed: 1,
            truncated: 0,
            severed: 1,
        };
        let mut m = RunMetrics::new();
        m.absorb_fault(&stats);
        assert_eq!(m.counter("fault.passed"), 7);
        assert_eq!(m.counter("fault.dropped"), 2);
        assert_eq!(m.counter("fault.delayed"), 1);
        assert_eq!(m.counter("fault.truncated"), 0);
        assert_eq!(m.counter("fault.severed"), 1);
    }
}
