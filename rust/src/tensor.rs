//! Host-side tensors exchanged with the PJRT runtime.
//!
//! The coordinator works in plain `Vec`-backed tensors; conversion to/from
//! `xla::Literal` happens only at the runtime boundary (runtime/mod.rs).

use std::fmt;

/// Dense f32 tensor (row-major).
#[derive(Clone, PartialEq)]
pub struct Tensor {
    /// logical dimensions (empty = scalar)
    pub shape: Vec<usize>,
    /// row-major elements
    pub data: Vec<f32>,
}

/// Dense i32 tensor (row-major) — token ids / targets.
#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    /// logical dimensions
    pub shape: Vec<usize>,
    /// row-major elements
    pub data: Vec<i32>,
}

/// A value crossing the runtime boundary.
#[derive(Clone, Debug)]
pub enum Value {
    /// float tensor
    F32(Tensor),
    /// integer tensor
    I32(IntTensor),
}

impl Tensor {
    /// Tensor from shape + row-major data (lengths must agree).
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    /// All-zeros tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    /// Rank-0 (scalar) tensor.
    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Bytes when shipped as f32 over the wire.
    pub fn wire_bytes(&self) -> usize {
        self.numel() * 4
    }

    /// Whether this is a rank-0 tensor.
    pub fn is_scalar(&self) -> bool {
        self.shape.is_empty()
    }

    /// The single element of a scalar tensor.
    pub fn item(&self) -> f32 {
        debug_assert_eq!(self.numel(), 1);
        self.data[0]
    }

    /// In-place elementwise add (gradient accumulation across microbatches).
    pub fn add_assign(&mut self, other: &Tensor) {
        debug_assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// In-place scale (averaging accumulated gradients).
    pub fn scale(&mut self, s: f32) {
        for a in &mut self.data {
            *a *= s;
        }
    }

    /// Frobenius norm (flat L2).
    pub fn frobenius_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Largest absolute element (0 for empty tensors).
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// Matrix rows/cols for 2-D tensors.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.shape.len(), 2, "dims2 on shape {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    /// Element (r, c) of a 2-D tensor.
    pub fn at2(&self, r: usize, c: usize) -> f32 {
        let (_, cols) = self.dims2();
        self.data[r * cols + c]
    }
}

impl IntTensor {
    /// Tensor from shape + row-major data (lengths must agree).
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        IntTensor { shape, data }
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

impl Value {
    /// Convenience constructor for a float value.
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        Value::F32(Tensor::new(shape, data))
    }

    /// Shape of the wrapped tensor.
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::I32(t) => &t.shape,
        }
    }

    /// Borrow as a float tensor (panics on i32 values).
    pub fn as_f32(&self) -> &Tensor {
        match self {
            Value::F32(t) => t,
            Value::I32(_) => panic!("expected f32 tensor"),
        }
    }

    /// Unwrap into a float tensor (panics on i32 values).
    pub fn into_f32(self) -> Tensor {
        match self {
            Value::F32(t) => t,
            Value::I32(_) => panic!("expected f32 tensor"),
        }
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}(", self.shape)?;
        for (i, v) in self.data.iter().take(6).enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{v:.4}")?;
        }
        if self.numel() > 6 {
            write!(f, ", …")?;
        }
        write!(f, ")")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_assign_and_scale() {
        let mut a = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::new(vec![2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        a.add_assign(&b);
        a.scale(0.5);
        assert_eq!(a.data, vec![1.0, 1.5, 2.0, 2.5]);
    }

    #[test]
    fn scalar_roundtrip() {
        let s = Tensor::scalar(3.5);
        assert!(s.is_scalar());
        assert_eq!(s.item(), 3.5);
    }

    #[test]
    fn wire_bytes_is_4x_numel() {
        let t = Tensor::zeros(&[3, 5]);
        assert_eq!(t.wire_bytes(), 60);
    }
}
