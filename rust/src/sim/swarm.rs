//! Multi-step, multi-replica discrete-event swarm simulation: latency
//! jitter, time-varying stragglers, and node churn over the hybrid
//! data-parallel × model-parallel step.
//!
//! The closed-form `hybrid_makespan` prices one *undisturbed* step.
//! Real decentralized swarms are never undisturbed: WAN latency
//! jitters, hosts throttle and recover, members leave mid-all-reduce
//! and rejoin minutes later needing a state sync. This engine executes
//! `steps` consecutive hybrid steps on a global simulated clock where
//! all of those are first-class events:
//!
//! - **Per-entity RNG streams.** Every pipeline link, ring link, and
//!   the churn process draws from its own stream derived via
//!   [`crate::par::cell_seed`]`(seed, entity)` — simulation results
//!   are a pure function of the spec, independent of anything else.
//! - **Jitter.** Bandwidth jitter comes from the `LinkSpec` (the
//!   paper's N(B, 0.2B)); latency jitter is layered per transfer via
//!   [`crate::netsim::Link::sample_jittered`].
//! - **Stragglers.** Per-replica [`SlowdownProfile`]s evaluated at
//!   each step's start extend the static `TimeModel::scaled` factors
//!   to trajectories (degrade-then-recover).
//! - **Churn.** Leaves (Poisson in *simulated time*, or scripted)
//!   remove a replica: an all-reduce in flight when the leave lands is
//!   aborted and restarted on the re-routed smaller ring
//!   ([`crate::netsim::ReplicaRing::all_reduce_among`]); a leave before
//!   a replica's pipeline drained discards that replica's step
//!   contribution. Rejoins integrate at the next step barrier after a
//!   state sync priced under the same `dp_mode` wire vocabulary as
//!   gradients (params + both Adam moments).
//!
//! Because churn is a rate per simulated *second*, protocols with slow
//! steps (raw activations at 80 Mbps) absorb proportionally more churn
//! per step than compressed ones — the effect
//! `examples/churn_swarm.rs` quantifies.
//!
//! **Parity contract** (`tests/sim_swarm.rs`): with zero jitter, no
//! churn, constant nominal profiles, one step and the GPipe schedule,
//! [`simulate_swarm`] reproduces `simulate_hybrid_step`'s
//! `HybridMakespan` within 1e-6 relative across a grid of (stages,
//! replicas, compression modes).

use anyhow::{bail, Result};

use crate::compress::{dp_wire_bytes, wire_bytes, Mode};
use crate::coordinator::schedule::{Makespan, Tx};
use crate::manifest::Hyper;
use crate::netsim::{Link, LinkSpec, ReplicaRing};
use crate::obs::trace;
use crate::par::cell_seed;
use crate::rng::Rng;
use crate::sim::step::{simulate_step_spec, Schedule, StepSpec};
use crate::timemodel::{
    stage_param_count, stage_seconds, Phase, SlowdownProfile, TimeModel,
};
use crate::transport::{gossip_pairs, Reduce};

/// What kind of membership change a scripted churn event applies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChurnKind {
    /// the replica crashes / disconnects at `time`
    Leave,
    /// the replica comes back at `time` (sync starts then; it
    /// re-enters the swarm at the next step barrier after sync)
    Rejoin,
}

/// One scripted membership change.
#[derive(Clone, Copy, Debug)]
pub struct ChurnEvent {
    /// simulated instant the change happens
    pub time: f64,
    /// which replica
    pub replica: usize,
    /// leave or rejoin
    pub kind: ChurnKind,
}

/// Churn process driving membership changes.
#[derive(Clone, Debug)]
pub enum ChurnSpec {
    /// stable membership
    None,
    /// leaves arrive as a Poisson process in simulated time; each
    /// leaver rejoins `downtime_s` later (sync at the next barrier)
    Poisson {
        /// expected leaves per simulated second (over the whole swarm)
        rate_per_s: f64,
        /// seconds a leaver stays away before rejoining
        downtime_s: f64,
    },
    /// explicit (time, replica, kind) list — deterministic scenarios
    /// and the mid-all-reduce edge-case tests
    Scripted(Vec<ChurnEvent>),
}

/// One step-indexed membership change — the unit of the shared churn
/// script consumed by *both* the swarm simulator (via
/// [`ChurnTimeline::to_scripted`]) and the real elastic runtime
/// (`transport::elastic`), so a chaos run and its predicted envelope
/// execute the exact same timeline (DESIGN.md §12).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StepChurn {
    /// 0-based optimizer step during which the change lands
    pub step: u64,
    /// which worker / replica
    pub worker: usize,
    /// leave (kill) or rejoin (restart)
    pub kind: ChurnKind,
}

/// A deterministic, step-indexed churn script. The CLI syntax (for
/// `train --chaos`) is comma-separated `kill:W@S` / `join:W@S` clauses:
/// `"kill:1@15,join:1@16"` kills worker 1 during step 15 and restarts
/// it during step 16.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ChurnTimeline {
    /// the script, sorted by step
    pub events: Vec<StepChurn>,
}

impl ChurnTimeline {
    /// Parse the CLI syntax above. Events come back sorted by step.
    pub fn parse(s: &str) -> Result<ChurnTimeline> {
        let mut events = Vec::new();
        for clause in s.split(',').filter(|c| !c.trim().is_empty()) {
            let clause = clause.trim();
            let (verb, rest) = clause.split_once(':').ok_or_else(|| {
                anyhow::anyhow!(
                    "churn clause {clause:?} missing ':' (expected \
                     kill:W@S or join:W@S)"
                )
            })?;
            let kind = match verb {
                "kill" => ChurnKind::Leave,
                "join" => ChurnKind::Rejoin,
                other => bail!(
                    "unknown churn verb {other:?} (expected kill|join)"
                ),
            };
            let (worker, step) = rest.split_once('@').ok_or_else(|| {
                anyhow::anyhow!(
                    "churn clause {clause:?} missing '@' (expected \
                     {verb}:W@S)"
                )
            })?;
            let worker: usize = worker.trim().parse().map_err(|_| {
                anyhow::anyhow!("bad worker index {worker:?} in {clause:?}")
            })?;
            let step: u64 = step.trim().parse().map_err(|_| {
                anyhow::anyhow!("bad step {step:?} in {clause:?}")
            })?;
            events.push(StepChurn { step, worker, kind });
        }
        let mut t = ChurnTimeline { events };
        t.events.sort_by_key(|e| e.step);
        Ok(t)
    }

    /// Render back to the CLI syntax (inverse of [`ChurnTimeline::parse`]
    /// up to ordering/whitespace).
    pub fn to_script(&self) -> String {
        self.events
            .iter()
            .map(|e| {
                let verb = match e.kind {
                    ChurnKind::Leave => "kill",
                    ChurnKind::Rejoin => "join",
                };
                format!("{verb}:{}@{}", e.worker, e.step)
            })
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Check every event against the run's shape: workers in range,
    /// steps inside the run.
    pub fn validate(&self, workers: usize, steps: u64) -> Result<()> {
        for e in &self.events {
            if e.worker >= workers {
                bail!(
                    "churn timeline names worker {} of {workers}",
                    e.worker
                );
            }
            if e.step >= steps {
                bail!(
                    "churn timeline fires at step {} of a {steps}-step run",
                    e.step
                );
            }
        }
        Ok(())
    }

    /// Workers the script kills during `step`.
    pub fn kills_at(&self, step: u64) -> Vec<usize> {
        self.events
            .iter()
            .filter(|e| e.step == step && e.kind == ChurnKind::Leave)
            .map(|e| e.worker)
            .collect()
    }

    /// Number of leave (kill) events in the script.
    pub fn leaves(&self) -> usize {
        self.events
            .iter()
            .filter(|e| e.kind == ChurnKind::Leave)
            .count()
    }

    /// True when the script is empty (a no-churn run).
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Lower the step-indexed script onto the simulator's continuous
    /// clock: an event at step S lands mid-step, at `(S + 0.5) ·
    /// step_seconds`. Feeding the result into [`SwarmSpec::churn`] makes
    /// the simulator predict the envelope for the *same* timeline the
    /// elastic runtime executes.
    pub fn to_scripted(&self, step_seconds: f64) -> ChurnSpec {
        ChurnSpec::Scripted(
            self.events
                .iter()
                .map(|e| ChurnEvent {
                    time: (e.step as f64 + 0.5) * step_seconds,
                    replica: e.worker,
                    kind: e.kind,
                })
                .collect(),
        )
    }
}

/// Full specification of one swarm simulation.
#[derive(Clone, Debug)]
pub struct SwarmSpec {
    /// model/pipeline dimensions (no manifest required)
    pub hyper: Hyper,
    /// microbatches per step
    pub microbatches: usize,
    /// activation (boundary) compression mode
    pub mode: Mode,
    /// weight-gradient all-reduce + rejoin-sync pricing mode
    pub dp_mode: Mode,
    /// cross-replica reduce the engine simulates: the churn-re-routed
    /// ring (default), seeded gossip rounds (`Gossip { degree }` runs
    /// `degree` pairing rounds per stage exchange — degrees > 1 live
    /// here in the simulator; real grids pin degree = 1), or `None`
    /// (pipelines only, no gradient exchange)
    pub reduce: Reduce,
    /// number of pipeline replicas R
    pub replicas: usize,
    /// pipeline schedule executed by the event engine
    pub schedule: Schedule,
    /// stage-to-stage (pipeline) link spec; its `jitter_frac` is the
    /// bandwidth jitter
    pub link: LinkSpec,
    /// cross-replica (ring) link spec
    pub ring_link: LinkSpec,
    /// σ/μ of the per-transfer latency factor (0 = deterministic)
    pub lat_jitter_frac: f64,
    /// compute-time model (scaled per replica by `straggler`)
    pub time_model: TimeModel,
    /// per-replica slowdown trajectories (empty = all nominal)
    pub straggler: Vec<SlowdownProfile>,
    /// membership-change process
    pub churn: ChurnSpec,
    /// optimizer steps to simulate
    pub steps: usize,
    /// master seed for every per-entity stream
    pub seed: u64,
}

impl SwarmSpec {
    /// Ready-to-run spec over uniform consumer links at `bw_bps`:
    /// mirrors `HybridSimSpec::uniform` (8 microbatches, subspace both
    /// axes, analytic clock, seed 17) plus GPipe schedule, no jitter
    /// beyond the links' own, no churn, one step.
    pub fn uniform(hyper: Hyper, replicas: usize, bw_bps: f64) -> SwarmSpec {
        SwarmSpec {
            hyper,
            microbatches: 8,
            mode: Mode::Subspace,
            dp_mode: Mode::Subspace,
            reduce: Reduce::Ring,
            replicas,
            schedule: Schedule::Gpipe,
            link: LinkSpec::internet(bw_bps),
            ring_link: LinkSpec::internet(bw_bps),
            lat_jitter_frac: 0.0,
            time_model: TimeModel::default_analytic(),
            straggler: Vec::new(),
            churn: ChurnSpec::None,
            steps: 1,
            seed: 17,
        }
    }

    /// Straggler profile of replica `r` (nominal when unspecified).
    pub fn profile_of(&self, r: usize) -> SlowdownProfile {
        self.straggler
            .get(r)
            .cloned()
            .unwrap_or_else(SlowdownProfile::nominal)
    }

    fn validate_link(spec: &LinkSpec, what: &str) -> Result<()> {
        if !spec.bandwidth_bps.is_finite() || spec.bandwidth_bps <= 0.0 {
            bail!(
                "{what} bandwidth must be finite and positive, got {} bps \
                 (a zero-bandwidth link would produce infinite event times)",
                spec.bandwidth_bps
            );
        }
        if !spec.latency_s.is_finite() || spec.latency_s < 0.0 {
            bail!("{what} latency must be finite and >= 0");
        }
        if !spec.jitter_frac.is_finite() || spec.jitter_frac < 0.0 {
            bail!("{what} jitter_frac must be finite and >= 0");
        }
        Ok(())
    }

    /// Check every modeling precondition; every error names the field.
    pub fn validate(&self) -> Result<()> {
        let h = &self.hyper;
        if h.stages < 2 || h.stages > 128 {
            bail!("pipeline needs 2..=128 stages, got {}", h.stages);
        }
        if self.microbatches == 0 {
            bail!("need >= 1 microbatch");
        }
        if self.replicas == 0 || self.replicas > 512 {
            bail!("need 1..=512 replicas, got {}", self.replicas);
        }
        if self.steps == 0 {
            bail!("need >= 1 step");
        }
        if let Reduce::Gossip { degree } = self.reduce {
            if degree == 0 {
                bail!("gossip needs >= 1 round per exchange");
            }
        }
        SwarmSpec::validate_link(&self.link, "pipeline link")?;
        SwarmSpec::validate_link(&self.ring_link, "ring link")?;
        if !self.lat_jitter_frac.is_finite() || self.lat_jitter_frac < 0.0 {
            bail!("lat_jitter_frac must be finite and >= 0");
        }
        if let Schedule::Interleaved { chunks } = self.schedule {
            if chunks < 2 {
                bail!("interleaved schedule needs >= 2 chunks");
            }
        }
        for (r, p) in self.straggler.iter().enumerate() {
            if !p.is_valid() {
                bail!("straggler profile of replica {r} is invalid: {p:?}");
            }
        }
        let heterogeneous = self.straggler.iter().any(|p| match p {
            SlowdownProfile::Constant(f) => (*f - 1.0).abs() > 1e-9,
            SlowdownProfile::Phases(v) => !v.is_empty(),
        });
        if heterogeneous && matches!(self.time_model, TimeModel::Measured) {
            bail!(
                "straggler profiles need an analytic time model: measured \
                 wall times cannot be re-attributed per replica"
            );
        }
        match &self.churn {
            ChurnSpec::None => {}
            ChurnSpec::Poisson { rate_per_s, downtime_s } => {
                if !rate_per_s.is_finite() || *rate_per_s < 0.0 {
                    bail!("churn rate must be finite and >= 0");
                }
                if !downtime_s.is_finite() || *downtime_s <= 0.0 {
                    bail!("churn downtime must be finite and positive");
                }
            }
            ChurnSpec::Scripted(events) => {
                for e in events {
                    if !e.time.is_finite() || e.time < 0.0 {
                        bail!("scripted churn times must be finite and >= 0");
                    }
                    if e.replica >= self.replicas {
                        bail!(
                            "scripted churn names replica {} of {}",
                            e.replica,
                            self.replicas
                        );
                    }
                }
            }
        }
        Ok(())
    }
}

/// What one swarm simulation measured. The first five fields mirror
/// [`Makespan`] (aggregated over the run); the next four mirror
/// `HybridMakespan` for the *last* step (offsets from its barrier);
/// the rest are swarm-only.
#[derive(Clone, Debug, Default)]
pub struct SimReport {
    /// simulated seconds for the whole run (== step time for 1 step)
    pub total: f64,
    /// pipeline-link serialization seconds, summed over steps/replicas
    pub comm_ser: f64,
    /// compute seconds, summed over steps and replicas
    pub compute: f64,
    /// seconds beyond the best per-step serial compute bound
    pub overhead: f64,
    /// last step's per-stage gradient-ready offsets (max over members)
    pub grad_ready: Vec<f64>,
    /// last step: instant (offset) the slowest surviving pipeline ended
    pub compute_end: f64,
    /// last step: instant (offset) the last all-reduce completed (0
    /// when a single member made comm free)
    pub comm_end: f64,
    /// last step: non-overlapped all-reduce tail
    pub tail: f64,
    /// ring-busy seconds across the run (incl. work lost to restarts)
    pub allreduce_busy: f64,
    /// steps simulated
    pub steps: usize,
    /// wall seconds of each step (barrier stalls included)
    pub step_seconds: Vec<f64>,
    /// members that left / rejoined across the run
    pub leaves: usize,
    /// rejoins integrated at barriers
    pub rejoins: usize,
    /// all-reduces aborted by a leave landing mid-flight
    pub allreduce_restarts: usize,
    /// seconds spent on rejoin state syncs
    pub sync_seconds: f64,
    /// smallest membership any step started with
    pub min_active: usize,
    /// bytes that crossed pipeline links
    pub wire_bytes: u64,
    /// bytes that crossed ring links
    pub dp_bytes: u64,
}

impl SimReport {
    /// Mean seconds per step.
    pub fn mean_step(&self) -> f64 {
        if self.step_seconds.is_empty() {
            0.0
        } else {
            self.step_seconds.iter().sum::<f64>()
                / self.step_seconds.len() as f64
        }
    }
}

// per-entity stream tags (see cell_seed): pipeline link l of replica r,
// ring link of replica r, the churn process
fn ent_pipe(r: usize, l: usize) -> usize {
    1_000 + r * 1_000 + l
}
fn ent_ring(r: usize) -> usize {
    2_000_000 + r
}
const ENT_CHURN: usize = 3_000_000;

struct Swarm<'a> {
    spec: &'a SwarmSpec,
    /// [replica][phys link] — p-1 pipeline links plus one wrap link
    pipe_links: Vec<Vec<Link>>,
    ring: ReplicaRing,
    churn_rng: Rng,
    active: Vec<bool>,
    /// (rejoin time, replica), unordered; scanned for the minimum
    pending_rejoin: Vec<(f64, usize)>,
    /// scripted leaves sorted by time, next at `script_idx`
    scripted_leaves: Vec<(f64, usize)>,
    script_idx: usize,
    /// absolute time of the next Poisson leave, if that process runs
    next_poisson: Option<f64>,
    clock: f64,
    report: SimReport,
}

impl<'a> Swarm<'a> {
    fn new(spec: &'a SwarmSpec) -> Swarm<'a> {
        let p = spec.hyper.stages;
        let pipe_links = (0..spec.replicas)
            .map(|r| {
                (0..p)
                    .map(|l| {
                        Link::new(
                            spec.link,
                            Rng::new(cell_seed(spec.seed, ent_pipe(r, l))),
                        )
                    })
                    .collect()
            })
            .collect();
        let ring = ReplicaRing {
            links: (0..spec.replicas)
                .map(|r| {
                    Link::new(
                        spec.ring_link,
                        Rng::new(cell_seed(spec.seed, ent_ring(r))),
                    )
                })
                .collect(),
        };
        let mut churn_rng = Rng::new(cell_seed(spec.seed, ENT_CHURN));
        let mut scripted_leaves = Vec::new();
        let mut pending_rejoin = Vec::new();
        let mut next_poisson = None;
        match &spec.churn {
            ChurnSpec::None => {}
            ChurnSpec::Poisson { rate_per_s, .. } => {
                if *rate_per_s > 0.0 {
                    next_poisson =
                        Some(exp_sample(&mut churn_rng, *rate_per_s));
                }
            }
            ChurnSpec::Scripted(events) => {
                for e in events {
                    match e.kind {
                        ChurnKind::Leave => {
                            scripted_leaves.push((e.time, e.replica))
                        }
                        // rejoins integrate at barriers; queue them now
                        ChurnKind::Rejoin => {
                            pending_rejoin.push((e.time, e.replica))
                        }
                    }
                }
                scripted_leaves
                    .sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            }
        }
        Swarm {
            spec,
            pipe_links,
            ring,
            churn_rng,
            active: vec![true; spec.replicas],
            pending_rejoin,
            scripted_leaves,
            script_idx: 0,
            next_poisson,
            clock: 0.0,
            report: SimReport::default(),
        }
    }

    fn active_count(&self) -> usize {
        self.active.iter().filter(|a| **a).count()
    }

    /// Time of the next undecided leave, if any.
    fn peek_leave(&self) -> Option<f64> {
        match &self.spec.churn {
            ChurnSpec::Poisson { .. } => self.next_poisson,
            ChurnSpec::Scripted(_) => self
                .scripted_leaves
                .get(self.script_idx)
                .map(|(t, _)| *t),
            ChurnSpec::None => None,
        }
    }

    /// Fire the next leave event (caller checked its time). Returns the
    /// replica that left, if the event found a victim.
    fn fire_leave(&mut self, t: f64) -> Option<usize> {
        match &self.spec.churn {
            ChurnSpec::Poisson { rate_per_s, downtime_s } => {
                self.next_poisson =
                    Some(t + exp_sample(&mut self.churn_rng, *rate_per_s));
                // never drop the last member (the inter-arrival draw
                // above already happened, keeping the stream aligned)
                let count = self.active_count();
                if count <= 1 {
                    return None;
                }
                let k = self.churn_rng.below(count);
                let victim = (0..self.active.len())
                    .filter(|r| self.active[*r])
                    .nth(k)
                    .expect("k < active count");
                self.active[victim] = false;
                self.report.leaves += 1;
                self.pending_rejoin.push((t + downtime_s, victim));
                trace::instant_at(
                    "sim",
                    "leave",
                    victim as u32,
                    0,
                    t * 1e6,
                    vec![trace::u("replica", victim as u64)],
                );
                Some(victim)
            }
            ChurnSpec::Scripted(_) => {
                let (_, replica) = self.scripted_leaves[self.script_idx];
                self.script_idx += 1;
                // same invariant as the Poisson path: a leave never
                // drops the last member — the scripted event is skipped
                if self.active[replica] && self.active_count() <= 1 {
                    return None;
                }
                if !self.active[replica] {
                    // the node went away again before its pending rejoin
                    // was integrated at a barrier: cancel that rejoin
                    // (it never made it back into the swarm)
                    self.pending_rejoin
                        .retain(|(rt, rr)| *rr != replica || *rt > t);
                    return None;
                }
                self.active[replica] = false;
                self.report.leaves += 1;
                trace::instant_at(
                    "sim",
                    "leave",
                    replica as u32,
                    0,
                    t * 1e6,
                    vec![trace::u("replica", replica as u64)],
                );
                Some(replica)
            }
            ChurnSpec::None => None,
        }
    }

    /// Step barrier: apply due leaves, integrate due rejoins (paying
    /// their state sync), and never start a step with zero members.
    fn barrier(&mut self) -> Result<f64> {
        let mut barrier = self.clock;
        loop {
            if let Some(tl) = self.peek_leave() {
                if tl <= barrier {
                    self.fire_leave(tl);
                    continue;
                }
            }
            // earliest due rejoin
            let mut due: Option<usize> = None;
            for (i, (t, _)) in self.pending_rejoin.iter().enumerate() {
                if *t <= barrier {
                    let better = match due {
                        None => true,
                        Some(j) => *t < self.pending_rejoin[j].0,
                    };
                    if better {
                        due = Some(i);
                    }
                }
            }
            if let Some(i) = due {
                let (rt, r) = self.pending_rejoin.swap_remove(i);
                if self.active[r] {
                    continue; // scripted rejoin of a present member
                }
                let dur = self.sync_duration(r);
                self.report.sync_seconds += dur;
                self.report.rejoins += 1;
                self.active[r] = true;
                trace::span_at(
                    "sim",
                    "state-sync",
                    r as u32,
                    0,
                    rt * 1e6,
                    dur * 1e6,
                    vec![trace::u("replica", r as u64)],
                );
                if rt + dur > barrier {
                    barrier = rt + dur;
                }
                continue;
            }
            if self.active_count() == 0 {
                // idle until somebody comes back
                let next = self
                    .pending_rejoin
                    .iter()
                    .map(|(t, _)| *t)
                    .fold(f64::INFINITY, f64::min);
                if !next.is_finite() {
                    bail!("every replica left and none is scheduled back");
                }
                if next > barrier {
                    barrier = next;
                }
                continue;
            }
            break;
        }
        Ok(barrier)
    }

    /// State-sync transfer for a rejoining replica: parameters plus
    /// both Adam moments, priced under `dp_mode`, over the replica's
    /// ring link.
    fn sync_duration(&mut self, r: usize) -> f64 {
        let h = &self.spec.hyper;
        let total_params: usize =
            (0..h.stages).map(|s| stage_param_count(h, s)).sum();
        let bytes = dp_wire_bytes(
            self.spec.dp_mode,
            3 * total_params,
            h.d,
            h.k,
            h.ratio,
        );
        let (ser, lat) = self.ring.links[r]
            .sample_jittered(bytes, self.spec.lat_jitter_frac);
        ser + lat
    }

    /// Per-replica step costs at this barrier instant.
    fn build_spec(&mut self, r: usize, barrier: f64) -> StepSpec {
        let spec = self.spec;
        let h = &spec.hyper;
        let p = h.stages;
        let m = spec.microbatches;
        let chunks = match spec.schedule {
            Schedule::Interleaved { chunks } => chunks,
            _ => 1,
        };
        let vstages = p * chunks;
        let tm = spec.time_model.scaled_at(&spec.profile_of(r), barrier);
        let compressed = spec.mode.compressed();
        let bbytes = wire_bytes(spec.mode, h.b, h.n, h.d, h.k, h.ratio);
        let cf = chunks as f64;

        let mut fwd = vec![vec![0.0; m]; vstages];
        let mut bwd = vec![vec![0.0; m]; vstages];
        for v in 0..vstages {
            let s = v % p;
            let (f, b) = if v == vstages - 1 {
                // the final chunk carries the fused fwd+loss+bwd
                let fused =
                    stage_seconds(tm, h, s, Phase::LastLoss, compressed, None);
                (fused / cf, 0.0)
            } else {
                (
                    stage_seconds(tm, h, s, Phase::Fwd, compressed, None) / cf,
                    stage_seconds(tm, h, s, Phase::Bwd, compressed, None) / cf,
                )
            };
            for mb in 0..m {
                fwd[v][mb] = f;
                bwd[v][mb] = b;
            }
        }
        let opt: Vec<f64> = (0..p)
            .map(|s| stage_seconds(tm, h, s, Phase::Opt, compressed, None))
            .collect();

        // sample every transfer from the replica's persistent per-link
        // streams; interleaved vlinks share physical links (chunk c's
        // boundary c·P+P−1 → next chunk crosses the wrap link P−1)
        let mut tx_fwd = vec![vec![Tx::default(); m]; vstages - 1];
        let mut tx_bwd = vec![vec![Tx::default(); m]; vstages - 1];
        let mut wire = 0u64;
        for vl in 0..vstages - 1 {
            let link = vl % p;
            for mb in 0..m {
                let (ser, lat) = self.pipe_links[r][link]
                    .sample_jittered(bbytes, spec.lat_jitter_frac);
                tx_fwd[vl][mb] = Tx { ser, lat };
                let (ser, lat) = self.pipe_links[r][link]
                    .sample_jittered(bbytes, spec.lat_jitter_frac);
                tx_bwd[vl][mb] = Tx { ser, lat };
                wire += 2 * bbytes as u64;
            }
        }
        self.report.wire_bytes += wire;

        StepSpec {
            workers: p,
            vstages,
            microbatches: m,
            worker_of: (0..vstages).map(|v| v % p).collect(),
            phys_link_of: (0..vstages - 1).map(|v| v % p).collect(),
            n_phys_links: if chunks == 1 { p - 1 } else { p },
            fwd,
            bwd,
            tx_fwd,
            tx_bwd,
            opt,
            tail: 0.0,
            schedule: spec.schedule,
        }
    }

    /// One hybrid step; returns its wall seconds.
    fn step(&mut self, is_last: bool) -> Result<f64> {
        let spec = self.spec;
        let h = &spec.hyper;
        let p = h.stages;
        let t_sched = self.clock;
        let step_idx = self.report.step_seconds.len() as u64;
        // captured before the barrier so rejoin state-sync bytes (which
        // cross ring links inside barrier()) land in this step's delta
        let dp_before = self.ring.total_bytes();
        let barrier = self.barrier()?;

        let members: Vec<usize> =
            (0..spec.replicas).filter(|r| self.active[*r]).collect();
        if members.len() < self.report.min_active
            || self.report.step_seconds.is_empty()
        {
            self.report.min_active = members.len();
        }

        // --- pipelines (event-driven) ---
        let mut makespans: Vec<(usize, Makespan)> =
            Vec::with_capacity(members.len());
        for &r in &members {
            let sspec = self.build_spec(r, barrier);
            let ms = simulate_step_spec(&sspec)?;
            self.report.compute += ms.compute;
            self.report.comm_ser += ms.comm_ser;
            trace::span_at(
                "sim",
                "pipeline",
                r as u32,
                0,
                barrier * 1e6,
                ms.total * 1e6,
                vec![
                    trace::u("step", step_idx),
                    trace::u("replica", r as u64),
                ],
            );
            makespans.push((r, ms));
        }
        let serial_bound = makespans
            .iter()
            .map(|(_, ms)| ms.total - ms.overhead)
            .fold(0.0, f64::max);

        // --- overlapped ring all-reduce with churn ---
        let payloads: Vec<usize> = (0..p)
            .map(|s| {
                dp_wire_bytes(
                    spec.dp_mode,
                    stage_param_count(h, s),
                    h.d,
                    h.k,
                    h.ratio,
                )
            })
            .collect();
        let mut live: Vec<usize> = members.clone();
        let mut left_at: Vec<(usize, f64)> = Vec::new();
        let mut done = vec![false; p];
        if matches!(spec.reduce, Reduce::None) {
            // pipelines only: no gradient exchange to schedule
            done.fill(true);
        }
        let mut ring_free = barrier;
        let mut reduced_any = false;
        let ready_of = |live: &[usize], ms: &[(usize, Makespan)], s: usize| {
            barrier
                + ms.iter()
                    .filter(|(r, _)| live.contains(r))
                    .map(|(_, m)| m.grad_ready.get(s).copied().unwrap_or(0.0))
                    .fold(0.0, f64::max)
        };
        loop {
            // next pending stage by (ready, stage)
            let mut next: Option<(f64, usize)> = None;
            for s in 0..p {
                if done[s] {
                    continue;
                }
                let rdy = ready_of(&live, &makespans, s);
                if next.is_none() || rdy < next.unwrap().0 {
                    next = Some((rdy, s));
                }
            }
            let (rdy, s) = match next {
                Some(n) => n,
                None => break,
            };
            if live.len() <= 1 {
                // nobody to reduce with: remaining stages are free
                done.fill(true);
                break;
            }
            let start = if rdy > ring_free { rdy } else { ring_free };
            // leaves up to the start land before any work is risked
            if let Some(tl) = self.peek_leave() {
                if tl <= start {
                    if let Some(victim) = self.fire_leave(tl) {
                        live.retain(|r| *r != victim);
                        left_at.push((victim, tl));
                    }
                    continue;
                }
            }
            let dur = match spec.reduce {
                Reduce::Gossip { degree } => {
                    // seeded pairing rounds over the full replica set
                    // (the wire schedule: dead members drop out of a
                    // pair, never out of the shuffle), filtered to the
                    // live pairs — same `gossip_pairs` stream the real
                    // grid draws, so degree = 1 round g = 0 matches the
                    // transport schedule exactly
                    let mut total = 0.0;
                    for g in 0..degree as u64 {
                        let pairs: Vec<(usize, usize)> = gossip_pairs(
                            spec.seed,
                            step_idx * degree as u64 + g,
                            spec.replicas,
                        )
                        .into_iter()
                        .filter(|&(a, b)| {
                            live.contains(&a) && live.contains(&b)
                        })
                        .collect();
                        total += self.ring.gossip_among(
                            &pairs,
                            payloads[s],
                            spec.lat_jitter_frac,
                        );
                    }
                    total
                }
                _ => self.ring.all_reduce_among(
                    &live,
                    payloads[s],
                    spec.lat_jitter_frac,
                ),
            };
            // a leave landing mid-all-reduce aborts it: the elapsed
            // rounds are wasted and the stage restarts on the
            // re-routed (smaller) ring
            if let Some(tl) = self.peek_leave() {
                if tl > start && tl < start + dur {
                    if let Some(victim) = self.fire_leave(tl) {
                        self.report.allreduce_restarts += 1;
                        self.report.allreduce_busy += tl - start;
                        live.retain(|r| *r != victim);
                        left_at.push((victim, tl));
                        ring_free = tl;
                        continue;
                    }
                }
            }
            self.report.allreduce_busy += dur;
            ring_free = start + dur;
            done[s] = true;
            reduced_any = true;
            trace::span_at(
                "sim",
                match spec.reduce {
                    Reduce::Gossip { .. } => "gossip",
                    _ => "all-reduce",
                },
                0,
                s as u32,
                start * 1e6,
                dur * 1e6,
                vec![
                    trace::u("step", step_idx),
                    trace::u("stage", s as u64),
                    trace::u("bytes", payloads[s] as u64),
                ],
            );
        }

        // --- step end: slowest surviving pipeline vs last all-reduce ---
        let pipe_end = |r: usize, ms: &[(usize, Makespan)]| {
            ms.iter()
                .find(|(rr, _)| *rr == r)
                .map(|(_, m)| barrier + m.total)
                .unwrap_or(barrier)
        };
        // a member that left before its own pipeline drained never
        // finished the step — its contribution to the drain is dropped
        let compute_end_over = |left_at: &[(usize, f64)]| -> f64 {
            let mut end = barrier;
            for &r in members.iter() {
                let pe = pipe_end(r, &makespans);
                let left_before =
                    left_at.iter().any(|(rr, t)| *rr == r && *t < pe);
                if !left_before {
                    end = end.max(pe);
                }
            }
            end
        };
        let mut compute_end = compute_end_over(&left_at);
        let comm_end = if reduced_any { ring_free } else { barrier };
        let mut step_end = compute_end.max(comm_end);
        // leaves in the pure-compute tail after the last all-reduce: a
        // crash at tl drops the crasher's contribution, but the step
        // still ends no earlier than tl — the survivors were waiting on
        // the crasher until the failure was detected, so the barrier
        // cannot retroactively move before the crash instant
        loop {
            let tl = match self.peek_leave() {
                Some(t) if t <= step_end => t,
                _ => break,
            };
            if let Some(victim) = self.fire_leave(tl) {
                let dropped_pending = pipe_end(victim, &makespans) > tl;
                live.retain(|r| *r != victim);
                left_at.push((victim, tl));
                compute_end = compute_end_over(&left_at);
                step_end = compute_end.max(comm_end);
                if dropped_pending && tl > step_end {
                    // survivors were stalled on the crasher until the
                    // failure was detected at tl
                    step_end = tl;
                }
            }
        }

        self.report.dp_bytes += self.ring.total_bytes() - dp_before;
        if is_last {
            self.report.compute_end = compute_end - barrier;
            self.report.comm_end =
                if reduced_any { comm_end - barrier } else { 0.0 };
            self.report.tail = step_end - compute_end;
            self.report.grad_ready = (0..p)
                .map(|s| {
                    makespans
                        .iter()
                        .map(|(_, m)| {
                            m.grad_ready.get(s).copied().unwrap_or(0.0)
                        })
                        .fold(0.0, f64::max)
                })
                .collect();
        }
        self.report.overhead += (step_end - barrier) - serial_bound;
        trace::span_at(
            "sim",
            "step",
            0,
            0,
            t_sched * 1e6,
            (step_end - t_sched) * 1e6,
            vec![trace::u("step", step_idx)],
        );
        self.clock = step_end;
        Ok(step_end - t_sched)
    }

    fn run(mut self) -> Result<SimReport> {
        let steps = self.spec.steps;
        for i in 0..steps {
            let dt = self.step(i + 1 == steps)?;
            self.report.step_seconds.push(dt);
        }
        self.report.steps = steps;
        self.report.total = self.clock;
        Ok(self.report)
    }
}

/// Exponential inter-arrival sample for a Poisson process of `rate`/s.
fn exp_sample(rng: &mut Rng, rate: f64) -> f64 {
    let u = rng.uniform();
    -(1.0 - u).ln() / rate
}

/// Run one swarm simulation end-to-end.
pub fn simulate_swarm(spec: &SwarmSpec) -> Result<SimReport> {
    spec.validate()?;
    Swarm::new(spec).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::MBPS;

    fn quiet(bw_mbps: f64) -> LinkSpec {
        LinkSpec {
            bandwidth_bps: bw_mbps * MBPS,
            latency_s: 2e-3,
            jitter_frac: 0.0,
        }
    }

    fn quiet_spec(replicas: usize, bw_mbps: f64) -> SwarmSpec {
        let mut s =
            SwarmSpec::uniform(Hyper::base_sim(), replicas, bw_mbps * MBPS);
        s.link = quiet(bw_mbps);
        s.ring_link = quiet(bw_mbps);
        s
    }

    #[test]
    fn gossip_reduce_moves_fewer_dp_bytes_than_the_ring() {
        let mut ring = quiet_spec(4, 80.0);
        ring.steps = 3;
        let mut gossip = ring.clone();
        gossip.reduce = Reduce::Gossip { degree: 1 };
        let a = simulate_swarm(&ring).unwrap();
        let b = simulate_swarm(&gossip).unwrap();
        // R = 4 ring: 4 links × 2·3 rounds × ⌈payload/4⌉ ≈ 6·payload
        // per stage; one gossip round: 2 pairs × 2 dirs × payload =
        // 4·payload — gossip strictly cheaper on the wire
        assert!(b.dp_bytes > 0);
        assert!(b.dp_bytes < a.dp_bytes, "{} vs {}", b.dp_bytes, a.dp_bytes);
        // R = 4 always shuffles into 2 pairs, so wire bytes scale
        // linearly in the gossip degree
        let mut twice = ring.clone();
        twice.reduce = Reduce::Gossip { degree: 2 };
        let c = simulate_swarm(&twice).unwrap();
        assert_eq!(c.dp_bytes, 2 * b.dp_bytes);
        // pipelines are untouched by the reduce choice
        assert_eq!(a.min_active, b.min_active);
        // and `none` schedules no exchange at all
        let mut none = ring.clone();
        none.reduce = Reduce::None;
        let d = simulate_swarm(&none).unwrap();
        assert_eq!(d.dp_bytes, 0);
        assert!(d.allreduce_busy == 0.0);
    }

    #[test]
    fn churn_timeline_parses_and_roundtrips() {
        let t = ChurnTimeline::parse("kill:1@15, join:1@16").unwrap();
        assert_eq!(
            t.events,
            vec![
                StepChurn { step: 15, worker: 1, kind: ChurnKind::Leave },
                StepChurn { step: 16, worker: 1, kind: ChurnKind::Rejoin },
            ]
        );
        assert_eq!(t.to_script(), "kill:1@15,join:1@16");
        assert_eq!(ChurnTimeline::parse(&t.to_script()).unwrap(), t);
        assert_eq!(t.kills_at(15), vec![1]);
        assert!(t.kills_at(16).is_empty());
        assert_eq!(t.leaves(), 1);
        // events come back sorted by step regardless of input order
        let t = ChurnTimeline::parse("join:0@9,kill:0@3").unwrap();
        assert_eq!(t.events[0].step, 3);
        assert!(ChurnTimeline::parse("").unwrap().is_empty());
        for bad in ["kill1@2", "boom:1@2", "kill:x@2", "kill:1@y"] {
            assert!(ChurnTimeline::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn churn_timeline_validates_against_run_shape() {
        let t = ChurnTimeline::parse("kill:2@5").unwrap();
        assert!(t.validate(3, 10).is_ok());
        assert!(t.validate(2, 10).unwrap_err().to_string().contains("worker"));
        assert!(t.validate(3, 5).unwrap_err().to_string().contains("step"));
    }

    #[test]
    fn churn_timeline_lowers_to_mid_step_scripted_events() {
        let t = ChurnTimeline::parse("kill:1@4,join:1@6").unwrap();
        let ChurnSpec::Scripted(events) = t.to_scripted(2.0) else {
            panic!("expected scripted churn");
        };
        assert_eq!(events.len(), 2);
        assert!((events[0].time - 9.0).abs() < 1e-12); // (4+0.5)·2
        assert_eq!(events[0].replica, 1);
        assert_eq!(events[0].kind, ChurnKind::Leave);
        assert!((events[1].time - 13.0).abs() < 1e-12);
        assert_eq!(events[1].kind, ChurnKind::Rejoin);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut spec = quiet_spec(4, 80.0);
        spec.link.jitter_frac = 0.2; // jittered, still deterministic
        spec.lat_jitter_frac = 0.2;
        spec.steps = 3;
        spec.churn =
            ChurnSpec::Poisson { rate_per_s: 0.5, downtime_s: 0.4 };
        let a = simulate_swarm(&spec).unwrap();
        let b = simulate_swarm(&spec).unwrap();
        assert_eq!(a.total, b.total);
        assert_eq!(a.step_seconds, b.step_seconds);
        assert_eq!(a.leaves, b.leaves);
        assert_eq!(a.allreduce_restarts, b.allreduce_restarts);
    }

    #[test]
    fn zero_bandwidth_link_is_an_error() {
        let mut spec = quiet_spec(2, 80.0);
        spec.link.bandwidth_bps = 0.0;
        let err = simulate_swarm(&spec).unwrap_err();
        assert!(err.to_string().contains("bandwidth"), "{err}");
        let mut spec = quiet_spec(2, 80.0);
        spec.ring_link.bandwidth_bps = f64::NAN;
        assert!(simulate_swarm(&spec).is_err());
    }

    #[test]
    fn multi_step_clock_accumulates() {
        let mut spec = quiet_spec(2, 300.0);
        spec.steps = 4;
        let rep = simulate_swarm(&spec).unwrap();
        assert_eq!(rep.steps, 4);
        assert_eq!(rep.step_seconds.len(), 4);
        let sum: f64 = rep.step_seconds.iter().sum();
        assert!((rep.total - sum).abs() < 1e-9);
        // undisturbed homogeneous steps all cost the same
        for w in rep.step_seconds.windows(2) {
            assert!((w[0] - w[1]).abs() < 1e-9, "{:?}", rep.step_seconds);
        }
    }

    #[test]
    fn time_varying_straggler_kicks_in_mid_run() {
        let mut spec = quiet_spec(2, 16_000.0);
        // compute-bound and latency-free so the 2x factor shows cleanly
        spec.link.latency_s = 0.0;
        spec.ring_link.latency_s = 0.0;
        spec.steps = 4;
        let base = simulate_swarm(&spec).unwrap();
        let step = base.step_seconds[0];
        // replica 1 degrades 2x from just after step 2's start
        let onset = step * 1.5;
        spec.straggler = vec![
            SlowdownProfile::nominal(),
            SlowdownProfile::Phases(vec![(onset, 2.0)]),
        ];
        let slow = simulate_swarm(&spec).unwrap();
        assert!(
            (slow.step_seconds[0] - step).abs() < 1e-9,
            "step 1 unaffected"
        );
        assert!(
            slow.step_seconds[3] > 1.8 * step,
            "late steps straggled: {:?}",
            slow.step_seconds
        );
    }

    #[test]
    fn scripted_leave_shrinks_membership_and_rejoin_pays_sync() {
        let mut spec = quiet_spec(4, 80.0);
        spec.steps = 3;
        let base = simulate_swarm(&spec).unwrap();
        let step = base.step_seconds[0];
        // replica 2 leaves early in step 2 and is back before step 2
        // ends, so step 3's barrier integrates it (paying the sync)
        spec.churn = ChurnSpec::Scripted(vec![
            ChurnEvent {
                time: step * 1.01,
                replica: 2,
                kind: ChurnKind::Leave,
            },
            ChurnEvent {
                time: step * 1.2,
                replica: 2,
                kind: ChurnKind::Rejoin,
            },
        ]);
        let churned = simulate_swarm(&spec).unwrap();
        assert_eq!(churned.leaves, 1);
        assert_eq!(churned.rejoins, 1);
        assert!(churned.sync_seconds > 0.0);
        // every step *started* with full membership (the leave landed
        // mid-step and the rejoin was integrated by the next barrier)
        assert_eq!(churned.min_active, 4);
        assert!(churned.total > 0.0 && base.total > 0.0);
    }

    #[test]
    fn poisson_rate_zero_is_no_churn() {
        let mut spec = quiet_spec(3, 80.0);
        spec.steps = 2;
        let base = simulate_swarm(&spec).unwrap();
        spec.churn = ChurnSpec::Poisson { rate_per_s: 0.0, downtime_s: 1.0 };
        let z = simulate_swarm(&spec).unwrap();
        assert_eq!(z.leaves, 0);
        assert_eq!(z.total, base.total);
    }

    #[test]
    fn last_member_never_leaves() {
        let mut spec = quiet_spec(1, 80.0);
        spec.steps = 3;
        spec.churn = ChurnSpec::Poisson { rate_per_s: 100.0, downtime_s: 0.1 };
        let rep = simulate_swarm(&spec).unwrap();
        assert_eq!(rep.leaves, 0, "a 1-replica swarm cannot shrink");
        assert_eq!(rep.min_active, 1);

        // the scripted path enforces the same invariant: the second
        // leave would empty the swarm and is skipped
        let mut spec = quiet_spec(2, 80.0);
        spec.steps = 2;
        spec.churn = ChurnSpec::Scripted(vec![
            ChurnEvent { time: 0.01, replica: 0, kind: ChurnKind::Leave },
            ChurnEvent { time: 0.02, replica: 1, kind: ChurnKind::Leave },
        ]);
        let rep = simulate_swarm(&spec).unwrap();
        assert_eq!(rep.leaves, 1);
        assert_eq!(rep.min_active, 1);
    }

    #[test]
    fn interleaved_swarm_runs_and_pays_more_comm() {
        // comm-bound regime: interleaved crosses every boundary twice
        let mut g = quiet_spec(2, 20.0);
        let mut i = quiet_spec(2, 20.0);
        i.schedule = Schedule::Interleaved { chunks: 2 };
        g.steps = 1;
        i.steps = 1;
        let rg = simulate_swarm(&g).unwrap();
        let ri = simulate_swarm(&i).unwrap();
        assert!(
            ri.comm_ser > 1.9 * rg.comm_ser,
            "interleaved comm {} vs gpipe {}",
            ri.comm_ser,
            rg.comm_ser
        );
        assert!(ri.total > 0.0 && rg.total > 0.0);
    }
}
