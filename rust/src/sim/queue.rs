//! Deterministic discrete-event queue.
//!
//! A binary heap keyed by `(time, seq)`: events fire in simulated-time
//! order, and events scheduled for the *same* instant fire in the order
//! they were pushed (`seq` is a monotonically increasing push counter).
//! That tie-break is what makes every simulation replayable — two runs
//! of the same spec produce the same event trace, byte for byte, no
//! matter how many ties the schedule generates.

use std::collections::BinaryHeap;

/// One scheduled event: fire time, push sequence number, payload.
struct Entry<E> {
    time: f64,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed: BinaryHeap is a max-heap, we want the earliest
        // (time, seq) on top. Times are asserted finite on push, so
        // partial_cmp never sees NaN.
        other
            .time
            .partial_cmp(&self.time)
            .expect("event times are finite by construction")
            .then(other.seq.cmp(&self.seq))
    }
}

/// Min-heap of `(time, seq, payload)` with deterministic tie-breaking.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        EventQueue::new()
    }
}

impl<E> EventQueue<E> {
    /// Empty queue; sequence numbers start at 0.
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    /// Schedule `payload` at `time`. Panics on non-finite times — an
    /// infinite event time always means an upstream modeling error
    /// (e.g. a zero-bandwidth link), which specs validate before
    /// simulating.
    pub fn push(&mut self, time: f64, payload: E) {
        assert!(
            time.is_finite(),
            "event time must be finite, got {time} (zero-bandwidth link?)"
        );
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { time, seq, payload });
    }

    /// Pop the earliest event (ties in push order); `None` when empty.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|e| (e.time, e.payload))
    }

    /// Fire time of the next event, if any.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_queue_pops_none() {
        let mut q: EventQueue<u32> = EventQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.len(), 0);
        assert!(q.pop().is_none());
        assert!(q.peek_time().is_none());
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.peek_time(), Some(1.0));
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((3.0, "c")));
        assert!(q.pop().is_none());
    }

    #[test]
    fn simultaneous_events_fire_in_push_order() {
        let mut q = EventQueue::new();
        for i in 0..64u32 {
            q.push(1.5, i);
        }
        // interleave an earlier and a later event among the ties
        q.push(0.5, 1000);
        q.push(2.5, 2000);
        assert_eq!(q.pop(), Some((0.5, 1000)));
        for i in 0..64u32 {
            assert_eq!(q.pop(), Some((1.5, i)), "tie {i} out of order");
        }
        assert_eq!(q.pop(), Some((2.5, 2000)));
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn non_finite_time_is_rejected() {
        let mut q = EventQueue::new();
        q.push(f64::INFINITY, ());
    }
}
