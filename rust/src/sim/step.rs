//! Event-driven execution of one pipeline step.
//!
//! Where `schedule::gpipe_makespan` *solves* the GPipe timing with a
//! closed-form recurrence, this engine *executes* it: every compute and
//! every transfer is an event on a [`EventQueue`], workers dispatch the
//! next ready task when they free up, and links serialize transfers in
//! the order their producers complete. The payoff is generality — the
//! same machine runs 1F1B and interleaved schedules (which have no
//! closed form here) and, via [`crate::sim::swarm`], multi-replica
//! steps with jitter and churn.
//!
//! **Parity contract** (enforced by `tests/sim_swarm.rs`): under
//! [`Schedule::Gpipe`] this engine reproduces `gpipe_makespan` exactly
//! (same floating-point operations on the same values) for *any*
//! `StepCosts`, jittered or not. The analytic recurrence resolves the
//! identical precedence DAG — stages serially busy, per-direction links
//! serializing in microbatch order, backwards gated behind the stage's
//! full forward wave — so the two paths must agree to the last bit.
//!
//! Schedule semantics:
//! - `Gpipe` — a stage starts backwards only after all M of its
//!   forwards completed (fill then drain; the analytic model).
//! - `OneFOneB` — backwards are eligible as soon as their gradient
//!   arrives, and each (virtual) stage caps in-flight forwards at its
//!   pipeline-depth remainder `min(V − v, M)`; backwards take priority,
//!   which yields the classic warmup / steady-1F1B / drain pattern.
//! - `Interleaved { chunks }` — each worker hosts `chunks` model chunks
//!   (virtual stages `c·P + w`), halving the per-chunk bubble at the
//!   price of `chunks`× as many boundary crossings, including the
//!   wrap-around link from worker P−1 back to worker 0. Virtual-chunk
//!   compute is an even split of the physical stage cost.

use anyhow::{bail, Result};

use crate::coordinator::schedule::{Makespan, StepCosts, Tx};
use crate::sim::queue::EventQueue;

/// Pipeline schedule executed by the event engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// fill-then-drain GPipe (the analytic-parity schedule)
    Gpipe,
    /// one-forward-one-backward with depth-capped in-flight forwards
    OneFOneB,
    /// interleaved virtual pipeline with `chunks` model chunks per worker
    Interleaved {
        /// model chunks per worker (≥ 2)
        chunks: usize,
    },
}

impl Schedule {
    /// Parse a CLI label: `"gpipe"`, `"1f1b"`, `"interleaved"` (2
    /// chunks) or `"interleaved:<chunks>"`.
    pub fn parse(s: &str) -> Option<Schedule> {
        match s {
            "gpipe" => Some(Schedule::Gpipe),
            "1f1b" => Some(Schedule::OneFOneB),
            "interleaved" => Some(Schedule::Interleaved { chunks: 2 }),
            other => {
                let rest = other.strip_prefix("interleaved:")?;
                let chunks: usize = rest.parse().ok()?;
                if chunks < 2 {
                    return None;
                }
                Some(Schedule::Interleaved { chunks })
            }
        }
    }

    /// Canonical CSV/CLI label (chunk count elided — use `Display` for
    /// the faithful round-trip form).
    pub fn as_str(&self) -> &'static str {
        match self {
            Schedule::Gpipe => "gpipe",
            Schedule::OneFOneB => "1f1b",
            Schedule::Interleaved { .. } => "interleaved",
        }
    }

    /// Representative schedules the exhaustive `FromStr`/`Display`
    /// round-trip property sweeps (interleaved is parameterized, so two
    /// chunk widths stand in for the family). A new variant that misses
    /// `parse`/`Display` fails the test instead of silently falling
    /// back to string matching at a CLI site.
    pub const ALL: [Schedule; 4] = [
        Schedule::Gpipe,
        Schedule::OneFOneB,
        Schedule::Interleaved { chunks: 2 },
        Schedule::Interleaved { chunks: 4 },
    ];
}

impl std::str::FromStr for Schedule {
    type Err = anyhow::Error;

    /// The canonical parse: `"1f1b".parse::<Schedule>()` — same table
    /// as [`Schedule::parse`], exposed through the standard trait so
    /// CLI sites compare parsed values instead of matching strings.
    fn from_str(s: &str) -> Result<Schedule> {
        Schedule::parse(s).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown schedule {s:?} (expected \
                 gpipe|1f1b|interleaved[:chunks], chunks >= 2)"
            )
        })
    }
}

impl std::fmt::Display for Schedule {
    /// Faithful round-trip form: `interleaved:<chunks>` keeps the chunk
    /// count `as_str` elides, so `format!("{s}").parse()` reproduces
    /// the value exactly.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Schedule::Interleaved { chunks } => {
                write!(f, "interleaved:{chunks}")
            }
            other => f.write_str(other.as_str()),
        }
    }
}

/// Fully-resolved inputs of one event-simulated step: per-virtual-stage
/// compute seconds and per-transfer link samples. Virtual stage `v`
/// runs on worker `worker_of[v]`; virtual link `v` (vstage v → v+1)
/// serializes on duplex physical link `phys_link_of[v]`.
#[derive(Clone, Debug)]
pub struct StepSpec {
    /// physical compute hosts P
    pub workers: usize,
    /// virtual stages V (== P except for interleaved schedules)
    pub vstages: usize,
    /// microbatches per step M
    pub microbatches: usize,
    /// vstage → worker
    pub worker_of: Vec<usize>,
    /// vlink → physical duplex link (serialization resource)
    pub phys_link_of: Vec<usize>,
    /// number of physical duplex links
    pub n_phys_links: usize,
    /// fwd compute seconds; the *last* vstage holds the fused
    /// fwd+loss+bwd cost (as in `StepCosts`)
    pub fwd: Vec<Vec<f64>>, // [vstage][mb]
    /// bwd compute seconds (last vstage unused — fused)
    pub bwd: Vec<Vec<f64>>, // [vstage][mb]
    /// activation transfer samples per vlink
    pub tx_fwd: Vec<Vec<Tx>>, // [vlink][mb]
    /// gradient transfer samples per vlink
    pub tx_bwd: Vec<Vec<Tx>>, // [vlink][mb]
    /// per-worker optimizer seconds (after the worker's last task)
    pub opt: Vec<f64>,
    /// serial seconds appended at the very end (Grassmann + broadcast)
    pub tail: f64,
    /// dispatch policy
    pub schedule: Schedule,
}

impl StepSpec {
    /// Identity mapping from the coordinator's `StepCosts`: V == P,
    /// vlink v is physical link v. `Interleaved` cannot be built from
    /// `StepCosts` (its wrap link has no sample source there) — use the
    /// swarm engine, which samples links itself.
    pub fn from_costs(c: &StepCosts, schedule: Schedule) -> Result<StepSpec> {
        if let Schedule::Interleaved { .. } = schedule {
            bail!(
                "interleaved schedules need wrap-link samples the \
                 coordinator's StepCosts does not carry; use the swarm \
                 simulator (`protomodels sim` / `exp sim-grid`)"
            );
        }
        let p = c.stages;
        if p < 2 {
            bail!("pipeline needs >= 2 stages, got {p}");
        }
        if c.microbatches == 0 {
            bail!("step needs >= 1 microbatch");
        }
        Ok(StepSpec {
            workers: p,
            vstages: p,
            microbatches: c.microbatches,
            worker_of: (0..p).collect(),
            phys_link_of: (0..p - 1).collect(),
            n_phys_links: p - 1,
            fwd: c.fwd.clone(),
            bwd: c.bwd.clone(),
            tx_fwd: c.tx_fwd.clone(),
            tx_bwd: c.tx_bwd.clone(),
            opt: c.opt.clone(),
            tail: c.tail,
            schedule,
        })
    }
}

/// Task class in the predicted timeline. At the *last* virtual stage
/// `Fwd` is the fused fwd+loss+bwd task (as in `StepCosts`), so a full
/// step has `V·M` forwards and `(V−1)·M` explicit backwards.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Class {
    /// forward compute (fused fwd+loss+bwd at the last vstage)
    Fwd,
    /// explicit backward compute
    Bwd,
}

/// One dispatched task in the engine's predicted timeline: vstage,
/// microbatch, class, and the `[start, end)` interval in simulated
/// seconds. Produced by [`simulate_step_timeline`]; `obs::diff`
/// compares these placements against a recorded trace's spans.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TaskSpan {
    /// virtual stage the task ran on
    pub v: usize,
    /// microbatch index
    pub mb: usize,
    /// forward (fused at the last vstage) or backward
    pub class: Class,
    /// dispatch time, simulated seconds
    pub start: f64,
    /// completion time, simulated seconds
    pub end: f64,
}

#[derive(Clone, Copy, Debug)]
enum Event {
    /// worker finished a task
    TaskDone { v: usize, mb: usize, class: Class },
    /// a payload arrived at vstage v, making its task ready
    Arrive { v: usize, mb: usize, class: Class },
}

/// Per-worker ready key. Ordering encodes dispatch priority within a
/// class set; class priority itself is schedule-dependent and applied
/// at selection time.
type Key = (Class, usize, usize); // (class, mb, vstage)

struct Engine<'a> {
    spec: &'a StepSpec,
    q: EventQueue<Event>,
    worker_busy: Vec<bool>,
    ready: Vec<std::collections::BTreeSet<Key>>,
    fwd_started: Vec<usize>,
    fwd_done: Vec<usize>,
    bwd_started: Vec<usize>,
    bwd_done: Vec<usize>,
    link_free_f: Vec<f64>,
    link_free_b: Vec<f64>,
    /// per-worker completion time of its most recent task
    last_done: Vec<f64>,
    /// per-vstage completion time of its latest gradient (bwd / fused)
    grad_done_v: Vec<f64>,
    tasks_done: usize,
    /// when `Some`, every dispatched task is recorded (obs::diff)
    timeline: Option<Vec<TaskSpan>>,
}

impl<'a> Engine<'a> {
    fn new(spec: &'a StepSpec) -> Engine<'a> {
        Engine {
            spec,
            q: EventQueue::new(),
            worker_busy: vec![false; spec.workers],
            ready: vec![Default::default(); spec.workers],
            fwd_started: vec![0; spec.vstages],
            fwd_done: vec![0; spec.vstages],
            bwd_started: vec![0; spec.vstages],
            bwd_done: vec![0; spec.vstages],
            link_free_f: vec![0.0; spec.n_phys_links],
            link_free_b: vec![0.0; spec.n_phys_links],
            last_done: vec![0.0; spec.workers],
            grad_done_v: vec![0.0; spec.vstages],
            tasks_done: 0,
            timeline: None,
        }
    }

    /// In-flight forward cap for vstage v under 1F1B-family schedules.
    fn fwd_cap(&self, v: usize) -> usize {
        let s = self.spec;
        (s.vstages - v).min(s.microbatches).max(1)
    }

    fn eligible(&self, key: &Key) -> bool {
        let (class, mb, v) = *key;
        // microbatches are processed in order per (vstage, class): even
        // if mb+1's payload arrives first (jittered latency can reorder
        // arrivals), the stage waits for mb — the semantics the analytic
        // recurrence encodes, and what a real in-order pipeline does
        match class {
            Class::Fwd if self.fwd_started[v] != mb => return false,
            Class::Bwd if self.bwd_started[v] != mb => return false,
            _ => {}
        }
        match (self.spec.schedule, class) {
            // GPipe: backwards gated behind the stage's full fwd wave
            (Schedule::Gpipe, Class::Bwd) => {
                self.fwd_done[v] == self.spec.microbatches
            }
            (Schedule::Gpipe, Class::Fwd) => true,
            // 1F1B / interleaved: forwards capped by remaining depth
            (_, Class::Fwd) => {
                self.fwd_started[v] - self.bwd_done[v] < self.fwd_cap(v)
            }
            (_, Class::Bwd) => true,
        }
    }

    /// Pick the next task for an idle worker. Class priority: GPipe
    /// prefers forwards (backwards are gated anyway until the wave
    /// ends); 1F1B-family prefers backwards. Within a class: lowest
    /// (mb, vstage) — the `Key` ordering, so both policies walk the
    /// ready set's own order (no allocation in the dispatch hot path:
    /// the Fwd prefix and Bwd suffix are contiguous `range`s).
    fn select(&self, w: usize) -> Option<Key> {
        let set = &self.ready[w];
        if self.spec.schedule == Schedule::Gpipe {
            return set.iter().copied().find(|k| self.eligible(k));
        }
        set.range((Class::Bwd, 0, 0)..)
            .copied()
            .find(|k| self.eligible(k))
            .or_else(|| {
                set.range(..(Class::Bwd, 0, 0))
                    .copied()
                    .find(|k| self.eligible(k))
            })
    }

    fn dispatch(&mut self, w: usize, t: f64) {
        if self.worker_busy[w] {
            return;
        }
        let key = match self.select(w) {
            Some(k) => k,
            None => return,
        };
        self.ready[w].remove(&key);
        let (class, mb, v) = key;
        let dur = match class {
            Class::Fwd => {
                self.fwd_started[v] += 1;
                self.spec.fwd[v][mb]
            }
            Class::Bwd => {
                self.bwd_started[v] += 1;
                self.spec.bwd[v][mb]
            }
        };
        self.worker_busy[w] = true;
        // one shared `end` feeds both the queue and the timeline, so
        // recording adds no fp operation to the parity-contracted path
        let end = t + dur;
        if let Some(tl) = &mut self.timeline {
            tl.push(TaskSpan { v, mb, class, start: t, end });
        }
        self.q.push(end, Event::TaskDone { v, mb, class });
    }

    /// Serialize a transfer on a physical link direction and schedule
    /// its arrival.
    fn send(&mut self, v_from: usize, mb: usize, class: Class, t: f64) {
        let (vlink, v_to) = match class {
            Class::Fwd => (v_from, v_from + 1),
            Class::Bwd => (v_from - 1, v_from - 1),
        };
        let link = self.spec.phys_link_of[vlink];
        let (tx, free) = match class {
            Class::Fwd => {
                (self.spec.tx_fwd[vlink][mb], &mut self.link_free_f[link])
            }
            Class::Bwd => {
                (self.spec.tx_bwd[vlink][mb], &mut self.link_free_b[link])
            }
        };
        let start = if t > *free { t } else { *free };
        *free = start + tx.ser;
        self.q
            .push(start + tx.ser + tx.lat, Event::Arrive { v: v_to, mb, class });
    }

    fn on_task_done(&mut self, v: usize, mb: usize, class: Class, t: f64) {
        let s = self.spec;
        let w = s.worker_of[v];
        self.worker_busy[w] = false;
        self.last_done[w] = t;
        self.tasks_done += 1;
        match class {
            Class::Fwd => {
                self.fwd_done[v] += 1;
                if v + 1 < s.vstages {
                    self.send(v, mb, Class::Fwd, t);
                } else {
                    // fused fwd+loss+bwd at the last vstage: this
                    // completion *is* the microbatch's gradient
                    self.bwd_done[v] += 1;
                    self.grad_done_v[v] = t;
                    if v > 0 {
                        self.send(v, mb, Class::Bwd, t);
                    }
                }
            }
            Class::Bwd => {
                self.bwd_done[v] += 1;
                self.grad_done_v[v] = t;
                if v > 0 {
                    self.send(v, mb, Class::Bwd, t);
                }
            }
        }
        self.dispatch(w, t);
    }

    fn run(&mut self) -> Result<Makespan> {
        let s = self.spec;
        // all first-vstage forwards are ready at t = 0
        for mb in 0..s.microbatches {
            self.ready[s.worker_of[0]].insert((Class::Fwd, mb, 0));
        }
        self.dispatch(s.worker_of[0], 0.0);
        while let Some((t, ev)) = self.q.pop() {
            match ev {
                Event::TaskDone { v, mb, class } => {
                    self.on_task_done(v, mb, class, t)
                }
                Event::Arrive { v, mb, class } => {
                    let w = s.worker_of[v];
                    self.ready[w].insert((class, mb, v));
                    self.dispatch(w, t);
                }
            }
        }
        // every vstage must have completed M forwards and M gradients
        let total_tasks = s.vstages * s.microbatches // forwards
            + (s.vstages - 1) * s.microbatches; // explicit backwards
        if self.tasks_done != total_tasks
            || self.fwd_done.iter().any(|&n| n != s.microbatches)
            || self.bwd_done.iter().any(|&n| n != s.microbatches)
        {
            bail!(
                "pipeline schedule deadlocked: {} of {} tasks completed \
                 (schedule {:?})",
                self.tasks_done,
                total_tasks,
                s.schedule
            );
        }

        let mut end = 0.0f64;
        for w in 0..s.workers {
            end = end.max(self.last_done[w] + s.opt[w]);
        }
        end += s.tail;

        // compute the diagnostics from the spec arrays in the same
        // order as the analytic path, so GPipe parity is exact rather
        // than merely close (event-order accumulation would differ in
        // the last ulp)
        let compute: f64 = s
            .fwd
            .iter()
            .chain(s.bwd.iter().take(s.vstages - 1))
            .map(|v| v.iter().sum::<f64>())
            .sum::<f64>()
            + s.opt.iter().sum::<f64>();
        let comm_ser: f64 = s
            .tx_fwd
            .iter()
            .chain(s.tx_bwd.iter())
            .map(|v| v.iter().map(|t| t.ser).sum::<f64>())
            .sum();
        // per-worker serial compute lower bound (mirrors the analytic
        // accounting: fused last vstage priced in fwd, its bwd excluded)
        let per_worker_max: f64 = (0..s.workers)
            .map(|w| {
                let mut acc = 0.0;
                for v in 0..s.vstages {
                    if s.worker_of[v] != w {
                        continue;
                    }
                    acc += s.fwd[v].iter().sum::<f64>();
                    if v + 1 != s.vstages {
                        acc += s.bwd[v].iter().sum::<f64>();
                    }
                }
                acc + s.opt[w]
            })
            .fold(0.0, f64::max);

        // per-worker gradient-complete instant: latest gradient of any
        // vstage hosted on the worker (for V == P this is the stage's
        // last backward / fused forward, matching the analytic field)
        let grad_ready: Vec<f64> = (0..s.workers)
            .map(|w| {
                (0..s.vstages)
                    .filter(|v| s.worker_of[*v] == w)
                    .map(|v| self.grad_done_v[v])
                    .fold(0.0, f64::max)
            })
            .collect();

        Ok(Makespan {
            total: end,
            comm_ser,
            compute,
            overhead: end - per_worker_max,
            grad_ready,
        })
    }
}

/// Execute one step's `StepSpec` on the event engine.
pub fn simulate_step_spec(spec: &StepSpec) -> Result<Makespan> {
    if spec.vstages < 2 {
        bail!("pipeline needs >= 2 virtual stages, got {}", spec.vstages);
    }
    if spec.microbatches == 0 {
        bail!("step needs >= 1 microbatch");
    }
    Engine::new(spec).run()
}

/// Execute one step and also return the engine's task *placements* —
/// every dispatched (vstage, microbatch, class) with its simulated
/// `[start, end)` interval. The makespan is bit-identical to
/// [`simulate_step_spec`] (recording reuses the engine's own `t + dur`
/// value); `obs::diff` replays a recorded trace against this timeline.
pub fn simulate_step_timeline(
    spec: &StepSpec,
) -> Result<(Makespan, Vec<TaskSpan>)> {
    if spec.vstages < 2 {
        bail!("pipeline needs >= 2 virtual stages, got {}", spec.vstages);
    }
    if spec.microbatches == 0 {
        bail!("step needs >= 1 microbatch");
    }
    let mut engine = Engine::new(spec);
    engine.timeline = Some(Vec::new());
    let ms = engine.run()?;
    Ok((ms, engine.timeline.take().unwrap_or_default()))
}

/// Event-simulate one coordinator step under `schedule` — the drop-in
/// replacement for `gpipe_makespan` used by the pipeline when a
/// non-GPipe schedule (or `--sim`) is requested.
pub fn step_makespan(costs: &StepCosts, schedule: Schedule) -> Result<Makespan> {
    simulate_step_spec(&StepSpec::from_costs(costs, schedule)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::schedule::gpipe_makespan;
    use crate::rng::Rng;

    #[test]
    fn schedule_display_round_trips_exhaustively() {
        for s in Schedule::ALL {
            let text = s.to_string();
            let back: Schedule = text.parse().expect("parse back");
            assert_eq!(back, s, "{text}");
            // as_str is the prefix of the faithful form
            assert!(text.starts_with(s.as_str()));
        }
    }

    #[test]
    fn schedule_parse_rejects_junk_descriptively() {
        for bad in ["pipedream", "interleaved:1", "interleaved:x", ""] {
            let err = bad.parse::<Schedule>().unwrap_err().to_string();
            assert!(err.contains("gpipe|1f1b|interleaved"), "{err}");
        }
        assert_eq!(
            "interleaved".parse::<Schedule>().unwrap(),
            Schedule::Interleaved { chunks: 2 }
        );
    }

    fn uniform_costs(
        p: usize,
        m: usize,
        f: f64,
        b: f64,
        ser: f64,
        lat: f64,
    ) -> StepCosts {
        StepCosts {
            stages: p,
            microbatches: m,
            fwd: vec![vec![f; m]; p],
            bwd: vec![vec![b; m]; p],
            tx_fwd: vec![vec![Tx { ser, lat }; m]; p - 1],
            tx_bwd: vec![vec![Tx { ser, lat }; m]; p - 1],
            opt: vec![0.0; p],
            tail: 0.0,
        }
    }

    fn random_costs(rng: &mut Rng, p: usize, m: usize) -> StepCosts {
        let mut c = uniform_costs(p, m, 0.0, 0.0, 0.0, 0.0);
        for s in 0..p {
            for mb in 0..m {
                c.fwd[s][mb] = 0.01 + rng.uniform();
                c.bwd[s][mb] = 0.01 + 2.0 * rng.uniform();
            }
            c.opt[s] = rng.uniform() * 0.3;
        }
        for l in 0..p - 1 {
            for mb in 0..m {
                c.tx_fwd[l][mb] =
                    Tx { ser: rng.uniform() * 0.5, lat: rng.uniform() * 0.05 };
                c.tx_bwd[l][mb] =
                    Tx { ser: rng.uniform() * 0.5, lat: rng.uniform() * 0.05 };
            }
        }
        c.tail = rng.uniform();
        c
    }

    #[test]
    fn gpipe_event_engine_matches_analytic_exactly() {
        // the parity contract on arbitrary (jittered) costs: identical
        // fp operations → identical results, not just 1e-6-close
        let mut rng = Rng::new(0x51A);
        for (p, m) in [(2usize, 1usize), (2, 8), (3, 4), (4, 8), (6, 16)] {
            for _ in 0..3 {
                let c = random_costs(&mut rng, p, m);
                let analytic = gpipe_makespan(&c);
                let event = step_makespan(&c, Schedule::Gpipe).unwrap();
                assert_eq!(event.total, analytic.total, "p={p} m={m}");
                assert_eq!(event.comm_ser, analytic.comm_ser);
                assert_eq!(event.compute, analytic.compute);
                assert_eq!(event.overhead, analytic.overhead);
                assert_eq!(event.grad_ready, analytic.grad_ready);
            }
        }
    }

    #[test]
    fn all_zero_costs_terminate_with_mass_ties() {
        // every event fires at t = 0: the (time, seq) tie-break must
        // still drive the schedule to completion, deterministically
        let c = uniform_costs(4, 8, 0.0, 0.0, 0.0, 0.0);
        for sched in [Schedule::Gpipe, Schedule::OneFOneB] {
            let a = step_makespan(&c, sched).unwrap();
            let b = step_makespan(&c, sched).unwrap();
            assert_eq!(a.total, 0.0, "{sched:?}");
            assert_eq!(a.total, b.total);
            assert_eq!(a.grad_ready, b.grad_ready);
        }
    }

    #[test]
    fn one_f_one_b_reference_values() {
        // values cross-checked against the python line-port of this
        // engine. With bwd = 3×fwd, 1F1B's depth cap delays forwards
        // and slightly *exceeds* this GPipe variant (which already
        // drains backwards per-arrival); with fwd == bwd they tie.
        let c = uniform_costs(4, 8, 1.0, 3.0, 0.0, 0.0);
        let g = step_makespan(&c, Schedule::Gpipe).unwrap();
        let o = step_makespan(&c, Schedule::OneFOneB).unwrap();
        assert!((g.total - 40.0).abs() < 1e-9, "gpipe {}", g.total);
        assert!((o.total - 42.0).abs() < 1e-9, "1f1b {}", o.total);
        assert_eq!(o.compute, g.compute);

        let c_sym = uniform_costs(4, 8, 1.0, 1.0, 0.0, 0.0);
        let g_sym = step_makespan(&c_sym, Schedule::Gpipe).unwrap();
        let o_sym = step_makespan(&c_sym, Schedule::OneFOneB).unwrap();
        assert!((g_sym.total - 20.0).abs() < 1e-9, "{}", g_sym.total);
        assert!((o_sym.total - 20.0).abs() < 1e-9, "{}", o_sym.total);
    }

    #[test]
    fn timeline_recording_is_exact_and_complete() {
        let mut rng = Rng::new(0x7131);
        for sched in [Schedule::Gpipe, Schedule::OneFOneB] {
            let c = random_costs(&mut rng, 4, 6);
            let spec = StepSpec::from_costs(&c, sched).unwrap();
            let plain = simulate_step_spec(&spec).unwrap();
            let (ms, tl) = simulate_step_timeline(&spec).unwrap();
            // recording must not perturb a single fp operation
            assert_eq!(ms.total, plain.total, "{sched:?}");
            assert_eq!(ms.grad_ready, plain.grad_ready);
            // every task appears exactly once, with sane intervals
            assert_eq!(tl.len(), 4 * 6 + 3 * 6);
            let last_end =
                tl.iter().map(|t| t.end).fold(0.0f64, f64::max);
            assert!(last_end <= ms.total);
            for t in &tl {
                assert!(t.start <= t.end);
                assert!(t.v < 4 && t.mb < 6);
            }
        }
    }

    #[test]
    fn interleaved_needs_swarm_path() {
        let c = uniform_costs(4, 8, 1.0, 3.0, 0.0, 0.0);
        let err =
            step_makespan(&c, Schedule::Interleaved { chunks: 2 }).unwrap_err();
        assert!(err.to_string().contains("wrap-link"), "{err}");
    }

    #[test]
    fn schedule_parse_roundtrip() {
        assert_eq!(Schedule::parse("gpipe"), Some(Schedule::Gpipe));
        assert_eq!(Schedule::parse("1f1b"), Some(Schedule::OneFOneB));
        assert_eq!(
            Schedule::parse("interleaved"),
            Some(Schedule::Interleaved { chunks: 2 })
        );
        assert_eq!(
            Schedule::parse("interleaved:3"),
            Some(Schedule::Interleaved { chunks: 3 })
        );
        assert_eq!(Schedule::parse("interleaved:1"), None);
        assert_eq!(Schedule::parse("bogus"), None);
    }

    #[test]
    fn degenerate_specs_error() {
        let c = uniform_costs(2, 1, 1.0, 1.0, 0.0, 0.0);
        let mut bad = c.clone();
        bad.microbatches = 0;
        bad.fwd = vec![vec![]; 2];
        bad.bwd = vec![vec![]; 2];
        bad.tx_fwd = vec![vec![]; 1];
        bad.tx_bwd = vec![vec![]; 1];
        assert!(step_makespan(&bad, Schedule::Gpipe).is_err());
        assert!(step_makespan(&c, Schedule::Gpipe).is_ok());
    }
}
