//! Discrete-event swarm simulator (DESIGN.md §9).
//!
//! Three layers, each usable on its own:
//!
//! - [`queue`] — the deterministic `(time, seq)` event queue;
//! - [`step`] — event-driven execution of one pipeline step under
//!   GPipe / 1F1B / interleaved schedules, exactly reproducing the
//!   analytic `gpipe_makespan` under GPipe (the parity contract);
//! - [`swarm`] — multi-step, multi-replica simulation with latency
//!   jitter, time-varying stragglers, and node churn (leave / rejoin
//!   with re-routed ring all-reduces and dp-mode-priced state syncs);
//! - [`serve`] — the serving-schedule predictor: replays the decode
//!   pipeline's replicated batcher and prices each step's compute and
//!   boundary frames, the twin `exp serve-report` holds against the
//!   measured `serve-infer` walls (DESIGN.md §16).
//!
//! The coordinator routes per-step timing through [`step_makespan`]
//! when a non-GPipe schedule (or `--sim`) is configured; the
//! artifact-free swarm engine powers `protomodels sim`, the
//! `sim-grid` / `churn-sweep` experiment drivers, and
//! `examples/churn_swarm.rs`.

pub mod queue;
pub mod serve;
pub mod step;
pub mod swarm;

pub use queue::EventQueue;
pub use serve::{predict_serve, ServeSchedule, ServeStepPred};
pub use step::{simulate_step_spec, step_makespan, Schedule, StepSpec};
pub use swarm::{
    simulate_swarm, ChurnEvent, ChurnKind, ChurnSpec, ChurnTimeline,
    SimReport, StepChurn, SwarmSpec,
};
