//! Serving-schedule simulator (DESIGN.md §16): the predicted twin of
//! `transport::serve`'s measured decode pipeline.
//!
//! [`predict_serve`] replays the *exact* replicated control flow of the
//! runtime — same [`generate_sessions`] table, same [`Batcher`]
//! admission / eviction, same idle fast-forward and step-budget
//! semantics — and prices each executed decode step instead of running
//! the kernels:
//!
//! - compute: `Σ_stage Σ_active decode_row_flops(h, stage, pos)` over
//!   `device_flops` (the pipeline is sequential per step — every stage
//!   touches the same batch of rows before the token relay returns);
//! - wire: `(p − 1)` boundary hops, each carrying one `Decode` frame
//!   right and one `Token` frame left, priced on the [`LinkSpec`] with
//!   the *actual shipped* per-session payload lengths
//!   ([`session_payload_len`], PowerLR dense stand-in included) so
//!   predicted bytes match `bytes_sent` on the measured run.
//!
//! Because the schedule replay is byte-identical to the runtime's, the
//! predicted per-step walls line up one-to-one with the measured
//! `step_seconds` of `run_serve_local` / `serve_infer`, and the
//! per-session admit→done spans yield predicted p50/p99 latencies —
//! `exp serve-report` holds the two against each other with the same
//! rel-err discipline as `trace-diff`.

use anyhow::Result;

use crate::netsim::LinkSpec;
use crate::timemodel::decode_row_flops;
use crate::transport::serve::{
    generate_sessions, session_payload_len, Batcher,
};
use crate::transport::{ServeSpec, HEADER_LEN};

/// Predicted cost of one executed decode step.
#[derive(Clone, Debug)]
pub struct ServeStepPred {
    /// Decode step index (gaps are idle fast-forwards, priced at zero).
    pub step: u64,
    /// Sessions in the batch this step.
    pub active: usize,
    /// Predicted compute seconds across all stages.
    pub compute_s: f64,
    /// Predicted wire seconds across the `(p − 1)` boundary round trips.
    pub comm_s: f64,
}

impl ServeStepPred {
    /// Total predicted wall for this step.
    pub fn seconds(&self) -> f64 {
        self.compute_s + self.comm_s
    }
}

/// The predicted serving schedule: per-step walls plus per-session
/// latency spans, mirroring [`ServeReport`]'s measured quantities.
///
/// [`ServeReport`]: crate::transport::ServeReport
#[derive(Clone, Debug, Default)]
pub struct ServeSchedule {
    /// One entry per *executed* step, in step order.
    pub steps: Vec<ServeStepPred>,
    /// Generated tokens across all sessions.
    pub tokens: u64,
    /// Predicted admit→done seconds per session (session-id order).
    pub latency_s: Vec<f64>,
    /// Decode + token payload bytes a full step pushes across the wire
    /// (all links, headers included) at the peak batch width.
    pub peak_step_wire_bytes: u64,
}

impl ServeSchedule {
    /// Sum of predicted step walls (idle gaps cost nothing).
    pub fn total_seconds(&self) -> f64 {
        self.steps.iter().map(|s| s.seconds()).sum()
    }

    /// Mean predicted wall per executed step.
    pub fn mean_step_seconds(&self) -> f64 {
        if self.steps.is_empty() {
            0.0
        } else {
            self.total_seconds() / self.steps.len() as f64
        }
    }

    /// Predicted serving throughput.
    pub fn tokens_per_sec(&self) -> f64 {
        let w = self.total_seconds();
        if w > 0.0 {
            self.tokens as f64 / w
        } else {
            0.0
        }
    }

    /// Nearest-rank percentile over predicted session latencies.
    pub fn latency_percentile(&self, p: f64) -> f64 {
        if self.latency_s.is_empty() {
            return 0.0;
        }
        let mut v = self.latency_s.clone();
        v.sort_by(|a, b| a.total_cmp(b));
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }
}

/// Replay the serving schedule and price it on `link` / `device_flops`.
///
/// Pass `device_flops = 1.0` to read raw FLOPs out of `compute_s` (the
/// calibration trick `exp serve-report` uses to fit an effective device
/// rate from one measured local run).
pub fn predict_serve(
    spec: &ServeSpec,
    link: &LinkSpec,
    device_flops: f64,
) -> Result<ServeSchedule> {
    spec.validate()?;
    let h = &spec.core.h;
    let mode = spec.core.cfg.mode;
    let p = h.stages;
    let sessions = generate_sessions(spec)?;
    let mut batcher = Batcher::new(&sessions, spec.max_batch);

    // Wire seconds for one step at batch width `s`: every boundary link
    // carries one Decode frame right and one Token frame left, and the
    // hops are sequential (stage s+1 cannot start before the frame from
    // stage s lands; the relay walks back the same way).
    let per_session = session_payload_len(h, mode);
    let step_wire = |active: usize| -> (f64, u64) {
        let decode = (HEADER_LEN + active * per_session) as u64;
        let token = (HEADER_LEN + active * 8) as u64;
        let links = (p - 1) as u64;
        let secs = links as f64
            * (link.expected_time(decode as usize)
                + link.expected_time(token as usize));
        (secs, links * (decode + token))
    };

    let mut out = ServeSchedule::default();
    let mut admit_s = vec![0.0f64; sessions.len()];
    let mut done_s = vec![0.0f64; sessions.len()];
    let mut clock = 0.0f64;
    let mut step: u64 = 0;
    while !batcher.finished() {
        batcher.admit(step);
        let active: Vec<u32> = batcher.active().to_vec();
        if active.is_empty() {
            match batcher.next_arrival() {
                // idle fast-forward, same as the runtime: no frames, no
                // budget, zero predicted seconds
                Some(a) => {
                    step = a;
                    continue;
                }
                None => break,
            }
        }
        if out.steps.len() >= spec.core.steps {
            anyhow::bail!(
                "decode-step budget of {} steps exhausted in the serving \
                 simulator at step {step} — raise --steps or shrink the \
                 traffic",
                spec.core.steps
            );
        }
        for &sid in &active {
            if batcher.position(sid) == 0 {
                admit_s[sid as usize] = clock;
            }
        }
        let mut compute = 0.0f64;
        for stage in 0..p {
            for &sid in &active {
                compute += decode_row_flops(
                    h,
                    stage,
                    batcher.position(sid),
                    mode.compressed(),
                );
            }
        }
        let compute_s = compute / device_flops;
        let (comm_s, wire) = step_wire(active.len());
        out.peak_step_wire_bytes = out.peak_step_wire_bytes.max(wire);
        clock += compute_s + comm_s;
        for &sid in &active {
            let s = &sessions[sid as usize];
            // a position past the prompt emits one generated token; the
            // final position emits the last one
            if batcher.position(sid) + 1 >= s.prompt.len() {
                out.tokens += 1;
            }
        }
        for sid in batcher.advance() {
            done_s[sid as usize] = clock;
        }
        out.steps.push(ServeStepPred {
            step,
            active: active.len(),
            compute_s,
            comm_s,
        });
        step += 1;
    }
    // session-id order, matching ServeReport::sessions
    out.latency_s = (0..sessions.len())
        .map(|i| done_s[i] - admit_s[i])
        .collect();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Mode;
    use crate::data::CorpusKind;
    use crate::manifest::Hyper;
    use crate::netsim::{LinkSpec, GBPS};
    use crate::transport::{run_serve_local, ServeSpec, TrafficSpec};

    fn tiny(mode: Mode) -> ServeSpec {
        ServeSpec::builder(Hyper::tiny_native())
            .mode(mode)
            .steps(400)
            .seed(11)
            .corpus(CorpusKind::Wiki, 4_000)
            .traffic(TrafficSpec {
                sessions: 3,
                mean_gap: 1.5,
                prompt: (2, 4),
                gen: (2, 3),
            })
            .max_batch(2)
            .build()
            .unwrap()
    }

    #[test]
    fn predicted_schedule_matches_measured_step_count_and_tokens() {
        let spec = tiny(Mode::Subspace);
        let link = LinkSpec::new(10.0 * GBPS, 50e-6);
        let pred = predict_serve(&spec, &link, 2e12).unwrap();
        let meas = run_serve_local(&spec).unwrap();
        // the simulator replays the runtime's batcher verbatim, so the
        // executed step set and token count must agree exactly
        assert_eq!(pred.steps.len() as u64, meas.steps);
        assert_eq!(pred.tokens, meas.tokens_generated);
        assert_eq!(pred.latency_s.len(), meas.sessions.len());
        assert!(pred.total_seconds() > 0.0);
        assert!(
            pred.latency_percentile(50.0) <= pred.latency_percentile(99.0)
        );
    }

    #[test]
    fn predicted_wire_bytes_match_shipped_frame_lengths() {
        for mode in [Mode::Subspace, Mode::TopK, Mode::PowerLR] {
            let spec = tiny(mode);
            let link = LinkSpec::new(10.0 * GBPS, 50e-6);
            let pred = predict_serve(&spec, &link, 2e12).unwrap();
            let meas = run_serve_local(&spec).unwrap();
            // peak step wire = (p−1) links × (decode + token frame) at
            // the widest batch; measured totals bound it from above
            assert!(pred.peak_step_wire_bytes > 0);
            assert!(
                pred.peak_step_wire_bytes
                    <= meas.decode_payload_bytes
                        + meas.token_payload_bytes
                        + meas.frames * crate::transport::HEADER_LEN as u64
            );
        }
    }

    #[test]
    fn narrower_link_predicts_slower_steps() {
        let spec = tiny(Mode::Subspace);
        let fast = predict_serve(
            &spec,
            &LinkSpec::new(10.0 * GBPS, 50e-6),
            2e12,
        )
        .unwrap();
        let slow = predict_serve(
            &spec,
            &LinkSpec::new(0.08 * GBPS, 20e-3),
            2e12,
        )
        .unwrap();
        assert!(slow.total_seconds() > fast.total_seconds());
        assert_eq!(slow.steps.len(), fast.steps.len());
    }
}
