//! Wire-format accounting and codecs for every boundary compression scheme.
//!
//! The *math* of each scheme executes inside the stage HLO (L2 calls the
//! L1 kernels / baselines); this module owns the two things the
//! coordinator needs on the rust side:
//!
//!  1. `wire_bytes` — the exact bytes a boundary tensor occupies on the
//!     wire under each scheme (mirrors python/compile/baselines.py;
//!     consumed by netsim for transfer-time simulation), and
//!  2. real encoders/decoders (`encode`/`decode`) so the byte accounting
//!     is backed by an actual serialization a deployment would ship —
//!     tested for round-trip fidelity where the scheme is lossless.

pub mod ckpt;

use anyhow::{bail, Result};

pub use ckpt::{CkptCodec, StageCheckpoint};

use crate::tensor::Tensor;

/// Boundary compression scheme (shared vocabulary for activation
/// payloads and, via [`dp_wire_bytes`], weight-gradient all-reduces).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Mode {
    /// The paper's subspace scheme — (b, n, k) f32 payload, lossless.
    Subspace,
    /// Uncompressed (b, n, d) f32.
    Raw,
    /// Magnitude top-k (value, index) pairs.
    TopK,
    /// Per-tensor int8 symmetric quantization.
    Quant,
    /// PowerSGD-style rank-r factors.
    PowerLR,
    /// Fig.-15 ablation: subspace wire format, but the token embedding is
    /// restricted entirely to S (no fixed high-rank component).
    NoFixed,
    /// `Raw` math with a bf16 wire: f32 boundary tensors truncated to
    /// bf16 (upper 16 bits) on encode, widened exactly back to f32 on
    /// decode. Halves the raw wire at ~3 significant decimal digits.
    RawBf16,
    /// `Subspace` math with a bf16 wire over the (b·n, k) coefficients.
    /// Composes the paper's k/d reduction with a further 2x from
    /// precision (DESIGN.md §13).
    SubspaceBf16,
}

impl Mode {
    /// Parse a CLI mode label (`"subspace"`, `"raw"`, …).
    pub fn parse(s: &str) -> Result<Mode> {
        Ok(match s {
            "subspace" => Mode::Subspace,
            "raw" => Mode::Raw,
            "topk" => Mode::TopK,
            "quant" => Mode::Quant,
            "powerlr" => Mode::PowerLR,
            "nofixed" => Mode::NoFixed,
            "raw-bf16" => Mode::RawBf16,
            "subspace-bf16" => Mode::SubspaceBf16,
            other => bail!("unknown mode {other:?}"),
        })
    }

    /// Canonical label, matching AOT artifact entry-key prefixes.
    pub fn as_str(&self) -> &'static str {
        match self {
            Mode::Subspace => "subspace",
            Mode::Raw => "raw",
            Mode::TopK => "topk",
            Mode::Quant => "quant",
            Mode::PowerLR => "powerlr",
            Mode::NoFixed => "nofixed",
            Mode::RawBf16 => "raw-bf16",
            Mode::SubspaceBf16 => "subspace-bf16",
        }
    }

    /// True for schemes that do not reconstruct the payload exactly.
    pub fn is_lossy(&self) -> bool {
        matches!(
            self,
            Mode::TopK
                | Mode::Quant
                | Mode::PowerLR
                | Mode::RawBf16
                | Mode::SubspaceBf16
        )
    }

    /// True for schemes whose boundary payload is the (b·n, k) subspace
    /// coefficients rather than the full (b·n, d) activations — the
    /// stages then carry the paper's projection/reconstruction maps.
    pub fn compressed(self) -> bool {
        matches!(
            self,
            Mode::Subspace | Mode::NoFixed | Mode::SubspaceBf16
        )
    }

    /// True for subspace schemes that keep the fixed high-rank token
    /// embedding component E (everything but the `NoFixed` ablation).
    pub fn uses_fixed_embedding(self) -> bool {
        matches!(self, Mode::Subspace | Mode::SubspaceBf16)
    }

    /// True for schemes that ship bf16 payloads on the wire (the math
    /// stays f32; precision is dropped only at the boundary).
    pub fn bf16_wire(self) -> bool {
        matches!(self, Mode::RawBf16 | Mode::SubspaceBf16)
    }

    /// The f32 scheme whose *math* this mode runs — identity for the
    /// f32 modes, the base scheme for the bf16-wire variants. Weight
    /// gradients, optimizer state, and checkpoints are priced under the
    /// base mode: bf16 applies to the boundary wire only.
    pub fn base(self) -> Mode {
        match self {
            Mode::RawBf16 => Mode::Raw,
            Mode::SubspaceBf16 => Mode::Subspace,
            other => other,
        }
    }

    /// Stable one-byte identifier of this mode in the framed wire
    /// protocol's codec field (DESIGN.md §11). The numbering is part of
    /// the wire format: never reorder, only append.
    pub fn wire_tag(self) -> u8 {
        match self {
            Mode::Subspace => 0,
            Mode::Raw => 1,
            Mode::TopK => 2,
            Mode::Quant => 3,
            Mode::PowerLR => 4,
            Mode::NoFixed => 5,
            Mode::RawBf16 => 6,
            Mode::SubspaceBf16 => 7,
        }
    }

    /// Inverse of [`Mode::wire_tag`]; `None` for unknown bytes (frames
    /// from a newer peer are rejected, not misinterpreted).
    pub fn from_wire_tag(tag: u8) -> Option<Mode> {
        Some(match tag {
            0 => Mode::Subspace,
            1 => Mode::Raw,
            2 => Mode::TopK,
            3 => Mode::Quant,
            4 => Mode::PowerLR,
            5 => Mode::NoFixed,
            6 => Mode::RawBf16,
            7 => Mode::SubspaceBf16,
            _ => return None,
        })
    }

    /// Every mode, in wire-tag order — the one list the exhaustive
    /// `FromStr`/`Display`/`wire_tag` round-trip properties sweep, so a
    /// new variant that misses any of the three fails a test instead of
    /// silently falling back to string matching.
    pub const ALL: [Mode; 8] = [
        Mode::Subspace,
        Mode::Raw,
        Mode::TopK,
        Mode::Quant,
        Mode::PowerLR,
        Mode::NoFixed,
        Mode::RawBf16,
        Mode::SubspaceBf16,
    ];
}

impl std::str::FromStr for Mode {
    type Err = anyhow::Error;

    /// The canonical parse: `"subspace".parse::<Mode>()` — same table
    /// as [`Mode::parse`], exposed through the standard trait so call
    /// sites compare parsed `Mode` values instead of matching strings.
    fn from_str(s: &str) -> Result<Mode> {
        Mode::parse(s)
    }
}

impl std::fmt::Display for Mode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Elements kept by top-k so (value,index) pairs hit the target byte
/// ratio: kept · 8B ≤ numel · 4B / ratio.
pub fn topk_keep(numel: usize, ratio: f64) -> usize {
    ((numel as f64 * 4.0 / (8.0 * ratio)) as usize).max(1)
}

/// PowerSGD rank giving (n+d)·r·4 ≈ n·d·4 / ratio.
pub fn powerlr_rank(n: usize, d: usize, ratio: f64) -> usize {
    (((n * d) as f64 / (ratio * (n + d) as f64)) as usize).max(1)
}

/// Bytes on the wire for one boundary tensor of logical shape (b, n, d)
/// compressed to rank k (subspace) or `ratio` (lossy schemes).
/// Mirrors `baselines.wire_bytes` — kept in lockstep by the pytest /
/// cargo cross-check in tests.
pub fn wire_bytes(mode: Mode, b: usize, n: usize, d: usize, k: usize, ratio: f64) -> usize {
    match mode {
        Mode::Subspace | Mode::NoFixed => b * n * k * 4,
        Mode::Raw => b * n * d * 4,
        Mode::TopK => topk_keep(b * n * d, ratio) * 8,
        Mode::Quant => b * n * d + 4, // int8 payload + f32 scale
        Mode::PowerLR => b * (n + d) * powerlr_rank(n, d, ratio) * 4,
        Mode::RawBf16 => b * n * d * 2,
        Mode::SubspaceBf16 => b * n * k * 2,
    }
}

/// Bytes on the wire for one *weight-gradient* payload of `elems`
/// parameter elements in the cross-replica all-reduce (data-parallel
/// axis), priced under the same `Mode` vocabulary as activations:
///
/// - `Raw` — dense f32 gradients,
/// - `Quant` — int8 symmetric quantization + f32 scale,
/// - `TopK` — (u32 index, f32 value) pairs at the target `ratio`,
/// - `Subspace`/`NoFixed` — "U-only" gradients: each d-dim row reduced
///   to its k subspace coefficients (k/d of the elements, the DP analogue
///   of the boundary scheme; never exceeds `Raw` since k ≤ d),
/// - `PowerLR` — low-rank factors sized to the target `ratio`,
/// - `RawBf16`/`SubspaceBf16` — the base scheme's element count at
///   2 B/element: gradient frames ship bf16 coefficients on the wire
///   and accumulate in f32 after the exact widen (DESIGN.md §14).
pub fn dp_wire_bytes(mode: Mode, elems: usize, d: usize, k: usize, ratio: f64) -> usize {
    match mode {
        Mode::Raw => elems * 4,
        Mode::Quant => elems + 4,
        Mode::TopK => topk_keep(elems, ratio) * 8,
        Mode::Subspace | Mode::NoFixed => {
            ((elems * k + d.max(1) - 1) / d.max(1)) * 4
        }
        Mode::PowerLR => {
            (((elems * 4) as f64 / ratio.max(1.0)).ceil() as usize).max(4) + 8
        }
        Mode::RawBf16 => elems * 2,
        Mode::SubspaceBf16 => (elems * k + d.max(1) - 1) / d.max(1) * 2,
    }
}

// ---------------------------------------------------------------------------
// codecs
// ---------------------------------------------------------------------------

/// Encoded wire frame.
#[derive(Clone, Debug)]
pub struct Frame {
    /// scheme this frame was encoded under
    pub mode: Mode,
    /// logical tensor shape (not serialized; carried out-of-band)
    pub shape: Vec<usize>,
    /// serialized payload bytes
    pub payload: Vec<u8>,
}

impl Frame {
    /// Bytes this frame occupies on the wire.
    pub fn wire_len(&self) -> usize {
        self.payload.len()
    }
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn get_f32s(buf: &[u8]) -> Vec<f32> {
    buf.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Dense f32 — used by both `Subspace` (payload is already (b,n,k)) and
/// `Raw`. Lossless by construction.
pub fn encode_dense(t: &Tensor, mode: Mode) -> Frame {
    let mut payload = Vec::new();
    put_f32s(&mut payload, &t.data);
    Frame { mode, shape: t.shape.clone(), payload }
}

/// Decode a dense f32 frame.
pub fn decode_dense(f: &Frame) -> Tensor {
    Tensor::new(f.shape.clone(), get_f32s(&f.payload))
}

/// f32 → bf16 by truncation: keep the upper 16 bits (sign, exponent,
/// top 7 mantissa bits), drop the rest. Truncation — not
/// round-to-nearest — so the rule is branch-free and documented as the
/// wire contract (DESIGN.md §13); relative error ≤ 2⁻⁷ per element.
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    (x.to_bits() >> 16) as u16
}

/// bf16 → f32 widening: place the 16 bits as the upper half of an f32.
/// Exact — every bf16 value is representable in f32, so downstream
/// accumulation happens in full f32.
#[inline]
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Dense bf16 — `RawBf16` / `SubspaceBf16` wires: 2 bytes per element,
/// truncate on encode, widen exactly on decode.
pub fn encode_dense_bf16(t: &Tensor, mode: Mode) -> Frame {
    let mut payload = Vec::with_capacity(t.numel() * 2);
    for &x in &t.data {
        payload.extend_from_slice(&f32_to_bf16(x).to_le_bytes());
    }
    Frame { mode, shape: t.shape.clone(), payload }
}

/// Decode a dense bf16 frame back to f32.
pub fn decode_dense_bf16(f: &Frame) -> Tensor {
    let data = f
        .payload
        .chunks_exact(2)
        .map(|c| bf16_to_f32(u16::from_le_bytes([c[0], c[1]])))
        .collect();
    Tensor::new(f.shape.clone(), data)
}

/// Top-k: (u32 index, f32 value) pairs for the `keep` largest |values|.
pub fn encode_topk(t: &Tensor, ratio: f64) -> Frame {
    let keep = topk_keep(t.numel(), ratio).min(t.numel());
    let mut idx: Vec<u32> = (0..t.numel() as u32).collect();
    // total_cmp: identical to the partial order on ordinary floats, but
    // NaNs (possible in a diverging run's activations) sort instead of
    // panicking — the caller then sees a NaN loss, not an abort
    idx.select_nth_unstable_by(keep.saturating_sub(1), |&a, &b| {
        t.data[b as usize].abs().total_cmp(&t.data[a as usize].abs())
    });
    idx.truncate(keep);
    idx.sort_unstable();
    let mut payload = Vec::with_capacity(keep * 8);
    for &i in &idx {
        payload.extend_from_slice(&i.to_le_bytes());
        payload.extend_from_slice(&t.data[i as usize].to_le_bytes());
    }
    Frame { mode: Mode::TopK, shape: t.shape.clone(), payload }
}

/// Decode a top-k frame back to a (sparse) dense tensor.
pub fn decode_topk(f: &Frame) -> Tensor {
    let numel = f.shape.iter().product();
    let mut data = vec![0.0f32; numel];
    for c in f.payload.chunks_exact(8) {
        let i = u32::from_le_bytes([c[0], c[1], c[2], c[3]]) as usize;
        let v = f32::from_le_bytes([c[4], c[5], c[6], c[7]]);
        data[i] = v;
    }
    Tensor::new(f.shape.clone(), data)
}

/// Per-tensor symmetric int8 quantization: scale then bytes.
pub fn encode_quant(t: &Tensor) -> Frame {
    let max = t.max_abs();
    let scale = if max > 0.0 { max / 127.0 } else { 1.0 };
    let mut payload = Vec::with_capacity(4 + t.numel());
    payload.extend_from_slice(&scale.to_le_bytes());
    for &x in &t.data {
        let q = (x / scale).round().clamp(-127.0, 127.0) as i8;
        payload.push(q as u8);
    }
    Frame { mode: Mode::Quant, shape: t.shape.clone(), payload }
}

/// Decode an int8 frame back to f32.
pub fn decode_quant(f: &Frame) -> Tensor {
    let scale = f32::from_le_bytes([
        f.payload[0],
        f.payload[1],
        f.payload[2],
        f.payload[3],
    ]);
    let data = f.payload[4..]
        .iter()
        .map(|&b| (b as i8) as f32 * scale)
        .collect();
    Tensor::new(f.shape.clone(), data)
}

/// Encode under a mode (PowerLR factors are produced inside the HLO, so
/// its rust-side frame ships the dense reconstruction for correctness
/// and *accounts* factor bytes via `wire_bytes`).
pub fn encode(t: &Tensor, mode: Mode, ratio: f64) -> Frame {
    match mode {
        Mode::Subspace | Mode::NoFixed | Mode::Raw | Mode::PowerLR => {
            encode_dense(t, mode)
        }
        Mode::RawBf16 | Mode::SubspaceBf16 => encode_dense_bf16(t, mode),
        Mode::TopK => encode_topk(t, ratio),
        Mode::Quant => encode_quant(t),
    }
}

/// Encode-then-decode one boundary tensor under `mode`'s codec,
/// returning the reconstruction plus the frame's wire bytes — an
/// `encode`∘`decode` convenience for tests and external callers.
/// Lossless for the dense modes (subspace payloads are already the
/// (b·n, k) coefficients), genuinely lossy for top-k / int8. The
/// backends themselves ship through `nn::encode_boundary` (the shared
/// single-process/distributed hook, which also owns PowerLR's
/// deterministic sketch RNG); this helper stays byte-identical to it
/// for every non-PowerLR mode by construction.
pub fn roundtrip(t: &Tensor, mode: Mode, ratio: f64) -> (Tensor, usize) {
    let f = encode(t, mode, ratio);
    (decode(&f), f.wire_len())
}

/// Decode a frame under its recorded mode.
pub fn decode(f: &Frame) -> Tensor {
    match f.mode {
        Mode::Subspace | Mode::NoFixed | Mode::Raw | Mode::PowerLR => {
            decode_dense(f)
        }
        Mode::RawBf16 | Mode::SubspaceBf16 => decode_dense_bf16(f),
        Mode::TopK => decode_topk(f),
        Mode::Quant => decode_quant(f),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randt(rng: &mut Rng, shape: &[usize]) -> Tensor {
        Tensor::new(shape.to_vec(), rng.normal_f32_vec(shape.iter().product(), 1.0))
    }

    #[test]
    fn dense_roundtrip_lossless() {
        let mut rng = Rng::new(1);
        let t = randt(&mut rng, &[2, 8, 4]);
        let f = encode_dense(&t, Mode::Subspace);
        assert_eq!(decode_dense(&f).data, t.data);
        assert_eq!(f.wire_len(), t.numel() * 4);
    }

    #[test]
    fn topk_keeps_largest() {
        let t = Tensor::new(vec![8], vec![0.1, -5.0, 0.2, 3.0, 0.0, -0.3, 4.0, 0.05]);
        let f = encode_topk(&t, 2.0); // keep 2 of 8
        let d = decode_topk(&f);
        assert_eq!(d.data[1], -5.0);
        assert_eq!(d.data[6], 4.0);
        assert_eq!(d.data.iter().filter(|x| **x != 0.0).count(), 2);
    }

    #[test]
    fn topk_wire_bytes_match_accounting() {
        let mut rng = Rng::new(2);
        let t = randt(&mut rng, &[4, 16, 8]);
        let ratio = 8.0;
        let f = encode_topk(&t, ratio);
        assert_eq!(f.wire_len(), wire_bytes(Mode::TopK, 4, 16, 8, 0, ratio));
    }

    #[test]
    fn quant_roundtrip_error_bounded() {
        let mut rng = Rng::new(3);
        let t = randt(&mut rng, &[64]);
        let f = encode_quant(&t);
        let d = decode_quant(&f);
        let scale = t.max_abs() / 127.0;
        for (a, b) in t.data.iter().zip(&d.data) {
            assert!((a - b).abs() <= scale * 0.5 + 1e-6);
        }
        assert_eq!(f.wire_len(), 4 + t.numel());
    }

    #[test]
    fn subspace_beats_everyone_at_high_ratio() {
        // base config: d=256, k=4 → 64x; everyone accounted at that ratio
        let (b, n, d, k) = (4, 128, 256, 4);
        let ratio = d as f64 / k as f64;
        let sub = wire_bytes(Mode::Subspace, b, n, d, k, ratio);
        let raw = wire_bytes(Mode::Raw, b, n, d, k, ratio);
        let quant = wire_bytes(Mode::Quant, b, n, d, k, ratio);
        assert_eq!(raw / sub, 64);
        assert!(quant > sub, "int8 only gives 4x");
        // topk / powerlr tuned to match the subspace ratio
        let topk = wire_bytes(Mode::TopK, b, n, d, k, ratio);
        assert!((topk as f64) <= raw as f64 / ratio * 1.1);
    }

    #[test]
    fn dp_wire_bytes_table() {
        let (elems, d, k) = (1_837_056usize, 256usize, 8usize);
        let ratio = d as f64 / k as f64;
        let raw = dp_wire_bytes(Mode::Raw, elems, d, k, ratio);
        assert_eq!(raw, elems * 4);
        let sub = dp_wire_bytes(Mode::Subspace, elems, d, k, ratio);
        // k/d of the elements, 4 B each (± rounding)
        assert!((sub as f64 / raw as f64 - k as f64 / d as f64).abs() < 1e-3);
        assert!(dp_wire_bytes(Mode::Quant, elems, d, k, ratio) < raw);
        assert!(dp_wire_bytes(Mode::TopK, elems, d, k, ratio) < raw);
        assert!(dp_wire_bytes(Mode::PowerLR, elems, d, k, ratio) < raw);
    }

    #[test]
    fn codec_frames_match_wire_accounting() {
        // the native backend ships real frames; their lengths must agree
        // with the analytic `wire_bytes` the netsim prices transfers by
        let (b, n, d, k) = (2usize, 16usize, 32usize, 4usize);
        let ratio = d as f64 / k as f64;
        let mut rng = Rng::new(9);
        let full = randt(&mut rng, &[b * n, d]);
        let coeff = randt(&mut rng, &[b * n, k]);
        for (mode, t) in [
            (Mode::Subspace, &coeff),
            (Mode::Raw, &full),
            (Mode::TopK, &full),
            (Mode::Quant, &full),
        ] {
            let (recon, bytes) = roundtrip(t, mode, ratio);
            assert_eq!(bytes, wire_bytes(mode, b, n, d, k, ratio), "{mode:?}");
            assert_eq!(recon.shape, t.shape);
            if !mode.is_lossy() {
                assert_eq!(recon.data, t.data, "{mode:?} must be lossless");
            }
        }
    }

    #[test]
    fn mode_parse_roundtrip() {
        for m in Mode::ALL {
            assert_eq!(Mode::parse(m.as_str()).unwrap(), m);
            // the FromStr/Display pair is the same table
            assert_eq!(m.to_string().parse::<Mode>().unwrap(), m);
            assert_eq!(m.to_string(), m.as_str());
        }
        assert!(Mode::parse("bogus").is_err());
        assert!("bogus".parse::<Mode>().is_err());
    }

    #[test]
    fn wire_tags_are_stable_and_invertible() {
        // the numbering is a wire-format contract (DESIGN.md §11):
        // append-only — 6/7 were claimed by the bf16 wires
        let all = [
            (Mode::Subspace, 0u8),
            (Mode::Raw, 1),
            (Mode::TopK, 2),
            (Mode::Quant, 3),
            (Mode::PowerLR, 4),
            (Mode::NoFixed, 5),
            (Mode::RawBf16, 6),
            (Mode::SubspaceBf16, 7),
        ];
        for (m, tag) in all {
            assert_eq!(m.wire_tag(), tag);
            assert_eq!(Mode::from_wire_tag(tag), Some(m));
        }
        assert_eq!(Mode::from_wire_tag(8), None);
        assert_eq!(Mode::from_wire_tag(255), None);
    }

    #[test]
    fn bf16_truncate_and_widen_rules() {
        // widening is exact for already-bf16 values
        for x in [0.0f32, -0.0, 1.0, -2.5, 3.0e20, -1.0e-20] {
            let h = f32_to_bf16(x);
            let w = bf16_to_f32(h);
            assert_eq!(f32_to_bf16(w), h);
        }
        // truncation toward zero: |bf16(x)| ≤ |x|, rel err ≤ 2⁻⁷
        let mut rng = Rng::new(11);
        for x in rng.normal_f32_vec(256, 3.0) {
            let w = bf16_to_f32(f32_to_bf16(x));
            assert!(w.abs() <= x.abs());
            assert!((w - x).abs() <= x.abs() / 128.0 + f32::MIN_POSITIVE);
        }
    }

    #[test]
    fn bf16_frames_match_wire_accounting() {
        let (b, n, d, k) = (2usize, 16usize, 32usize, 4usize);
        let ratio = d as f64 / k as f64;
        let mut rng = Rng::new(10);
        let full = randt(&mut rng, &[b * n, d]);
        let coeff = randt(&mut rng, &[b * n, k]);
        for (mode, t) in
            [(Mode::RawBf16, &full), (Mode::SubspaceBf16, &coeff)]
        {
            let (recon, bytes) = roundtrip(t, mode, ratio);
            assert_eq!(bytes, wire_bytes(mode, b, n, d, k, ratio), "{mode:?}");
            assert_eq!(bytes, t.numel() * 2);
            assert!(mode.is_lossy());
            for (a, r) in t.data.iter().zip(&recon.data) {
                assert!((a - r).abs() <= a.abs() / 128.0 + f32::MIN_POSITIVE);
            }
        }
    }

    #[test]
    fn bf16_base_mode_and_predicates() {
        assert_eq!(Mode::RawBf16.base(), Mode::Raw);
        assert_eq!(Mode::SubspaceBf16.base(), Mode::Subspace);
        assert!(!Mode::RawBf16.compressed());
        assert!(Mode::SubspaceBf16.compressed());
        assert!(Mode::SubspaceBf16.uses_fixed_embedding());
        assert!(!Mode::NoFixed.uses_fixed_embedding());
        assert!(Mode::RawBf16.bf16_wire() && Mode::SubspaceBf16.bf16_wire());
        assert!(!Mode::Raw.bf16_wire());
        // bf16 DP gradient frames ship half the base mode's bytes: the
        // same element count at 2 B/element (PR 7's reserved headroom)
        let (elems, d, k) = (10_000usize, 64usize, 8usize);
        for m in [Mode::RawBf16, Mode::SubspaceBf16] {
            assert_eq!(
                dp_wire_bytes(m, elems, d, k, 8.0) * 2,
                dp_wire_bytes(m.base(), elems, d, k, 8.0)
            );
        }
        assert_eq!(dp_wire_bytes(Mode::RawBf16, elems, d, k, 8.0), elems * 2);
        assert_eq!(
            dp_wire_bytes(Mode::SubspaceBf16, elems, d, k, 8.0),
            (elems * k + d - 1) / d * 2
        );
    }
}
