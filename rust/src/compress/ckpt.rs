//! Checkpoint payload codec for elastic recovery (DESIGN.md §12).
//!
//! A stage worker periodically serializes its trainable state — stage
//! parameters, AdamW moments, the shared basis U, and (last stage only)
//! the Grassmann activation accumulator — into one `Checkpoint` frame
//! payload. Two codecs:
//!
//! - [`CkptCodec::Raw`] — every tensor dense f32. Restore is **bitwise**:
//!   a run resumed from a raw checkpoint reproduces the unfailed run's
//!   loss curve exactly (the flagship chaos test's contract).
//! - [`CkptCodec::Coeff`] — subspace-constrained parameters (`wp1`,
//!   `wp2`, `t_s`; see `stage::constrained`) ship as their k-dim row
//!   coefficients `P·U`, the checkpoint analogue of the boundary scheme;
//!   the byte cost of each such tensor is *exactly*
//!   [`crate::compress::dp_wire_bytes`] under the run's mode. Optimizer
//!   moments always ship raw — `m`/`v` are not subspace-closed (the
//!   moment of a projected gradient is not itself projected), so
//!   compressing them would corrupt the optimizer.
//!
//! Layout (little-endian; `PMCK` magic, then a 32-byte header):
//!
//! ```text
//! magic     4 B   "PMCK"
//! mode      1 B   compress::Mode::wire_tag of the training run
//! codec     1 B   CkptCodec tag (0 raw, 1 coeff)
//! flags     1 B   bit 0: s_acc present
//! reserved  1 B   zero
//! step      8 B   u64 — first un-trained step (checkpoint boundary)
//! stage     4 B   u32 — stage index the state belongs to
//! n_params  4 B   u32 — schema length, validated on decode
//! s_count   8 B   u64 — samples in the Grassmann accumulator
//! ```
//!
//! followed by U (d·k f32), then per schema slot: param bytes (coeff or
//! raw), m (raw), v (raw), and finally s_acc (d·d f32) when flagged.
//! The analytic size is [`crate::memory::checkpoint_payload_bytes`];
//! tests here pin encoder output length to that formula.

use anyhow::{bail, Context, Result};

use crate::linalg;
use crate::stage::{constrained, StageState};
use crate::tensor::Tensor;

use super::Mode;

/// Checkpoint payload magic.
pub const CKPT_MAGIC: [u8; 4] = *b"PMCK";

/// Fixed checkpoint header length (magic included), in bytes.
pub const CKPT_HEADER_LEN: usize = 32;

/// How parameter tensors are serialized inside a checkpoint payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CkptCodec {
    /// Dense f32 for everything — bitwise-exact restore.
    Raw,
    /// Subspace-constrained parameters as k-dim row coefficients `P·U`
    /// (priced by `dp_wire_bytes`); everything else dense.
    Coeff,
}

impl CkptCodec {
    /// Parse a CLI label (`"raw"` / `"coeff"`).
    pub fn parse(s: &str) -> Result<CkptCodec> {
        match s {
            "raw" => Ok(CkptCodec::Raw),
            "coeff" => Ok(CkptCodec::Coeff),
            other => bail!(
                "unknown checkpoint codec {other:?} (expected raw|coeff)"
            ),
        }
    }

    /// Canonical label.
    pub fn as_str(&self) -> &'static str {
        match self {
            CkptCodec::Raw => "raw",
            CkptCodec::Coeff => "coeff",
        }
    }

    /// Stable one-byte identifier in the checkpoint header. Part of the
    /// wire format: never reorder, only append.
    pub fn tag(self) -> u8 {
        match self {
            CkptCodec::Raw => 0,
            CkptCodec::Coeff => 1,
        }
    }

    /// Inverse of [`CkptCodec::tag`].
    pub fn from_tag(tag: u8) -> Option<CkptCodec> {
        match tag {
            0 => Some(CkptCodec::Raw),
            1 => Some(CkptCodec::Coeff),
            _ => None,
        }
    }

    /// Every codec, in tag order — the list the exhaustive
    /// `FromStr`/`Display`/`tag` round-trip properties sweep, so a new
    /// variant that misses any of them fails a test instead of silently
    /// falling back to string matching.
    pub const ALL: [CkptCodec; 2] = [CkptCodec::Raw, CkptCodec::Coeff];
}

impl std::str::FromStr for CkptCodec {
    type Err = anyhow::Error;

    /// The canonical parse: `"coeff".parse::<CkptCodec>()` — same table
    /// as [`CkptCodec::parse`], exposed through the standard trait so
    /// CLI sites compare parsed values instead of matching strings.
    fn from_str(s: &str) -> Result<CkptCodec> {
        CkptCodec::parse(s)
    }
}

impl std::fmt::Display for CkptCodec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// True when `codec` stores this parameter as subspace coefficients
/// under `mode` (constrained name + compressed mode + coeff codec).
fn coeff_encoded(name: &str, mode: Mode, codec: CkptCodec) -> bool {
    codec == CkptCodec::Coeff && mode.compressed() && constrained(name)
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn take_f32s(buf: &[u8], off: &mut usize, n: usize) -> Result<Vec<f32>> {
    let need = n * 4;
    let Some(chunk) = buf.get(*off..*off + need) else {
        bail!(
            "checkpoint truncated: need {need} B at offset {off} of a \
             {} B payload",
            buf.len()
        );
    };
    *off += need;
    Ok(chunk
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// The non-`StageState` half of a decoded checkpoint: everything the
/// worker restores *around* the parameters.
#[derive(Clone)]
pub struct StageCheckpoint {
    /// stage index recorded in the header
    pub stage: usize,
    /// first un-trained step — training resumes here
    pub step: u64,
    /// shared subspace basis U at the boundary
    pub u: Tensor,
    /// Grassmann activation accumulator (last stage, compressed modes)
    pub s_acc: Option<Tensor>,
    /// samples in `s_acc`
    pub s_count: u64,
}

/// Serialize one stage's trainable state at a step boundary.
pub fn encode_stage(
    st: &StageState,
    u: &Tensor,
    s_acc: Option<&Tensor>,
    s_count: u64,
    step: u64,
    mode: Mode,
    codec: CkptCodec,
) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&CKPT_MAGIC);
    out.push(mode.wire_tag());
    out.push(codec.tag());
    out.push(u8::from(s_acc.is_some()));
    out.push(0);
    out.extend_from_slice(&step.to_le_bytes());
    out.extend_from_slice(&(st.stage as u32).to_le_bytes());
    out.extend_from_slice(&(st.schema.len() as u32).to_le_bytes());
    out.extend_from_slice(&s_count.to_le_bytes());
    debug_assert_eq!(out.len(), CKPT_HEADER_LEN);
    put_f32s(&mut out, &u.data);
    for (i, (name, _)) in st.schema.iter().enumerate() {
        if coeff_encoded(name, mode, codec) {
            put_f32s(&mut out, &linalg::matmul(&st.params[i], u).data);
        } else {
            put_f32s(&mut out, &st.params[i].data);
        }
        put_f32s(&mut out, &st.m[i].data);
        put_f32s(&mut out, &st.v[i].data);
    }
    if let Some(s) = s_acc {
        put_f32s(&mut out, &s.data);
    }
    out
}

/// Restore a stage from a checkpoint payload: parameters and moments are
/// written into `st` (whose schema must match the encoder's), and the
/// surrounding state comes back as a [`StageCheckpoint`]. `d`/`k` are
/// the run's subspace dimensions (they size U and the coefficient
/// expansion); `mode` must equal the training run's boundary mode.
pub fn decode_stage(
    bytes: &[u8],
    st: &mut StageState,
    d: usize,
    k: usize,
    mode: Mode,
) -> Result<StageCheckpoint> {
    if bytes.len() < CKPT_HEADER_LEN {
        bail!(
            "checkpoint truncated: {} B is shorter than the {CKPT_HEADER_LEN} \
             B header",
            bytes.len()
        );
    }
    if bytes[0..4] != CKPT_MAGIC {
        bail!("bad checkpoint magic {:02x?}", &bytes[0..4]);
    }
    let got_mode = Mode::from_wire_tag(bytes[4])
        .with_context(|| format!("unknown checkpoint mode tag {}", bytes[4]))?;
    if got_mode != mode {
        bail!(
            "checkpoint mode {} does not match the run's mode {}",
            got_mode.as_str(),
            mode.as_str()
        );
    }
    let codec = CkptCodec::from_tag(bytes[5])
        .with_context(|| format!("unknown checkpoint codec tag {}", bytes[5]))?;
    let has_s_acc = bytes[6] & 1 == 1;
    let step = u64::from_le_bytes(bytes[8..16].try_into().expect("u64"));
    let stage =
        u32::from_le_bytes(bytes[16..20].try_into().expect("u32")) as usize;
    let n_params =
        u32::from_le_bytes(bytes[20..24].try_into().expect("u32")) as usize;
    let s_count = u64::from_le_bytes(bytes[24..32].try_into().expect("u64"));
    if stage != st.stage {
        bail!(
            "checkpoint for stage {stage} offered to stage {}",
            st.stage
        );
    }
    if n_params != st.schema.len() {
        bail!(
            "checkpoint schema length {n_params} != local schema {}",
            st.schema.len()
        );
    }
    let mut off = CKPT_HEADER_LEN;
    let u = Tensor::new(vec![d, k], take_f32s(bytes, &mut off, d * k)?);
    for i in 0..st.schema.len() {
        let (name, shape) = st.schema[i].clone();
        let numel: usize = shape.iter().product();
        if coeff_encoded(&name, mode, codec) {
            let rows = numel / d;
            let coeff = Tensor::new(
                vec![rows, k],
                take_f32s(bytes, &mut off, rows * k)?,
            );
            let mut p = linalg::matmul_nt(&coeff, &u);
            p.shape = shape;
            st.params[i] = p;
        } else {
            st.params[i] = Tensor::new(
                shape.clone(),
                take_f32s(bytes, &mut off, numel)?,
            );
        }
        st.m[i] =
            Tensor::new(shape.clone(), take_f32s(bytes, &mut off, numel)?);
        st.v[i] = Tensor::new(shape, take_f32s(bytes, &mut off, numel)?);
    }
    let s_acc = if has_s_acc {
        Some(Tensor::new(vec![d, d], take_f32s(bytes, &mut off, d * d)?))
    } else {
        None
    };
    if off != bytes.len() {
        bail!(
            "checkpoint has {} trailing bytes past the decoded state",
            bytes.len() - off
        );
    }
    Ok(StageCheckpoint { stage, step, u, s_acc, s_count })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::Hyper;
    use crate::rng::Rng;
    use crate::stage::GlobalState;

    #[test]
    fn ckpt_codec_round_trips_exhaustively() {
        for c in CkptCodec::ALL {
            assert_eq!(c.to_string().parse::<CkptCodec>().unwrap(), c);
            assert_eq!(CkptCodec::from_tag(c.tag()), Some(c));
        }
        let err = "gzip".parse::<CkptCodec>().unwrap_err().to_string();
        assert!(err.contains("raw|coeff"), "{err}");
    }

    fn setup(mode: Mode, stage: usize) -> (Hyper, GlobalState, StageState) {
        let h = Hyper::tiny_native();
        let mut rng = Rng::new(31);
        let g = GlobalState::from_hyper(&h, &mut rng);
        let st = StageState::from_schema(
            h.stage_schema(stage),
            h.stage_kind(stage),
            stage,
            mode,
            &g,
            &mut rng,
        )
        .unwrap();
        (h, g, st)
    }

    fn scramble_moments(st: &mut StageState, rng: &mut Rng) {
        for t in st.m.iter_mut().chain(st.v.iter_mut()) {
            t.data = rng.normal_f32_vec(t.numel(), 0.5);
        }
    }

    #[test]
    fn raw_codec_roundtrips_bitwise() {
        let (h, g, mut st) = setup(Mode::Subspace, 0);
        let mut rng = Rng::new(5);
        scramble_moments(&mut st, &mut rng);
        let bytes = encode_stage(
            &st,
            &g.u,
            None,
            0,
            12,
            Mode::Subspace,
            CkptCodec::Raw,
        );
        let mut fresh = setup(Mode::Subspace, 0).2;
        let ck = decode_stage(&bytes, &mut fresh, h.d, h.k, Mode::Subspace)
            .unwrap();
        assert_eq!(ck.step, 12);
        assert_eq!(ck.u.data, g.u.data);
        assert!(ck.s_acc.is_none());
        for i in 0..st.params.len() {
            assert_eq!(fresh.params[i].data, st.params[i].data, "param {i}");
            assert_eq!(fresh.m[i].data, st.m[i].data, "m {i}");
            assert_eq!(fresh.v[i].data, st.v[i].data, "v {i}");
        }
    }

    #[test]
    fn coeff_codec_restores_within_projection_error_and_stays_in_s() {
        let (h, g, mut st) = setup(Mode::Subspace, 0);
        let mut rng = Rng::new(6);
        scramble_moments(&mut st, &mut rng);
        let bytes = encode_stage(
            &st,
            &g.u,
            None,
            0,
            3,
            Mode::Subspace,
            CkptCodec::Coeff,
        );
        let mut fresh = setup(Mode::Subspace, 0).2;
        decode_stage(&bytes, &mut fresh, h.d, h.k, Mode::Subspace).unwrap();
        for (i, (name, _)) in st.schema.iter().enumerate() {
            let (a, b) = (&st.params[i], &fresh.params[i]);
            assert_eq!(a.shape, b.shape);
            let err: f32 = a
                .data
                .iter()
                .zip(&b.data)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0, f32::max);
            if constrained(name) {
                // params start in S, so P·U·Uᵀ ≈ P to float rounding
                assert!(err < 1e-4, "{name}: coeff error {err}");
            } else {
                assert_eq!(a.data, b.data, "{name} must ship raw");
            }
            // moments are never compressed, even on constrained slots
            assert_eq!(fresh.m[i].data, st.m[i].data, "m {i}");
            assert_eq!(fresh.v[i].data, st.v[i].data, "v {i}");
        }
        assert!(fresh.subspace_leak(&g.u) < 1e-5);
    }

    #[test]
    fn payload_length_matches_memory_model_for_all_codecs() {
        let h = Hyper::tiny_native();
        for stage in 0..h.stages {
            let (_, g, st) = setup(Mode::Subspace, stage);
            let last = stage == h.stages - 1;
            let s_acc = last.then(|| Tensor::zeros(&[h.d, h.d]));
            for codec in [CkptCodec::Raw, CkptCodec::Coeff] {
                let bytes = encode_stage(
                    &st,
                    &g.u,
                    s_acc.as_ref(),
                    7,
                    9,
                    Mode::Subspace,
                    codec,
                );
                assert_eq!(
                    bytes.len(),
                    crate::memory::checkpoint_payload_bytes(
                        &h,
                        stage,
                        Mode::Subspace,
                        codec,
                        last,
                    ),
                    "stage {stage} {codec:?}"
                );
            }
        }
    }

    #[test]
    fn coeff_constrained_tensors_cost_exactly_dp_wire_bytes() {
        let (h, g, st) = setup(Mode::Subspace, 0);
        let raw = encode_stage(
            &st, &g.u, None, 0, 0, Mode::Subspace, CkptCodec::Raw,
        );
        let coeff = encode_stage(
            &st, &g.u, None, 0, 0, Mode::Subspace, CkptCodec::Coeff,
        );
        let constrained_elems: usize = st
            .schema
            .iter()
            .filter(|(n, _)| constrained(n))
            .map(|(_, s)| s.iter().product::<usize>())
            .sum();
        let saved = raw.len() - coeff.len();
        assert_eq!(
            saved,
            constrained_elems * 4
                - crate::compress::dp_wire_bytes(
                    Mode::Subspace,
                    constrained_elems,
                    h.d,
                    h.k,
                    h.ratio,
                ),
            "coeff savings must equal the dp_wire_bytes discount"
        );
    }

    #[test]
    fn corrupt_and_mismatched_payloads_are_rejected() {
        let (h, g, st) = setup(Mode::Subspace, 1);
        let bytes = encode_stage(
            &st,
            &g.u,
            None,
            0,
            2,
            Mode::Subspace,
            CkptCodec::Raw,
        );
        let mut fresh = setup(Mode::Subspace, 1).2;
        // truncation
        let err = decode_stage(
            &bytes[..bytes.len() / 2],
            &mut fresh,
            h.d,
            h.k,
            Mode::Subspace,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("truncated"), "{err}");
        // bad magic
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        let err =
            decode_stage(&bad, &mut fresh, h.d, h.k, Mode::Subspace)
                .unwrap_err()
                .to_string();
        assert!(err.contains("magic"), "{err}");
        // wrong mode
        let err = decode_stage(&bytes, &mut fresh, h.d, h.k, Mode::Raw)
            .unwrap_err()
            .to_string();
        assert!(err.contains("mode"), "{err}");
        // wrong stage
        let mut other = setup(Mode::Subspace, 2).2;
        let err =
            decode_stage(&bytes, &mut other, h.d, h.k, Mode::Subspace)
                .unwrap_err()
                .to_string();
        assert!(err.contains("stage"), "{err}");
    }
}
