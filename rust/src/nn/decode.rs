//! Tape-free autoregressive decode: the serving-side forward path
//! (DESIGN.md §16).
//!
//! Training pushes `(b·n, d)` activations through [`super::tape::Tape`]
//! subgraphs because it needs the backward pass. Serving needs neither
//! the tape nor the full sequence: one decode step advances one
//! position per session, attending over **cached** K/V rows instead of
//! recomputing the whole prefix. This module mirrors
//! [`super::model::build_stage`]'s arithmetic operation-for-operation
//! (same pre-LN blocks, same f64 LayerNorm/softmax accumulation, same
//! causal attention per row) but indexes into a per-session
//! [`StageKv`] cache, so a step costs O(pos·d) attention instead of
//! O(n²·d) recompute.
//!
//! The paper's boundary trick applies verbatim at decode time: a
//! non-last stage emits `(x − e) · U` — the k-dimensional subspace
//! coefficients of its single new row — and the next stage
//! reconstructs `coeffs · Uᵀ + e`. The high-rank component
//! `E = PE + T_fixed[tok]` is computable on every stage from the
//! position and the token id alone, which is why the token relay
//! ([`crate::transport::frame::FrameKind::Token`]) rides the wire: it
//! is simultaneously the user-visible output stream and the seed every
//! stage needs to rebuild `E` for the next position.

use anyhow::{bail, Result};

use crate::compress::Mode;
use crate::manifest::Hyper;
use crate::tensor::Tensor;

use super::tape::LN_EPS;

/// Per-block K/V cache of one session on one stage: rows are appended
/// per decoded position, heads packed exactly like the training-side
/// `(b·n, d)` projections.
#[derive(Clone, Debug, Default)]
pub struct BlockKv {
    /// cached key rows, `pos · d` floats
    pub k: Vec<f32>,
    /// cached value rows, `pos · d` floats
    pub v: Vec<f32>,
}

/// One session's K/V cache on one stage: a [`BlockKv`] per transformer
/// block, plus the number of positions decoded so far.
#[derive(Clone, Debug)]
pub struct StageKv {
    /// per-block caches, `blocks_per_stage` entries
    pub blocks: Vec<BlockKv>,
    /// positions already cached (the next row lands at index `pos`)
    pub pos: usize,
}

impl StageKv {
    /// An empty cache for `blocks` transformer blocks.
    pub fn new(blocks: usize) -> StageKv {
        StageKv { blocks: vec![BlockKv::default(); blocks], pos: 0 }
    }

    /// Bytes this cache actually holds — the measured side of the
    /// [`crate::memory::kv_cache_bytes`] exactness contract.
    pub fn bytes(&self) -> usize {
        self.blocks
            .iter()
            .map(|b| (b.k.len() + b.v.len()) * 4)
            .sum()
    }
}

/// `out = row · W` for a 2-D weight `(d_in, d_out)`.
fn row_matmul(row: &[f32], w: &Tensor) -> Vec<f32> {
    let (d_in, d_out) = w.dims2();
    debug_assert_eq!(row.len(), d_in);
    let mut out = vec![0.0f32; d_out];
    for (i, &a) in row.iter().enumerate() {
        let wrow = &w.data[i * d_out..(i + 1) * d_out];
        for (o, &wc) in out.iter_mut().zip(wrow) {
            *o += a * wc;
        }
    }
    out
}

/// Row-wise LayerNorm — the single-row mirror of
/// [`super::tape::Tape::layer_norm`], bit-for-bit (f64 mean/var, the
/// same ε, the same f32 narrowing points).
fn ln_row(row: &[f32], g: &Tensor, b: &Tensor) -> Vec<f32> {
    let d = row.len();
    debug_assert_eq!(g.data.len(), d);
    debug_assert_eq!(b.data.len(), d);
    let mean = row.iter().map(|v| *v as f64).sum::<f64>() / d as f64;
    let var = row
        .iter()
        .map(|v| (*v as f64 - mean).powi(2))
        .sum::<f64>()
        / d as f64;
    let mu = mean as f32;
    let rstd = (1.0 / (var + LN_EPS as f64).sqrt()) as f32;
    (0..d)
        .map(|j| (row[j] - mu) * rstd * g.data[j] + b.data[j])
        .collect()
}

/// Causal attention for the one new row at position `pos`, reading the
/// cached K/V rows `0..=pos` — the i-th-row arithmetic of the training
/// kernel (max-subtracted softmax, f64 sum, f32 inverse) verbatim.
fn attend_row(
    q: &[f32],
    kv: &BlockKv,
    pos: usize,
    heads: usize,
) -> Vec<f32> {
    let d = q.len();
    let dh = d / heads;
    debug_assert_eq!(dh * heads, d);
    debug_assert!(kv.k.len() >= (pos + 1) * d);
    let scale = 1.0f32 / (dh as f32).sqrt();
    let mut out = vec![0.0f32; d];
    let mut scores = vec![0.0f32; pos + 1];
    for h in 0..heads {
        let off = h * dh;
        let qrow = &q[off..off + dh];
        let mut mx = f32::NEG_INFINITY;
        for (j, sj) in scores.iter_mut().enumerate() {
            let krow = &kv.k[j * d + off..j * d + off + dh];
            let mut s = 0.0f32;
            for (qc, kc) in qrow.iter().zip(krow) {
                s += qc * kc;
            }
            let s = s * scale;
            *sj = s;
            mx = mx.max(s);
        }
        let mut sum = 0.0f64;
        for sj in scores.iter_mut() {
            let e = (*sj - mx).exp();
            *sj = e;
            sum += e as f64;
        }
        let inv = (1.0 / sum) as f32;
        let orow = &mut out[off..off + dh];
        for (j, sj) in scores.iter().enumerate() {
            let a = sj * inv;
            let vrow = &kv.v[j * d + off..j * d + off + dh];
            for (oc, vc) in orow.iter_mut().zip(vrow) {
                *oc += a * vc;
            }
        }
    }
    out
}

/// Greedy sampling: the argmax with strictly-greater comparison, so
/// ties break to the lowest index — deterministic on every platform.
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &l) in logits.iter().enumerate() {
        if l > bv {
            bv = l;
            best = i;
        }
    }
    best as u32
}

/// One stage's decode-side weights and shared bases, borrowed from the
/// same [`crate::stage::StageState`]/[`crate::stage::GlobalState`]
/// tensors the training path builds — serving replays the seeded init
/// stream, so every worker holds identical parameters.
pub struct StageDecoder<'a> {
    /// model dimensions
    pub h: &'a Hyper,
    /// boundary codec mode (decides compressed boundaries and E)
    pub mode: Mode,
    /// pipeline stage index
    pub stage: usize,
    /// schema-ordered parameter tensors of this stage
    pub params: &'a [Tensor],
    /// shared orthonormal basis `U_k`
    pub u: &'a Tensor,
    /// fixed high-rank token embedding `T_fixed` (subspace modes)
    pub t_fixed: &'a Tensor,
    /// sinusoidal positional embedding `(n, d)`
    pub pe: &'a Tensor,
}

impl StageDecoder<'_> {
    /// The high-rank component `E` for one `(pos, tok)` pair — the
    /// single-row mirror of [`super::model::high_rank_e`].
    fn e_row(&self, pos: usize, tok: u32) -> Vec<f32> {
        let d = self.h.d;
        let mut row = self.pe.data[pos * d..(pos + 1) * d].to_vec();
        if self.mode.uses_fixed_embedding() {
            let id = tok as usize * d;
            for (r, f) in row.iter_mut().zip(&self.t_fixed.data[id..id + d]) {
                *r += f;
            }
        }
        row
    }

    /// Advance one session by one position. `tok` is the token at the
    /// session's position `kv.pos` (a prompt token while prefilling,
    /// the previously sampled token afterwards); `input` is the
    /// boundary row from the left neighbor (stages > 0): `k` subspace
    /// coefficients in the compressed modes, the full `d`-width
    /// activation otherwise.
    ///
    /// Returns the stage's output row: the boundary payload for
    /// non-last stages (`k` or `d` floats), the `vocab`-width logits
    /// for the last stage.
    pub fn step(
        &self,
        kv: &mut StageKv,
        tok: u32,
        input: Option<&[f32]>,
    ) -> Result<Vec<f32>> {
        let h = self.h;
        let pos = kv.pos;
        if pos >= h.n {
            bail!(
                "session exceeded the per-session KV capacity n = {} \
                 (the positional embedding and cache are sized to n)",
                h.n
            );
        }
        if tok as usize >= h.vocab {
            bail!("token {tok} out of vocab {}", h.vocab);
        }
        let d = h.d;
        let compressed = self.mode.compressed();
        let last = self.stage == h.stages - 1;

        // ---- stage input: embedding + E, or boundary reconstruction
        let mut x = if self.stage == 0 {
            let t_s = &self.params[0];
            debug_assert_eq!(t_s.dims2(), (h.vocab, d));
            let mut row = self.e_row(pos, tok);
            let emb = &t_s.data[tok as usize * d..(tok as usize + 1) * d];
            for (r, v) in row.iter_mut().zip(emb) {
                *r += v;
            }
            row
        } else {
            let xin = input.ok_or_else(|| {
                anyhow::anyhow!("stage {} needs a boundary input", self.stage)
            })?;
            if compressed {
                if xin.len() != h.k {
                    bail!(
                        "boundary row is {} wide (expected k = {})",
                        xin.len(),
                        h.k
                    );
                }
                // coeffs · Uᵀ + e  (U is (d, k))
                let mut row = self.e_row(pos, tok);
                for (j, r) in row.iter_mut().enumerate() {
                    let urow = &self.u.data[j * h.k..(j + 1) * h.k];
                    let mut acc = 0.0f32;
                    for (c, uc) in xin.iter().zip(urow) {
                        acc += c * uc;
                    }
                    *r += acc;
                }
                row
            } else {
                if xin.len() != d {
                    bail!(
                        "boundary row is {} wide (expected d = {d})",
                        xin.len()
                    );
                }
                xin.to_vec()
            }
        };

        // ---- transformer blocks over the cached prefix
        let first_block = usize::from(self.stage == 0);
        if kv.blocks.len() != h.blocks_per_stage {
            bail!(
                "KV cache has {} blocks (stage schema has {})",
                kv.blocks.len(),
                h.blocks_per_stage
            );
        }
        for blk in 0..h.blocks_per_stage {
            let p = |i: usize| &self.params[first_block + blk * 10 + i];
            let a = ln_row(&x, p(0), p(1));
            let q = row_matmul(&a, p(2));
            let krow = row_matmul(&a, p(3));
            let vrow = row_matmul(&a, p(4));
            let cache = &mut kv.blocks[blk];
            cache.k.extend_from_slice(&krow);
            cache.v.extend_from_slice(&vrow);
            let attn = attend_row(&q, cache, pos, h.heads);
            let attn_out = row_matmul(&attn, p(5));
            for (xj, aj) in x.iter_mut().zip(&attn_out) {
                *xj += aj;
            }
            let hn = ln_row(&x, p(6), p(7));
            let mut h1 = row_matmul(&hn, p(8));
            for v in h1.iter_mut() {
                *v = v.max(0.0);
            }
            let mlp_out = row_matmul(&h1, p(9));
            for (xj, mj) in x.iter_mut().zip(&mlp_out) {
                *xj += mj;
            }
        }
        kv.pos += 1;

        // ---- stage output: boundary payload or logits
        if last {
            let base = first_block + h.blocks_per_stage * 10;
            let xl = ln_row(&x, &self.params[base], &self.params[base + 1]);
            Ok(row_matmul(&xl, &self.params[base + 2]))
        } else if compressed {
            let e = self.e_row(pos, tok);
            let mut coeffs = vec![0.0f32; h.k];
            for j in 0..d {
                let c = x[j] - e[j];
                let urow = &self.u.data[j * h.k..(j + 1) * h.k];
                for (o, uc) in coeffs.iter_mut().zip(urow) {
                    *o += c * uc;
                }
            }
            Ok(coeffs)
        } else {
            Ok(x)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::{
        build_stage, high_rank_e, sinusoidal_pe, StageIo,
    };
    use crate::rng::Rng;
    use crate::stage::{GlobalState, StageState};
    use crate::tensor::IntTensor;

    fn setup(mode: Mode) -> (Hyper, GlobalState, Vec<StageState>, Rng) {
        let mut h = Hyper::tiny_native();
        h.b = 1; // decode compares single sequences
        let mut rng = Rng::new(7);
        let global = GlobalState::from_hyper(&h, &mut rng);
        let stages = (0..h.stages)
            .map(|s| {
                StageState::from_schema(
                    h.stage_schema(s),
                    h.stage_kind(s),
                    s,
                    mode,
                    &global,
                    &mut rng,
                )
                .unwrap()
            })
            .collect();
        (h, global, stages, rng)
    }

    /// Full-sequence pipeline forward through the *training* tapes,
    /// returning the last stage's logits tensor `(n, vocab)`.
    fn tape_logits(
        h: &Hyper,
        mode: Mode,
        global: &GlobalState,
        stages: &[StageState],
        tok: &IntTensor,
    ) -> Tensor {
        let pe = sinusoidal_pe(h.n, h.d);
        let e = high_rank_e(h, mode, &pe, &global.t_fixed, tok);
        let mut cur: Option<Tensor> = None;
        for s in 0..h.stages - 1 {
            let built = build_stage(
                h,
                mode,
                s,
                &stages[s].params,
                StageIo {
                    u: &global.u,
                    e: &e,
                    tok,
                    input: cur.as_ref(),
                    targets: None,
                },
            );
            cur = Some(built.tape.value(built.output).clone());
        }
        // rebuild the last stage's tail by hand (build_stage folds the
        // logits into the loss): reconstruct x, run blocks via the same
        // tape ops, then LN + head
        let last = h.stages - 1;
        let schema_len = stages[last].params.len();
        let base = schema_len - 3;
        let mut tape = crate::nn::Tape::new();
        let pv: Vec<_> = stages[last]
            .params
            .iter()
            .map(|p| tape.leaf(p.clone(), false))
            .collect();
        let xin = tape.leaf(cur.unwrap(), false);
        let mut x = if mode.compressed() {
            let u = tape.leaf(global.u.clone(), false);
            let ev = tape.leaf(e.clone(), false);
            let rec = tape.matmul_nt(xin, u);
            tape.add(rec, ev)
        } else {
            xin
        };
        let dims = crate::nn::AttnDims {
            b: h.b,
            n: h.n,
            heads: h.heads,
            d: h.d,
        };
        for blk in 0..h.blocks_per_stage {
            let p = |i: usize| pv[blk * 10 + i];
            let a = tape.layer_norm(x, p(0), p(1));
            let q = tape.matmul(a, p(2));
            let k = tape.matmul(a, p(3));
            let v = tape.matmul(a, p(4));
            let attn = tape.causal_attention(q, k, v, dims);
            let attn_out = tape.matmul(attn, p(5));
            x = tape.add(x, attn_out);
            let hn = tape.layer_norm(x, p(6), p(7));
            let h1 = tape.matmul(hn, p(8));
            let h1 = tape.relu(h1);
            let mlp_out = tape.matmul(h1, p(9));
            x = tape.add(x, mlp_out);
        }
        let xl = tape.layer_norm(x, pv[base], pv[base + 1]);
        let logits = tape.matmul(xl, pv[base + 2]);
        tape.value(logits).clone()
    }

    /// Decode-path forward of the same tokens, one position at a time
    /// through every stage, returning each position's logits.
    fn decode_logits(
        h: &Hyper,
        mode: Mode,
        global: &GlobalState,
        stages: &[StageState],
        toks: &[u32],
    ) -> Vec<Vec<f32>> {
        let pe = sinusoidal_pe(h.n, h.d);
        let decs: Vec<StageDecoder<'_>> = (0..h.stages)
            .map(|s| StageDecoder {
                h,
                mode,
                stage: s,
                params: &stages[s].params,
                u: &global.u,
                t_fixed: &global.t_fixed,
                pe: &pe,
            })
            .collect();
        let mut kvs: Vec<StageKv> = (0..h.stages)
            .map(|_| StageKv::new(h.blocks_per_stage))
            .collect();
        let mut out = Vec::new();
        for &tok in toks {
            let mut row: Option<Vec<f32>> = None;
            for s in 0..h.stages {
                row = Some(
                    decs[s]
                        .step(&mut kvs[s], tok, row.as_deref())
                        .unwrap(),
                );
            }
            out.push(row.unwrap());
        }
        out
    }

    #[test]
    fn decode_rows_match_tape_forward() {
        // the KV-cached decode path must reproduce the training tapes'
        // logits at every position (same arithmetic, reassociated
        // matmuls → tight relative tolerance, not bitwise)
        for mode in [Mode::Subspace, Mode::Raw] {
            let (h, global, stages, mut rng) = setup(mode);
            let toks: Vec<u32> =
                (0..h.n).map(|_| rng.below(h.vocab) as u32).collect();
            let tok = IntTensor::new(
                vec![1, h.n],
                toks.iter().map(|&t| t as i32).collect(),
            );
            let reference = tape_logits(&h, mode, &global, &stages, &tok);
            let got = decode_logits(&h, mode, &global, &stages, &toks);
            assert_eq!(got.len(), h.n);
            for (pos, row) in got.iter().enumerate() {
                let rref = &reference.data
                    [pos * h.vocab..(pos + 1) * h.vocab];
                let num: f64 = row
                    .iter()
                    .zip(rref)
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
                    .sqrt();
                let den: f64 = rref
                    .iter()
                    .map(|v| (*v as f64).powi(2))
                    .sum::<f64>()
                    .sqrt()
                    + 1e-12;
                assert!(
                    num / den < 1e-3,
                    "{mode:?} pos {pos}: decode row diverges {}",
                    num / den
                );
                // and greedy sampling agrees with the reference row
                assert_eq!(
                    argmax(row),
                    argmax(rref),
                    "{mode:?} pos {pos}: sampled token diverges"
                );
            }
        }
    }

    #[test]
    fn kv_cache_grows_by_exactly_the_analytic_model() {
        let (h, global, stages, _) = setup(Mode::Subspace);
        let pe = sinusoidal_pe(h.n, h.d);
        let dec = StageDecoder {
            h: &h,
            mode: Mode::Subspace,
            stage: 0,
            params: &stages[0].params,
            u: &global.u,
            t_fixed: &global.t_fixed,
            pe: &pe,
        };
        let mut kv = StageKv::new(h.blocks_per_stage);
        assert_eq!(kv.bytes(), 0);
        for pos in 1..=4usize {
            dec.step(&mut kv, 3, None).unwrap();
            assert_eq!(kv.bytes(), crate::memory::kv_cache_bytes(&h, pos));
        }
    }

    #[test]
    fn capacity_and_shape_errors_are_descriptive() {
        let (h, global, stages, _) = setup(Mode::Subspace);
        let pe = sinusoidal_pe(h.n, h.d);
        let dec = StageDecoder {
            h: &h,
            mode: Mode::Subspace,
            stage: 1,
            params: &stages[1].params,
            u: &global.u,
            t_fixed: &global.t_fixed,
            pe: &pe,
        };
        let mut kv = StageKv::new(h.blocks_per_stage);
        // missing boundary input
        let err = dec.step(&mut kv, 0, None).unwrap_err().to_string();
        assert!(err.contains("boundary input"), "{err}");
        // wrong boundary width
        let err = dec
            .step(&mut kv, 0, Some(&vec![0.0; h.k + 1]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("expected k"), "{err}");
        // capacity: n positions fit, n+1 does not
        let row = vec![0.0f32; h.k];
        for _ in 0..h.n {
            dec.step(&mut kv, 0, Some(&row)).unwrap();
        }
        let err =
            dec.step(&mut kv, 0, Some(&row)).unwrap_err().to_string();
        assert!(err.contains("KV capacity"), "{err}");
        // vocab bound
        let mut kv2 = StageKv::new(h.blocks_per_stage);
        let err = dec
            .step(&mut kv2, h.vocab as u32, Some(&row))
            .unwrap_err()
            .to_string();
        assert!(err.contains("out of vocab"), "{err}");
    }

    #[test]
    fn argmax_breaks_ties_toward_the_lowest_index() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
        assert_eq!(argmax(&[0.0]), 0);
    }
}
