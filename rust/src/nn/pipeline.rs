//! The native training pipeline: GPipe microbatching over the tape
//! subgraphs, with the **stage-boundary compression hook** routing every
//! forward activation and backward activation-gradient through the real
//! [`crate::compress`] codecs.
//!
//! This is the artifact-free sibling of [`crate::coordinator::Pipeline`]:
//! same [`PipelineConfig`], same [`StepStats`], same netsim byte
//! accounting and virtual-clock pricing, same RNG streams (identical
//! seeds produce identical init and data batches on both backends) — but
//! the numerics run here, in-process, on the [`super::tape`] autodiff
//! engine instead of AOT HLO through PJRT. Backward uses GPipe-style
//! rematerialization: the forward wave keeps only each stage's boundary
//! input; the backward wave rebuilds the stage subgraph and runs the
//! tape backward through it.
//!
//! Determinism: every tensor op is thread-count-bit-stable (matmuls
//! keep a fixed accumulation order; the tape's data-parallel ops give
//! each pool task sole ownership of its output region — DESIGN.md §13),
//! and all randomness derives from `cfg.seed` — a training run is a
//! pure function of its config, which is what
//! `tests/par_determinism.rs` asserts for the `convergence-native`
//! experiment grid.
//!
//! Weight gradients are microbatch-fused: each backward runs
//! [`Tape::backward_into`], streaming matmul dW products straight into
//! the cross-microbatch accumulators (bitwise what one `matmul_tn` over
//! the row-concatenated microbatches would produce) instead of
//! materializing per-microbatch gradients on the tape and adding them.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::compress::{self, powerlr_rank, Mode};
use crate::coordinator::schedule::{gpipe_makespan, Makespan, StepCosts, Tx};
use crate::coordinator::{PipelineConfig, StepStats};
use crate::linalg;
use crate::manifest::Hyper;
use crate::netsim::Topology;
use crate::obs::trace;
use crate::rng::Rng;
use crate::stage::{constrained, GlobalState, StageState};
use crate::tensor::{IntTensor, Tensor};
use crate::timemodel::{stage_seconds, Phase};

use super::model::{build_stage, high_rank_e, sinusoidal_pe, StageIo};
use super::optim::{step_stage, OptStep, Optim};
use super::tape::Tape;

/// Which direction a boundary payload travels (seeds the deterministic
/// PowerLR sketch stream and picks the wire-frame kind in the
/// distributed transport).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BoundaryDir {
    /// stage s → s+1 activation payload
    Fwd,
    /// stage s+1 → s activation-gradient payload
    Bwd,
}

/// Encode one boundary payload exactly as the native backend ships it —
/// the **single** codec path shared by [`NativePipeline`] (in-process)
/// and the distributed transport workers, so a frame produced on one
/// side of a socket is bit-identical to what the single-process run
/// would have round-tripped. For PowerLR the deterministic rank-limited
/// reconstruction (sketch stream derived from `(seed, step, link, mb,
/// dir)`) is applied *before* dense encoding, mirroring the in-process
/// hook; its frame is the dense stand-in while `wire_bytes` accounts
/// factor shipping (see [`crate::compress::encode`]). `link` is the
/// pipeline link index the payload crosses: the sending stage for
/// forward payloads, the receiving stage for backward ones.
pub fn encode_boundary(
    cfg: &PipelineConfig,
    h: &Hyper,
    t: &Tensor,
    link: usize,
    mb: usize,
    dir: BoundaryDir,
    step: u64,
) -> compress::Frame {
    match cfg.mode {
        Mode::PowerLR => {
            let rank = powerlr_rank(h.n, h.d, h.ratio);
            let tag = (link as u64) << 20
                | (mb as u64) << 4
                | match dir {
                    BoundaryDir::Fwd => 0,
                    BoundaryDir::Bwd => 1,
                };
            let mut rng = Rng::new(
                cfg.seed ^ 0x70E7 ^ step.wrapping_mul(0x9E37) ^ tag,
            );
            let reduced = linalg::low_rank_approx(t, rank, &mut rng);
            compress::encode_dense(&reduced, Mode::PowerLR)
        }
        mode => compress::encode(t, mode, h.ratio),
    }
}

/// One Riemannian Grassmann step of the shared basis: U ← retract(U −
/// η·tangent) with η adapted by trace(S̄) — the pure math of
/// `grassmann_update`, extracted so the distributed last-stage worker
/// computes the *same* new basis the single-process backend would
/// (timing/broadcast accounting stays with the callers).
pub fn grassmann_step_u(
    u: &Tensor,
    s_acc: &Tensor,
    s_count: u64,
    eta_base: f64,
) -> Tensor {
    let d = u.dims2().0;
    let mut s_avg = s_acc.clone();
    s_avg.scale(1.0 / s_count.max(1) as f32);
    let trace: f64 = (0..d).map(|i| s_avg.at2(i, i) as f64).sum();
    let eta = if trace > 1e-12 {
        (eta_base * d as f64 / trace) as f32
    } else {
        0.0
    };
    // ∇L(U) = −2·S·U; tangent = ∇ − U(Uᵀ∇); retraction = MGS
    let mut g_euc = linalg::matmul(&s_avg, u);
    g_euc.scale(-2.0);
    let utg = linalg::matmul_tn(u, &g_euc);
    let mut u_new = u.clone();
    let proj = linalg::matmul(u, &utg);
    for i in 0..u_new.data.len() {
        u_new.data[i] -= eta * (g_euc.data[i] - proj.data[i]);
    }
    linalg::orthonormalize_columns(&mut u_new);
    u_new
}

/// Re-project one stage's constrained weights and first moments onto
/// the (new) subspace — the per-stage half of the Grassmann protocol,
/// shared verbatim between the in-process backend and the distributed
/// workers (each worker re-projects only the stage it owns).
pub fn reproject_stage(st: &mut StageState, u: &Tensor) {
    for i in 0..st.params.len() {
        if constrained(&st.schema[i].0) {
            st.params[i] = linalg::project_rows(&st.params[i], u);
            st.m[i] = linalg::project_rows(&st.m[i], u);
        }
    }
}

/// The state suspended between the two halves of a training step —
/// produced by [`NativePipeline::forward_backward`], consumed by
/// [`NativePipeline::apply_update`]. `grad_acc` is the only field a
/// caller mutates: the DP drivers (in-process reference and wire grid
/// alike) all-reduce it across replicas at this seam, so the optimizer
/// sees replica-averaged gradients exactly where a fused single-process
/// run would (DESIGN.md §14).
pub struct PendingStep {
    /// per-stage parameter gradients, already averaged over
    /// microbatches (the 1/M scale is applied)
    pub grad_acc: Vec<Vec<Tensor>>,
    /// f64 sum of this step's microbatch losses (divide by M for the
    /// step's mean loss)
    pub loss_sum: f64,
    costs: StepCosts,
    wire: u64,
    t_host: Instant,
}

/// A natively-trained pipeline: P stage subgraphs over a netsim
/// [`Topology`], stepped entirely in-process.
pub struct NativePipeline {
    /// model/pipeline dimensions
    pub h: Hyper,
    /// run-level configuration (shared with the PJRT backend)
    pub cfg: PipelineConfig,
    /// optimizer the native backend steps with
    pub optim: Optim,
    /// stage-to-stage network links
    pub topo: Topology,
    /// per-stage parameters + optimizer state
    pub stages: Vec<StageState>,
    /// leader-owned global state (U_k basis, fixed embedding)
    pub global: GlobalState,
    /// sinusoidal positional embedding (n, d)
    pub pe: Tensor,
    /// optimizer steps completed
    pub step: u64,
    /// simulated seconds since construction (includes startup broadcast)
    pub clock: f64,
    /// host wall-clock seconds actually spent computing
    pub host_seconds: f64,
    /// last step's averaged per-stage gradients (when cfg.record_grads)
    pub last_grads: Option<Vec<Vec<Tensor>>>,
    /// Grassmann accumulator S = Σ GᵀG and its sample count
    s_acc: Tensor,
    s_count: u64,
    rng: Rng,
    /// peak transient+persistent bytes observed over the last step
    peak_bytes: usize,
}

impl NativePipeline {
    /// Build a native pipeline from bare dimensions — no manifest, no
    /// artifacts, no PJRT. Initialization mirrors the PJRT path bit for
    /// bit (same RNG stream layout), so both backends start from the
    /// same parameters when their dimensions agree.
    pub fn new(
        h: Hyper,
        topo: Topology,
        cfg: PipelineConfig,
        optim: Optim,
    ) -> Result<NativePipeline> {
        if topo.stages() != h.stages {
            bail!(
                "topology has {} stages, model needs {}",
                topo.stages(),
                h.stages
            );
        }
        if h.d % h.heads != 0 {
            bail!("d={} not divisible by heads={}", h.d, h.heads);
        }
        if h.blocks_per_stage * h.stages != h.layers {
            bail!(
                "layers={} != blocks_per_stage={} x stages={}",
                h.layers,
                h.blocks_per_stage,
                h.stages
            );
        }
        if h.k >= h.d {
            bail!("subspace rank k={} must be < d={}", h.k, h.d);
        }
        if h.stages < 2 {
            bail!("the native pipeline needs >= 2 stages (got {})", h.stages);
        }
        if matches!(cfg.schedule, crate::sim::Schedule::Interleaved { .. }) {
            bail!(
                "interleaved schedules need wrap-link samples the \
                 coordinator does not carry; use the swarm simulator"
            );
        }
        let mut rng = Rng::new(cfg.seed ^ 0x9137);
        let global = GlobalState::from_hyper(&h, &mut rng);
        let stages = (0..h.stages)
            .map(|s| {
                StageState::from_schema(
                    h.stage_schema(s),
                    h.stage_kind(s),
                    s,
                    cfg.mode,
                    &global,
                    &mut rng,
                )
            })
            .collect::<Result<Vec<_>>>()?;
        let pe = sinusoidal_pe(h.n, h.d);
        let d = h.d;
        let mut pipe = NativePipeline {
            pe,
            stages,
            global,
            topo,
            optim,
            step: 0,
            clock: 0.0,
            host_seconds: 0.0,
            last_grads: None,
            s_acc: Tensor::zeros(&[d, d]),
            s_count: 0,
            rng,
            peak_bytes: 0,
            h,
            cfg,
        };
        if pipe.compressed() {
            let bytes =
                (pipe.h.vocab * pipe.h.d + pipe.h.d * pipe.h.k) * 4;
            pipe.clock += pipe.topo.broadcast(bytes);
        }
        Ok(pipe)
    }

    /// Re-seed the training-data RNG stream without touching parameters.
    pub fn reseed_data(&mut self, seed: u64) {
        self.rng = Rng::new(seed ^ 0xDA7A_5EED);
    }

    fn compressed(&self) -> bool {
        self.cfg.compressed()
    }

    /// Bytes one boundary payload occupies on the wire (identical to the
    /// PJRT path's accounting; the codec frames are asserted against it
    /// in tests).
    pub fn boundary_bytes(&self) -> usize {
        self.cfg.boundary_bytes(&self.h)
    }

    fn lr_now(&self) -> f32 {
        self.cfg.lr_at(self.step)
    }

    /// The boundary hook: route one payload through the configured
    /// codec via the shared [`encode_boundary`] path (the same frames
    /// the distributed transport puts on the wire). Returns (delivered
    /// tensor, wire bytes). Subspace/raw payloads round-trip the dense
    /// codec losslessly; top-k and int8 round-trip their real (lossy)
    /// encoders; PowerLR applies an actual rank-limited reconstruction
    /// with a sketch stream derived deterministically from (seed, step,
    /// stage, microbatch, direction).
    fn ship(
        &self,
        t: &Tensor,
        stage: usize,
        mb: usize,
        dir: BoundaryDir,
    ) -> (Tensor, usize) {
        let tt = trace::begin();
        let bytes = self.boundary_bytes();
        let frame =
            encode_boundary(&self.cfg, &self.h, t, stage, mb, dir, self.step);
        // PowerLR's dense frame stands in for factor shipping — wire
        // accounting stays on the factor bytes; every other codec's
        // frame IS the wire representation
        let wire = if self.cfg.mode == Mode::PowerLR {
            bytes
        } else {
            debug_assert_eq!(
                frame.wire_len(),
                bytes,
                "codec frame disagrees with wire accounting"
            );
            frame.wire_len()
        };
        let delivered = compress::decode(&frame);
        if trace::enabled() {
            trace::set_track(0, stage as u32);
            trace::end(
                "codec",
                match dir {
                    BoundaryDir::Fwd => "ship:fwd",
                    BoundaryDir::Bwd => "ship:bwd",
                },
                tt,
                vec![
                    trace::u("step", self.step),
                    trace::u("mb", mb as u64),
                    trace::u("bytes", wire as u64),
                ],
            );
        }
        (delivered, wire)
    }

    fn note_peak(&mut self, tape: &Tape, extra: usize) {
        self.peak_bytes = self.peak_bytes.max(
            self.persistent_bytes() + tape.bytes() + extra,
        );
    }

    /// Bytes held for the whole run: parameters, both optimizer moment
    /// sets, and the shared global state (U, T_fixed, PE).
    pub fn persistent_bytes(&self) -> usize {
        let params: usize =
            self.stages.iter().map(|s| s.param_count() * 3 * 4).sum();
        params
            + (self.global.u.numel()
                + self.global.t_fixed.numel()
                + self.pe.numel())
                * 4
    }

    /// Peak bytes (persistent + transient) observed during the most
    /// recent [`NativePipeline::train_step`] — the measured side of the
    /// `memory::native_peak_bytes` model.
    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    /// Max relative out-of-subspace leak across constrained weights.
    pub fn subspace_leak(&self) -> f64 {
        self.stages
            .iter()
            .map(|s| s.subspace_leak(&self.global.u))
            .fold(0.0, f64::max)
    }

    /// Accumulate one built stage's parameter gradients into `acc`
    /// without cloning (grads stay borrowed from the tape; params the
    /// root does not depend on contribute nothing).
    fn accumulate_grads(built: &super::model::BuiltStage, acc: &mut [Tensor]) {
        for (a, p) in acc.iter_mut().zip(&built.params) {
            if let Some(g) = built.tape.grad(*p) {
                a.add_assign(g);
            }
        }
    }

    /// One full training step over `cfg.microbatches` sampled batches —
    /// [`forward_backward`](Self::forward_backward) then
    /// [`apply_update`](Self::apply_update), with nothing in between.
    pub fn train_step<F>(&mut self, sampler: F) -> Result<StepStats>
    where
        F: FnMut(&mut Rng) -> (IntTensor, IntTensor),
    {
        let pending = self.forward_backward(sampler)?;
        self.apply_update(pending)
    }

    /// The forward/backward half of one training step: run every
    /// microbatch's waves, fuse weight gradients, and return the
    /// per-stage accumulators already averaged over microbatches (the
    /// 1/M scale applied). This is the data-parallel seam: a DP driver
    /// reduces `PendingStep::grad_acc` across replicas before handing it
    /// to [`apply_update`](Self::apply_update); calling the two halves
    /// back-to-back is bitwise [`train_step`](Self::train_step).
    pub fn forward_backward<F>(&mut self, mut sampler: F) -> Result<PendingStep>
    where
        F: FnMut(&mut Rng) -> (IntTensor, IntTensor),
    {
        let t_host = Instant::now();
        let h = self.h.clone();
        let (p, m_count) = (h.stages, self.cfg.microbatches);
        let last = p - 1;
        let bbytes = self.boundary_bytes();
        let compressed = self.compressed();
        let tm = self.cfg.time_model;

        let mut grad_acc: Vec<Vec<Tensor>> =
            self.stages.iter().map(|st| st.zero_grads()).collect();
        let grad_acc_bytes: usize =
            grad_acc.iter().flatten().map(|g| g.numel() * 4).sum();
        let mut costs = StepCosts {
            stages: p,
            microbatches: m_count,
            fwd: vec![vec![0.0; m_count]; p],
            bwd: vec![vec![0.0; m_count]; p],
            tx_fwd: vec![vec![Tx::default(); m_count]; p - 1],
            tx_bwd: vec![vec![Tx::default(); m_count]; p - 1],
            opt: vec![0.0; p],
            tail: 0.0,
        };
        let mut loss_sum = 0.0f64;
        let mut wire = 0u64;
        self.peak_bytes = 0;

        let mut data_rng = self.rng.fork(0xDA7A ^ self.step);
        for mb in 0..m_count {
            let (tok, tgt) = sampler(&mut data_rng);
            let e = high_rank_e(
                &h,
                self.cfg.mode,
                &self.pe,
                &self.global.t_fixed,
                &tok,
            );
            // ---- forward wave (tapes dropped: GPipe rematerialization)
            let mut saved_inputs: Vec<Option<Tensor>> = vec![None; p];
            let mut saved_bytes = 0usize;
            for s in 0..last {
                if trace::enabled() {
                    trace::set_track(0, s as u32);
                }
                let t0 = Instant::now();
                let tt = trace::begin();
                let built = build_stage(
                    &h,
                    self.cfg.mode,
                    s,
                    &self.stages[s].params,
                    StageIo {
                        u: &self.global.u,
                        e: &e,
                        tok: &tok,
                        input: saved_inputs[s].as_ref(),
                        targets: None,
                    },
                );
                let out = built.tape.value(built.output).clone();
                if trace::enabled() {
                    trace::end(
                        "compute",
                        "fwd",
                        tt,
                        vec![
                            trace::u("step", self.step),
                            trace::u("mb", mb as u64),
                        ],
                    );
                }
                costs.fwd[s][mb] = stage_seconds(
                    tm,
                    &h,
                    s,
                    Phase::Fwd,
                    compressed,
                    Some(t0.elapsed().as_secs_f64()),
                );
                self.note_peak(
                    &built.tape,
                    grad_acc_bytes + saved_bytes,
                );
                let (delivered, nbytes) = self.ship(&out, s, mb, BoundaryDir::Fwd);
                let (ser, lat) = self.topo.links[s].sample(bbytes);
                costs.tx_fwd[s][mb] = Tx { ser, lat };
                wire += nbytes as u64;
                saved_bytes += delivered.numel() * 4;
                saved_inputs[s + 1] = Some(delivered);
            }
            // ---- last stage: fused fwd + loss + bwd
            if trace::enabled() {
                trace::set_track(0, last as u32);
            }
            let t0 = Instant::now();
            let tt = trace::begin();
            let mut built = build_stage(
                &h,
                self.cfg.mode,
                last,
                &self.stages[last].params,
                StageIo {
                    u: &self.global.u,
                    e: &e,
                    tok: &tok,
                    input: saved_inputs[last].as_ref(),
                    targets: Some(&tgt),
                },
            );
            loss_sum += built.tape.value(built.output).item() as f64;
            built.tape.backward_into(
                built.output,
                None,
                &built.params,
                &mut grad_acc[last],
            );
            costs.fwd[last][mb] = stage_seconds(
                tm,
                &h,
                last,
                Phase::LastLoss,
                compressed,
                Some(t0.elapsed().as_secs_f64()),
            );
            if trace::enabled() {
                trace::end(
                    "compute",
                    "fused",
                    tt,
                    vec![
                        trace::u("step", self.step),
                        trace::u("mb", mb as u64),
                    ],
                );
            }
            // matmul weight grads went straight into grad_acc; harvest
            // the tape-held rest (LayerNorm gains/biases, t_s)
            Self::accumulate_grads(&built, &mut grad_acc[last]);
            if compressed {
                let g_full = built
                    .tape
                    .grad(built.x_full.expect("last stage reconstructs"))
                    .expect("g_full");
                linalg::matmul_tn_acc(g_full, g_full, &mut self.s_acc);
                self.s_count += 1;
            }
            let mut gc = built
                .tape
                .grad(built.input.expect("last stage has an input"))
                .expect("boundary gradient")
                .clone();
            self.note_peak(&built.tape, grad_acc_bytes + saved_bytes);
            drop(built);

            // ---- backward wave
            for s in (0..last).rev() {
                let (delivered, nbytes) = self.ship(&gc, s, mb, BoundaryDir::Bwd);
                let (ser, lat) = self.topo.links[s].sample(bbytes);
                costs.tx_bwd[s][mb] = Tx { ser, lat };
                wire += nbytes as u64;

                if trace::enabled() {
                    trace::set_track(0, s as u32);
                }
                let t0 = Instant::now();
                let tt = trace::begin();
                let mut built = build_stage(
                    &h,
                    self.cfg.mode,
                    s,
                    &self.stages[s].params,
                    StageIo {
                        u: &self.global.u,
                        e: &e,
                        tok: &tok,
                        input: saved_inputs[s].as_ref(),
                        targets: None,
                    },
                );
                built.tape.backward_into(
                    built.output,
                    Some(delivered),
                    &built.params,
                    &mut grad_acc[s],
                );
                costs.bwd[s][mb] = stage_seconds(
                    tm,
                    &h,
                    s,
                    Phase::Bwd,
                    compressed,
                    Some(t0.elapsed().as_secs_f64()),
                );
                if trace::enabled() {
                    trace::end(
                        "compute",
                        "bwd",
                        tt,
                        vec![
                            trace::u("step", self.step),
                            trace::u("mb", mb as u64),
                        ],
                    );
                }
                Self::accumulate_grads(&built, &mut grad_acc[s]);
                self.note_peak(&built.tape, grad_acc_bytes + saved_bytes);
                if s > 0 {
                    gc = built
                        .tape
                        .grad(built.input.expect("mid stage has an input"))
                        .expect("boundary gradient")
                        .clone();
                }
            }
        }

        // ---- average grads over microbatches (the 1/M scale)
        let scale = 1.0 / m_count as f32;
        for st_grads in grad_acc.iter_mut() {
            for g in st_grads.iter_mut() {
                g.scale(scale);
            }
        }
        if self.cfg.record_grads {
            self.last_grads = Some(grad_acc.clone());
        }
        Ok(PendingStep { grad_acc, loss_sum, costs, wire, t_host })
    }

    /// The optimizer half of one training step: step every stage with
    /// the (possibly replica-reduced) gradients, run Grassmann subspace
    /// maintenance at its cadence, and settle the step's makespan and
    /// clocks. Consumes the [`PendingStep`] its
    /// [`forward_backward`](Self::forward_backward) produced.
    pub fn apply_update(&mut self, pending: PendingStep) -> Result<StepStats> {
        let PendingStep { grad_acc, loss_sum, mut costs, wire, t_host } =
            pending;
        let h = self.h.clone();
        let (p, m_count) = (h.stages, self.cfg.microbatches);
        let compressed = self.compressed();
        let tm = self.cfg.time_model;
        let lr = self.lr_now();
        let t_opt = (self.step + 1) as f32;
        let u = self.global.u.clone();
        for s in 0..p {
            if trace::enabled() {
                trace::set_track(0, s as u32);
            }
            let t0 = Instant::now();
            let tt = trace::begin();
            step_stage(
                &mut self.stages[s],
                &grad_acc[s],
                &OptStep {
                    optim: self.optim,
                    u: compressed.then_some(&u),
                    lr,
                    t: t_opt,
                },
            );
            costs.opt[s] = stage_seconds(
                tm,
                &h,
                s,
                Phase::Opt,
                compressed,
                Some(t0.elapsed().as_secs_f64()),
            );
            if trace::enabled() {
                trace::end(
                    "compute",
                    "opt",
                    tt,
                    vec![trace::u("step", self.step)],
                );
            }
        }

        // ---- Grassmann subspace maintenance (Sec. 4.5)
        if compressed
            && self.cfg.grassmann_interval > 0
            && (self.step + 1) % self.cfg.grassmann_interval as u64 == 0
            && self.s_count > 0
        {
            costs.tail += self.grassmann_update();
        }

        let makespan = self.step_makespan(&costs)?;
        self.clock += makespan.total;
        self.step += 1;
        self.host_seconds += t_host.elapsed().as_secs_f64();
        Ok(StepStats {
            step: self.step,
            loss: loss_sum / m_count as f64,
            sim_seconds: makespan.total,
            wire_bytes: wire,
            tokens: m_count * h.b * h.n,
            makespan,
        })
    }

    /// Price one step's costs under the configured schedule (same rules
    /// as the PJRT path).
    fn step_makespan(&self, costs: &StepCosts) -> Result<Makespan> {
        if matches!(self.cfg.schedule, crate::sim::Schedule::Gpipe)
            && !self.cfg.event_sim
        {
            Ok(gpipe_makespan(costs))
        } else {
            crate::sim::step_makespan(costs, self.cfg.schedule)
        }
    }

    /// Riemannian subspace update + re-projection of constrained
    /// weights/momenta; returns simulated tail seconds. The math lives
    /// in [`grassmann_step_u`] / [`reproject_stage`], shared with the
    /// distributed transport's last-stage worker.
    fn grassmann_update(&mut self) -> f64 {
        let h = self.h.clone();
        if trace::enabled() {
            trace::set_track(0, (h.stages - 1) as u32);
        }
        let tt = trace::begin();
        let t0 = Instant::now();
        self.global.u = grassmann_step_u(
            &self.global.u,
            &self.s_acc,
            self.s_count,
            self.cfg.grassmann_eta,
        );
        let mut secs = stage_seconds(
            self.cfg.time_model,
            &h,
            h.stages - 1,
            Phase::Grassmann,
            true,
            Some(t0.elapsed().as_secs_f64()),
        );
        for s in 0..h.stages {
            let t0 = Instant::now();
            reproject_stage(&mut self.stages[s], &self.global.u);
            secs += stage_seconds(
                self.cfg.time_model,
                &h,
                s,
                Phase::Grassmann,
                true,
                Some(t0.elapsed().as_secs_f64()),
            );
        }
        secs += self.topo.broadcast(h.d * h.k * 4);
        self.s_acc = Tensor::zeros(&[h.d, h.d]);
        self.s_count = 0;
        if trace::enabled() {
            trace::end(
                "compute",
                "grassmann",
                tt,
                vec![trace::u("step", self.step)],
            );
        }
        secs
    }

    /// Mean validation loss over `batches` forward passes (no backward,
    /// no optimizer). Side-effect free like the PJRT path: the batch
    /// stream derives from `(cfg.seed, step)` only, so mid-training
    /// evals never shift subsequent training batches.
    pub fn eval<F>(&mut self, batches: usize, mut sampler: F) -> Result<f64>
    where
        F: FnMut(&mut Rng) -> (IntTensor, IntTensor),
    {
        let h = self.h.clone();
        let last = h.stages - 1;
        let mut rng = Rng::new(
            self.cfg.seed ^ 0xE7A1 ^ self.step.wrapping_mul(0x9E37_79B9),
        );
        let mut sum = 0.0;
        for _ in 0..batches {
            let (tok, tgt) = sampler(&mut rng);
            let e = high_rank_e(
                &h,
                self.cfg.mode,
                &self.pe,
                &self.global.t_fixed,
                &tok,
            );
            let mut cur: Option<Tensor> = None;
            for s in 0..last {
                let built = build_stage(
                    &h,
                    self.cfg.mode,
                    s,
                    &self.stages[s].params,
                    StageIo {
                        u: &self.global.u,
                        e: &e,
                        tok: &tok,
                        input: cur.as_ref(),
                        targets: None,
                    },
                );
                let out = built.tape.value(built.output).clone();
                let (delivered, _) = self.ship(&out, s, 0, BoundaryDir::Fwd);
                cur = Some(delivered);
            }
            let built = build_stage(
                &h,
                self.cfg.mode,
                last,
                &self.stages[last].params,
                StageIo {
                    u: &self.global.u,
                    e: &e,
                    tok: &tok,
                    input: cur.as_ref(),
                    targets: Some(&tgt),
                },
            );
            sum += built.tape.value(built.output).item() as f64;
        }
        Ok(sum / batches.max(1) as f64)
    }

    /// Serialize every stage's trainable state at the current step
    /// boundary — one [`crate::compress::ckpt`] blob per stage, the
    /// exact payloads the elastic runtime ships in `Checkpoint` frames
    /// (the Grassmann accumulator rides with the last stage, mirroring
    /// the one distributed worker that owns it).
    pub fn checkpoint(&self, codec: crate::compress::CkptCodec) -> Vec<Vec<u8>> {
        let tt = trace::begin();
        let last = self.h.stages - 1;
        let with_acc = self.compressed();
        let blobs: Vec<Vec<u8>> = (0..self.h.stages)
            .map(|s| {
                crate::compress::ckpt::encode_stage(
                    &self.stages[s],
                    &self.global.u,
                    (s == last && with_acc).then_some(&self.s_acc),
                    self.s_count,
                    self.step,
                    self.cfg.mode,
                    codec,
                )
            })
            .collect();
        if trace::enabled() {
            let bytes: usize = blobs.iter().map(Vec::len).sum();
            trace::end(
                "ckpt",
                "write",
                tt,
                vec![
                    trace::u("step", self.step),
                    trace::u("bytes", bytes as u64),
                ],
            );
        }
        blobs
    }

    /// Restore from per-stage checkpoint blobs taken at step boundary
    /// `step` (by this pipeline or a distributed worker with the same
    /// spec). The data-RNG forks of the skipped steps are burned so the
    /// post-restore batch stream is byte-identical to a pipeline that
    /// really trained them — with the `Raw` codec, resumed training is
    /// **bitwise** the uninterrupted run. Restoring backwards is
    /// rejected: the RNG stream cannot rewind (build a fresh pipeline).
    pub fn restore(&mut self, blobs: &[Vec<u8>], step: u64) -> Result<()> {
        let tt = trace::begin();
        if blobs.len() != self.h.stages {
            bail!(
                "restore got {} blobs for a {}-stage pipeline",
                blobs.len(),
                self.h.stages
            );
        }
        if step < self.step {
            bail!(
                "cannot rewind from step {} to {step}: the data-RNG \
                 stream only advances",
                self.step
            );
        }
        let (d, k) = (self.h.d, self.h.k);
        let mode = self.cfg.mode;
        let mut restored: Option<crate::compress::ckpt::StageCheckpoint> =
            None;
        for (s, blob) in blobs.iter().enumerate() {
            let ck = crate::compress::ckpt::decode_stage(
                blob,
                &mut self.stages[s],
                d,
                k,
                mode,
            )
            .with_context(|| format!("restoring stage {s}"))?;
            if ck.step != step {
                bail!(
                    "stage {s} checkpoint is for boundary {} (expected \
                     {step})",
                    ck.step
                );
            }
            restored = Some(ck);
        }
        let ck = restored.expect(">= 2 stages");
        self.global.u = ck.u;
        self.s_count = ck.s_count;
        if let Some(acc) = ck.s_acc {
            self.s_acc = acc;
        }
        for s in self.step..step {
            let _ = self.rng.fork(0xDA7A ^ s);
        }
        self.step = step;
        if trace::enabled() {
            let bytes: usize = blobs.iter().map(Vec::len).sum();
            trace::end(
                "ckpt",
                "restore",
                tt,
                vec![
                    trace::u("step", step),
                    trace::u("bytes", bytes as u64),
                ],
            );
        }
        Ok(())
    }
}
