//! The paper's decoder-only transformer as per-stage tape subgraphs.
//!
//! This is the native mirror of `python/compile/model.py`: identical
//! architecture (pre-LN blocks `x += Attn(LN(x))·W_p1`,
//! `x += relu(LN(x)·W_1)·W_p2`), identical parameter schema
//! ([`Hyper::stage_schema`]), and identical boundary semantics — in the
//! compressed modes the high-rank component `E = PE + T_fixed[tok]` is
//! subtracted before projecting onto `U_k` at the sending stage and
//! re-added after reconstruction at the receiver (Eq. 8), so the (b·n, k)
//! coefficients are the only trainable signal on the wire. Because the
//! projection/reconstruction pair lives *on the tape*, the gradient of
//! the boundary-input leaf is already the k-dimensional coefficient
//! cotangent `G·U_k` (Eq. 9) — the backward wire payload falls out of
//! autodiff instead of being a bolted-on approximation.

use crate::compress::Mode;
use crate::manifest::Hyper;
use crate::tensor::{IntTensor, Tensor};

use super::tape::{AttnDims, Tape, Var};

/// Sinusoidal positional embedding (n, d) — deterministic, computable
/// locally on every node, hence part of the high-rank component E.
pub fn sinusoidal_pe(n: usize, d: usize) -> Tensor {
    let mut data = vec![0.0f32; n * d];
    for pos in 0..n {
        for i in 0..d {
            let angle = pos as f64
                / 10000f64.powf(2.0 * (i / 2) as f64 / d as f64);
            data[pos * d + i] =
                if i % 2 == 0 { angle.sin() } else { angle.cos() } as f32;
        }
    }
    Tensor::new(vec![n, d], data)
}

/// The high-rank additive component E for one microbatch, as a (b·n, d)
/// host tensor: `PE + T_fixed[tok]` in subspace mode, plain broadcast PE
/// in the nofixed ablation and in the raw/lossy modes (whose trainable
/// embedding lives on the tape instead).
pub fn high_rank_e(
    h: &Hyper,
    mode: Mode,
    pe: &Tensor,
    t_fixed: &Tensor,
    tok: &IntTensor,
) -> Tensor {
    let (b, n, d) = (h.b, h.n, h.d);
    debug_assert_eq!(tok.numel(), b * n);
    let mut data = vec![0.0f32; b * n * d];
    for bi in 0..b {
        for t in 0..n {
            let row = &mut data[(bi * n + t) * d..(bi * n + t + 1) * d];
            row.copy_from_slice(&pe.data[t * d..(t + 1) * d]);
            if mode.uses_fixed_embedding() {
                let id = tok.data[bi * n + t] as usize;
                let fixed = &t_fixed.data[id * d..(id + 1) * d];
                for (r, f) in row.iter_mut().zip(fixed) {
                    *r += f;
                }
            }
        }
    }
    Tensor::new(vec![b * n, d], data)
}

/// Non-parameter inputs of one stage subgraph.
pub struct StageIo<'a> {
    /// shared orthonormal basis U_k (compressed modes)
    pub u: &'a Tensor,
    /// high-rank component E of this microbatch, (b·n, d)
    pub e: &'a Tensor,
    /// token ids (b, n) — consumed by the stage-0 embedding
    pub tok: &'a IntTensor,
    /// boundary input for stages > 0: (b·n, k) coefficients in the
    /// compressed modes, the (possibly lossily reconstructed) (b·n, d)
    /// activation otherwise
    pub input: Option<&'a Tensor>,
    /// next-token targets — last stage only
    pub targets: Option<&'a IntTensor>,
}

/// A stage subgraph, built and ready for backward.
pub struct BuiltStage {
    /// the tape holding the graph
    pub tape: Tape,
    /// parameter leaves, schema order
    pub params: Vec<Var>,
    /// boundary-input leaf (stages > 0): its gradient is the backward
    /// wire payload
    pub input: Option<Var>,
    /// boundary payload (non-last stages) or the scalar loss (last)
    pub output: Var,
    /// the full-width activation right after boundary reconstruction
    /// (stages > 0) — its gradient is `g_full`, the Grassmann
    /// accumulator term at the last stage
    pub x_full: Option<Var>,
    /// the full-width residual stream right before the boundary
    /// projection (non-last stages) — the closure diagnostic: `x − e`
    /// must lie in S for the compressed wire to be lossless
    pub pre_boundary: Option<Var>,
}

/// Build one stage's forward subgraph. Names/shapes follow
/// [`Hyper::stage_schema`]; `params` must be in schema order.
pub fn build_stage(
    h: &Hyper,
    mode: Mode,
    stage: usize,
    params: &[Tensor],
    io: StageIo<'_>,
) -> BuiltStage {
    let compressed = mode.compressed();
    let last = stage == h.stages - 1;
    let mut tape = Tape::new();
    let pvars: Vec<Var> =
        params.iter().map(|p| tape.leaf(p.clone(), true)).collect();
    // E is consumed by the stage-0 embedding and by the compressed
    // boundary pair; raw/lossy mid+last stages never touch it
    let e = (stage == 0 || compressed)
        .then(|| tape.leaf(io.e.clone(), false));
    let u = compressed.then(|| tape.leaf(io.u.clone(), false));

    // ---- stage input
    let mut input_var = None;
    let mut x_full = None;
    let mut x = if stage == 0 {
        let emb = tape.embed(pvars[0], io.tok);
        tape.add(e.expect("stage 0 uses E"), emb)
    } else {
        let xin = tape.leaf(
            io.input.expect("stage > 0 needs a boundary input").clone(),
            true,
        );
        input_var = Some(xin);
        let x = if let Some(u) = u {
            let rec = tape.matmul_nt(xin, u);
            tape.add(rec, e.expect("compressed stages use E"))
        } else {
            xin
        };
        x_full = Some(x);
        x
    };

    // ---- transformer blocks
    let dims = AttnDims { b: h.b, n: h.n, heads: h.heads, d: h.d };
    let first_block = if stage == 0 { 1 } else { 0 };
    for blk in 0..h.blocks_per_stage {
        let p = |i: usize| pvars[first_block + blk * 10 + i];
        let (ln1_g, ln1_b) = (p(0), p(1));
        let (wq, wk, wv, wp1) = (p(2), p(3), p(4), p(5));
        let (ln2_g, ln2_b) = (p(6), p(7));
        let (w1, wp2) = (p(8), p(9));

        let a = tape.layer_norm(x, ln1_g, ln1_b);
        let q = tape.matmul(a, wq);
        let k = tape.matmul(a, wk);
        let v = tape.matmul(a, wv);
        let attn = tape.causal_attention(q, k, v, dims);
        let attn_out = tape.matmul(attn, wp1);
        x = tape.add(x, attn_out);

        let hn = tape.layer_norm(x, ln2_g, ln2_b);
        let h1 = tape.matmul(hn, w1);
        let h1 = tape.relu(h1);
        let mlp_out = tape.matmul(h1, wp2);
        x = tape.add(x, mlp_out);
    }

    // ---- stage output
    let mut pre_boundary = None;
    let output = if last {
        let base = first_block + h.blocks_per_stage * 10;
        let xl = tape.layer_norm(x, pvars[base], pvars[base + 1]);
        let logits = tape.matmul(xl, pvars[base + 2]);
        tape.cross_entropy(
            logits,
            io.targets.expect("last stage needs targets"),
        )
    } else {
        pre_boundary = Some(x);
        if let Some(u) = u {
            let centered = tape.sub(x, e.expect("compressed stages use E"));
            tape.matmul(centered, u)
        } else {
            x
        }
    };

    BuiltStage {
        tape,
        params: pvars,
        input: input_var,
        output,
        x_full,
        pre_boundary,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::stage::{GlobalState, StageState};

    fn setup(mode: Mode) -> (Hyper, GlobalState, Vec<StageState>, Rng) {
        let h = Hyper::tiny_native();
        let mut rng = Rng::new(7);
        let global = GlobalState::from_hyper(&h, &mut rng);
        let stages = (0..h.stages)
            .map(|s| {
                StageState::from_schema(
                    h.stage_schema(s),
                    h.stage_kind(s),
                    s,
                    mode,
                    &global,
                    &mut rng,
                )
                .unwrap()
            })
            .collect();
        (h, global, stages, rng)
    }

    fn batch(h: &Hyper, rng: &mut Rng) -> (IntTensor, IntTensor) {
        let draw = |rng: &mut Rng| {
            IntTensor::new(
                vec![h.b, h.n],
                (0..h.b * h.n).map(|_| rng.below(h.vocab) as i32).collect(),
            )
        };
        (draw(rng), draw(rng))
    }

    #[test]
    fn pe_matches_reference_values() {
        let pe = sinusoidal_pe(8, 4);
        // pos 0: sin(0)=0, cos(0)=1 alternating
        assert_eq!(pe.at2(0, 0), 0.0);
        assert_eq!(pe.at2(0, 1), 1.0);
        // pos 1, i=0: sin(1)
        assert!((pe.at2(1, 0) - (1.0f32).sin()).abs() < 1e-6);
        // pos 1, i=2: sin(1/10000^(2/4)) = sin(0.01)
        assert!((pe.at2(1, 2) - (0.01f32).sin()).abs() < 1e-6);
    }

    #[test]
    fn subspace_boundary_payload_is_lossless() {
        // forward a microbatch through stage 0; the projected payload,
        // reconstructed, must reproduce x exactly up to fp rounding
        // (rows of x − e lie in S by construction: t_s, wp1, wp2 ∈ S)
        let (h, global, stages, mut rng) = setup(Mode::Subspace);
        let (tok, _) = batch(&h, &mut rng);
        let pe = sinusoidal_pe(h.n, h.d);
        let e = high_rank_e(&h, Mode::Subspace, &pe, &global.t_fixed, &tok);
        let built = build_stage(
            &h,
            Mode::Subspace,
            0,
            &stages[0].params,
            StageIo {
                u: &global.u,
                e: &e,
                tok: &tok,
                input: None,
                targets: None,
            },
        );
        let payload = built.tape.value(built.output);
        assert_eq!(payload.shape, vec![h.b * h.n, h.k]);
        // losslessness (Eq. 7): the residual stream minus E lies in S by
        // construction (t_s, wp1, wp2 rows ∈ S), so projecting onto U and
        // reconstructing loses nothing
        let x = built.tape.value(built.pre_boundary.unwrap());
        let mut centered = x.clone();
        let mut neg = e.clone();
        neg.scale(-1.0);
        centered.add_assign(&neg);
        let leak = crate::linalg::out_of_subspace_norm(&centered, &global.u);
        let norm = centered.frobenius_norm() as f64 + 1e-12;
        assert!(leak / norm < 1e-4, "boundary payload leaks {}", leak / norm);
        // and the reconstruction round-trips to x
        let mut recon = crate::linalg::matmul_nt(payload, &global.u);
        recon.add_assign(&e);
        let err: f64 = recon
            .data
            .iter()
            .zip(&x.data)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let xnorm = x.frobenius_norm() as f64 + 1e-12;
        assert!(err / xnorm < 1e-4, "reconstruction error {}", err / xnorm);
    }

    #[test]
    fn loss_is_finite_and_backward_fills_all_param_grads() {
        for mode in [Mode::Subspace, Mode::Raw, Mode::NoFixed] {
            let (h, global, stages, mut rng) = setup(mode);
            let (tok, tgt) = batch(&h, &mut rng);
            let pe = sinusoidal_pe(h.n, h.d);
            let e = high_rank_e(&h, mode, &pe, &global.t_fixed, &tok);
            let compressed = mode.compressed();
            // run the forward wave to the last stage
            let mut cur: Option<Tensor> = None;
            for s in 0..h.stages - 1 {
                let built = build_stage(
                    &h,
                    mode,
                    s,
                    &stages[s].params,
                    StageIo {
                        u: &global.u,
                        e: &e,
                        tok: &tok,
                        input: cur.as_ref(),
                        targets: None,
                    },
                );
                cur = Some(built.tape.value(built.output).clone());
            }
            let last = h.stages - 1;
            let mut built = build_stage(
                &h,
                mode,
                last,
                &stages[last].params,
                StageIo {
                    u: &global.u,
                    e: &e,
                    tok: &tok,
                    input: cur.as_ref(),
                    targets: Some(&tgt),
                },
            );
            let loss = built.tape.value(built.output).item();
            assert!(loss.is_finite() && loss > 0.0, "{mode:?} loss {loss}");
            // random-ish init: loss should be near ln(vocab)
            let uniform = (h.vocab as f32).ln();
            assert!(
                (loss - uniform).abs() < 1.5,
                "{mode:?} init loss {loss} vs ln V {uniform}"
            );
            built.tape.backward(built.output);
            for (i, p) in built.params.iter().enumerate() {
                let g = built.tape.grad(*p).unwrap_or_else(|| {
                    panic!("{mode:?} param {i} got no gradient")
                });
                assert!(g.data.iter().all(|x| x.is_finite()));
            }
            let gin = built
                .tape
                .grad(built.input.unwrap())
                .expect("boundary input gradient");
            let want_cols = if compressed { h.k } else { h.d };
            assert_eq!(gin.shape, vec![h.b * h.n, want_cols]);
        }
    }
}
