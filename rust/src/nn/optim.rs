//! Optimizers for the native backend — AdamW plus the Sec. 5
//! subspace-preserving variant, and plain SGD(+momentum) as a baseline.
//!
//! Mirror of `python/compile/optim.py`. Per-parameter rules in subspace
//! mode:
//!
//! * `*_wp2`, `t_s` — gradient projected onto S, then a **row-constant**
//!   second-moment scaling, which keeps Row(W) ⊆ S exactly without ever
//!   re-projecting W (Appendix A);
//! * `*_wp1` — standard AdamW followed by an explicit row projection
//!   onto S (the attention nonlinearity upstream breaks the row-wise
//!   argument);
//! * everything else — standard AdamW.
//!
//! Raw/lossy modes use standard AdamW for every parameter. LayerNorm
//! gains/biases are excluded from weight decay. SGD under the subspace
//! rules projects the constrained gradients onto S, which (updates being
//! linear) preserves the constraint without re-projection.

use anyhow::{bail, Result};

use crate::linalg::project_rows;
use crate::stage::{constrained, StageState};
use crate::tensor::Tensor;

/// Adam first-moment decay.
pub const BETA1: f32 = 0.9;
/// Adam second-moment decay.
pub const BETA2: f32 = 0.999;
/// Adam denominator epsilon.
pub const EPS: f32 = 1e-8;
/// Decoupled weight decay (skipped for `*_g` / `*_b` norm parameters).
pub const WEIGHT_DECAY: f32 = 0.01;

/// Which optimizer the native backend steps with.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Optim {
    /// AdamW (paper default; subspace rules as in Sec. 5)
    AdamW,
    /// SGD with momentum (0.0 = plain SGD)
    Sgd {
        /// momentum coefficient in [0, 1)
        momentum: f32,
    },
}

impl Optim {
    /// Parse a CLI label: `"adamw"`, `"sgd"`, `"sgd:<momentum>"`.
    pub fn parse(s: &str) -> Result<Optim> {
        if s == "adamw" {
            return Ok(Optim::AdamW);
        }
        if s == "sgd" {
            return Ok(Optim::Sgd { momentum: 0.0 });
        }
        if let Some(rest) = s.strip_prefix("sgd:") {
            let momentum: f32 = rest
                .parse()
                .map_err(|_| anyhow::anyhow!("bad momentum {rest:?}"))?;
            if !(0.0..1.0).contains(&momentum) {
                bail!("momentum {momentum} outside [0, 1)");
            }
            return Ok(Optim::Sgd { momentum });
        }
        bail!("unknown optimizer {s:?} (have adamw, sgd, sgd:<momentum>)")
    }

    /// Canonical label.
    pub fn as_str(&self) -> &'static str {
        match self {
            Optim::AdamW => "adamw",
            Optim::Sgd { .. } => "sgd",
        }
    }
}

/// Schedule-dependent scalars of one optimizer step.
#[derive(Clone, Copy, Debug)]
pub struct OptStep<'a> {
    /// which optimizer
    pub optim: Optim,
    /// `Some(U)` applies the subspace closure rules; `None` = raw rules
    pub u: Option<&'a Tensor>,
    /// learning rate after warmup/decay
    pub lr: f32,
    /// 1-based step count (Adam bias correction)
    pub t: f32,
}

fn decay_for(name: &str) -> f32 {
    if name.ends_with("_g") || name.ends_with("_b") {
        0.0
    } else {
        WEIGHT_DECAY
    }
}

/// One optimizer step over a whole stage's parameters (schema order).
pub fn step_stage(st: &mut StageState, grads: &[Tensor], ctx: &OptStep<'_>) {
    debug_assert_eq!(grads.len(), st.params.len());
    let bc1 = 1.0 - BETA1.powf(ctx.t);
    let bc2 = 1.0 - BETA2.powf(ctx.t);
    for i in 0..st.params.len() {
        let name = st.schema[i].0.clone();
        let wd = decay_for(&name);
        let g = &grads[i];
        match (ctx.optim, ctx.u) {
            (Optim::AdamW, Some(u)) => {
                if name.ends_with("wp2") || name == "t_s" {
                    rowwise_adamw(
                        &mut st.params[i],
                        g,
                        &mut st.m[i],
                        &mut st.v[i],
                        u,
                        (ctx.lr, bc1, bc2, wd),
                    );
                } else if name.ends_with("wp1") {
                    standard_adamw(
                        &mut st.params[i],
                        g,
                        &mut st.m[i],
                        &mut st.v[i],
                        (ctx.lr, bc1, bc2, wd),
                    );
                    st.params[i] = project_rows(&st.params[i], u);
                } else {
                    standard_adamw(
                        &mut st.params[i],
                        g,
                        &mut st.m[i],
                        &mut st.v[i],
                        (ctx.lr, bc1, bc2, wd),
                    );
                }
            }
            (Optim::AdamW, None) => standard_adamw(
                &mut st.params[i],
                g,
                &mut st.m[i],
                &mut st.v[i],
                (ctx.lr, bc1, bc2, wd),
            ),
            (Optim::Sgd { momentum }, u) => {
                let gp = match u {
                    Some(u) if constrained(&name) => project_rows(g, u),
                    _ => g.clone(),
                };
                sgd(&mut st.params[i], &gp, &mut st.m[i], momentum, ctx.lr, wd);
            }
        }
    }
}

/// Standard AdamW on one parameter. `h = (lr, 1−β1ᵗ, 1−β2ᵗ, wd)`.
fn standard_adamw(
    w: &mut Tensor,
    g: &Tensor,
    m: &mut Tensor,
    v: &mut Tensor,
    h: (f32, f32, f32, f32),
) {
    let (lr, bc1, bc2, wd) = h;
    for i in 0..w.data.len() {
        let gi = g.data[i];
        let mi = BETA1 * m.data[i] + (1.0 - BETA1) * gi;
        let vi = BETA2 * v.data[i] + (1.0 - BETA2) * gi * gi;
        m.data[i] = mi;
        v.data[i] = vi;
        let mhat = mi / bc1;
        let vhat = vi / bc2;
        w.data[i] -=
            lr * mhat / (vhat.sqrt() + EPS) + lr * wd * w.data[i];
    }
}

/// Sec. 5 row-wise AdamW for W_p2 / T_S: project g onto S, then make the
/// 1/√V̂ scaling constant per row so the update stays inside Row(W) ⊆ S.
fn rowwise_adamw(
    w: &mut Tensor,
    g: &Tensor,
    m: &mut Tensor,
    v: &mut Tensor,
    u: &Tensor,
    h: (f32, f32, f32, f32),
) {
    let (lr, bc1, bc2, wd) = h;
    let gp = project_rows(g, u);
    let (rows, cols) = w.dims2();
    for r in 0..rows {
        let base = r * cols;
        // moments first, then the row-mean of v̂
        let mut vrow = 0.0f64;
        for c in 0..cols {
            let gi = gp.data[base + c];
            let mi = BETA1 * m.data[base + c] + (1.0 - BETA1) * gi;
            let vi = BETA2 * v.data[base + c] + (1.0 - BETA2) * gi * gi;
            m.data[base + c] = mi;
            v.data[base + c] = vi;
            vrow += (vi / bc2) as f64;
        }
        let denom = (vrow / cols as f64).sqrt() as f32 + EPS;
        for c in 0..cols {
            let mhat = m.data[base + c] / bc1;
            w.data[base + c] -=
                lr * mhat / denom + lr * wd * w.data[base + c];
        }
    }
}

/// SGD with momentum; the momentum buffer lives in the stage's `m` slot.
fn sgd(
    w: &mut Tensor,
    g: &Tensor,
    m: &mut Tensor,
    momentum: f32,
    lr: f32,
    wd: f32,
) {
    for i in 0..w.data.len() {
        let mi = momentum * m.data[i] + g.data[i];
        m.data[i] = mi;
        w.data[i] -= lr * mi + lr * wd * w.data[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Mode;
    use crate::linalg::{out_of_subspace_norm, random_orthonormal};
    use crate::manifest::Hyper;
    use crate::rng::Rng;
    use crate::stage::GlobalState;

    fn tiny_stage(mode: Mode, rng: &mut Rng) -> (StageState, GlobalState, Hyper) {
        let h = Hyper::tiny_native();
        let global = GlobalState::from_hyper(&h, rng);
        let st = StageState::from_schema(
            h.stage_schema(1),
            "mid",
            1,
            mode,
            &global,
            rng,
        )
        .unwrap();
        (st, global, h)
    }

    #[test]
    fn parse_roundtrip_and_validation() {
        assert_eq!(Optim::parse("adamw").unwrap(), Optim::AdamW);
        assert_eq!(
            Optim::parse("sgd").unwrap(),
            Optim::Sgd { momentum: 0.0 }
        );
        assert_eq!(
            Optim::parse("sgd:0.9").unwrap(),
            Optim::Sgd { momentum: 0.9 }
        );
        assert!(Optim::parse("sgd:1.5").is_err());
        assert!(Optim::parse("lion").is_err());
    }

    #[test]
    fn adamw_step_moves_against_gradient() {
        let mut w = Tensor::new(vec![2, 2], vec![1.0, -1.0, 0.5, 0.0]);
        let g = Tensor::new(vec![2, 2], vec![1.0, -1.0, 1.0, -1.0]);
        let mut m = Tensor::zeros(&[2, 2]);
        let mut v = Tensor::zeros(&[2, 2]);
        let before = w.clone();
        standard_adamw(&mut w, &g, &mut m, &mut v, (0.1, 0.1, 0.001, 0.0));
        for i in 0..4 {
            let delta = w.data[i] - before.data[i];
            assert!(
                delta * g.data[i] < 0.0,
                "update {delta} not against grad {}",
                g.data[i]
            );
        }
    }

    #[test]
    fn subspace_rules_keep_constrained_rows_in_s() {
        let mut rng = Rng::new(11);
        let (mut st, global, _h) = tiny_stage(Mode::Subspace, &mut rng);
        // noisy full-rank gradients — exactly what the closure must absorb
        let grads: Vec<Tensor> = st
            .params
            .iter()
            .map(|p| {
                Tensor::new(
                    p.shape.clone(),
                    rng.normal_f32_vec(p.numel(), 0.1),
                )
            })
            .collect();
        for optim in [Optim::AdamW, Optim::Sgd { momentum: 0.9 }] {
            let mut st2 = st.clone();
            for t in 1..=5 {
                step_stage(
                    &mut st2,
                    &grads,
                    &OptStep {
                        optim,
                        u: Some(&global.u),
                        lr: 1e-2,
                        t: t as f32,
                    },
                );
            }
            let leak = st2.subspace_leak(&global.u);
            assert!(leak < 1e-4, "{optim:?} leak {leak}");
        }
        // raw rules on the same gradients leak immediately
        step_stage(
            &mut st,
            &grads,
            &OptStep { optim: Optim::AdamW, u: None, lr: 1e-2, t: 1.0 },
        );
        assert!(st.subspace_leak(&global.u) > 1e-3);
    }

    #[test]
    fn rowwise_update_direction_is_in_s() {
        // one rowwise step from W ∈ S must land back in S even with an
        // out-of-S gradient
        let mut rng = Rng::new(12);
        let u = random_orthonormal(32, 4, &mut rng);
        let w0 = Tensor::new(vec![16, 32], rng.normal_f32_vec(512, 0.1));
        let mut w = project_rows(&w0, &u);
        let g = Tensor::new(vec![16, 32], rng.normal_f32_vec(512, 1.0));
        let mut m = Tensor::zeros(&[16, 32]);
        let mut v = Tensor::zeros(&[16, 32]);
        rowwise_adamw(&mut w, &g, &mut m, &mut v, &u, (0.05, 0.1, 0.001, 0.01));
        let leak = out_of_subspace_norm(&w, &u)
            / (w.frobenius_norm() as f64 + 1e-12);
        assert!(leak < 1e-5, "leak {leak}");
    }
}
