//! Native autodiff training backend — the subsystem that turns the repo
//! from a cost-model simulator into a trainer (DESIGN.md §10).
//!
//! Three layers, all dependency-free on top of [`crate::linalg`]:
//!
//! - [`tape`] — reverse-mode autodiff over a flat op tape (matmuls,
//!   residual add/sub, ReLU, LayerNorm, fused causal attention,
//!   embedding gather, softmax cross-entropy), thread-count-bit-stable;
//! - [`model`] — the paper's decoder-only transformer partitioned into
//!   per-stage subgraphs, with the subspace boundary pair
//!   (project `(X−E)·U` / reconstruct `Xc·Uᵀ+E`) *on the tape* so the
//!   backward wire payload is the exact coefficient cotangent;
//! - [`optim`] — AdamW with the Sec. 5 subspace closure rules (row-wise
//!   second moment for `W_p2`/`T_S`, post-step projection for `W_p1`)
//!   plus SGD, mirroring `python/compile/optim.py`;
//! - [`decode`] — the tape-free serving forward: per-session KV caches
//!   and single-row kernels mirroring the tape arithmetic, feeding the
//!   `serve-infer` decode pipeline (DESIGN.md §16);
//! - [`pipeline`] — [`NativePipeline`], the artifact-free sibling of
//!   [`crate::coordinator::Pipeline`]: same config, stats, netsim byte
//!   accounting and virtual clock, but with every activation and
//!   activation-gradient computed in-process and routed through the
//!   real [`crate::compress`] codecs at stage boundaries.
//!
//! The point: the paper's convergence-parity claim (subspace loss curves
//! match raw at a fraction of the wire bytes, while lossy baselines at
//! matched bytes degrade) is *measured* here, per step, instead of being
//! priced analytically — see `exp convergence-native` and
//! `examples/native_convergence.rs`.

pub mod decode;
pub mod model;
pub mod optim;
pub mod pipeline;
pub mod tape;

pub use decode::{argmax, StageDecoder, StageKv};
pub use optim::Optim;
pub use pipeline::{
    encode_boundary, grassmann_step_u, reproject_stage, BoundaryDir,
    NativePipeline, PendingStep,
};
pub use tape::{AttnDims, Tape, Var};
